"""Streaming SAFL aggregation service, standalone (no virtual clock).

Builds a ``StreamingAggregator`` with a quorum trigger and
staleness-bounded admission, feeds it a synthetic semi-asynchronous
update stream, checkpoints it mid-stream, then resumes into a fresh
service and verifies the resumed state picks up where it left off.

    PYTHONPATH=src python examples/stream_aggregation.py [--updates 300]
"""
import argparse
import sys, os, tempfile
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--updates", type=int, default=300)
    ap.add_argument("--clients", type=int, default=48)
    ap.add_argument("--algo", default="fedqs-sgd")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from repro.core import FedQSHyperParams, make_algorithm
    from repro.models import make_mlp_spec
    from repro.serve import (
        Quorum, StalenessAdmission, StreamingAggregator, replay, synthetic_stream,
    )

    hp = FedQSHyperParams(buffer_k=8)
    spec = make_mlp_spec()
    params = spec.init(jax.random.PRNGKey(args.seed))

    def build():
        return StreamingAggregator(
            make_algorithm(args.algo, hp), hp, params, args.clients,
            trigger=Quorum(k=8, quorum=4, grace=5.0),
            admission=StalenessAdmission(tau_max=3, mode="downweight"),
            on_round=lambda rep: print(
                f"  round {rep.round:3d}  K={rep.n_updates:2d} "
                f"distinct={rep.n_distinct:2d} stale_max={rep.max_staleness} "
                f"dropped={rep.dropped_since_last} agg={rep.agg_seconds*1e3:.1f}ms"
            ),
        )

    stream = list(synthetic_stream(params, args.clients, args.updates,
                                   seed=args.seed))
    half = len(stream) // 2

    print(f"phase 1: ingest {half} updates")
    svc = build()
    replay(svc, stream[:half], flush=False)
    ckpt = os.path.join(tempfile.gettempdir(), "stream_agg_ck")
    svc.save(ckpt)
    print(f"checkpointed at round {svc.round} → {ckpt}")

    print(f"phase 2: resume and ingest the remaining {len(stream) - half}")
    svc2 = build()
    svc2.restore(ckpt)
    assert svc2.round == svc.round, "resume must restore the round counter"
    replay(svc2, stream[half:])

    s = svc2.stats
    drift = max(
        float(np.abs(np.asarray(a) - np.asarray(b)).max())
        for a, b in zip(jax.tree_util.tree_leaves(svc2.global_params),
                        jax.tree_util.tree_leaves(params))
    )
    print(f"done: {s.rounds} resumed-service rounds, {s.downweighted} downweighted, "
          f"{s.dropped} dropped; |global - init|_max = {drift:.2e}")


if __name__ == "__main__":
    main()
