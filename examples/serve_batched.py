"""Batched serving example (deliverable b): serve a reduced gemma3-style
model with mixed-length batched requests through prefill + decode,
exercising the ring-buffer KV caches and the window-attention path.

    PYTHONPATH=src python examples/serve_batched.py --arch gemma3-1b
"""
import argparse
import sys, os, time
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=24)
    args = ap.parse_args()

    from repro.configs import get_reduced
    from repro.models import transformer as T

    cfg = get_reduced(args.arch)
    key = jax.random.PRNGKey(0)
    params = T.init_params(cfg, key)

    # mixed-length requests, left-padded into one batch
    rng = np.random.default_rng(0)
    lens = rng.integers(4, 14, args.requests)
    max_len = int(lens.max())
    max_seq = max_len + args.max_new + 1
    prompts = np.zeros((args.requests, max_len), np.int32)
    for i, L in enumerate(lens):
        prompts[i, max_len - L:] = rng.integers(1, cfg.vocab, L)

    me = None
    if cfg.frontend != "none":
        me = jax.random.normal(key, (args.requests, cfg.n_frontend_tokens, cfg.d_model))

    prefill = jax.jit(lambda p, t: T.prefill(cfg, p, t, me, max_seq=max_seq))
    decode = jax.jit(lambda p, c, t: T.decode_step(cfg, p, c, t, me))

    t0 = time.perf_counter()
    logits, cache = prefill(params, jnp.asarray(prompts))
    toks = jnp.argmax(logits, -1)
    outs = [np.asarray(toks)]
    for _ in range(args.max_new):
        logits, cache = decode(params, cache, toks)
        toks = jnp.argmax(logits, -1)
        outs.append(np.asarray(toks))
    jax.block_until_ready(toks)
    dt = time.perf_counter() - t0
    gen = np.stack(outs, 1)
    print(f"served {args.requests} reqs (len {lens.min()}–{lens.max()}), "
          f"{args.max_new} new tokens each, in {dt*1e3:.0f} ms "
          f"({args.requests*args.max_new/dt:.1f} tok/s on CPU, reduced cfg)")
    for i in range(min(3, args.requests)):
        print(f"  req[{i}] len={lens[i]:2d} → {gen[i, :10].tolist()} ...")


if __name__ == "__main__":
    main()
