"""Quickstart: FedQS vs its foundational baselines on a non-IID task.

Runs FedQS-SGD, FedQS-Avg, FedSGD and FedAvg in the semi-asynchronous
engine (100 heterogeneous clients, 1:50 resources, buffered K=10) on the
Adult-like tabular task, and prints the Table-2-style comparison.

    PYTHONPATH=src python examples/quickstart.py [--rounds 100]
"""
import argparse
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import FedQSHyperParams, SAFLEngine, make_algorithm
from repro.data import make_federated_data
from repro.models import make_mlp_spec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=80)
    ap.add_argument("--clients", type=int, default=40)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    data = make_federated_data("rwd", args.clients, sigma=1.2, seed=args.seed,
                               n_total=4000)
    spec = make_mlp_spec()
    hp = FedQSHyperParams(buffer_k=max(4, args.clients // 10))

    print(f"{'algorithm':12s} {'best_acc':>9s} {'final_acc':>9s} "
          f"{'conv@95%':>9s} {'#osc':>5s} {'virt_time':>9s}")
    results = {}
    for name in ("fedavg", "fedqs-avg", "fedsgd", "fedqs-sgd"):
        eng = SAFLEngine(data, spec, make_algorithm(name, hp), hp,
                         seed=args.seed, eval_every=2)
        res = eng.run(args.rounds)
        results[name] = res
        target = 0.95 * res.final_accuracy()
        conv = res.rounds_to_accuracy(target)
        print(f"{name:12s} {res.best_accuracy():9.4f} {res.final_accuracy():9.4f} "
              f"{str(conv):>9s} {res.oscillations(0.05):5d} {res.virtual_time():9.1f}")

    gain_avg = results["fedqs-avg"].final_accuracy() - results["fedavg"].final_accuracy()
    gain_sgd = results["fedqs-sgd"].final_accuracy() - results["fedsgd"].final_accuracy()
    print(f"\nFedQS-Avg vs FedAvg: {gain_avg:+.4f}   FedQS-SGD vs FedSGD: {gain_sgd:+.4f}")


if __name__ == "__main__":
    main()
