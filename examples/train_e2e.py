"""End-to-end driver (deliverable b): train a model with FedQS for a few
hundred rounds on the CV task family (ResNet-analogue CNN on Dirichlet
non-IID image data), with checkpointing and a convergence report.

Default is a laptop-scale run; ``--big`` switches to the widest CNN this
container can train in reasonable time.

    PYTHONPATH=src python examples/train_e2e.py --rounds 200
"""
import argparse
import sys, os, time
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.checkpoint import save_server_state
from repro.core import FedQSHyperParams, SAFLEngine, make_algorithm
from repro.data import make_federated_data
from repro.models import make_cnn_spec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=200)
    ap.add_argument("--clients", type=int, default=50)
    ap.add_argument("--alpha", type=float, default=0.5, help="Dirichlet x")
    ap.add_argument("--algo", default="fedqs-sgd")
    ap.add_argument("--big", action="store_true")
    ap.add_argument("--ckpt", default="/tmp/fedqs_e2e_ckpt")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    width = 32 if args.big else 12
    data = make_federated_data("cv", args.clients, alpha=args.alpha,
                               seed=args.seed, n_total=4000)
    spec = make_cnn_spec(width=width, batch_size=32)
    hp = FedQSHyperParams(buffer_k=max(4, args.clients // 10))
    eng = SAFLEngine(data, spec, make_algorithm(args.algo, hp), hp,
                     seed=args.seed, eval_every=5)

    print(f"training CNN(width={width}) with {args.algo} on Dirichlet(x={args.alpha}) "
          f"CV task, N={args.clients}, K={hp.buffer_k}, rounds={args.rounds}")
    t0 = time.time()
    res = eng.run(args.rounds)
    for m in res.metrics[:: max(1, len(res.metrics) // 15)]:
        print(f"  round {m.round:4d}  loss={m.loss:.4f}  acc={m.accuracy:.4f}  "
              f"stale={m.n_stale}/{hp.buffer_k}  mean_staleness={m.mean_staleness:.2f}")
    print(f"\nbest={res.best_accuracy():.4f} final={res.final_accuracy():.4f} "
          f"osc={res.oscillations()} wall={time.time()-t0:.1f}s")
    save_server_state(args.ckpt, eng)
    print("server state checkpointed →", args.ckpt)


if __name__ == "__main__":
    main()
