"""Compressed update transport end to end (docs/COMPRESSION.md).

Feeds the same synthetic semi-asynchronous stream through the streaming
service three ways — dense fp32, int8, and topk|int8 with error
feedback — and reports wire bytes, rounds, and how far each compressed
global model lands from the dense one.  Finishes with a checkpoint /
resume of the codec state (the error-feedback residual bank).

    PYTHONPATH=src python examples/compressed_stream.py [--updates 300]
"""
import argparse
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np


def gap(a, b):
    return max(
        float(np.abs(np.asarray(x) - np.asarray(y)).max())
        for x, y in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b))
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--updates", type=int, default=300)
    ap.add_argument("--clients", type=int, default=48)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true", help="reduced sizes (CI)")
    args = ap.parse_args()
    if args.smoke:
        args.updates = 120

    from repro.compress import ClientCompressor, compress_stream
    from repro.core import FedQSHyperParams, make_algorithm
    from repro.core.types import AggregationStrategy
    from repro.models import make_mlp_spec
    from repro.serve import StreamingAggregator, replay, synthetic_stream

    hp = FedQSHyperParams(buffer_k=8)
    spec = make_mlp_spec()
    params = spec.init(jax.random.PRNGKey(args.seed))
    base = list(synthetic_stream(params, args.clients, args.updates,
                                 seed=args.seed))
    dense_bytes = 4 * sum(l.size for l in jax.tree_util.tree_leaves(params))

    def serve(codec_spec):
        svc = StreamingAggregator(make_algorithm("fedqs-sgd", hp), hp, params,
                                  args.clients, batched=True)
        comp = None
        stream = base
        if codec_spec:
            comp = ClientCompressor(codec_spec, args.clients, seed=args.seed)
            svc.compressor = comp
            stream = compress_stream(iter(base), comp,
                                     strategy=AggregationStrategy.GRADIENT)
        replay(svc, stream)
        return svc, comp

    print(f"{args.updates} updates, {args.clients} clients, "
          f"dense payload = {dense_bytes} bytes/update")
    dense_svc, _ = serve(None)
    for codec_spec in ("int8", "topk:0.1|int8"):
        svc, comp = serve(codec_spec)
        s = comp.stats
        print(f"  {codec_spec:14s} {s.bytes_per_update:7.0f} bytes/update "
              f"({s.ratio:4.1f}x smaller)  rounds={svc.stats.rounds:3d}  "
              f"|global - dense|_max = {gap(svc.global_params, dense_svc.global_params):.2e}")

    # checkpoint the compressed service mid-stream, resume, keep going
    half = len(base) // 2
    svc = StreamingAggregator(make_algorithm("fedqs-sgd", hp), hp, params,
                              args.clients, batched=True)
    comp = ClientCompressor("topk:0.1|int8", args.clients, seed=args.seed)
    svc.compressor = comp
    replay(svc, compress_stream(iter(base[:half]), comp,
                                strategy=AggregationStrategy.GRADIENT),
           flush=False)
    ckpt = os.path.join(tempfile.gettempdir(), "compressed_stream_ck")
    svc.save(ckpt)

    svc2 = StreamingAggregator(make_algorithm("fedqs-sgd", hp), hp, params,
                               args.clients, batched=True)
    comp2 = ClientCompressor("topk:0.1|int8", args.clients, seed=args.seed)
    svc2.compressor = comp2
    svc2.restore(ckpt)
    assert svc2.round == svc.round, "resume must restore the round counter"
    assert np.array_equal(comp2.residual, comp.residual), \
        "resume must restore the error-feedback residual bank"
    replay(svc2, compress_stream(iter(base[half:]), comp2,
                                 strategy=AggregationStrategy.GRADIENT))
    print(f"checkpoint/resume: residual bank restored at round {svc.round}, "
          f"resumed service reached round {svc2.round}")


if __name__ == "__main__":
    main()
