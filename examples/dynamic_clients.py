"""Paper §5.3 dynamic-environment scenarios (Table 6 analogue):
resource-scale shift, unstable per-client resources, 50% client dropout —
demonstrating FedQS's robustness hooks.

    PYTHONPATH=src python examples/dynamic_clients.py
"""
import argparse
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import FedQSHyperParams, SAFLEngine, make_algorithm
from repro.core.safl import (
    scenario_dropout,
    scenario_resource_scale,
    scenario_unstable_resources,
)
from repro.data import make_federated_data
from repro.models import make_mlp_spec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=60)
    ap.add_argument("--clients", type=int, default=30)
    args = ap.parse_args()

    data = make_federated_data("rwd", args.clients, sigma=1.2, seed=2, n_total=3000)
    spec = make_mlp_spec()
    hp = FedQSHyperParams(buffer_k=5)

    scenarios = {
        "static": None,
        "scenario1: ratio 1:50→1:100 @r20": scenario_resource_scale(20, 100.0),
        "scenario2: ±10 unit jitter": scenario_unstable_resources(),
        "scenario3: 50% dropout @r15": scenario_dropout(15, 0.5),
    }
    for sname, dyn in scenarios.items():
        print(f"\n== {sname} ==")
        for algo in ("fedsgd", "fedqs-sgd"):
            eng = SAFLEngine(data, spec, make_algorithm(algo, hp), hp,
                             seed=2, eval_every=3, dynamics=dyn)
            res = eng.run(args.rounds)
            print(f"  {algo:10s} best={res.best_accuracy():.4f} "
                  f"final={res.final_accuracy(5):.4f} osc={res.oscillations(0.05)}")


if __name__ == "__main__":
    main()
