"""Simulate a churning client population — the scenario-engine walkthrough.

Three acts:

1. the paper-faithful event engine under the ``churn`` scenario (clients
   leave every few rounds, the departed rejoin later), FedQS vs FedSGD;
2. the same comparison under ``diurnal`` availability (day/night arrival
   waves — the buffer fills slowly at night, so staleness spikes);
3. the vectorized cohort fast path scaling the diurnal-churn scenario to
   thousands of clients without a per-client Python loop.

    PYTHONPATH=src python examples/scenario_churn.py
    PYTHONPATH=src python examples/scenario_churn.py --smoke   # CI-sized
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import FedQSHyperParams, SAFLEngine, make_algorithm
from repro.data import make_federated_data
from repro.models import make_mlp_spec
from repro.scenarios import CohortEngine, get_scenario


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=40)
    ap.add_argument("--clients", type=int, default=30)
    ap.add_argument("--cohort-clients", type=int, default=2000)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes for CI smoke runs")
    args = ap.parse_args()
    if args.smoke:
        args.rounds, args.clients, args.cohort_clients = 6, 10, 200

    data = make_federated_data("rwd", args.clients, sigma=1.2, seed=2,
                               n_total=2000)
    spec = make_mlp_spec()
    hp = FedQSHyperParams(buffer_k=max(3, args.clients // 6))

    for sname in ("churn", "diurnal"):
        scn = get_scenario(sname)
        print(f"\n== {scn.describe()} ==")
        for algo in ("fedsgd", "fedqs-sgd"):
            eng = SAFLEngine(data, spec, make_algorithm(algo, hp), hp,
                             seed=2, eval_every=3, scenario=scn)
            res = eng.run(args.rounds)
            stale = sum(m.n_stale for m in res.metrics)
            print(f"  {algo:10s} best={res.best_accuracy():.4f} "
                  f"final={res.final_accuracy(5):.4f} "
                  f"alive={int(eng.alive.sum())}/{args.clients} "
                  f"stale_updates={stale} vt={res.virtual_time():.0f}")

    n = args.cohort_clients
    k = max(16, n // 16)
    print(f"\n== cohort fast path: diurnal-churn @ N={n}, K={k} ==")
    eng = CohortEngine(get_scenario("diurnal-churn"), n,
                       hp=FedQSHyperParams(buffer_k=k), cohort_k=k,
                       seed=0, eval_every=2)
    res = eng.run(args.rounds)
    served = eng.service.stats.accepted
    print(f"  {eng.round} rounds, {served} updates in {res.wall_seconds:.1f}s "
          f"({served / max(res.wall_seconds, 1e-9):.0f} updates/s) "
          f"best={res.best_accuracy():.4f} final={res.final_accuracy(3):.4f}")


if __name__ == "__main__":
    main()
