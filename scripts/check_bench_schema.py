#!/usr/bin/env python
"""Schema validator for the committed BENCH_*.json benchmark artifacts.

``bench_diff.py`` and the experiment tooling parse these artifacts, so a
row that drifts shape (a string us_per_call, a numeric derived value, a
missing key) would break the perf-regression gate silently.  This check
fails CI loudly instead.  Validated shape (benchmarks/common.py):

    {"suite": str, "fast": bool, "generated_unix": int, "wall_s": number,
     "results": [{"name": str, "us_per_call": number,
                  "derived": {str: str}}, ...]}

    python scripts/check_bench_schema.py            # validate ./BENCH_*.json
    python scripts/check_bench_schema.py path.json  # validate specific files
"""
from __future__ import annotations

import glob
import json
import sys
from typing import List


def validate_payload(doc: object, path: str = "<doc>") -> List[str]:
    """All schema violations in one artifact (empty list = valid)."""
    errors: List[str] = []
    if not isinstance(doc, dict):
        return [f"{path}: top level must be an object, got {type(doc).__name__}"]
    if not isinstance(doc.get("suite"), str):
        errors.append(f"{path}: 'suite' must be a string")
    if not isinstance(doc.get("fast"), bool):
        errors.append(f"{path}: 'fast' must be a bool")
    if not isinstance(doc.get("generated_unix"), int) \
            or isinstance(doc.get("generated_unix"), bool):
        errors.append(f"{path}: 'generated_unix' must be an int")
    if not isinstance(doc.get("wall_s"), (int, float)) \
            or isinstance(doc.get("wall_s"), bool):
        errors.append(f"{path}: 'wall_s' must be numeric")
    results = doc.get("results")
    if not isinstance(results, list):
        errors.append(f"{path}: 'results' must be a list")
        return errors
    seen = set()
    for i, row in enumerate(results):
        where = f"{path}: results[{i}]"
        if not isinstance(row, dict):
            errors.append(f"{where}: must be an object")
            continue
        name = row.get("name")
        if not isinstance(name, str) or not name:
            errors.append(f"{where}: 'name' must be a non-empty string")
        elif name in seen:
            errors.append(f"{where}: duplicate row name {name!r}")
        else:
            seen.add(name)
        us = row.get("us_per_call")
        if not isinstance(us, (int, float)) or isinstance(us, bool):
            errors.append(f"{where}: 'us_per_call' must be numeric, "
                          f"got {type(us).__name__}")
        derived = row.get("derived")
        if not isinstance(derived, dict):
            errors.append(f"{where}: 'derived' must be an object")
            continue
        for k, v in derived.items():
            if not isinstance(k, str):
                errors.append(f"{where}: derived key {k!r} must be a string")
            if not isinstance(v, str):
                errors.append(f"{where}: derived[{k!r}] must be a string "
                              f"(emit() stringifies), got {type(v).__name__}")
        # gate rows (serve_saturation, serve_straggler_adaptive, ...) abort
        # their suite on breach, so a committed artifact must never carry a
        # failed verdict — one that does means the artifact was hand-edited
        # or the suite stopped enforcing its own gate
        if derived.get("gate") not in (None, "True"):
            errors.append(f"{where}: gate row {name!r} recorded "
                          f"gate={derived['gate']!r}; a failing gate must "
                          f"abort the suite, not land in the artifact")
    return errors


def main(argv=None) -> int:
    paths = list(argv if argv is not None else sys.argv[1:])
    if not paths:
        paths = sorted(glob.glob("BENCH_*.json"))
    if not paths:
        print("check_bench_schema: no BENCH_*.json artifacts found")
        return 1
    errors: List[str] = []
    for path in paths:
        try:
            with open(path, encoding="utf-8") as fh:
                doc = json.load(fh)
        except (OSError, json.JSONDecodeError) as e:
            errors.append(f"{path}: unreadable: {e}")
            continue
        errs = validate_payload(doc, path)
        errors.extend(errs)
        if not errs:
            n = len(doc.get("results", []))
            print(f"check_bench_schema: {path}: OK ({n} rows)")
    for e in errors:
        print(f"check_bench_schema: {e}", file=sys.stderr)
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
