#!/usr/bin/env python
"""Docs link check: fail on broken relative links in README.md / docs/*.md.

Scans markdown inline links ``[text](target)``; external schemes
(http/https/mailto) and pure in-page anchors are skipped, ``#anchor``
suffixes on file targets are stripped, and each remaining target must
exist relative to the file that references it.  Run by scripts/ci.sh.
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

LINK_RE = re.compile(r"(?<!\!)\[[^\]]*\]\(([^)\s]+)\)")
SKIP_PREFIXES = ("http://", "https://", "mailto:", "#")


def check_file(path: Path) -> list:
    broken = []
    text = path.read_text(encoding="utf-8")
    for m in LINK_RE.finditer(text):
        target = m.group(1)
        if target.startswith(SKIP_PREFIXES) or "://" in target:
            continue
        rel = target.split("#", 1)[0]
        if not rel:
            continue
        if not (path.parent / rel).exists():
            line = text.count("\n", 0, m.start()) + 1
            broken.append((path, line, target))
    return broken


def main() -> int:
    root = Path(__file__).resolve().parent.parent
    files = [root / "README.md", *sorted((root / "docs").glob("*.md"))]
    broken = []
    for f in files:
        if f.exists():
            broken.extend(check_file(f))
    if broken:
        for path, line, target in broken:
            print(f"BROKEN LINK {path.relative_to(root)}:{line}: ({target})")
        return 1
    print(f"docs links OK ({len(files)} files)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
