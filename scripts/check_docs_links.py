#!/usr/bin/env python
"""Docs link check: fail on broken references in README.md / docs/*.md.

Three validation passes over markdown inline links ``[text](target)``
plus backticked path spans:

1. **relative file links** — external schemes (http/https/mailto) are
   skipped; each remaining target (minus any ``#anchor`` suffix) must
   exist relative to the file that references it;
2. **anchors** — pure in-page ``#anchor`` links and ``file.md#anchor``
   suffixes must match a heading in the target file, using GitHub's
   slugification (lowercase, punctuation stripped, spaces → hyphens,
   ``-N`` suffixes for duplicates);
3. **source paths** — any backticked span that looks like a repo path
   (``src/...``, ``benchmarks/...``, ``scripts/...``, ``examples/...``,
   ``tests/...``, ``experiments/...``) must exist relative to the repo
   root (a trailing ``::qualifier`` is ignored).

Run by scripts/ci.sh.
"""
from __future__ import annotations

import re
import sys
from collections import Counter
from pathlib import Path

LINK_RE = re.compile(r"(?<!\!)\[[^\]]*\]\(([^)\s]+)\)")
HEADING_RE = re.compile(r"^(#{1,6})\s+(.*?)\s*#*\s*$")
CODE_SPAN_RE = re.compile(r"`([^`\n]+)`")
SRC_PATH_RE = re.compile(
    r"^(?:src|benchmarks|scripts|examples|tests|experiments)/[\w\-./]+$")
FENCE_RE = re.compile(r"^\s*(```|~~~)")
SKIP_PREFIXES = ("http://", "https://", "mailto:")


def slugify(heading: str) -> str:
    """GitHub-style anchor slug for one heading line.

    Only formatting markers (backticks, asterisks) are stripped —
    literal underscores survive into GitHub anchors, so they must
    survive here too (``\\w`` keeps them through the punctuation pass).
    """
    s = re.sub(r"[`*]", "", heading.strip()).lower()
    s = re.sub(r"[^\w\- ]", "", s, flags=re.UNICODE)
    return s.replace(" ", "-")


def heading_slugs(text: str) -> set:
    """Every anchor GitHub generates for ``text`` (duplicates get -N)."""
    slugs: set = set()
    seen: Counter = Counter()
    in_fence = False
    for line in text.splitlines():
        if FENCE_RE.match(line):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        m = HEADING_RE.match(line)
        if not m:
            continue
        base = slugify(m.group(2))
        slugs.add(base if not seen[base] else f"{base}-{seen[base]}")
        seen[base] += 1
    return slugs


def _strip_fences(text: str) -> str:
    """Drop fenced code blocks (their content is not rendered links)."""
    out, in_fence = [], False
    for line in text.splitlines():
        if FENCE_RE.match(line):
            in_fence = not in_fence
            continue
        if not in_fence:
            out.append(line)
    return "\n".join(out)


def check_file(path: Path, root: Path, slug_cache: dict) -> list:
    broken = []
    text = path.read_text(encoding="utf-8")
    rendered = _strip_fences(text)

    def slugs_of(p: Path) -> set:
        if p not in slug_cache:
            slug_cache[p] = heading_slugs(p.read_text(encoding="utf-8"))
        return slug_cache[p]

    def line_of(fragment: str) -> int:
        pos = text.find(fragment)
        return text.count("\n", 0, pos) + 1 if pos >= 0 else 0

    for m in LINK_RE.finditer(rendered):
        target = m.group(1)
        if target.startswith(SKIP_PREFIXES) or "://" in target:
            continue
        rel, _, anchor = target.partition("#")
        if rel:
            dest = (path.parent / rel).resolve()
            if not dest.exists():
                broken.append((path, line_of(f"({target})"),
                               f"missing file ({target})"))
                continue
        else:
            dest = path  # pure in-page anchor
        if anchor and dest.suffix == ".md":
            if anchor not in slugs_of(dest):
                broken.append((path, line_of(f"({target})"),
                               f"missing anchor ({target})"))

    for m in CODE_SPAN_RE.finditer(rendered):
        span = m.group(1).split("::", 1)[0].strip()
        if not SRC_PATH_RE.match(span):
            continue
        if not (root / span).exists():
            broken.append((path, line_of(f"`{m.group(1)}`"),
                           f"missing source path (`{span}`)"))
    return broken


def main() -> int:
    root = Path(__file__).resolve().parent.parent
    files = [root / "README.md", *sorted((root / "docs").glob("*.md"))]
    slug_cache: dict = {}
    broken = []
    for f in files:
        if f.exists():
            broken.extend(check_file(f, root, slug_cache))
    if broken:
        for path, line, what in broken:
            print(f"BROKEN {path.relative_to(root)}:{line}: {what}")
        return 1
    print(f"docs links OK ({len(files)} files)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
