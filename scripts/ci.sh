#!/usr/bin/env bash
# Continuous-integration entry point: dependency check, tier-1 tests, and
# smoke runs of the README quickstart commands, so the advertised entry
# points stay continuously exercised.
#
#   bash scripts/ci.sh            # full tier-1 + smokes
#   CI_SKIP_SMOKE=1 bash scripts/ci.sh   # tests only
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
PY=${PYTHON:-python}

echo "== deps =="
$PY -c "import jax, numpy; print('jax', jax.__version__, '| numpy', numpy.__version__)"
# test-only deps: install if absent and an index is reachable; the suite
# runs without hypothesis (property tests skip collection), so failure to
# install extras is non-fatal.
$PY -c "import pytest" 2>/dev/null || $PY -m pip install -q pytest || true
$PY -c "import hypothesis" 2>/dev/null \
  && echo "hypothesis: present (property suites active)" \
  || { $PY -m pip install -q hypothesis 2>/dev/null \
       || echo "hypothesis: absent (property suites skipped)"; }

echo "== docs link check =="
$PY scripts/check_docs_links.py

echo "== tier-1 tests =="
$PY -m pytest -x -q

echo "== kernel parity fuzz =="
# the property-based oracle harness (docs/KERNELS.md) under the pinned
# derandomized profile: every run draws the same examples, so a red gate
# is a real kernel regression, never an unlucky draw
if $PY -c "import hypothesis" 2>/dev/null; then
  $PY -m pytest tests/test_kernel_parity.py -q --hypothesis-profile kernel-ci
else
  echo "hypothesis absent — parity fuzz skipped (interpret-mode parity"
  echo "is still pinned by tests/test_kernels.py grids in tier-1)"
fi

if [ -z "${CI_SKIP_STRESS:-}" ]; then
  echo "== stress soak: overlapped-round pipeline =="
  # the seeded Zipf-burst concurrency soak (tests/test_pipeline.py): a
  # pipelined service hammered with bursts for REPRO_SOAK_SECONDS must
  # not deadlock, drop rounds, or leak updates from the conservation
  # ledger.  Excluded from tier-1 by the stress marker; a separate CI
  # step because it budgets wall time by design (CI_SOAK_SECONDS trims
  # it on constrained hosts, CI_SKIP_STRESS=1 skips)
  if $PY -c "import hypothesis" 2>/dev/null; then
    REPRO_SOAK_SECONDS="${CI_SOAK_SECONDS:-60}" \
      $PY -m pytest tests/test_pipeline.py -m stress -q \
          --hypothesis-profile stress
  else
    REPRO_SOAK_SECONDS="${CI_SOAK_SECONDS:-60}" \
      $PY -m pytest tests/test_pipeline.py -m stress -q
  fi
fi

if [ -z "${CI_SKIP_SMOKE:-}" ]; then
  echo "== smoke: quickstart =="
  $PY examples/quickstart.py --rounds 8 --clients 10

  echo "== smoke: streaming service =="
  $PY -m repro.launch.serve --safl-stream --updates 120 --trigger kbuffer

  echo "== smoke: overlapped-round pipeline =="
  # a 200-client burst through the pipelined service (the default) and
  # the --no-pipeline escape hatch: every recorded event must parse
  # against the documented taxonomy, and after stripping wall-time
  # fields the two streams must be identical — the determinism contract
  # of docs/ARCHITECTURE.md 'Overlapped rounds', end to end through the
  # launcher and the async telemetry sink
  PIPEDIR=$(mktemp -d)
  $PY -m repro.launch.serve --safl-stream --clients 200 --updates 400 \
      --batched --telemetry "$PIPEDIR/pipe.jsonl"
  $PY -m repro.launch.serve --safl-stream --clients 200 --updates 400 \
      --batched --no-pipeline --telemetry "$PIPEDIR/sync.jsonl"
  $PY - "$PIPEDIR" <<'EOF'
import json, sys, os
d = sys.argv[1]
sys.path.insert(0, "src")
from repro.telemetry import EVENT_TYPES
def norm(name):
    recs = [json.loads(l) for l in open(os.path.join(d, name)) if l.strip()]
    assert recs, f"{name}: pipeline smoke recorded no events"
    unknown = {r["e"] for r in recs} - set(EVENT_TYPES)
    assert not unknown, f"{name}: events outside the taxonomy: {unknown}"
    out = []
    for r in recs:
        r.pop("agg_seconds", None)
        if r.get("e") == "metrics-snapshot":
            r["metrics"] = {k: v for k, v in r["metrics"].items()
                            if "seconds" not in k and "agg_s" not in k}
        out.append(r)
    return out
pipe, sync = norm("pipe.jsonl"), norm("sync.jsonl")
assert pipe[-1]["e"] == "metrics-snapshot", "missing final metrics snapshot"
assert pipe == sync, (f"pipelined and --no-pipeline event streams diverge "
                      f"({len(pipe)} vs {len(sync)} events)")
print(f"pipeline smoke OK ({len(pipe)} events identical across modes)")
EOF
  rm -rf "$PIPEDIR"

  echo "== smoke: telemetry record -> report =="
  # record a 50-client stream, assert every JSONL event parses against the
  # documented taxonomy, and render the experiment report from it
  TELEDIR=$(mktemp -d)
  $PY -m repro.launch.serve --safl-stream --clients 50 --updates 200 \
      --telemetry "$TELEDIR/run.jsonl" --report "$TELEDIR/report.md"
  $PY - "$TELEDIR" <<'EOF'
import json, sys, os
d = sys.argv[1]
sys.path.insert(0, "src")
from repro.telemetry import EVENT_TYPES
records = [json.loads(l) for l in open(os.path.join(d, "run.jsonl")) if l.strip()]
assert records, "telemetry smoke recorded no events"
unknown = {r["e"] for r in records} - set(EVENT_TYPES)
assert not unknown, f"events outside the documented taxonomy: {unknown}"
assert records[-1]["e"] == "metrics-snapshot", "missing final metrics snapshot"
report = open(os.path.join(d, "report.md")).read()
for section in ("## Run overview", "## Staleness distribution",
                "## Participation fairness", "## Metrics snapshot"):
    assert section in report, f"report missing section {section!r}"
print(f"telemetry smoke OK ({len(records)} events, "
      f"{len(report.splitlines())} report lines)")
EOF
  rm -rf "$TELEDIR"

  echo "== bench artifacts: serve suite (--fast) =="
  # the --fast serve suite doubles as the telemetry overhead/parity gate
  # and leaves BENCH_serve.json at the repo root as the uploadable artifact
  $PY -m benchmarks.run --only serve --fast
  test -s BENCH_serve.json
  $PY -c "import json; rows = json.load(open('BENCH_serve.json'))['results']; \
assert rows, 'BENCH_serve.json has no results'; \
print('BENCH_serve.json OK:', len(rows), 'rows')"

  echo "== bench artifacts: ingest suite (--fast) =="
  # fused-ingestion gates: kernel ≡ oracle bit-exact, fused serve rounds
  # ≤1e-5 vs unfused and ≥1.5× faster, autotune cache sweep + roofline
  $PY -m benchmarks.run --only ingest --fast
  test -s BENCH_ingest.json
  $PY -c "import json; rows = json.load(open('BENCH_ingest.json'))['results']; \
assert rows, 'BENCH_ingest.json has no results'; \
print('BENCH_ingest.json OK:', len(rows), 'rows')"

  echo "== bench artifacts: health suite (--fast) =="
  # training-health gates: health plane ≤5% overhead with bit-identical
  # params, injected norm explosion alerts within 5 rounds, healthy
  # stream stays silent, flight dump round-trips through --postmortem
  $PY -m benchmarks.run --only health --fast
  test -s BENCH_health.json
  $PY -c "import json; rows = json.load(open('BENCH_health.json'))['results']; \
assert rows, 'BENCH_health.json has no results'; \
print('BENCH_health.json OK:', len(rows), 'rows')"

  echo "== bench artifacts: schema + perf diff =="
  # every BENCH_*.json must match the documented artifact shape (the
  # perf-diff tooling parses them), then diff the fresh artifacts against
  # the committed baselines; report-only on CI hosts — wall times jitter
  # too much to hard-gate, a quiet host runs bench_diff without the flag
  $PY scripts/check_bench_schema.py
  $PY scripts/bench_diff.py BENCH_serve.json BENCH_ingest.json \
      BENCH_health.json --report-only

  echo "== smoke: distributed tracing =="
  # a 200-client traced stream: the exported file must load as Chrome
  # trace-event JSON and the critical-path analyzer must explain >=90%
  # of the round wall with measured stages (docs/OBSERVABILITY.md)
  TRACEDIR=$(mktemp -d)
  $PY -m repro.launch.serve --safl-stream --clients 200 --updates 400 \
      --trigger kbuffer --trace "$TRACEDIR/run.trace.json"
  $PY - "$TRACEDIR" <<'EOF'
import json, sys, os
d = sys.argv[1]
doc = json.load(open(os.path.join(d, "run.trace.json")))
evs = doc["traceEvents"]
assert evs, "trace smoke exported no events"
for e in evs:
    assert e["ph"] in ("X", "M"), f"unexpected phase {e['ph']!r}"
    if e["ph"] == "X":
        assert isinstance(e["ts"], (int, float)) and e["dur"] >= 0
xs = [e for e in evs if e["ph"] == "X"]
rounds = [e for e in xs if e["name"] == "round"]
assert rounds, "trace smoke fired no rounds"
wall = sum(e["dur"] for e in rounds)
staged = sum(e["dur"] for e in xs
             if e["name"] in ("dispatch", "finalize"))
assert 0.9 <= staged / wall <= 1.1, \
    f"stage times cover {staged / wall:.1%} of round wall (outside 90-110%)"
assert doc.get("metadata", {}).get("spans_dropped", 0) == 0, "spans dropped"
print(f"trace smoke OK ({len(xs)} spans, {len(rounds)} rounds, "
      f"coverage {staged / wall:.1%})")
EOF
  rm -rf "$TRACEDIR"

  echo "== smoke: simulator launcher =="
  $PY -m repro.launch.train --task rwd --algo fedqs-sgd --rounds 4 \
      --clients 10 --eval-every 2 --n-total 1000

  echo "== smoke: scenario engine =="
  $PY examples/scenario_churn.py --smoke
  $PY benchmarks/bench_scenarios.py --quick

  echo "== smoke: compressed transport =="
  $PY -m repro.launch.train --task rwd --algo fedqs-sgd --rounds 4 \
      --clients 10 --eval-every 2 --n-total 1000 --compress int8
  $PY examples/compressed_stream.py --smoke
  $PY benchmarks/bench_compress.py --fast

  echo "== smoke: chaos / straggler-adaptive serving =="
  # a seeded 200-client straggler-heavy stream through the adaptive-
  # deadline service, and a flaky-battery stream that kills devices
  # mid-round: both must terminate (no deadlock) and every robustness
  # event must parse against the documented taxonomy
  CHAOSDIR=$(mktemp -d)
  $PY -m repro.launch.serve --safl-stream --scenario straggler-heavy \
      --clients 200 --updates 400 --trigger adaptive --tau-max 2 \
      --telemetry "$CHAOSDIR/straggler.jsonl"
  $PY -m repro.launch.serve --safl-stream --scenario flaky-battery \
      --clients 64 --updates 150 --telemetry "$CHAOSDIR/flaky.jsonl"
  $PY - "$CHAOSDIR" <<'EOF'
import json, sys, os
d = sys.argv[1]
sys.path.insert(0, "src")
from repro.telemetry import EVENT_TYPES
for ev in ("client-dropped", "partial-admitted", "deadline-adapted"):
    assert ev in EVENT_TYPES, f"{ev} missing from the event taxonomy"
def load(name):
    recs = [json.loads(l) for l in open(os.path.join(d, name)) if l.strip()]
    unknown = {r["e"] for r in recs} - set(EVENT_TYPES)
    assert not unknown, f"{name}: events outside the taxonomy: {unknown}"
    return recs
strag = load("straggler.jsonl")
kinds = {r["e"] for r in strag}
assert "partial-admitted" in kinds, "straggler run admitted no partial work"
assert "deadline-adapted" in kinds, "adaptive trigger never re-planned"
flaky = load("flaky.jsonl")
drops = [r for r in flaky if r["e"] == "client-dropped"]
assert drops, "flaky-battery run dropped no clients"
print(f"chaos smoke OK ({len(strag)} straggler events, "
      f"{len(drops)} mid-round drops)")
EOF
  rm -rf "$CHAOSDIR"

  echo "== smoke: training-health plane =="
  # a healthy 200-client stream through the detectors must stay silent;
  # a seeded norm explosion must raise an alert and leave a flight dump
  # the postmortem renderer can read back (docs/OBSERVABILITY.md)
  HEALTHDIR=$(mktemp -d)
  $PY -m repro.launch.serve --safl-stream --clients 200 --updates 400 \
      --batched --health --flightrec "$HEALTHDIR/flight.jsonl" \
      --telemetry "$HEALTHDIR/healthy.jsonl"
  $PY -m repro.launch.monitor --events "$HEALTHDIR/healthy.jsonl" \
      --prom "$HEALTHDIR/healthy.prom" > /dev/null
  $PY - "$HEALTHDIR" <<'EOF'
import json, sys, os
d = sys.argv[1]
sys.path.insert(0, "src")
import jax
from repro.telemetry import EVENT_TYPES, Telemetry
recs = [json.loads(l) for l in open(os.path.join(d, "healthy.jsonl"))
        if l.strip()]
unknown = {r["e"] for r in recs} - set(EVENT_TYPES)
assert not unknown, f"events outside the taxonomy: {unknown}"
alerts = [r for r in recs if r["e"] == "health-alert"]
assert not alerts, f"healthy stream raised alerts: {alerts[:3]}"
prom = open(os.path.join(d, "healthy.prom")).read()
assert "repro_health_alerts_critical 0" in prom, "prom exposition missing"

# seeded divergence: the detectors must fire and dump the black box
from repro.core import FedQSHyperParams, make_algorithm
from repro.models import make_mlp_spec
from repro.serve import KBuffer, StreamingAggregator, replay, synthetic_stream
from repro.serve.stream import inject_norm_explosion
from repro.telemetry.report import postmortem_report
params = make_mlp_spec().init(jax.random.PRNGKey(0))
flight = os.path.join(d, "chaos-flight.jsonl")
tel = Telemetry.in_memory(health=True, flightrec=flight)
hp = FedQSHyperParams(buffer_k=5)
svc = StreamingAggregator(make_algorithm("fedqs-sgd", hp), hp, params, 16,
                          trigger=KBuffer(5), batched=True, telemetry=tel)
stream = inject_norm_explosion(synthetic_stream(params, 16, 120, seed=0),
                               after=50, scale=100.0)
replay(svc, list(stream))
hm = tel.health
assert hm.alerts, "injected norm explosion raised no health alert"
lag = min(a.round for a in hm.alerts) - (50 // 5 + 1)
assert 0 <= lag <= 5, f"first alert {lag} rounds after injection (>5)"
report = postmortem_report(flight)
assert "black box" in report and "alert" in report, "postmortem empty"
tel.close()
print(f"health smoke OK ({len(recs)} healthy events silent, "
      f"{len(hm.alerts)} alerts on chaos, lag={lag} rounds, "
      f"postmortem {len(report.splitlines())} lines)")
EOF
  rm -rf "$HEALTHDIR"

  echo "== smoke: hierarchical aggregation plane =="
  # 2-tier, 200 clients: segment-kernel exactness + trigger parity vs
  # the flat service (the gates exit non-zero on divergence)
  $PY benchmarks/bench_hier.py --fast --parity-only
  $PY -m repro.launch.serve --safl-stream --topology hier:16x4 \
      --clients 200 --updates 200 --edge-k 2
fi

echo "CI OK"
