#!/usr/bin/env python
"""Perf-regression detector over the committed BENCH_*.json artifacts.

Diffs a current benchmark artifact against a baseline (by default the
committed copy at ``git show HEAD:BENCH_<suite>.json``) row by row on
``us_per_call`` and flags any benchmark that slowed down beyond the
threshold.  Wired into scripts/ci.sh in ``--report-only`` mode — CPU CI
hosts are too noisy to hard-gate wall times, so CI prints the table and
a regression note without failing; run without ``--report-only`` on a
quiet host (or TPU CI) to enforce the gate.

    PYTHONPATH=src python scripts/bench_diff.py BENCH_serve.json
    PYTHONPATH=src python scripts/bench_diff.py BENCH_serve.json \
        --baseline old/BENCH_serve.json --threshold 1.5
    PYTHONPATH=src python scripts/bench_diff.py BENCH_*.json --report-only
"""
from __future__ import annotations

import argparse
import json
import subprocess
import sys
from typing import List, Optional

# Rows whose us_per_call is a pure pass/fail marker, not a wall time
# (e.g. serve_telemetry_hier_parity records 0.0): a zero baseline makes
# every ratio infinite, so they are skipped, not gated.
_EPS = 1e-9


def compare(baseline: dict, current: dict, *, threshold: float = 2.0) -> dict:
    """Row-by-row us_per_call diff of two benchmark artifacts.

    Returns ``{"suite", "rows": [...], "regressions": [...], "added",
    "removed"}`` where each row carries the baseline/current timings and
    the slowdown ratio.  A row regresses when
    ``current >= baseline * threshold``; zero-baseline rows (pass/fail
    markers) and rows missing from either side are reported but never
    gated.
    """
    base_rows = {r["name"]: r for r in baseline.get("results", [])}
    cur_rows = {r["name"]: r for r in current.get("results", [])}
    rows: List[dict] = []
    regressions: List[dict] = []
    for name, cur in cur_rows.items():
        base = base_rows.get(name)
        if base is None:
            continue
        b, c = float(base["us_per_call"]), float(cur["us_per_call"])
        if b <= _EPS:  # pass/fail marker row, not a timing
            rows.append({"name": name, "baseline_us": b, "current_us": c,
                         "ratio": None, "regressed": False})
            continue
        ratio = c / b
        row = {"name": name, "baseline_us": b, "current_us": c,
               "ratio": ratio, "regressed": ratio >= threshold}
        rows.append(row)
        if row["regressed"]:
            regressions.append(row)
    return {
        "suite": current.get("suite", "?"),
        "threshold": threshold,
        "rows": rows,
        "regressions": regressions,
        "added": sorted(set(cur_rows) - set(base_rows)),
        "removed": sorted(set(base_rows) - set(cur_rows)),
    }


def format_diff(diff: dict) -> str:
    lines = [f"bench_diff: suite={diff['suite']} "
             f"threshold={diff['threshold']:.2f}x"]
    for row in diff["rows"]:
        if row["ratio"] is None:
            lines.append(f"  {row['name']:<34} (pass/fail marker, skipped)")
            continue
        flag = "  << REGRESSION" if row["regressed"] else ""
        lines.append(f"  {row['name']:<34} {row['baseline_us']:>10.1f} -> "
                     f"{row['current_us']:>10.1f} us/call "
                     f"({row['ratio']:.2f}x){flag}")
    if diff["added"]:
        lines.append(f"  new rows (no baseline): {', '.join(diff['added'])}")
    if diff["removed"]:
        lines.append(f"  rows gone from current: {', '.join(diff['removed'])}")
    return "\n".join(lines)


def _git_baseline(path: str) -> Optional[dict]:
    """The committed copy of ``path`` at HEAD, or None if untracked."""
    try:
        out = subprocess.run(
            ["git", "show", f"HEAD:{path}"],
            capture_output=True, text=True, check=True, cwd=".").stdout
        return json.loads(out)
    except (subprocess.CalledProcessError, json.JSONDecodeError):
        return None


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("artifacts", nargs="+",
                    help="current BENCH_<suite>.json artifact(s)")
    ap.add_argument("--baseline", default=None,
                    help="baseline artifact path (default: the committed "
                         "copy, git show HEAD:<artifact>)")
    ap.add_argument("--threshold", type=float, default=2.0,
                    help="slowdown ratio that counts as a regression "
                         "(default 2.0x — CPU wall times jitter)")
    ap.add_argument("--report-only", action="store_true",
                    help="print the diff but always exit 0 (CI on noisy "
                         "hosts)")
    args = ap.parse_args(argv)
    if args.baseline and len(args.artifacts) > 1:
        ap.error("--baseline only makes sense with a single artifact")

    failed = False
    for path in args.artifacts:
        with open(path, encoding="utf-8") as fh:
            current = json.load(fh)
        if args.baseline:
            with open(args.baseline, encoding="utf-8") as fh:
                baseline = json.load(fh)
        else:
            baseline = _git_baseline(path)
            if baseline is None:
                print(f"bench_diff: {path}: no committed baseline at HEAD, "
                      "skipping")
                continue
        diff = compare(baseline, current, threshold=args.threshold)
        print(format_diff(diff))
        if diff["regressions"]:
            names = ", ".join(r["name"] for r in diff["regressions"])
            print(f"bench_diff: {len(diff['regressions'])} regression(s) "
                  f"in {path}: {names}")
            failed = True
    if failed and not args.report_only:
        return 1
    if failed:
        print("bench_diff: --report-only, not failing the build")
    return 0


if __name__ == "__main__":
    sys.exit(main())
