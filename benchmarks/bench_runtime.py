"""Paper Table 3: runtime — virtual-clock duration to finish T rounds
(the paper's wall-clock analogue under simulated heterogeneity) plus real
wall-seconds of the simulation, including the synchronous-FL shadow
columns."""
from .common import emit, run_safl, us_per_round

ROUNDS = 20


def run():
    cases = [
        ("fedavg_sfl", "fedavg", True), ("fedsgd_sfl", "fedsgd", True),
        ("fedavg", "fedavg", False), ("fedsgd", "fedsgd", False),
        ("fedbuff", "fedbuff", False), ("wkafl", "wkafl", False),
        ("safa", "safa", False), ("fedat", "fedat", False),
        ("m-step", "m-step", False), ("fedac", "fedac", False),
        ("defedavg", "defedavg", False), ("fadas", "fadas", False),
        ("ca2fl", "ca2fl", False),
        ("fedqs-avg", "fedqs-avg", False), ("fedqs-sgd", "fedqs-sgd", False),
    ]
    base_async = None
    for name, algo, sync in cases:
        _, res = run_safl("rwd", algo, rounds=ROUNDS, sync_mode=sync, seed=3)
        vt = res.virtual_time()
        if name == "fedavg":
            base_async = vt
        emit(f"table3.runtime.{name}", us_per_round(res, ROUNDS),
             virtual_time=round(vt, 1),
             wall_s=round(res.wall_seconds, 2), sync=int(sync))
    # headline: SAFL vs SFL virtual-time reduction (paper: ~70%)
    _, sfl = run_safl("rwd", "fedavg", rounds=ROUNDS, sync_mode=True, seed=3)
    emit("table3.safl_vs_sfl_reduction", 0.0,
         reduction=round(1 - base_async / max(sfl.virtual_time(), 1e-9), 4))


if __name__ == "__main__":
    run()
