"""Benchmark harness entry point — one module per paper table/figure
(DESIGN §8).  Prints ``name,us_per_call,derived`` CSV rows.

    PYTHONPATH=src python -m benchmarks.run [--only table2,kernels] [--fast]
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

SUITES = ("factors", "accuracy", "runtime", "ablation", "dynamic",
          "hparams", "kernels", "roofline")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help=f"comma list from {SUITES}")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else set(SUITES)

    print("name,us_per_call,derived")
    t0 = time.time()
    if "factors" in only:
        from . import bench_factors; bench_factors.run()
    if "accuracy" in only:
        from . import bench_accuracy; bench_accuracy.run()
    if "runtime" in only:
        from . import bench_runtime; bench_runtime.run()
    if "ablation" in only:
        from . import bench_ablation; bench_ablation.run()
    if "dynamic" in only:
        from . import bench_dynamic; bench_dynamic.run()
    if "hparams" in only:
        from . import bench_hparams; bench_hparams.run()
    if "kernels" in only:
        from . import bench_kernels; bench_kernels.run()
    if "roofline" in only:
        from . import roofline; roofline.run()
    print(f"# total_bench_wall_s={time.time()-t0:.1f}", file=sys.stderr)


if __name__ == '__main__':
    main()
