"""Benchmark harness entry point — one module per paper table/figure
(DESIGN §8).  Prints ``name,us_per_call,derived`` CSV rows and writes
each suite's rows to ``BENCH_<suite>.json`` at the repo root (override
the directory with ``--out-dir``; ``--no-json`` disables the artifacts),
so the perf trajectory is machine-readable run over run.

    PYTHONPATH=src python -m benchmarks.run [--only serve,kernels] [--fast]

``--fast`` threads through to every suite that has a reduced mode
(serve / scenarios / compress run their ``--quick``/``--fast``
configurations); suites without one run their single configuration.
"""
import argparse
import inspect
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def _suite(module_name: str):
    def call(fast: bool) -> None:
        import importlib

        mod = importlib.import_module(f".{module_name}", package=__package__
                                      or "benchmarks")
        run = mod.run
        if "fast" in inspect.signature(run).parameters:
            run(fast=fast)
        else:
            run()

    return call


SUITES = {
    "factors": _suite("bench_factors"),
    "accuracy": _suite("bench_accuracy"),
    "runtime": _suite("bench_runtime"),
    "ablation": _suite("bench_ablation"),
    "dynamic": _suite("bench_dynamic"),
    "hparams": _suite("bench_hparams"),
    "kernels": _suite("bench_kernels"),
    "ingest": _suite("bench_ingest"),
    "roofline": _suite("roofline"),
    "serve": _suite("bench_serve"),
    "scenarios": _suite("bench_scenarios"),
    "compress": _suite("bench_compress"),
    "hier": _suite("bench_hier"),
    "health": _suite("bench_health"),
}


def _write_bench_json(out_dir: str, suite: str, rows, *, fast: bool,
                      wall_s: float) -> str:
    path = os.path.join(out_dir, f"BENCH_{suite}.json")
    payload = {
        "suite": suite,
        "fast": bool(fast),
        "generated_unix": int(time.time()),
        "wall_s": round(wall_s, 2),
        "results": rows,
    }
    with open(path, "w", encoding="utf-8") as fh:
        json.dump(payload, fh, indent=2)
        fh.write("\n")
    return path


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help=f"comma list from {tuple(SUITES)}")
    ap.add_argument("--fast", action="store_true",
                    help="reduced configurations where a suite supports them")
    ap.add_argument("--out-dir", default=ROOT,
                    help="where BENCH_<suite>.json artifacts land "
                         "(default: repo root)")
    ap.add_argument("--no-json", action="store_true",
                    help="skip writing BENCH_<suite>.json artifacts")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else set(SUITES)
    unknown = only - set(SUITES)
    if unknown:
        raise SystemExit(f"unknown suites: {sorted(unknown)} "
                         f"(know: {sorted(SUITES)})")

    try:
        from benchmarks import common  # python -m benchmarks.run
    except ImportError:
        import common  # bare-script fallback, matching the suites

    print("name,us_per_call,derived")
    t0 = time.time()
    for name, call in SUITES.items():
        if name not in only:
            continue
        common.drain_results()  # suite rows only, even after a prior crash
        t_suite = time.time()
        call(args.fast)
        if not args.no_json:
            path = _write_bench_json(args.out_dir, name,
                                     common.drain_results(), fast=args.fast,
                                     wall_s=time.time() - t_suite)
            print(f"# wrote {path}", file=sys.stderr)
    print(f"# total_bench_wall_s={time.time()-t0:.1f}", file=sys.stderr)


if __name__ == '__main__':
    main()
