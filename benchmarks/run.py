"""Benchmark harness entry point — one module per paper table/figure
(DESIGN §8).  Prints ``name,us_per_call,derived`` CSV rows.

    PYTHONPATH=src python -m benchmarks.run [--only serve,kernels] [--fast]

``--fast`` threads through to every suite that has a reduced mode
(serve / scenarios / compress run their ``--quick``/``--fast``
configurations); suites without one run their single configuration.
"""
import argparse
import inspect
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def _suite(module_name: str):
    def call(fast: bool) -> None:
        import importlib

        mod = importlib.import_module(f".{module_name}", package=__package__
                                      or "benchmarks")
        run = mod.run
        if "fast" in inspect.signature(run).parameters:
            run(fast=fast)
        else:
            run()

    return call


SUITES = {
    "factors": _suite("bench_factors"),
    "accuracy": _suite("bench_accuracy"),
    "runtime": _suite("bench_runtime"),
    "ablation": _suite("bench_ablation"),
    "dynamic": _suite("bench_dynamic"),
    "hparams": _suite("bench_hparams"),
    "kernels": _suite("bench_kernels"),
    "roofline": _suite("roofline"),
    "serve": _suite("bench_serve"),
    "scenarios": _suite("bench_scenarios"),
    "compress": _suite("bench_compress"),
    "hier": _suite("bench_hier"),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help=f"comma list from {tuple(SUITES)}")
    ap.add_argument("--fast", action="store_true",
                    help="reduced configurations where a suite supports them")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else set(SUITES)
    unknown = only - set(SUITES)
    if unknown:
        raise SystemExit(f"unknown suites: {sorted(unknown)} "
                         f"(know: {sorted(SUITES)})")

    print("name,us_per_call,derived")
    t0 = time.time()
    for name, call in SUITES.items():
        if name in only:
            call(args.fast)
    print(f"# total_bench_wall_s={time.time()-t0:.1f}", file=sys.stderr)


if __name__ == '__main__':
    main()
