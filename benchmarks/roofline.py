"""§Roofline report: read the dry-run JSONs (experiments/dryrun/) and emit
the per-(arch × shape) three-term roofline table + bottleneck analysis.

    PYTHONPATH=src python -m benchmarks.roofline [--dir experiments/dryrun]
                                                 [--markdown]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys

from repro.launch.analysis import HBM_BW, ICI_BW, PEAK_FLOPS

DEF_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")


def load(dir_=DEF_DIR, mesh="16x16"):
    recs = []
    for f in sorted(glob.glob(os.path.join(dir_, f"*__{mesh}.json"))):
        with open(f) as fh:
            recs.append(json.load(fh))
    return recs


def advice(rec) -> str:
    """One sentence on what would move the dominant term down."""
    dom = rec["roofline"]["dominant"]
    mode = rec["mode"]
    if dom == "compute":
        return ("increase per-chip batch locality / MXU utilization; for MoE, "
                "raise capacity-factor efficiency so dispatched FLOPs are useful")
    if dom == "memory":
        if mode == "decode":
            return ("decode is weight/KV-streaming bound: shrink the resident KV "
                    "(window/latent caches), quantize weights, or batch more tokens "
                    "per weight pass")
        return ("cut HBM traffic: fuse elementwise chains, rematerialize instead "
                "of spilling activations, keep bf16 end-to-end")
    return ("reduce collective volume: shard so the Mod-3 reduction becomes a "
            "reduce-scatter over already-local shards, overlap all-to-all with "
            "expert compute, or move the pod-level sync to once-per-k-rounds")


def rows(recs):
    out = []
    for r in recs:
        if r.get("status") != "ok":
            out.append({"arch": r["arch"], "shape": r["shape"],
                        "status": r.get("status"), "reason": r.get("reason", r.get("error", ""))})
            continue
        rf = r["roofline"]
        out.append({
            "arch": r["arch"], "shape": r["shape"], "status": "ok",
            "compute_s": rf["compute_s"], "memory_s": rf["memory_s"],
            "collective_s": rf["collective_s"], "dominant": rf["dominant"],
            "model_flops": r["model_flops"],
            "useful_ratio": r["useful_flops_ratio"],
            "chips": r["chips"],
            "advice": advice(r),
        })
    return out


def kernel_rows():
    """Measured Pallas-kernel configs from the autotune cache → roofline
    rows (achieved GB/s vs the HBM ceiling).  Empty until a sweep has
    run (benchmarks/bench_ingest.py or any ``*_auto_op`` tuning pass)."""
    from repro.kernels import autotune

    for row in autotune.roofline_rows():
        print(
            f"roofline.kernel.{row['key'].replace('|', '.')},"
            f"{row['us']:.1f},block_d={row['block_d']}|gbps={row['gbps']}|"
            f"pct_roofline={row['pct_roofline']}"
        )


def run(dir_=DEF_DIR):
    kernel_rows()
    recs = load(dir_)
    if not recs:
        print("roofline.no_dryrun_data,0.0,hint=run repro.launch.dryrun first")
        return
    for row in rows(recs):
        if row["status"] != "ok":
            print(f"roofline.{row['arch']}.{row['shape']},0.0,status={row['status']}")
            continue
        bound_s = max(row["compute_s"], row["memory_s"], row["collective_s"])
        print(
            f"roofline.{row['arch']}.{row['shape']},{bound_s*1e6:.1f},"
            f"compute_s={row['compute_s']:.3e}|memory_s={row['memory_s']:.3e}|"
            f"collective_s={row['collective_s']:.3e}|dominant={row['dominant']}|"
            f"useful_flops_ratio={row['useful_ratio'] if row['useful_ratio'] is None else round(row['useful_ratio'],4)}"
        )


def markdown(dir_=DEF_DIR, mesh="16x16"):
    recs = load(dir_, mesh)
    print(f"| arch | shape | compute (s) | memory (s) | collective (s) | "
          f"dominant | MODEL_FLOPS/HLO | next lever |")
    print("|---|---|---|---|---|---|---|---|")
    for row in rows(recs):
        if row["status"] != "ok":
            print(f"| {row['arch']} | {row['shape']} | — | — | — | "
                  f"{row['status']} | — | {row.get('reason','')[:60]} |")
            continue
        ur = row["useful_ratio"]
        # MODEL_FLOPS is global; HLO flops are per-chip ⇒ ratio uses chips
        ur_chip = (row["model_flops"] / row["chips"]) / (
            row["compute_s"] * PEAK_FLOPS) if row["compute_s"] else 0
        print(f"| {row['arch']} | {row['shape']} | {row['compute_s']:.2e} | "
              f"{row['memory_s']:.2e} | {row['collective_s']:.2e} | "
              f"**{row['dominant']}** | {ur_chip:.2f} | {row['advice'][:80]}… |")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default=DEF_DIR)
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--markdown", action="store_true")
    a = ap.parse_args()
    if a.markdown:
        markdown(a.dir, a.mesh)
    else:
        run(a.dir)
