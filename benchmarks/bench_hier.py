"""Hierarchical aggregation plane benchmark (docs/HIERARCHY.md).

Three sections, each with a hard gate (the script exits non-zero on
regression):

* **kernel** — ``segment_agg_op`` (the Pallas kernel body, interpret
  mode off-TPU) vs the ``segment_agg_ref`` one-hot-matmul oracle; gate:
  **exact** fp32 equality (the two deliberately share their algebra);
* **parity** — a 2-tier ``HierarchicalService`` with all-pass edge
  triggers vs the flat ``StreamingAggregator`` on the same recorded
  stream; gate: identical round count, exact status table, and global
  model equal to ≤ 1e-5 relative error;
* **throughput** — 10k clients / 64 edges: sustained latency of the
  **globally-serialized aggregation stage** (``stats.agg_seconds`` per
  round) for the flat service vs the tiered plane; gate: hierarchy ≥ 3×.

Reading the throughput numbers: rounds serialize on the global
aggregation (``repro.serve.service`` — at most one fire in flight), so
the global stage bounds the sustainable round rate.  Flat, that stage
stacks and reduces every buffered client row — O(K) work on the one
contended server.  Tiered, edges and regions pre-reduce their members
(work that shards across edge hosts, or across devices via
``segment_agg_sharded``) and the global stage touches only partial
rows — O(#regions).  Total host wall is reported unguarded
(``total_wall_s``): in-process the tier work still runs inline, the
win is where it sits, not whether it runs.

    PYTHONPATH=src python benchmarks/bench_hier.py [--fast] [--parity-only]
"""
from __future__ import annotations

import argparse
import time

try:
    from .common import emit, make_suite_run
except ImportError:  # run as a script: python benchmarks/bench_hier.py
    from common import emit, make_suite_run

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import FedQSHyperParams, make_algorithm
from repro.hier import HierarchicalService, Topology
from repro.kernels import segment_agg_op
from repro.kernels.ref import segment_agg_ref
from repro.models import make_mlp_spec
from repro.serve import KBuffer, StreamingAggregator, replay, synthetic_stream

SPEEDUP_FACTOR = 3.0   # hier global-stage latency gate vs flat
PARITY_RTOL = 1e-5     # all-pass 2-tier vs flat relative error gate


def bench_kernel(args) -> bool:
    """segment_agg kernel body vs oracle — exact fp32 equality."""
    exact = True
    shapes = [(64, 4096, 8)] if args.fast else [(64, 4096, 8), (512, 16384, 64)]
    for K, D, G in shapes:
        x = jax.random.normal(jax.random.PRNGKey(0), (K, D))
        w = jax.random.uniform(jax.random.PRNGKey(1), (K,))
        seg = jax.random.randint(jax.random.PRNGKey(2), (K,), 0, G)
        t0 = time.perf_counter()
        got = jax.block_until_ready(
            segment_agg_op(x, w, seg, num_segments=G))
        dt = time.perf_counter() - t0
        want = segment_agg_ref(x, w, seg, G)
        ok = bool((np.asarray(got) == np.asarray(want)).all())
        exact &= ok
        emit(
            f"hier_kernel_K{K}_D{D}_G{G}",
            dt * 1e6,
            exact=ok,
            max_abs_gap=f"{float(jnp.abs(got - want).max()):.2e}",
        )
    return exact


def _rel_gap(a, b) -> float:
    gaps = []
    for la, lb in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)):
        la, lb = np.asarray(la), np.asarray(lb)
        gaps.append(np.abs(la - lb).max() / max(np.abs(la).max(), 1e-12))
    return float(max(gaps))


def bench_parity(args) -> float:
    """All-pass 2-tier plane vs flat service on one recorded stream."""
    spec = make_mlp_spec()
    params = spec.init(jax.random.PRNGKey(args.seed))
    hp = FedQSHyperParams(buffer_k=args.buffer_k)
    stream = list(synthetic_stream(params, args.parity_clients,
                                   args.parity_updates, seed=args.seed))

    flat = StreamingAggregator(make_algorithm(args.algo, hp), hp, params,
                               args.parity_clients, batched=True)
    replay(flat, stream, flush=False)

    topo = Topology.from_spec(f"hier:{args.parity_edges}", args.parity_clients)
    hier = HierarchicalService(make_algorithm(args.algo, hp), hp, params,
                               args.parity_clients, topo)
    t0 = time.perf_counter()
    replay(hier, stream, flush=False)
    dt = time.perf_counter() - t0

    gap = _rel_gap(flat.global_params, hier.global_params)
    table_ok = bool(
        (np.asarray(flat.table.counts) == np.asarray(hier.table.counts)).all()
        and np.allclose(np.asarray(flat.table.sims),
                        np.asarray(hier.table.sims))
    )
    rounds_ok = flat.round == hier.round
    emit(
        "hier_parity_2tier",
        dt / max(len(stream), 1) * 1e6,
        rel_gap=f"{gap:.2e}",
        rounds=f"{hier.round}/{flat.round}",
        table_exact=table_ok,
        equivalent=bool(gap <= PARITY_RTOL and table_ok and rounds_ok),
    )
    return gap if (table_ok and rounds_ok) else float("inf")


def bench_throughput(args) -> float:
    """Global-stage aggregation latency, flat vs tiered, at scale."""
    spec = make_mlp_spec()
    params = spec.init(jax.random.PRNGKey(args.seed))
    n, edges, regions = args.clients, args.edges, args.regions
    K = args.agg_k
    hp = FedQSHyperParams(buffer_k=K)
    stream = list(synthetic_stream(params, n, args.updates, seed=args.seed,
                                   distinct_deltas=4))

    results = {}
    for name, build in (
        ("flat", lambda: StreamingAggregator(
            make_algorithm(args.algo, hp), hp, params, n,
            trigger=KBuffer(K), batched=True)),
        ("hier", lambda: HierarchicalService(
            make_algorithm(args.algo, hp), hp, params, n,
            Topology.from_spec(f"hier:{edges}x{regions}", n),
            trigger=KBuffer(K),
            edge_trigger=lambda e: KBuffer(max(1, K // edges)),
            region_trigger=lambda r: KBuffer(max(1, K // regions)))),
    ):
        svc = build()
        warm = build()
        replay(warm, stream[: K + edges], flush=True)  # compile the shapes
        t0 = time.perf_counter()
        replay(svc, stream, flush=False)
        wall = time.perf_counter() - t0
        s = svc.stats
        agg_ms = s.agg_seconds / max(s.rounds, 1) * 1e3
        results[name] = agg_ms
        emit(
            f"hier_throughput_{name}_{n}c_{edges}e",
            s.agg_seconds / max(s.accepted, 1) * 1e6,
            global_agg_ms_per_round=f"{agg_ms:.2f}",
            rounds=s.rounds,
            updates=s.accepted,
            total_wall_s=f"{wall:.1f}",
            updates_per_sec=f"{s.accepted / wall:.0f}",
        )
    speedup = results["flat"] / max(results["hier"], 1e-9)
    emit("hier_throughput_speedup", 0.0, speedup=f"{speedup:.1f}",
         gate=f">={SPEEDUP_FACTOR:g}x")
    return speedup


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--algo", default="fedqs-sgd")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--buffer-k", type=int, default=10)
    # parity section (the ci.sh smoke runs exactly this config)
    ap.add_argument("--parity-clients", type=int, default=200)
    ap.add_argument("--parity-updates", type=int, default=600)
    ap.add_argument("--parity-edges", type=int, default=16)
    ap.add_argument("--parity-only", action="store_true",
                    help="kernel + parity gates only (the CI smoke)")
    # throughput section
    ap.add_argument("--clients", type=int, default=10_000)
    ap.add_argument("--edges", type=int, default=64)
    ap.add_argument("--regions", type=int, default=8)
    ap.add_argument("--agg-k", type=int, default=1024,
                    help="global K-buffer (and the flat stacking size)")
    ap.add_argument("--updates", type=int, default=6000)
    ap.add_argument("--fast", action="store_true",
                    help="smaller kernel/throughput sections")
    args = ap.parse_args(argv)
    if args.fast:
        args.clients, args.edges, args.regions = 2000, 16, 4
        args.agg_k, args.updates = 256, 1500
        args.parity_updates = 300

    failures = []
    if not bench_kernel(args):
        failures.append("kernel gate: segment_agg_op != segment_agg_ref (fp32)")
    gap = bench_parity(args)
    if gap > PARITY_RTOL:
        failures.append(
            f"parity gate: 2-tier vs flat rel gap {gap:.2e} > {PARITY_RTOL:g}")
    if not args.parity_only:
        speedup = bench_throughput(args)
        if speedup < SPEEDUP_FACTOR:
            failures.append(
                f"throughput gate: hier global stage only {speedup:.1f}x "
                f"faster than flat (< {SPEEDUP_FACTOR:g}x)")
    if failures:
        raise SystemExit("hierarchy regression: " + "; ".join(failures))


run = make_suite_run(main, "--fast")  # harness entry: python -m benchmarks.run


if __name__ == "__main__":
    main()
