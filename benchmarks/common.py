"""Shared helpers for the benchmark harness.

Every benchmark prints CSV rows ``name,us_per_call,derived`` where
``us_per_call`` is wall-microseconds per simulated global round (or per
kernel call) and ``derived`` carries the paper-table metric
(accuracy / gap / rounds-to-target / ...) as ``key=value|key=value``.

Importing this module also puts ``src/`` on ``sys.path`` (resolved
relative to this file, not the CWD), so every benchmark works both as a
harness suite (``python -m benchmarks.run``) and as a bare script
(``python benchmarks/bench_<x>.py``) without its own path bootstrap.
"""
from __future__ import annotations

import os
import sys
import time
from typing import Dict, Optional

_SRC = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..", "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

from repro.core import FedQSHyperParams, SAFLEngine, make_algorithm
from repro.data import make_federated_data
from repro.models import make_cnn_spec, make_lstm_spec, make_mlp_spec


def make_suite_run(main, fast_flag: str = "--quick"):
    """Bind a benchmark's ``main(argv)`` into the ``run(fast=...)`` entry
    the harness (``python -m benchmarks.run``) calls — the one place the
    ``--fast``/``--quick`` threading convention lives."""

    def run(fast: bool = False):
        main([fast_flag] if fast else [])

    return run

_SPEC_CACHE: Dict[str, object] = {}


def get_spec(task: str):
    if task not in _SPEC_CACHE:
        _SPEC_CACHE[task] = {
            "cv": lambda: make_cnn_spec(width=10, batch_size=32),
            "nlp": lambda: make_lstm_spec(embed=16, hidden=32, batch_size=32),
            "rwd": lambda: make_mlp_spec(),
        }[task]()
    return _SPEC_CACHE[task]


_DATA_CACHE: Dict[tuple, object] = {}


def get_data(task: str, n_clients: int, **kw):
    key = (task, n_clients, tuple(sorted(kw.items())))
    if key not in _DATA_CACHE:
        _DATA_CACHE[key] = make_federated_data(task, n_clients, **kw)
    return _DATA_CACHE[key]


def run_safl(task: str, algo: str, *, rounds: int = 40, n_clients: int = 20,
             hp: Optional[FedQSHyperParams] = None, seed: int = 0,
             sync_mode: bool = False, resource_ratio: float = 50.0,
             dynamics=None, eval_every: int = 2, **data_kw):
    hp = hp or FedQSHyperParams(buffer_k=max(3, n_clients // 5))
    data = get_data(task, n_clients, seed=seed, n_total=4000, **data_kw)
    eng = SAFLEngine(data, get_spec(task), make_algorithm(algo, hp), hp,
                     seed=seed, eval_every=eval_every, sync_mode=sync_mode,
                     resource_ratio=resource_ratio, dynamics=dynamics)
    res = eng.run(rounds)
    return eng, res


# Machine-readable twin of the CSV rows: every emit() call also appends
# a plain dict here, and the harness (benchmarks/run.py) drains the list
# after each suite into BENCH_<suite>.json so the perf trajectory is
# tracked run over run, not lost in terminal scrollback.
_RESULTS = []


def drain_results():
    """Return and clear the rows emitted since the last drain."""
    global _RESULTS
    rows, _RESULTS = _RESULTS, []
    return rows


def emit(name: str, us_per_call: float, **derived):
    d = "|".join(f"{k}={v}" for k, v in derived.items())
    print(f"{name},{us_per_call:.1f},{d}")
    sys.stdout.flush()
    _RESULTS.append({"name": name, "us_per_call": round(float(us_per_call), 1),
                     "derived": {k: str(v) for k, v in derived.items()}})


def us_per_round(res, rounds: int) -> float:
    return res.wall_seconds / max(rounds, 1) * 1e6
