"""Training-health plane gates (docs/OBSERVABILITY.md):

1. **overhead** — enabling the health plane (on-kernel update statistics
   + streaming detectors + in-memory hub) on the batched fused path may
   cost at most 5% sustained updates/sec vs the same service without it;
2. **bit-identity** — the stats variant emits its extra outputs in the
   same VMEM pass but must not perturb aggregation: enabled and disabled
   services must land on bit-identical global params;
3. **efficacy** — a seeded norm explosion (``inject_norm_explosion``)
   must raise a health alert within 5 rounds of the injection round;
4. **silence** — the healthy synthetic stream must produce zero alerts
   (the detectors are useless if they cry wolf);
5. **postmortem round-trip** — the on-alert flight dump must render
   through ``repro.telemetry.report.postmortem_report``.

CSV rows follow benchmarks/common.py: ``name,us_per_call,derived``.

    PYTHONPATH=src python benchmarks/bench_health.py [--updates 800] [--quick]
"""
from __future__ import annotations

import argparse
import os
import tempfile
import time

try:
    from .common import emit, make_suite_run
except ImportError:  # run as a script: python benchmarks/bench_health.py
    from common import emit, make_suite_run

import jax
import numpy as np

from repro.core import FedQSHyperParams, make_algorithm
from repro.models import make_mlp_spec
from repro.serve import KBuffer, StreamingAggregator, replay, synthetic_stream
from repro.serve.stream import inject_norm_explosion
from repro.telemetry import Telemetry


def _make_service(params, args, telemetry=None, *, buffer_k=None):
    hp = FedQSHyperParams(buffer_k=buffer_k or args.buffer_k)
    return StreamingAggregator(
        make_algorithm("fedqs-sgd", hp), hp, params, args.clients,
        trigger=KBuffer(hp.buffer_k), batched=True, telemetry=telemetry)


def bench_overhead(params, args):
    """Gates 1+2: paired throughput + bit-identity, health plane on/off.

    Chunk-interleaved paired timing (the bench_serve telemetry-gate
    recipe): both services advance through the SAME stream in
    alternating ~50-update chunks with the order flipped per chunk, so
    scheduler bursts hit both configs and only a genuine regression
    survives the accumulation.  Re-measured up to 3× on a breach —
    noise decorrelates across attempts, a real >5% regression does not.
    """
    stream = list(synthetic_stream(params, args.clients,
                                   max(args.updates, 800), seed=args.seed))

    # compile warm-up for BOTH jitted round variants (the stats round is
    # a different program: extra VMEM outputs) so steady state is timed
    replay(_make_service(params, args), stream[: args.buffer_k], flush=True)
    replay(_make_service(params, args, Telemetry.in_memory(health=True)),
           stream[: args.buffer_k], flush=True)

    passes, chunk = (3, 50) if args.quick else (5, 50)
    services = {}

    def measure():
        total = {"plain": 0.0, "health": 0.0}
        for rep in range(passes):
            pair = [("plain", _make_service(params, args)),
                    ("health", _make_service(
                        params, args, Telemetry.in_memory(health=True)))]
            for key, svc in pair:
                services[key] = svc
            for ci, start in enumerate(range(0, len(stream), chunk)):
                part = stream[start:start + chunk]
                for key, svc in (pair if (rep + ci) % 2 == 0 else pair[::-1]):
                    t0 = time.perf_counter()
                    replay(svc, part, flush=False)
                    total[key] += time.perf_counter() - t0
        return total

    attempts = []
    for _ in range(3):
        total = measure()
        attempts.append((total["health"] / total["plain"] - 1.0, total))
        if attempts[-1][0] <= 0.05:
            break
    overhead, total = min(attempts, key=lambda a: a[0])
    n_updates = passes * len(stream)
    plain_ups = n_updates / total["plain"]
    health_ups = n_updates / total["health"]

    gap = max(
        float(np.abs(np.asarray(a) - np.asarray(b)).max())
        for a, b in zip(
            jax.tree_util.tree_leaves(services["plain"].global_params),
            jax.tree_util.tree_leaves(services["health"].global_params))
    )
    hm = services["health"].telemetry.health
    emit(
        "serve_health_overhead",
        1e6 / max(health_ups, 1e-9),
        plain_updates_per_sec=f"{plain_ups:.1f}",
        health_updates_per_sec=f"{health_ups:.1f}",
        overhead_pct=f"{overhead * 100:.1f}",
        measurements=len(attempts),
        bit_identical=(gap == 0.0),
        alerts=len(hm.alerts),
    )
    if gap != 0.0:
        raise SystemExit(f"health plane changed aggregation: gap={gap:.3e}")
    if overhead > 0.05:
        raise SystemExit(
            f"health overhead gate: {overhead * 100:.1f}% updates/sec "
            f"regression (> 5%): plain={plain_ups:.1f}, "
            f"health={health_ups:.1f}")
    # gate 4 piggybacks on the measured run: the synthetic stream is
    # healthy by construction, so the detectors must have stayed silent
    emit("serve_health_silent", 0.0, alerts=len(hm.alerts),
         rounds=services["health"].round, ok=(len(hm.alerts) == 0))
    if hm.alerts:
        a = hm.alerts[0]
        raise SystemExit(
            f"health detectors alerted on a healthy stream: "
            f"{a.detector} z={a.zscore:.1f} @ round {a.round}")


def bench_efficacy(params, args):
    """Gates 3+5: seeded chaos must alert fast, and the on-alert flight
    dump must round-trip through the postmortem renderer."""
    from repro.telemetry.report import postmortem_report

    k = 5
    after = 50
    inj_round = after // k + 1  # round that aggregates the first hot update
    stream = list(inject_norm_explosion(
        synthetic_stream(params, 16, 120, seed=args.seed),
        after=after, scale=100.0))

    with tempfile.TemporaryDirectory() as tmp:
        flight = os.path.join(tmp, "flight.jsonl")
        tel = Telemetry.in_memory(health=True, flightrec=flight)
        svc = StreamingAggregator(
            make_algorithm("fedqs-sgd", FedQSHyperParams(buffer_k=k)),
            FedQSHyperParams(buffer_k=k), params, 16,
            trigger=KBuffer(k), batched=True, telemetry=tel)
        t0 = time.perf_counter()
        replay(svc, stream)
        dt = time.perf_counter() - t0
        hm = tel.health
        first = min((a.round for a in hm.alerts), default=-1)
        lag = first - inj_round if first >= 0 else -1
        ok = hm.alerts and 0 <= lag <= 5
        emit(
            "serve_health_efficacy",
            dt / max(len(stream), 1) * 1e6,
            inject_round=inj_round,
            first_alert_round=first,
            detect_lag_rounds=lag,
            alerts=len(hm.alerts),
            critical=sum(1 for a in hm.alerts if a.severity == "critical"),
            ok=bool(ok),
        )
        if not ok:
            raise SystemExit(
                f"health efficacy gate: injected divergence at round "
                f"{inj_round}, first alert at round {first} "
                f"(must be within 5 rounds)")

        dumped = sorted(
            p for p in os.listdir(tmp) if p.startswith("flight.jsonl"))
        report = postmortem_report(flight)
        roundtrip_ok = (os.path.exists(flight)
                        and "black box" in report
                        and "alert" in report)
        tel.close()
        emit("health_postmortem_roundtrip", 0.0,
             dumps=len(dumped), report_lines=len(report.splitlines()),
             ok=bool(roundtrip_ok))
        if not roundtrip_ok:
            raise SystemExit("flight dump failed to round-trip through "
                             "postmortem_report")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", type=int, default=64)
    ap.add_argument("--updates", type=int, default=800)
    ap.add_argument("--buffer-k", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args(argv)

    spec = make_mlp_spec()
    params = spec.init(jax.random.PRNGKey(args.seed))
    bench_overhead(params, args)
    bench_efficacy(params, args)


run = make_suite_run(main)


if __name__ == "__main__":
    main()
