"""Streaming-service throughput: sustained updates/sec per trigger policy,
batched-vs-sequential aggregation, and stream-vs-virtual-clock parity.

CSV rows follow benchmarks/common.py: ``name,us_per_call,derived`` where
us_per_call is wall-microseconds per *submitted update* and derived
carries updates/sec, rounds fired, and admission drops.

Reading the numbers: the K-buffer trigger aggregates fixed-shape [K, D]
batches, so XLA compiles the round once and steady state is a few ms per
round.  Variable-K triggers (time-window; quorum grace fires; end-of-stream
flushes) used to pay a per-shape compile on every new buffer size — a
profile of serve_timewindow showed ~5.5 s of its aggregate wall time was
backend_compile across 364 pjit cache misses, 626 ms/round mean.  The
time-window row therefore runs the batched *fused* ingestion path, whose
``bucket_rows`` power-of-two row padding caps compiles at log2(K_max)
per payload shape (repro/serve/batched.py); the sequential variable-K
rows are kept for contrast.

    PYTHONPATH=src python benchmarks/bench_serve.py [--updates 400] [--quick]
"""
from __future__ import annotations

import argparse
import contextlib
import time

try:
    from .common import emit, make_suite_run
except ImportError:  # run as a script: python benchmarks/bench_serve.py
    from common import emit, make_suite_run

import jax
import numpy as np

from repro.core import FedQSHyperParams, SAFLEngine, make_algorithm
from repro.data import make_federated_data
from repro.models import make_mlp_spec
from repro.serve import (
    CaptureStream,
    KBuffer,
    Quorum,
    StalenessAdmission,
    StreamingAggregator,
    TimeWindow,
    flatten_bursts,
    replay,
    replay_bursts,
    synthetic_stream,
    zipf_burst_stream,
)


def bench_trigger(name, trigger, params, args, *, admission=None, batched=False,
                  algo="fedqs-sgd"):
    hp = FedQSHyperParams(buffer_k=args.buffer_k)
    svc = StreamingAggregator(
        make_algorithm(algo, hp), hp, params, args.clients,
        trigger=trigger, admission=admission, batched=batched,
    )
    stream = list(synthetic_stream(params, args.clients, args.updates,
                                   seed=args.seed))
    # warm-up: compile the aggregation path once so steady-state throughput
    # is measured, not jit tracing
    warm = StreamingAggregator(
        make_algorithm(algo, hp), hp, params, args.clients,
        trigger=KBuffer(args.buffer_k), admission=admission, batched=batched)
    replay(warm, stream[: args.buffer_k], flush=True)

    t0 = time.perf_counter()
    replay(svc, stream)
    dt = time.perf_counter() - t0
    s = svc.stats
    emit(
        name,
        dt / max(s.submitted, 1) * 1e6,
        updates_per_sec=f"{s.submitted / dt:.1f}",
        rounds=s.rounds,
        dropped=s.dropped,
        mean_agg_ms=f"{s.agg_seconds / max(s.rounds, 1) * 1e3:.2f}",
    )
    return svc


def bench_parity(args):
    """Stream replay vs the virtual-clock engine on the seed small model."""
    data = make_federated_data("rwd", 10, sigma=1.0, seed=0, n_total=1000)
    spec = make_mlp_spec()
    hp = FedQSHyperParams(buffer_k=4)
    eng = SAFLEngine(data, spec, make_algorithm("fedqs-sgd", hp), hp, seed=1)
    init = eng.global_params
    cap = CaptureStream()
    cap.wrap(eng.service)
    t0 = time.perf_counter()
    eng.run(args.parity_rounds)
    dt_engine = time.perf_counter() - t0

    svc = StreamingAggregator(make_algorithm("fedqs-sgd", hp), hp, init,
                              data.n_clients)
    t0 = time.perf_counter()
    replay(svc, cap.updates, flush=False)
    dt_stream = time.perf_counter() - t0

    gap = max(
        float(np.abs(np.asarray(a) - np.asarray(b)).max())
        for a, b in zip(jax.tree_util.tree_leaves(eng.global_params),
                        jax.tree_util.tree_leaves(svc.global_params))
    )
    ok = gap <= 1e-5 and svc.round == eng.round
    emit(
        "serve_parity_vs_virtual_clock",
        dt_stream / max(len(cap.updates), 1) * 1e6,
        equivalent=ok,
        max_abs_gap=f"{gap:.2e}",
        rounds=svc.round,
        engine_s=f"{dt_engine:.2f}",
        stream_s=f"{dt_stream:.2f}",
    )
    if not ok:
        raise SystemExit(f"stream/virtual-clock divergence: gap={gap:.3e}")


def bench_telemetry(params, args):
    """Telemetry-plane gates (docs/OBSERVABILITY.md):

    1. **overhead** — enabling a ring-sink telemetry hub may cost at most
       5% sustained updates/sec vs the same service without one;
    2. **bit-identity** — telemetry never touches tensors: the enabled
       and disabled services must land on bit-identical global params;
    3. **flat/hier parity** — on an all-pass run the flat and the
       hierarchical service must emit the same member-level event stream
       (update-admitted + round-fired, timing fields excluded).
    """
    from repro.hier import HierarchicalService, parse_topology
    from repro.telemetry import Telemetry

    hp = FedQSHyperParams(buffer_k=args.buffer_k)
    # the overhead gate needs enough updates that a replay outlasts host
    # scheduling jitter — never trim it below 800 even in --quick (at
    # ~1e3 updates/s that is <1s per replay; jitter on shorter replays
    # swamps the few-µs true emit cost the gate measures)
    stream = list(synthetic_stream(params, args.clients,
                                   max(args.updates, 800), seed=args.seed))

    def make_flat(telemetry=None):
        return StreamingAggregator(
            make_algorithm("fedqs-sgd", hp), hp, params, args.clients,
            trigger=KBuffer(args.buffer_k), telemetry=telemetry)

    # compile warm-up so both timings measure steady state
    replay(make_flat(), stream[: args.buffer_k], flush=True)

    # Chunk-interleaved paired timing: whole-replay wall times jitter
    # ±10%+ on a busy host, far above the few-µs-per-update emit cost the
    # gate measures.  Instead the plain and telemetry services advance
    # through the SAME stream in alternating ~50-update chunks (order
    # flipped per chunk), so every scheduler burst hits both configs, and
    # the accumulated per-config totals over several passes compare like
    # for like.  A genuine >5% regression inflates every telemetry chunk
    # and survives the averaging; transient noise cancels.
    passes, chunk = (3, 50) if args.quick else (5, 50)
    services = {}

    def measure():
        total = {"plain": 0.0, "tel": 0.0}
        for rep in range(passes):
            pair = [("plain", make_flat()),
                    ("tel", make_flat(Telemetry.in_memory()))]
            for key, svc in pair:
                services[key] = svc
            for ci, start in enumerate(range(0, len(stream), chunk)):
                part = stream[start:start + chunk]
                for key, svc in (pair if (rep + ci) % 2 == 0 else pair[::-1]):
                    t0 = time.perf_counter()
                    replay(svc, part, flush=False)
                    total[key] += time.perf_counter() - t0
        return total

    # The per-round XLA dispatch this host serves varies several-fold run
    # to run, so a single paired measurement still carries ±10% noise —
    # far above the few-µs true emit cost.  Re-measure independently on a
    # breach and fail only if EVERY attempt exceeds the gate: transient
    # noise decorrelates across attempts, a real >5% regression does not.
    attempts = []
    for _ in range(3):
        total = measure()
        attempts.append((total["tel"] / total["plain"] - 1.0, total))
        if attempts[-1][0] <= 0.05:
            break
    overhead, total = min(attempts, key=lambda a: a[0])
    n_updates = passes * len(stream)
    plain_ups = n_updates / total["plain"]
    tel_ups = n_updates / total["tel"]

    gap = max(
        float(np.abs(np.asarray(a) - np.asarray(b)).max())
        for a, b in zip(
            jax.tree_util.tree_leaves(services["plain"].global_params),
            jax.tree_util.tree_leaves(services["tel"].global_params))
    )
    emit(
        "serve_telemetry_overhead",
        1e6 / max(tel_ups, 1e-9),
        plain_updates_per_sec=f"{plain_ups:.1f}",
        telemetry_updates_per_sec=f"{tel_ups:.1f}",
        overhead_pct=f"{overhead * 100:.1f}",
        measurements=len(attempts),
        bit_identical=(gap == 0.0),
    )
    if gap != 0.0:
        raise SystemExit(f"telemetry changed aggregation results: gap={gap:.3e}")
    if overhead > 0.05:
        raise SystemExit(
            f"telemetry overhead gate: {overhead * 100:.1f}% updates/sec "
            f"regression (> 5%): plain={plain_ups:.1f}, telemetry={tel_ups:.1f}")

    def member_events(factory):
        tel = Telemetry.in_memory()
        replay(factory(tel), stream, flush=False)
        return [
            {k: v for k, v in rec.items() if k != "agg_seconds"}
            for rec in tel.ring.records
            if rec["e"] in ("update-admitted", "round-fired")
        ]

    flat_events = member_events(make_flat)
    topo = parse_topology("hier:8", args.clients)
    hier_events = member_events(lambda tel: HierarchicalService(
        make_algorithm("fedqs-sgd", hp), hp, params, args.clients, topo,
        trigger=KBuffer(args.buffer_k), telemetry=tel))
    same = flat_events == hier_events
    emit(
        "serve_telemetry_hier_parity",
        0.0,
        equivalent=same,
        member_events=len(flat_events),
    )
    if not same:
        diff = next(i for i, (a, b) in enumerate(zip(flat_events, hier_events))
                    if a != b) if len(flat_events) == len(hier_events) else -1
        raise SystemExit(
            f"flat/hier member-level event streams diverge "
            f"(flat={len(flat_events)}, hier={len(hier_events)}, "
            f"first diff at {diff})")


def bench_trace(params, args):
    """Trace-plane gates (docs/OBSERVABILITY.md):

    1. **overhead** — span tracing + kernel timing hooks may cost at most
       5% sustained updates/sec vs the same service with telemetry=None;
    2. **bit-identity** — tracing never touches tensors: traced and
       untraced services must land on bit-identical global params;
    3. **coverage** — the critical-path analyzer must explain the round
       wall with measured stages (coverage in [0.9, 1.1]).

    Same chunk-interleaved paired methodology as the telemetry overhead
    gate above: both services advance through the SAME stream in
    alternating ~50-update chunks, repeated over several passes, with
    independent re-measurement on a breach so only a persistent
    regression fails the gate.
    """
    from repro.telemetry import Telemetry, profile
    from repro.telemetry.critical_path import stage_summary

    hp = FedQSHyperParams(buffer_k=args.buffer_k)
    stream = list(synthetic_stream(params, args.clients,
                                   max(args.updates, 800), seed=args.seed))

    def make_flat(telemetry=None):
        return StreamingAggregator(
            make_algorithm("fedqs-sgd", hp), hp, params, args.clients,
            trigger=KBuffer(args.buffer_k), telemetry=telemetry)

    replay(make_flat(), stream[: args.buffer_k], flush=True)

    passes, chunk = (3, 50) if args.quick else (5, 50)
    services = {}
    tracers = {}

    def measure():
        total = {"plain": 0.0, "trace": 0.0}
        for rep in range(passes):
            tel = Telemetry.in_memory(trace=True)
            pair = [("plain", make_flat(), None),
                    ("trace", make_flat(tel), tel)]
            for key, svc, _ in pair:
                services[key] = svc
            tracers["trace"] = tel.tracer
            for ci, start in enumerate(range(0, len(stream), chunk)):
                part = stream[start:start + chunk]
                for key, svc, t in (pair if (rep + ci) % 2 == 0 else pair[::-1]):
                    scope = (profile.activate(t) if t is not None
                             else contextlib.nullcontext())
                    with scope:
                        t0 = time.perf_counter()
                        replay(svc, part, flush=False)
                        total[key] += time.perf_counter() - t0
        return total

    attempts = []
    for _ in range(3):
        total = measure()
        attempts.append((total["trace"] / total["plain"] - 1.0, total))
        if attempts[-1][0] <= 0.05:
            break
    overhead, total = min(attempts, key=lambda a: a[0])
    n_updates = passes * len(stream)
    plain_ups = n_updates / total["plain"]
    trace_ups = n_updates / total["trace"]

    gap = max(
        float(np.abs(np.asarray(a) - np.asarray(b)).max())
        for a, b in zip(
            jax.tree_util.tree_leaves(services["plain"].global_params),
            jax.tree_util.tree_leaves(services["trace"].global_params))
    )
    summary = stage_summary(tracers["trace"].spans)
    coverage = summary["coverage"]
    emit(
        "serve_trace_overhead",
        1e6 / max(trace_ups, 1e-9),
        plain_updates_per_sec=f"{plain_ups:.1f}",
        traced_updates_per_sec=f"{trace_ups:.1f}",
        overhead_pct=f"{overhead * 100:.1f}",
        measurements=len(attempts),
        bit_identical=(gap == 0.0),
        spans=summary["spans"],
        rounds=summary["rounds"],
        coverage=f"{coverage:.4f}",
    )
    if gap != 0.0:
        raise SystemExit(f"tracing changed aggregation results: gap={gap:.3e}")
    if overhead > 0.05:
        raise SystemExit(
            f"trace overhead gate: {overhead * 100:.1f}% updates/sec "
            f"regression (> 5%): plain={plain_ups:.1f}, traced={trace_ups:.1f}")
    if not 0.9 <= coverage <= 1.1:
        raise SystemExit(
            f"critical-path coverage gate: measured stages explain "
            f"{coverage:.1%} of round wall (outside [90%, 110%])")


def bench_saturation(params, args):
    """Overlapped-round saturation gate (docs/ARCHITECTURE.md
    'Overlapped rounds'): a Zipf-popularity burst trace over a
    million-client population replays through the synchronous per-update
    service and the pipelined burst path.  Two hard gates:

    1. **throughput** — the pipelined service must sustain **≥3×** the
       synchronous updates/sec (vectorized admission verdicts plus the
       device aggregation of round *r* overlapping the host ingestion of
       round *r+1*);
    2. **bit-identity** — overlap is a latency optimization, never a
       semantics change: both services must land on bit-identical global
       params and identical ``ServiceStats`` (wall time excluded).
    """
    import dataclasses

    n_clients, n_updates, k, burst = ((120_000, 8_000, 1024, 1024)
                                      if args.quick else
                                      (1_000_000, 40_000, 2048, 2048))
    hp = FedQSHyperParams(buffer_k=k)
    bursts = list(zipf_burst_stream(params, n_clients, n_updates,
                                    seed=args.seed, burst=burst,
                                    stale_spread=3))
    flat = flatten_bursts(bursts)
    admission = StalenessAdmission(tau_max=2, mode="downweight")

    def make(pipelined):
        return StreamingAggregator(
            make_algorithm("fedqs-sgd", hp), hp, params, n_clients,
            trigger=KBuffer(k), admission=admission, batched=True,
            pipeline=pipelined)

    # compile warm-up: one full-K round plus the partial flush shape
    replay(make(False), flat[: k + k // 2])

    sync = make(False)
    t0 = time.perf_counter()
    replay(sync, flat)
    dt_sync = time.perf_counter() - t0

    pipe = make(True)
    t0 = time.perf_counter()
    replay_bursts(pipe, bursts)
    dt_pipe = time.perf_counter() - t0
    pipe.close()

    gap = max(
        float(np.abs(np.asarray(a) - np.asarray(b)).max())
        for a, b in zip(jax.tree_util.tree_leaves(sync.global_params),
                        jax.tree_util.tree_leaves(pipe.global_params))
    )
    stats = [dataclasses.asdict(s.stats) for s in (sync, pipe)]
    for d in stats:
        d.pop("agg_seconds")
    same_stats = stats[0] == stats[1]
    speedup = dt_sync / dt_pipe
    emit(
        "serve_saturation",
        dt_pipe / max(n_updates, 1) * 1e6,
        clients=n_clients,
        sync_updates_per_sec=f"{n_updates / dt_sync:.1f}",
        pipelined_updates_per_sec=f"{n_updates / dt_pipe:.1f}",
        speedup=f"{speedup:.2f}",
        rounds=sync.stats.rounds,
        dropped=sync.stats.dropped,
        bit_identical=(gap == 0.0 and same_stats),
        gate=bool(speedup >= 3.0),
    )
    if gap != 0.0:
        raise SystemExit(
            f"saturation gate: pipelined params diverge from synchronous "
            f"(max abs gap {gap:.3e})")
    if not same_stats:
        raise SystemExit(
            f"saturation gate: ServiceStats diverge: sync={stats[0]} "
            f"pipelined={stats[1]}")
    if speedup < 3.0:
        raise SystemExit(
            f"saturation gate: pipelined speedup {speedup:.2f}x < 3x "
            f"(sync={n_updates / dt_sync:.1f} up/s, "
            f"pipelined={n_updates / dt_pipe:.1f} up/s)")


def bench_straggler_adaptive(params, args):
    """Adaptive-deadline gate (docs/ROBUSTNESS.md): the same
    straggler-heavy stream replays through a fixed ``TimeWindow`` and an
    ``AdaptiveTimeWindow`` under drop-mode staleness admission; the
    adaptive service must drop **≥30% fewer** updates.

    The adaptive trigger runs with ``min_window = window`` here: the
    point of adaptation on this workload is *stretching* the deadline so
    straggler deliveries land inside their round — allowing it to also
    contract below the operator deadline early on (before any slow
    delivery has physically arrived to be observed) would race the round
    counter ahead on fast-only history, the failure mode the gate exists
    to catch.
    """
    from repro.scenarios import get_scenario
    from repro.serve import AdaptiveTimeWindow, scenario_stream

    hp = FedQSHyperParams(buffer_k=args.buffer_k)
    n_clients, n_updates = 64, max(args.updates, 600)
    stream = list(scenario_stream(params, get_scenario("straggler-heavy"),
                                  n_clients, n_updates, seed=args.seed))

    def run(trigger):
        svc = StreamingAggregator(
            make_algorithm("fedqs-sgd", hp), hp, params, n_clients,
            trigger=trigger,
            admission=StalenessAdmission(tau_max=2, mode="drop"),
            batched=True)
        t0 = time.perf_counter()
        replay(svc, iter(stream))
        return svc, time.perf_counter() - t0

    fixed, _ = run(TimeWindow(args.window, min_updates=2))
    adaptive, dt = run(AdaptiveTimeWindow(args.window, min_updates=2,
                                          min_window=args.window))
    reduction = 1.0 - adaptive.stats.dropped / max(fixed.stats.dropped, 1)
    emit(
        "serve_straggler_adaptive",
        dt / max(adaptive.stats.submitted, 1) * 1e6,
        fixed_dropped=fixed.stats.dropped,
        adaptive_dropped=adaptive.stats.dropped,
        drop_reduction_pct=f"{reduction * 100:.1f}",
        fixed_rounds=fixed.stats.rounds,
        adaptive_rounds=adaptive.stats.rounds,
        gate=bool(reduction >= 0.30),
    )
    if reduction < 0.30:
        raise SystemExit(
            f"adaptive-deadline gate: drop reduction {reduction * 100:.1f}% "
            f"< 30% (fixed={fixed.stats.dropped}, "
            f"adaptive={adaptive.stats.dropped})")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--updates", type=int, default=400)
    ap.add_argument("--clients", type=int, default=64)
    ap.add_argument("--buffer-k", type=int, default=10)
    ap.add_argument("--window", type=float, default=3.0)
    ap.add_argument("--parity-rounds", type=int, default=5)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args(argv)
    if args.quick:
        args.updates, args.parity_rounds = 120, 3

    spec = make_mlp_spec()
    params = spec.init(jax.random.PRNGKey(args.seed))

    k, q = args.buffer_k, max(2, args.buffer_k // 2)
    bench_trigger("serve_kbuffer", KBuffer(k), params, args)
    bench_trigger("serve_timewindow", TimeWindow(args.window, min_updates=2),
                  params, args, batched=True)
    bench_trigger("serve_quorum", Quorum(k, q, grace=args.window), params, args)
    bench_trigger("serve_kbuffer_batched", KBuffer(k), params, args, batched=True)
    bench_trigger("serve_kbuffer_admission", KBuffer(k), params, args,
                  admission=StalenessAdmission(tau_max=2, mode="drop"))
    bench_saturation(params, args)
    bench_straggler_adaptive(params, args)
    bench_parity(args)
    bench_telemetry(params, args)
    bench_trace(params, args)


run = make_suite_run(main)  # harness entry: python -m benchmarks.run


if __name__ == "__main__":
    main()
