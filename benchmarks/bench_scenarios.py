"""Scenario engine benchmark: catalog scenarios through the event-driven
engine, and the vectorized cohort fast path at 10k+ clients.

CSV rows follow benchmarks/common.py: ``name,us_per_call,derived`` where
us_per_call is wall-microseconds per aggregation round and derived
carries accuracy / virtual time / throughput.

The headline row is ``cohort_diurnal_churn_10000``: a 10,000-client
diurnal-churn scenario (bimodal speeds, sinusoidal availability,
periodic join/leave) end-to-end through the virtual-clock cohort engine
— the acceptance gate is wall < 60 s on CPU, and the script exits
non-zero if it regresses past that.

    PYTHONPATH=src python benchmarks/bench_scenarios.py [--quick]
"""
from __future__ import annotations

import argparse
import time

try:
    from .common import emit, make_suite_run
except ImportError:  # run as a script: python benchmarks/bench_scenarios.py
    from common import emit, make_suite_run

from repro.core import FedQSHyperParams, SAFLEngine, make_algorithm
from repro.data import make_federated_data
from repro.models import make_mlp_spec
from repro.scenarios import CohortEngine, get_scenario


ENGINE_SCENARIOS = [
    "static", "resource-shift", "unstable", "dropout", "churn",
    "diurnal", "burst", "zipf-poisson", "drift", "degrade",
]


def bench_engine_scenarios(args):
    """Every catalog scenario through the paper-faithful event engine."""
    spec = make_mlp_spec()
    hp = FedQSHyperParams(buffer_k=max(3, args.clients // 5))
    for name in ENGINE_SCENARIOS:
        # fresh data per scenario: data-mutating events (drift) edit client
        # datasets in place and must not contaminate later rows
        data = make_federated_data("rwd", args.clients, sigma=1.0, seed=0,
                                   n_total=2000)
        scn = get_scenario(name)
        eng = SAFLEngine(data, spec, make_algorithm("fedqs-sgd", hp), hp,
                         seed=0, eval_every=2, scenario=scn)
        res = eng.run(args.rounds)
        rounds = max(eng.round, 1)
        emit(
            f"scenario_{name.replace('-', '_')}",
            res.wall_seconds / rounds * 1e6,
            rounds=rounds,
            final_acc=f"{res.final_accuracy(5):.4f}",
            virtual_time=f"{res.virtual_time():.1f}",
            n_alive=int(eng.alive.sum()),
        )


def bench_cohort_scale(args):
    """The fast path: diurnal-churn at increasing population sizes."""
    budget_exceeded = False
    for n in args.scales:
        k = max(32, min(128, n // 16))
        hp = FedQSHyperParams(buffer_k=k)
        t0 = time.perf_counter()
        eng = CohortEngine(get_scenario("diurnal-churn"), n, hp=hp,
                           cohort_k=k, seed=0, eval_every=5)
        res = eng.run(args.cohort_rounds)
        dt = time.perf_counter() - t0
        served = eng.service.stats.accepted
        under = dt < 60.0
        emit(
            f"cohort_diurnal_churn_{n}",
            dt / max(eng.round, 1) * 1e6,
            clients=n,
            rounds=eng.round,
            updates=served,
            updates_per_sec=f"{served / dt:.0f}",
            wall_s=f"{dt:.1f}",
            final_acc=f"{res.final_accuracy(3):.4f}",
            under_60s=under,
        )
        if n >= 10_000 and not under:
            budget_exceeded = True
    if budget_exceeded:
        raise SystemExit("cohort fast path regressed: 10k clients took >= 60s")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", type=int, default=20)
    ap.add_argument("--rounds", type=int, default=16)
    ap.add_argument("--cohort-rounds", type=int, default=30)
    ap.add_argument("--scales", type=int, nargs="+", default=[1_000, 10_000])
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args(argv)
    if args.quick:
        args.rounds, args.cohort_rounds, args.scales = 6, 8, [500]

    bench_engine_scenarios(args)
    bench_cohort_scale(args)


run = make_suite_run(main)  # harness entry: python -m benchmarks.run


if __name__ == "__main__":
    main()
