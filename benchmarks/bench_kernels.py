"""Kernel micro-benchmarks: Pallas (interpret=True on CPU — correctness
path) vs the pure-jnp oracle, per DESIGN §7 shape grid.

On this CPU container the interpret numbers measure the emulation, not
TPU performance; the derived column carries bytes-touched so the §Roofline
report can place each kernel on the memory roof analytically.
"""
import time

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.similarity import fused_similarity_stats
from repro.kernels.weighted_agg import weighted_agg
from repro.kernels.window_attention import window_decode_attention

from .common import emit


def _time(fn, *args, iters=3):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def run():
    key = jax.random.PRNGKey(0)
    # weighted_agg: K=10 buffer over a ~1M-param model vector
    for K, D in ((10, 1 << 20), (16, 1 << 18)):
        x = jax.random.normal(key, (K, D))
        w = jnp.full((K,), 1.0 / K)
        t_k = _time(lambda a, b: weighted_agg(a, b, interpret=True), x, w)
        t_r = _time(jax.jit(ref.weighted_agg_ref), x, w)
        emit(f"kernel.weighted_agg.K{K}_D{D}", t_k,
             ref_us=round(t_r, 1), hbm_bytes=K * D * 4,
             roofline_us_tpu=round(K * D * 4 / 819e9 * 1e6, 2))

    # fused similarity on a 4M-element parameter vector
    for D in (1 << 22,):
        a = jax.random.normal(key, (D,))
        b = jax.random.normal(jax.random.PRNGKey(1), (D,))
        t_k = _time(lambda x, y: fused_similarity_stats(x, y, interpret=True), a, b)
        t_r = _time(jax.jit(ref.fused_similarity_stats_ref), a, b)
        emit(f"kernel.similarity.D{D}", t_k, ref_us=round(t_r, 1),
             hbm_bytes=2 * D * 4,
             roofline_us_tpu=round(2 * D * 4 / 819e9 * 1e6, 2))

    # window decode attention at gemma3-like dims
    B, H, KV, W, dh = 4, 4, 1, 512, 256
    q = jax.random.normal(key, (B, H, dh))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, W, KV, dh))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, W, KV, dh))
    vl = jnp.asarray(W)
    t_k = _time(lambda *xs: window_decode_attention(*xs, interpret=True), q, k, v, vl)
    t_r = _time(jax.jit(ref.window_decode_attention_ref), q, k, v, vl)
    bytes_ = 2 * B * W * KV * dh * 4
    emit(f"kernel.window_attn.B{B}_W{W}", t_k, ref_us=round(t_r, 1),
         hbm_bytes=bytes_, roofline_us_tpu=round(bytes_ / 819e9 * 1e6, 2))


if __name__ == "__main__":
    run()
