"""Paper Figure 5 / Tables 11–14: hyper-parameter sensitivity of FedQS
(η0, a, m0, k)."""
from repro.core import FedQSHyperParams

from .common import emit, run_safl, us_per_round

ROUNDS = 40


def run():
    grids = (
        ("eta0", [0.01, 0.1, 0.2], lambda v: FedQSHyperParams(buffer_k=4, eta0=v)),
        ("a", [0.002, 0.01], lambda v: FedQSHyperParams(buffer_k=4, a=v)),
        ("m0", [0.1, 0.4], lambda v: FedQSHyperParams(buffer_k=4, m0=v)),
        ("k", [0.2, 0.4], lambda v: FedQSHyperParams(buffer_k=4, k=v)),
    )
    for pname, values, mk in grids:
        for v in values:
            for algo in ("fedqs-sgd", "fedqs-avg"):
                _, res = run_safl("rwd", algo, rounds=ROUNDS, hp=mk(v), seed=6)
                emit(f"tables11_14.{pname}_{v}.{algo}", us_per_round(res, ROUNDS),
                     best_acc=round(res.best_accuracy(), 4),
                     oscillations=res.oscillations(0.05))


if __name__ == "__main__":
    run()
