"""Paper Table 1: the 2×2 factor study — (staleness × data heterogeneity)
→ accuracy gap between gradient and model aggregation.

Factor 1 (stale updates) toggles sync vs semi-async; Factor 2 (data
heterogeneity) toggles IID-ish vs strongly non-IID partitions.  The paper
finds the gap explodes (11.52%) only when BOTH factors are active.
"""
from .common import emit, run_safl, us_per_round

ROUNDS = 60


def run():
    for f1, sync in ((0, True), (1, False)):
        for f2, sigma in ((0, 0.1), (1, 1.6)):
            accs = {}
            wall = 0.0
            for algo in ("fedsgd", "fedavg"):
                _, res = run_safl("rwd", algo, rounds=ROUNDS, sync_mode=sync,
                                  sigma=sigma, seed=1)
                accs[algo] = res.best_accuracy()
                wall += res.wall_seconds
            gap = accs["fedsgd"] - accs["fedavg"]
            emit(f"table1.factors_s{f1}_h{f2}",
                 wall / (2 * ROUNDS) * 1e6,
                 grad_acc=round(accs["fedsgd"], 4),
                 model_acc=round(accs["fedavg"], 4),
                 gap=round(gap, 4), stale=f1, noniid=f2)


if __name__ == "__main__":
    run()
