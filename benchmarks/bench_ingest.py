"""Fused-ingestion suite: the ``ingest_agg`` kernel and the fused serve
round it powers, with three CI gates (docs/KERNELS.md):

1. **oracle parity** — the interpret-mode kernel must be bit-exact
   against its jitted ``ingest_agg_ref`` oracle, and agree to ≤1e-5
   (relative) with the unfused composition it replaces: dequantize →
   host-side §3.4 weight fold → ``weighted_agg``;
2. **serve speedup** — the fused batched FedQS round must beat the
   unfused batched path by ≥1.5× on mean aggregation latency while
   landing ≤1e-5 (relative) from its global params;
3. **autotune sweep** — the block-size sweep runs end to end, persists
   the winner in the on-disk config cache, and reports achieved GB/s
   against the HBM roofline.

    PYTHONPATH=src python benchmarks/bench_ingest.py [--quick]
"""
from __future__ import annotations

import argparse
import time

try:
    from .common import emit, make_suite_run
except ImportError:  # run as a script: python benchmarks/bench_ingest.py
    from common import emit, make_suite_run

import jax
import jax.numpy as jnp
import numpy as np

from repro.compress import ClientCompressor, compress_stream
from repro.core import FedQSHyperParams, make_algorithm
from repro.core.types import AggregationStrategy
from repro.kernels import autotune
from repro.kernels.ingest_agg import ingest_agg
from repro.kernels.ref import ingest_agg_ref, ingest_weights, weighted_agg_ref
from repro.models import make_mlp_spec
from repro.serve import KBuffer, StreamingAggregator, replay, synthetic_stream

SPEEDUP_GATE = 1.5   # fused vs unfused batched mean_agg_ms, dense stream
PARITY_GATE = 1e-5   # relative gap, kernel-vs-composition and serve params


def _meta(rng, K, n_clients, ratio_clip=3.0):
    """Random §3.4 metadata in the ranges the serve plane produces."""
    n = rng.integers(1, 200, K).astype(np.float32)
    F = rng.uniform(1.0 / ratio_clip, ratio_clip, K).astype(np.float32)
    G = rng.uniform(1.0 / ratio_clip, ratio_clip, K).astype(np.float32)
    fb = (rng.random(K) < 0.5).astype(np.float32)
    return n, F, G, fb


def _composition(rows, n, F, G, fb, k, n_clients):
    """The unfused reference: host-side weight fold, dense reduction."""
    col = lambda v: np.asarray(v, np.float32).reshape(-1, 1)
    p = ingest_weights(col(n), col(F), col(G), col(fb), np.float32(k),
                       n_clients=n_clients, normalize=True, xp=np)
    return weighted_agg_ref(jnp.asarray(rows), jnp.asarray(p[:, 0]))


def bench_parity(args):
    """Gate 1: interpret kernel ≡ jitted oracle (bitwise) and ≤1e-5 vs
    the dequant → host-decay → weighted_agg composition."""
    rng = np.random.default_rng(args.seed)
    n_clients = 64
    shapes = [(8, 1 << 14)] if args.quick else [(10, 1 << 16), (7, 1000)]
    for K, D in shapes:
        x = rng.standard_normal((K, D)).astype(np.float32)
        n, F, G, fb = _meta(rng, K, n_clients)
        k = float(K)
        t0 = time.perf_counter()
        got = jax.block_until_ready(ingest_agg(
            jnp.asarray(x), None, jnp.asarray(n), jnp.asarray(F),
            jnp.asarray(G), jnp.asarray(fb), jnp.float32(k),
            n_clients=n_clients, interpret=True))
        dt = time.perf_counter() - t0
        ref = ingest_agg_ref(jnp.asarray(x), None, jnp.asarray(n),
                             jnp.asarray(F), jnp.asarray(G), jnp.asarray(fb),
                             jnp.float32(k), n_clients=n_clients)
        bitexact = bool(jnp.array_equal(got, ref))
        want = _composition(x, n, F, G, fb, k, n_clients)
        rel = float(jnp.abs(got - want).max()) / max(
            float(jnp.abs(want).max()), 1e-12)
        emit(f"ingest_parity_dense_K{K}_D{D}", dt * 1e6,
             bitexact_vs_oracle=bitexact, rel_gap_vs_composition=f"{rel:.2e}")
        if not bitexact:
            raise SystemExit(
                f"ingest_agg K{K}_D{D}: interpret kernel != jitted oracle")
        if rel > PARITY_GATE:
            raise SystemExit(
                f"ingest_agg K{K}_D{D}: {rel:.3e} from composition "
                f"(> {PARITY_GATE:.0e})")

    # int8 path: saturated codes included, chunked scales
    K, chunk, nc = 8, 256, 8 if args.quick else 32
    D = chunk * nc
    q = rng.integers(-127, 128, (K, D)).astype(np.int8)
    q[0, :chunk] = 127  # saturation edge
    scales = (rng.random((K, nc)).astype(np.float32) + 0.1) * 1e-2
    n, F, G, fb = _meta(rng, K, n_clients)
    k = float(K)
    t0 = time.perf_counter()
    got = jax.block_until_ready(ingest_agg(
        jnp.asarray(q), jnp.asarray(scales), jnp.asarray(n), jnp.asarray(F),
        jnp.asarray(G), jnp.asarray(fb), jnp.float32(k), chunk=chunk,
        n_clients=n_clients, interpret=True))
    dt = time.perf_counter() - t0
    ref = ingest_agg_ref(jnp.asarray(q), jnp.asarray(scales), jnp.asarray(n),
                         jnp.asarray(F), jnp.asarray(G), jnp.asarray(fb),
                         jnp.float32(k), n_clients=n_clients)
    bitexact = bool(jnp.array_equal(got, ref))
    dense = (q.astype(np.float32).reshape(K, nc, chunk)
             * scales[:, :, None]).reshape(K, D)
    want = _composition(dense, n, F, G, fb, k, n_clients)
    rel = float(jnp.abs(got - want).max()) / max(
        float(jnp.abs(want).max()), 1e-12)
    emit(f"ingest_parity_int8_K{K}_D{D}_c{chunk}", dt * 1e6,
         bitexact_vs_oracle=bitexact, rel_gap_vs_composition=f"{rel:.2e}",
         int8_hbm_bytes=K * D + 4 * K * nc, dense_hbm_bytes=4 * K * D)
    if not bitexact:
        raise SystemExit("ingest_agg int8: interpret kernel != jitted oracle")
    if rel > PARITY_GATE:
        raise SystemExit(
            f"ingest_agg int8: {rel:.3e} from composition (> {PARITY_GATE:.0e})")


def _replay_batched(params, stream, args, *, fused):
    hp = FedQSHyperParams(buffer_k=args.buffer_k)
    # warm-up service compiles the round for this (shape, K-bucket) so the
    # measured service reports steady-state latency, not jit tracing
    for svc in (
        StreamingAggregator(make_algorithm("fedqs-sgd", hp), hp, params,
                            args.clients, batched=True, fused=fused),
        StreamingAggregator(make_algorithm("fedqs-sgd", hp), hp, params,
                            args.clients, batched=True, fused=fused),
    ):
        replay(svc, stream, flush=False)
    return svc


def bench_serve(args):
    """Gate 2: fused vs unfused batched FedQS rounds on the same stream."""
    spec = make_mlp_spec()
    params = spec.init(jax.random.PRNGKey(args.seed))
    n_up = 150 if args.quick else 400
    base = list(synthetic_stream(params, args.clients, n_up, seed=args.seed))

    for label, cspec in (("dense", None), ("int8", "int8")):
        if cspec is None:
            stream = base
        else:
            comp = ClientCompressor(cspec, args.clients, seed=args.seed)
            stream = list(compress_stream(
                iter(base), comp, strategy=AggregationStrategy.GRADIENT))
        fused = _replay_batched(params, stream, args, fused=True)
        unfused = _replay_batched(params, stream, args, fused=False)

        gap = max(
            float(np.abs(np.asarray(a) - np.asarray(b)).max())
            for a, b in zip(jax.tree_util.tree_leaves(fused.global_params),
                            jax.tree_util.tree_leaves(unfused.global_params)))
        scale = max(
            float(np.abs(np.asarray(l)).max())
            for l in jax.tree_util.tree_leaves(unfused.global_params))
        rel = gap / max(scale, 1e-12)
        f_ms = fused.stats.agg_seconds / max(fused.stats.rounds, 1) * 1e3
        u_ms = unfused.stats.agg_seconds / max(unfused.stats.rounds, 1) * 1e3
        ratio = u_ms / max(f_ms, 1e-12)
        emit(f"ingest_serve_{label}", f_ms * 1e3,
             fused_mean_agg_ms=f"{f_ms:.2f}",
             unfused_mean_agg_ms=f"{u_ms:.2f}",
             speedup=f"{ratio:.2f}", rounds=fused.stats.rounds,
             rel_param_gap=f"{rel:.2e}")
        if rel > PARITY_GATE:
            raise SystemExit(
                f"fused {label} serve diverged from unfused: rel gap "
                f"{rel:.3e} (> {PARITY_GATE:.0e})")
        if label == "dense" and ratio < SPEEDUP_GATE:
            raise SystemExit(
                f"fused serve speedup gate: {ratio:.2f}x vs unfused "
                f"(< {SPEEDUP_GATE}x): fused={f_ms:.2f}ms unfused={u_ms:.2f}ms")


def bench_autotune(args):
    """Gate 3: the sweep itself — measure candidates on the interpret
    kernel, persist the winner, and report it against the HBM roofline.
    On this CPU container the µs measure Pallas emulation, so the chosen
    block is only meaningful as proof the sweep/cache machinery works."""
    rng = np.random.default_rng(args.seed)
    n_clients = 64
    K, D = (8, 1 << 13) if args.quick else (8, 1 << 15)
    x = jnp.asarray(rng.standard_normal((K, D)).astype(np.float32))
    n, F, G, fb = map(jnp.asarray, _meta(rng, K, n_clients))
    k = jnp.float32(K)

    def make_call(block_d):
        return lambda: ingest_agg(x, None, n, F, G, fb, k,
                                  n_clients=n_clients, block_d=block_d,
                                  interpret=True)

    path = autotune.default_cache_path()
    autotune.reload_cache(path)
    cfg = autotune.autotune(
        "ingest_agg", make_call, x.shape, x.dtype,
        candidates=(2048, 4096) if args.quick else (1024, 2048, 4096),
        bytes_moved=(K * D + 1) * 4, path=path)
    emit("ingest_autotune_sweep", cfg.us or 0.0,
         block_d=cfg.block_d, source=cfg.source,
         gbps=f"{cfg.gbps:.3f}" if cfg.gbps else "n/a", cache=path)
    for row in autotune.roofline_rows(path):
        emit(f"ingest_roofline.{row['kernel']}", row["us"] or 0.0,
             key=row["key"], block_d=row["block_d"],
             gbps=row["gbps"], pct_roofline=row["pct_roofline"])


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", type=int, default=64)
    ap.add_argument("--buffer-k", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args(argv)

    bench_parity(args)
    bench_serve(args)
    bench_autotune(args)


run = make_suite_run(main)  # harness entry: python -m benchmarks.run


if __name__ == "__main__":
    main()
