"""Compressed-transport benchmark: wire bytes, aggregation throughput,
kernel parity, and accuracy-vs-ratio (docs/COMPRESSION.md).

CSV rows follow benchmarks/common.py: ``name,us_per_call,derived``.
Four sections, each with a hard gate (the script exits non-zero on
regression):

* **bytes**       — bytes/update per codec spec vs dense fp32; gate:
  ``topk|int8`` achieves >= 3x reduction;
* **kernel**      — fused ``dequant_agg`` (interpret mode) vs the
  decode-then-``weighted_agg`` oracle; gate: fp32 allclose;
* **throughput**  — synthetic stream through the StreamingAggregator,
  dense vs compressed ingestion (updates/sec);
* **accuracy**    — the CohortEngine smoke config (500 clients, K=32,
  60 rounds) dense vs ``int8`` vs ``topk:0.25|int8``; gate: int8+top-k
  with error feedback loses < 1% final accuracy vs dense.

    PYTHONPATH=src python benchmarks/bench_compress.py [--fast]
"""
from __future__ import annotations

import argparse
import time

try:
    from .common import emit, make_suite_run
except ImportError:  # run as a script: python benchmarks/bench_compress.py
    from common import emit, make_suite_run

import jax
import jax.numpy as jnp
import numpy as np

from repro.compress import ClientCompressor, compress_stream, parse_codec
from repro.core import FedQSHyperParams, make_algorithm
from repro.core.types import AggregationStrategy
from repro.kernels.dequant_agg import dequant_agg
from repro.kernels.ref import dequant_agg_ref, weighted_agg_ref
from repro.models import make_mlp_spec
from repro.serve import StreamingAggregator, replay, synthetic_stream

GATE_SPEC = "topk:0.25|int8"  # the int8+top-k CI-gate codec
ACC_TOLERANCE = 0.01          # < 1% final-accuracy loss vs dense
BYTES_FACTOR = 3.0            # >= 3x bytes/update reduction vs dense


def bench_bytes(args) -> float:
    """bytes/update per codec on a real model-shaped delta stream."""
    spec = make_mlp_spec()
    params = spec.init(jax.random.PRNGKey(args.seed))
    n_up = 40 if args.fast else 120
    ratios = {}
    for cspec in ("none", "int8", "topk:0.05", "topk:0.05|int8", GATE_SPEC):
        comp = ClientCompressor(cspec, args.clients, seed=args.seed)
        for u, _ in synthetic_stream(params, args.clients, n_up, seed=args.seed):
            comp.encode_update(u, strategy=AggregationStrategy.GRADIENT)
        s = comp.stats
        ratios[cspec] = s.ratio
        emit(
            f"compress_bytes_{cspec.replace('|', '_').replace(':', '')}",
            0.0,
            bytes_per_update=f"{s.bytes_per_update:.0f}",
            dense_bytes=s.dense_bytes // max(s.updates, 1),
            ratio=f"{s.ratio:.1f}",
        )
    return ratios[GATE_SPEC]


def bench_kernel(args) -> float:
    """Fused dequant_agg vs decode-then-weighted_agg, interpret mode."""
    key = jax.random.PRNGKey(args.seed)
    worst = 0.0
    shapes = [(8, 4096, 256)] if args.fast else [(8, 4096, 256), (16, 65536, 512)]
    for K, D, chunk in shapes:
        q = jax.random.randint(key, (K, D), -127, 128, jnp.int8)
        s = jax.random.uniform(jax.random.PRNGKey(1), (K, D // chunk)) * 1e-2
        w = jax.random.uniform(jax.random.PRNGKey(2), (K,))
        t0 = time.perf_counter()
        got = jax.block_until_ready(dequant_agg(q, s, w, chunk=chunk, interpret=True))
        dt = time.perf_counter() - t0
        # oracle: decode to dense f32 rows, then the dense reduction
        dense = (q.astype(jnp.float32).reshape(K, D // chunk, chunk)
                 * s[..., None]).reshape(K, D)
        want = weighted_agg_ref(dense, w)
        gap = float(jnp.abs(got - want).max())
        rel = gap / max(float(jnp.abs(want).max()), 1e-12)
        worst = max(worst, rel)
        np.testing.assert_allclose(got, dequant_agg_ref(q, s, w), rtol=1e-5, atol=1e-5)
        emit(
            f"compress_kernel_K{K}_D{D}_c{chunk}",
            dt * 1e6,
            max_abs_gap=f"{gap:.2e}",
            rel_gap=f"{rel:.2e}",
            int8_hbm_bytes=K * D + 4 * K * (D // chunk),
            dense_hbm_bytes=4 * K * D,
        )
    return worst


def bench_throughput(args):
    """Dense vs compressed ingestion through the streaming service."""
    spec = make_mlp_spec()
    params = spec.init(jax.random.PRNGKey(args.seed))
    hp = FedQSHyperParams(buffer_k=args.buffer_k)
    n_up = 120 if args.fast else 400
    base = list(synthetic_stream(params, args.clients, n_up, seed=args.seed))
    for cspec in (None, "int8", GATE_SPEC):
        algo = make_algorithm("fedqs-sgd", hp)
        svc = StreamingAggregator(algo, hp, params, args.clients, batched=True)
        if cspec is None:
            stream = base
        else:
            comp = ClientCompressor(cspec, args.clients, seed=args.seed)
            svc.compressor = comp
            stream = list(compress_stream(iter(base), comp,
                                          strategy=AggregationStrategy.GRADIENT))
        # warm-up: compile the fixed-shape aggregation once
        warm = StreamingAggregator(make_algorithm("fedqs-sgd", hp), hp, params,
                                   args.clients, batched=True)
        replay(warm, stream[: args.buffer_k])
        t0 = time.perf_counter()
        replay(svc, stream)
        dt = time.perf_counter() - t0
        s = svc.stats
        emit(
            f"compress_serve_{(cspec or 'dense').replace('|', '_').replace(':', '')}",
            dt / max(s.submitted, 1) * 1e6,
            updates_per_sec=f"{s.submitted / dt:.1f}",
            rounds=s.rounds,
            mean_agg_ms=f"{s.agg_seconds / max(s.rounds, 1) * 1e3:.2f}",
        )


def bench_accuracy(args) -> dict:
    """Accuracy-vs-ratio on the cohort smoke config; this is the CI gate.

    The smoke config (500 virtual clients, K=32, 60 rounds, seed 0) is
    identical across codecs, so the comparison isolates transport loss;
    error feedback is what keeps the sparsified runs on the dense curve.
    """
    from repro.scenarios import CohortEngine, Scenario

    accs = {}
    for cspec in (None, "int8", GATE_SPEC):
        hp = FedQSHyperParams(buffer_k=32)
        t0 = time.perf_counter()
        eng = CohortEngine(Scenario(), 500, hp=hp, cohort_k=32, seed=args.seed,
                           compress=cspec)
        res = eng.run(60)
        dt = time.perf_counter() - t0
        acc = res.final_accuracy(5)
        accs[cspec or "dense"] = acc
        cs = eng.compressor.stats if eng.compressor else None
        emit(
            f"compress_accuracy_{(cspec or 'dense').replace('|', '_').replace(':', '')}",
            dt / max(eng.round, 1) * 1e6,
            final_acc=f"{acc:.4f}",
            rounds=eng.round,
            ratio=f"{cs.ratio:.1f}" if cs else "1.0",
            wall_s=f"{dt:.1f}",
        )
    return accs


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", type=int, default=64)
    ap.add_argument("--buffer-k", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--fast", action="store_true",
                    help="smaller bytes/kernel/throughput sections (the "
                         "accuracy gate always runs its fixed smoke config)")
    ap.add_argument("--skip-accuracy", action="store_true",
                    help="skip the cohort accuracy section (quick local runs)")
    args = ap.parse_args(argv)

    gate_ratio = bench_bytes(args)
    worst_rel = bench_kernel(args)
    bench_throughput(args)

    failures = []
    if gate_ratio < BYTES_FACTOR:
        failures.append(
            f"bytes gate: {GATE_SPEC} reduction {gate_ratio:.1f}x < {BYTES_FACTOR}x")
    if worst_rel > 1e-5:
        failures.append(f"kernel gate: rel gap {worst_rel:.2e} > 1e-5")
    if not args.skip_accuracy:
        accs = bench_accuracy(args)
        loss = accs["dense"] - accs[GATE_SPEC]
        if loss >= ACC_TOLERANCE:
            failures.append(
                f"accuracy gate: {GATE_SPEC} lost {loss * 100:.2f}% >= "
                f"{ACC_TOLERANCE * 100:.0f}% vs dense "
                f"({accs[GATE_SPEC]:.4f} vs {accs['dense']:.4f})")
    if failures:
        raise SystemExit("compression regression: " + "; ".join(failures))


run = make_suite_run(main, "--fast")  # harness entry: python -m benchmarks.run


if __name__ == "__main__":
    main()
