"""Paper Table 5: ablations — Mod-1 similarity function, Mod-2 momentum
on/off, Mod-3 feedback on/off, for both FedQS modes."""
from repro.core import FedQSHyperParams

from .common import emit, run_safl, us_per_round

ROUNDS = 60


def _case(tag, hp, algo):
    _, res = run_safl("rwd", algo, rounds=ROUNDS, hp=hp, seed=4, sigma=1.3)
    target = 0.95 * res.final_accuracy()
    conv = res.rounds_to_accuracy(target)
    emit(f"table5.{tag}.{algo}", us_per_round(res, ROUNDS),
         best_acc=round(res.best_accuracy(), 4),
         conv_rounds=conv if conv is not None else -1,
         oscillations=res.oscillations(0.05))


def run():
    K = 4
    for algo in ("fedqs-avg", "fedqs-sgd"):
        # Mod-1: similarity function
        for sim in ("cosine", "euclidean", "manhattan"):
            _case(f"mod1_{sim}", FedQSHyperParams(buffer_k=K, similarity=sim), algo)
        # Mod-2: momentum
        _case("mod2_no_momentum", FedQSHyperParams(buffer_k=K, use_momentum=False), algo)
        _case("mod2_with_momentum", FedQSHyperParams(buffer_k=K), algo)
        # Mod-3: feedback
        _case("mod3_no_feedback", FedQSHyperParams(buffer_k=K, use_feedback=False), algo)
        _case("mod3_with_feedback", FedQSHyperParams(buffer_k=K), algo)


if __name__ == "__main__":
    run()
