"""Paper Table 2: accuracy + convergence speed of FedQS vs all baselines
across the three task families (synthetic stand-ins, DESIGN §4)."""
from .common import emit, run_safl, us_per_round

ALGOS = ("fedavg", "safa", "fedat", "m-step", "fedqs-avg",
         "fedsgd", "fedbuff", "wkafl", "fedac", "defedavg", "fadas",
         "ca2fl", "fedqs-sgd")

TASKS = (
    ("cv_x0.5", "cv", dict(alpha=0.5), 60),
    ("nlp_r2", "nlp", dict(roles_per_client=2), 30),
    ("rwd_gender", "rwd", dict(sigma=1.0), 120),
)


def run():
    for tname, task, kw, rounds in TASKS:
        for algo in ALGOS:
            _, res = run_safl(task, algo, rounds=rounds, seed=2, **kw)
            target = 0.95 * res.final_accuracy()
            conv = res.rounds_to_accuracy(target)
            emit(f"table2.{tname}.{algo}", us_per_round(res, rounds),
                 best_acc=round(res.best_accuracy(), 4),
                 final_acc=round(res.final_accuracy(), 4),
                 conv_rounds=conv if conv is not None else -1,
                 oscillations=res.oscillations(0.05))


if __name__ == "__main__":
    run()
