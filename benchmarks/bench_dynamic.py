"""Paper Tables 4 & 6: robustness — system-setting variations (N clients,
resource ratio) and the three dynamic scenarios."""
from repro.core.safl import (
    scenario_dropout,
    scenario_resource_scale,
    scenario_unstable_resources,
)

from .common import emit, run_safl, us_per_round

ROUNDS = 50


def run():
    # Table 4: N × resource-ratio grid (reduced)
    for N, ratio in ((10, 20.0), (30, 100.0)):
        for algo in ("fedavg", "fedqs-avg", "fedsgd", "fedqs-sgd"):
            _, res = run_safl("rwd", algo, rounds=ROUNDS, n_clients=N,
                              resource_ratio=ratio, seed=5)
            emit(f"table4.N{N}_r{int(ratio)}.{algo}", us_per_round(res, ROUNDS),
                 best_acc=round(res.best_accuracy(), 4),
                 oscillations=res.oscillations(0.05))

    # Table 6: dynamic scenarios
    scenarios = (
        ("scen1_scale", scenario_resource_scale(ROUNDS // 3, 100.0)),
        ("scen2_jitter", scenario_unstable_resources()),
        ("scen3_dropout", scenario_dropout(ROUNDS // 3, 0.5)),
    )
    for sname, dyn in scenarios:
        for algo in ("fedsgd", "fedqs-sgd", "fedavg", "fedqs-avg"):
            _, res = run_safl("rwd", algo, rounds=ROUNDS, seed=5, dynamics=dyn)
            target = 0.95 * res.final_accuracy()
            conv = res.rounds_to_accuracy(target)
            emit(f"table6.{sname}.{algo}", us_per_round(res, ROUNDS),
                 best_acc=round(res.best_accuracy(), 4),
                 conv_rounds=conv if conv is not None else -1)


if __name__ == "__main__":
    run()
