"""Generate the §Dry-run / §Roofline tables of EXPERIMENTS.md from the
recorded dry-run JSONs.

    PYTHONPATH=src python experiments/make_report.py > experiments/report.md
"""
import glob
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
from repro.launch.analysis import HBM_BW, ICI_BW, PEAK_FLOPS  # noqa: E402

DIR = os.path.join(os.path.dirname(__file__), "dryrun")


def load(mesh, tagged=False):
    recs = {}
    for f in sorted(glob.glob(os.path.join(DIR, f"*__{mesh}*.json"))):
        base = os.path.basename(f)[:-5]
        is_tagged = not base.endswith(mesh)
        if is_tagged != tagged:
            continue
        recs[base] = json.load(open(f))
    return recs


def fmt_bytes(b):
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(b) < 1024 or unit == "TB":
            return f"{b:.1f}{unit}" if unit != "B" else f"{int(b)}B"
        b /= 1024


def dryrun_table(mesh):
    print(f"\n### Mesh {mesh}\n")
    print("| arch | shape | status | compile (s) | per-chip HLO FLOPs | "
          "per-chip HBM est | collective bytes/chip | args (GB) | temps (GB) |")
    print("|---|---|---|---|---|---|---|---|---|")
    for name, r in load(mesh).items():
        if r.get("status") == "skipped":
            print(f"| {r['arch']} | {r['shape']} | skip ({r['reason'][:48]}…) "
                  f"| — | — | — | — | — | — |")
            continue
        m = r["memory"]
        print(f"| {r['arch']} | {r['shape']} | ok | {r['compile_s']} | "
              f"{r['hlo_struct']['flops']:.2e} | "
              f"{r['hlo_struct']['hbm_bytes_est']:.2e} | "
              f"{r['collectives']['total']:.2e} | "
              f"{m.get('argument_size_in_bytes', 0)/1e9:.1f} | "
              f"{m.get('temp_size_in_bytes', 0)/1e9:.1f} |")


def roofline_table(mesh="16x16"):
    print("\n| arch | shape | compute (s) | memory (s) | collective (s) | "
          "dominant | MODEL_FLOPS | useful ratio† | next lever |")
    print("|---|---|---|---|---|---|---|---|---|")
    from benchmarks.roofline import advice
    for name, r in load(mesh).items():
        if r.get("status") != "ok":
            continue
        rf = r["roofline"]
        ur = r["useful_flops_ratio"]
        print(f"| {r['arch']} | {r['shape']} | {rf['compute_s']:.2e} | "
              f"{rf['memory_s']:.2e} | {rf['collective_s']:.2e} | "
              f"**{rf['dominant']}** | {r['model_flops']:.2e} | "
              f"{ur:.3f} | {advice(r)[:70]}… |")


def perf_table():
    print("\n| run | variant | compute (s) | memory (s) | collective (s) | "
          "dominant | temps (GB) |")
    print("|---|---|---|---|---|---|---|")
    rows = {}
    rows.update(load("16x16"))
    rows.update(load("16x16", tagged=True))
    interesting = ("kimi-k2-1t-a32b__train_4k", "deepseek-v3-671b__decode_32k",
                   "gemma3-1b__train_4k", "seamless-m4t-medium__decode_32k")
    for name, r in rows.items():
        if r.get("status") != "ok":
            continue
        if not any(name.startswith(i) for i in interesting):
            continue
        rf = r["roofline"]
        var = r.get("variant", "") or (f"g={r.get('client_group_size')}"
                                       if r.get("client_group_size", 1) > 1 else "baseline")
        if r.get("client_group_size", 1) > 1 and r.get("variant"):
            var = f"g={r['client_group_size']},{r['variant']}"
        print(f"| {name.split('__16x16')[0]} | {var} | {rf['compute_s']:.2e} | "
              f"{rf['memory_s']:.2e} | {rf['collective_s']:.2e} | "
              f"{rf['dominant']} | "
              f"{r['memory'].get('temp_size_in_bytes', 0)/1e9:.1f} |")


if __name__ == "__main__":
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    if which in ("all", "dryrun"):
        print("## §Dry-run")
        dryrun_table("16x16")
        dryrun_table("2x16x16")
    if which in ("all", "roofline"):
        print("\n## §Roofline (single-pod 16×16)")
        roofline_table()
    if which in ("all", "perf"):
        print("\n## §Perf variants")
        perf_table()
