"""Gemma-3 1B [hf:google/gemma-3-1b-pt] — 5:1 local:global interleave.

26L d_model=1152 4H (GQA kv=1) d_ff=6912 vocab=262144, head_dim 256,
sliding window 512.  Layout: 4 super-blocks of (5 local + 1 global) + 2
trailing locals = 22 local / 4 global ≈ 5.5:1 (noted in DESIGN §5 — an
exact 5:1 does not divide 26 layers).  ``global_cache_cap``
bounds the global layers' decode cache at the 32k trained context, which
is what makes long_500k a bounded-memory decode."""
from repro.models.transformer import ArchConfig

_PATTERN = (("local", "dense"),) * 5 + (("attn", "dense"),)

CONFIG = ArchConfig(
    arch_id="gemma3-1b",
    family="dense",
    d_model=1152,
    n_heads=4,
    n_kv_heads=1,
    d_head=256,
    d_ff=6912,
    vocab=262144,
    pattern=_PATTERN,
    n_repeats=4,
    suffix=(("local", "dense"),) * 2,
    window=512,
    global_cache_cap=32768,
    rope_theta=1e6,
    fl_mode="stacked",
    source="[hf:google/gemma-3-1b-pt]",
)

REDUCED = ArchConfig(
    arch_id="gemma3-1b/reduced",
    family="dense",
    d_model=128,
    n_heads=4,
    n_kv_heads=1,
    d_head=32,
    d_ff=256,
    vocab=512,
    pattern=(("local", "dense"), ("attn", "dense")),
    n_repeats=1,
    window=16,
    global_cache_cap=64,
    fl_mode="stacked",
    source="reduced smoke variant",
)
