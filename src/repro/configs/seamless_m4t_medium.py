"""SeamlessM4T-medium backbone [arXiv:2308.11596].

Enc-dec: 12 encoder + 12 decoder layers, d_model=1024 16H (kv=16)
d_ff=4096 vocab=256206.  The speech frontend (mel-spectrogram + conv
feature extractor) is the allowed stub: ``input_specs`` supplies
precomputed frame embeddings [B, n_frames, d_model]."""
from repro.models.transformer import ArchConfig

CONFIG = ArchConfig(
    arch_id="seamless-m4t-medium",
    family="audio",
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_head=64,
    d_ff=4096,
    vocab=256206,
    pattern=(("attn_cross", "dense"),),
    n_repeats=12,
    n_encoder_layers=12,
    frontend="audio",
    n_frontend_tokens=512,    # precomputed speech-frame embeddings (stub)
    fl_mode="stacked",
    source="[arXiv:2308.11596] SeamlessM4T medium",
)

REDUCED = ArchConfig(
    arch_id="seamless-m4t-medium/reduced",
    family="audio",
    d_model=128,
    n_heads=4,
    n_kv_heads=4,
    d_head=32,
    d_ff=256,
    vocab=512,
    pattern=(("attn_cross", "dense"),),
    n_repeats=2,
    n_encoder_layers=2,
    frontend="audio",
    n_frontend_tokens=16,
    fl_mode="stacked",
    source="reduced smoke variant",
)
