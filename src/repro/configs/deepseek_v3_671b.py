"""DeepSeek-V3 671B [arXiv:2412.19437].

61L d_model=7168, MLA (q_lora 1536, kv_lora 512, rope 64, nope 128, v 128),
first 3 layers dense (d_ff 18432), 58 MoE layers: 1 shared + 256 routed
top-8 experts (expert_d_ff=2048), vocab=129280, MTP head."""
from repro.models.transformer import ArchConfig

CONFIG = ArchConfig(
    arch_id="deepseek-v3-671b",
    family="moe",
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    d_head=128,               # MLA supersedes GQA dims; kept for layout
    d_ff=18432,               # dense FFN width of the first 3 layers
    vocab=129280,
    prefix=(("mla", "dense"),) * 3,
    pattern=(("mla", "moe"),),
    n_repeats=58,
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_rope_dim=64,
    qk_nope_dim=128,
    v_head_dim=128,
    n_experts=256,
    top_k=8,
    n_shared_experts=1,
    expert_d_ff=2048,
    mtp=True,
    rope_theta=1e4,
    fl_mode="fsdp",
    source="[arXiv:2412.19437] DeepSeek-V3 technical report",
)

REDUCED = ArchConfig(
    arch_id="deepseek-v3-671b/reduced",
    family="moe",
    d_model=128,
    n_heads=4,
    n_kv_heads=4,
    d_head=32,
    d_ff=256,
    vocab=512,
    prefix=(("mla", "dense"),),
    pattern=(("mla", "moe"),),
    n_repeats=1,
    q_lora_rank=32,
    kv_lora_rank=32,
    qk_rope_dim=16,
    qk_nope_dim=16,
    v_head_dim=32,
    n_experts=4,
    top_k=2,
    n_shared_experts=1,
    expert_d_ff=64,
    mtp=True,
    fl_mode="fsdp",
    source="reduced smoke variant",
)
