"""Llama-3.2-Vision 90B backbone [hf:meta-llama/Llama-3.2-11B-Vision].

100L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256; every 5th layer
is a cross-attention image layer (20 of 100).  The ViT/SigLIP vision
encoder + projector is the allowed stub: ``input_specs`` supplies
precomputed patch embeddings [B, n_patches, d_model]."""
from repro.models.transformer import ArchConfig

_PATTERN = (("attn", "dense"),) * 4 + (("cross", "dense"),)

CONFIG = ArchConfig(
    arch_id="llama-3.2-vision-90b",
    family="vlm",
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_head=128,
    d_ff=28672,
    vocab=128256,
    pattern=_PATTERN,
    n_repeats=20,
    rope_theta=5e5,
    frontend="vision",
    n_frontend_tokens=256,    # precomputed patch embeddings (stub)
    fl_mode="fsdp",
    source="[hf:meta-llama/Llama-3.2-11B-Vision] scaled to 90B table entry",
)

REDUCED = ArchConfig(
    arch_id="llama-3.2-vision-90b/reduced",
    family="vlm",
    d_model=128,
    n_heads=4,
    n_kv_heads=2,
    d_head=32,
    d_ff=256,
    vocab=512,
    pattern=(("attn", "dense"), ("cross", "dense")),
    n_repeats=1,
    frontend="vision",
    n_frontend_tokens=8,
    fl_mode="fsdp",
    source="reduced smoke variant",
)
