"""Jamba v0.1 52B [arXiv:2403.19887] — hybrid Mamba+attention 1:7, MoE.

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536, MoE 16e top-2.
Super-block of 8 layers: attention at index 4, MoE on every other layer."""
from repro.models.transformer import ArchConfig

_PATTERN = (
    ("mamba", "moe"), ("mamba", "dense"), ("mamba", "moe"), ("mamba", "dense"),
    ("attn", "moe"), ("mamba", "dense"), ("mamba", "moe"), ("mamba", "dense"),
)

CONFIG = ArchConfig(
    arch_id="jamba-v0.1-52b",
    family="hybrid",
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_head=128,
    d_ff=14336,
    vocab=65536,
    pattern=_PATTERN,
    n_repeats=4,
    n_experts=16,
    top_k=2,
    expert_d_ff=14336,
    ssm_state=16,
    ssm_conv=4,
    ssm_expand=2,
    window=4096,              # its attn layers decode long_500k windowed
    global_cache_cap=32768,   # bounded cache for the 1-in-8 attn layers
    fl_mode="stacked",
    source="[arXiv:2403.19887] Jamba v0.1",
)

REDUCED = ArchConfig(
    arch_id="jamba-v0.1-52b/reduced",
    family="hybrid",
    d_model=128,
    n_heads=4,
    n_kv_heads=2,
    d_head=32,
    d_ff=256,
    vocab=512,
    pattern=(("mamba", "moe"), ("attn", "dense")),
    n_repeats=1,
    n_experts=4,
    top_k=2,
    expert_d_ff=64,
    ssm_state=8,
    ssm_conv=4,
    ssm_expand=2,
    fl_mode="stacked",
    source="reduced smoke variant",
)
