"""Architecture config registry (``--arch <id>``) + input-shape table.

Every config cites its source in ``source``.  ``supports_shape`` encodes
the DESIGN §5 skip rules (long_500k only for sub-quadratic archs).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Tuple

from repro.models.transformer import ArchConfig

from . import (
    kimi_k2_1t_a32b,
    seamless_m4t_medium,
    phi4_mini_3_8b,
    deepseek_v3_671b,
    minicpm_2b,
    jamba_v0_1_52b,
    rwkv6_3b,
    llama_3_2_vision_90b,
    gemma3_1b,
    qwen1_5_110b,
)

_MODULES = {
    "kimi-k2-1t-a32b": kimi_k2_1t_a32b,
    "seamless-m4t-medium": seamless_m4t_medium,
    "phi4-mini-3.8b": phi4_mini_3_8b,
    "deepseek-v3-671b": deepseek_v3_671b,
    "minicpm-2b": minicpm_2b,
    "jamba-v0.1-52b": jamba_v0_1_52b,
    "rwkv6-3b": rwkv6_3b,
    "llama-3.2-vision-90b": llama_3_2_vision_90b,
    "gemma3-1b": gemma3_1b,
    "qwen1.5-110b": qwen1_5_110b,
}

ARCH_IDS = tuple(_MODULES)


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    mode: str  # train | prefill | decode


INPUT_SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}

# archs allowed to run the 500k decode shape (DESIGN §5): SSM / hybrid /
# native-sliding-window only.
_LONG_OK = {"rwkv6-3b", "jamba-v0.1-52b", "gemma3-1b"}


def get_config(arch_id: str) -> ArchConfig:
    try:
        return _MODULES[arch_id].CONFIG
    except KeyError:
        raise ValueError(f"unknown arch {arch_id!r}; choose from {sorted(_MODULES)}") from None


def get_reduced(arch_id: str) -> ArchConfig:
    return _MODULES[arch_id].REDUCED


def supports_shape(arch_id: str, shape_name: str) -> bool:
    if shape_name == "long_500k":
        return arch_id in _LONG_OK
    return True


def skip_reason(arch_id: str, shape_name: str) -> str:
    if shape_name == "long_500k" and arch_id not in _LONG_OK:
        return ("pure full-attention arch: 500k decode skipped per DESIGN §5 "
                "(no sliding-window variant claimed; trained context ≪ 500k)")
    return ""
