"""Qwen1.5-110B [hf:Qwen/Qwen1.5-0.5B family, 110B table entry].

80L d_model=8192 64H (GQA kv=8) d_ff=49152 vocab=152064 — QKV bias."""
from repro.models.transformer import ArchConfig

CONFIG = ArchConfig(
    arch_id="qwen1.5-110b",
    family="dense",
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_head=128,
    d_ff=49152,
    vocab=152064,
    pattern=(("attn", "dense"),),
    n_repeats=80,
    qkv_bias=True,
    rope_theta=1e6,
    fl_mode="fsdp",
    source="[hf:Qwen/Qwen1.5] 110B table entry (QKV bias)",
)

REDUCED = ArchConfig(
    arch_id="qwen1.5-110b/reduced",
    family="dense",
    d_model=128,
    n_heads=4,
    n_kv_heads=2,
    d_head=32,
    d_ff=256,
    vocab=512,
    pattern=(("attn", "dense"),),
    n_repeats=2,
    qkv_bias=True,
    fl_mode="stacked",
    source="reduced smoke variant",
)
