"""RWKV-6 "Finch" 3B [arXiv:2404.05892] — attention-free, data-dependent
decay.  32L d_model=2560 d_ff=8960 vocab=65536; 40 heads of dim 64."""
from repro.models.transformer import ArchConfig

CONFIG = ArchConfig(
    arch_id="rwkv6-3b",
    family="ssm",
    d_model=2560,
    n_heads=40,
    n_kv_heads=40,
    d_head=64,
    d_ff=8960,
    vocab=65536,
    pattern=(("rwkv", "dense"),),
    n_repeats=32,
    fl_mode="stacked",
    source="[arXiv:2404.05892] RWKV-6 Finch",
)

REDUCED = ArchConfig(
    arch_id="rwkv6-3b/reduced",
    family="ssm",
    d_model=128,
    n_heads=4,
    n_kv_heads=4,
    d_head=32,
    d_ff=256,
    vocab=512,
    pattern=(("rwkv", "dense"),),
    n_repeats=2,
    fl_mode="stacked",
    source="reduced smoke variant",
)
