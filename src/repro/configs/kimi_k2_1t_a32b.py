"""Kimi K2 — trillion-param MoE [arXiv:2501.kimi2].

61L d_model=7168 64H (GQA kv=8) expert_d_ff=2048 vocab=163840,
MoE 384 experts top-8 + 1 shared; first layer dense (DeepSeek-V3-style)."""
from repro.models.transformer import ArchConfig

CONFIG = ArchConfig(
    arch_id="kimi-k2-1t-a32b",
    family="moe",
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    d_head=128,
    d_ff=16384,               # dense FFN width of the first layer
    vocab=163840,
    prefix=(("attn", "dense"),),
    pattern=(("attn", "moe"),),
    n_repeats=60,
    n_experts=384,
    top_k=8,
    n_shared_experts=1,
    expert_d_ff=2048,
    rope_theta=5e4,
    fl_mode="fsdp",           # ~1T params: shared-weights scan-clients mode
    source="[arXiv:2501.kimi2] Kimi K2 paper-table config",
)

REDUCED = ArchConfig(
    arch_id="kimi-k2-1t-a32b/reduced",
    family="moe",
    d_model=128,
    n_heads=4,
    n_kv_heads=2,
    d_head=32,
    d_ff=256,
    vocab=512,
    prefix=(("attn", "dense"),),
    pattern=(("attn", "moe"),),
    n_repeats=1,
    n_experts=4,
    top_k=2,
    n_shared_experts=1,
    expert_d_ff=64,
    fl_mode="fsdp",
    source="reduced smoke variant",
)
