"""MiniCPM-2B [arXiv:2404.06395] — llama-like dense, WSD schedule.

40L d_model=2304 36H (MHA kv=36) d_ff=5760 vocab=122753."""
from repro.models.transformer import ArchConfig

CONFIG = ArchConfig(
    arch_id="minicpm-2b",
    family="dense",
    d_model=2304,
    n_heads=36,
    n_kv_heads=36,
    d_head=64,
    d_ff=5760,
    vocab=122753,
    pattern=(("attn", "dense"),),
    n_repeats=40,
    rope_theta=1e4,
    fl_mode="stacked",
    source="[arXiv:2404.06395] MiniCPM (WSD schedule in repro.optim.schedule)",
)

REDUCED = ArchConfig(
    arch_id="minicpm-2b/reduced",
    family="dense",
    d_model=144,
    n_heads=4,
    n_kv_heads=4,
    d_head=36,
    d_ff=288,
    vocab=512,
    pattern=(("attn", "dense"),),
    n_repeats=2,
    fl_mode="stacked",
    source="reduced smoke variant",
)
