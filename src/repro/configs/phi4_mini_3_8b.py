"""Phi-4-mini 3.8B [arXiv:2412.08905].

32L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=200064 — RoPE SwiGLU GQA."""
from repro.models.transformer import ArchConfig

CONFIG = ArchConfig(
    arch_id="phi4-mini-3.8b",
    family="dense",
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    d_head=128,
    d_ff=8192,
    vocab=200064,
    pattern=(("attn", "dense"),),
    n_repeats=32,
    rope_theta=1e4,
    fl_mode="stacked",
    source="[arXiv:2412.08905] Phi-4 technical report (mini)",
)

REDUCED = ArchConfig(
    arch_id="phi4-mini-3.8b/reduced",
    family="dense",
    d_model=128,
    n_heads=4,
    n_kv_heads=2,
    d_head=32,
    d_ff=256,
    vocab=512,
    pattern=(("attn", "dense"),),
    n_repeats=2,
    fl_mode="stacked",
    source="reduced smoke variant",
)
