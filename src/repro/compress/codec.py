"""Codecs for compressed client-update transport (DESIGN: transport layer).

At 10k+ clients per round the uplink and the aggregation path are
byte-bound, not FLOP-bound: a dense fp32 upload costs 4·D bytes per
client per round.  This module defines the wire format and the encoders
that shrink it:

* ``Int8Codec``  — QSGD-style int8 quantization with *per-chunk* scales
  and stochastic rounding (unbiased: E[decode(encode(v))] = v);
* ``TopKCodec``  — magnitude top-k sparsification (indices + values);
* ``Chain``      — composition, e.g. ``topk:0.05|int8``: sparsify, then
  quantize the survivors.  Int8 scales are always defined over chunks of
  the *decoded* coordinate space, so a sparse-quantized payload can be
  scattered into dense int8 rows without per-element scale bookkeeping —
  exactly the layout the fused ``dequant_agg`` Pallas kernel consumes.

Every encoder is a pure jnp function of statically-shaped inputs, so it
jits and vmaps — the cohort engine encodes whole cohorts per round with
one ``jax.vmap`` call.  The ``Encoded`` wire struct is self-describing:
decoding needs no codec object, only the struct (see ``decode``).

Spec grammar (``parse_codec``)::

    spec    := stage ("|" stage)*
    stage   := "none" | "int8"[":" opt (":" opt)*] | "topk" ":" opt ...
    opt     := "chunk=<int>" | "det" | "ratio=<float>" | "k=<int>"
               | <float in (0,1)>  (topk ratio)  | <int>  (topk k)

    "int8"            dense int8, chunk=256, stochastic rounding
    "int8:chunk=128"  smaller scale granularity
    "int8:det"        deterministic (round-to-nearest) quantization
    "topk:0.05"       keep the 5% largest-|v| coordinates
    "topk:k=100"      keep exactly 100 coordinates
    "topk:0.05|int8"  sparsify then quantize the kept values

Whitespace around ``|`` is tolerated (``"topk:0.1 | int8"``).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.types import Params, Update, tree_flat_vector

INT8_MAX = 127.0
DEFAULT_CHUNK = 256


# --------------------------------------------------------------------------
# wire format
# --------------------------------------------------------------------------
@dataclass
class Encoded:
    """One encoded flat vector — the self-describing wire payload.

    ``data``    quantized int8 values (dense, padded to a chunk multiple)
                or raw f32 values (top-k without quantization);
    ``scales``  f32[n_chunks] per-chunk dequantization scales over the
                *decoded* axis (None when ``data`` is raw f32);
    ``indices`` i32[k] coordinate of each value (None when dense);
    ``d``       decoded length;
    ``chunk``   scale granularity in decoded coordinates (0 = unscaled).
    """

    data: jnp.ndarray
    scales: Optional[jnp.ndarray]
    indices: Optional[jnp.ndarray]
    d: int
    chunk: int = 0

    @property
    def nbytes(self) -> int:
        """Wire bytes of the payload (arrays only; the fixed per-update
        metadata header is negligible and identical for dense uploads)."""
        n = self.data.size * self.data.dtype.itemsize
        if self.scales is not None:
            n += self.scales.size * self.scales.dtype.itemsize
        if self.indices is not None:
            n += self.indices.size * self.indices.dtype.itemsize
        return int(n)

    @property
    def is_quantized(self) -> bool:
        return self.scales is not None


def _encoded_flatten(e: Encoded):
    return (e.data, e.scales, e.indices), (e.d, e.chunk)


def _encoded_unflatten(aux, children):
    data, scales, indices = children
    return Encoded(data, scales, indices, d=aux[0], chunk=aux[1])


jax.tree_util.register_pytree_node(Encoded, _encoded_flatten, _encoded_unflatten)


def decode(enc: Encoded) -> jnp.ndarray:
    """Encoded → dense f32[d].  Needs no codec: the struct is self-describing."""
    vals = enc.data.astype(jnp.float32)
    idx = None if enc.indices is None else enc.indices.astype(jnp.int32)
    if enc.scales is not None:
        if idx is None:
            nc = enc.scales.shape[0]
            vals = (vals.reshape(nc, -1) * enc.scales[:, None]).ravel()
        else:
            vals = vals * enc.scales[idx // enc.chunk]
    if idx is not None:
        return jnp.zeros((enc.d,), jnp.float32).at[idx].set(vals)
    return vals[: enc.d]


# --------------------------------------------------------------------------
# codecs
# --------------------------------------------------------------------------
class Codec:
    """Stateless encoder: flat f32[d] → ``Encoded``.  Implementations are
    pure jnp transforms of statically-shaped inputs (jit/vmap-safe);
    randomness (stochastic rounding) comes in through ``key``."""

    spec: str = "none"

    def encode(self, v: jnp.ndarray, key: Optional[jax.Array] = None) -> Encoded:
        raise NotImplementedError

    def decode(self, enc: Encoded) -> jnp.ndarray:
        return decode(enc)

    def describe(self) -> str:
        return self.spec


class Identity(Codec):
    """Dense fp32 pass-through (the ``none`` spec) — for A/B benchmarking."""

    spec = "none"

    def encode(self, v, key=None):
        return Encoded(v.astype(jnp.float32), None, None, d=v.shape[0])


def _index_dtype(d: int):
    """Smallest integer dtype that addresses a length-``d`` vector — top-k
    wire bytes are index-dominated, so int16 when it fits halves them."""
    return jnp.int16 if d <= 32767 else jnp.int32


def _stochastic_round(x: jnp.ndarray, key: Optional[jax.Array]) -> jnp.ndarray:
    """Unbiased round: ⌊x + u⌋, u ~ U[0,1).  Falls back to round-to-nearest
    when no key is supplied."""
    if key is None:
        return jnp.rint(x)
    return jnp.floor(x + jax.random.uniform(key, x.shape))


class Int8Codec(Codec):
    """Per-chunk absmax int8 quantization (QSGD with s=127 levels).

    The flat vector is padded to a multiple of ``chunk``; each chunk gets
    scale = absmax/127 and its values are stochastically rounded to
    int8 — unbiased, with per-element error < scale.  ``encode_sparse``
    quantizes (index, value) pairs against chunks of the *decoded* axis,
    which is what lets ``topk|int8`` payloads scatter into dense int8
    rows for the fused aggregation kernel.
    """

    def __init__(self, chunk: int = DEFAULT_CHUNK, stochastic: bool = True):
        if chunk < 1:
            raise ValueError(f"chunk must be >= 1, got {chunk}")
        self.chunk = int(chunk)
        self.stochastic = bool(stochastic)
        self.spec = f"int8:chunk={self.chunk}" + ("" if self.stochastic else ":det")

    def _key(self, key):
        return key if self.stochastic else None

    def encode(self, v, key=None):
        d = v.shape[0]
        pad = (-d) % self.chunk
        vp = jnp.pad(v.astype(jnp.float32), (0, pad))
        nc = vp.shape[0] // self.chunk
        chunks = vp.reshape(nc, self.chunk)
        scales = jnp.max(jnp.abs(chunks), axis=1) / INT8_MAX
        safe = jnp.maximum(scales, 1e-12)
        q = _stochastic_round(chunks / safe[:, None], self._key(key))
        q = jnp.clip(q, -INT8_MAX, INT8_MAX).astype(jnp.int8)
        return Encoded(q.ravel(), scales, None, d=d, chunk=self.chunk)

    def encode_sparse(self, indices: jnp.ndarray, vals: jnp.ndarray, d: int,
                      key: Optional[jax.Array] = None) -> Encoded:
        """Quantize sparse (index, value) pairs; scales live on decoded-axis
        chunks (chunks holding no value get scale 0)."""
        nc = -(-d // self.chunk)
        cid = indices.astype(jnp.int32) // self.chunk
        scales = jax.ops.segment_max(
            jnp.abs(vals.astype(jnp.float32)), cid, num_segments=nc
        )
        scales = jnp.maximum(scales, 0.0) / INT8_MAX  # segment_max fill is -inf
        safe = jnp.maximum(scales, 1e-12)
        q = _stochastic_round(vals.astype(jnp.float32) / safe[cid], self._key(key))
        q = jnp.clip(q, -INT8_MAX, INT8_MAX).astype(jnp.int8)
        return Encoded(q, scales, indices.astype(_index_dtype(d)), d=d,
                       chunk=self.chunk)


class TopKCodec(Codec):
    """Magnitude top-k sparsification: keep the k largest-|v| coordinates.

    ``ratio`` resolves to k = max(1, round(ratio·d)) at encode time, so one
    codec object serves any model size; pass ``k`` to pin it.  Combine
    with client-side error feedback (``repro.compress.feedback``) so the
    discarded mass re-enters later uploads instead of vanishing.
    """

    def __init__(self, ratio: Optional[float] = None, k: Optional[int] = None):
        if (ratio is None) == (k is None):
            raise ValueError("TopKCodec needs exactly one of ratio= or k=")
        if ratio is not None and not 0.0 < ratio <= 1.0:
            raise ValueError(f"topk ratio must be in (0, 1], got {ratio}")
        if k is not None and k < 1:
            raise ValueError(f"topk k must be >= 1, got {k}")
        self.ratio = ratio
        self.k = k
        self.spec = f"topk:{ratio}" if ratio is not None else f"topk:k={k}"

    def resolve_k(self, d: int) -> int:
        k = self.k if self.k is not None else max(1, int(round(self.ratio * d)))
        return min(int(k), int(d))

    def top(self, v: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
        k = self.resolve_k(v.shape[0])
        _, idx = jax.lax.top_k(jnp.abs(v), k)
        idx = jnp.sort(idx)  # ascending positions: nicer wire format + chunk locality
        return idx.astype(_index_dtype(v.shape[0])), v[idx]

    def encode(self, v, key=None):
        idx, vals = self.top(v)
        return Encoded(vals.astype(jnp.float32), None, idx, d=v.shape[0])


class Chain(Codec):
    """Stage composition.  The supported pipelines are the useful ones:
    ``topk`` → ``int8`` (sparsify then quantize survivors), plus each
    stage alone; arbitrary stacks would need value-space re-indexing that
    nothing upstream produces."""

    def __init__(self, stages: List[Codec]):
        stages = [s for s in stages if not isinstance(s, Identity)]
        if not stages:
            stages = [Identity()]
        if len(stages) > 2 or (
            len(stages) == 2
            and not (isinstance(stages[0], TopKCodec) and isinstance(stages[1], Int8Codec))
        ):
            raise ValueError(
                "unsupported codec chain: compose as 'topk|int8', or use a "
                f"single stage (got {[s.spec for s in stages]})"
            )
        self.stages = stages
        self.spec = "|".join(s.spec for s in stages)

    def encode(self, v, key=None):
        if len(self.stages) == 1:
            return self.stages[0].encode(v, key)
        topk, int8 = self.stages
        idx, vals = topk.top(v)
        return int8.encode_sparse(idx, vals, v.shape[0], key)


# --------------------------------------------------------------------------
# spec grammar
# --------------------------------------------------------------------------
KNOWN_STAGES = ("none", "int8", "topk")


def _parse_stage(stage: str) -> Codec:
    parts = [p.strip() for p in stage.split(":") if p.strip()]
    if not parts:
        raise ValueError(
            f"empty codec stage; known stages: {', '.join(KNOWN_STAGES)}"
        )
    name, opts = parts[0].lower(), parts[1:]
    if name in ("none", "dense", "fp32"):
        if opts:
            raise ValueError(f"'{name}' takes no options")
        return Identity()
    if name == "int8":
        chunk, stochastic = DEFAULT_CHUNK, True
        for o in opts:
            if o == "det":
                stochastic = False
            elif o == "sr":
                stochastic = True
            elif o.startswith("chunk="):
                chunk = int(o[len("chunk="):])
            else:
                raise ValueError(f"unknown int8 option {o!r}")
        return Int8Codec(chunk=chunk, stochastic=stochastic)
    if name == "topk":
        if len(opts) != 1:
            raise ValueError("topk needs one option: a ratio in (0,1), or k=<int>")
        o = opts[0]
        if o.startswith("k="):
            return TopKCodec(k=int(o[2:]))
        if o.startswith("ratio="):
            return TopKCodec(ratio=float(o[len("ratio="):]))
        val = float(o)
        if val <= 1.0:  # topk:1.0 keeps everything, like ratio=1.0
            return TopKCodec(ratio=val)
        if val != int(val):
            raise ValueError(f"topk:{o}: a count must be an integer "
                             "(ratios live in (0, 1])")
        return TopKCodec(k=int(val))
    raise ValueError(
        f"unknown codec stage {name!r}; known stages: "
        f"{', '.join(KNOWN_STAGES)} (e.g. 'int8', 'topk:0.05|int8')"
    )


def parse_codec(spec: str) -> Codec:
    """Parse the spec grammar (module docstring) into a ``Codec``.

    Whitespace around stages and their options is tolerated
    (``"topk :0.05 | int8"`` parses like ``"topk:0.05|int8"``); an
    unknown stage raises a ``ValueError`` naming the known stages.
    """
    stages = [_parse_stage(s.strip()) for s in str(spec).split("|")]
    return stages[0] if len(stages) == 1 else Chain(stages)


# --------------------------------------------------------------------------
# compressed wire update
# --------------------------------------------------------------------------
@dataclass
class CompressedUpdate:
    """Wire form of ``repro.core.types.Update``: identical metadata, but
    the tensor payloads are ``Encoded`` flat vectors.

    Admission control, triggers, and the status-table update read only
    the metadata fields — a gateway weighs staleness and buffers the
    update without ever decoding the payload.  Decoding happens once, at
    aggregation time, and the batched service path skips even that by
    feeding quantized rows straight to the fused ``dequant_agg`` kernel.
    """

    cid: int
    n_samples: int
    stale_round: int
    lr: float
    similarity: float
    feedback: bool
    speed_f: float
    delta: Optional[Encoded] = None    # encoded raveled pseudo-gradient
    params: Optional[Encoded] = None   # encoded raveled local model

    @property
    def nbytes(self) -> int:
        return sum(p.nbytes for p in (self.delta, self.params) if p is not None)

    def to_update(self, unravel) -> Update:
        """Decode into a dense ``Update`` (``unravel``: flat [D] → pytree,
        e.g. from ``jax.flatten_util.ravel_pytree`` of the global model)."""
        return Update(
            cid=self.cid,
            n_samples=self.n_samples,
            stale_round=self.stale_round,
            lr=self.lr,
            similarity=self.similarity,
            feedback=self.feedback,
            speed_f=self.speed_f,
            delta=unravel(decode(self.delta)) if self.delta is not None else None,
            params=unravel(decode(self.params)) if self.params is not None else None,
        )


def is_compressed(update) -> bool:
    return isinstance(update, CompressedUpdate)


def compress_update(update: Update, codec: Codec,
                    key: Optional[jax.Array] = None, *,
                    payloads: Tuple[str, ...] = ("delta", "params")) -> CompressedUpdate:
    """Encode a dense ``Update``'s pytree payload(s) into wire form.

    Flattening uses leaf order (``ravel_flat``), matching the unravel
    closure the service derives from its global model.
    """
    enc = {}
    for name in ("delta", "params"):
        tree = getattr(update, name)
        if tree is not None and name in payloads:
            enc[name] = codec.encode(ravel_flat(tree), key)
    return CompressedUpdate(
        cid=update.cid,
        n_samples=update.n_samples,
        stale_round=update.stale_round,
        lr=update.lr,
        similarity=update.similarity,
        feedback=update.feedback,
        speed_f=update.speed_f,
        delta=enc.get("delta"),
        params=enc.get("params"),
    )


# the wire-format flatten IS the Mod-1 similarity-space flatten: one leaf
# order shared by encode, decode-unravel, and similarity computations
ravel_flat = tree_flat_vector


def ravel_flat_batch(tree: Params) -> jnp.ndarray:
    """Batched ravel: a pytree whose leaves carry a leading batch axis
    [B, ...] → one [B, D] f32 matrix, rows in the same leaf order as
    ``ravel_flat`` of each slice (the cohort engine's per-round encode)."""
    leaves = jax.tree_util.tree_leaves(tree)
    if not leaves:
        return jnp.zeros((0, 0), jnp.float32)
    B = leaves[0].shape[0]
    return jnp.concatenate(
        [l.reshape(B, -1).astype(jnp.float32) for l in leaves], axis=1
    )
