"""Compressed update transport (docs/COMPRESSION.md).

Codecs shrink client uploads — int8 per-chunk quantization, top-k
sparsification, composed as ``topk:0.05|int8`` — with client-side error
feedback, a self-describing ``Encoded`` wire struct, and a
``CompressedUpdate`` the streaming service ingests without decoding
(the batched path aggregates quantized rows directly through the fused
Pallas ``dequant_agg`` kernel).
"""
from .codec import (
    Chain,
    Codec,
    CompressedUpdate,
    Encoded,
    Identity,
    Int8Codec,
    TopKCodec,
    compress_update,
    decode,
    is_compressed,
    parse_codec,
    ravel_flat,
    ravel_flat_batch,
)
from .feedback import (
    ClientCompressor,
    CompressorStats,
    compress_stream,
    quantizer_stage,
)

__all__ = [
    "Chain", "Codec", "CompressedUpdate", "Encoded", "Identity",
    "Int8Codec", "TopKCodec", "compress_update", "decode", "is_compressed",
    "parse_codec", "ravel_flat", "ravel_flat_batch",
    "ClientCompressor", "CompressorStats", "compress_stream",
    "quantizer_stage",
]
