"""Client-side compression state: error feedback + wire accounting.

Sparsification is lossy in a way plain averaging never recovers — the
discarded coordinates are simply gone.  Error feedback (Stich et al.,
"Sparsified SGD with memory") fixes this client-side: each client keeps
the residual ``e_i = v - decode(encode(v))`` of its last upload and adds
it back into the next one, so every coordinate's mass eventually crosses
the wire and the *cumulative* transport error stays bounded instead of
growing with the round count.

``ClientCompressor`` owns that per-client residual bank plus the byte
counters the benchmarks report.  It has two encode surfaces:

* ``encode_update(update)``     — one dense ``Update`` → ``CompressedUpdate``
  (the event-driven engine and the stream generators);
* ``encode_flat_batch(cids, flats)`` — a whole cohort's raveled deltas
  ``[B, D]`` in one ``jax.vmap`` call (the cohort fast path).

Residuals apply to **delta** payloads only: deltas are additive
transport, where deferred mass is recovered by later rounds.  ``params``
payloads are absolute model state — they are quantized (the chain's
quantizer stage) but never sparsified or residual-corrected, since a
model with 95% of its weights zeroed is not a model.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.types import AggregationStrategy, Update
from repro.telemetry import BYTES_BUCKETS, CodecEncoded

from .codec import (
    Chain,
    Codec,
    CompressedUpdate,
    Encoded,
    Identity,
    Int8Codec,
    TopKCodec,
    decode,
    parse_codec,
    ravel_flat,
)


def quantizer_stage(codec: Codec) -> Codec:
    """The chain's quantization-only stage (for absolute ``params``
    payloads): ``topk|int8`` → ``int8``; bare ``topk`` → identity."""
    if isinstance(codec, Chain):
        stages = [s for s in codec.stages if not isinstance(s, TopKCodec)]
        return stages[0] if stages else Identity()
    if isinstance(codec, TopKCodec):
        return Identity()
    return codec


@dataclass
class CompressorStats:
    updates: int = 0
    payload_bytes: int = 0
    dense_bytes: int = 0

    @property
    def ratio(self) -> float:
        """Dense-to-wire byte ratio (>1 = compression wins)."""
        return self.dense_bytes / max(self.payload_bytes, 1)

    @property
    def bytes_per_update(self) -> float:
        return self.payload_bytes / max(self.updates, 1)


class ClientCompressor:
    """Codec + per-client error-feedback residual bank.

    The residual matrix is allocated lazily at the first encode (when D
    becomes known) as f32[n_clients, D] — at cohort scale this is the
    same footprint as one stacked update batch.  ``state_dict`` /
    ``load_state_dict`` round-trip it through checkpoints.
    """

    def __init__(
        self,
        codec: Union[Codec, str],
        n_clients: int,
        *,
        error_feedback: bool = True,
        seed: int = 0,
    ):
        self.codec = parse_codec(codec) if isinstance(codec, str) else codec
        self.params_codec = quantizer_stage(self.codec)
        self.n_clients = int(n_clients)
        self.error_feedback = bool(error_feedback)
        self.residual: Optional[np.ndarray] = None  # f32[n_clients, D], lazy
        self.stats = CompressorStats()
        # telemetry hub (docs/OBSERVABILITY.md), attached by the engine /
        # launcher that owns the run; None = no events, zero overhead.
        # Metric handles bind lazily (the hub arrives post-construction)
        # and are cached per hub so the per-upload path skips the
        # registry's string lookups.
        self.telemetry = None
        self._tm_handles = None
        self._key = jax.random.PRNGKey(seed)
        self._encode_batch = jax.jit(jax.vmap(self.codec.encode))
        self._decode_batch = jax.jit(jax.vmap(decode))

    def describe(self) -> str:
        ef = "+ef" if self.error_feedback else ""
        return f"{self.codec.spec}{ef}"

    # ----------------------------------------------------------- internals
    def _next_key(self) -> jax.Array:
        self._key, sub = jax.random.split(self._key)
        return sub

    def _ensure_residual(self, d: int) -> np.ndarray:
        if self.residual is None:
            self.residual = np.zeros((self.n_clients, d), np.float32)
        elif self.residual.shape[1] != d:
            raise ValueError(
                f"payload dim changed: residual bank is D={self.residual.shape[1]}, "
                f"got D={d}"
            )
        return self.residual

    def _account(self, enc: Encoded, d: int) -> None:
        self.stats.payload_bytes += enc.nbytes
        self.stats.dense_bytes += 4 * d

    def _emit_encoded(self, cid: int, dense_bytes: int, wire_bytes: int) -> None:
        """One ``codec-encoded`` telemetry event + byte metrics (no-op
        without a hub).  ``cid=-1`` marks unattributed payloads (the
        cohort's cid-less params batch)."""
        tel = self.telemetry
        if tel is None:
            return
        handles = self._tm_handles
        if handles is None or handles[0] is not tel:
            m = tel.metrics
            handles = (
                tel,
                m.counter("compress.wire_bytes", unit="bytes",
                          layer="compress"),
                m.counter("compress.dense_bytes", unit="bytes",
                          layer="compress"),
                m.histogram("compress.update_bytes", BYTES_BUCKETS,
                            unit="bytes", layer="compress"),
            )
            self._tm_handles = handles
        _, wire_counter, dense_counter, update_hist = handles
        wire_counter.inc(int(wire_bytes))
        dense_counter.inc(int(dense_bytes))
        update_hist.observe(int(wire_bytes))
        tel.emit(CodecEncoded(
            t=None, cid=int(cid), spec=self.codec.spec,
            dense_bytes=int(dense_bytes), wire_bytes=int(wire_bytes),
        ))

    # ------------------------------------------------------- single update
    def encode_delta(self, cid: int, flat: jnp.ndarray) -> Encoded:
        """Error-feedback encode of one client's raveled delta."""
        d = int(flat.shape[0])
        if self.error_feedback:
            res = self._ensure_residual(d)
            v = flat + res[cid]
        else:
            v = flat
        enc = self.codec.encode(v, self._next_key())
        if self.error_feedback:
            res[cid] = np.asarray(v - decode(enc), np.float32)
        self._account(enc, d)
        return enc

    def encode_params(self, flat: jnp.ndarray) -> Encoded:
        """Quantize-only encode of absolute model state (no residuals)."""
        enc = self.params_codec.encode(flat, self._next_key())
        self._account(enc, int(flat.shape[0]))
        return enc

    def encode_update(
        self,
        update: Update,
        *,
        strategy: Optional[AggregationStrategy] = None,
    ) -> CompressedUpdate:
        """Dense ``Update`` → ``CompressedUpdate``.

        With a ``strategy`` only the payload that strategy aggregates is
        shipped (GRADIENT → delta, MODEL → params) — half the wire bytes
        and exactly what a strategy-aware client would upload.  Without
        one, every present payload is encoded.
        """
        delta = params = None
        want_delta = update.delta is not None and strategy in (
            None, AggregationStrategy.GRADIENT)
        want_params = update.params is not None and strategy in (
            None, AggregationStrategy.MODEL)
        wire0 = self.stats.payload_bytes
        dense0 = self.stats.dense_bytes
        if want_delta:
            delta = self.encode_delta(update.cid, ravel_flat(update.delta))
        if want_params:
            params = self.encode_params(ravel_flat(update.params))
        self.stats.updates += 1
        self._emit_encoded(update.cid, self.stats.dense_bytes - dense0,
                           self.stats.payload_bytes - wire0)
        return CompressedUpdate(
            cid=update.cid,
            n_samples=update.n_samples,
            stale_round=update.stale_round,
            lr=update.lr,
            similarity=update.similarity,
            feedback=update.feedback,
            speed_f=update.speed_f,
            delta=delta,
            params=params,
        )

    # -------------------------------------------------------- cohort batch
    def encode_flat_batch(
        self, cids: Sequence[int], flats: jnp.ndarray
    ) -> List[Encoded]:
        """Encode a cohort's raveled deltas [B, D] in one vmap call.

        Residual correction, encode, decode-for-residual all run
        vectorized; the result is unstacked into per-client ``Encoded``
        payloads for submission.
        """
        cids = np.asarray(cids, np.int64)
        B, d = flats.shape
        if self.error_feedback:
            res = self._ensure_residual(int(d))
            v = jnp.asarray(flats) + jnp.asarray(res[cids])
        else:
            v = jnp.asarray(flats)
        keys = jax.random.split(self._next_key(), B)
        batched = self._encode_batch(v, keys)
        if self.error_feedback:
            res[cids] = np.asarray(v - self._decode_batch(batched), np.float32)
        encs = [
            jax.tree_util.tree_map(lambda a, i=i: a[i], batched) for i in range(B)
        ]
        for cid, enc in zip(cids, encs):
            self._account(enc, int(d))
            self._emit_encoded(int(cid), 4 * int(d), enc.nbytes)
        self.stats.updates += B
        return encs

    def encode_params_flat_batch(self, flats: jnp.ndarray) -> List[Encoded]:
        """Quantize-only vmapped encode of absolute model rows [B, D]
        (MODEL-strategy cohorts; no residual correction — see module
        docstring)."""
        B, d = flats.shape
        keys = jax.random.split(self._next_key(), B)
        batched = jax.vmap(self.params_codec.encode)(jnp.asarray(flats), keys)
        encs = [
            jax.tree_util.tree_map(lambda a, i=i: a[i], batched) for i in range(B)
        ]
        for enc in encs:
            self._account(enc, int(d))
            self._emit_encoded(-1, 4 * int(d), enc.nbytes)
        self.stats.updates += B
        return encs

    # ----------------------------------------------------------- checkpoint
    def state_dict(self) -> dict:
        return {
            "spec": self.codec.spec,
            "error_feedback": self.error_feedback,
            "residual": None if self.residual is None else self.residual,
        }

    def load_state_dict(self, state: dict) -> None:
        if state.get("spec") != self.codec.spec:
            raise ValueError(
                f"codec mismatch: checkpoint has {state.get('spec')!r}, "
                f"compressor is {self.codec.spec!r}"
            )
        res = state.get("residual")
        if res is not None:
            res = np.asarray(res, np.float32)
            if res.shape[0] != self.n_clients:
                raise ValueError(
                    f"residual bank is for {res.shape[0]} clients, "
                    f"compressor has {self.n_clients}"
                )
            self.residual = res
        else:
            self.residual = None


def compress_stream(stream, compressor: ClientCompressor, *,
                    strategy: Optional[AggregationStrategy] = None):
    """Wrap an (update, now) stream, encoding each update on the fly —
    the load-generation twin of a compressing client population."""
    for update, now in stream:
        yield compressor.encode_update(update, strategy=strategy), now
