"""Population models: sample heterogeneous client cohorts.

A population model answers "who are the N clients?" — their compute
speeds (virtual seconds per local round), their data volumes (quantity
skew), and their label distributions (Dirichlet label skew).  Everything
is generated vectorized from a caller-supplied ``numpy`` Generator, so a
10k- or 1M-client cohort costs one array draw, and the same seed always
produces the same cohort (the determinism contract the scenario tests
pin down).

Speed distributions (docs/SCENARIOS.md "Population models"):

* ``UniformSpeeds``   — the engine's historic 1:ratio uniform spread;
* ``LognormalSpeeds`` — heavy-tailed device times (FLGo's phone traces
  and the MLSys device benchmarks are roughly log-normal);
* ``BimodalSpeeds``   — two device classes (flagship vs budget), the
  CSAFL grouping-by-delay setting;
* ``ZipfSpeeds``      — a power-law long tail: a few very slow devices,
  most fast.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import numpy as np


# --------------------------------------------------------------------------
# speed models
# --------------------------------------------------------------------------
class SpeedModel:
    """Base: sample per-client virtual seconds per local round."""

    def sample(self, n: int, rng: np.random.Generator) -> np.ndarray:
        raise NotImplementedError

    def describe(self) -> str:
        return type(self).__name__


@dataclass
class UniformSpeeds(SpeedModel):
    """U[lo, hi] — the engine default (``resource_ratio`` spread)."""

    lo: float = 1.0
    hi: float = 50.0

    def sample(self, n, rng):
        return rng.uniform(self.lo, self.hi, n)

    def describe(self):
        return f"uniform[{self.lo:g},{self.hi:g}]"


@dataclass
class LognormalSpeeds(SpeedModel):
    """exp(N(ln median, σ²)), clipped to [lo, hi] — heavy-tailed devices."""

    median: float = 8.0
    sigma: float = 0.75
    lo: float = 1.0
    hi: float = 200.0

    def sample(self, n, rng):
        s = rng.lognormal(np.log(self.median), self.sigma, n)
        return np.clip(s, self.lo, self.hi)

    def describe(self):
        return f"lognormal(med={self.median:g},sigma={self.sigma:g})"


@dataclass
class BimodalSpeeds(SpeedModel):
    """Two device classes: ``slow_frac`` of clients around ``slow``,
    the rest around ``fast``; each class gets ±``jitter`` relative noise."""

    fast: float = 2.0
    slow: float = 30.0
    slow_frac: float = 0.3
    jitter: float = 0.2

    def sample(self, n, rng):
        is_slow = rng.random(n) < self.slow_frac
        base = np.where(is_slow, self.slow, self.fast)
        return base * rng.uniform(1.0 - self.jitter, 1.0 + self.jitter, n)

    def describe(self):
        return f"bimodal(fast={self.fast:g},slow={self.slow:g},frac={self.slow_frac:g})"


@dataclass
class ZipfSpeeds(SpeedModel):
    """Power-law straggler tail: slowness ∝ (n/rank)^exponent, so most
    clients sit near the fast floor ``scale`` and a handful (low ranks)
    are extreme stragglers, clipped at ``hi``."""

    exponent: float = 1.2
    scale: float = 1.0
    hi: float = 100.0

    def sample(self, n, rng):
        ranks = rng.permutation(n) + 1.0
        slowness = self.scale * (n / ranks) ** self.exponent
        return np.clip(slowness, self.scale, self.hi)

    def describe(self):
        return f"zipf(s={self.exponent:g})"


# --------------------------------------------------------------------------
# data-skew models
# --------------------------------------------------------------------------
@dataclass
class DirichletLabelSkew:
    """Per-client label distribution π_i ~ Dir(α·1_C) (paper Eq. 13).

    Smaller α ⇒ more skew; α→∞ recovers IID.  Vectorized: one
    ``rng.dirichlet`` call of shape [N, C].
    """

    alpha: float = 0.5

    def sample(self, n: int, n_labels: int, rng: np.random.Generator) -> np.ndarray:
        return rng.dirichlet([self.alpha] * n_labels, size=n).astype(np.float32)

    def describe(self):
        return f"dirichlet(alpha={self.alpha:g})"


@dataclass
class QuantitySkew:
    """Per-client sample counts ~ round(Log-N(ln mean, σ²)), ≥ min_samples."""

    mean: float = 100.0
    sigma: float = 0.8
    min_samples: int = 8

    def sample(self, n: int, rng: np.random.Generator) -> np.ndarray:
        sizes = rng.lognormal(np.log(self.mean), self.sigma, n)
        return np.maximum(sizes.astype(np.int64), self.min_samples)

    def describe(self):
        return f"lognormal-qty(mean={self.mean:g},sigma={self.sigma:g})"


# --------------------------------------------------------------------------
# the composed population
# --------------------------------------------------------------------------
@dataclass
class Cohort:
    """One sampled client population (all arrays are length N)."""

    speeds: np.ndarray       # f64[N] — virtual seconds per local round
    n_samples: np.ndarray    # i64[N] — local dataset sizes
    label_probs: np.ndarray  # f32[N, C] — per-client label distribution

    @property
    def n(self) -> int:
        return len(self.speeds)


@dataclass
class Population:
    """Composable cohort sampler: speed model × quantity skew × label skew."""

    speeds: SpeedModel = field(default_factory=UniformSpeeds)
    quantity: QuantitySkew = field(default_factory=QuantitySkew)
    labels: DirichletLabelSkew = field(default_factory=DirichletLabelSkew)
    n_labels: int = 10

    def sample(self, n: int, rng: np.random.Generator) -> Cohort:
        return Cohort(
            speeds=self.speeds.sample(n, rng),
            n_samples=self.quantity.sample(n, rng),
            label_probs=self.labels.sample(n, self.n_labels, rng),
        )

    def sample_speeds(self, n: int, rng: np.random.Generator) -> np.ndarray:
        """Speeds only — what ``SAFLEngine`` needs (its data is external)."""
        return self.speeds.sample(n, rng)

    def describe(self) -> str:
        return (f"{self.speeds.describe()} × {self.quantity.describe()} "
                f"× {self.labels.describe()}")
