"""Dynamic events: mid-run mutations of the client population.

Each event keeps the contract of the engine's historic ``dynamics``
callback — ``apply(round, speeds, rng) -> Optional[np.ndarray]`` where a
``None`` return means "no change" and NaN entries mark dead clients —
so a ``Scenario`` carrying one wrapped callback is *bit-identical* to
the legacy path (tests/test_scenarios.py::TestDynamicsParity).  The
paper-§5.3 events delegate to the exact legacy implementations in
``repro.core.safl`` so they consume the same RNG draws.

Beyond the callback contract, events may additionally revive clients
(a finite speed where there was NaN: the engine re-enqueues them) and
mutate client data (``mutate_data``), which the callbacks never could.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional

import numpy as np


class DynamicEvent:
    """Base event: speed-array mutation per aggregation round."""

    def apply(self, rnd: int, speeds: np.ndarray,
              rng: np.random.Generator) -> Optional[np.ndarray]:
        return None

    def mutate_data(self, rnd: int, data, rng: np.random.Generator) -> None:
        """Optional hook mutating ``FederatedData`` in place (drift)."""

    def describe(self) -> str:
        return type(self).__name__


@dataclass
class CallbackEvent(DynamicEvent):
    """Adapter for a legacy ``dynamics`` callback (the shim the engine
    installs when callers still pass ``dynamics=``)."""

    fn: Callable[[int, np.ndarray, np.random.Generator], Optional[np.ndarray]]

    def apply(self, rnd, speeds, rng):
        return self.fn(rnd, speeds, rng)

    def describe(self):
        return f"callback({getattr(self.fn, '__name__', 'fn')})"


@dataclass
class ResourceScale(DynamicEvent):
    """Paper §5.3 scenario 1: the speed spread rescales from 1:50 to
    1:``new_ratio`` at round ``at_round`` (same math as
    ``repro.core.safl.scenario_resource_scale``)."""

    at_round: int
    new_ratio: float = 100.0

    def __post_init__(self):
        from repro.core.safl import scenario_resource_scale
        self._fn = scenario_resource_scale(self.at_round, self.new_ratio)

    def apply(self, rnd, speeds, rng):
        return self._fn(rnd, speeds, rng)

    def describe(self):
        return f"resource-scale(@{self.at_round}→1:{self.new_ratio:g})"


@dataclass
class SpeedJitter(DynamicEvent):
    """Paper §5.3 scenario 2: every client's resource fluctuates within
    ±``unit`` per round, clipped to [lo, hi]."""

    lo: float = 1.0
    hi: float = 50.0
    unit: float = 10.0

    def __post_init__(self):
        from repro.core.safl import scenario_unstable_resources
        self._fn = scenario_unstable_resources(self.lo, self.hi, self.unit)

    def apply(self, rnd, speeds, rng):
        return self._fn(rnd, speeds, rng)

    def describe(self):
        return f"speed-jitter(±{self.unit:g})"


@dataclass
class Dropout(DynamicEvent):
    """Paper §5.3 scenario 3: ``frac`` of clients leave permanently at
    round ``at_round`` (NaN = dead)."""

    at_round: int
    frac: float = 0.5

    def __post_init__(self):
        from repro.core.safl import scenario_dropout
        self._fn = scenario_dropout(self.at_round, self.frac)

    def apply(self, rnd, speeds, rng):
        return self._fn(rnd, speeds, rng)

    def describe(self):
        return f"dropout(@{self.at_round},{self.frac:.0%})"


@dataclass
class SpeedShift(DynamicEvent):
    """Mid-run global speed shift: all live clients' speeds multiply by
    ``factor`` at ``at_round`` (a network-tier change, e.g. wifi→LTE)."""

    at_round: int
    factor: float = 2.0

    def apply(self, rnd, speeds, rng):
        if rnd == self.at_round:
            return speeds * self.factor
        return None

    def describe(self):
        return f"speed-shift(@{self.at_round}×{self.factor:g})"


@dataclass
class Churn(DynamicEvent):
    """Join/leave churn: every ``period`` rounds, ``frac`` of the *live*
    clients leave (NaN) and every currently-dead client rejoins with a
    fresh speed drawn uniformly from the live speed range.

    Unlike ``Dropout`` this cycles — the population breathes.  Revived
    entries (NaN → finite) are re-enqueued by the engine.
    """

    period: int = 10
    frac: float = 0.2

    def apply(self, rnd, speeds, rng):
        if rnd == 0 or rnd % self.period != 0:
            return None
        out = speeds.copy()
        dead = np.flatnonzero(~np.isfinite(out))
        live = np.flatnonzero(np.isfinite(out))
        if len(live) > 0:
            lo, hi = float(out[live].min()), float(out[live].max())
            # rejoin first so the draw range reflects the pre-churn spread
            if len(dead) > 0:
                out[dead] = rng.uniform(lo, max(hi, lo + 1e-9), len(dead))
            n_leave = int(len(live) * self.frac)
            if n_leave > 0:
                out[rng.choice(live, n_leave, replace=False)] = np.nan
        return out

    def describe(self):
        return f"churn(every {self.period}r, {self.frac:.0%})"


@dataclass
class LabelDrift(DynamicEvent):
    """Distribution drift: at ``at_round``, a ``frac`` of clients see
    their local label semantics rotate (y ← (y+shift) mod C) — the
    concept-drift analogue of §5.3's environment changes.  Mutates the
    engine's ``FederatedData`` in place (train, validation); the global
    test set is untouched, so drifted clients now pull the global model
    away from it.
    """

    at_round: int
    frac: float = 0.3
    shift: int = 1

    def mutate_data(self, rnd, data, rng):
        if rnd != self.at_round or data is None:
            return
        n = data.n_clients
        picked = rng.choice(n, max(1, int(n * self.frac)), replace=False)
        for cid in picked:
            ds = data.clients[cid]
            ds.y = (ds.y + self.shift) % data.n_labels
            ds.val_y = (ds.val_y + self.shift) % data.n_labels

    def describe(self):
        return f"label-drift(@{self.at_round},{self.frac:.0%})"
