"""Device-state layer: availability, latency, battery, partial work.

The arrival processes (``repro.scenarios.arrivals``) say *when* a client
starts training; this module models what the device does to the update
after that (docs/ROBUSTNESS.md):

* **availability** — ``MarkovAvailability`` is a continuous-time on/off
  chain (FLGo's system-simulator idiom): exponentially distributed on-
  and off-periods, clients started in the stationary distribution.
  Recorded availability windows replay through the existing
  ``TraceReplay`` / ``trace:<path>`` grammar unchanged;
* **network latency** — a ``LatencyModel`` delays the *delivery* of a
  finished update, so staleness becomes latency-coupled: a straggling
  uplink can push an update into the next round.  The pre-latency finish
  time is stamped as ``Update.sent_at``, which is what the adaptive
  deadline trigger (``serve.triggers.AdaptiveTimeWindow``) learns from;
* **battery / dropout mid-round** — with probability ``drop_prob`` a
  scheduled local round dies before uploading; the stream emits a
  ``client-dropped`` telemetry event and the client returns after
  ``recovery_gap`` plus its arrival process's think time;
* **partial local work** — with probability ``partial_prob`` the client
  finishes only ``completed_fraction ∈ partial_range`` of its local
  epochs; the update uploads early, flagged so the server can scale its
  Eq. §3.4 weight by the completed share.

RNG contract (the bit-identity parity gate in ``tests/test_device.py``
rests on it): a ``DeviceStateModel`` with ``drop_prob = partial_prob =
0`` and ``latency = None`` consumes **zero** draws from the caller's
Generator, so an all-complete device-state run replays the exact RNG
stream — and therefore the exact update stream — of a run with no
device model at all.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

import numpy as np

from .arrivals import ArrivalProcess


# ------------------------------------------------------------------ latency
class LatencyModel:
    """Uplink delivery-latency distribution; draws only from the caller's
    Generator (same purity contract as ``ArrivalProcess``)."""

    def sample(self, cid: int, rng: np.random.Generator) -> float:
        raise NotImplementedError

    def describe(self) -> str:
        return type(self).__name__


@dataclass
class LognormalLatency(LatencyModel):
    """Heavy-tailed uplink latency: ``median · exp(sigma·Z)``, Z ~ N(0,1).

    The classic wireless-uplink shape — most deliveries cluster near the
    median with a long slow tail (the stragglers adaptive deadlines are
    for).
    """

    median: float = 1.0
    sigma: float = 0.5

    def __post_init__(self):
        if self.median < 0:
            raise ValueError(f"median must be >= 0, got {self.median}")
        if self.sigma < 0:
            raise ValueError(f"sigma must be >= 0, got {self.sigma}")

    def sample(self, cid, rng):
        return self.median * float(np.exp(self.sigma * rng.standard_normal()))

    def describe(self):
        return f"lognormal(median={self.median:g},sigma={self.sigma:g})"


@dataclass
class BimodalLatency(LatencyModel):
    """Two-population latency: WiFi-fast vs cellular-slow uplinks.

    A fraction ``slow_prob`` of deliveries draw around ``slow``, the rest
    around ``fast``; both modes carry multiplicative U(1−jitter, 1+jitter)
    noise.
    """

    fast: float = 0.5
    slow: float = 8.0
    slow_prob: float = 0.2
    jitter: float = 0.3

    def __post_init__(self):
        if not 0.0 <= self.slow_prob <= 1.0:
            raise ValueError(f"slow_prob must be in [0,1], got {self.slow_prob}")
        if not 0.0 <= self.jitter < 1.0:
            raise ValueError(f"jitter must be in [0,1), got {self.jitter}")
        if self.fast < 0 or self.slow < 0:
            raise ValueError("latency modes must be >= 0")

    def sample(self, cid, rng):
        base = self.slow if rng.random() < self.slow_prob else self.fast
        return base * float(rng.uniform(1.0 - self.jitter, 1.0 + self.jitter))

    def describe(self):
        return (f"bimodal(fast={self.fast:g},slow={self.slow:g},"
                f"p_slow={self.slow_prob:g})")


# ------------------------------------------------------------- availability
@dataclass
class MarkovAvailability(ArrivalProcess):
    """Continuous-time on/off availability chain.

    Each client alternates Exp(``mean_on``) available periods with
    Exp(``mean_off``) unavailable ones; first states draw from the
    stationary distribution P(on) = mean_on / (mean_on + mean_off), so
    the population is statistically steady from t = 0.  While inside an
    on-period a client behaves always-on (restarts immediately); once the
    period ends, the chain walks off/on alternations until an on-period
    reaches past the finish time.
    """

    mean_on: float = 50.0
    mean_off: float = 20.0
    _until: Dict[int, float] = field(default_factory=dict, repr=False)

    def __post_init__(self):
        if self.mean_on <= 0 or self.mean_off <= 0:
            raise ValueError(
                f"mean_on/mean_off must be > 0, got "
                f"({self.mean_on}, {self.mean_off})")

    def start(self, n, rng):
        p_on = self.mean_on / (self.mean_on + self.mean_off)
        on = rng.random(n) < p_on
        # a fixed draw count regardless of the state vector keeps the
        # trace a pure function of the seed (replay determinism)
        off_residual = rng.exponential(self.mean_off, n)
        starts = np.where(on, 0.0, off_residual)
        untils = starts + rng.exponential(self.mean_on, n)
        self._until = {cid: float(untils[cid]) for cid in range(n)}
        return starts

    def next_start(self, cid, finished_at, rng):
        until = self._until.get(cid, 0.0)
        if finished_at < until:
            return finished_at  # still inside the on-period
        t = until
        while True:  # walk the chain: off-period, then on-period
            t += rng.exponential(self.mean_off)
            on_end = t + rng.exponential(self.mean_on)
            if on_end > finished_at:
                self._until[cid] = on_end
                return max(t, finished_at)
            t = on_end

    def describe(self):
        return f"markov(on={self.mean_on:g},off={self.mean_off:g})"


# ------------------------------------------------------------- device state
@dataclass
class DeviceStateModel:
    """Per-round device behavior attached to a ``Scenario`` (tentpole of
    docs/ROBUSTNESS.md; see the module docstring for the semantics and
    the zero-draw RNG contract).

    ``round_outcome`` is drawn once per *scheduled* local round, at
    schedule time — the engines fold the outcome into the round's finish
    time so event ordering stays monotone.
    """

    drop_prob: float = 0.0          # P(device dies mid-round)
    partial_prob: float = 0.0       # P(update uploads with cf < 1)
    partial_range: Tuple[float, float] = (0.3, 0.9)
    latency: Optional[LatencyModel] = None
    recovery_gap: float = 0.0       # extra off-time after a mid-round death

    def __post_init__(self):
        if not 0.0 <= self.drop_prob <= 1.0:
            raise ValueError(f"drop_prob must be in [0,1], got {self.drop_prob}")
        if not 0.0 <= self.partial_prob <= 1.0:
            raise ValueError(
                f"partial_prob must be in [0,1], got {self.partial_prob}")
        lo, hi = self.partial_range
        if not 0.0 < lo <= hi <= 1.0:
            raise ValueError(
                f"partial_range must satisfy 0 < lo <= hi <= 1, "
                f"got {self.partial_range}")
        if self.recovery_gap < 0:
            raise ValueError(
                f"recovery_gap must be >= 0, got {self.recovery_gap}")

    @property
    def trivial(self) -> bool:
        """True when the model cannot alter a run (the zero-draw case)."""
        return (self.drop_prob == 0.0 and self.partial_prob == 0.0
                and self.latency is None)

    def round_outcome(self, cid: int,
                      rng: np.random.Generator) -> Tuple[bool, float]:
        """(dropped, completed_fraction) for one scheduled local round.

        Guarded so that a zero probability consumes zero draws — the
        bit-identity contract above.
        """
        if self.drop_prob > 0.0 and rng.random() < self.drop_prob:
            return True, 0.0
        if self.partial_prob > 0.0 and rng.random() < self.partial_prob:
            lo, hi = self.partial_range
            return False, float(lo + (hi - lo) * rng.random())
        return False, 1.0

    def sample_latency(self, cid: int, rng: np.random.Generator) -> float:
        """Uplink delivery latency for one finished round (0 without a
        latency model — and no draw, per the contract)."""
        if self.latency is None:
            return 0.0
        return max(0.0, float(self.latency.sample(cid, rng)))

    def describe(self) -> str:
        parts = []
        if self.drop_prob > 0:
            parts.append(f"drop={self.drop_prob:g}")
        if self.partial_prob > 0:
            lo, hi = self.partial_range
            parts.append(f"partial={self.partial_prob:g}@[{lo:g},{hi:g}]")
        if self.latency is not None:
            parts.append(f"lat={self.latency.describe()}")
        if self.recovery_gap > 0:
            parts.append(f"recover={self.recovery_gap:g}")
        return "device(" + ",".join(parts) + ")" if parts else "device(off)"
