"""Virtual federated data for the cohort fast path.

At 10k+ clients, materializing per-client datasets (the
``FederatedData`` path) costs O(N) arrays before the first round runs.
The cohort fast path instead keeps only the *generating law*: C class
templates in feature space plus each client's label distribution
π_i (from the population's Dirichlet skew).  Minibatches are sampled on
demand, vectorized over the whole cohort — one inverse-CDF gather per
round, no per-client Python.

This is the same class-conditional Gaussian construction as
``repro.data.synthetic.synth_adult``/``synth_cifar10`` (template + noise,
learnable by the small models), so accuracy numbers are comparable
across the two paths even though clients never own a fixed sample set.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Tuple

import numpy as np


@dataclass
class VirtualTaskData:
    """Class-template task: x = template[y] + N(0, noise²)."""

    templates: np.ndarray          # f32[C, d]
    noise: float
    test_x: np.ndarray             # f32[T, d]
    test_y: np.ndarray             # i32[T]

    @property
    def n_labels(self) -> int:
        return self.templates.shape[0]

    @property
    def n_features(self) -> int:
        return self.templates.shape[1]

    @staticmethod
    def make(n_labels: int = 10, n_features: int = 14, *, noise: float = 1.0,
             n_test: int = 512, seed: int = 0) -> "VirtualTaskData":
        rng = np.random.default_rng(seed)
        templates = rng.normal(0, 1, (n_labels, n_features)).astype(np.float32)
        test_y = rng.integers(0, n_labels, n_test).astype(np.int32)
        test_x = templates[test_y] + rng.normal(0, noise, (n_test, n_features)).astype(np.float32)
        return VirtualTaskData(templates, noise, test_x, test_y)

    def sample_cohort_batches(
        self,
        label_probs: np.ndarray,   # f32[B, C] — the cohort rows of the skew
        n_epochs: int,
        batch_size: int,
        rng: np.random.Generator,
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Vectorized draw of [B, E, bs] labeled samples.

        Labels come from each client's π_i by inverse CDF; features are
        template + Gaussian noise.  Costs one [B,E,bs,C] comparison and
        one gather — no loop over clients.
        """
        B = label_probs.shape[0]
        cdf = np.cumsum(label_probs.astype(np.float64), axis=1)   # [B, C]
        cdf[:, -1] = 1.0                                          # guard fp drift
        u = rng.random((B, n_epochs, batch_size))
        y = (u[..., None] > cdf[:, None, None, :]).sum(-1).astype(np.int32)
        x = self.templates[y] + rng.normal(
            0, self.noise, (B, n_epochs, batch_size, self.n_features)
        ).astype(np.float32)
        return x, y
