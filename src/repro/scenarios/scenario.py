"""The ``Scenario`` object: population × arrivals × dynamic events.

A scenario fully describes a simulated environment:

* **who** the clients are (``population`` — speed/quantity/label skew);
* **when** they are available (``arrivals`` — Poisson/diurnal/burst/trace;
  ``None`` keeps the engine's legacy always-on loop, bit-identical to
  the pre-scenario engine);
* **what changes** mid-run (``events`` — churn, speed shifts, drift;
  the paper-§5.3 scenarios are one event each).

``SAFLEngine(..., scenario=...)`` consumes it directly; the old
``dynamics=`` callback is auto-wrapped via ``Scenario.from_dynamics``.
The named catalog lives in ``repro.scenarios.catalog`` and is documented
knob-by-knob in docs/SCENARIOS.md.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional, Sequence, Tuple

import numpy as np

from .arrivals import ArrivalProcess
from .device import DeviceStateModel
from .events import CallbackEvent, DynamicEvent
from .population import Population


@dataclass
class Scenario:
    name: str = "static"
    population: Optional[Population] = None
    arrivals: Optional[ArrivalProcess] = None
    events: Sequence[DynamicEvent] = ()
    # how devices misbehave after they start a round: mid-round dropout,
    # partial local work, uplink latency (docs/ROBUSTNESS.md)
    device: Optional[DeviceStateModel] = None
    description: str = ""

    # ------------------------------------------------------------- factories
    @staticmethod
    def from_dynamics(fn: Callable, name: str = "dynamics-shim") -> "Scenario":
        """Wrap a legacy ``dynamics(round, speeds, rng)`` callback.  The
        resulting scenario consumes exactly the same RNG draws, so engine
        runs are bit-identical to the callback path."""
        return Scenario(name=name, events=(CallbackEvent(fn),))

    # ------------------------------------------------------------ population
    def sample_speeds(self, n: int, rng: np.random.Generator,
                      default_ratio: float = 50.0) -> np.ndarray:
        """Cohort speeds; without a population model this is the engine's
        historic uniform 1:ratio draw (same single ``rng.uniform`` call)."""
        if self.population is not None:
            return self.population.sample_speeds(n, rng)
        return rng.uniform(1.0, default_ratio, n)

    # ---------------------------------------------------------------- events
    def apply_events(self, rnd: int, speeds: np.ndarray,
                     rng: np.random.Generator) -> Optional[np.ndarray]:
        """Chain every event's speed mutation for this round.  Returns the
        final speed array, or ``None`` when no event changed anything —
        the exact contract of the legacy ``dynamics`` callback."""
        current, changed = speeds, False
        for ev in self.events:
            out = ev.apply(rnd, current, rng)
            if out is not None:
                current, changed = out, True
        return current if changed else None

    def mutate_data(self, rnd: int, data, rng: np.random.Generator) -> None:
        for ev in self.events:
            ev.mutate_data(rnd, data, rng)

    # ------------------------------------------------------------------ misc
    @property
    def has_data_events(self) -> bool:
        return any(
            type(ev).mutate_data is not DynamicEvent.mutate_data
            for ev in self.events
        )

    def describe(self) -> str:
        parts = [self.name]
        if self.population is not None:
            parts.append(f"pop[{self.population.describe()}]")
        if self.arrivals is not None:
            parts.append(f"arr[{self.arrivals.describe()}]")
        if self.device is not None:
            parts.append(self.device.describe())
        if self.events:
            parts.append("ev[" + ", ".join(e.describe() for e in self.events) + "]")
        return " ".join(parts)
