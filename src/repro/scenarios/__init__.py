"""Scenario engine: trace-driven heterogeneous client populations.

Replaces the engine's bare ``dynamics`` callback with a composable
subsystem (docs/SCENARIOS.md is the catalog):

* **population models** — who the clients are: speed distributions
  (uniform / log-normal / bimodal / Zipf), quantity skew, Dirichlet
  label skew; vectorized and seed-deterministic;
* **arrival processes** — when they are available: always-on (legacy),
  Poisson, diurnal (sinusoidal rate), burst, and trace replay
  (CSV/JSONL ``client_id,t_arrival,t_compute``);
* **dynamic events** — what changes mid-run: the paper-§5.3 scenarios
  (resource shift / instability / dropout) plus join-leave churn,
  speed shifts, and label drift;
* the **cohort fast path** (``CohortEngine``) — same-round clients
  batched under ``vmap`` so 10k+ client simulations need no per-client
  Python loop.

``SAFLEngine(..., scenario=get_scenario("churn"))`` runs any of these
through the paper-faithful event-driven engine; ``repro.serve`` gets
scenario-driven load generation via ``scenario_stream``.
"""
from .arrivals import (
    AlwaysOn,
    ArrivalProcess,
    BurstArrivals,
    DiurnalArrivals,
    PoissonArrivals,
    TraceReplay,
)
from .catalog import SCENARIOS, get_scenario, list_scenarios
from .cohort import CohortEngine, make_cohort_trainer
from .device import (
    BimodalLatency,
    DeviceStateModel,
    LatencyModel,
    LognormalLatency,
    MarkovAvailability,
)
from .events import (
    CallbackEvent,
    Churn,
    Dropout,
    DynamicEvent,
    LabelDrift,
    ResourceScale,
    SpeedJitter,
    SpeedShift,
)
from .population import (
    BimodalSpeeds,
    Cohort,
    DirichletLabelSkew,
    LognormalSpeeds,
    Population,
    QuantitySkew,
    SpeedModel,
    UniformSpeeds,
    ZipfSpeeds,
)
from .scenario import Scenario
from .virtual_data import VirtualTaskData

__all__ = [
    "AlwaysOn", "ArrivalProcess", "BurstArrivals", "DiurnalArrivals",
    "PoissonArrivals", "TraceReplay",
    "SCENARIOS", "get_scenario", "list_scenarios",
    "CohortEngine", "make_cohort_trainer",
    "BimodalLatency", "DeviceStateModel", "LatencyModel",
    "LognormalLatency", "MarkovAvailability",
    "CallbackEvent", "Churn", "Dropout", "DynamicEvent", "LabelDrift",
    "ResourceScale", "SpeedJitter", "SpeedShift",
    "BimodalSpeeds", "Cohort", "DirichletLabelSkew", "LognormalSpeeds",
    "Population", "QuantitySkew", "SpeedModel", "UniformSpeeds", "ZipfSpeeds",
    "Scenario", "VirtualTaskData",
]
