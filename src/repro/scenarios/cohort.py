"""Vectorized cohort fast path: 10k+ client SAFL without per-client Python.

``SAFLEngine`` is event-driven: every local-training burst is one Python
heap event and one jitted grad call — perfect fidelity, O(N) Python work
per round.  At the ROADMAP's "millions of users" regime that loop is the
bottleneck, not the math.  ``CohortEngine`` keeps the SAFL semantics —
K-buffer trigger, staleness from late fetches, Mod-1/2/3 — but processes
each aggregation round as one *cohort*: the K clients whose virtual
finish times land in the round's window, trained as a single ``vmap``
batch and pushed through the same ``StreamingAggregator`` the
event-driven engine uses.

Approximations (documented in docs/SCENARIOS.md "Cohort fast path"):

* all cohort members start local training from the *newest* global
  model; staleness is still tracked per client (from each one's actual
  start time against the fire history) and still feeds Mod-3 weighting
  and metrics, but stale *parameters* are not replayed;
* Mod-1 similarity is computed against the shared (current − previous)
  pseudo-global gradient, vectorized over the cohort;
* Mod-2 runs in its branch-free vector form (``repro.core.classify``)
  with the SSBC situation detector defaulting to Situation 1 (there is
  no per-client validation set — data is virtual).

Everything else — the status table, feedback weighting, the trigger and
admission pipeline, round reports — is the production service code path.
"""
from __future__ import annotations

import time as _time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.classify import adapt
from repro.core.safl import EngineResult, ModelSpec
from repro.core.similarity import local_global_similarity
from repro.core.types import (
    FedQSHyperParams,
    RoundMetrics,
    Update,
    tree_clip_by_global_norm,
    tree_sub,
    tree_zeros_like,
)
from repro.telemetry import ClientClassified, RoundMetricsEvent
from .population import Population, UniformSpeeds
from .scenario import Scenario
from .virtual_data import VirtualTaskData


def make_cohort_trainer(grad_fn, n_epochs: int, grad_clip: float, similarity: str):
    """Build the jitted, vmapped cohort step.

    One call trains B clients for E local epochs from the shared start
    params (Eq. 3 momentum recursion, per-client lr/momentum), and
    returns stacked end params, stacked deltas (w_start − w_end), and
    Mod-1 similarities against the pseudo-global gradient.
    """

    def train_one(w, xs, ys, lr, mom):
        v = tree_zeros_like(w)
        for e in range(n_epochs):
            g = grad_fn(w, {"x": xs[e], "y": ys[e]})
            g = tree_clip_by_global_norm(g, grad_clip)
            v = jax.tree_util.tree_map(lambda g_, v_: g_ + mom * v_, g, v)
            w = jax.tree_util.tree_map(lambda w_, v_: w_ - lr * v_, w, v)
        return w

    @jax.jit
    def cohort_step(w_global, w_prev, xs, ys, lr, mom):
        w_end = jax.vmap(train_one, in_axes=(None, 0, 0, 0, 0))(
            w_global, xs, ys, lr, mom
        )
        delta = jax.tree_util.tree_map(lambda we, ws: ws - we, w_end, w_global)
        pg = tree_sub(w_global, w_prev)
        sims = jax.vmap(
            lambda d: local_global_similarity(
                jax.tree_util.tree_map(jnp.negative, d), pg, similarity
            )
        )(delta)
        return w_end, delta, sims

    return cohort_step


class CohortEngine:
    """Scenario-driven SAFL at scale (see module docstring).

    The server side is a ``StreamingAggregator`` with the paper's
    K-buffer trigger and the batched stacked aggregation path, exactly
    as the event-driven engine uses it — one server code path at every
    scale.
    """

    def __init__(
        self,
        scenario: Scenario,
        n_clients: int,
        *,
        hp: Optional[FedQSHyperParams] = None,
        spec: Optional[ModelSpec] = None,
        task: Optional[VirtualTaskData] = None,
        algo=None,
        seed: int = 0,
        cohort_k: Optional[int] = None,
        eval_every: int = 1,
        resource_ratio: float = 50.0,
        compress: Optional[str] = None,
        topology=None,
        telemetry=None,
    ):
        if scenario.has_data_events:
            # cohort data is virtual (a generating law, not per-client
            # arrays), so FederatedData-mutating events cannot apply —
            # refuse rather than silently run the scenario minus its drift
            raise ValueError(
                f"scenario {scenario.name!r} carries data-mutating events "
                "(e.g. LabelDrift), which the cohort fast path cannot apply "
                "to virtual data — run it through SAFLEngine instead"
            )
        self.scenario = scenario
        self.hp = hp or FedQSHyperParams()
        self.rng = np.random.default_rng(seed)
        self.eval_every = eval_every
        n = int(n_clients)
        self.n_clients = n
        self.cohort_k = int(cohort_k or self.hp.buffer_k)

        # without a population model, mirror SAFLEngine's default uniform
        # 1:resource_ratio spread so engine configs port over unchanged
        pop = scenario.population or Population(
            speeds=UniformSpeeds(1.0, resource_ratio)
        )
        cohort = pop.sample(n, self.rng)
        # scenario speeds win over the raw population draw only in the
        # sense that the scenario *is* the population; keep the arrays
        self.speeds = cohort.speeds
        self.n_samples = cohort.n_samples
        self.label_probs = cohort.label_probs

        self.task = task or VirtualTaskData.make(
            n_labels=self.label_probs.shape[1], seed=seed
        )
        if spec is None:
            from repro.models.small import make_mlp_spec

            spec = make_mlp_spec(
                n_features=self.task.n_features, n_classes=self.task.n_labels
            )
        self.spec = spec

        from repro.core.algorithms import make_algorithm
        from repro.serve.triggers import KBuffer

        self.algo = algo or make_algorithm("fedqs-sgd", self.hp)
        key = jax.random.PRNGKey(seed)
        # with a topology, the server side is the tiered aggregation
        # plane: edge assignment is derived from the sampled population
        # (speed bands → regions, label clusters → edges), and the global
        # K-buffer counts client updates through the partial member view,
        # so the cohort round cadence is unchanged (docs/HIERARCHY.md)
        from repro.hier import make_aggregation_service

        self.service = make_aggregation_service(
            self.algo, self.hp, spec.init(key), n,
            topology=topology,
            trigger=KBuffer(self.cohort_k),
            context=self,
            speeds=self.speeds,
            label_probs=self.label_probs,
            batched=True,
            telemetry=telemetry,
        )
        # telemetry (docs/OBSERVABILITY.md): the service publishes the
        # serve-layer events; the cohort engine adds the vectorized Mod-2
        # classifications and per-round evaluation metrics
        self.telemetry = telemetry
        if telemetry is not None:
            from repro.core.types import Quadrant

            self._tm_quadrants = {
                int(q): telemetry.metrics.gauge(
                    f"engine.quadrant_{q.name.lower()}",
                    unit="clients", layer="scenarios")
                for q in Quadrant
            }
        # compressed transport: deltas (or models) are encoded per virtual
        # client under vmap before submission; the service's batched path
        # aggregates the quantized rows through the fused dequant_agg kernel
        self.compressor = None
        if compress is not None and compress != "none":
            from repro.compress import ClientCompressor

            self.compressor = ClientCompressor(compress, n, seed=seed)
            self.compressor.telemetry = telemetry
            self.service.compressor = self.compressor
        # Algorithm facade (server_aggregate reads ctx.data.n_clients)
        from types import SimpleNamespace

        self.data = SimpleNamespace(n_clients=n)

        self._trainer = make_cohort_trainer(
            spec.grad_fn, self.hp.local_epochs, self.hp.grad_clip,
            self.hp.similarity,
        )
        self._prev_global = self.service.global_params

        # per-client vector state
        self.alive = np.ones(n, bool)
        self.lr = np.full(n, self.hp.eta0, np.float32)
        self.momentum = np.full(n, self.hp.m0, np.float32)
        self.last_sim = np.zeros(n, np.float32)
        self.quadrant = np.full(n, 2, np.int32)  # SWBC default, like ClientState
        arr = scenario.arrivals
        if arr is not None:
            self.started_at = arr.start(n, self.rng)
        else:
            self.started_at = np.zeros(n)
        # first-burst durations: the engine's desynchronizing 0.5–1.5 jitter,
        # with the arrival process able to pin them (trace-replayed compute)
        defaults = self.speeds * self.rng.uniform(0.5, 1.5, n)
        if arr is not None:
            finite = np.flatnonzero(np.isfinite(self.started_at))
            for cid in finite:
                defaults[cid] = arr.compute_time(
                    int(cid), float(self.started_at[cid]),
                    float(defaults[cid]), self.rng,
                )
        self.next_finish = self.started_at + defaults
        self.next_finish[~np.isfinite(self.started_at)] = np.inf
        # device-state layer (docs/ROBUSTNESS.md): each scheduled local
        # round's outcome — mid-round death, partial work, uplink latency —
        # is drawn once at schedule time and folded into next_finish, so the
        # virtual clock stays monotone; a trivial model draws nothing and
        # the run is bit-identical to a device-free one
        self.device = getattr(scenario, "device", None)
        self._pending_drop = np.zeros(n, bool)
        self._pending_cf = np.ones(n, np.float32)
        self._pending_sent = np.full(n, -1.0)
        if self.device is not None:
            for cid in np.flatnonzero(np.isfinite(self.started_at)):
                cid = int(cid)
                compute = float(self.next_finish[cid] - self.started_at[cid])
                self.next_finish[cid] = self._device_finish(
                    cid, float(self.started_at[cid]), compute)
        self._fire_times: List[float] = []

    # --------------------------------------------------- server-state facade
    @property
    def global_params(self):
        return self.service.global_params

    @property
    def table(self):
        return self.service.table

    @property
    def round(self) -> int:
        return self.service.round

    # ---------------------------------------------------------------- driver
    def run(self, n_rounds: int) -> EngineResult:
        t0 = _time.perf_counter()
        metrics: List[RoundMetrics] = []
        K = self.cohort_k
        while self.round < n_rounds:
            self._drain_drops()
            ready = (self.alive & np.isfinite(self.next_finish)
                     & ~self._pending_drop)
            if ready.sum() < K:
                break
            vt, report = self._one_round(np.flatnonzero(ready), K)
            if self.round % self.eval_every == 0 or self.round == n_rounds:
                metrics.append(self._metrics(vt, report))
            self._apply_events(vt)
        return EngineResult(metrics, _time.perf_counter() - t0,
                            self.service.global_params)

    def _one_round(self, ready: np.ndarray, K: int):
        # cohort = the K earliest finishers (ties break by client id)
        finish = self.next_finish[ready]
        order = np.lexsort((ready, finish))[:K]
        cohort = ready[order]
        finish = finish[order]
        vt = float(finish[-1])

        # Mod-2, vectorized over the cohort (FedQS adaptation; base
        # algorithms keep constant lr / zero momentum, like the zoo)
        counts = np.asarray(self.table.counts)
        f_all = counts / max(counts.sum(), 1)
        from repro.core.algorithms import FedQS

        if isinstance(self.algo, FedQS):
            d = adapt(
                jnp.asarray(f_all[cohort], jnp.float32),
                float(f_all.mean()),
                jnp.asarray(self.last_sim[cohort], jnp.float32),
                float(np.asarray(self.table.sims).mean()),
                jnp.asarray(self.lr[cohort], jnp.float32),
                self.hp,
            )
            lr_c = np.asarray(d.lr, np.float32)
            mom_c = np.asarray(d.momentum, np.float32)
            fb_c = np.asarray(d.feedback, bool)
            self.quadrant[cohort] = np.asarray(d.quadrant, np.int32)
        else:
            lr_c = np.full(K, self.hp.eta0, np.float32)
            mom_c = np.zeros(K, np.float32)
            fb_c = np.zeros(K, bool)
        self.lr[cohort] = lr_c
        self.momentum[cohort] = mom_c
        if self.telemetry is not None:
            # member-level classification events, mirroring the event
            # engine's per-fetch emission (vectorized adapt, scalar emits)
            for i in range(K):
                self.telemetry.emit(ClientClassified(
                    t=float(finish[i]), round=self.round,
                    cid=int(cohort[i]), quadrant=int(self.quadrant[cohort[i]]),
                    lr=float(lr_c[i]), momentum=float(mom_c[i]),
                    feedback=bool(fb_c[i]),
                ))

        # vmapped local training on virtual data
        xs, ys = self.task.sample_cohort_batches(
            self.label_probs[cohort], self.hp.local_epochs,
            self.spec.batch_size, self.rng,
        )
        w_global = self.service.global_params
        w_end, delta, sims = self._trainer(
            w_global, self._prev_global, jnp.asarray(xs), jnp.asarray(ys),
            jnp.asarray(lr_c), jnp.asarray(mom_c),
        )
        sims = np.asarray(sims, np.float32)
        if not self._fire_times:
            sims = np.zeros_like(sims)  # no pseudo-global gradient yet
        self.last_sim[cohort] = sims

        # staleness: the round each client's burst actually started in
        fetch_rounds = np.searchsorted(
            np.asarray(self._fire_times), self.started_at[cohort], side="right"
        )

        # submit in finish order through the service (K-th submit fires)
        report = None
        self._prev_global = w_global
        enc_delta = enc_params = None
        if self.compressor is not None:
            # encode the whole cohort in one vmap call; only the payload
            # the algorithm's strategy aggregates crosses the wire
            from repro.compress import ravel_flat_batch
            from repro.core.types import AggregationStrategy

            if getattr(self.algo, "strategy", None) is AggregationStrategy.MODEL:
                enc_params = self.compressor.encode_params_flat_batch(
                    ravel_flat_batch(w_end))
            else:
                enc_delta = self.compressor.encode_flat_batch(
                    cohort, ravel_flat_batch(delta))
            from repro.compress import CompressedUpdate
        for i in range(K):
            cid = int(cohort[i])
            meta = dict(
                cid=cid,
                n_samples=int(self.n_samples[cid]),
                stale_round=int(fetch_rounds[i]),
                lr=float(lr_c[i]),
                similarity=float(sims[i]),
                feedback=bool(fb_c[i]),
                speed_f=float(f_all[cid]),
            )
            if self.device is not None:
                # partial work scales the server-side weight only — the
                # vmapped trainer still ran full local epochs (documented
                # cohort approximation, docs/ROBUSTNESS.md)
                meta.update(
                    completed_fraction=float(self._pending_cf[cid]),
                    sent_at=float(self._pending_sent[cid]),
                )
            if self.compressor is not None:
                u = CompressedUpdate(
                    **meta,
                    delta=enc_delta[i] if enc_delta is not None else None,
                    params=enc_params[i] if enc_params is not None else None,
                )
            else:
                u = Update(
                    **meta,
                    delta=jax.tree_util.tree_map(lambda l, i=i: l[i], delta),
                    params=jax.tree_util.tree_map(lambda l, i=i: l[i], w_end),
                )
            res = self.service.submit(u, now=float(finish[i]))
            if res.fired:
                report = res.report
        assert report is not None, "K cohort submits must fire the K-buffer"
        self._fire_times.append(vt)

        # reschedule the cohort
        arr = self.scenario.arrivals
        for i in range(K):
            cid = int(cohort[i])
            t_fin = float(finish[i])
            nxt = arr.next_start(cid, t_fin, self.rng) if arr is not None else t_fin
            self._schedule(cid, nxt, arr)
        return vt, report

    def _schedule(self, cid: int, start: float, arr) -> None:
        if not np.isfinite(start):
            self.next_finish[cid] = np.inf
            return
        default = float(self.speeds[cid]) * self.rng.uniform(0.9, 1.1)
        compute = arr.compute_time(cid, start, default, self.rng) if arr is not None else default
        self.started_at[cid] = start
        if self.device is None:
            self.next_finish[cid] = start + compute
        else:
            self.next_finish[cid] = self._device_finish(cid, start, compute)

    def _device_finish(self, cid: int, start: float, compute: float) -> float:
        """Draw the device outcome for a planned round; returns the event's
        pop time (death time for a drop, delivery time otherwise)."""
        dev = self.device
        dropped, cf = dev.round_outcome(cid, self.rng)
        self._pending_drop[cid] = dropped
        self._pending_cf[cid] = cf
        if dropped:
            t_death = start + self.rng.uniform(0.0, 1.0) * compute
            self._pending_sent[cid] = t_death
            return t_death
        sent = start + cf * compute
        self._pending_sent[cid] = sent
        return sent + dev.sample_latency(cid, self.rng)

    def _drain_drops(self) -> None:
        """Process every pending mid-round death before cohort selection:
        emit the drop event at its death time and reschedule the client
        through recovery + its arrival law (re-drawn rounds may drop again,
        hence the loop; a bound guards drop_prob≈1 pathologies)."""
        dev = self.device
        if dev is None:
            return
        arr = self.scenario.arrivals
        from repro.telemetry import ClientDropped

        for _ in range(64):
            idx = np.flatnonzero(
                self.alive & np.isfinite(self.next_finish) & self._pending_drop)
            if idx.size == 0:
                return
            for cid in idx:
                cid = int(cid)
                t_death = float(self.next_finish[cid])
                if self.telemetry is not None:
                    self.telemetry.emit(ClientDropped(
                        t=t_death, round=self.round, cid=cid, reason="battery"))
                self._pending_drop[cid] = False
                restart = t_death + dev.recovery_gap
                nxt = (arr.next_start(cid, restart, self.rng)
                       if arr is not None else restart)
                self._schedule(cid, nxt, arr)

    def _apply_events(self, vt: float) -> None:
        new_speeds = self.scenario.apply_events(self.round, self.speeds, self.rng)
        if new_speeds is None:
            return
        was_dead = ~self.alive
        self.speeds = new_speeds
        finite = np.isfinite(new_speeds)
        died = self.alive & ~finite
        self.alive[died] = False
        self.next_finish[died] = np.inf
        revived = np.flatnonzero(was_dead & finite)
        arr = self.scenario.arrivals
        for cid in revived:
            self.alive[cid] = True
            nxt = arr.next_start(int(cid), vt, self.rng) if arr is not None else vt
            self._schedule(int(cid), nxt, arr)

    def _metrics(self, vt: float, report) -> RoundMetrics:
        loss, acc = self.spec.eval_fn(
            self.service.global_params, self.task.test_x, self.task.test_y
        )
        qc: Dict[str, int] = {}
        vals, cnts = np.unique(self.quadrant[self.alive], return_counts=True)
        for v, c in zip(vals, cnts):
            qc[str(int(v))] = int(c)
        stale = [self.round - 1 - u.stale_round for u in report.buffer]
        m = RoundMetrics(
            round=self.round,
            virtual_time=vt,
            loss=float(loss),
            accuracy=float(acc),
            n_stale=sum(1 for s in stale if s > 0),
            mean_staleness=float(np.mean(stale)) if stale else 0.0,
            quadrant_counts=qc,
        )
        if self.telemetry is not None:
            for q, gauge in self._tm_quadrants.items():
                gauge.set(qc.get(str(q), 0))
            self.telemetry.emit(RoundMetricsEvent(
                t=float(vt), round=m.round, loss=m.loss, accuracy=m.accuracy,
                n_stale=m.n_stale, mean_staleness=m.mean_staleness,
                quadrant_counts=dict(qc),
            ))
            if self.telemetry.health is not None:
                self.telemetry.health.observe_metrics(
                    t=float(vt), round=m.round, loss=m.loss,
                    accuracy=m.accuracy, quadrant_counts=qc)
        return m
