"""Arrival processes: when is each client available to train?

The virtual-clock engine historically assumed *always-on* clients — every
client starts its next local round the instant the previous one finishes.
Real federated populations are intermittently available (devices charge,
users sleep, networks drop), and SAFL behavior depends heavily on the
arrival law (SEAFL, arXiv:2503.05755).  An ``ArrivalProcess`` decides the
next *start* time of a client; the client's speed (plus jitter, or a
trace-provided compute time) decides when the resulting update lands.

Contract — every method draws only from the caller's Generator, so the
full event trace is a pure function of the seed:

* ``start(n, rng)``            → f64[N] first start times (vectorized);
* ``next_start(cid, t, rng)``  → next start strictly after finishing at
  ``t`` (``inf`` = the client never returns);
* ``compute_time(cid, t, default, rng)`` → local-round duration
  (traces override it; synthetic processes keep the engine's default).
"""
from __future__ import annotations

import csv
import json
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


class ArrivalProcess:
    def start(self, n: int, rng: np.random.Generator) -> np.ndarray:
        raise NotImplementedError

    def next_start(self, cid: int, finished_at: float, rng: np.random.Generator) -> float:
        raise NotImplementedError

    def compute_time(self, cid: int, started_at: float, default: float,
                     rng: np.random.Generator) -> float:
        return default

    def describe(self) -> str:
        return type(self).__name__


@dataclass
class AlwaysOn(ArrivalProcess):
    """The legacy regime: clients re-start immediately after finishing."""

    def start(self, n, rng):
        return np.zeros(n)

    def next_start(self, cid, finished_at, rng):
        return finished_at

    def describe(self):
        return "always-on"


@dataclass
class PoissonArrivals(ArrivalProcess):
    """Homogeneous Poisson availability: think time ~ Exp(mean_gap).

    ``mean_gap`` is in virtual-clock units (the same units as client
    speeds); a gap of 0 degenerates to always-on.
    """

    mean_gap: float = 10.0

    def start(self, n, rng):
        return rng.exponential(self.mean_gap, n)

    def next_start(self, cid, finished_at, rng):
        return finished_at + rng.exponential(self.mean_gap)

    def describe(self):
        return f"poisson(gap={self.mean_gap:g})"


@dataclass
class DiurnalArrivals(ArrivalProcess):
    """Inhomogeneous Poisson with a sinusoidal day/night rate:

        λ(t) = (1/mean_gap) · (1 + amplitude · sin(2πt/period))

    sampled by Ogata thinning against λ_max.  ``amplitude`` ∈ [0, 1);
    at amplitude → 1 the trough rate approaches zero (deep night).
    """

    mean_gap: float = 10.0
    period: float = 200.0
    amplitude: float = 0.8
    phase: float = 0.0

    def _rate(self, t: float) -> float:
        return (1.0 / self.mean_gap) * (
            1.0 + self.amplitude * np.sin(2.0 * np.pi * (t / self.period) + self.phase)
        )

    def _thin(self, t: float, rng: np.random.Generator) -> float:
        lam_max = (1.0 + self.amplitude) / self.mean_gap
        for _ in range(10_000):
            t += rng.exponential(1.0 / lam_max)
            if rng.random() * lam_max <= self._rate(t):
                return t
        return t  # pathological amplitude≈1 troughs: accept the last point

    def start(self, n, rng):
        # vectorized first arrivals: thin a stacked candidate block, falling
        # back to the scalar loop only for clients that never accepted
        lam_max = (1.0 + self.amplitude) / self.mean_gap
        t = np.zeros(n)
        pending = np.arange(n)
        for _ in range(64):
            if len(pending) == 0:
                break
            t[pending] += rng.exponential(1.0 / lam_max, len(pending))
            # _rate is pure numpy algebra, so it broadcasts over the block
            accept = rng.random(len(pending)) * lam_max <= self._rate(t[pending])
            pending = pending[~accept]
        for cid in pending:  # deep-trough stragglers: keep thinning scalar
            t[cid] = self._thin(float(t[cid]), rng)
        return t

    def next_start(self, cid, finished_at, rng):
        return self._thin(finished_at, rng)

    def describe(self):
        return (f"diurnal(gap={self.mean_gap:g},period={self.period:g},"
                f"amp={self.amplitude:g})")


@dataclass
class BurstArrivals(ArrivalProcess):
    """Quiet Poisson traffic punctuated by synchronized bursts: every
    ``burst_every`` units, the next ``burst_len`` units run at
    ``quiet_gap/burst_factor`` think time (a flash crowd / synchronized
    wake-up, e.g. devices plugged in at 22:00)."""

    quiet_gap: float = 30.0
    burst_every: float = 150.0
    burst_len: float = 20.0
    burst_factor: float = 20.0

    def _gap(self, t: float) -> float:
        in_burst = (t % self.burst_every) < self.burst_len
        return self.quiet_gap / self.burst_factor if in_burst else self.quiet_gap

    def start(self, n, rng):
        return rng.exponential(self.quiet_gap / self.burst_factor, n) % self.burst_len

    def next_start(self, cid, finished_at, rng):
        return finished_at + rng.exponential(self._gap(finished_at))

    def describe(self):
        return (f"burst(quiet={self.quiet_gap:g},every={self.burst_every:g},"
                f"len={self.burst_len:g},x{self.burst_factor:g})")


@dataclass
class TraceReplay(ArrivalProcess):
    """Replay a recorded availability trace.

    ``events`` is a sequence of ``(client_id, t_arrival, t_compute)``
    tuples; loaders for CSV (header ``client_id,t_arrival,t_compute``)
    and JSONL (one object per line with those keys) are provided.  Each
    client consumes its own arrivals in time order; after the trace is
    exhausted the client never returns (inf).  ``t_compute`` ≤ 0 means
    "use the engine's synthetic compute time".
    """

    events: Sequence[Tuple[int, float, float]] = ()
    _by_client: Dict[int, List[Tuple[float, float]]] = field(default_factory=dict, repr=False)
    _cursor: Dict[int, int] = field(default_factory=dict, repr=False)
    _last_compute: Dict[int, float] = field(default_factory=dict, repr=False)

    def __post_init__(self):
        by: Dict[int, List[Tuple[float, float]]] = {}
        for cid, t_arr, t_cmp in self.events:
            t_arr = float(t_arr)
            if not np.isfinite(t_arr) or t_arr < 0.0:
                # a bad stamp would silently produce negative inter-arrival
                # gaps (or an event the cursor can never reach) — reject it
                # loudly and name the offending row
                raise ValueError(
                    f"trace event for client {int(cid)} has invalid "
                    f"t_arrival={t_arr!r} (must be finite and >= 0)")
            by.setdefault(int(cid), []).append((t_arr, float(t_cmp)))
        for cid in by:
            # stable sort on t_arrival ONLY: out-of-order rows are ordered
            # deterministically, and same-timestamp rows keep their trace
            # order instead of being reshuffled by the compute-time column
            by[cid].sort(key=lambda ev: ev[0])
        self._by_client = by
        self._cursor = {cid: 0 for cid in by}

    @staticmethod
    def from_csv(path: str) -> "TraceReplay":
        with open(path, newline="") as f:
            rows = list(csv.DictReader(f))
        return TraceReplay([
            (int(r["client_id"]), float(r["t_arrival"]), float(r.get("t_compute", 0) or 0))
            for r in rows
        ])

    @staticmethod
    def from_jsonl(path: str) -> "TraceReplay":
        events = []
        with open(path) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                o = json.loads(line)
                events.append((int(o["client_id"]), float(o["t_arrival"]),
                               float(o.get("t_compute", 0) or 0)))
        return TraceReplay(events)

    @staticmethod
    def from_file(path: str) -> "TraceReplay":
        if path.endswith(".jsonl") or path.endswith(".json"):
            return TraceReplay.from_jsonl(path)
        return TraceReplay.from_csv(path)

    def _advance(self, cid: int, after: float) -> float:
        q = self._by_client.get(cid)
        if not q:
            return float("inf")
        i = self._cursor.get(cid, 0)
        while i < len(q) and q[i][0] < after:
            i += 1
        if i >= len(q):
            self._cursor[cid] = i
            return float("inf")
        t_arr, t_cmp = q[i]
        self._cursor[cid] = i + 1
        self._last_compute[cid] = t_cmp
        return t_arr

    def start(self, n, rng):
        # a run always begins at t=0: rewind the cursors so one TraceReplay
        # (and therefore one trace Scenario) can drive any number of runs
        self._cursor = {cid: 0 for cid in self._by_client}
        self._last_compute = {}
        out = np.full(n, np.inf)
        for cid in range(n):
            out[cid] = self._advance(cid, 0.0)
        return out

    def next_start(self, cid, finished_at, rng):
        return self._advance(cid, finished_at)

    def compute_time(self, cid, started_at, default, rng):
        t = self._last_compute.get(cid, 0.0)
        return t if t > 0 else default

    def describe(self):
        n_ev = sum(len(v) for v in self._by_client.values())
        return f"trace({len(self._by_client)} clients, {n_ev} events)"
