"""The named scenario catalog (documented in docs/SCENARIOS.md).

``get_scenario(name)`` builds a fresh ``Scenario`` from the registry;
``trace:<path>`` replays a recorded availability trace (CSV/JSONL of
``client_id,t_arrival,t_compute``).  Every entry is a zero-argument
recipe with paper-calibrated defaults — pass keyword overrides through
``get_scenario`` to tweak (they are forwarded to the factory).
"""
from __future__ import annotations

from typing import Callable, Dict, List

from .arrivals import BurstArrivals, DiurnalArrivals, PoissonArrivals, TraceReplay
from .device import (
    BimodalLatency,
    DeviceStateModel,
    LognormalLatency,
    MarkovAvailability,
)
from .events import Churn, Dropout, LabelDrift, ResourceScale, SpeedJitter, SpeedShift
from .population import (
    BimodalSpeeds,
    DirichletLabelSkew,
    LognormalSpeeds,
    Population,
    QuantitySkew,
    ZipfSpeeds,
)
from .scenario import Scenario


def _static() -> Scenario:
    return Scenario(name="static", description="no dynamics — the paper's base SAFL setting")


def _resource_shift(at_round: int = 20, new_ratio: float = 100.0) -> Scenario:
    return Scenario(
        name="resource-shift",
        events=(ResourceScale(at_round, new_ratio),),
        description=f"paper §5.3 scenario 1: speed spread 1:50 → 1:{new_ratio:g} at round {at_round}",
    )


def _unstable(unit: float = 10.0) -> Scenario:
    return Scenario(
        name="unstable",
        events=(SpeedJitter(unit=unit),),
        description=f"paper §5.3 scenario 2: per-round ±{unit:g} resource fluctuation",
    )


def _dropout(at_round: int = 15, frac: float = 0.5) -> Scenario:
    return Scenario(
        name="dropout",
        events=(Dropout(at_round, frac),),
        description=f"paper §5.3 scenario 3: {frac:.0%} of clients leave at round {at_round}",
    )


def _churn(period: int = 10, frac: float = 0.2) -> Scenario:
    return Scenario(
        name="churn",
        events=(Churn(period, frac),),
        description=f"join/leave churn: every {period} rounds {frac:.0%} leave, the departed rejoin",
    )


def _diurnal(mean_gap: float = 20.0, period: float = 400.0, amplitude: float = 0.8) -> Scenario:
    return Scenario(
        name="diurnal",
        population=Population(speeds=LognormalSpeeds()),
        arrivals=DiurnalArrivals(mean_gap=mean_gap, period=period, amplitude=amplitude),
        description="log-normal device speeds, sinusoidal day/night availability",
    )


def _diurnal_churn(mean_gap: float = 20.0, period: float = 400.0,
                   churn_period: int = 10, churn_frac: float = 0.2) -> Scenario:
    return Scenario(
        name="diurnal-churn",
        population=Population(
            speeds=BimodalSpeeds(),
            quantity=QuantitySkew(),
            labels=DirichletLabelSkew(alpha=0.5),
        ),
        arrivals=DiurnalArrivals(mean_gap=mean_gap, period=period, amplitude=0.8),
        events=(Churn(churn_period, churn_frac),),
        description=("the 10k-scale headline: bimodal devices, diurnal arrivals, "
                     "periodic join/leave churn"),
    )


def _burst() -> Scenario:
    return Scenario(
        name="burst",
        population=Population(speeds=LognormalSpeeds()),
        arrivals=BurstArrivals(),
        description="flash-crowd traffic: quiet Poisson baseline with synchronized bursts",
    )


def _zipf_poisson(mean_gap: float = 15.0) -> Scenario:
    return Scenario(
        name="zipf-poisson",
        population=Population(speeds=ZipfSpeeds()),
        arrivals=PoissonArrivals(mean_gap=mean_gap),
        description="power-law speed tail with memoryless availability",
    )


def _drift(at_round: int = 20, frac: float = 0.3) -> Scenario:
    return Scenario(
        name="drift",
        events=(LabelDrift(at_round, frac),),
        description=f"distribution drift: {frac:.0%} of clients' labels rotate at round {at_round}",
    )


def _degrade(at_round: int = 15, factor: float = 3.0) -> Scenario:
    return Scenario(
        name="degrade",
        events=(SpeedShift(at_round, factor),),
        description=f"mid-run network degradation: every client {factor:g}× slower from round {at_round}",
    )


def _straggler_heavy(slow_prob: float = 0.25, slow: float = 40.0,
                     partial_prob: float = 0.15) -> Scenario:
    return Scenario(
        name="straggler-heavy",
        population=Population(speeds=LognormalSpeeds()),
        device=DeviceStateModel(
            partial_prob=partial_prob,
            latency=BimodalLatency(fast=1.0, slow=slow, slow_prob=slow_prob),
        ),
        description=(f"bimodal uplinks ({slow_prob:.0%} on a {slow:g}× slower"
                     " path) plus occasional partial local work — the"
                     " adaptive-deadline stress test (docs/ROBUSTNESS.md)"),
    )


def _mobile_markov(mean_on: float = 80.0, mean_off: float = 40.0,
                   median_lat: float = 2.0) -> Scenario:
    return Scenario(
        name="mobile-markov",
        population=Population(speeds=LognormalSpeeds()),
        arrivals=MarkovAvailability(mean_on=mean_on, mean_off=mean_off),
        device=DeviceStateModel(
            partial_prob=0.2,
            latency=LognormalLatency(median=median_lat, sigma=0.8),
        ),
        description=("phones on an on/off Markov availability chain with"
                     " heavy-tailed uplink latency and partial local work"),
    )


def _flaky_battery(drop_prob: float = 0.1, recovery_gap: float = 25.0) -> Scenario:
    return Scenario(
        name="flaky-battery",
        population=Population(speeds=LognormalSpeeds()),
        device=DeviceStateModel(
            drop_prob=drop_prob,
            partial_prob=0.1,
            recovery_gap=recovery_gap,
        ),
        description=(f"{drop_prob:.0%} of local rounds die mid-round"
                     f" (battery/network), clients recover after"
                     f" {recovery_gap:g} time units"),
    )


SCENARIOS: Dict[str, Callable[..., Scenario]] = {
    "static": _static,
    "resource-shift": _resource_shift,
    "unstable": _unstable,
    "dropout": _dropout,
    "churn": _churn,
    "diurnal": _diurnal,
    "diurnal-churn": _diurnal_churn,
    "burst": _burst,
    "zipf-poisson": _zipf_poisson,
    "drift": _drift,
    "degrade": _degrade,
    "straggler-heavy": _straggler_heavy,
    "mobile-markov": _mobile_markov,
    "flaky-battery": _flaky_battery,
}


def list_scenarios() -> List[str]:
    return sorted(SCENARIOS)


def get_scenario(name: str, **overrides) -> Scenario:
    """Build a catalog scenario by name, or replay ``trace:<path>``."""
    if name.startswith("trace:"):
        if overrides:
            raise TypeError(
                f"trace:<path> scenarios take no overrides, got {sorted(overrides)}"
            )
        path = name.split(":", 1)[1]
        return Scenario(
            name=f"trace({path})",
            arrivals=TraceReplay.from_file(path),
            description="availability replayed from a recorded trace",
        )
    if name not in SCENARIOS:
        raise KeyError(
            f"unknown scenario {name!r}; known: {', '.join(list_scenarios())} "
            f"or trace:<path>"
        )
    return SCENARIOS[name](**overrides)
