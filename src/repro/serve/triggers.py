"""Pluggable aggregation trigger policies for the streaming SAFL service.

The paper's server fires on a fixed K-buffer (§3.4).  Production
semi-asynchronous deployments also need time-bounded rounds (bound the
tail latency when traffic is thin) and participation quorums (bound the
bias when traffic is bursty from a few fast clients) — cf. SEAFL
(arXiv:2503.05755) on adaptive buffered aggregation.  A trigger policy
observes the ingest buffer on every admitted update and decides when the
service should swap buffers and aggregate.

All policies are host-side and allocation-free per submit; ``now`` is
whatever clock the caller uses (virtual time in the simulator, wall time
in a live service) — policies only compare differences of it.
"""
from __future__ import annotations

from collections import deque
from typing import List, Optional, Sequence, Tuple

from repro.core.types import Update


class TriggerPolicy:
    """Decides when the ingest buffer is ready to aggregate."""

    name = "base"

    def arm(self, now: float) -> None:
        """Called when a fresh ingest buffer opens (service start / post-fire)."""

    def should_fire(self, buffer: Sequence[Update], now: float) -> bool:
        raise NotImplementedError

    def describe(self) -> str:
        return self.name


class KBuffer(TriggerPolicy):
    """Paper-faithful trigger: fire once K updates are buffered (§3.4)."""

    name = "kbuffer"

    def __init__(self, k: int):
        if k < 1:
            raise ValueError(f"KBuffer needs k >= 1, got {k}")
        self.k = int(k)

    def should_fire(self, buffer, now):
        return len(buffer) >= self.k

    def describe(self):
        return f"kbuffer(k={self.k})"


class TimeWindow(TriggerPolicy):
    """Fire every ``window`` clock units, provided ≥ ``min_updates`` arrived.

    The window opens lazily at the first submit observed after (re)arming,
    so a service idling on a wall clock does not fire on stale windows.
    """

    name = "timewindow"

    def __init__(self, window: float, min_updates: int = 1):
        if window <= 0:
            raise ValueError(f"TimeWindow needs window > 0, got {window}")
        self.window = float(window)
        self.min_updates = int(min_updates)
        self._opened: Optional[float] = None

    def arm(self, now):
        # reopen lazily at the next observed submit — measuring from the
        # fire time would make the first submit after an idle gap fire
        # instantly on a stale window
        self._opened = None

    def should_fire(self, buffer, now):
        if self._opened is None:  # first submit after an idle period
            self._opened = now
        return len(buffer) >= self.min_updates and (now - self._opened) >= self.window

    def describe(self):
        return f"timewindow(w={self.window},min={self.min_updates})"


class AdaptiveTimeWindow(TimeWindow):
    """SEAFL-style adaptive deadline: the window tracks a running quantile
    of observed client delivery latencies instead of staying fixed.

    Every accepted update whose ``sent_at`` stamp is known contributes one
    latency sample ``now − sent_at`` (the service calls ``observe`` on
    admission).  At each fire the deadline is re-planned to
    ``clip(quantile_q(latencies) · slack, min_window, max_window)``: when
    stragglers dominate the stream the window stretches so their updates
    land inside the round instead of arriving one round stale (and being
    dropped by staleness admission); when the population speeds up the
    window contracts back toward ``min_window``.  With no latency
    observations (legacy streams never stamp ``sent_at``) the trigger
    degrades to the plain fixed ``TimeWindow`` it inherits from.
    """

    name = "adaptive"

    def __init__(self, window: float, min_updates: int = 1, *,
                 q: float = 0.9, slack: float = 1.25,
                 min_window: Optional[float] = None,
                 max_window: Optional[float] = None,
                 history: int = 256, warmup: int = 8):
        super().__init__(window, min_updates)
        if not 0.0 < q <= 1.0:
            raise ValueError(f"quantile must be in (0, 1], got {q}")
        if slack <= 0:
            raise ValueError(f"slack must be > 0, got {slack}")
        self.q = float(q)
        self.slack = float(slack)
        self.min_window = float(min_window if min_window is not None
                                else window * 0.25)
        self.max_window = float(max_window if max_window is not None
                                else window * 16.0)
        self.warmup = int(warmup)
        self._lats: deque = deque(maxlen=int(history))
        self._adaptation: Optional[Tuple[float, float, float]] = None

    def observe(self, update: Update, now: float) -> None:
        """Record one delivery-latency sample from an accepted update."""
        sent = float(getattr(update, "sent_at", -1.0))
        if sent >= 0.0 and now > sent:
            self._lats.append(now - sent)

    def observe_batch(self, updates: Sequence[Update], now: float) -> None:
        """Batched ``observe`` over one burst segment — the service's
        vectorized burst path shows arrivals in segments that close before
        each re-arm, so the latency history (and therefore every
        re-planned deadline) is bit-identical to per-update observation:
        ``now - sent`` is the same float expression, the deque's maxlen
        trims the same way under extend as under repeated append."""
        self._lats.extend(
            now - sent
            for sent in (float(getattr(u, "sent_at", -1.0)) for u in updates)
            if sent >= 0.0 and now > sent
        )

    def _quantile(self) -> float:
        # nearest-rank on the sorted history — tiny (≤ history) and only
        # run once per fire, so no numpy dependency needed here
        lats = sorted(self._lats)
        idx = min(len(lats) - 1, max(0, int(self.q * len(lats)) ))
        return lats[idx]

    def arm(self, now):
        if len(self._lats) >= self.warmup:
            q_lat = self._quantile()
            target = min(self.max_window,
                         max(self.min_window, q_lat * self.slack))
            if target != self.window:
                self._adaptation = (self.window, target, q_lat)
                self.window = target
        super().arm(now)

    def consume_adaptation(self) -> Optional[Tuple[float, float, float]]:
        """(old_window, new_window, quantile_latency) of the last re-plan,
        once — the service turns it into a ``deadline-adapted`` event."""
        a, self._adaptation = self._adaptation, None
        return a

    def describe(self):
        return (f"adaptive(w={self.window:.3g},min={self.min_updates},"
                f"q={self.q},slack={self.slack})")


class Quorum(TriggerPolicy):
    """Hybrid trigger: K updates from at least ``quorum`` distinct clients.

    Guards against one fast client filling the whole buffer (the bias mode
    SEAFL's adaptive aggregation targets).  An optional ``grace`` window
    fires anyway once it expires with a non-empty buffer, so a thin stream
    of repeat uploaders cannot stall rounds forever.
    """

    name = "quorum"

    def __init__(self, k: int, quorum: int, grace: Optional[float] = None):
        if quorum > k:
            raise ValueError(f"quorum ({quorum}) cannot exceed k ({k})")
        self.k = int(k)
        self.quorum = int(quorum)
        self.grace = grace
        self._opened: Optional[float] = None

    def arm(self, now):
        self._opened = None  # lazy reopen, same rationale as TimeWindow

    def should_fire(self, buffer, now):
        if self._opened is None:
            self._opened = now
        if len(buffer) >= self.k:
            distinct = len({u.cid for u in buffer})
            if distinct >= self.quorum:
                return True
        if self.grace is not None and buffer and (now - self._opened) >= self.grace:
            return True
        return False

    def describe(self):
        g = f",grace={self.grace}" if self.grace is not None else ""
        return f"quorum(k={self.k},q={self.quorum}{g})"


def make_trigger(name: str, **kw) -> TriggerPolicy:
    """Factory used by launch/bench CLIs:
    kbuffer | timewindow | adaptive | quorum."""
    table = {"kbuffer": KBuffer, "timewindow": TimeWindow,
             "adaptive": AdaptiveTimeWindow, "quorum": Quorum}
    try:
        return table[name](**kw)
    except KeyError:
        raise ValueError(f"unknown trigger {name!r}; choose from {sorted(table)}") from None
