"""Update-stream utilities: synthetic client streams and capture/replay.

``synthetic_stream`` fabricates a realistic semi-asynchronous upload
sequence (heterogeneous client rates, natural staleness lag, noisy
deltas shaped like the model) for load-testing the service without
running local training — this is what the throughput benchmark and the
``--safl-stream`` launcher feed in.

``scenario_stream`` is its scenario-driven twin: the population model
decides client speeds and data volumes, the arrival process decides
upload timing (diurnal troughs thin the stream, bursts flood it), and
dynamic events churn the uploading population mid-stream — so trigger
and admission policies can be load-tested against every catalog entry
in docs/SCENARIOS.md (``--scenario`` on ``repro.launch.serve``).

``replay`` pushes a recorded (update, timestamp) sequence through a
service; together with ``CaptureStream`` it underpins the
stream-vs-virtual-clock equivalence test.
"""
from __future__ import annotations

import dataclasses

from dataclasses import dataclass, field
from typing import Iterator, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.types import Params, Update

from .service import RoundReport, StreamingAggregator


def synthetic_stream(
    params: Params,
    n_clients: int,
    n_updates: int,
    *,
    seed: int = 0,
    delta_scale: float = 1e-3,
    rate_ratio: float = 50.0,
    distinct_deltas: int = 8,
) -> Iterator[Tuple[Update, float]]:
    """Yield ``(update, arrival_time)`` pairs mimicking SAFL traffic.

    Client inter-upload gaps are drawn per-client from a 1:``rate_ratio``
    speed spread (fast clients upload often → they dominate the stream,
    exactly the bias the quorum trigger exists for).  ``stale_round``
    lags a virtual round counter by a speed-correlated amount.  Deltas
    cycle through ``distinct_deltas`` pre-generated noise pytrees so the
    generator costs O(distinct) model copies, not O(n_updates).
    """
    rng = np.random.default_rng(seed)
    speeds = rng.uniform(1.0, rate_ratio, n_clients)
    next_at = speeds * rng.uniform(0.5, 1.5, n_clients)
    n_samples = rng.integers(20, 200, n_clients)

    deltas, models = _noise_trees(params, distinct_deltas, delta_scale, seed)

    virtual_round = 0
    for i in range(n_updates):
        cid = int(np.argmin(next_at))
        now = float(next_at[cid])
        next_at[cid] += speeds[cid] * rng.uniform(0.9, 1.1)
        # slow clients trained on an older global round
        lag = int(speeds[cid] / rate_ratio * 5)
        yield Update(
            cid=cid,
            n_samples=int(n_samples[cid]),
            stale_round=max(0, virtual_round - lag),
            lr=0.1,
            similarity=float(rng.uniform(0.05, 1.0)),
            feedback=bool(rng.random() < 0.3),
            speed_f=float(1.0 / speeds[cid]),
            delta=deltas[i % distinct_deltas],
            params=models[i % distinct_deltas],
        ), now
        virtual_round += 1 if (i + 1) % 10 == 0 else 0


def _noise_trees(params: Params, n: int, scale: float, seed: int):
    """Pre-generate ``n`` model-shaped noise pytrees (and params+noise)."""
    key = jax.random.PRNGKey(seed)
    leaves, treedef = jax.tree_util.tree_flatten(params)
    deltas, models = [], []
    for _ in range(n):
        key, sub = jax.random.split(key)
        ks = jax.random.split(sub, len(leaves))
        noise = [
            scale * jax.random.normal(k, l.shape, jnp.float32)
            for k, l in zip(ks, leaves)
        ]
        delta = jax.tree_util.tree_unflatten(treedef, noise)
        deltas.append(delta)
        models.append(jax.tree_util.tree_map(jnp.add, params, delta))
    return deltas, models


def inject_norm_explosion(
    stream: Iterator[Tuple[Update, float]],
    *,
    after: int,
    scale: float = 100.0,
    span: Optional[int] = None,
) -> Iterator[Tuple[Update, float]]:
    """Seeded chaos injection for the health-detector efficacy gates:
    from the ``after``-th update on (for ``span`` updates, or forever),
    every payload is multiplied by ``scale`` — a diverging client whose
    gradients explode, exactly the excursion the ``update_norm`` /
    ``dispersion`` detectors must catch within a few rounds
    (``benchmarks/bench_health.py``, ``tests/test_health.py``).

    Deterministic by construction: the underlying stream supplies all
    randomness, this wrapper only rescales tensors at fixed positions.
    """
    blow = lambda tree: (None if tree is None else jax.tree_util.tree_map(
        lambda l: l * jnp.float32(scale), tree))
    for i, (u, t) in enumerate(stream):
        if i >= after and (span is None or i < after + span):
            u = dataclasses.replace(u, delta=blow(u.delta),
                                    params=blow(u.params))
        yield u, t


def scenario_stream(
    params: Params,
    scenario,
    n_clients: int,
    n_updates: int,
    *,
    seed: int = 0,
    delta_scale: float = 1e-3,
    distinct_deltas: int = 8,
    updates_per_round: int = 10,
    telemetry=None,
) -> Iterator[Tuple[Update, float]]:
    """Yield ``(update, arrival_time)`` pairs driven by a ``Scenario``.

    Speeds and data volumes come from the scenario's population model
    (falling back to the historic uniform spread), upload timing from
    its arrival process (always-on when absent), and the scenario's
    dynamic events mutate the uploading population at every
    ``updates_per_round``-update virtual round boundary — churned
    clients stop uploading, revived ones come back.  ``stale_round``
    is the virtual round at each burst's start, so arrival gaps map to
    staleness the way they do in the engine.

    A ``scenario.device`` model (docs/ROBUSTNESS.md) acts at *schedule*
    time so the event queue stays time-sorted: each planned local round
    draws its outcome once — a mid-round death pops as a ``client-dropped``
    telemetry event instead of an update (the client returns after
    ``recovery_gap`` + its arrival law's think time), partial work
    finishes early at ``start + cf·compute`` with ``completed_fraction``
    stamped on the update, and uplink latency is folded into the
    delivery time while the pre-latency finish rides along as
    ``Update.sent_at`` for the adaptive-deadline trigger to learn from.
    All device draws happen *after* the legacy compute-time draws and a
    trivial model draws nothing, so an all-complete device run replays
    the no-device stream bit-for-bit.
    """
    from repro.scenarios.arrivals import AlwaysOn

    rng = np.random.default_rng(seed)
    speeds = scenario.sample_speeds(n_clients, rng)
    if scenario.population is not None:
        n_samples = scenario.population.quantity.sample(n_clients, rng)
    else:
        n_samples = rng.integers(20, 200, n_clients)
    arr = scenario.arrivals if scenario.arrivals is not None else AlwaysOn()
    dev = getattr(scenario, "device", None)

    deltas, models = _noise_trees(params, distinct_deltas, delta_scale, seed)

    alive = np.ones(n_clients, bool)
    burst_start = arr.start(n_clients, rng)
    next_finish = np.full(n_clients, np.inf)
    fetch_round = np.zeros(n_clients, np.int64)
    # per-client outcome of the *planned* round, decided at schedule time
    pending_cf = np.ones(n_clients, np.float32)
    pending_drop = np.zeros(n_clients, bool)
    pending_sent = np.full(n_clients, -1.0)

    def _plan(cid: int, start: float) -> float:
        """Delivery time of the round starting at ``start`` (device-aware)."""
        default = speeds[cid] * rng.uniform(0.9, 1.1)
        compute = arr.compute_time(cid, start, default, rng)
        if dev is None:
            return start + compute
        dropped, cf = dev.round_outcome(cid, rng)
        pending_drop[cid] = dropped
        pending_cf[cid] = cf
        if dropped:
            # the battery dies somewhere inside the local round
            pending_sent[cid] = start + rng.uniform(0.0, 1.0) * compute
            return float(pending_sent[cid])
        pending_sent[cid] = start + cf * compute
        return float(pending_sent[cid]) + dev.sample_latency(cid, rng)

    for cid in range(n_clients):
        if np.isfinite(burst_start[cid]):
            next_finish[cid] = _plan(cid, float(burst_start[cid]))

    virtual_round = 0
    i = 0  # updates emitted
    pops = 0
    # liveness guard: a pathological device model (drop_prob≈1 over an
    # always-on arrival law) would pop drop events forever without ever
    # emitting an update — bound total pops instead of looping blind
    max_pops = n_updates * 20 + 10 * n_clients
    while i < n_updates and pops < max_pops:
        ready = alive & np.isfinite(next_finish)
        if not ready.any():
            return
        cid = int(np.flatnonzero(ready)[np.argmin(next_finish[ready])])
        now = float(next_finish[cid])
        pops += 1

        if dev is not None and pending_drop[cid]:
            # mid-round death: no upload; recover, then rejoin through the
            # arrival law so availability semantics keep holding
            if telemetry is not None:
                from repro.telemetry import ClientDropped

                telemetry.emit(ClientDropped(
                    t=now, round=virtual_round, cid=cid, reason="battery"))
            nxt = arr.next_start(cid, now + dev.recovery_gap, rng)
            burst_start[cid] = nxt
            if np.isfinite(nxt):
                next_finish[cid] = _plan(cid, float(nxt))
                fetch_round[cid] = virtual_round
            else:
                next_finish[cid] = np.inf
            continue

        yield Update(
            cid=cid,
            n_samples=int(n_samples[cid]),
            stale_round=int(fetch_round[cid]),
            lr=0.1,
            similarity=float(rng.uniform(0.05, 1.0)),
            feedback=bool(rng.random() < 0.3),
            speed_f=float(1.0 / speeds[cid]),
            delta=deltas[i % distinct_deltas],
            params=models[i % distinct_deltas],
            completed_fraction=float(pending_cf[cid]) if dev is not None else 1.0,
            sent_at=float(pending_sent[cid]) if dev is not None else -1.0,
        ), now
        i += 1

        nxt = arr.next_start(cid, now, rng)
        burst_start[cid] = nxt
        if np.isfinite(nxt):
            next_finish[cid] = _plan(cid, float(nxt))
            fetch_round[cid] = virtual_round
        else:
            next_finish[cid] = np.inf

        if i % updates_per_round == 0:
            virtual_round += 1
            # clients whose next burst has not yet begun keep fetching: their
            # stale_round tracks the round at burst *start* (the engine's
            # arrival-gated fetch semantics), not at their previous upload
            waiting = alive & np.isfinite(burst_start) & (burst_start >= now)
            fetch_round[waiting] = virtual_round
            new_speeds = scenario.apply_events(virtual_round, speeds, rng)
            if new_speeds is not None:
                was_dead = ~alive
                speeds = new_speeds
                finite = np.isfinite(new_speeds)
                alive = finite
                next_finish[~finite] = np.inf
                for rcid in np.flatnonzero(was_dead & finite):
                    t = arr.next_start(int(rcid), now, rng)
                    burst_start[rcid] = t
                    if np.isfinite(t):
                        next_finish[rcid] = _plan(int(rcid), float(t))
                        fetch_round[rcid] = virtual_round


@dataclass
class CaptureStream:
    """Records every update offered to a service (install via ``wrap``)."""

    updates: List[Tuple[Update, Optional[float]]] = field(default_factory=list)

    def wrap(self, service: StreamingAggregator) -> StreamingAggregator:
        inner = service.submit

        def recording_submit(update, now=None):
            self.updates.append((update, now))
            return inner(update, now=now)

        service.submit = recording_submit  # type: ignore[method-assign]
        return service


def replay(
    service: StreamingAggregator,
    stream,
    *,
    flush: bool = True,
) -> List[RoundReport]:
    """Push an (update, time) sequence through ``service``; returns the
    round reports of every fire (including the final flush if requested)."""
    reports: List[RoundReport] = []
    last = None
    for update, now in stream:
        last = now
        res = service.submit(update, now=now)
        if res.fired and res.report is not None:
            reports.append(res.report)
    if flush:
        rep = service.flush(now=last)
        if rep is not None:
            reports.append(rep)
    service.join()
    return reports
