"""Update-stream utilities: synthetic client streams and capture/replay.

``synthetic_stream`` fabricates a realistic semi-asynchronous upload
sequence (heterogeneous client rates, natural staleness lag, noisy
deltas shaped like the model) for load-testing the service without
running local training — this is what the throughput benchmark and the
``--safl-stream`` launcher feed in.

``replay`` pushes a recorded (update, timestamp) sequence through a
service; together with ``CaptureStream`` it underpins the
stream-vs-virtual-clock equivalence test.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Iterator, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.types import Params, Update

from .service import RoundReport, StreamingAggregator


def synthetic_stream(
    params: Params,
    n_clients: int,
    n_updates: int,
    *,
    seed: int = 0,
    delta_scale: float = 1e-3,
    rate_ratio: float = 50.0,
    distinct_deltas: int = 8,
) -> Iterator[Tuple[Update, float]]:
    """Yield ``(update, arrival_time)`` pairs mimicking SAFL traffic.

    Client inter-upload gaps are drawn per-client from a 1:``rate_ratio``
    speed spread (fast clients upload often → they dominate the stream,
    exactly the bias the quorum trigger exists for).  ``stale_round``
    lags a virtual round counter by a speed-correlated amount.  Deltas
    cycle through ``distinct_deltas`` pre-generated noise pytrees so the
    generator costs O(distinct) model copies, not O(n_updates).
    """
    rng = np.random.default_rng(seed)
    speeds = rng.uniform(1.0, rate_ratio, n_clients)
    next_at = speeds * rng.uniform(0.5, 1.5, n_clients)
    n_samples = rng.integers(20, 200, n_clients)

    key = jax.random.PRNGKey(seed)
    leaves, treedef = jax.tree_util.tree_flatten(params)
    deltas, models = [], []
    for d in range(distinct_deltas):
        key, sub = jax.random.split(key)
        ks = jax.random.split(sub, len(leaves))
        noise = [
            delta_scale * jax.random.normal(k, l.shape, jnp.float32)
            for k, l in zip(ks, leaves)
        ]
        delta = jax.tree_util.tree_unflatten(treedef, noise)
        deltas.append(delta)
        models.append(jax.tree_util.tree_map(jnp.add, params, delta))

    virtual_round = 0
    for i in range(n_updates):
        cid = int(np.argmin(next_at))
        now = float(next_at[cid])
        next_at[cid] += speeds[cid] * rng.uniform(0.9, 1.1)
        # slow clients trained on an older global round
        lag = int(speeds[cid] / rate_ratio * 5)
        yield Update(
            cid=cid,
            n_samples=int(n_samples[cid]),
            stale_round=max(0, virtual_round - lag),
            lr=0.1,
            similarity=float(rng.uniform(0.05, 1.0)),
            feedback=bool(rng.random() < 0.3),
            speed_f=float(1.0 / speeds[cid]),
            delta=deltas[i % distinct_deltas],
            params=models[i % distinct_deltas],
        ), now
        virtual_round += 1 if (i + 1) % 10 == 0 else 0


@dataclass
class CaptureStream:
    """Records every update offered to a service (install via ``wrap``)."""

    updates: List[Tuple[Update, Optional[float]]] = field(default_factory=list)

    def wrap(self, service: StreamingAggregator) -> StreamingAggregator:
        inner = service.submit

        def recording_submit(update, now=None):
            self.updates.append((update, now))
            return inner(update, now=now)

        service.submit = recording_submit  # type: ignore[method-assign]
        return service


def replay(
    service: StreamingAggregator,
    stream,
    *,
    flush: bool = True,
) -> List[RoundReport]:
    """Push an (update, time) sequence through ``service``; returns the
    round reports of every fire (including the final flush if requested)."""
    reports: List[RoundReport] = []
    last = None
    for update, now in stream:
        last = now
        res = service.submit(update, now=now)
        if res.fired and res.report is not None:
            reports.append(res.report)
    if flush:
        rep = service.flush(now=last)
        if rep is not None:
            reports.append(rep)
    service.join()
    return reports
