"""Update-stream utilities: synthetic client streams and capture/replay.

``synthetic_stream`` fabricates a realistic semi-asynchronous upload
sequence (heterogeneous client rates, natural staleness lag, noisy
deltas shaped like the model) for load-testing the service without
running local training — this is what the throughput benchmark and the
``--safl-stream`` launcher feed in.

``scenario_stream`` is its scenario-driven twin: the population model
decides client speeds and data volumes, the arrival process decides
upload timing (diurnal troughs thin the stream, bursts flood it), and
dynamic events churn the uploading population mid-stream — so trigger
and admission policies can be load-tested against every catalog entry
in docs/SCENARIOS.md (``--scenario`` on ``repro.launch.serve``).

``replay`` pushes a recorded (update, timestamp) sequence through a
service; together with ``CaptureStream`` it underpins the
stream-vs-virtual-clock equivalence test.
"""
from __future__ import annotations

import dataclasses

from dataclasses import dataclass, field
from typing import Iterator, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.types import Params, Update

from .service import RoundReport, StreamingAggregator


def synthetic_stream(
    params: Params,
    n_clients: int,
    n_updates: int,
    *,
    seed: int = 0,
    delta_scale: float = 1e-3,
    rate_ratio: float = 50.0,
    distinct_deltas: int = 8,
) -> Iterator[Tuple[Update, float]]:
    """Yield ``(update, arrival_time)`` pairs mimicking SAFL traffic.

    Client inter-upload gaps are drawn per-client from a 1:``rate_ratio``
    speed spread (fast clients upload often → they dominate the stream,
    exactly the bias the quorum trigger exists for).  ``stale_round``
    lags a virtual round counter by a speed-correlated amount.  Deltas
    cycle through ``distinct_deltas`` pre-generated noise pytrees so the
    generator costs O(distinct) model copies, not O(n_updates).
    """
    rng = np.random.default_rng(seed)
    speeds = rng.uniform(1.0, rate_ratio, n_clients)
    next_at = speeds * rng.uniform(0.5, 1.5, n_clients)
    n_samples = rng.integers(20, 200, n_clients)

    deltas, models = _noise_trees(params, distinct_deltas, delta_scale, seed)

    virtual_round = 0
    for i in range(n_updates):
        cid = int(np.argmin(next_at))
        now = float(next_at[cid])
        next_at[cid] += speeds[cid] * rng.uniform(0.9, 1.1)
        # slow clients trained on an older global round
        lag = int(speeds[cid] / rate_ratio * 5)
        yield Update(
            cid=cid,
            n_samples=int(n_samples[cid]),
            stale_round=max(0, virtual_round - lag),
            lr=0.1,
            similarity=float(rng.uniform(0.05, 1.0)),
            feedback=bool(rng.random() < 0.3),
            speed_f=float(1.0 / speeds[cid]),
            delta=deltas[i % distinct_deltas],
            params=models[i % distinct_deltas],
        ), now
        virtual_round += 1 if (i + 1) % 10 == 0 else 0


def _noise_trees(params: Params, n: int, scale: float, seed: int):
    """Pre-generate ``n`` model-shaped noise pytrees (and params+noise)."""
    key = jax.random.PRNGKey(seed)
    leaves, treedef = jax.tree_util.tree_flatten(params)
    deltas, models = [], []
    for _ in range(n):
        key, sub = jax.random.split(key)
        ks = jax.random.split(sub, len(leaves))
        noise = [
            scale * jax.random.normal(k, l.shape, jnp.float32)
            for k, l in zip(ks, leaves)
        ]
        delta = jax.tree_util.tree_unflatten(treedef, noise)
        deltas.append(delta)
        models.append(jax.tree_util.tree_map(jnp.add, params, delta))
    return deltas, models


def zipf_burst_stream(
    params: Params,
    n_clients: int,
    n_updates: int,
    *,
    seed: int = 0,
    burst: int = 256,
    zipf_a: float = 1.2,
    delta_scale: float = 1e-3,
    distinct_deltas: int = 8,
    rounds_per_burst: int = 1,
    stale_spread: int = 4,
    dt: float = 1.0,
) -> Iterator[Tuple[List[Update], float]]:
    """Yield ``(updates, arrival_time)`` *bursts* of SAFL traffic with a
    heavy-tailed Zipf(``zipf_a``) client popularity over an arbitrarily
    large population — the serve_saturation trace (1M clients).

    Per-burst attributes are drawn as vectors, so generation stays O(burst)
    however big ``n_clients`` is: client ranks come from a Zipf draw folded
    into the population (a handful of hot clients dominate, the long tail
    trickles), ``stale_round`` lags a virtual round counter by a seeded
    spread (so staleness admission has real work), and ``sent_at`` is
    stamped before the arrival time (so adaptive deadlines have latencies
    to learn from).  Payloads cycle ``distinct_deltas`` pre-generated noise
    pytrees, like ``synthetic_stream``.  Fully deterministic per seed.
    """
    rng = np.random.default_rng(seed)
    deltas, models = _noise_trees(params, distinct_deltas, delta_scale, seed)

    virtual_round = 0
    emitted = 0
    b = 0
    while emitted < n_updates:
        k = min(burst, n_updates - emitted)
        now = (b + 1) * dt
        ranks = rng.zipf(zipf_a, size=k).astype(np.int64)
        cids = (ranks - 1) % n_clients
        lags = rng.integers(0, stale_spread + 1, k)
        stale_rounds = np.maximum(0, virtual_round - lags)
        ns = rng.integers(20, 200, k)
        sims = rng.uniform(0.05, 1.0, k)
        fb = rng.random(k) < 0.3
        sent = now - rng.uniform(0.1, 2.0, k)
        yield [
            Update(
                cid=int(cids[j]),
                n_samples=int(ns[j]),
                stale_round=int(stale_rounds[j]),
                lr=0.1,
                similarity=float(sims[j]),
                feedback=bool(fb[j]),
                speed_f=1.0,
                delta=deltas[(emitted + j) % distinct_deltas],
                params=models[(emitted + j) % distinct_deltas],
                sent_at=float(sent[j]),
            )
            for j in range(k)
        ], now
        emitted += k
        b += 1
        virtual_round += rounds_per_burst


def flatten_bursts(
    bursts,
) -> List[Tuple[Update, float]]:
    """One ``(update, time)`` pair per burst member, in burst order — the
    per-update view of a burst trace, for driving the synchronous service
    over the identical arrival sequence (the bit-identity pins)."""
    return [(u, now) for batch, now in bursts for u in batch]


def inject_norm_explosion(
    stream: Iterator[Tuple[Update, float]],
    *,
    after: int,
    scale: float = 100.0,
    span: Optional[int] = None,
) -> Iterator[Tuple[Update, float]]:
    """Seeded chaos injection for the health-detector efficacy gates:
    from the ``after``-th update on (for ``span`` updates, or forever),
    every payload is multiplied by ``scale`` — a diverging client whose
    gradients explode, exactly the excursion the ``update_norm`` /
    ``dispersion`` detectors must catch within a few rounds
    (``benchmarks/bench_health.py``, ``tests/test_health.py``).

    Deterministic by construction: the underlying stream supplies all
    randomness, this wrapper only rescales tensors at fixed positions.
    """
    blow = lambda tree: (None if tree is None else jax.tree_util.tree_map(
        lambda l: l * jnp.float32(scale), tree))
    for i, (u, t) in enumerate(stream):
        if i >= after and (span is None or i < after + span):
            u = dataclasses.replace(u, delta=blow(u.delta),
                                    params=blow(u.params))
        yield u, t


def scenario_stream(
    params: Params,
    scenario,
    n_clients: int,
    n_updates: int,
    *,
    seed: int = 0,
    delta_scale: float = 1e-3,
    distinct_deltas: int = 8,
    updates_per_round: int = 10,
    telemetry=None,
) -> Iterator[Tuple[Update, float]]:
    """Yield ``(update, arrival_time)`` pairs driven by a ``Scenario``.

    Speeds and data volumes come from the scenario's population model
    (falling back to the historic uniform spread), upload timing from
    its arrival process (always-on when absent), and the scenario's
    dynamic events mutate the uploading population at every
    ``updates_per_round``-update virtual round boundary — churned
    clients stop uploading, revived ones come back.  ``stale_round``
    is the virtual round at each burst's start, so arrival gaps map to
    staleness the way they do in the engine.

    A ``scenario.device`` model (docs/ROBUSTNESS.md) acts at *schedule*
    time so the event queue stays time-sorted: each planned local round
    draws its outcome once — a mid-round death pops as a ``client-dropped``
    telemetry event instead of an update (the client returns after
    ``recovery_gap`` + its arrival law's think time), partial work
    finishes early at ``start + cf·compute`` with ``completed_fraction``
    stamped on the update, and uplink latency is folded into the
    delivery time while the pre-latency finish rides along as
    ``Update.sent_at`` for the adaptive-deadline trigger to learn from.
    All device draws happen *after* the legacy compute-time draws and a
    trivial model draws nothing, so an all-complete device run replays
    the no-device stream bit-for-bit.
    """
    from repro.scenarios.arrivals import AlwaysOn

    rng = np.random.default_rng(seed)
    speeds = scenario.sample_speeds(n_clients, rng)
    if scenario.population is not None:
        n_samples = scenario.population.quantity.sample(n_clients, rng)
    else:
        n_samples = rng.integers(20, 200, n_clients)
    arr = scenario.arrivals if scenario.arrivals is not None else AlwaysOn()
    dev = getattr(scenario, "device", None)

    deltas, models = _noise_trees(params, distinct_deltas, delta_scale, seed)

    alive = np.ones(n_clients, bool)
    burst_start = arr.start(n_clients, rng)
    next_finish = np.full(n_clients, np.inf)
    fetch_round = np.zeros(n_clients, np.int64)
    # per-client outcome of the *planned* round, decided at schedule time
    pending_cf = np.ones(n_clients, np.float32)
    pending_drop = np.zeros(n_clients, bool)
    pending_sent = np.full(n_clients, -1.0)

    def _plan(cid: int, start: float) -> float:
        """Delivery time of the round starting at ``start`` (device-aware)."""
        default = speeds[cid] * rng.uniform(0.9, 1.1)
        compute = arr.compute_time(cid, start, default, rng)
        if dev is None:
            return start + compute
        dropped, cf = dev.round_outcome(cid, rng)
        pending_drop[cid] = dropped
        pending_cf[cid] = cf
        if dropped:
            # the battery dies somewhere inside the local round
            pending_sent[cid] = start + rng.uniform(0.0, 1.0) * compute
            return float(pending_sent[cid])
        pending_sent[cid] = start + cf * compute
        return float(pending_sent[cid]) + dev.sample_latency(cid, rng)

    for cid in range(n_clients):
        if np.isfinite(burst_start[cid]):
            next_finish[cid] = _plan(cid, float(burst_start[cid]))

    virtual_round = 0
    i = 0  # updates emitted
    pops = 0
    # liveness guard: a pathological device model (drop_prob≈1 over an
    # always-on arrival law) would pop drop events forever without ever
    # emitting an update — bound total pops instead of looping blind
    max_pops = n_updates * 20 + 10 * n_clients
    while i < n_updates and pops < max_pops:
        ready = alive & np.isfinite(next_finish)
        if not ready.any():
            return
        cid = int(np.flatnonzero(ready)[np.argmin(next_finish[ready])])
        now = float(next_finish[cid])
        pops += 1

        if dev is not None and pending_drop[cid]:
            # mid-round death: no upload; recover, then rejoin through the
            # arrival law so availability semantics keep holding
            if telemetry is not None:
                from repro.telemetry import ClientDropped

                telemetry.emit(ClientDropped(
                    t=now, round=virtual_round, cid=cid, reason="battery"))
            nxt = arr.next_start(cid, now + dev.recovery_gap, rng)
            burst_start[cid] = nxt
            if np.isfinite(nxt):
                next_finish[cid] = _plan(cid, float(nxt))
                fetch_round[cid] = virtual_round
            else:
                next_finish[cid] = np.inf
            continue

        yield Update(
            cid=cid,
            n_samples=int(n_samples[cid]),
            stale_round=int(fetch_round[cid]),
            lr=0.1,
            similarity=float(rng.uniform(0.05, 1.0)),
            feedback=bool(rng.random() < 0.3),
            speed_f=float(1.0 / speeds[cid]),
            delta=deltas[i % distinct_deltas],
            params=models[i % distinct_deltas],
            completed_fraction=float(pending_cf[cid]) if dev is not None else 1.0,
            sent_at=float(pending_sent[cid]) if dev is not None else -1.0,
        ), now
        i += 1

        nxt = arr.next_start(cid, now, rng)
        burst_start[cid] = nxt
        if np.isfinite(nxt):
            next_finish[cid] = _plan(cid, float(nxt))
            fetch_round[cid] = virtual_round
        else:
            next_finish[cid] = np.inf

        if i % updates_per_round == 0:
            virtual_round += 1
            # clients whose next burst has not yet begun keep fetching: their
            # stale_round tracks the round at burst *start* (the engine's
            # arrival-gated fetch semantics), not at their previous upload
            waiting = alive & np.isfinite(burst_start) & (burst_start >= now)
            fetch_round[waiting] = virtual_round
            new_speeds = scenario.apply_events(virtual_round, speeds, rng)
            if new_speeds is not None:
                was_dead = ~alive
                speeds = new_speeds
                finite = np.isfinite(new_speeds)
                alive = finite
                next_finish[~finite] = np.inf
                for rcid in np.flatnonzero(was_dead & finite):
                    t = arr.next_start(int(rcid), now, rng)
                    burst_start[rcid] = t
                    if np.isfinite(t):
                        next_finish[rcid] = _plan(int(rcid), float(t))
                        fetch_round[rcid] = virtual_round


@dataclass
class CaptureStream:
    """Records every update offered to a service (install via ``wrap``)."""

    updates: List[Tuple[Update, Optional[float]]] = field(default_factory=list)

    def wrap(self, service: StreamingAggregator) -> StreamingAggregator:
        inner = service.submit

        def recording_submit(update, now=None):
            self.updates.append((update, now))
            return inner(update, now=now)

        service.submit = recording_submit  # type: ignore[method-assign]
        return service


class _ReportCollector:
    """Temporarily chains onto ``service.on_round`` to collect every round
    report delivered during a replay — the one delivery channel that works
    for all three aggregation modes (sync fires return reports from
    ``submit``, async_agg and the pipeline surface them via the hook)."""

    def __init__(self, service: StreamingAggregator):
        self.service = service
        self.reports: List[RoundReport] = []

    def __enter__(self):
        self._prev = self.service.on_round

        def hook(rep, _prev=self._prev):
            self.reports.append(rep)
            if _prev is not None:
                _prev(rep)

        self.service.on_round = hook
        return self.reports

    def __exit__(self, *exc):
        self.service.on_round = self._prev
        return False


def replay(
    service: StreamingAggregator,
    stream,
    *,
    flush: bool = True,
) -> List[RoundReport]:
    """Push an (update, time) sequence through ``service``; returns the
    round reports of every fire (including the final flush if requested),
    collected via ``on_round`` so pipelined/async rounds are included."""
    with _ReportCollector(service) as reports:
        last = None
        for update, now in stream:
            last = now
            service.submit(update, now=now)
        if flush:
            service.flush(now=last)
        service.join()
    return reports


def replay_bursts(
    service: StreamingAggregator,
    bursts,
    *,
    flush: bool = True,
) -> List[RoundReport]:
    """Burst twin of ``replay``: pushes ``(updates, arrival_time)`` bursts
    through ``submit_burst`` (the vectorized admission path when the
    policy supports it) and collects every resolved round report."""
    with _ReportCollector(service) as reports:
        last = None
        for batch, now in bursts:
            last = now
            service.submit_burst(batch, now=now)
        if flush:
            service.flush(now=last)
        service.join()
    return reports
