"""Streaming SAFL aggregation service (DESIGN: runtime layer 2).

Generalizes the virtual-clock engine's buffered K-trigger loop into a
real ingestion pipeline:

1. **admission** — every incoming ``Update`` passes staleness-bounded
   admission control (``repro.serve.admission``) before entering the
   ingest buffer;
2. **trigger** — a pluggable policy (``repro.serve.triggers``) decides
   when the buffer is ready: the paper's K-buffer, a time window, or a
   distinct-client quorum hybrid;
3. **aggregation** — the frozen buffer is handed to the ``Algorithm``'s
   ``server_aggregate`` (all 12 baselines plug in unchanged), or — for
   linear-weighting algorithms — to the batched stacked path that
   dispatches the Pallas ``weighted_agg`` kernel with a jnp fallback;
4. **double-buffering** — the ingest buffer is swapped out at fire time,
   so ingestion continues into a fresh buffer while the frozen batch
   aggregates (synchronously inline, or on a worker thread with
   ``async_agg=True``; rounds always serialize);
5. **overlapped rounds** — with ``pipeline=True`` the fused-kernel
   dispatch of round r is handed to a single-worker executor while
   ``submit``/``submit_burst`` keep admitting round r+1's arrivals; the
   round is *resolved* (params installed, ``RoundReport`` emitted,
   health/trace spans closed) at the next fire or an explicit
   ``drain()``.  The determinism contract: the same stream produces
   bit-identical params, stats, and telemetry event streams whether
   pipelined or synchronous (pinned in tests/test_pipeline.py);
6. **hooks** — per-round metrics via ``on_round`` and checkpoint/resume
   via ``save``/``restore`` (``repro.checkpoint.ckpt``).

The virtual-clock engine (``repro.core.safl``) is one client of this
API: it constructs the service with the paper's K-buffer trigger and
admit-all policy and submits updates as its event loop produces them,
which keeps the stream path and the paper-faithful path one code path.
"""
from __future__ import annotations

import threading
import time as _time
from concurrent.futures import Future, ThreadPoolExecutor
from dataclasses import dataclass, field, replace
from types import SimpleNamespace
from typing import Callable, List, Optional, Sequence

import jax
import numpy as np
from jax.flatten_util import ravel_pytree

from repro.core.aggregation import server_aggregate as fedqs_server_aggregate
from repro.core.aggregation import update_table
from repro.core.algorithms import Algorithm, FedQS
from repro.core.aggregation import aggregate_gradients, aggregate_models
from repro.core.types import (
    AggregationStrategy,
    FedQSHyperParams,
    Params,
    ServerTable,
    Update,
)
import jax.numpy as jnp

from repro.compress.codec import is_compressed
from repro.telemetry import (
    SECONDS_BUCKETS,
    STALENESS_BUCKETS,
    DeadlineAdapted,
    PartialAdmitted,
    RoundFired,
    Telemetry,
    UpdateAdmitted,
    UpdateRejected,
)

from .admission import AdmissionPolicy, AdmitAll
from .batched import fused_ingest_round, make_tree_sum, unravel_like
from .triggers import KBuffer, TriggerPolicy

# lookahead of the vectorized burst-admission walk: verdicts for this many
# updates are evaluated against one round snapshot; a mid-window fire
# invalidates the remainder (the round advanced), so larger windows only
# waste verdicts once rounds fire more often than every ~256 updates
_BURST_WINDOW = 256


@dataclass
class RoundReport:
    """What one aggregation fire produced (delivered via ``on_round``).

    ``buffer`` holds one record per aggregated *client update*.  On the
    flat service these are the full ``Update`` objects (tensor payloads
    included); on the hierarchical plane (``repro.hier``) they are
    metadata-only ``MemberRef`` records — cid, n_samples, stale_round,
    similarity, feedback — because partial aggregates do not retain
    per-member tensors.  Hooks that must work on both services should
    touch only that shared metadata surface.
    """

    round: int                 # round number after the fire
    n_updates: int             # client updates aggregated in the fire
    n_distinct: int            # distinct clients among them
    mean_staleness: float      # mean τ over the buffer (pre-fire round basis)
    max_staleness: int
    dropped_since_last: int    # admission drops since the previous fire
    trigger: str               # trigger.describe() at fire time
    agg_seconds: float         # host wall time of the aggregation call
    buffer: List = field(repr=False, default_factory=list)  # Update | MemberRef


@dataclass
class SubmitResult:
    accepted: bool
    fired: bool
    round: int                 # service round after this submit
    reason: str = ""           # admission reason when rejected/downweighted
    report: Optional[RoundReport] = None  # None for async fires (see on_round)


@dataclass
class ServiceStats:
    submitted: int = 0
    accepted: int = 0
    dropped: int = 0
    downweighted: int = 0
    partial: int = 0           # accepted with completed_fraction < 1
    rounds: int = 0
    agg_seconds: float = 0.0

    def __post_init__(self):
        # the pipelined service bumps counters from ingest threads and the
        # round-resolve path concurrently; bare `+=` is read-modify-write
        # and loses counts under contention (regression-pinned in
        # tests/test_pipeline.py), so every increment goes through bump()
        self._lock = threading.Lock()

    def bump(self, **deltas) -> None:
        """Atomically add ``deltas`` to the named counters."""
        with self._lock:
            for k, v in deltas.items():
                setattr(self, k, getattr(self, k) + v)


@dataclass
class BurstResult:
    """Aggregate outcome of one ``submit_burst`` call.  Per-update
    ``SubmitResult`` objects are deliberately not materialized — dodging
    that per-update allocation is half the point of the burst path; round
    reports still arrive through ``on_round`` / ``flush`` / ``drain``."""

    submitted: int = 0
    accepted: int = 0
    dropped: int = 0
    fired: int = 0             # rounds fired while draining the burst


@dataclass
class _PendingRound:
    """One fired-but-unresolved pipelined round.  Everything the resolve
    step needs to emit exactly what the synchronous path would have is
    captured at fire time — by resolution time the trigger has re-armed
    and ``service.round`` has moved on."""

    future: Optional[Future]
    round: int                 # report.round (the round this fire produces)
    now: float                 # fire-time stream clock
    members: List
    stale: List[int]
    dropped: int
    trigger_desc: str
    adapted: Optional[tuple]   # consume_adaptation() captured at fire
    pending_n: int             # len(_ingest) right after the swap


class StreamingAggregator:
    """Ingestion front-end + buffered aggregation back-end for SAFL.

    Presents the same server-state surface as ``SAFLEngine`` to the
    ``Algorithm`` interface (``global_params``, ``table``, ``round``,
    ``data.n_clients``, ``speeds``), so every algorithm's
    ``server_aggregate`` runs against it unchanged.  When embedded in the
    engine, ``context`` points back at the engine so algorithms that read
    engine-only state (e.g. FedAT's observed speeds) keep working.
    """

    def __init__(
        self,
        algo: Algorithm,
        hp: FedQSHyperParams,
        init_params: Params,
        n_clients: int,
        *,
        trigger: Optional[TriggerPolicy] = None,
        admission: Optional[AdmissionPolicy] = None,
        context=None,
        batched: bool = False,
        use_kernel: Optional[bool] = None,
        fused: Optional[bool] = None,
        async_agg: bool = False,
        pipeline: bool = False,
        on_round: Optional[Callable[[RoundReport], None]] = None,
        speeds: Optional[np.ndarray] = None,
        clock: Callable[[], float] = _time.monotonic,
        telemetry: Optional[Telemetry] = None,
    ):
        self.algo = algo
        self.hp = hp
        self.global_params = init_params
        self.table = ServerTable.init(n_clients)
        self.round = 0
        self.n_clients = int(n_clients)
        self.data = SimpleNamespace(n_clients=int(n_clients))  # Algorithm facade
        self.speeds = speeds
        self.trigger = trigger or KBuffer(hp.buffer_k)
        self.admission = admission or AdmitAll()
        self.stats = ServiceStats()
        self.on_round = on_round
        self._context = context
        self._clock = clock
        self._ingest: List[Update] = []
        self._dropped_since_fire = 0
        self._batched = batched
        self._tree_sum = (
            make_tree_sum(use_kernel, unravel_fn=self._unravel) if batched else None
        )
        # fused ingestion (kernels/ingest_agg): one jitted dispatch per
        # fire with the §3.4 weight fold on-device and the row axis
        # bucketed (batched.bucket_rows).  None → on whenever batched;
        # False → the pre-fusion batched path, bit-identical bookkeeping.
        # use_kernel=True forces the interpret-mode kernel body here too.
        self._fused = batched if fused is None else bool(fused)
        self._fused_mode = {True: "kernel", False: "ref"}.get(use_kernel)
        self._flat_cache = None   # flat [D] of global_params, if current...
        self._flat_src = None     # ...for exactly this params object
        self._pending_flat = None # handed from _dispatch to _aggregate
        self._pool = ThreadPoolExecutor(max_workers=1) if async_agg else None
        self._inflight: Optional[Future] = None
        # overlapped-round pipeline (docs/ARCHITECTURE.md "Overlapped
        # rounds"): round r's device dispatch runs on a single-worker
        # executor while ingestion admits round r+1 into the live buffer.
        # Mutually exclusive with async_agg (which serializes rounds by
        # joining *before* the next fire — a different overlap contract
        # pinned by tests/test_serve.py) and with an engine context (the
        # engine's virtual clock steps synchronously by construction).
        if pipeline and async_agg:
            raise ValueError(
                "pipeline and async_agg are mutually exclusive round-overlap "
                "modes: async_agg returns reports from the *firing* submit, "
                "the pipeline resolves them at the next fire / drain()")
        if pipeline and context is not None:
            raise ValueError(
                "pipeline=True serves live streams; an engine-embedded "
                "service (context=...) aggregates synchronously")
        self._pipeline = bool(pipeline)
        self._pipe_pool = (
            ThreadPoolExecutor(max_workers=1, thread_name_prefix="agg-pipe")
            if pipeline else None
        )
        self._pending_round: Optional[_PendingRound] = None
        # telemetry events held back while a round is in flight: the
        # in-flight round's events must precede them in the output stream
        # (flushed by _resolve_pending, preserving the synchronous order)
        self._deferred: List = []
        # guards the ingest plane (admission, buffer append, trigger check,
        # buffer swap) against concurrent submitters.  Reentrant because a
        # fire under the lock may join()/drain() and on_round hooks may call
        # back into the service.  The aggregation worker never takes it —
        # ingestion keeps admitting while the dispatch is in flight.
        self._lock = threading.RLock()
        # optional ClientCompressor attached by whoever encodes the stream
        # (engine / cohort / launcher); checkpointed with the service state
        self.compressor = None
        # telemetry hook (docs/OBSERVABILITY.md): None = fully disabled —
        # every emit site below is behind one `is not None` check, and no
        # telemetry code ever touches tensors, so aggregation results are
        # bit-identical either way (gated in benchmarks/bench_serve.py)
        self.telemetry = telemetry
        # span tracer (docs/OBSERVABILITY.md "Tracing"): present only when
        # the hub carries one; cached so every trace site is one `is None`
        # check (the serve_trace_overhead gate)
        self._tracer = telemetry.tracer if telemetry is not None else None
        # health monitor (docs/OBSERVABILITY.md "Training health"): same
        # zero-overhead contract — cached once, every observe site is one
        # `is None` check.  When present, fused dense rounds route through
        # the stats_agg kernel (bit-identical aggregate) so the detectors
        # see the per-round stability vector.
        self._health = telemetry.health if telemetry is not None else None
        self._pending_stats = None  # handed from _fused_round to _aggregate
        self._last_tid = -1
        self._ingest_t: List = []  # (trace id, admit-exit perf_counter)
        self._span_round = -1      # round id sub-stage spans attach to
        if telemetry is not None:
            m = telemetry.metrics
            self._tm_submitted = m.counter("serve.submitted",
                                           unit="updates", layer="serve")
            self._tm_accepted = m.counter("serve.accepted",
                                          unit="updates", layer="serve")
            self._tm_rejected = m.counter("serve.rejected",
                                          unit="updates", layer="serve")
            self._tm_downweighted = m.counter("serve.downweighted",
                                              unit="updates", layer="serve")
            self._tm_rounds = m.counter("serve.rounds",
                                        unit="rounds", layer="serve")
            self._tm_staleness = m.histogram("serve.staleness",
                                             STALENESS_BUCKETS,
                                             unit="rounds", layer="serve")
            self._tm_admit_s = m.histogram("serve.admission_seconds",
                                           SECONDS_BUCKETS,
                                           unit="s", layer="serve")
            self._tm_agg_s = m.histogram("serve.agg_seconds",
                                         SECONDS_BUCKETS,
                                         unit="s", layer="serve")
            self._tm_pending = m.gauge("serve.pending",
                                       unit="updates", layer="serve")
            self._tm_round = m.gauge("serve.round",
                                     unit="rounds", layer="serve")
        # the trigger arms itself lazily at the first submit — the service
        # cannot arm it here because callers may drive any clock (virtual
        # time in the simulator, wall time live)

    # ------------------------------------------------------------- ingestion
    def submit(self, update: Update, now: Optional[float] = None) -> SubmitResult:
        """Offer one client update to the service.

        Admission runs against the current round; on acceptance the update
        joins the ingest buffer and the trigger policy is consulted.  A
        firing trigger swaps the buffer (ingestion continues immediately)
        and aggregates the frozen batch.
        """
        now = self._clock() if now is None else now
        with self._lock:
            update, verdict = self._admit(update, now)
            if update is None:
                return SubmitResult(False, False, self.round, verdict.reason)
            self._buffer_admitted(update, now)
            if self.trigger.should_fire(self._trigger_view(), now):
                report = self._fire(now)
                return SubmitResult(True, True, self.round, verdict.reason,
                                    report)
            return SubmitResult(True, False, self.round, verdict.reason)

    def submit_burst(self, updates: Sequence[Update],
                     now: Optional[float] = None) -> BurstResult:
        """Admit one arrival burst (updates sharing a delivery timestamp).

        Semantically identical to calling ``submit`` per update in order —
        the bit-identity pin in tests/test_pipeline.py — but when the
        admission policy exposes a vectorized verdict (``admit_mask``) and
        no telemetry/tracer demands per-update event objects, the
        per-update Python prologue collapses into a few numpy passes per
        lookahead window.  Combined with ``pipeline=True`` this is the
        serve_saturation fast path (benchmarks/bench_serve.py).
        """
        now = self._clock() if now is None else now
        updates = updates if isinstance(updates, list) else list(updates)
        with self._lock:
            if (self.telemetry is not None or self._tracer is not None
                    or getattr(self.admission, "admit_mask", None) is None):
                return self._burst_slow(updates, now)
            return self._burst_fast(updates, now)

    def _burst_slow(self, updates: List[Update], now: float) -> BurstResult:
        """Reference burst path: the per-update pipeline, verbatim — taken
        whenever an observer (telemetry/tracer) needs per-update events or
        the admission policy has no batched verdict."""
        res = BurstResult(submitted=len(updates))
        for u in updates:
            u2, _ = self._admit(u, now)
            if u2 is None:
                res.dropped += 1
                continue
            res.accepted += 1
            self._buffer_admitted(u2, now)
            if self.trigger.should_fire(self._trigger_view(), now):
                self._fire(now)
                res.fired += 1
        return res

    def _burst_fast(self, updates: List[Update], now: float) -> BurstResult:
        """Vectorized burst admission (telemetry off).

        Verdicts are evaluated for a whole lookahead window against the
        *current* round in one ``admit_mask`` call; the walk then appends
        admitted updates and consults the trigger per append, exactly as
        the per-update path would.  A fire inside the window advances the
        round, so the remaining updates are re-windowed and re-judged
        fresh — staleness verdicts never go stale mid-burst.  Adaptive
        triggers see every arrival through ``observe_batch`` in segments
        that close *before* each re-arm, reproducing the per-update
        observation history bit-for-bit.
        """
        res = BurstResult(submitted=len(updates))
        trigger = self.trigger
        observe = getattr(trigger, "observe", None)
        observe_batch = getattr(trigger, "observe_batch", None)

        def _observe_upto(hi: int, lo: int) -> int:
            if observe is None or lo >= hi:
                return hi
            if observe_batch is not None:
                observe_batch(updates[lo:hi], now)
            else:
                for uu in updates[lo:hi]:
                    observe(uu, now)
            return hi

        n = len(updates)
        i = 0        # next update to admit
        obs_lo = 0   # arrivals not yet shown to the trigger's observer
        acc = drp = dwn = par = 0
        while i < n:
            rnd = self.round
            window = updates[i:i + _BURST_WINDOW]
            cf = np.asarray([u.completed_fraction for u in window])
            stale = np.asarray([u.stale_round for u in window], np.int64)
            stale_c = np.minimum(stale, rnd)  # future-round clamp (cf _admit)
            mask, scales = self.admission.admit_mask(stale_c, rnd)
            keep = (cf > 0.0) & mask
            for j, u in enumerate(window):
                if not keep[j]:
                    drp += 1
                    self._dropped_since_fire += 1
                    continue
                changed = {}
                if stale_c[j] != stale[j]:
                    changed["stale_round"] = int(rnd)
                if cf[j] > 1.0:
                    changed["completed_fraction"] = 1.0
                s = float(scales[j])
                if s != 1.0:
                    dwn += 1
                    changed["n_samples"] = max(1, int(round(u.n_samples * s)))
                if changed:
                    u = replace(u, **changed)
                if u.completed_fraction < 1.0:
                    par += 1
                acc += 1
                self._buffer_admitted(u, now)
                if trigger.should_fire(self._trigger_view(), now):
                    obs_lo = _observe_upto(i + j + 1, obs_lo)
                    self._fire(now)
                    res.fired += 1
                    i = i + j + 1
                    break
            else:
                i += len(window)
        _observe_upto(n, obs_lo)
        self.stats.bump(submitted=len(updates), accepted=acc, dropped=drp,
                        downweighted=dwn, partial=par)
        res.accepted, res.dropped = acc, drp
        return res

    def _buffer_admitted(self, update: Update, now: float) -> None:
        """Place one admitted update into the ingest plane (the
        hierarchical service overrides this to route through its tier
        topology instead of the flat buffer)."""
        self._ingest.append(update)
        if self._tracer is not None:
            self._ingest_t.append((self._last_tid, _time.perf_counter()))

    def _trigger_view(self):
        """What the trigger policy inspects after each admit (the
        hierarchical service shows a member-count view of partials)."""
        return self._ingest

    def _admit(self, update, now: float):
        """The admission prologue every ingestion front-end shares (the
        hierarchical service routes to tiers instead of one buffer but
        must admit identically): stats, future-round clamp, policy
        verdict, drop/downweight bookkeeping, telemetry.  Returns
        ``(None, verdict)`` on rejection."""
        tel = self.telemetry
        tr = self._tracer
        t0 = _time.perf_counter() if tel is not None else 0.0
        if tr is not None:
            self._last_tid = tr.new_trace()
        if update.stale_round > self.round:
            # no update can be trained on a future round — a live gateway
            # stamps τ against its own round registry, so clamp here
            update = replace(update, stale_round=self.round)
        tau = self.round - update.stale_round
        # adaptive triggers learn the deadline from delivery latencies;
        # they must see every arrival, admitted or not — conditioning on
        # admission would bias the history toward survivors (fast
        # clients) and collapse the window exactly when stragglers are
        # being dropped, the case the adaptation exists to fix
        observe = getattr(self.trigger, "observe", None)
        if observe is not None:
            observe(update, now)
        admitted, verdict = self.admission.apply(update, self.round)
        if admitted is None:
            self.stats.bump(submitted=1, dropped=1)
            self._dropped_since_fire += 1
            if tel is not None:
                self._tm_submitted.inc()
                self._tm_rejected.inc()
                self._tm_admit_s.observe(_time.perf_counter() - t0)
                self._emit_event(UpdateRejected(
                    t=float(now), round=self.round, cid=int(update.cid),
                    stale_round=int(update.stale_round), staleness=int(tau),
                    reason=verdict.reason,
                ))
            if tr is not None:
                tr.record("admit", "update", t0,
                          _time.perf_counter() - t0, tid=self._last_tid)
            return None, verdict
        downweighted = verdict.weight_scale != 1.0
        cf = float(getattr(admitted, "completed_fraction", 1.0))
        partial = cf < 1.0
        self.stats.bump(submitted=1, accepted=1,
                        downweighted=int(downweighted), partial=int(partial))
        if tel is not None:
            self._tm_submitted.inc()
            self._tm_accepted.inc()
            if downweighted:
                self._tm_downweighted.inc()
            self._tm_admit_s.observe(_time.perf_counter() - t0)
            self._emit_event(UpdateAdmitted(
                t=float(now), round=self.round, cid=int(admitted.cid),
                n_samples=int(admitted.n_samples),
                stale_round=int(admitted.stale_round), staleness=int(tau),
                downweighted=downweighted,
            ))
            if partial:
                self._emit_event(PartialAdmitted(
                    t=float(now), round=self.round, cid=int(admitted.cid),
                    completed_fraction=cf,
                ))
        if tr is not None:
            tr.record("admit", "update", t0, _time.perf_counter() - t0,
                      tid=self._last_tid)
        return admitted, verdict

    def _emit_event(self, event) -> None:
        """Telemetry emit that respects the pipeline boundary: while a
        round is in flight its events must come first in the output
        stream, so ingest-side events are held back and flushed by
        ``_resolve_pending`` — the emitted sequence reads exactly like the
        synchronous service's.  With nothing in flight (always true off
        the pipeline) this is a plain emit.  Only ever called under a
        ``telemetry is not None`` guard."""
        if self._pending_round is not None:
            self._deferred.append(event)
        else:
            self.telemetry.emit(event)

    def flush(self, now: Optional[float] = None) -> Optional[RoundReport]:
        """Force-aggregate whatever is buffered (end of stream / sync mode
        with fewer live clients than K).  Returns None only for the
        empty-buffer no-op — a flush is a barrier, so an async service
        joins the dispatched round and a pipelined service resolves the
        flush-fired round; both return its report."""
        with self._lock:
            if not self._ingest:
                if self._pipeline:
                    return self._resolve_pending()
                return None
            report = self._fire(self._clock() if now is None else now)
            if self._pipeline:
                return self._resolve_pending()
            if report is None and self._inflight is not None:
                report = self._inflight.result()
                self._inflight = None
            return report

    @property
    def pending(self) -> int:
        return len(self._ingest)

    def drain(self) -> Optional[RoundReport]:
        """Resolve the in-flight pipelined round, if any: install its
        params/table, emit its report/telemetry, and flush any deferred
        ingest events.  Idempotent — with nothing in flight it is a no-op
        returning None (tests/test_pipeline.py pins both)."""
        with self._lock:
            return self._resolve_pending()

    def join(self) -> None:
        """Block until any in-flight aggregation has completed — the
        async_agg worker round, or the pipelined round (which is fully
        resolved, so post-join state is checkpoint-consistent)."""
        self.drain()
        if self._inflight is not None:
            self._inflight.result()
            self._inflight = None

    def close(self) -> None:
        self.join()
        if self._pool is not None:
            self._pool.shutdown(wait=True)
            self._pool = None
        if self._pipe_pool is not None:
            self._pipe_pool.shutdown(wait=True)
            self._pipe_pool = None

    # ----------------------------------------------------------- aggregation
    def _fire(self, now: float) -> Optional[RoundReport]:
        if self._pipeline:
            # resolve round r before firing r+1: its report and events must
            # precede the new batch's in the output stream, and its device
            # results must be installed before the worker job that reads
            # them (global_params / table / flat cache) is enqueued
            self._resolve_pending()
        # double-buffer swap: new submissions land in a fresh list while
        # the frozen batch aggregates
        batch, self._ingest = self._ingest, []
        batch_t: Optional[List] = None
        if self._tracer is not None:
            batch_t, self._ingest_t = self._ingest_t, []
        self.trigger.arm(now)
        dropped, self._dropped_since_fire = self._dropped_since_fire, 0
        if self._pipeline:
            return self._fire_pipelined(batch, dropped, now, batch_t)
        if self._pool is None:
            return self._aggregate(batch, dropped, now, batch_t)
        self.join()  # rounds serialize: at most one aggregation in flight
        self._inflight = self._pool.submit(self._aggregate, batch, dropped,
                                           now, batch_t)
        return None

    def _fire_pipelined(self, batch: List[Update], dropped: int, now: float,
                        batch_t: Optional[List]) -> None:
        """Stage-0 of the overlapped round: freeze everything the resolve
        step will need (members, staleness, trigger description, deadline
        adaptation — all judged against the *pre-arm, pre-next-round*
        state the synchronous path would see), advance the round so
        admission immediately runs against it, and hand the device work to
        the single-worker executor.  Returns None — the report surfaces at
        the next fire or ``drain()`` via ``on_round``."""
        rnd = self.round + 1
        tr = self._tracer
        if tr is not None and batch_t:
            fire_t = _time.perf_counter()
            for tid, t_in in batch_t:
                tr.record("buffer", "update", t_in, fire_t - t_in,
                          round=rnd, tid=tid)
        members = self._batch_members(batch)
        stale = [self.round - u.stale_round for u in members]
        # the round advances NOW: overlapped-window admissions must judge
        # staleness against the round being produced, exactly as they
        # would after a synchronous fire returned
        self.round += 1
        adapted = None
        if self.telemetry is not None:
            ca = getattr(self.trigger, "consume_adaptation", None)
            if ca is not None:
                adapted = ca()
        pend = _PendingRound(
            future=None, round=rnd, now=now, members=members, stale=stale,
            dropped=dropped, trigger_desc=self.trigger.describe(),
            adapted=adapted, pending_n=len(self._ingest),
        )
        pend.future = self._pipe_pool.submit(self._compute_round, batch, rnd)
        self._pending_round = pend
        return None

    def _compute_round(self, batch: List[Update], rnd: int):
        """Stage-1, on the worker: dispatch the round and block for the
        device.  Runs WITHOUT the service lock — that is the tentpole:
        ingestion keeps admitting while this blocks.  The worker only
        reads server state (global_params/table/flat cache) installed by
        the resolve step *before* this job was enqueued, so the executor
        queue provides the happens-before edge; the §3.4 handshake state
        (_pending_flat/_pending_stats) is produced and consumed entirely
        on this thread."""
        t0 = _time.perf_counter()
        self._span_round = rnd
        new_global, new_table = self._dispatch(self, batch)
        jax.block_until_ready(jax.tree_util.tree_leaves(new_global))
        dt = _time.perf_counter() - t0
        if self._tracer is not None:
            self._tracer.record("dispatch", "serve", t0, dt, round=rnd)
        stats_vec, self._pending_stats = self._pending_stats, None
        pflat, self._pending_flat = self._pending_flat, None
        return new_global, new_table, dt, stats_vec, pflat, t0

    def _resolve_pending(self) -> Optional[RoundReport]:
        """Stage-2, back under the lock: install the worker's results and
        emit everything the synchronous path would have emitted at this
        round's finalize — then release the deferred ingest events that
        arrived while the round was in flight."""
        pend = self._pending_round
        if pend is None:
            return None
        new_global, new_table, dt, stats_vec, pflat, t0 = pend.future.result()
        self._pending_round = None
        tr = self._tracer
        f0 = _time.perf_counter() if tr is not None else 0.0
        self.global_params = new_global
        self.table = new_table
        if pflat is not None:
            self._flat_cache, self._flat_src = pflat, new_global
        self.stats.bump(rounds=1, agg_seconds=dt)
        report = RoundReport(
            round=pend.round,
            n_updates=len(pend.members),
            n_distinct=len({u.cid for u in pend.members}),
            mean_staleness=float(np.mean(pend.stale)) if pend.stale else 0.0,
            max_staleness=int(max(pend.stale)) if pend.stale else 0,
            dropped_since_last=pend.dropped,
            trigger=pend.trigger_desc,
            agg_seconds=dt,
            buffer=pend.members,
        )
        tel = self.telemetry
        if tel is not None:
            if pend.adapted is not None:
                old_w, new_w, q_lat = pend.adapted
                tel.emit(DeadlineAdapted(
                    t=float(pend.now), round=pend.round,
                    old_window=float(old_w), new_window=float(new_w),
                    quantile_latency=float(q_lat),
                ))
            self._tm_rounds.inc()
            self._tm_agg_s.observe(dt)
            for s in pend.stale:
                self._tm_staleness.observe(s)
            self._tm_round.set(pend.round)
            self._tm_pending.set(pend.pending_n)
            tel.emit(RoundFired(
                t=float(pend.now), round=pend.round,
                n_updates=report.n_updates, n_distinct=report.n_distinct,
                mean_staleness=report.mean_staleness,
                max_staleness=report.max_staleness,
                dropped_since_last=pend.dropped, trigger=report.trigger,
                agg_seconds=dt,
                members=[[int(u.cid), int(u.n_samples), int(u.stale_round)]
                         for u in pend.members],
            ))
        hm = self._health
        if hm is not None:
            hm.observe_round(t=float(pend.now), round=pend.round,
                             mean_staleness=report.mean_staleness,
                             stats=stats_vec)
        if self.on_round is not None:
            self.on_round(report)
        if tr is not None:
            end = _time.perf_counter()
            tr.record("finalize", "serve", f0, end - f0, round=pend.round)
            # the pipelined round span sums its *active* stages — dispatch
            # on the worker plus finalize here; the wall gap between them
            # is overlap with ingestion, not round work, so critical-path
            # coverage stays 1.0 (docs/OBSERVABILITY.md "Overlapped rounds")
            tr.record("round", "serve", t0, dt + (end - f0), round=pend.round)
        if self._deferred:
            for ev in self._deferred:
                tel.emit(ev)
            self._deferred.clear()
        return report

    def _aggregate(self, batch: List[Update], dropped: int,
                   now: float = 0.0,
                   batch_t: Optional[List] = None) -> RoundReport:
        tr = self._tracer
        rnd = self.round + 1  # the round this fire produces (report.round)
        if tr is not None:
            self._span_round = rnd
            if batch_t:
                # buffer residency: admission exit → aggregation start,
                # one span per traced update in the frozen batch
                fire_t = _time.perf_counter()
                for tid, t_in in batch_t:
                    tr.record("buffer", "update", t_in, fire_t - t_in,
                              round=rnd, tid=tid)
        t0 = _time.perf_counter()
        ctx = self._context if self._context is not None else self
        new_global, new_table = self._dispatch(ctx, batch)
        jax.block_until_ready(jax.tree_util.tree_leaves(new_global))
        dt = _time.perf_counter() - t0
        if tr is not None:
            tr.record("dispatch", "serve", t0, dt, round=rnd)
        f0 = _time.perf_counter() if tr is not None else 0.0

        # the report describes *client updates*; a subclass whose batch
        # items fold several of them (hierarchical partials) expands here
        members = self._batch_members(batch)
        stale = [self.round - u.stale_round for u in members]
        self.global_params = new_global
        self.table = new_table
        if self._pending_flat is not None:
            # the fused round produced new_global by unraveling this very
            # vector, so caching it skips the re-ravel on the next fire
            self._flat_cache, self._flat_src = self._pending_flat, new_global
            self._pending_flat = None
        self.round += 1
        self.stats.bump(rounds=1, agg_seconds=dt)
        report = RoundReport(
            round=self.round,
            n_updates=len(members),
            n_distinct=len({u.cid for u in members}),
            mean_staleness=float(np.mean(stale)) if stale else 0.0,
            max_staleness=int(max(stale)) if stale else 0,
            dropped_since_last=dropped,
            trigger=self.trigger.describe(),
            agg_seconds=dt,
            buffer=members,
        )
        tel = self.telemetry
        if tel is not None:
            adapted = getattr(self.trigger, "consume_adaptation", None)
            adapted = adapted() if adapted is not None else None
            if adapted is not None:
                old_w, new_w, q_lat = adapted
                tel.emit(DeadlineAdapted(
                    t=float(now), round=self.round,
                    old_window=float(old_w), new_window=float(new_w),
                    quantile_latency=float(q_lat),
                ))
            self._tm_rounds.inc()
            self._tm_agg_s.observe(dt)
            for s in stale:
                self._tm_staleness.observe(s)
            self._tm_round.set(self.round)
            self._tm_pending.set(len(self._ingest))
            tel.emit(RoundFired(
                t=float(now), round=self.round,
                n_updates=report.n_updates, n_distinct=report.n_distinct,
                mean_staleness=report.mean_staleness,
                max_staleness=report.max_staleness,
                dropped_since_last=dropped, trigger=report.trigger,
                agg_seconds=dt,
                members=[[int(u.cid), int(u.n_samples), int(u.stale_round)]
                         for u in members],
            ))
        hm = self._health
        if hm is not None:
            stats_vec, self._pending_stats = self._pending_stats, None
            hm.observe_round(t=float(now), round=self.round,
                             mean_staleness=report.mean_staleness,
                             stats=stats_vec)
        if self.on_round is not None:
            self.on_round(report)
        if tr is not None:
            end = _time.perf_counter()
            tr.record("finalize", "serve", f0, end - f0, round=rnd)
            tr.record("round", "serve", t0, end - t0, round=rnd)
        return report

    def _batch_members(self, batch: List[Update]) -> List[Update]:
        """The per-client-update view of one frozen batch (what the
        round report counts and carries); the flat buffer IS that view."""
        return batch

    def _unravel(self):
        """Flat-[D] → model-pytree closure of the served model (cached per
        structure in ``repro.serve.batched``) — what the compressed paths
        use to rebuild aggregates and decode payloads."""
        return unravel_like(self.global_params)

    def _densify(self, batch: List[Update]) -> List[Update]:
        """Decode any ``CompressedUpdate`` in the batch into a dense
        ``Update`` — the fallback for algorithms (or the sequential path)
        that need real pytrees.  Dense updates pass through untouched."""
        if not any(is_compressed(u) for u in batch):
            return batch
        unravel = self._unravel()
        return [u.to_update(unravel) if is_compressed(u) else u for u in batch]

    def _dispatch(self, ctx, batch: List[Update]):
        """Route one frozen batch to the algorithm.

        The batched fast path only applies to algorithms whose aggregation
        is a pure weighted reduction with externally computed weights —
        FedQS itself and any algorithm still on the base
        ``Algorithm.server_aggregate`` (FedAvg/FedSGD/DeFedAvg).  Stateful
        baselines (caches, momenta, EMAs) always take their own path.

        Compressed buffers stay encoded on the batched fast path — the
        tree_sum stacks quantized rows and dispatches the fused
        ``dequant_agg`` kernel; every other path decodes first.
        """
        if not self._batched:
            batch = self._densify(batch)
        elif any(is_compressed(u) for u in batch) and not all(
            is_compressed(u) for u in batch
        ):
            # the stacked tree_sum needs a homogeneous buffer; a stream
            # mixing wire formats decodes the compressed minority
            batch = self._densify(batch)
        if self._batched and self._fused and isinstance(self.algo, FedQS):
            out = self._fused_round(ctx, batch)
            if out is not None:
                return out
        if self._batched and isinstance(self.algo, FedQS):
            new_global, new_table, _ = fedqs_server_aggregate(
                self.algo.strategy, ctx.global_params, batch, ctx.table,
                self.hp, ctx.data.n_clients, tree_sum=self._tree_sum,
            )
            return new_global, new_table
        if self._batched and type(self.algo).server_aggregate is Algorithm.server_aggregate:
            cids = jnp.asarray([u.cid for u in batch], jnp.int32)
            sims = jnp.asarray([u.similarity for u in batch], jnp.float32)
            new_table = update_table(ctx.table, cids, sims)
            p = self.algo._base_weights(batch)
            if self.algo.strategy is AggregationStrategy.GRADIENT:
                new_global = aggregate_gradients(
                    ctx.global_params, [u.delta for u in batch], p,
                    self.hp.eta_g, tree_sum=self._tree_sum,
                )
            else:
                new_global = aggregate_models(
                    [u.params for u in batch], p, tree_sum=self._tree_sum
                )
            return new_global, new_table
        return self.algo.server_aggregate(ctx, self._densify(batch))

    def _fused_round(self, ctx, batch):
        """The fused-ingestion round (``repro.serve.batched``): flat
        global in, flat global out — so successive fused rounds never
        re-ravel the model, and the §3.4 weighting runs inside the
        ``ingest_agg`` kernel.  Returns None when the batch shape cannot
        fuse (missing payloads); the caller then falls through to the
        unfused batched dispatch."""
        if ctx.global_params is self._flat_src and self._flat_cache is not None:
            flat_g = self._flat_cache
        else:
            flat_g, _ = ravel_pytree(ctx.global_params)
        want_stats = self._health is not None
        out = fused_ingest_round(
            batch, ctx.table, flat_g, self.hp, ctx.data.n_clients,
            self.algo.strategy, mode=self._fused_mode,
            tracer=self._tracer, span_round=self._span_round,
            stats=want_stats,
        )
        if out is None:
            return None
        if want_stats:
            new_flat, new_table, self._pending_stats = out
        else:
            new_flat, new_table = out
        self._pending_flat = new_flat
        return self._unravel()(new_flat), new_table

    # ------------------------------------------------------------ checkpoint
    def save(self, path: str) -> None:
        from repro.checkpoint.ckpt import save_service_state

        self.join()
        if self._tracer is not None:
            with self._tracer.span("save", "ckpt", round=self.round):
                save_service_state(path, self)
        else:
            save_service_state(path, self)

    def restore(self, path: str) -> None:
        from repro.checkpoint.ckpt import load_service_state

        self.join()
        self._flat_cache = self._flat_src = self._pending_flat = None
        load_service_state(path, self)
