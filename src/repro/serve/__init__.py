"""Streaming SAFL aggregation service (runtime layer 2).

Generalizes the virtual-clock engine's K-buffer loop into a live
ingestion pipeline: staleness-bounded admission → pluggable trigger
(K-buffer / time-window / quorum) → batched aggregation (Pallas
``weighted_agg`` on TPU, jnp fallback) with double-buffered ingest and
checkpoint/resume.  See docs/ARCHITECTURE.md.
"""
from .admission import Admission, AdmissionPolicy, AdmitAll, StalenessAdmission
from .batched import (
    batched_weighted_sum,
    compressed_weighted_sum,
    make_tree_sum,
    stack_encoded,
    stack_trees,
    unravel_like,
)
from .service import (
    BurstResult,
    RoundReport,
    ServiceStats,
    StreamingAggregator,
    SubmitResult,
)
from .stream import (
    CaptureStream,
    flatten_bursts,
    replay,
    replay_bursts,
    scenario_stream,
    synthetic_stream,
    zipf_burst_stream,
)
from .triggers import (
    AdaptiveTimeWindow,
    KBuffer,
    Quorum,
    TimeWindow,
    TriggerPolicy,
    make_trigger,
)

__all__ = [
    "Admission", "AdmissionPolicy", "AdmitAll", "StalenessAdmission",
    "batched_weighted_sum", "compressed_weighted_sum", "make_tree_sum",
    "stack_encoded", "stack_trees", "unravel_like",
    "BurstResult", "RoundReport", "ServiceStats", "StreamingAggregator",
    "SubmitResult",
    "CaptureStream", "flatten_bursts", "replay", "replay_bursts",
    "scenario_stream", "synthetic_stream", "zipf_burst_stream",
    "AdaptiveTimeWindow", "KBuffer", "Quorum", "TimeWindow", "TriggerPolicy",
    "make_trigger",
]
