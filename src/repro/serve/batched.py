"""Batched (stacked) Mod-3 aggregation for the streaming service.

The K buffered updates are flattened into one ``[K, D]`` matrix and the
weighted reduction Σ_k w[k]·x[k] runs as a single matvec:

* on TPU it dispatches to the Pallas ``weighted_agg`` kernel
  (``repro.kernels.weighted_agg``) — every parameter byte crosses HBM
  exactly once;
* elsewhere it falls back to the pure-jnp oracle (one fused einsum) —
  interpret-mode Pallas is far too slow for a hot ingestion loop.

Compressed buffers (``repro.compress``) skip the decode entirely: int8
payloads are stacked as quantized rows (sparse ones scattered into
dense int8) and handed to the fused ``dequant_agg`` kernel, which
dequantizes in VMEM during the reduction — ≈ 4× less HBM traffic than
even the dense path.  Raw-f32 top-k payloads decode to dense rows and
take the ``weighted_agg`` path.

This is numerically a reordering of ``repro.core.types.tree_weighted_sum``
(sequential scale+add), so results agree to fp32 tolerance, not bitwise;
the virtual-clock engine therefore keeps the sequential form by default
and the streaming service opts in.
"""
from __future__ import annotations

import functools
import time

from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.flatten_util import ravel_pytree

from repro.compress.codec import Encoded, decode
from repro.core.types import AggregationStrategy, Params
from repro.kernels import dequant_agg_auto_op, weighted_agg_auto_op
from repro.kernels.autotune import get_config
from repro.kernels.dequant_agg import dequant_agg
from repro.kernels.ingest_agg import ingest_agg
from repro.kernels.ref import (dequant_agg_ref, ingest_agg_ref,
                               stats_agg_ref, weighted_agg_ref)
from repro.kernels.stats_agg import round_stats, stats_agg
from repro.kernels.weighted_agg import weighted_agg

# unravel closures keyed by (treedef, leaf avals): the buffer carries the
# same model structure round after round, so the closure (and the ravel
# bookkeeping inside it) is built once, not per fire
_UNRAVEL_CACHE: Dict[tuple, Callable[[jnp.ndarray], Params]] = {}

# stack-call observability: every [K, D] stacking of a frozen buffer bumps
# one of these.  A trigger fire must build its stacked matrix exactly once
# (pinned by tests/test_ingest.py) — re-stacking per fire was the
# serve_timewindow regression this guards against.
STACK_CALLS: Dict[str, int] = {"trees": 0, "encoded": 0}


def _tree_key(leaves, treedef) -> tuple:
    return (treedef, tuple((l.shape, jnp.result_type(l)) for l in leaves))


def unravel_like(tree: Params) -> Callable[[jnp.ndarray], Params]:
    """Cached flat-[D] → pytree closure for ``tree``'s structure."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    key = _tree_key(leaves, treedef)
    unravel = _UNRAVEL_CACHE.get(key)
    if unravel is None:
        _, unravel = ravel_pytree(tree)
        _UNRAVEL_CACHE[key] = unravel
    return unravel


@jax.jit
def _stack_rows(all_leaves):
    # one fused ravel+cast+concat+stack over the whole buffer; jax caches
    # the compilation per (treedef, avals) of the nested leaf list so
    # steady state is a single dispatch
    return jnp.stack([
        jnp.concatenate([l.reshape(-1).astype(jnp.float32) for l in leaves])
        for leaves in all_leaves])


def stack_trees(trees: List[Params]) -> Tuple[jnp.ndarray, Callable[[jnp.ndarray], Params]]:
    """Ravel each pytree to a row of a [K, D] f32 matrix; returns the matrix
    and the (cached) unravel closure mapping a flat [D] vector back to the
    pytree.  All trees must share one structure — a buffer mixing model
    shapes is a caller bug and raises instead of silently unraveling rows
    with the first tree's closure.

    The stacking is ONE jitted ravel/cast/concat/stack dispatch over the
    whole buffer — not per-tree eager ops.  Profiling the serve round
    showed the old per-tree form cost ~90 host dispatches per fire
    (K=10 × 4 leaves × ravel/astype/concat), several ms/round on CPU,
    dwarfing the aggregation math itself."""
    if not trees:
        raise ValueError("cannot stack an empty buffer")
    STACK_CALLS["trees"] += 1
    leaves0, treedef0 = jax.tree_util.tree_flatten(trees[0])
    unravel = unravel_like(trees[0])
    all_leaves = [leaves0]
    for t in trees[1:]:
        leaves, treedef = jax.tree_util.tree_flatten(t)
        if treedef != treedef0:
            raise ValueError(
                f"buffer mixes pytree structures: {treedef} vs {treedef0}"
            )
        all_leaves.append(leaves)
    if not leaves0:
        return jnp.zeros((len(trees), 0), jnp.float32), unravel
    return _stack_rows(all_leaves), unravel


def batched_weighted_sum(
    trees: List[Params],
    weights,
    *,
    use_kernel: Optional[bool] = None,
) -> Params:
    """Σ_i w_i · tree_i via the stacked [K, D] matvec.

    ``use_kernel``: None → auto (Pallas on TPU, jnp einsum elsewhere);
    True → force the Pallas kernel (interpreted off-TPU, for validation);
    False → force the jnp oracle.

    Drop-in for ``tree_weighted_sum`` — pass as the ``tree_sum`` argument
    of ``repro.core.aggregation.server_aggregate``.
    """
    x, unravel = stack_trees(trees)
    w = jnp.asarray(weights, jnp.float32)
    if use_kernel is None:
        flat = weighted_agg_auto_op(x, w)
    elif use_kernel:
        flat = weighted_agg(x, w, interpret=jax.default_backend() != "tpu")
    else:
        flat = weighted_agg_ref(x, w)
    return unravel(flat)


# ------------------------------------------------------------- compressed
def fused_eligible(encs: Sequence[Encoded]) -> bool:
    """True when the buffer can feed ``dequant_agg`` directly: every
    payload int8-quantized with one shared (chunk, decoded-dim)."""
    if not encs:
        return False
    first = encs[0]
    return all(
        e.is_quantized and e.chunk == first.chunk and e.d == first.d
        for e in encs
    )


def stack_encoded(encs: Sequence[Encoded]) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Stack quantized payloads into dense int8 rows + scale rows for the
    fused kernel.  Sparse payloads scatter into zeros — their per-chunk
    scales are already defined over the decoded axis (``repro.compress``),
    so the scattered row dequantizes identically."""
    STACK_CALLS["encoded"] += 1
    nc = encs[0].scales.shape[0]
    dp = nc * encs[0].chunk
    rows, srows = [], []
    for e in encs:
        if e.indices is None:
            rows.append(e.data)
        else:
            rows.append(
                jnp.zeros((dp,), jnp.int8)
                .at[e.indices.astype(jnp.int32)].set(e.data)
            )
        srows.append(e.scales)
    return jnp.stack(rows), jnp.stack(srows)


def compressed_weighted_sum(
    encs: Sequence[Encoded],
    weights,
    unravel: Callable[[jnp.ndarray], Params],
    *,
    use_kernel: Optional[bool] = None,
) -> Params:
    """Σ_i w_i · decode(enc_i) without materializing decoded rows in HBM
    when the buffer is int8 (the fused kernel path)."""
    if not encs:
        raise ValueError("cannot aggregate an empty compressed buffer")
    w = jnp.asarray(weights, jnp.float32)
    d = encs[0].d
    if fused_eligible(encs):
        q, scales = stack_encoded(encs)
        chunk = encs[0].chunk
        if use_kernel is None:
            flat = dequant_agg_auto_op(q, scales, w, chunk=chunk)
        elif use_kernel:
            flat = dequant_agg(q, scales, w, chunk=chunk,
                               interpret=jax.default_backend() != "tpu")
        else:
            flat = dequant_agg_ref(q, scales, w)
        return unravel(flat[:d])
    # raw-f32 top-k (or heterogeneous) buffers: decode to dense rows and
    # take the dense kernel path
    x = jnp.stack([decode(e) for e in encs])
    if use_kernel:
        flat = weighted_agg(x, w, interpret=jax.default_backend() != "tpu")
    elif use_kernel is None:
        flat = weighted_agg_auto_op(x, w)
    else:
        flat = weighted_agg_ref(x, w)
    return unravel(flat)


# --------------------------------------------------------------- fused round
def bucket_rows(k: int) -> int:
    """Row-axis shape bucket: K padded up to a power of two (≥ 4).

    Variable-K triggers (time-window, quorum grace) produce a different
    buffer length every fire; without bucketing every length is a fresh
    XLA compile — profiling the serve_timewindow benchmark showed ~5.5 s
    of its 9.4 s aggregate wall time was backend_compile across 364 pjit
    cache misses.  Bucketing caps compiles at log2(K_max) per payload
    shape; padding rows carry ``n_samples = fb = 0`` and weigh exactly 0.
    """
    return max(4, 1 << max(int(k) - 1, 0).bit_length())


def _round_meta(counts, tsims, cids, sims, ratio_clip):
    # the §3.4 F/G ratios against the post-update table — same algebra as
    # repro.core.aggregation.server_aggregate, folded into the round jit
    total = jnp.maximum(jnp.sum(counts), 1)
    f = counts.astype(jnp.float32) / total
    f_bar = jnp.mean(f)
    s_bar = jnp.mean(tsims)
    F = jnp.clip(f_bar / jnp.maximum(f[cids], 1e-12),
                 1.0 / ratio_clip, ratio_clip)
    s_i = jnp.maximum(sims, 1e-6)
    G = jnp.clip(jnp.maximum(s_bar, 1e-6) / s_i, 1.0 / ratio_clip, ratio_clip)
    return F, G


def _finish(flat, flat_g, eta_g, grad):
    # GRADIENT: w − η_g·Σp·δ on the flat vector; MODEL: Σp·w directly
    return flat_g - eta_g * flat if grad else flat


@functools.partial(jax.jit, static_argnames=(
    "n_clients", "grad", "mode", "block_d"))
def _fused_dense_round(x, counts, tsims, cids, sims, n, fb, cf, k, flat_g,
                       eta_g, ratio_clip, *, n_clients, grad,
                       mode="auto", block_d=0):
    F, G = _round_meta(counts, tsims, cids, sims, ratio_clip)
    if mode == "kernel":  # interpret-mode kernel body (validation only)
        flat = ingest_agg(x, None, n, F, G, fb, k, cf, n_clients=n_clients,
                          interpret=jax.default_backend() != "tpu")
    elif mode == "tpu":
        flat = ingest_agg(x, None, n, F, G, fb, k, cf, n_clients=n_clients,
                          **({"block_d": block_d} if block_d else {}))
    else:
        flat = ingest_agg_ref(x, None, n, F, G, fb, k, cf,
                              n_clients=n_clients)
    return _finish(flat, flat_g, eta_g, grad)


@functools.partial(jax.jit, static_argnames=(
    "n_clients", "grad", "mode", "block_d"))
def _fused_dense_stats_round(x, counts, tsims, cids, sims, n, fb, cf, k,
                             flat_g, eta_g, ratio_clip, *, n_clients, grad,
                             mode="auto", block_d=0):
    # the health-instrumented sibling of _fused_dense_round: same round
    # algebra through the stats_agg kernel, which emits the per-round
    # stability vector from the same VMEM sweep.  The aggregate (and so
    # the returned flat global) is bit-identical to the stats-free round
    # — gated by tests/test_health.py and benchmarks/bench_health.py.
    F, G = _round_meta(counts, tsims, cids, sims, ratio_clip)
    if mode == "kernel":  # interpret-mode kernel body (validation only)
        agg, row_sq, w = stats_agg(x, n, F, G, fb, k, cf,
                                   n_clients=n_clients,
                                   interpret=jax.default_backend() != "tpu")
    elif mode == "tpu":
        agg, row_sq, w = stats_agg(x, n, F, G, fb, k, cf,
                                   n_clients=n_clients,
                                   **({"block_d": block_d} if block_d else {}))
    else:
        agg, row_sq, w = stats_agg_ref(x, n, F, G, fb, k, cf,
                                       n_clients=n_clients)
    return _finish(agg, flat_g, eta_g, grad), round_stats(agg, row_sq, w, k)


@functools.partial(jax.jit, static_argnames=(
    "chunk", "d_out", "n_clients", "grad", "mode", "block_d"))
def _fused_quant_round(q, scales, counts, tsims, cids, sims, n, fb, cf, k,
                       flat_g, eta_g, ratio_clip, *, chunk, d_out,
                       n_clients, grad, mode="auto", block_d=0):
    F, G = _round_meta(counts, tsims, cids, sims, ratio_clip)
    if mode == "kernel":
        flat = ingest_agg(q, scales, n, F, G, fb, k, cf, chunk=chunk,
                          n_clients=n_clients,
                          interpret=jax.default_backend() != "tpu")
    elif mode == "tpu":
        flat = ingest_agg(q, scales, n, F, G, fb, k, cf, chunk=chunk,
                          n_clients=n_clients,
                          **({"block_d": block_d} if block_d else {}))
    else:
        flat = ingest_agg_ref(q, scales, n, F, G, fb, k, cf,
                              n_clients=n_clients)
    return _finish(flat[:d_out], flat_g, eta_g, grad)


def fused_ingest_round(batch, table, flat_g, hp, n_clients: int,
                       strategy, *, mode: Optional[str] = None,
                       tracer=None, span_round: int = -1,
                       stats: bool = False):
    """One fused FedQS round over a frozen buffer → (new flat global,
    new table) — or (new flat global, new table, stats) when ``stats``
    is requested.

    The whole Mod-3 pass — Eq. 1/2 table-derived F/G ratios, Eq. §3.4
    feedback weight fold, Σp·x, and the global step — runs as ONE jitted
    dispatch per (payload-shape, K-bucket), with the weight algebra
    folded into the ``ingest_agg`` kernel so no staleness math happens
    host-side.  Host work per fire: the status-table scatter (kept in
    ``update_table`` so bookkeeping is bit-identical to the unfused
    path) and one payload stack.

    ``batch`` mixes dense ``Update`` and ``CompressedUpdate`` items only
    through the caller's densify; here it must be homogeneous.  ``mode``:
    None → compiled kernel on TPU / jitted oracle elsewhere; ``"kernel"``
    forces the interpret-mode kernel body (validation).

    ``tracer``/``span_round`` (``repro.telemetry.trace``): when set, the
    host sub-stages are recorded as ``table``/``stack`` spans of that
    round so the critical-path analyzer can split dispatch wall time
    into host work vs the derived kernel remainder.

    ``stats=True`` (the training-health plane) routes the dense path
    through ``stats_agg`` and appends the [5] stability vector
    (``repro.kernels.stats_agg.STATS_FIELDS``) to the return — ``None``
    on the int8 fused path, which keeps the plain kernel (the stats
    variant is dense-only).  The aggregate is bit-identical either way.
    """
    from repro.core.aggregation import update_table

    grad = strategy is AggregationStrategy.GRADIENT
    attr = "delta" if grad else "params"
    payloads = [getattr(u, attr) for u in batch]
    if any(p is None for p in payloads):
        return None  # caller falls back to the unfused dispatch

    K = len(batch)
    t_tab = time.perf_counter() if tracer is not None else 0.0
    cids = np.asarray([u.cid for u in batch], np.int32)
    sims = np.asarray([u.similarity for u in batch], np.float32)
    new_table = update_table(table, jnp.asarray(cids), jnp.asarray(sims))

    Kb = bucket_rows(K)
    pad = Kb - K
    meta = dict(
        cids=np.pad(cids, (0, pad)),
        sims=np.pad(sims, (0, pad), constant_values=1.0),
        n=np.pad(np.asarray([u.n_samples for u in batch], np.float32),
                 (0, pad)),
        fb=np.pad(np.asarray(
            [float(bool(u.feedback) and hp.use_feedback) for u in batch],
            np.float32), (0, pad)),
        # padding rows carry cf = 1.0 (their weight is already exactly 0);
        # all-complete buffers multiply by exactly 1.0, which is IEEE-exact
        cf=np.pad(np.asarray(
            [float(getattr(u, "completed_fraction", 1.0)) for u in batch],
            np.float32), (0, pad), constant_values=1.0),
    )
    k = jnp.float32(K)
    eta_g = jnp.float32(hp.eta_g)
    ratio_clip = jnp.float32(hp.ratio_clip)
    mode = mode or ("tpu" if jax.default_backend() == "tpu" else "ref")
    if tracer is not None:
        tracer.record("table", "serve", t_tab,
                      time.perf_counter() - t_tab, round=span_round)
        t_stk = time.perf_counter()

    encoded = isinstance(payloads[0], Encoded)
    if encoded and fused_eligible(payloads):
        q, scales = stack_encoded(payloads)
        if pad:
            q = jnp.pad(q, ((0, pad), (0, 0)))
            scales = jnp.pad(scales, ((0, pad), (0, 0)))
        if tracer is not None:
            tracer.record("stack", "serve", t_stk,
                          time.perf_counter() - t_stk, round=span_round)
        block = (get_config("ingest_agg", q.shape, q.dtype).block_d
                 if mode == "tpu" else 0)
        new_flat = _fused_quant_round(
            q, scales, new_table.counts, new_table.sims, meta["cids"],
            meta["sims"], meta["n"], meta["fb"], meta["cf"], k, flat_g,
            eta_g, ratio_clip, chunk=payloads[0].chunk, d_out=payloads[0].d,
            n_clients=n_clients, grad=grad, mode=mode, block_d=block)
        return (new_flat, new_table, None) if stats else (new_flat, new_table)
    if encoded:
        # raw-f32 top-k (or heterogeneous chunks): decode to dense rows
        x = jnp.stack([decode(e) for e in payloads])
    else:
        x, _ = stack_trees(payloads)
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    if tracer is not None:
        tracer.record("stack", "serve", t_stk,
                      time.perf_counter() - t_stk, round=span_round)
    if stats:
        block = (get_config("stats_agg", x.shape, x.dtype).block_d
                 if mode == "tpu" else 0)
        new_flat, stats_vec = _fused_dense_stats_round(
            x, new_table.counts, new_table.sims, meta["cids"], meta["sims"],
            meta["n"], meta["fb"], meta["cf"], k, flat_g, eta_g, ratio_clip,
            n_clients=n_clients, grad=grad, mode=mode, block_d=block)
        return new_flat, new_table, stats_vec
    block = (get_config("ingest_agg", x.shape, x.dtype).block_d
             if mode == "tpu" else 0)
    new_flat = _fused_dense_round(
        x, new_table.counts, new_table.sims, meta["cids"], meta["sims"],
        meta["n"], meta["fb"], meta["cf"], k, flat_g, eta_g, ratio_clip,
        n_clients=n_clients, grad=grad, mode=mode, block_d=block)
    return new_flat, new_table


def make_tree_sum(use_kernel: Optional[bool] = None,
                  unravel_fn: Optional[Callable[[], Callable]] = None):
    """Bind ``use_kernel`` into a tree_sum(trees, weights) callable.

    The returned callable accepts either pytrees or ``Encoded`` payloads
    (the compressed transport); ``unravel_fn`` lazily supplies the
    flat-to-pytree closure of the served model for the compressed path.
    """

    def tree_sum(trees, weights):
        if trees and isinstance(trees[0], Encoded):
            if unravel_fn is None:
                raise ValueError(
                    "compressed buffer needs an unravel closure — construct "
                    "tree_sum via make_tree_sum(unravel_fn=...)"
                )
            return compressed_weighted_sum(
                trees, weights, unravel_fn(), use_kernel=use_kernel
            )
        return batched_weighted_sum(trees, weights, use_kernel=use_kernel)

    return tree_sum
