"""Batched (stacked) Mod-3 aggregation for the streaming service.

The K buffered updates are flattened into one ``[K, D]`` matrix and the
weighted reduction Σ_k w[k]·x[k] runs as a single matvec:

* on TPU it dispatches to the Pallas ``weighted_agg`` kernel
  (``repro.kernels.weighted_agg``) — every parameter byte crosses HBM
  exactly once;
* elsewhere it falls back to the pure-jnp oracle (one fused einsum) —
  interpret-mode Pallas is far too slow for a hot ingestion loop.

Compressed buffers (``repro.compress``) skip the decode entirely: int8
payloads are stacked as quantized rows (sparse ones scattered into
dense int8) and handed to the fused ``dequant_agg`` kernel, which
dequantizes in VMEM during the reduction — ≈ 4× less HBM traffic than
even the dense path.  Raw-f32 top-k payloads decode to dense rows and
take the ``weighted_agg`` path.

This is numerically a reordering of ``repro.core.types.tree_weighted_sum``
(sequential scale+add), so results agree to fp32 tolerance, not bitwise;
the virtual-clock engine therefore keeps the sequential form by default
and the streaming service opts in.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree

from repro.compress.codec import Encoded, decode
from repro.core.types import Params
from repro.kernels import dequant_agg_auto_op, weighted_agg_auto_op
from repro.kernels.dequant_agg import dequant_agg
from repro.kernels.ref import dequant_agg_ref, weighted_agg_ref
from repro.kernels.weighted_agg import weighted_agg

# unravel closures keyed by (treedef, leaf avals): the buffer carries the
# same model structure round after round, so the closure (and the ravel
# bookkeeping inside it) is built once, not per fire
_UNRAVEL_CACHE: Dict[tuple, Callable[[jnp.ndarray], Params]] = {}


def _tree_key(leaves, treedef) -> tuple:
    return (treedef, tuple((l.shape, jnp.result_type(l)) for l in leaves))


def unravel_like(tree: Params) -> Callable[[jnp.ndarray], Params]:
    """Cached flat-[D] → pytree closure for ``tree``'s structure."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    key = _tree_key(leaves, treedef)
    unravel = _UNRAVEL_CACHE.get(key)
    if unravel is None:
        _, unravel = ravel_pytree(tree)
        _UNRAVEL_CACHE[key] = unravel
    return unravel


def stack_trees(trees: List[Params]) -> Tuple[jnp.ndarray, Callable[[jnp.ndarray], Params]]:
    """Ravel each pytree to a row of a [K, D] f32 matrix; returns the matrix
    and the (cached) unravel closure mapping a flat [D] vector back to the
    pytree.  All trees must share one structure — a buffer mixing model
    shapes is a caller bug and raises instead of silently unraveling rows
    with the first tree's closure."""
    if not trees:
        raise ValueError("cannot stack an empty buffer")
    leaves0, treedef0 = jax.tree_util.tree_flatten(trees[0])
    unravel = unravel_like(trees[0])
    flats = []
    for t in trees:
        leaves, treedef = jax.tree_util.tree_flatten(t)
        if treedef != treedef0:
            raise ValueError(
                f"buffer mixes pytree structures: {treedef} vs {treedef0}"
            )
        parts = [
            p if p.dtype == jnp.float32 else p.astype(jnp.float32)
            for p in (jnp.ravel(l) for l in leaves)
        ]
        flats.append(jnp.concatenate(parts) if parts else jnp.zeros((0,), jnp.float32))
    return jnp.stack(flats), unravel


def batched_weighted_sum(
    trees: List[Params],
    weights,
    *,
    use_kernel: Optional[bool] = None,
) -> Params:
    """Σ_i w_i · tree_i via the stacked [K, D] matvec.

    ``use_kernel``: None → auto (Pallas on TPU, jnp einsum elsewhere);
    True → force the Pallas kernel (interpreted off-TPU, for validation);
    False → force the jnp oracle.

    Drop-in for ``tree_weighted_sum`` — pass as the ``tree_sum`` argument
    of ``repro.core.aggregation.server_aggregate``.
    """
    x, unravel = stack_trees(trees)
    w = jnp.asarray(weights, jnp.float32)
    if use_kernel is None:
        flat = weighted_agg_auto_op(x, w)
    elif use_kernel:
        flat = weighted_agg(x, w, interpret=jax.default_backend() != "tpu")
    else:
        flat = weighted_agg_ref(x, w)
    return unravel(flat)


# ------------------------------------------------------------- compressed
def fused_eligible(encs: Sequence[Encoded]) -> bool:
    """True when the buffer can feed ``dequant_agg`` directly: every
    payload int8-quantized with one shared (chunk, decoded-dim)."""
    if not encs:
        return False
    first = encs[0]
    return all(
        e.is_quantized and e.chunk == first.chunk and e.d == first.d
        for e in encs
    )


def stack_encoded(encs: Sequence[Encoded]) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Stack quantized payloads into dense int8 rows + scale rows for the
    fused kernel.  Sparse payloads scatter into zeros — their per-chunk
    scales are already defined over the decoded axis (``repro.compress``),
    so the scattered row dequantizes identically."""
    nc = encs[0].scales.shape[0]
    dp = nc * encs[0].chunk
    rows, srows = [], []
    for e in encs:
        if e.indices is None:
            rows.append(e.data)
        else:
            rows.append(
                jnp.zeros((dp,), jnp.int8)
                .at[e.indices.astype(jnp.int32)].set(e.data)
            )
        srows.append(e.scales)
    return jnp.stack(rows), jnp.stack(srows)


def compressed_weighted_sum(
    encs: Sequence[Encoded],
    weights,
    unravel: Callable[[jnp.ndarray], Params],
    *,
    use_kernel: Optional[bool] = None,
) -> Params:
    """Σ_i w_i · decode(enc_i) without materializing decoded rows in HBM
    when the buffer is int8 (the fused kernel path)."""
    if not encs:
        raise ValueError("cannot aggregate an empty compressed buffer")
    w = jnp.asarray(weights, jnp.float32)
    d = encs[0].d
    if fused_eligible(encs):
        q, scales = stack_encoded(encs)
        chunk = encs[0].chunk
        if use_kernel is None:
            flat = dequant_agg_auto_op(q, scales, w, chunk=chunk)
        elif use_kernel:
            flat = dequant_agg(q, scales, w, chunk=chunk,
                               interpret=jax.default_backend() != "tpu")
        else:
            flat = dequant_agg_ref(q, scales, w)
        return unravel(flat[:d])
    # raw-f32 top-k (or heterogeneous) buffers: decode to dense rows and
    # take the dense kernel path
    x = jnp.stack([decode(e) for e in encs])
    if use_kernel:
        flat = weighted_agg(x, w, interpret=jax.default_backend() != "tpu")
    elif use_kernel is None:
        flat = weighted_agg_auto_op(x, w)
    else:
        flat = weighted_agg_ref(x, w)
    return unravel(flat)


def make_tree_sum(use_kernel: Optional[bool] = None,
                  unravel_fn: Optional[Callable[[], Callable]] = None):
    """Bind ``use_kernel`` into a tree_sum(trees, weights) callable.

    The returned callable accepts either pytrees or ``Encoded`` payloads
    (the compressed transport); ``unravel_fn`` lazily supplies the
    flat-to-pytree closure of the served model for the compressed path.
    """

    def tree_sum(trees, weights):
        if trees and isinstance(trees[0], Encoded):
            if unravel_fn is None:
                raise ValueError(
                    "compressed buffer needs an unravel closure — construct "
                    "tree_sum via make_tree_sum(unravel_fn=...)"
                )
            return compressed_weighted_sum(
                trees, weights, unravel_fn(), use_kernel=use_kernel
            )
        return batched_weighted_sum(trees, weights, use_kernel=use_kernel)

    return tree_sum
