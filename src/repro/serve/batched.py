"""Batched (stacked) Mod-3 aggregation for the streaming service.

The K buffered updates are flattened into one ``[K, D]`` matrix and the
weighted reduction Σ_k w[k]·x[k] runs as a single matvec:

* on TPU it dispatches to the Pallas ``weighted_agg`` kernel
  (``repro.kernels.weighted_agg``) — every parameter byte crosses HBM
  exactly once;
* elsewhere it falls back to the pure-jnp oracle (one fused einsum) —
  interpret-mode Pallas is far too slow for a hot ingestion loop.

This is numerically a reordering of ``repro.core.types.tree_weighted_sum``
(sequential scale+add), so results agree to fp32 tolerance, not bitwise;
the virtual-clock engine therefore keeps the sequential form by default
and the streaming service opts in.
"""
from __future__ import annotations

from typing import Callable, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree

from repro.core.types import Params
from repro.kernels import weighted_agg_auto_op
from repro.kernels.ref import weighted_agg_ref
from repro.kernels.weighted_agg import weighted_agg


def stack_trees(trees: List[Params]) -> Tuple[jnp.ndarray, Callable[[jnp.ndarray], Params]]:
    """Ravel each pytree to a row of a [K, D] f32 matrix; returns the matrix
    and the unravel closure mapping a flat [D] vector back to the pytree."""
    if not trees:
        raise ValueError("cannot stack an empty buffer")
    flats = []
    unravel = None
    for t in trees:
        f, u = ravel_pytree(t)
        flats.append(f.astype(jnp.float32))
        if unravel is None:
            unravel = u
    return jnp.stack(flats), unravel


def batched_weighted_sum(
    trees: List[Params],
    weights,
    *,
    use_kernel: Optional[bool] = None,
) -> Params:
    """Σ_i w_i · tree_i via the stacked [K, D] matvec.

    ``use_kernel``: None → auto (Pallas on TPU, jnp einsum elsewhere);
    True → force the Pallas kernel (interpreted off-TPU, for validation);
    False → force the jnp oracle.

    Drop-in for ``tree_weighted_sum`` — pass as the ``tree_sum`` argument
    of ``repro.core.aggregation.server_aggregate``.
    """
    x, unravel = stack_trees(trees)
    w = jnp.asarray(weights, jnp.float32)
    if use_kernel is None:
        flat = weighted_agg_auto_op(x, w)
    elif use_kernel:
        flat = weighted_agg(x, w, interpret=jax.default_backend() != "tpu")
    else:
        flat = weighted_agg_ref(x, w)
    return unravel(flat)


def make_tree_sum(use_kernel: Optional[bool] = None):
    """Bind ``use_kernel`` into a tree_sum(trees, weights) callable."""

    def tree_sum(trees, weights):
        return batched_weighted_sum(trees, weights, use_kernel=use_kernel)

    return tree_sum
