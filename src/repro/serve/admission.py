"""Staleness-bounded admission control for the update queue.

SEAFL (arXiv:2503.05755) shows that bounding the staleness of admitted
updates — dropping or attenuating those older than a threshold — is what
keeps buffered semi-asynchronous aggregation efficient under heavy
heterogeneity.  An admission policy inspects every incoming ``Update``
against the server's current round *before* it enters the ingest buffer.

Down-weighting is expressed through the update's sample count
``n_samples``: every algorithm in the zoo (FedQS included — its initial
weights are p_i = n_i/n) weights buffered updates by sample count, so
scaling n_i attenuates the update uniformly across all 12 algorithms
without touching their ``server_aggregate`` implementations.
"""
from __future__ import annotations

from dataclasses import dataclass, replace

import numpy as np

from repro.core.types import Update


@dataclass
class Admission:
    """Verdict for one incoming update."""

    accepted: bool
    weight_scale: float = 1.0  # applied to n_samples when < 1.0
    reason: str = ""


class AdmissionPolicy:
    """Base policy: admit everything at full weight."""

    name = "admit-all"

    # Vectorized verdict path for the service's burst admission
    # (StreamingAggregator._burst_fast).  A policy whose verdict is a pure
    # function of staleness may override with a method
    # ``admit_mask(stale_rounds, current_round) -> (accept, weight_scale)``
    # over numpy arrays; policies with richer verdicts (custom ``admit`` /
    # ``apply`` overrides) leave it None and bursts fall back to the exact
    # per-update path.  The cf ≤ 0 rejection and cf > 1 clamp stay with the
    # caller — they are policy-independent invariants.
    admit_mask = None

    def admit(self, update: Update, current_round: int) -> Admission:
        return Admission(True)

    def describe(self) -> str:
        return self.name

    def apply(self, update: Update, current_round: int):
        """Run the policy; returns (update_or_None, Admission).

        The returned update carries any down-weighting baked into its
        ``n_samples`` (floored at 1 so an admitted update never vanishes).

        Before any policy logic, the ``completed_fraction`` invariant is
        enforced for every policy: an update reporting no completed local
        work (cf ≤ 0) is rejected outright — it carries no gradient
        signal, and the Eq. §3.4 weight would vanish or flip sign — and
        cf > 1 is clamped (a client cannot over-complete its epochs).
        """
        cf = float(getattr(update, "completed_fraction", 1.0))
        if cf <= 0.0:
            return None, Admission(
                False, reason=f"no completed work: completed_fraction={cf}")
        if cf > 1.0:
            update = replace(update, completed_fraction=1.0)
        verdict = self.admit(update, current_round)
        if not verdict.accepted:
            return None, verdict
        if verdict.weight_scale != 1.0:
            scaled = max(1, int(round(update.n_samples * verdict.weight_scale)))
            update = replace(update, n_samples=scaled)
        return update, verdict


class AdmitAll(AdmissionPolicy):
    """Simulator default — the virtual-clock engine admits every update,
    matching the paper's server exactly."""

    def admit_mask(self, stale_rounds: np.ndarray, current_round: int):
        n = len(stale_rounds)
        return np.ones(n, bool), np.ones(n)


class StalenessAdmission(AdmissionPolicy):
    """Bounded-staleness admission: τ = round − stale_round vs ``tau_max``.

    mode="drop":       reject updates with τ > τ_max outright;
    mode="downweight": admit them at weight ``decay**(τ − τ_max)``.
    """

    name = "staleness"

    def __init__(self, tau_max: int, mode: str = "drop", decay: float = 0.5):
        if mode not in ("drop", "downweight"):
            raise ValueError(f"mode must be 'drop' or 'downweight', got {mode!r}")
        if not 0.0 < decay <= 1.0:
            raise ValueError(f"decay must be in (0, 1], got {decay}")
        self.tau_max = int(tau_max)
        self.mode = mode
        self.decay = float(decay)

    def admit(self, update, current_round):
        tau = max(0, current_round - update.stale_round)
        if tau <= self.tau_max:
            return Admission(True)
        if self.mode == "drop":
            return Admission(False, reason=f"stale: tau={tau} > tau_max={self.tau_max}")
        return Admission(
            True,
            weight_scale=self.decay ** (tau - self.tau_max),
            reason=f"downweighted: tau={tau} > tau_max={self.tau_max}",
        )

    def admit_mask(self, stale_rounds: np.ndarray, current_round: int):
        """One-pass burst verdicts: same τ arithmetic as ``admit``, same
        IEEE results (np.float64 ** int matches Python's float pow), so
        the burst path is bit-identical to per-update admission."""
        tau = np.maximum(0, current_round - stale_rounds)
        over = tau > self.tau_max
        if self.mode == "drop":
            return ~over, np.ones(len(tau))
        return (np.ones(len(tau), bool),
                np.where(over, np.float64(self.decay) ** (tau - self.tau_max),
                         1.0))

    def describe(self):
        return f"staleness(tau_max={self.tau_max},mode={self.mode})"
