"""Event sinks: where emitted records go.

Two shipped sinks cover the two consumption modes:

* ``JsonlSink`` — append one JSON object per line to a file; the durable
  record a report is generated from (``repro.launch.analysis``);
* ``RingSink`` — a bounded in-memory deque; what tests, benchmarks, and
  live dashboards read without touching the filesystem.

A sink is anything with ``write(record: dict)`` and ``close()``; the
``Telemetry`` hub fans every event out to all of its sinks.
"""
from __future__ import annotations

import json
import threading
from collections import deque
from typing import IO, Iterator, List, Optional


class Sink:
    def write(self, record: dict) -> None:
        raise NotImplementedError

    def close(self) -> None:
        """Flush and release resources (idempotent)."""


def _json_default(o):
    # emitters cast to plain Python types, but be forgiving about the odd
    # numpy scalar that slips through a field dict
    try:
        return o.item()
    except AttributeError:
        return str(o)


class JsonlSink(Sink):
    """Append-only JSONL file sink (the documented wire format)."""

    def __init__(self, path: str):
        self.path = str(path)
        self._fh: Optional[IO[str]] = open(self.path, "w", encoding="utf-8")
        self.written = 0
        # an async_agg service emits round-fired from its worker thread
        # while the ingest thread emits admissions — one locked write per
        # record keeps lines whole
        self._lock = threading.Lock()

    def write(self, record: dict) -> None:
        line = json.dumps(record, default=_json_default) + "\n"
        with self._lock:
            if self._fh is None:
                raise ValueError(f"JsonlSink({self.path}) is closed")
            self._fh.write(line)
            self.written += 1

    def flush(self) -> None:
        """Push buffered lines to the OS (no-op once closed)."""
        with self._lock:
            if self._fh is not None:
                self._fh.flush()

    def close(self) -> None:
        with self._lock:
            if self._fh is not None:
                self._fh.flush()
                self._fh.close()
                self._fh = None


class RingSink(Sink):
    """Bounded in-memory sink: keeps the most recent ``capacity`` records.

    Eviction is counted, not silent: ``dropped`` is surfaced by
    ``Telemetry.close()`` as the ``telemetry_events_dropped`` counter and
    warned about in the experiment report, so a run that outgrew its
    ring reads as truncated rather than short.
    """

    def __init__(self, capacity: int = 65536):
        self.capacity = int(capacity)
        self._ring: deque = deque()
        self.dropped = 0

    def write(self, record: dict) -> None:
        if len(self._ring) >= self.capacity:
            self._ring.popleft()
            self.dropped += 1
        self._ring.append(record)

    @property
    def records(self) -> List[dict]:
        return list(self._ring)

    def events(self, name: Optional[str] = None) -> Iterator[dict]:
        """Iterate buffered records, optionally filtered by event name."""
        for rec in self._ring:
            if name is None or rec.get("e") == name:
                yield rec

    def __len__(self) -> int:
        return len(self._ring)

    def clear(self) -> None:
        self._ring.clear()
        self.dropped = 0
