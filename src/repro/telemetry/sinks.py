"""Event sinks: where emitted records go.

Three shipped sinks cover the consumption modes:

* ``JsonlSink`` — append one JSON object per line to a file; the durable
  record a report is generated from (``repro.launch.analysis``);
* ``RingSink`` — a bounded in-memory deque; what tests, benchmarks, and
  live dashboards read without touching the filesystem;
* ``AsyncSink`` — a non-blocking decorator for any sink: ``write``
  enqueues onto a bounded queue drained by a daemon writer thread, so
  serialization/IO never stalls the ingest path of a pipelined service.

A sink is anything with ``write(record: dict)`` and ``close()``; the
``Telemetry`` hub fans every event out to all of its sinks.
"""
from __future__ import annotations

import json
import queue
import threading
from collections import deque
from typing import IO, Iterator, List, Optional


class Sink:
    def write(self, record: dict) -> None:
        raise NotImplementedError

    def close(self) -> None:
        """Flush and release resources (idempotent)."""


def _json_default(o):
    # emitters cast to plain Python types, but be forgiving about the odd
    # numpy scalar that slips through a field dict
    try:
        return o.item()
    except AttributeError:
        return str(o)


class JsonlSink(Sink):
    """Append-only JSONL file sink (the documented wire format)."""

    def __init__(self, path: str):
        self.path = str(path)
        self._fh: Optional[IO[str]] = open(self.path, "w", encoding="utf-8")
        self.written = 0
        # an async_agg service emits round-fired from its worker thread
        # while the ingest thread emits admissions — one locked write per
        # record keeps lines whole
        self._lock = threading.Lock()

    def write(self, record: dict) -> None:
        line = json.dumps(record, default=_json_default) + "\n"
        with self._lock:
            if self._fh is None:
                raise ValueError(f"JsonlSink({self.path}) is closed")
            self._fh.write(line)
            self.written += 1

    def flush(self) -> None:
        """Push buffered lines to the OS (no-op once closed)."""
        with self._lock:
            if self._fh is not None:
                self._fh.flush()

    def close(self) -> None:
        with self._lock:
            if self._fh is not None:
                self._fh.flush()
                self._fh.close()
                self._fh = None


class RingSink(Sink):
    """Bounded in-memory sink: keeps the most recent ``capacity`` records.

    Eviction is counted, not silent: ``dropped`` is surfaced by
    ``Telemetry.close()`` as the ``telemetry_events_dropped`` counter and
    warned about in the experiment report, so a run that outgrew its
    ring reads as truncated rather than short.
    """

    def __init__(self, capacity: int = 65536):
        self.capacity = int(capacity)
        self._ring: deque = deque()
        self.dropped = 0

    def write(self, record: dict) -> None:
        if len(self._ring) >= self.capacity:
            self._ring.popleft()
            self.dropped += 1
        self._ring.append(record)

    @property
    def records(self) -> List[dict]:
        return list(self._ring)

    def events(self, name: Optional[str] = None) -> Iterator[dict]:
        """Iterate buffered records, optionally filtered by event name."""
        for rec in self._ring:
            if name is None or rec.get("e") == name:
                yield rec

    def __len__(self) -> int:
        return len(self._ring)

    def clear(self) -> None:
        self._ring.clear()
        self.dropped = 0


class AsyncSink(Sink):
    """Fully non-blocking decorator around another sink.

    ``write`` is a bounded ``put_nowait`` — never blocks, never does IO on
    the caller's thread; a single daemon writer thread drains the queue
    into the wrapped sink, preserving emission order.  When the queue is
    full the record is *dropped and counted* rather than applying
    backpressure to the ingest path: ``dropped`` is surfaced by
    ``Telemetry.close()`` as ``telemetry_events_dropped``, the same
    truncation contract as ``RingSink`` eviction.  ``close()`` drains
    everything already enqueued (so the final metrics-snapshot line always
    lands) and then closes the inner sink.
    """

    _CLOSE = object()

    def __init__(self, inner: Sink, capacity: int = 65536):
        self.inner = inner
        self.capacity = int(capacity)
        self._q: queue.Queue = queue.Queue(maxsize=self.capacity)
        self.dropped = 0
        self._closed = False
        self._thread = threading.Thread(
            target=self._run, daemon=True, name="telemetry-writer")
        self._thread.start()

    def _run(self) -> None:
        while True:
            rec = self._q.get()
            try:
                if rec is self._CLOSE:
                    return
                try:
                    self.inner.write(rec)
                except Exception:
                    # a dead inner sink must not kill the writer thread —
                    # the record is accounted as dropped, like queue overflow
                    self.dropped += 1
            finally:
                self._q.task_done()

    def write(self, record: dict) -> None:
        if self._closed:
            raise ValueError("AsyncSink is closed")
        try:
            self._q.put_nowait(record)
        except queue.Full:
            self.dropped += 1

    def flush(self) -> None:
        """Barrier: wait until every record enqueued so far is written
        through, then flush the inner sink (if it can)."""
        self._q.join()
        inner_flush = getattr(self.inner, "flush", None)
        if inner_flush is not None:
            inner_flush()

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        self._q.put(self._CLOSE)  # blocking: the sentinel must land
        self._thread.join()
        self.inner.close()
