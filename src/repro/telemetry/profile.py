"""Kernel/dispatch profiling hooks for ``repro.kernels``.

The kernel layer cannot take a ``telemetry=`` argument — its public ops
are plain functions called from jitted code paths all over the tree —
so profiling uses a process-global activation slot instead: a launcher
(or benchmark) wraps the run in ``profile.activate(telemetry)`` and the
instrumented dispatchers in ``kernels/ops.py``/``autotune.py`` check
one module global per call.  When nothing is active the hook is a
single ``is None`` test; when active, each op dispatch is timed
(``jax.block_until_ready``), recorded as a ``kernel`` span, and fed
into histogram metrics, and autotune cache probes count hits/misses.

Activation is deliberately explicit rather than implied by constructing
a ``Telemetry`` hub: the paired overhead benchmarks run a traced and an
untraced service in the same process, and a constructor-installed
global would bleed kernel timing into the untraced arm.

Metrics published while active (docs/OBSERVABILITY.md):

* ``kernels.dispatch_seconds``   — histogram of per-op wall time
* ``kernels.autotune_hits`` / ``kernels.autotune_misses`` — cache probes
* ``kernels.ref_fallback``       — ops served by the jnp reference path
  (``REPRO_KERNEL_MODE=ref`` or no TPU backend for an ``auto_op``)
"""
from __future__ import annotations

import os
import time
from contextlib import contextmanager
from typing import Iterator, Optional

import jax

from .events import KernelProfile
from .metrics import SECONDS_BUCKETS


def resolved_mode(auto: bool = False) -> str:
    """The dispatch path ``kernels/ops.py`` resolves under the current
    env: ``ref`` when ``REPRO_KERNEL_MODE=ref``; otherwise ``pallas`` on
    TPU; off-TPU the validation ops run the kernel body under
    ``interpret`` while the ``*_auto_op`` throughput ops fall back to
    ``ref``."""
    if os.environ.get("REPRO_KERNEL_MODE", "") == "ref":
        return "ref"
    if jax.default_backend() == "tpu":
        return "pallas"
    return "ref" if auto else "interpret"


class Profiler:
    """Bound metric handles + the span recorder for one activation."""

    __slots__ = ("telemetry", "tracer", "_dispatch_h", "_hits", "_misses",
                 "_fallback", "_base")

    def __init__(self, telemetry):
        self.telemetry = telemetry
        self.tracer = getattr(telemetry, "tracer", None)
        m = telemetry.metrics
        self._dispatch_h = m.histogram(
            "kernels.dispatch_seconds", SECONDS_BUCKETS,
            unit="s", layer="kernels")
        self._hits = m.counter("kernels.autotune_hits", layer="kernels")
        self._misses = m.counter("kernels.autotune_misses", layer="kernels")
        self._fallback = m.counter("kernels.ref_fallback", layer="kernels")
        # registry handles are shared across activations; remember the
        # entry values so the closing kernel-profile event reports this
        # activation's deltas
        self._base = (self._dispatch_h.count, self._fallback.value,
                      self._hits.value, self._misses.value)

    def dispatch(self, name: str, mode: str, t0: float, dur: float) -> None:
        """One timed op call.  ``mode`` is the resolved dispatch path:
        ``pallas`` | ``interpret`` | ``ref``."""
        self._dispatch_h.observe(dur)
        if mode == "ref":
            self._fallback.inc()
        if self.tracer is not None:
            self.tracer.record(name, "kernel", t0, dur,
                               args={"mode": mode})

    def config_probe(self, hit: bool) -> None:
        (self._hits if hit else self._misses).inc()

    def summary_event(self, t: Optional[float] = None) -> KernelProfile:
        """This activation's visibility record (docs/OBSERVABILITY.md)."""
        d0, f0, h0, m0 = self._base
        return KernelProfile(
            t=t, backend=jax.default_backend(), mode=resolved_mode(),
            dispatches=self._dispatch_h.count - d0,
            ref_fallbacks=self._fallback.value - f0,
            autotune_hits=self._hits.value - h0,
            autotune_misses=self._misses.value - m0)


# The process-global activation slot.  ``None`` → every hook is one
# global read + ``is None`` check (the zero-overhead contract).
_ACTIVE: Optional[Profiler] = None


def active() -> Optional[Profiler]:
    return _ACTIVE


@contextmanager
def activate(telemetry) -> Iterator[Profiler]:
    """Route kernel-layer profiling into ``telemetry`` for this scope.

    On exit a ``kernel-profile`` event is emitted so ref-path fallbacks
    and autotune cache misses are visible in the run's report even when
    nobody reads the metrics registry.
    """
    global _ACTIVE
    prev = _ACTIVE
    prof = Profiler(telemetry)
    _ACTIVE = prof
    try:
        yield prof
    finally:
        _ACTIVE = prev
        telemetry.emit(prof.summary_event())


def timed_call(name: str, mode: str, fn, *args, **kw):
    """Run ``fn`` under the active profiler (or straight through).

    The instrumented dispatchers in ``kernels/ops.py`` funnel here: when
    a profiler is active the result is blocked on so the recorded span
    covers dispatch *and* device execution; when none is, the call is
    returned untouched — no block, no timing, bit-identical async
    behavior.
    """
    prof = _ACTIVE
    if prof is None:
        return fn(*args, **kw)
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    jax.block_until_ready(out)
    prof.dispatch(name, mode, t0, time.perf_counter() - t0)
    return out


__all__ = ["Profiler", "activate", "active", "timed_call", "resolved_mode"]
