"""Critical-path analysis over recorded spans.

Reconstructs each round's causal chain from the span stream
(``repro.telemetry.trace``) and attributes the measured round wall time
to named stages.  The invariant the CI trace smoke gates on: the
in-round stages must sum to the round span's wall time within 10%
(``coverage`` in [0.9, 1.1]) on every aggregation path — flat KBuffer,
TimeWindow, and hierarchical.

Stage definitions (docs/OBSERVABILITY.md):

* ``host_stack``      — payload stacking on the host (``stack`` spans)
* ``table_update``    — client-table math (``table`` spans)
* ``kernel_dispatch`` — device dispatch + wait: the dispatch span minus
  its measured host sub-stages (derived, so XLA async execution never
  double-counts)
* ``finalize``        — post-dispatch bookkeeping (report rows, events)
* ``other``           — round wall time outside dispatch+finalize
  (pre-dispatch setup; small by construction)

Stages measured *outside* the round wall are reported separately and do
not count toward coverage:

* ``admission_wait``   — per-update admission decision cost
* ``buffer_residency`` — accepted updates' wait until their round fired
* ``tier_merge``       — edge/region ``_reduce`` time (hier plane)
* ``checkpoint``       — checkpoint serialization
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Optional

from .trace import Span

# span name -> in-round stage (attributed against the round wall)
_IN_ROUND = {"stack": "host_stack", "table": "table_update",
             "finalize": "finalize"}
# span (cat, name) -> out-of-round stage (reported, not covered)
_OUT_OF_ROUND = {("update", "admit"): "admission_wait",
                 ("update", "buffer"): "buffer_residency",
                 ("hier", "tier-fire"): "tier_merge",
                 ("ckpt", "save"): "checkpoint"}

STAGES = ("host_stack", "table_update", "kernel_dispatch", "finalize",
          "other")
OUT_OF_ROUND_STAGES = ("admission_wait", "buffer_residency", "tier_merge",
                       "checkpoint")


class RoundPath:
    """One round's latency attribution."""

    __slots__ = ("round", "wall", "stages", "coverage")

    def __init__(self, round: int, wall: float, stages: Dict[str, float]):
        self.round = round
        self.wall = wall
        self.stages = stages
        covered = sum(stages.values())
        self.coverage = covered / wall if wall > 0 else 0.0

    def __repr__(self) -> str:
        return (f"RoundPath(round={self.round}, wall={self.wall * 1e3:.2f}ms, "
                f"coverage={self.coverage:.3f})")


def analyze(spans: Iterable[Span]) -> List[RoundPath]:
    """Attribute each round's wall time to stages; one entry per round.

    Rounds are identified by ``serve``/``round`` spans.  A round's
    dispatch time is decomposed into measured host sub-stages plus the
    derived ``kernel_dispatch`` remainder; whatever the round wall holds
    beyond dispatch+finalize lands in ``other`` so the stages always sum
    to the wall exactly (coverage gates then check the decomposition is
    dominated by *measured* stages, not the residual).
    """
    per_round: Dict[int, Dict[str, float]] = {}
    walls: Dict[int, float] = {}
    for s in spans:
        if s.round < 0:
            continue
        if s.cat in ("serve", "hier") and s.name == "round":
            walls[s.round] = walls.get(s.round, 0.0) + s.dur
            continue
        bucket = per_round.setdefault(s.round, {})
        if s.name == "dispatch":
            bucket["_dispatch"] = bucket.get("_dispatch", 0.0) + s.dur
        elif s.name in _IN_ROUND:
            key = _IN_ROUND[s.name]
            bucket[key] = bucket.get(key, 0.0) + s.dur

    out: List[RoundPath] = []
    for rnd in sorted(walls):
        wall = walls[rnd]
        bucket = per_round.get(rnd, {})
        dispatch = bucket.get("_dispatch", 0.0)
        stack = bucket.get("host_stack", 0.0)
        table = bucket.get("table_update", 0.0)
        finalize = bucket.get("finalize", 0.0)
        kernel = max(dispatch - stack - table, 0.0)
        other = max(wall - dispatch - finalize, 0.0)
        out.append(RoundPath(rnd, wall, {
            "host_stack": stack,
            "table_update": table,
            "kernel_dispatch": kernel,
            "finalize": finalize,
            "other": other,
        }))
    return out


def stage_summary(spans: Iterable[Span]) -> dict:
    """Aggregate attribution across all rounds (the report's view).

    ``coverage`` here is the wall-weighted mean of per-round coverage
    *excluding* the ``other`` residual — i.e. the fraction of round wall
    time explained by measured stages — which is what the trace smoke
    gates on.
    """
    spans = list(spans)
    paths = analyze(spans)
    stages: Dict[str, float] = {k: 0.0 for k in STAGES}
    wall = 0.0
    measured = 0.0
    for p in paths:
        wall += p.wall
        for k, v in p.stages.items():
            stages[k] += v
            if k != "other":
                measured += v
    outside: Dict[str, float] = {k: 0.0 for k in OUT_OF_ROUND_STAGES}
    n_outside: Dict[str, int] = {k: 0 for k in OUT_OF_ROUND_STAGES}
    for s in spans:
        key = _OUT_OF_ROUND.get((s.cat, s.name))
        if key is not None:
            outside[key] += s.dur
            n_outside[key] += 1
    # kernel-hook spans (telemetry.profile) — reported for cross-checking
    # the derived kernel_dispatch stage, never summed into coverage
    kernel_hook = sum(s.dur for s in spans if s.cat == "kernel")
    return {
        "rounds": len(paths),
        "spans": len(spans),
        "wall_s": wall,
        "coverage": (measured / wall) if wall > 0 else 0.0,
        "stages_s": {k: stages[k] for k in STAGES},
        "outside_s": outside,
        "outside_n": n_outside,
        "kernel_hook_s": kernel_hook,
    }


def format_summary(summary: dict) -> List[str]:
    """Markdown table rows for the report's Critical path section."""
    wall = summary.get("wall_s", 0.0) or 0.0
    lines = ["| stage | total (ms) | % of round wall |",
             "|---|---:|---:|"]
    for k in STAGES:
        v = summary.get("stages_s", {}).get(k, 0.0)
        pct = 100.0 * v / wall if wall > 0 else 0.0
        lines.append(f"| {k} | {v * 1e3:.2f} | {pct:.1f}% |")
    for k in OUT_OF_ROUND_STAGES:
        v = summary.get("outside_s", {}).get(k, 0.0)
        n = summary.get("outside_n", {}).get(k, 0)
        if n or v > 0:  # trace-summary records carry outside_s but not outside_n
            suffix = f", n={n}" if n else ""
            lines.append(f"| {k} (outside round{suffix}) | {v * 1e3:.2f} | — |")
    return lines


__all__ = ["RoundPath", "analyze", "stage_summary", "format_summary",
           "STAGES", "OUT_OF_ROUND_STAGES"]
