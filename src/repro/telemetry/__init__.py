"""Telemetry plane: structured events + metrics registry (docs/OBSERVABILITY.md).

One ``Telemetry`` hub is shared by a whole run: the engines and services
emit typed events (``repro.telemetry.events``) into its sinks and
publish counters/gauges/histograms into its registry
(``repro.telemetry.metrics``).  The hook point is deliberately
*zero-overhead when disabled*: every instrumented component takes
``telemetry=None`` and guards each emit site with one ``is not None``
check — no hub, no work, bit-identical aggregation either way (the gate
in ``benchmarks/bench_serve.py``).

Record a run and render its experiment report::

    tel = Telemetry.to_jsonl("run.jsonl")
    eng = SAFLEngine(data, spec, algo, hp, telemetry=tel)
    eng.run(60)
    tel.close()          # appends the final metrics-snapshot record

    # then: PYTHONPATH=src python -m repro.launch.analysis --events run.jsonl
"""
from __future__ import annotations

from typing import List, Optional, Sequence

from .events import (
    EVENT_TYPES,
    ClientClassified,
    ClientDropped,
    CodecEncoded,
    DeadlineAdapted,
    Event,
    KernelProfile,
    MetricsSnapshot,
    PartialAdmitted,
    RoundFired,
    RoundMetricsEvent,
    TierMerged,
    TraceSummary,
    UpdateAdmitted,
    UpdateRejected,
)
from .metrics import (
    BYTES_BUCKETS,
    SECONDS_BUCKETS,
    STALENESS_BUCKETS,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
)
from .sinks import JsonlSink, RingSink, Sink
from .trace import Span, SpanRing, Tracer, to_chrome_trace


class Telemetry:
    """The per-run hub: a metrics registry plus a fan-out of event sinks.

    Pass ``tracer=Tracer()`` (or use the ``trace=True`` factory knobs)
    to additionally record monotonic-clock spans for critical-path
    analysis; instrumented components cache ``telemetry.tracer`` once
    and skip all span work when it is ``None`` — the same zero-overhead
    contract as the event plane.
    """

    def __init__(self, sinks: Optional[Sequence[Sink]] = None,
                 registry: Optional[MetricsRegistry] = None,
                 tracer: Optional[Tracer] = None):
        self.sinks: List[Sink] = list(sinks or [])
        self.metrics = registry or MetricsRegistry()
        self.tracer = tracer
        self._closed = False

    # ------------------------------------------------------------ factories
    @classmethod
    def to_jsonl(cls, path: str, *, ring: bool = False,
                 capacity: int = 65536, trace: bool = False,
                 trace_capacity: int = 262144) -> "Telemetry":
        """Record to a JSONL file (optionally tee into a ring buffer)."""
        sinks: List[Sink] = [JsonlSink(path)]
        if ring:
            sinks.append(RingSink(capacity))
        return cls(sinks, tracer=Tracer(trace_capacity) if trace else None)

    @classmethod
    def in_memory(cls, capacity: int = 65536, *, trace: bool = False,
                  trace_capacity: int = 262144) -> "Telemetry":
        """Ring-buffer-only hub (tests, benchmarks, live inspection)."""
        return cls([RingSink(capacity)],
                   tracer=Tracer(trace_capacity) if trace else None)

    # -------------------------------------------------------------- surface
    @property
    def ring(self) -> Optional[RingSink]:
        """The first ring sink, if any (convenience for tests/benchmarks)."""
        for s in self.sinks:
            if isinstance(s, RingSink):
                return s
        return None

    def emit(self, event: Event) -> None:
        rec = event.to_record()
        for sink in self.sinks:
            sink.write(rec)

    def trace_summary(self, t: Optional[float] = None) -> Optional[TraceSummary]:
        """Critical-path digest of the recorded spans (``None`` untraced)."""
        if self.tracer is None or not len(self.tracer.ring):
            return None
        from .critical_path import stage_summary
        s = stage_summary(self.tracer.spans)
        return TraceSummary(
            t=t, rounds=s["rounds"], spans=s["spans"],
            spans_dropped=self.tracer.dropped, wall_s=s["wall_s"],
            coverage=s["coverage"], stages_s=s["stages_s"],
            outside_s=s["outside_s"])

    def close(self, t: Optional[float] = None) -> None:
        """Append the final ``metrics-snapshot`` record and close sinks.

        Also surfaces lossiness before snapshotting: ring-sink evictions
        and tracer span drops land in the ``telemetry_events_dropped``
        counter, and a traced run gets its ``trace-summary`` record.
        """
        if self._closed:
            return
        dropped = sum(getattr(s, "dropped", 0) for s in self.sinks)
        if self.tracer is not None:
            dropped += self.tracer.dropped
        if dropped:
            self.metrics.counter("telemetry_events_dropped",
                                 layer="telemetry").inc(dropped)
        summary = self.trace_summary(t)
        if summary is not None:
            self.emit(summary)
        self.emit(MetricsSnapshot(t=t, metrics=self.metrics.snapshot()))
        for sink in self.sinks:
            sink.close()
        self._closed = True

    def __enter__(self) -> "Telemetry":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


__all__ = [
    "Telemetry",
    # events
    "EVENT_TYPES", "Event", "ClientClassified", "ClientDropped",
    "CodecEncoded", "DeadlineAdapted", "KernelProfile", "MetricsSnapshot",
    "PartialAdmitted", "RoundFired", "RoundMetricsEvent", "TierMerged",
    "TraceSummary", "UpdateAdmitted", "UpdateRejected",
    # metrics
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "STALENESS_BUCKETS", "SECONDS_BUCKETS", "BYTES_BUCKETS",
    # sinks
    "Sink", "JsonlSink", "RingSink",
    # tracing
    "Span", "SpanRing", "Tracer", "to_chrome_trace",
]
