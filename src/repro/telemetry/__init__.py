"""Telemetry plane: structured events + metrics registry (docs/OBSERVABILITY.md).

One ``Telemetry`` hub is shared by a whole run: the engines and services
emit typed events (``repro.telemetry.events``) into its sinks and
publish counters/gauges/histograms into its registry
(``repro.telemetry.metrics``).  The hook point is deliberately
*zero-overhead when disabled*: every instrumented component takes
``telemetry=None`` and guards each emit site with one ``is not None``
check — no hub, no work, bit-identical aggregation either way (the gate
in ``benchmarks/bench_serve.py``).

Record a run and render its experiment report::

    tel = Telemetry.to_jsonl("run.jsonl")
    eng = SAFLEngine(data, spec, algo, hp, telemetry=tel)
    eng.run(60)
    tel.close()          # appends the final metrics-snapshot record

    # then: PYTHONPATH=src python -m repro.launch.analysis --events run.jsonl
"""
from __future__ import annotations

from typing import List, Optional, Sequence

from .events import (
    EVENT_TYPES,
    ClientClassified,
    ClientDropped,
    CodecEncoded,
    DeadlineAdapted,
    Event,
    MetricsSnapshot,
    PartialAdmitted,
    RoundFired,
    RoundMetricsEvent,
    TierMerged,
    UpdateAdmitted,
    UpdateRejected,
)
from .metrics import (
    BYTES_BUCKETS,
    SECONDS_BUCKETS,
    STALENESS_BUCKETS,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
)
from .sinks import JsonlSink, RingSink, Sink


class Telemetry:
    """The per-run hub: a metrics registry plus a fan-out of event sinks."""

    def __init__(self, sinks: Optional[Sequence[Sink]] = None,
                 registry: Optional[MetricsRegistry] = None):
        self.sinks: List[Sink] = list(sinks or [])
        self.metrics = registry or MetricsRegistry()
        self._closed = False

    # ------------------------------------------------------------ factories
    @classmethod
    def to_jsonl(cls, path: str, *, ring: bool = False,
                 capacity: int = 65536) -> "Telemetry":
        """Record to a JSONL file (optionally tee into a ring buffer)."""
        sinks: List[Sink] = [JsonlSink(path)]
        if ring:
            sinks.append(RingSink(capacity))
        return cls(sinks)

    @classmethod
    def in_memory(cls, capacity: int = 65536) -> "Telemetry":
        """Ring-buffer-only hub (tests, benchmarks, live inspection)."""
        return cls([RingSink(capacity)])

    # -------------------------------------------------------------- surface
    @property
    def ring(self) -> Optional[RingSink]:
        """The first ring sink, if any (convenience for tests/benchmarks)."""
        for s in self.sinks:
            if isinstance(s, RingSink):
                return s
        return None

    def emit(self, event: Event) -> None:
        rec = event.to_record()
        for sink in self.sinks:
            sink.write(rec)

    def close(self, t: Optional[float] = None) -> None:
        """Append the final ``metrics-snapshot`` record and close sinks."""
        if self._closed:
            return
        self.emit(MetricsSnapshot(t=t, metrics=self.metrics.snapshot()))
        for sink in self.sinks:
            sink.close()
        self._closed = True

    def __enter__(self) -> "Telemetry":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


__all__ = [
    "Telemetry",
    # events
    "EVENT_TYPES", "Event", "ClientClassified", "ClientDropped",
    "CodecEncoded", "DeadlineAdapted", "MetricsSnapshot", "PartialAdmitted",
    "RoundFired", "RoundMetricsEvent", "TierMerged",
    "UpdateAdmitted", "UpdateRejected",
    # metrics
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "STALENESS_BUCKETS", "SECONDS_BUCKETS", "BYTES_BUCKETS",
    # sinks
    "Sink", "JsonlSink", "RingSink",
]
