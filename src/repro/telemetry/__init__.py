"""Telemetry plane: structured events + metrics registry (docs/OBSERVABILITY.md).

One ``Telemetry`` hub is shared by a whole run: the engines and services
emit typed events (``repro.telemetry.events``) into its sinks and
publish counters/gauges/histograms into its registry
(``repro.telemetry.metrics``).  The hook point is deliberately
*zero-overhead when disabled*: every instrumented component takes
``telemetry=None`` and guards each emit site with one ``is not None``
check — no hub, no work, bit-identical aggregation either way (the gate
in ``benchmarks/bench_serve.py``).

Record a run and render its experiment report::

    tel = Telemetry.to_jsonl("run.jsonl")
    eng = SAFLEngine(data, spec, algo, hp, telemetry=tel)
    eng.run(60)
    tel.close()          # appends the final metrics-snapshot record

    # then: PYTHONPATH=src python -m repro.launch.analysis --events run.jsonl
"""
from __future__ import annotations

import threading

from typing import List, Optional, Sequence

from .events import (
    EVENT_TYPES,
    ClientClassified,
    ClientDropped,
    CodecEncoded,
    DeadlineAdapted,
    Event,
    FlightDump,
    HealthAlert,
    KernelProfile,
    MetricsSnapshot,
    PartialAdmitted,
    RoundFired,
    RoundMetricsEvent,
    TierMerged,
    TraceSummary,
    UpdateAdmitted,
    UpdateRejected,
)
from .flightrec import FlightRecorder
from .health import DEFAULT_DETECTORS, DetectorConfig, EwmaDetector, HealthMonitor
from .metrics import (
    BYTES_BUCKETS,
    SECONDS_BUCKETS,
    STALENESS_BUCKETS,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
)
from .sinks import AsyncSink, JsonlSink, RingSink, Sink
from .trace import Span, SpanRing, Tracer, to_chrome_trace


class Telemetry:
    """The per-run hub: a metrics registry plus a fan-out of event sinks.

    Pass ``tracer=Tracer()`` (or use the ``trace=True`` factory knobs)
    to additionally record monotonic-clock spans for critical-path
    analysis; instrumented components cache ``telemetry.tracer`` once
    and skip all span work when it is ``None`` — the same zero-overhead
    contract as the event plane.
    """

    def __init__(self, sinks: Optional[Sequence[Sink]] = None,
                 registry: Optional[MetricsRegistry] = None,
                 tracer: Optional[Tracer] = None,
                 health: Optional[HealthMonitor] = None,
                 flightrec: Optional[FlightRecorder] = None):
        self.sinks: List[Sink] = list(sinks or [])
        self.metrics = registry or MetricsRegistry()
        self.tracer = tracer
        # the flight recorder joins the sink fan-out LAST (so on close
        # its final dump happens after the primary sinks flushed) and is
        # bound before the health monitor, which picks it up for
        # on-alert dumps
        self.flightrec = flightrec
        if flightrec is not None:
            self.sinks.append(flightrec)
            flightrec.bind(self)
        self.health = health
        if health is not None:
            health.bind(self)
        self._closed = False
        self._close_lock = threading.Lock()

    # ------------------------------------------------------------ factories
    @classmethod
    def to_jsonl(cls, path: str, *, ring: bool = False,
                 capacity: int = 65536, trace: bool = False,
                 trace_capacity: int = 262144, health: bool = False,
                 flightrec: Optional[str] = None,
                 async_io: bool = False) -> "Telemetry":
        """Record to a JSONL file (optionally tee into a ring buffer).

        ``health=True`` attaches the default detector bank
        (``repro.telemetry.health``); ``flightrec=<path>`` attaches a
        flight recorder dumping its black box to that path.
        ``async_io=True`` wraps the file sink in an ``AsyncSink`` so JSON
        serialization and file writes happen on a writer thread instead
        of the emitting (ingest) thread — what the pipelined launcher
        uses; ``close()`` still drains every enqueued record first, so
        the on-disk stream is identical."""
        file_sink: Sink = JsonlSink(path)
        if async_io:
            file_sink = AsyncSink(file_sink, capacity=capacity)
        sinks: List[Sink] = [file_sink]
        if ring:
            sinks.append(RingSink(capacity))
        return cls(sinks, tracer=Tracer(trace_capacity) if trace else None,
                   health=HealthMonitor() if health else None,
                   flightrec=(FlightRecorder(flightrec)
                              if flightrec else None))

    @classmethod
    def in_memory(cls, capacity: int = 65536, *, trace: bool = False,
                  trace_capacity: int = 262144, health: bool = False,
                  flightrec: Optional[str] = None) -> "Telemetry":
        """Ring-buffer-only hub (tests, benchmarks, live inspection)."""
        return cls([RingSink(capacity)],
                   tracer=Tracer(trace_capacity) if trace else None,
                   health=HealthMonitor() if health else None,
                   flightrec=(FlightRecorder(flightrec)
                              if flightrec else None))

    # -------------------------------------------------------------- surface
    @property
    def ring(self) -> Optional[RingSink]:
        """The first ring sink, if any (convenience for tests/benchmarks)."""
        for s in self.sinks:
            if isinstance(s, RingSink):
                return s
        return None

    def emit(self, event: Event) -> None:
        rec = event.to_record()
        for sink in self.sinks:
            sink.write(rec)

    def trace_summary(self, t: Optional[float] = None) -> Optional[TraceSummary]:
        """Critical-path digest of the recorded spans (``None`` untraced)."""
        if self.tracer is None or not len(self.tracer.ring):
            return None
        from .critical_path import stage_summary
        s = stage_summary(self.tracer.spans)
        return TraceSummary(
            t=t, rounds=s["rounds"], spans=s["spans"],
            spans_dropped=self.tracer.dropped, wall_s=s["wall_s"],
            coverage=s["coverage"], stages_s=s["stages_s"],
            outside_s=s["outside_s"])

    def close(self, t: Optional[float] = None) -> None:
        """Append the final ``metrics-snapshot`` record and close sinks.

        Also surfaces lossiness before snapshotting: ring-sink evictions
        and tracer span drops land in the ``telemetry_events_dropped``
        counter, and a traced run gets its ``trace-summary`` record.

        Idempotent and thread-safe: the whole teardown runs under one
        lock with the flag flipped first, so concurrent closers (a
        signal handler racing the main thread, a flushing sink racing
        ``__exit__``) see exactly one trace-summary / snapshot and the
        drop counter is bumped once — a bare boolean used to double-emit
        both when two closers interleaved before the flag was set.
        """
        with self._close_lock:
            if self._closed:
                return
            self._closed = True
            dropped = sum(getattr(s, "dropped", 0) for s in self.sinks)
            if self.tracer is not None:
                dropped += self.tracer.dropped
            if dropped:
                self.metrics.counter("telemetry_events_dropped",
                                     layer="telemetry").inc(dropped)
            summary = self.trace_summary(t)
            if summary is not None:
                self.emit(summary)
            self.emit(MetricsSnapshot(t=t, metrics=self.metrics.snapshot()))
            for sink in self.sinks:
                sink.close()

    def __enter__(self) -> "Telemetry":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


__all__ = [
    "Telemetry",
    # events
    "EVENT_TYPES", "Event", "ClientClassified", "ClientDropped",
    "CodecEncoded", "DeadlineAdapted", "FlightDump", "HealthAlert",
    "KernelProfile", "MetricsSnapshot", "PartialAdmitted", "RoundFired",
    "RoundMetricsEvent", "TierMerged", "TraceSummary", "UpdateAdmitted",
    "UpdateRejected",
    # metrics
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "STALENESS_BUCKETS", "SECONDS_BUCKETS", "BYTES_BUCKETS",
    # sinks
    "Sink", "AsyncSink", "JsonlSink", "RingSink",
    # tracing
    "Span", "SpanRing", "Tracer", "to_chrome_trace",
    # health plane
    "DEFAULT_DETECTORS", "DetectorConfig", "EwmaDetector", "FlightRecorder",
    "HealthMonitor",
]
