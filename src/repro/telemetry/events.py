"""Typed telemetry events — the structured-event taxonomy.

Every observable state transition in the three runtimes is one of the
event types below (docs/OBSERVABILITY.md is the schema reference).  An
event is a plain ``__slots__`` dataclass whose fields are already plain
Python scalars/lists — emitters cast numpy/jax scalars at construction
so sinks can ``json.dumps`` a record without a sanitizing pass.

Wire format (one JSON object per JSONL line)::

    {"e": "<event name>", "t": <caller clock>, "round": <int>, ...fields}

``t`` is whatever clock the emitting runtime drives — virtual time in
the simulators, wall time in a live service — exactly like the trigger
policies; consumers only compare differences of it.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional


@dataclass(slots=True)
class Event:
    """Base event: subclasses set ``name`` and add their fields.

    Events are on the per-update hot path of the overhead gate in
    ``benchmarks/bench_serve.py``, so ``to_record`` walks the field
    names directly instead of ``dataclasses.asdict`` (whose recursive
    deep-copy costs ~10× more per event).
    """

    name = "event"

    def to_record(self) -> dict:
        rec = {"e": self.name}
        for f in self.__dataclass_fields__:
            rec[f] = getattr(self, f)
        return rec


@dataclass(slots=True)
class UpdateAdmitted(Event):
    """One client update passed admission and entered an ingest buffer."""

    name = "update-admitted"

    t: float
    round: int
    cid: int
    n_samples: int
    stale_round: int
    staleness: int          # tau = round - stale_round at admission
    downweighted: bool      # admission scaled n_samples below upload value


@dataclass(slots=True)
class UpdateRejected(Event):
    """Admission control dropped one incoming update."""

    name = "update-rejected"

    t: float
    round: int
    cid: int
    stale_round: int
    staleness: int
    reason: str


@dataclass(slots=True)
class RoundFired(Event):
    """One global aggregation fire (the service's round boundary).

    ``members`` is the member-level view of the aggregated buffer —
    ``[cid, n_samples, stale_round]`` per client update — identical
    between the flat and hierarchical services on an all-pass run (the
    parity gate in ``benchmarks/bench_serve.py``).  ``agg_seconds`` is
    host wall time of the aggregation dispatch and is the only field a
    cross-service comparison must exclude.
    """

    name = "round-fired"

    t: float
    round: int
    n_updates: int
    n_distinct: int
    mean_staleness: float
    max_staleness: int
    dropped_since_last: int
    trigger: str
    agg_seconds: float
    members: List[List[int]] = field(default_factory=list)


@dataclass(slots=True)
class TierMerged(Event):
    """A hierarchical tier node fired and forwarded one partial upward."""

    name = "tier-merged"

    t: float
    round: int
    tier: str               # "edge" | "region"
    node_id: int
    n_members: int


@dataclass(slots=True)
class CodecEncoded(Event):
    """One client upload crossed the compressed-transport boundary."""

    name = "codec-encoded"

    t: Optional[float]
    cid: int
    spec: str               # codec spec string, e.g. "topk:0.05|int8"
    dense_bytes: int        # fp32 bytes the payload would cost uncompressed
    wire_bytes: int         # bytes actually crossing the wire


@dataclass(slots=True)
class ClientClassified(Event):
    """Mod-2 classified one client at fetch time (paper §3.3)."""

    name = "client-classified"

    t: float
    round: int
    cid: int
    quadrant: int           # repro.core.types.Quadrant value
    lr: float
    momentum: float
    feedback: bool


@dataclass(slots=True)
class ClientDropped(Event):
    """A simulated device died mid-round (battery / availability loss) —
    its local work for this round is lost and never reaches admission."""

    name = "client-dropped"

    t: float
    round: int
    cid: int
    reason: str             # "battery" | "availability" | "chaos"


@dataclass(slots=True)
class PartialAdmitted(Event):
    """An update carrying incomplete local work was admitted; its Eq. §3.4
    weight is scaled by ``completed_fraction`` (docs/ROBUSTNESS.md)."""

    name = "partial-admitted"

    t: float
    round: int
    cid: int
    completed_fraction: float


@dataclass(slots=True)
class DeadlineAdapted(Event):
    """The adaptive trigger re-planned its deadline from the running
    latency quantile (``serve.triggers.AdaptiveTimeWindow``)."""

    name = "deadline-adapted"

    t: float
    round: int
    old_window: float
    new_window: float
    quantile_latency: float


@dataclass(slots=True)
class RoundMetricsEvent(Event):
    """Per-round evaluation metrics (the engines' ``RoundMetrics``)."""

    name = "round-metrics"

    t: float                # virtual time of the evaluated round
    round: int
    loss: float
    accuracy: float
    n_stale: int
    mean_staleness: float
    quadrant_counts: Dict[str, int] = field(default_factory=dict)


@dataclass(slots=True)
class HealthAlert(Event):
    """A streaming health detector crossed its z-score threshold
    (``repro.telemetry.health``).  Debounced: one alert per detector per
    cooldown window, so an alert storm cannot flood the sinks."""

    name = "health-alert"

    t: float
    round: int
    detector: str           # "loss" | "accuracy" | "update_norm" | ...
    severity: str           # "warn" | "critical"
    value: float            # the observation that tripped the detector
    mean: float             # EWMA mean at the time of the observation
    std: float              # EWMA std (floored) used for the z-score
    zscore: float


@dataclass(slots=True)
class FlightDump(Event):
    """The flight recorder persisted its black-box ring to disk
    (on alert, crash, or atexit — ``repro.telemetry.flightrec``)."""

    name = "flight-dump"

    t: Optional[float]
    round: int
    path: str
    n_records: int
    reason: str             # "alert" | "crash" | "atexit" | "close"


@dataclass(slots=True)
class KernelProfile(Event):
    """Kernel-layer visibility record, emitted when a profiled scope
    closes (``repro.telemetry.profile``): resolved dispatch mode plus
    autotune cache hit/miss totals, so a silent ``REPRO_KERNEL_MODE=ref``
    fallback or a cold autotune cache shows up in the report instead of
    only in a slower BENCH row."""

    name = "kernel-profile"

    t: Optional[float]
    backend: str            # jax.default_backend() at activation
    mode: str               # "pallas" | "interpret" | "ref"
    dispatches: int         # timed op calls while active
    ref_fallbacks: int      # of which served by the jnp reference path
    autotune_hits: int
    autotune_misses: int


@dataclass(slots=True)
class TraceSummary(Event):
    """Critical-path digest of a traced run, appended by
    ``Telemetry.close()`` when a tracer recorded spans — the single
    record the report's Critical path section renders from."""

    name = "trace-summary"

    t: Optional[float]
    rounds: int
    spans: int
    spans_dropped: int
    wall_s: float           # summed round wall time (perf_counter seconds)
    coverage: float         # fraction of wall explained by measured stages
    stages_s: dict = field(default_factory=dict)
    outside_s: dict = field(default_factory=dict)


@dataclass(slots=True)
class MetricsSnapshot(Event):
    """Final registry snapshot, appended by ``Telemetry.close()``."""

    name = "metrics-snapshot"

    t: Optional[float]
    metrics: dict = field(default_factory=dict)


EVENT_TYPES = {
    cls.name: cls
    for cls in (
        UpdateAdmitted, UpdateRejected, RoundFired, TierMerged,
        CodecEncoded, ClientClassified, ClientDropped, PartialAdmitted,
        DeadlineAdapted, RoundMetricsEvent, HealthAlert, FlightDump,
        KernelProfile, TraceSummary, MetricsSnapshot,
    )
}
