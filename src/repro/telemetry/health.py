"""Streaming training-health detectors (docs/OBSERVABILITY.md).

FedQS's own framing — gradient-style aggregation converges fast but
*fluctuates*, model-style is stable but slow — means a live run has a
handful of scalar series whose excursions are the whole story: loss and
accuracy, the per-round update-norm and weighted dispersion the fused
``stats_agg`` kernel now emits for free, mean staleness, and the
quadrant participation mix.  This module watches those series with
EWMA+z-score monitors and emits debounced ``health-alert`` events when
one leaves its own recent envelope.

The detector is deliberately simple and O(1) per observation::

    z      = (v − mean) / max(std, floor)     # BEFORE absorbing v
    d      = v − mean
    mean  += α·d
    var    = (1 − α)·(var + α·d²)             # EW variance recurrence

The z-score is computed against the *pre-update* envelope so a spike
cannot mask itself; the std floor (``max(abs_floor, rel_floor·|mean|)``)
keeps near-constant series (a converged loss, a zero-staleness stream)
from alerting on fp noise.  ``warmup`` observations seed the envelope
before any alert is possible, and ``cooldown`` debounces: at most one
alert per detector per cooldown window, so a sustained divergence emits
a few records, not thousands.

Zero-overhead contract: components cache ``telemetry.health`` once in
their constructor (``None`` when the plane is off) and guard each
observe site with one ``is not None`` check — no tensors are ever
touched, so aggregation stays bit-identical (the
``serve_health_overhead`` gate in ``benchmarks/bench_health.py``).
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Dict, List, Optional

from .events import HealthAlert

#: ``stats_agg.round_stats`` vector order, re-declared here so the
#: telemetry plane never imports the kernel package (which imports
#: telemetry.profile — keep the dependency one-way).
STATS_FIELDS = ("sum_w", "wnorm2", "dispersion", "max_sq", "mean_sq")


@dataclass(frozen=True)
class DetectorConfig:
    """Tuning knobs of one EWMA+z-score detector (docs/OBSERVABILITY.md
    lists the defaults per signal and when to move them)."""

    alpha: float = 0.25      # EWMA smoothing (≈ last ~1/α rounds matter)
    z_warn: float = 3.0      # |z| ≥ z_warn  → "warn"
    z_crit: float = 6.0      # |z| ≥ z_crit → "critical"
    warmup: int = 5          # observations before alerting is possible
    cooldown: int = 5        # min observations between alerts
    direction: str = "high"  # "high" | "low" | "both": which excursions alert
    rel_floor: float = 0.05  # std floor as a fraction of |mean|
    abs_floor: float = 1e-9  # absolute std floor


#: Default detector set: signal name → config.  Directions follow the
#: failure mode each signal encodes (a *drop* in accuracy is bad, a
#: *rise* in everything else).  Staleness uses an absolute floor of one
#: round so ordinary ±1 jitter on healthy streams never alerts.
DEFAULT_DETECTORS: Dict[str, DetectorConfig] = {
    "loss": DetectorConfig(direction="high"),
    "accuracy": DetectorConfig(direction="low"),
    "update_norm": DetectorConfig(direction="high", rel_floor=0.10),
    "dispersion": DetectorConfig(direction="high", rel_floor=0.25),
    "staleness": DetectorConfig(direction="high", rel_floor=0.25,
                                abs_floor=1.0),
    "quadrant_skew": DetectorConfig(direction="high", abs_floor=0.05),
}


class EwmaDetector:
    """One streaming envelope over one scalar series (module docstring)."""

    __slots__ = ("name", "cfg", "mean", "var", "count", "_last_alert")

    def __init__(self, name: str, cfg: DetectorConfig):
        self.name = name
        self.cfg = cfg
        self.mean = 0.0
        self.var = 0.0
        self.count = 0
        self._last_alert = -1

    def observe(self, value: float):
        """Absorb one observation; returns ``(severity, z, mean, std)``
        when it trips the (debounced) threshold, else ``None``."""
        v = float(value)
        cfg = self.cfg
        alert = None
        if self.count >= cfg.warmup:
            std = max(self.var, 0.0) ** 0.5
            std = max(std, cfg.abs_floor, cfg.rel_floor * abs(self.mean))
            z = (v - self.mean) / std
            signed = z if cfg.direction == "high" else (
                -z if cfg.direction == "low" else abs(z))
            if signed >= cfg.z_warn and (
                    self._last_alert < 0
                    or self.count - self._last_alert >= cfg.cooldown):
                sev = "critical" if signed >= cfg.z_crit else "warn"
                alert = (sev, z, self.mean, std)
                self._last_alert = self.count
        d = v - self.mean
        self.mean += cfg.alpha * d
        self.var = (1.0 - cfg.alpha) * (self.var + cfg.alpha * d * d)
        self.count += 1
        return alert


def _gini(counts) -> float:
    """Gini of a participation count vector (0 = uniform, →1 = skewed).
    Same definition as ``telemetry.report.gini``; duplicated to keep the
    hot path free of the report module."""
    vals = sorted(float(c) for c in counts)
    n = len(vals)
    total = sum(vals)
    if n == 0 or total <= 0.0:
        return 0.0
    cum = 0.0
    for i, v in enumerate(vals, start=1):
        cum += i * v
    return (2.0 * cum) / (n * total) - (n + 1.0) / n


class HealthMonitor:
    """The per-run detector bank — one instance on the ``Telemetry``
    hub, shared by every instrumented component of the run.

    Components feed it from two places: services call ``observe_round``
    with the kernel stats vector + staleness after each fire, engines
    call ``observe_metrics`` with per-round evaluation metrics.  Either
    call is a handful of float ops; an alert emits one ``health-alert``
    event, bumps the severity counter, and (when a flight recorder is
    attached) triggers a black-box dump.
    """

    def __init__(self, detectors: Optional[Dict[str, DetectorConfig]] = None,
                 *, overrides: Optional[Dict[str, DetectorConfig]] = None):
        cfgs = dict(DEFAULT_DETECTORS if detectors is None else detectors)
        if overrides:
            cfgs.update(overrides)
        self.detectors = {n: EwmaDetector(n, c) for n, c in cfgs.items()}
        self.alerts: List[HealthAlert] = []
        self._telemetry = None
        self._flightrec = None
        self._warn = None
        self._crit = None

    def bind(self, telemetry) -> None:
        """Attach to a hub: eager counter creation so even an alert-free
        run's metrics-snapshot shows the plane was on (``health.*`` = 0),
        and pick up the hub's flight recorder for on-alert dumps."""
        self._telemetry = telemetry
        self._flightrec = getattr(telemetry, "flightrec", None)
        self._warn = telemetry.metrics.counter(
            "health.alerts_warn", layer="health")
        self._crit = telemetry.metrics.counter(
            "health.alerts_critical", layer="health")

    def configure(self, name: str, **kw) -> None:
        """Re-tune one detector in place (e.g. ``configure("loss",
        z_warn=4.0)``) — resets its envelope."""
        det = self.detectors[name]
        self.detectors[name] = EwmaDetector(name, replace(det.cfg, **kw))

    # ------------------------------------------------------------- feeding
    def observe(self, name: str, value: float, *, t: float = 0.0,
                round: int = -1) -> Optional[HealthAlert]:
        """Feed one scalar to one detector (unknown names are ignored so
        callers never have to mirror the configured detector set)."""
        det = self.detectors.get(name)
        if det is None:
            return None
        hit = det.observe(value)
        if hit is None:
            return None
        sev, z, mean, std = hit
        alert = HealthAlert(t=float(t), round=int(round), detector=name,
                            severity=sev, value=float(value),
                            mean=float(mean), std=float(std),
                            zscore=float(z))
        self.alerts.append(alert)
        if self._telemetry is not None:
            (self._crit if sev == "critical" else self._warn).inc()
            self._telemetry.emit(alert)
        if self._flightrec is not None:
            self._flightrec.dump(reason="alert", round=int(round), t=float(t))
        return alert

    def observe_round(self, *, t: float, round: int,
                      mean_staleness: Optional[float] = None,
                      stats=None) -> None:
        """Per-fire service signals: mean staleness plus the fused
        kernel's stability vector (``STATS_FIELDS`` order; ``None`` on
        rounds the stats variant doesn't cover, e.g. int8 buffers)."""
        if mean_staleness is not None:
            self.observe("staleness", mean_staleness, t=t, round=round)
        if stats is not None:
            import numpy as np
            vec = np.asarray(stats, dtype=np.float64)
            s = dict(zip(STATS_FIELDS, vec.tolist()))
            self.observe("update_norm", s["max_sq"] ** 0.5, t=t, round=round)
            self.observe("dispersion", s["dispersion"], t=t, round=round)

    def observe_metrics(self, *, t: float, round: int,
                        loss: Optional[float] = None,
                        accuracy: Optional[float] = None,
                        quadrant_counts=None) -> None:
        """Per-round engine signals (evaluation metrics + Mod-2 mix)."""
        if loss is not None:
            self.observe("loss", loss, t=t, round=round)
        if accuracy is not None:
            self.observe("accuracy", accuracy, t=t, round=round)
        if quadrant_counts:
            vals = (list(quadrant_counts.values())
                    if hasattr(quadrant_counts, "values")
                    else list(quadrant_counts))
            self.observe("quadrant_skew", _gini(vals), t=t, round=round)
