"""Experiment-report generator: recorded telemetry → Markdown.

Consumes the JSONL event log a ``Telemetry`` hub recorded (or the raw
record list from a ring sink) and renders the run as a Markdown
experiment report: accuracy/loss curves as tables, the staleness
histogram, a participation-fairness summary, per-tier throughput, codec
byte accounting, and the final metrics snapshot.  This is the read side
of docs/OBSERVABILITY.md; the CLI lives in ``repro.launch.analysis``::

    PYTHONPATH=src python -m repro.launch.analysis --events run.jsonl --out report.md
"""
from __future__ import annotations

import json
from collections import Counter as _TallyCounter
from collections import defaultdict
from typing import Dict, List, Optional, Sequence

from repro.core.types import Quadrant

from .metrics import STALENESS_BUCKETS


def load_events(path: str) -> List[dict]:
    """Parse one recorded JSONL event log (skips blank lines, raises on
    malformed ones — a truncated log should fail loudly, not silently)."""
    records = []
    with open(path, encoding="utf-8") as fh:
        for i, line in enumerate(fh, 1):
            line = line.strip()
            if not line:
                continue
            try:
                records.append(json.loads(line))
            except json.JSONDecodeError as e:
                raise ValueError(f"{path}:{i}: malformed JSONL record: {e}")
    return records


def load_events_tolerant(path: str):
    """Parse a JSONL event log, skipping unparseable lines instead of
    raising — the loader for flight-recorder dumps, whose tail can be
    torn mid-line when a dump races a crash (docs/OBSERVABILITY.md).
    Returns ``(records, skipped)`` so the postmortem can disclose how
    much of the black box was unreadable."""
    records, skipped = [], 0
    with open(path, encoding="utf-8") as fh:
        for line in fh:
            line = line.strip()
            if not line:
                continue
            try:
                records.append(json.loads(line))
            except json.JSONDecodeError:
                skipped += 1
    return records, skipped


def _by_name(records: Sequence[dict]) -> Dict[str, List[dict]]:
    out: Dict[str, List[dict]] = defaultdict(list)
    for rec in records:
        out[rec.get("e", "?")].append(rec)
    return out


def _sample(rows: List, limit: int) -> List:
    """At most ``limit`` rows, evenly spaced, always keeping the last."""
    if len(rows) <= limit:
        return list(rows)
    step = -(-len(rows) // limit)  # ceiling: len(out) <= limit
    out = rows[::step]
    if out[-1] is not rows[-1]:
        out[-1] = rows[-1]
    return out


def gini(counts: Sequence[float]) -> float:
    """Gini coefficient of the per-client participation distribution
    (0 = perfectly even, →1 = one client dominates)."""
    xs = sorted(float(c) for c in counts)
    n = len(xs)
    total = sum(xs)
    if n == 0 or total <= 0:
        return 0.0
    cum = 0.0
    for i, x in enumerate(xs, 1):
        cum += i * x
    return (2.0 * cum) / (n * total) - (n + 1.0) / n


def _bar(count: int, peak: int, width: int = 30) -> str:
    if peak <= 0:
        return ""
    return "#" * max(1 if count else 0, round(count / peak * width))


def staleness_counts(fired: Sequence[dict],
                     bounds: Sequence[float] = STALENESS_BUCKETS):
    """Member-level staleness histogram from round-fired events: each
    member's tau is (round − 1) − stale_round (the pre-fire round basis
    the RoundReport uses)."""
    from bisect import bisect_left

    counts = [0] * (len(bounds) + 1)
    total = 0
    for rec in fired:
        basis = int(rec.get("round", 0)) - 1
        for member in rec.get("members", []):
            tau = basis - int(member[2])
            counts[bisect_left(bounds, tau)] += 1  # le-bucket semantics
            total += 1
    return counts, total


def _fmt_bucket(bounds: Sequence[float], i: int) -> str:
    if i == 0:
        return f"<= {bounds[0]:g}"
    if i == len(bounds):
        return f"> {bounds[-1]:g}"
    return f"({bounds[i - 1]:g}, {bounds[i]:g}]"


def experiment_report(records: Sequence[dict], *,
                      title: str = "Experiment report",
                      curve_rows: int = 20) -> str:
    """Render a recorded run as Markdown (see module docstring)."""
    groups = _by_name(records)
    lines: List[str] = [f"# {title}", ""]

    # ------------------------------------------------------------- overview
    admitted = groups.get("update-admitted", [])
    rejected = groups.get("update-rejected", [])
    fired = groups.get("round-fired", [])
    lines += ["## Run overview", ""]
    lines += ["| quantity | value |", "|---|---|"]
    lines.append(f"| events recorded | {len(records)} |")
    for name in ("update-admitted", "update-rejected", "round-fired",
                 "tier-merged", "codec-encoded", "client-classified",
                 "round-metrics"):
        if groups.get(name):
            lines.append(f"| `{name}` events | {len(groups[name])} |")
    if fired:
        lines.append(f"| rounds fired | {fired[-1]['round']} |")
        span = fired[-1]["t"] - fired[0]["t"]
        if span > 0:
            lines.append(f"| rounds/clock-unit | {len(fired) / span:.3f} |")
    if admitted:
        distinct = len({rec["cid"] for rec in admitted})
        lines.append(f"| distinct clients admitted | {distinct} |")
    lines.append("")

    # ----------------------------------------------------- lossiness warning
    snaps_ = groups.get("metrics-snapshot", [])
    dropped_events = 0
    if snaps_:
        m = snaps_[-1].get("metrics", {}).get("telemetry_events_dropped")
        if m:
            dropped_events = int(m.get("value", 0))
    spans_dropped = sum(int(rec.get("spans_dropped", 0))
                        for rec in groups.get("trace-summary", []))
    if dropped_events or spans_dropped:
        lines += ["> **Warning — lossy recording.** "
                  f"{dropped_events} event(s) and {spans_dropped} span(s) "
                  "were dropped at capacity-bounded sinks; histograms and "
                  "curves below undercount. Raise the ring/trace capacity "
                  "or record to JSONL (docs/OBSERVABILITY.md).", ""]

    # ------------------------------------------------------- health / alerts
    alerts = groups.get("health-alert", [])
    dumps = groups.get("flight-dump", [])
    health_on = bool(alerts or dumps)
    if not health_on and snaps_:
        # the monitor registers its counters eagerly at bind, so even an
        # alert-free run's snapshot says whether the plane was watching
        health_on = any(k.startswith("health.")
                        for k in snaps_[-1].get("metrics", {}))
    if health_on:
        lines += ["## Health / alerts", ""]
        if not alerts:
            lines += ["Health plane enabled — **no alerts fired**.", ""]
        else:
            n_crit = sum(1 for a in alerts if a.get("severity") == "critical")
            dets = sorted({str(a.get("detector")) for a in alerts})
            lines += [f"**{len(alerts)} alert(s)** ({n_crit} critical) from "
                      f"detector(s): {', '.join(f'`{d}`' for d in dets)}.", ""]
            lines += ["| round | t | detector | severity | value | mean | z |",
                      "|---|---|---|---|---|---|---|"]
            for a in _sample(alerts, curve_rows):
                lines.append(
                    f"| {a.get('round', -1)} | {float(a.get('t', 0.0)):.1f} "
                    f"| `{a.get('detector', '?')}` | {a.get('severity', '?')} "
                    f"| {float(a.get('value', 0.0)):.4g} "
                    f"| {float(a.get('mean', 0.0)):.4g} "
                    f"| {float(a.get('zscore', 0.0)):.1f} |")
            lines.append("")
        if dumps:
            lines += ["| flight dump | records | round | reason |",
                      "|---|---|---|---|"]
            for dmp in dumps:
                lines.append(f"| `{dmp.get('path', '?')}` "
                             f"| {dmp.get('n_records', 0)} "
                             f"| {dmp.get('round', -1)} "
                             f"| {dmp.get('reason', '?')} |")
            lines.append("")

    # ------------------------------------------------- accuracy/loss curves
    rounds = groups.get("round-metrics", [])
    if rounds:
        lines += ["## Accuracy / loss", ""]
        lines += ["| round | virtual time | loss | accuracy | stale members "
                  "| mean staleness |", "|---|---|---|---|---|---|"]
        for rec in _sample(rounds, curve_rows):
            lines.append(
                f"| {rec['round']} | {rec['t']:.1f} | {rec['loss']:.4f} "
                f"| {rec['accuracy']:.4f} | {rec['n_stale']} "
                f"| {rec['mean_staleness']:.2f} |")
        best = max(rec["accuracy"] for rec in rounds)
        tail = rounds[-min(len(rounds), 20):]
        final = sum(rec["accuracy"] for rec in tail) / len(tail)
        lines += ["", f"Best accuracy **{best:.4f}**; tail-window mean "
                      f"(last {len(tail)} evals) **{final:.4f}**.", ""]

    # --------------------------------------------------- staleness histogram
    if fired:
        # rebuild against the run's actual bucket ladder when the
        # snapshot carries one (configure_bounds overrides the default
        # STALENESS_BUCKETS for straggler-heavy streams)
        bounds = tuple(STALENESS_BUCKETS)
        if snaps_:
            h = snaps_[-1].get("metrics", {}).get("serve.staleness")
            if isinstance(h, dict) and h.get("bounds"):
                bounds = tuple(h["bounds"])
        counts, total = staleness_counts(fired, bounds)
        lines += ["## Staleness distribution (member-level, at fire)", ""]
        lines += ["| tau (rounds) | members | share | |", "|---|---|---|---|"]
        peak = max(counts) if counts else 0
        for i, c in enumerate(counts):
            if c == 0:
                continue
            lines.append(
                f"| {_fmt_bucket(bounds, i)} | {c} "
                f"| {c / max(total, 1):.1%} | `{_bar(c, peak)}` |")
        if counts[-1]:
            lines += ["", f"**{counts[-1]} member(s) ({counts[-1] / max(total, 1):.1%}) "
                          f"overflow the > {bounds[-1]:g} bucket** — widen the "
                          "ladder via `MetricsRegistry.configure_bounds"
                          "(\"serve.staleness\", ...)` to resolve the tail."]
        lines.append("")

    # ------------------------------------------------------ fairness summary
    if fired or admitted:
        participation: _TallyCounter = _TallyCounter()
        for rec in fired:
            for member in rec.get("members", []):
                participation[int(member[0])] += 1
        if not participation:  # no fires recorded: fall back to admissions
            for rec in admitted:
                participation[int(rec["cid"])] += 1
        if participation:
            counts = list(participation.values())
            top = participation.most_common(1)[0]
            lines += ["## Participation fairness", ""]
            lines += ["| quantity | value |", "|---|---|"]
            lines.append(f"| participating clients | {len(counts)} |")
            lines.append(f"| aggregated client updates | {sum(counts)} |")
            lines.append(
                f"| mean updates/client | {sum(counts) / len(counts):.2f} |")
            lines.append(f"| max share (client {top[0]}) "
                         f"| {top[1] / sum(counts):.1%} |")
            lines.append(f"| Gini coefficient | {gini(counts):.3f} |")
            if rejected:
                lines.append(
                    f"| admission drop rate "
                    f"| {len(rejected) / (len(rejected) + len(admitted)):.1%} |")
            lines.append("")

    # --------------------------------------------------- per-tier throughput
    tiers = groups.get("tier-merged", [])
    if tiers or fired:
        lines += ["## Per-tier throughput", ""]
        lines += ["| tier | nodes | fires | client updates | "
                  "mean members/fire |", "|---|---|---|---|---|"]
        for tier in ("edge", "region"):
            recs = [rec for rec in tiers if rec["tier"] == tier]
            if not recs:
                continue
            members = sum(rec["n_members"] for rec in recs)
            nodes = len({rec["node_id"] for rec in recs})
            lines.append(f"| {tier} | {nodes} | {len(recs)} | {members} "
                         f"| {members / len(recs):.1f} |")
        if fired:
            members = sum(rec["n_updates"] for rec in fired)
            lines.append(f"| global | 1 | {len(fired)} | {members} "
                         f"| {members / len(fired):.1f} |")
        lines.append("")

    # ------------------------------------------------------- codec accounting
    encoded = groups.get("codec-encoded", [])
    if encoded:
        wire = sum(rec["wire_bytes"] for rec in encoded)
        dense = sum(rec["dense_bytes"] for rec in encoded)
        lines += ["## Compressed transport", ""]
        lines += ["| quantity | value |", "|---|---|"]
        lines.append(f"| codec | `{encoded[0]['spec']}` |")
        lines.append(f"| encoded uploads | {len(encoded)} |")
        lines.append(f"| bytes on wire | {wire} |")
        lines.append(f"| dense fp32 bytes | {dense} |")
        lines.append(f"| compression ratio | {dense / max(wire, 1):.1f}x |")
        lines.append("")

    # ---------------------------------------------------------- quadrant mix
    classified = groups.get("client-classified", [])
    if classified:
        last: Dict[int, int] = {}
        for rec in classified:
            last[int(rec["cid"])] = int(rec["quadrant"])
        tally = _TallyCounter(last.values())
        lines += ["## Mod-2 quadrant mix (last classification per client)", ""]
        lines += ["| quadrant | clients |", "|---|---|"]
        for q in Quadrant:
            if tally.get(int(q)):
                lines.append(f"| {q.name} | {tally[int(q)]} |")
        lines.append("")

    # --------------------------------------------------------- critical path
    traces = groups.get("trace-summary", [])
    if traces:
        from .critical_path import format_summary

        ts = traces[-1]
        lines += ["## Critical path (traced run)", ""]
        lines += [f"{ts.get('rounds', 0)} rounds, {ts.get('spans', 0)} spans; "
                  f"round wall {ts.get('wall_s', 0.0) * 1e3:.1f} ms total, "
                  f"**{ts.get('coverage', 0.0):.1%}** explained by measured "
                  "stages (docs/OBSERVABILITY.md).", ""]
        lines += format_summary(ts)
        lines.append("")

    # -------------------------------------------------------- kernel profile
    kprofs = groups.get("kernel-profile", [])
    if kprofs:
        kp = kprofs[-1]
        lines += ["## Kernel profile", ""]
        lines += ["| quantity | value |", "|---|---|"]
        lines.append(f"| backend / dispatch mode | {kp.get('backend', '?')} / "
                     f"`{kp.get('mode', '?')}` |")
        lines.append(f"| timed op dispatches | {kp.get('dispatches', 0)} |")
        lines.append(f"| ref-path fallbacks | {kp.get('ref_fallbacks', 0)} |")
        lines.append(f"| autotune cache hits / misses "
                     f"| {kp.get('autotune_hits', 0)} / "
                     f"{kp.get('autotune_misses', 0)} |")
        lines.append("")

    # ------------------------------------------------------- metrics snapshot
    snaps = groups.get("metrics-snapshot", [])
    if snaps:
        metrics = snaps[-1].get("metrics", {})
        if metrics:
            lines += ["## Metrics snapshot", ""]
            lines += ["| metric | type | unit | value |", "|---|---|---|---|"]
            for name in sorted(metrics):
                m = metrics[name]
                if m["type"] == "histogram":
                    mean = m["sum"] / m["count"] if m["count"] else 0.0
                    value = (f"n={m['count']} mean={mean:.4g} "
                             f"min={m['min']} max={m['max']}")
                    over = (m.get("counts") or [0])[-1]
                    if over:
                        # saturating ladders undercount quantiles — say so
                        value += f" **overflow={over}**"
                else:
                    value = f"{m['value']:g}"
                lines.append(
                    f"| `{name}` | {m['type']} | {m.get('unit') or '—'} "
                    f"| {value} |")
            lines.append("")

    return "\n".join(lines).rstrip() + "\n"


def report_from_jsonl(path: str, *, title: Optional[str] = None) -> str:
    """One-call convenience: JSONL event log → Markdown report."""
    return experiment_report(load_events(path),
                             title=title or f"Experiment report — {path}")


def postmortem_report(path: str, *, title: Optional[str] = None,
                      curve_rows: int = 20) -> str:
    """Render a flight-recorder dump (``repro.telemetry.flightrec``) as
    a Markdown postmortem: a black-box preamble (dump reason/round, how
    much of the tail was torn), then the standard experiment report over
    the recorded window.  Tolerant by construction — a dump racing a
    crash can end mid-line, and the report must still render."""
    records, skipped = load_events_tolerant(path)
    meta = next((r for r in reversed(records) if r.get("e") == "flight-dump"),
                None)
    lines: List[str] = [f"# {title or f'Postmortem — {path}'}", ""]
    lines += ["> Reconstructed from a flight-recorder black box: a bounded "
              "ring of the run's most recent records, so counts below cover "
              "the final window only, not the whole run "
              "(docs/OBSERVABILITY.md).", ""]
    lines += ["| black box | value |", "|---|---|"]
    lines.append(f"| records recovered | {len(records)} |")
    if skipped:
        lines.append(f"| unreadable lines (torn tail) | {skipped} |")
    if meta is not None:
        lines.append(f"| dump reason | {meta.get('reason', '?')} |")
        lines.append(f"| dump round | {meta.get('round', -1)} |")
        lines.append(f"| ring records at dump | {meta.get('n_records', 0)} |")
    lines.append("")
    body = experiment_report(records, title="Recorded window",
                             curve_rows=curve_rows)
    return "\n".join(lines) + "\n" + body
