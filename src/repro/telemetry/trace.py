"""Span tracing: per-update lineage on the monotonic clock.

The event plane (``repro.telemetry.events``) records *what happened* on
the run's virtual clock; this module records *where wall time went*.  A
``Tracer`` hands out trace ids at ``submit()`` and the instrumented
components stamp named spans — admission, buffer residency, host stack,
kernel dispatch, tier merge, checkpoint — into a bounded ring of
``Span`` records on ``time.perf_counter``.  The critical-path analyzer
(``repro.telemetry.critical_path``) reconstructs each round's causal
DAG from those spans, and ``to_chrome_trace`` exports them as Chrome
trace-event JSON that loads directly in Perfetto / ``chrome://tracing``.

The contract mirrors the event plane's ``telemetry=None`` rule: every
instrumented site caches ``tracer = telemetry.tracer if telemetry else
None`` and guards with one ``is None`` check, so a hub without a tracer
costs nothing and aggregates bit-identically (gated by
``serve_trace_overhead`` in ``benchmarks/bench_serve.py``).

Span taxonomy (category / name — docs/OBSERVABILITY.md has the table):

* ``update``/``admit``   — admission decision for one update (has ``tid``)
* ``update``/``buffer``  — accepted update's residency until its round fires
* ``serve``/``round``    — one whole ``_aggregate`` call (wall time of a round)
* ``serve``/``dispatch`` — kernel routing + device work + block_until_ready
* ``serve``/``stack``    — host-side payload stacking inside dispatch
* ``serve``/``table``    — client-table math inside dispatch
* ``serve``/``finalize`` — post-dispatch bookkeeping (report rows, events)
* ``hier``/``tier-fire`` — one edge/region ``_reduce``
* ``kernel``/``<op>``    — one Pallas/XLA op dispatch (``telemetry.profile``)
* ``ckpt``/``save``      — checkpoint serialization
"""
from __future__ import annotations

import itertools
import time
from contextlib import contextmanager
from typing import Dict, Iterator, List, Optional


class Span:
    """One named interval on the monotonic clock.

    ``t0``/``dur`` are ``time.perf_counter`` seconds.  ``round`` and
    ``tid`` (trace id) are -1 when not applicable; ``args`` is an
    optional dict of small JSON-safe extras for the exported trace.
    """

    __slots__ = ("name", "cat", "t0", "dur", "round", "tid", "args")

    def __init__(self, name: str, cat: str, t0: float, dur: float,
                 round: int = -1, tid: int = -1,
                 args: Optional[dict] = None):
        self.name = name
        self.cat = cat
        self.t0 = t0
        self.dur = dur
        self.round = round
        self.tid = tid
        self.args = args

    def __repr__(self) -> str:  # debugging aid only
        return (f"Span({self.name!r}, cat={self.cat!r}, t0={self.t0:.6f}, "
                f"dur={self.dur * 1e3:.3f}ms, round={self.round}, "
                f"tid={self.tid})")


class SpanRing:
    """Bounded span store: drops the newest when full, counting drops.

    Appends are a single ``list.append`` — atomic under the GIL, so the
    async-dispatch worker thread and the ingest thread can both record
    without a lock.  Unlike the event plane's ``RingSink`` (which keeps
    the *most recent* records for live inspection), a trace is only
    causally analyzable from its start, so once full we drop *new*
    spans and surface the loss via ``dropped`` — the report and the
    ``telemetry_events_dropped`` counter make the truncation loud.
    """

    def __init__(self, capacity: int = 262144):
        self.capacity = int(capacity)
        self._spans: List[Span] = []
        self.dropped = 0

    def append(self, span: Span) -> None:
        if len(self._spans) >= self.capacity:
            self.dropped += 1
            return
        self._spans.append(span)

    @property
    def spans(self) -> List[Span]:
        return list(self._spans)

    def __len__(self) -> int:
        return len(self._spans)

    def clear(self) -> None:
        self._spans.clear()
        self.dropped = 0


class Tracer:
    """Hands out trace ids and records spans into a ``SpanRing``."""

    def __init__(self, capacity: int = 262144):
        self.ring = SpanRing(capacity)
        self._tids = itertools.count()

    # ------------------------------------------------------------- recording
    def new_trace(self) -> int:
        """A fresh trace id; one per submitted update."""
        return next(self._tids)

    @staticmethod
    def clock() -> float:
        return time.perf_counter()

    def record(self, name: str, cat: str, t0: float, dur: float,
               round: int = -1, tid: int = -1,
               args: Optional[dict] = None) -> None:
        """Record a span whose endpoints the caller already measured."""
        self.ring.append(Span(name, cat, t0, dur, round, tid, args))

    @contextmanager
    def span(self, name: str, cat: str, round: int = -1, tid: int = -1,
             args: Optional[dict] = None) -> Iterator[None]:
        t0 = time.perf_counter()
        try:
            yield
        finally:
            self.ring.append(
                Span(name, cat, t0, time.perf_counter() - t0, round, tid,
                     args))

    # ------------------------------------------------------------- consuming
    @property
    def spans(self) -> List[Span]:
        return self.ring.spans

    @property
    def dropped(self) -> int:
        return self.ring.dropped


# Stable Chrome-trace "thread" lanes per span category, so Perfetto
# renders admission/kernel/tier work as parallel tracks.
_CAT_LANES: Dict[str, int] = {
    "serve": 1, "kernel": 2, "hier": 3, "update": 4, "ckpt": 5,
}


def to_chrome_trace(spans: List[Span], *, dropped: int = 0) -> dict:
    """Render spans as a Chrome trace-event JSON object.

    The output is the standard ``{"traceEvents": [...]}`` wrapper with
    complete-duration (``ph="X"``) events in microseconds, loadable by
    Perfetto (ui.perfetto.dev) and ``chrome://tracing`` as-is.
    """
    events: List[dict] = []
    for cat, lane in sorted(_CAT_LANES.items(), key=lambda kv: kv[1]):
        events.append({
            "ph": "M", "pid": 1, "tid": lane, "name": "thread_name",
            "args": {"name": cat},
        })
    for s in spans:
        ev = {
            "name": s.name,
            "cat": s.cat,
            "ph": "X",
            "pid": 1,
            "tid": _CAT_LANES.get(s.cat, 0),
            "ts": s.t0 * 1e6,
            "dur": s.dur * 1e6,
        }
        args: dict = {}
        if s.round >= 0:
            args["round"] = s.round
        if s.tid >= 0:
            args["trace_id"] = s.tid
        if s.args:
            args.update(s.args)
        if args:
            ev["args"] = args
        events.append(ev)
    out = {"traceEvents": events, "displayTimeUnit": "ms"}
    if dropped:
        out["metadata"] = {"spans_dropped": int(dropped)}
    return out


__all__ = ["Span", "SpanRing", "Tracer", "to_chrome_trace"]
