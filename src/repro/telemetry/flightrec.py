"""Flight recorder: an always-on bounded black box for crashed runs.

A 10k-client chaos run that dies two hours in leaves, today, whatever
the JSONL sink flushed — everything since the last flush is gone, and
an in-memory-only run leaves nothing.  The flight recorder is a small
locked ring that rides the hub's sink fan-out (it implements the
``Sink`` protocol), always holding the last ``capacity`` records, and
persists them to disk when it matters:

* **alert** — a health detector fired (``HealthMonitor`` calls
  ``dump(reason="alert")``), so the window *around* the anomaly is
  captured, not just the anomaly line itself;
* **atexit** — interpreter shutdown, which also covers unhandled
  exceptions (Python runs atexit hooks after the traceback), so a
  crashed run still leaves its final window behind;
* **close** — ``Telemetry.close()`` closes its sinks, giving every
  clean run a final black box beside its artifacts.

Each dump is a standalone JSONL file — the ring's records in order,
then one trailing ``flight-dump`` meta record — readable by
``launch/analysis.py --postmortem`` (which tolerates a torn tail: a
dump racing a crash can end mid-line).  Successive dumps go to
``<path>``, ``<path>.1``, ``<path>.2``, … so an alert dump is never
overwritten by the atexit one.

The ring drops its *oldest* records by design; that is normal
operation, not lossiness, so the counter is named ``evicted`` — the
``dropped`` attribute name would make ``Telemetry.close()`` count
black-box turnover as telemetry loss and taint every report.
"""
from __future__ import annotations

import atexit
import json
import threading
from collections import deque
from typing import Optional

from .events import FlightDump


class FlightRecorder:
    """Bounded black-box ring sink (module docstring).

    ``capacity`` trades retrospect depth against dump size; 4096 records
    is a few hundred KB and covers hundreds of rounds of round-level
    events (per-update events on a big stream shorten the window — raise
    capacity for update-level forensics).
    """

    def __init__(self, path: str, capacity: int = 4096, *,
                 auto_dump: bool = True):
        self.path = str(path)
        self._ring: deque = deque(maxlen=int(capacity))
        self._lock = threading.Lock()
        self.evicted = 0        # oldest-record turnover (normal, not loss)
        self.dumps = 0          # files written so far
        self._closed = False
        self._telemetry = None
        if auto_dump:
            atexit.register(self._atexit_dump)

    def bind(self, telemetry) -> None:
        """Hub back-reference so dumps can emit ``flight-dump`` events
        into the *other* sinks (the recorder itself sees them too)."""
        self._telemetry = telemetry

    # ------------------------------------------------------------ Sink API
    def write(self, record: dict) -> None:
        with self._lock:
            if len(self._ring) == self._ring.maxlen:
                self.evicted += 1
            self._ring.append(record)

    def close(self) -> None:
        if self._closed:
            return
        # flip the flag FIRST: the close dump must not emit a
        # flight-dump event back through the hub, whose other sinks are
        # already closed by the time ours is
        self._closed = True
        self.dump(reason="close")

    def __len__(self) -> int:
        return len(self._ring)

    # --------------------------------------------------------------- dumps
    def _dump_path(self) -> str:
        return self.path if self.dumps == 0 else f"{self.path}.{self.dumps}"

    def _atexit_dump(self) -> None:
        # interpreter shutdown with the recorder still open = the run
        # never reached Telemetry.close() — a crash or a kill
        if not self._closed and len(self._ring):
            self.dump(reason="atexit")

    def dump(self, *, reason: str, round: int = -1,
             t: Optional[float] = None) -> Optional[str]:
        """Persist the current ring to the next dump file; returns the
        path (``None`` when the ring is empty).  Thread-safe; the file
        write happens outside the ring lock so a slow disk never stalls
        emitters."""
        with self._lock:
            if not self._ring:
                return None
            records = list(self._ring)
            path = self._dump_path()
            self.dumps += 1
        meta = FlightDump(t=t, round=int(round), path=path,
                          n_records=len(records), reason=reason)
        with open(path, "w") as fh:
            for rec in records:
                fh.write(json.dumps(rec) + "\n")
            fh.write(json.dumps(meta.to_record()) + "\n")
        if self._telemetry is not None and not self._closed:
            self._telemetry.emit(meta)
        return path
