"""Metrics registry: counters, gauges, and bounded histograms.

Host-side, allocation-free on the hot path (a counter ``inc`` is one
int add; a histogram ``observe`` is one bisect + two adds), and fully
snapshot-able to plain JSON — the registry is what the benchmarks and
the experiment report read after a run.  Metric names are dotted
``<layer>.<metric>`` strings; the canonical schema table lives in
docs/OBSERVABILITY.md.
"""
from __future__ import annotations

from bisect import bisect_left
from typing import Dict, Optional, Sequence, Tuple

# Default bucket ladders (upper bounds; the last bucket is +inf).
STALENESS_BUCKETS: Tuple[float, ...] = (0, 1, 2, 3, 5, 8, 13, 21, 34)
SECONDS_BUCKETS: Tuple[float, ...] = (
    1e-5, 1e-4, 3e-4, 1e-3, 3e-3, 1e-2, 3e-2, 0.1, 0.3, 1.0, 3.0, 10.0)
BYTES_BUCKETS: Tuple[float, ...] = (
    256, 1024, 4096, 16384, 65536, 262144, 1048576, 4194304, 16777216)


class Counter:
    """Monotone event count."""

    __slots__ = ("name", "unit", "layer", "value")

    def __init__(self, name: str, unit: str = "", layer: str = ""):
        self.name, self.unit, self.layer = name, unit, layer
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n

    def snapshot(self) -> dict:
        return {"type": "counter", "unit": self.unit, "layer": self.layer,
                "value": self.value}


class Gauge:
    """Last-written level (buffer depth, per-quadrant population, ...)."""

    __slots__ = ("name", "unit", "layer", "value")

    def __init__(self, name: str, unit: str = "", layer: str = ""):
        self.name, self.unit, self.layer = name, unit, layer
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = v

    def snapshot(self) -> dict:
        return {"type": "gauge", "unit": self.unit, "layer": self.layer,
                "value": self.value}


class Histogram:
    """Bounded histogram: fixed bucket upper bounds plus an overflow
    bucket, with running count/sum/min/max — O(log #buckets) per
    observation and a few dozen ints of state however long the run."""

    __slots__ = ("name", "unit", "layer", "bounds", "counts",
                 "count", "total", "vmin", "vmax")

    def __init__(self, name: str, bounds: Sequence[float],
                 unit: str = "", layer: str = ""):
        self.name, self.unit, self.layer = name, unit, layer
        self.bounds = tuple(float(b) for b in bounds)
        if list(self.bounds) != sorted(self.bounds):
            raise ValueError(f"histogram bounds must be sorted: {bounds}")
        self.counts = [0] * (len(self.bounds) + 1)  # last = overflow
        self.count = 0
        self.total = 0.0
        self.vmin = float("inf")
        self.vmax = float("-inf")

    def observe(self, v: float) -> None:
        v = float(v)
        # upper-bound-inclusive buckets (Prometheus "le" semantics):
        # bucket i counts v <= bounds[i]; the last bucket is the overflow
        self.counts[bisect_left(self.bounds, v)] += 1
        self.count += 1
        self.total += v
        if v < self.vmin:
            self.vmin = v
        if v > self.vmax:
            self.vmax = v

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def snapshot(self) -> dict:
        return {
            "type": "histogram", "unit": self.unit, "layer": self.layer,
            "bounds": list(self.bounds), "counts": list(self.counts),
            "count": self.count, "sum": self.total,
            "min": self.vmin if self.count else None,
            "max": self.vmax if self.count else None,
        }


class MetricsRegistry:
    """Name → metric store with get-or-create semantics.

    Re-requesting an existing name returns the same instance (so every
    layer can bind its handles independently); requesting it as a
    different metric type raises.
    """

    def __init__(self):
        self._metrics: Dict[str, object] = {}
        self._bounds: Dict[str, Tuple[float, ...]] = {}

    def _get_or_create(self, cls, name: str, *args, **kw):
        m = self._metrics.get(name)
        if m is None:
            m = cls(name, *args, **kw)
            self._metrics[name] = m
        elif not isinstance(m, cls):
            raise TypeError(
                f"metric {name!r} already registered as "
                f"{type(m).__name__}, requested {cls.__name__}")
        return m

    def counter(self, name: str, *, unit: str = "", layer: str = "") -> Counter:
        return self._get_or_create(Counter, name, unit, layer)

    def gauge(self, name: str, *, unit: str = "", layer: str = "") -> Gauge:
        return self._get_or_create(Gauge, name, unit, layer)

    def configure_bounds(self, name: str, bounds: Sequence[float]) -> None:
        """Override the bucket ladder a *future* ``histogram(name, ...)``
        call will use — the per-metric escape hatch for defaults that
        saturate (the hardcoded staleness ladder tops out at 34 rounds;
        a straggler-heavy stream piles everything into its overflow
        bucket, docs/OBSERVABILITY.md).  Must run before the metric is
        first created: overriding an already-materialized histogram
        would silently rebucket mid-run, so that raises instead."""
        m = self._metrics.get(name)
        if m is not None:
            if isinstance(m, Histogram) and tuple(
                    float(b) for b in bounds) == m.bounds:
                return  # no-op re-assertion of the live ladder
            raise ValueError(
                f"metric {name!r} already materialized; configure_bounds "
                "must run before the first histogram() call")
        self._bounds[name] = tuple(float(b) for b in bounds)

    def histogram(self, name: str, bounds: Sequence[float], *,
                  unit: str = "", layer: str = "") -> Histogram:
        bounds = self._bounds.get(name, bounds)
        return self._get_or_create(Histogram, name, bounds, unit, layer)

    def get(self, name: str) -> Optional[object]:
        return self._metrics.get(name)

    def __contains__(self, name: str) -> bool:
        return name in self._metrics

    def names(self):
        return sorted(self._metrics)

    def snapshot(self) -> dict:
        """Plain-JSON view of every registered metric."""
        return {name: self._metrics[name].snapshot() for name in self.names()}
