"""Mod-1: global aggregation estimation.

Clients store the two most recent global models and derive the
pseudo-global gradient ``L_g(w_g^t) = w_g^t - w_g^{t-1}`` (paper §3.2,
following FedBuff/FedAC).  The local-global update similarity s_i^t is
computed between the client's latest local update direction and that
pseudo-global gradient.

All three similarity functions from the paper's Mod-1 ablation (Table 5)
are provided.  Each maps to [-1, 1]-ish scores where larger = more aligned:

* cosine     — ⟨a,b⟩ / (‖a‖‖b‖)                      (default)
* euclidean  — 1 / (1 + ‖a−b‖)   ∈ (0, 1]
* manhattan  — 1 / (1 + ‖a−b‖₁)  ∈ (0, 1]

The distance-based scores are squashed so that "larger is more similar"
holds for every metric, which the quadrant logic (Mod-2) relies on.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from .types import Params, tree_flat_vector, tree_sub


def pseudo_global_gradient(w_curr: Params, w_prev: Params) -> Params:
    """L_g(w_g^t) = w_g^t − w_g^{t−1} (paper Eq. in §3.2)."""
    return tree_sub(w_curr, w_prev)


def cosine_similarity(a: jnp.ndarray, b: jnp.ndarray, eps: float = 1e-12) -> jnp.ndarray:
    dot = jnp.vdot(a, b)
    na = jnp.linalg.norm(a)
    nb = jnp.linalg.norm(b)
    return dot / jnp.maximum(na * nb, eps)


def euclidean_similarity(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    return 1.0 / (1.0 + jnp.linalg.norm(a - b))


def manhattan_similarity(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    return 1.0 / (1.0 + jnp.sum(jnp.abs(a - b)))


_SIMILARITY_FNS: dict[str, Callable] = {
    "cosine": cosine_similarity,
    "euclidean": euclidean_similarity,
    "manhattan": manhattan_similarity,
}


def get_similarity_fn(name: str) -> Callable:
    try:
        return _SIMILARITY_FNS[name]
    except KeyError:
        raise ValueError(
            f"unknown similarity {name!r}; choose from {sorted(_SIMILARITY_FNS)}"
        ) from None


def local_global_similarity(
    local_update: Params,
    pseudo_global: Params,
    kind: str = "cosine",
) -> jnp.ndarray:
    """s_i^t — similarity between a local update and the pseudo-global gradient.

    Note on sign conventions: the pseudo-global gradient ``w^t − w^{t−1}``
    points along the *descent step* the server took, while a raw local
    gradient points uphill.  Callers must pass the local update in *step*
    space (i.e. ``−η·Σ∇F`` or ``w_i − w_g``), which is what both FedQS
    uploads already are.
    """
    fn = get_similarity_fn(kind)
    a = tree_flat_vector(local_update)
    b = tree_flat_vector(pseudo_global)
    return fn(a, b)


# Fused one-pass statistics used by the distributed runtime & Pallas kernel.
def fused_dot_norms(a: jnp.ndarray, b: jnp.ndarray):
    """Return (⟨a,b⟩, ‖a‖², ‖b‖²) — the reduction triple behind cosine.

    Reference semantics for ``repro.kernels.similarity``; the kernel computes
    the same triple in one HBM pass.
    """
    return jnp.vdot(a, b), jnp.vdot(a, a), jnp.vdot(b, b)
