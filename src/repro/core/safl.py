"""Event-driven semi-asynchronous FL engine (virtual clock).

This is the paper-faithful runtime (DESIGN §2 layer 1): N autonomous
clients with heterogeneous speeds train on possibly-stale global models and
push updates; the server buffers K updates and then aggregates (SAFL
conditional trigger).  FedQS and all 11 baselines plug in through the
``Algorithm`` interface (``repro.core.algorithms``).

The server side lives in ``repro.serve.StreamingAggregator`` — the engine
is one client of its ingestion API: the event loop ``submit``s each
finished local-training burst and the service owns the K-buffer trigger,
the aggregation dispatch, and the server state (global model, status
table, round counter), which the engine re-exports as properties.

Fidelity notes:
* staleness τ_i arises naturally: a client trains on the global round it
  last fetched; fast clients re-fetch often, stragglers lag;
* Mod-1 runs client-side on the last two global models the client has seen
  (not the server's — the paper is explicit that Mod-1 is client-local);
* the server's status table, averages f̄/s̄ and the 3-float downlink are
  modeled exactly;
* dynamic environments (paper §5.3 scenarios 1–3) are first-class
  ``Scenario`` objects (``repro.scenarios``): population models decide
  who the clients are, arrival processes decide when they are available
  (always-on / Poisson / diurnal / burst / trace replay), and dynamic
  events mutate speeds, churn membership, or drift data per round.  The
  historic ``dynamics`` callback still works — it is wrapped into a
  single-event scenario, bit-identical to the legacy path.  For 10k+
  client populations use the vectorized ``repro.scenarios.CohortEngine``.
"""
from __future__ import annotations

import heapq
import time as _time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import FederatedData
from repro.optim.sgd import local_train_epochs
from .aggregation import server_aggregate
from .similarity import local_global_similarity, pseudo_global_gradient
from repro.telemetry import ClientClassified, RoundMetricsEvent

from .types import (
    AggregationStrategy,
    ClientState,
    FedQSHyperParams,
    Params,
    Quadrant,
    RoundMetrics,
    ServerTable,
    Update,
    tree_sub,
)


@dataclass
class ModelSpec:
    """Task model plugged into the engine (see ``repro.models.small``)."""

    init: Callable[[jax.Array], Params]
    grad_fn: Callable[[Params, dict], Params]          # jitted ∇F(w; batch)
    eval_fn: Callable[[Params, np.ndarray, np.ndarray], Tuple[float, float]]
    predict_fn: Callable[[Params, np.ndarray], np.ndarray]
    batch_size: int = 32


@dataclass
class EngineResult:
    metrics: List[RoundMetrics]
    wall_seconds: float
    final_params: Params

    def best_accuracy(self) -> float:
        return max(m.accuracy for m in self.metrics) if self.metrics else 0.0

    def final_accuracy(self, last: int = 20) -> float:
        """Mean accuracy over the **tail window** of the ``last`` most
        recent evaluated rounds — not the single final round.  The
        window smooths SAFL's round-to-round oscillation (paper Fig. 4);
        pass ``last=1`` for the literal final-round accuracy.  Fewer
        than ``last`` recorded rounds simply average what exists.

        Raises ``ValueError`` for ``last <= 0`` (a non-positive window
        would silently average the *whole* history via Python's
        negative-slice semantics).
        """
        if last <= 0:
            raise ValueError(f"final_accuracy window must be >= 1, got {last}")
        tail = self.metrics[-last:]
        return float(np.mean([m.accuracy for m in tail])) if tail else 0.0

    def rounds_to_accuracy(self, target: float) -> Optional[int]:
        for m in self.metrics:
            if m.accuracy >= target:
                return m.round
        return None

    def oscillations(self, threshold: float = 0.15) -> int:
        acc = [m.accuracy for m in self.metrics]
        return sum(1 for a, b in zip(acc, acc[1:]) if a - b > threshold)

    def stability_score(self, threshold: float = 0.15) -> float:
        """Fraction of round-to-round transitions that are NOT an
        oscillation (an accuracy drop deeper than ``threshold``), in
        [0, 1]: 1.0 = monotone-stable learning, lower = choppier
        (paper Fig. 4's oscillation phenomenon).  Monotone
        non-increasing in the number of oscillation events for a fixed
        history length; fewer than two recorded rounds score 1.0.
        """
        transitions = len(self.metrics) - 1
        if transitions <= 0:
            return 1.0
        return 1.0 - self.oscillations(threshold) / transitions

    def virtual_time(self) -> float:
        return self.metrics[-1].virtual_time if self.metrics else 0.0


class SAFLEngine:
    """Semi-asynchronous driver.  ``algo`` decides client adaptation and
    server weighting; the engine owns time, staleness and the K-buffer."""

    def __init__(
        self,
        data: FederatedData,
        spec: ModelSpec,
        algo: "Algorithm",
        hp: FedQSHyperParams,
        *,
        resource_ratio: float = 50.0,
        seed: int = 0,
        dynamics: Optional[Callable[[int, np.ndarray, np.random.Generator], np.ndarray]] = None,
        scenario: Optional["Scenario"] = None,
        eval_every: int = 1,
        sync_mode: bool = False,
        compress: Optional[str] = None,
        topology=None,
        telemetry=None,
    ):
        self.data = data
        self.spec = spec
        self.algo = algo
        self.hp = hp
        self.rng = np.random.default_rng(seed)
        self.eval_every = eval_every
        self.sync_mode = sync_mode

        # Environment description.  ``scenario`` is the first-class API
        # (repro.scenarios); a legacy ``dynamics`` callback is wrapped into
        # a single-event scenario consuming identical RNG draws, so old
        # callers are bit-identical.  Imported lazily (scenarios imports
        # repro.core back).
        from repro.scenarios.scenario import Scenario

        if scenario is not None and dynamics is not None:
            raise ValueError("pass either scenario= or the legacy dynamics=, not both")
        if scenario is None:
            scenario = (Scenario.from_dynamics(dynamics) if dynamics is not None
                        else Scenario())
        if sync_mode and (scenario.events or scenario.arrivals is not None):
            # the sync reference loop consults neither events nor arrivals —
            # refuse rather than silently run the static setting
            raise ValueError(
                "sync_mode supports only static scenarios (population models "
                "are fine); dynamic events and arrival processes are "
                "semi-asynchronous features"
            )
        if getattr(scenario, "device", None) is not None:
            # the per-client event loop has no schedule-time outcome hook —
            # refuse rather than silently run the scenario minus its device
            # model (docs/ROBUSTNESS.md)
            raise ValueError(
                f"scenario {scenario.name!r} carries a device-state model, "
                "which the event-driven engine does not simulate — run it "
                "through CohortEngine or serve.scenario_stream instead"
            )
        self.scenario = scenario
        self.dynamics = dynamics  # kept for introspection/back-compat

        n = data.n_clients
        # compute resources: the scenario's population model, defaulting to
        # the historic uniform spread, fastest:slowest = 1:ratio (the same
        # single rng.uniform draw, keeping seeded runs reproducible)
        self.speeds = scenario.sample_speeds(n, self.rng, resource_ratio)
        key = jax.random.PRNGKey(seed)
        self.prev_global: Dict[int, Params] = {}
        self.clients = [
            ClientState(
                cid=i,
                n_samples=data.clients[i].n,
                speed=float(self.speeds[i]),
                lr=hp.eta0,
                momentum=hp.m0,
            )
            for i in range(n)
        ]
        self.alive = np.ones(n, bool)
        # per-client event-chain generation: bumped on revival so stale heap
        # events from before a death are discarded instead of forking the
        # client into two concurrent chains
        self._gen = np.zeros(n, np.int64)

        # the server is the streaming service with the paper's K-buffer
        # trigger and admit-all policy; ``context=self`` hands algorithms
        # the full engine surface (speeds, clients, data) at aggregation.
        # With a topology the server becomes the tiered plane
        # (docs/HIERARCHY.md): clients report to edge aggregators whose
        # assignment follows the sampled speeds, and the global K-buffer
        # counts client updates through the partial member view, so round
        # cadence matches the flat service.  Imported lazily: repro.hier
        # pulls in repro.serve/repro.core at module scope.
        from repro.hier import make_aggregation_service
        from repro.serve.triggers import KBuffer

        self.service = make_aggregation_service(
            algo, hp, spec.init(key), n,
            topology=topology,
            trigger=KBuffer(hp.buffer_k),
            context=self,
            speeds=self.speeds,
            telemetry=telemetry,
        )
        # telemetry (docs/OBSERVABILITY.md): the service publishes the
        # serve-layer events; the engine adds Mod-2 classifications and
        # per-round evaluation metrics.  None = fully disabled.
        self.telemetry = telemetry
        if telemetry is not None:
            self._tm_quadrants = {
                int(q): telemetry.metrics.gauge(
                    f"engine.quadrant_{q.name.lower()}",
                    unit="clients", layer="core")
                for q in Quadrant
            }

        # compressed uplink (docs/COMPRESSION.md): each client's upload is
        # encoded at the submit boundary — exactly where the wire would be —
        # and the service decodes (or fused-aggregates) server-side
        self.compressor = None
        if compress is not None and compress != "none":
            from repro.compress import ClientCompressor

            self.compressor = ClientCompressor(compress, n, seed=seed)
            self.compressor.telemetry = telemetry
            self.service.compressor = self.compressor

        # client-side Mod-1 storage: the last two global models seen
        self._client_globals: Dict[int, Tuple[int, Params, Optional[Params]]] = {}

    # ------------------------------------------------- server state (service)
    # The service owns the server state; these properties keep the historic
    # engine surface (tests, checkpointing, algorithms) working unchanged.
    @property
    def global_params(self) -> Params:
        return self.service.global_params

    @global_params.setter
    def global_params(self, value: Params) -> None:
        self.service.global_params = value

    @property
    def table(self) -> ServerTable:
        return self.service.table

    @table.setter
    def table(self, value: ServerTable) -> None:
        self.service.table = value

    @property
    def round(self) -> int:
        return self.service.round

    @round.setter
    def round(self, value: int) -> None:
        self.service.round = value

    # ---------------------------------------------------------- client side
    def _client_fetch(self, cid: int):
        """Client synchronizes to the current global model (keeps previous
        for pseudo-global-gradient computation)."""
        prev = self._client_globals.get(cid)
        prev_params = prev[1] if prev is not None else None
        self._client_globals[cid] = (self.round, self.global_params, prev_params)

    def _server_view(self):
        """The 3-float downlink: (f̄, s̄, f_i broadcast as table)."""
        counts = np.asarray(self.table.counts)
        total = max(counts.sum(), 1)
        f = counts / total
        return f, float(f.mean()), float(np.asarray(self.table.sims).mean())

    def _client_train(self, cid: int, now: float = 0.0) -> Update:
        """One autonomous local-training burst → an Update for the buffer."""
        fetched_round, w_start, w_prev = self._client_globals[cid]
        c = self.clients[cid]
        ds = self.data.clients[cid]

        f_all, f_bar, s_bar = self._server_view()
        decision = self.algo.client_adapt(
            self, cid, float(f_all[cid]), f_bar, c.last_similarity, s_bar
        )
        c.lr, c.momentum = float(decision[0]), float(decision[1])
        feedback = bool(decision[2])
        c.quadrant = int(decision[3])
        if self.telemetry is not None:
            self.telemetry.emit(ClientClassified(
                t=float(now), round=self.round, cid=cid,
                quadrant=c.quadrant, lr=c.lr, momentum=c.momentum,
                feedback=feedback,
            ))

        batches = ds.batches(
            self.spec.batch_size,
            epoch_seed=self.rng.integers(2**31),
            n_batches=self.hp.local_epochs,
        )
        w_end, _ = local_train_epochs(
            w_start,
            self.spec.grad_fn,
            batches,
            c.lr,
            c.momentum,
            grad_clip=self.hp.grad_clip,
        )

        delta = tree_sub(w_start, w_end)  # η Σ_e ΔF_{i,e}  (Remark B.1)

        # Mod-1: similarity vs. pseudo-global gradient (client-local)
        if w_prev is not None:
            pg = pseudo_global_gradient(w_start, w_prev)
            # both vectors in *step* space: −delta is the local step taken
            sim = float(
                local_global_similarity(
                    jax.tree_util.tree_map(jnp.negative, delta), pg, self.hp.similarity
                )
            )
        else:
            sim = 0.0
        c.last_similarity = sim
        c.feedback = feedback
        c.stale_round = fetched_round

        return Update(
            cid=cid,
            n_samples=c.n_samples,
            stale_round=fetched_round,
            lr=c.lr,
            similarity=sim,
            feedback=feedback,
            speed_f=float(f_all[cid]),
            delta=delta,
            params=w_end,
        )

    def _submit(self, update: Update, now: float):
        """Submit one finished burst, crossing the (possibly compressed)
        uplink: with a compressor the update is encoded here — error
        feedback against this client's residual — and the service ingests
        the wire form."""
        if self.compressor is not None:
            update = self.compressor.encode_update(
                update, strategy=getattr(self.algo, "strategy", None)
            )
        return self.service.submit(update, now=now)

    # ---------------------------------------------------------- server side
    def _metrics(self, vt: float, buffer: List[Update]) -> RoundMetrics:
        loss, acc = self.spec.eval_fn(self.global_params, self.data.test_x, self.data.test_y)
        stale = [self.round - 1 - u.stale_round for u in buffer]
        qc: Dict[str, int] = {}
        for c in self.clients:
            qc[str(c.quadrant)] = qc.get(str(c.quadrant), 0) + 1
        m = RoundMetrics(
            round=self.round,
            virtual_time=vt,
            loss=float(loss),
            accuracy=float(acc),
            n_stale=sum(1 for s in stale if s > 0),
            mean_staleness=float(np.mean(stale)) if stale else 0.0,
            quadrant_counts=qc,
        )
        if self.telemetry is not None:
            for q, gauge in self._tm_quadrants.items():
                gauge.set(qc.get(str(q), 0))
            self.telemetry.emit(RoundMetricsEvent(
                t=float(vt), round=m.round, loss=m.loss, accuracy=m.accuracy,
                n_stale=m.n_stale, mean_staleness=m.mean_staleness,
                quadrant_counts=dict(qc),
            ))
            if self.telemetry.health is not None:
                self.telemetry.health.observe_metrics(
                    t=float(vt), round=m.round, loss=m.loss,
                    accuracy=m.accuracy, quadrant_counts=qc)
        return m

    # ---------------------------------------------------------------- driver
    def run(self, n_rounds: int) -> EngineResult:
        t0 = _time.perf_counter()
        if self.sync_mode:
            result = self._run_sync(n_rounds)
        else:
            result = self._run_async(n_rounds)
        return EngineResult(result, _time.perf_counter() - t0, self.global_params)

    def _run_async(self, n_rounds: int) -> List[RoundMetrics]:
        if self.scenario.arrivals is not None:
            return self._run_async_arrivals(n_rounds)
        n = self.data.n_clients
        heap: List[Tuple[float, int, int, int]] = []  # (finish_time, seq, cid, gen)
        seq = 0
        for cid in range(n):
            self._client_fetch(cid)
            jitter = self.rng.uniform(0.5, 1.5)
            heapq.heappush(heap, (self.clients[cid].speed * jitter, seq, cid, 0))
            seq += 1

        metrics: List[RoundMetrics] = []
        vt = 0.0
        while self.round < n_rounds and heap:
            vt, _, cid, gen = heapq.heappop(heap)
            if not self.alive[cid] or gen != self._gen[cid]:
                continue
            update = self._client_train(cid, now=vt)
            # client immediately checks for a fresh global model, then keeps
            # going — the fetch deliberately precedes the submit so the
            # uploader trains on the pre-aggregation model (upload/fetch race)
            self._client_fetch(cid)
            jitter = self.rng.uniform(0.9, 1.1)
            heapq.heappush(heap, (vt + self.clients[cid].speed * jitter, seq, cid, gen))
            seq += 1

            result = self._submit(update, now=vt)
            if result.fired:
                if self.round % self.eval_every == 0:
                    metrics.append(self._metrics(vt, result.report.buffer))
                for rcid in self._post_round():
                    self._client_fetch(rcid)
                    jitter = self.rng.uniform(0.9, 1.1)
                    heapq.heappush(
                        heap,
                        (vt + self.clients[rcid].speed * jitter, seq, rcid,
                         int(self._gen[rcid])),
                    )
                    seq += 1
        return metrics

    _START, _FINISH = 0, 1

    def _run_async_arrivals(self, n_rounds: int) -> List[RoundMetrics]:
        """Arrival-gated event loop: the scenario's ``ArrivalProcess``
        decides when each client begins a local-training burst.  Unlike
        the always-on loop, the client fetches the global model at burst
        *start* (not right after its previous upload), so availability
        gaps translate into staleness exactly as they would live; trace
        replay can also pin per-burst compute times."""
        n = self.data.n_clients
        arr = self.scenario.arrivals
        heap: List[Tuple[float, int, int, int, int]] = []  # (time, seq, cid, kind, gen)
        seq = 0
        starts = arr.start(n, self.rng)
        for cid in range(n):
            if np.isfinite(starts[cid]):
                heapq.heappush(heap, (float(starts[cid]), seq, cid, self._START, 0))
                seq += 1

        metrics: List[RoundMetrics] = []
        while self.round < n_rounds and heap:
            vt, _, cid, kind, gen = heapq.heappop(heap)
            if not self.alive[cid] or gen != self._gen[cid]:
                continue
            if kind == self._START:
                self._client_fetch(cid)
                default = self.clients[cid].speed * self.rng.uniform(0.9, 1.1)
                compute = arr.compute_time(cid, vt, default, self.rng)
                heapq.heappush(heap, (vt + float(compute), seq, cid, self._FINISH, gen))
                seq += 1
                continue
            update = self._client_train(cid, now=vt)
            result = self._submit(update, now=vt)
            nxt = arr.next_start(cid, vt, self.rng)
            if np.isfinite(nxt):
                heapq.heappush(heap, (max(float(nxt), vt), seq, cid, self._START, gen))
                seq += 1
            if result.fired:
                if self.round % self.eval_every == 0:
                    metrics.append(self._metrics(vt, result.report.buffer))
                for rcid in self._post_round():
                    t = arr.next_start(rcid, vt, self.rng)
                    if np.isfinite(t):
                        heapq.heappush(
                            heap,
                            (max(float(t), vt), seq, rcid, self._START,
                             int(self._gen[rcid])),
                        )
                        seq += 1
        return metrics

    def _post_round(self) -> List[int]:
        """Apply the scenario's dynamic events after an aggregation fire.

        Speed mutations follow the historic ``dynamics`` contract (NaN =
        dead); additionally a dead client whose speed turns finite again
        is *revived* — returned so the caller can re-enqueue it — and
        data-mutating events (drift) run against ``self.data``.
        """
        revived: List[int] = []
        new_speeds = self.scenario.apply_events(self.round, self.speeds, self.rng)
        if new_speeds is not None:
            self.speeds = new_speeds
            for i, c in enumerate(self.clients):
                if np.isfinite(new_speeds[i]):
                    c.speed = float(new_speeds[i])
                    if not self.alive[i]:
                        # bump the generation so any heap event from before
                        # the death is discarded — revival starts one fresh
                        # event chain, never a duplicate
                        self.alive[i] = True
                        self._gen[i] += 1
                        revived.append(i)
                else:
                    self.alive[i] = False
        if self.scenario.has_data_events:
            self.scenario.mutate_data(self.round, self.data, self.rng)
        return revived

    def _run_sync(self, n_rounds: int) -> List[RoundMetrics]:
        """Synchronous FL reference (paper Table 3 shadowed columns):
        the server activates K clients per round and waits for the slowest."""
        metrics: List[RoundMetrics] = []
        vt = 0.0
        n = self.data.n_clients
        while self.round < n_rounds:
            live = np.flatnonzero(self.alive)
            sel = self.rng.choice(live, size=min(self.hp.buffer_k, len(live)), replace=False)
            vt += max(self.clients[c].speed for c in sel)  # idle until slowest
            report = None
            for cid in sel:
                self._client_fetch(cid)
                res = self._submit(self._client_train(cid, now=vt), now=vt)
                if res.fired:
                    report = res.report
            if report is None:  # fewer live clients than K: force the round
                report = self.service.flush(now=vt)
            if self.round % self.eval_every == 0:
                metrics.append(self._metrics(vt, report.buffer))
        return metrics


# --------------------------------------------------------------------------
# dynamic-environment callbacks (paper §5.3) — legacy API.  The first-class
# form is ``repro.scenarios`` (ResourceScale / SpeedJitter / Dropout events
# delegate to these exact functions, so the two paths are bit-identical).
# --------------------------------------------------------------------------
def scenario_resource_scale(at_round: int, new_ratio: float):
    """Scenario 1: speed ratio shifts (1:50 → 1:new_ratio) at ``at_round``."""

    def fn(rnd, speeds, rng):
        if rnd == at_round:
            lo = speeds.min()
            return lo + (speeds - lo) * (new_ratio - 1) / max(speeds.max() / lo - 1, 1e-9)
        return None

    return fn


def scenario_unstable_resources(lo: float = 1.0, hi: float = 50.0, unit: float = 10.0):
    """Scenario 2: each client's resource fluctuates within ±unit per round."""

    def fn(rnd, speeds, rng):
        return np.clip(speeds + rng.uniform(-unit, unit, speeds.shape), lo, hi)

    return fn


def scenario_dropout(at_round: int, frac: float = 0.5):
    """Scenario 3: ``frac`` of clients churn at ``at_round`` (NaN = dead)."""

    def fn(rnd, speeds, rng):
        if rnd == at_round:
            out = speeds.copy()
            dead = rng.choice(len(speeds), int(len(speeds) * frac), replace=False)
            out[dead] = np.nan
            return out
        return None

    return fn
