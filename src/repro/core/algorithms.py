"""FedQS and the 11 baseline algorithms (paper §5.2, Appendix D.4).

Each algorithm implements two hooks used by ``SAFLEngine``:

* ``client_adapt``    → (lr, momentum, feedback_bit, quadrant) at fetch time;
* ``server_aggregate``→ (new_global, new_table) over one K-buffer.

Baselines follow Appendix D.4's descriptions, mapped to this engine's
buffered-trigger SAFL loop.  All operate on pytrees, so they run unchanged
for every model family in the zoo.
"""
from __future__ import annotations

from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .aggregation import (
    aggregate_gradients,
    aggregate_models,
    aggregation_weights,
    server_aggregate as fedqs_server_aggregate,
    update_table,
)
from .classify import adapt as mod2_adapt, ssbc_situation
from .similarity import tree_flat_vector
from .types import (
    AggregationStrategy,
    FedQSHyperParams,
    Params,
    Quadrant,
    ServerTable,
    SSBCSituation,
    Update,
    tree_add,
    tree_scale,
    tree_sub,
    tree_weighted_sum,
    tree_zeros_like,
)


class Algorithm:
    name = "base"
    strategy = AggregationStrategy.MODEL

    def __init__(self, hp: FedQSHyperParams):
        self.hp = hp

    # -------- client side: constant lr, no momentum, no feedback ---------
    def client_adapt(self, engine, cid, f_i, f_bar, s_i, s_bar):
        return (self.hp.eta0, 0.0, False, int(Quadrant.SWBC))

    # -------- server side: sample-count weighting -------------------------
    def _base_weights(self, buffer: List[Update]) -> jnp.ndarray:
        n = np.asarray([u.n_samples for u in buffer], np.float32)
        return jnp.asarray(n / n.sum())

    def _table(self, engine, buffer) -> ServerTable:
        cids = jnp.asarray([u.cid for u in buffer], jnp.int32)
        sims = jnp.asarray([u.similarity for u in buffer], jnp.float32)
        return update_table(engine.table, cids, sims)

    def server_aggregate(self, engine, buffer: List[Update]):
        table = self._table(engine, buffer)
        p = self._base_weights(buffer)
        if self.strategy is AggregationStrategy.GRADIENT:
            new = aggregate_gradients(engine.global_params, [u.delta for u in buffer], p, self.hp.eta_g)
        else:
            new = aggregate_models([u.params for u in buffer], p)
        return new, table


# ===========================================================================
# FedQS (the paper)
# ===========================================================================
class FedQS(Algorithm):
    """FedQS-SGD / FedQS-Avg depending on ``strategy``."""

    def __init__(self, hp: FedQSHyperParams, strategy=AggregationStrategy.GRADIENT):
        super().__init__(hp)
        self.strategy = strategy
        self.name = f"fedqs-{strategy.value}"

    def client_adapt(self, engine, cid, f_i, f_bar, s_i, s_bar):
        c = engine.clients[cid]
        sit = SSBCSituation.STRAGGLER
        # SSBC pre-check: only bother with the validation pass if the client
        # would land in SSBC (slow & biased).
        if f_i <= f_bar and s_i < s_bar:
            ds = engine.data.clients[cid]
            per_label = ds.per_label_val_accuracy(
                lambda x: engine.spec.predict_fn(engine.global_params, x),
                engine.data.n_labels,
            )
            sit = int(ssbc_situation(jnp.asarray(per_label), self.hp.ssbc_cv_threshold))
        d = mod2_adapt(f_i, f_bar, s_i, s_bar, c.lr, self.hp, ssbc_sit=sit)
        return (float(d.lr), float(d.momentum), bool(d.feedback), int(d.quadrant))

    def server_aggregate(self, engine, buffer):
        new, table, _ = fedqs_server_aggregate(
            self.strategy, engine.global_params, buffer, engine.table,
            self.hp, engine.data.n_clients,
        )
        return new, table


# ===========================================================================
# foundational baselines
# ===========================================================================
class FedAvg(Algorithm):
    name = "fedavg"
    strategy = AggregationStrategy.MODEL


class FedSGD(Algorithm):
    name = "fedsgd"
    strategy = AggregationStrategy.GRADIENT


# ===========================================================================
# model-aggregation baselines
# ===========================================================================
class SAFA(Algorithm):
    """SAFA [31]: server-side model cache per client; each trigger
    aggregates *all* cached models (lag-bounded), refreshing the cache with
    the newest uploads first."""

    name = "safa"
    strategy = AggregationStrategy.MODEL

    def __init__(self, hp, lag_tolerance: int = 5):
        super().__init__(hp)
        self.cache: dict[int, Tuple[Params, int, int]] = {}  # cid -> (w, round, n)
        self.lag = lag_tolerance

    def server_aggregate(self, engine, buffer):
        table = self._table(engine, buffer)
        for u in buffer:
            self.cache[u.cid] = (u.params, engine.round, u.n_samples)
        # deprecate entries older than the lag tolerance
        live = {c: v for c, v in self.cache.items() if engine.round - v[1] <= self.lag}
        self.cache = live
        models = [v[0] for v in live.values()]
        n = np.asarray([v[2] for v in live.values()], np.float32)
        p = jnp.asarray(n / n.sum())
        return aggregate_models(models, p), table


class FedAT(Algorithm):
    """FedAT [18]: speed-tiered aggregation; tiers that update less often
    get *larger* weight to rebalance (their weighted heuristic)."""

    name = "fedat"
    strategy = AggregationStrategy.MODEL
    n_tiers = 5

    def __init__(self, hp):
        super().__init__(hp)
        self.tier_of: Optional[np.ndarray] = None
        self.tier_updates = np.zeros(self.n_tiers)

    def _ensure_tiers(self, engine):
        if self.tier_of is None:
            # cluster by observed speed (no prior knowledge claim is FedQS's
            # advantage; FedAT does use it — Appendix D.4)
            q = np.quantile(engine.speeds, np.linspace(0, 1, self.n_tiers + 1)[1:-1])
            self.tier_of = np.digitize(engine.speeds, q)

    def server_aggregate(self, engine, buffer):
        self._ensure_tiers(engine)
        table = self._table(engine, buffer)
        for u in buffer:
            self.tier_updates[self.tier_of[u.cid]] += 1
        tot = self.tier_updates.sum()
        # cross-tier weight ∝ (1 + total − own) → rarely-updating tiers favored
        tier_w = (1.0 + tot - self.tier_updates) / max(tot, 1.0)
        n = np.asarray([u.n_samples for u in buffer], np.float32)
        w = n * np.asarray([tier_w[self.tier_of[u.cid]] for u in buffer])
        p = jnp.asarray(w / w.sum())
        return aggregate_models([u.params for u in buffer], p), table


class MStep(Algorithm):
    """M-step-FedAsync [37]: weights from model-deviation degree (inner
    product of local vs global parameters) × update frequency."""

    name = "m-step"
    strategy = AggregationStrategy.MODEL

    def server_aggregate(self, engine, buffer):
        table = self._table(engine, buffer)
        g = tree_flat_vector(engine.global_params)
        gn = jnp.linalg.norm(g) + 1e-12
        counts = np.asarray(table.counts, np.float32)
        ws = []
        for u in buffer:
            v = tree_flat_vector(u.params)
            dev = jnp.vdot(v, g) / (jnp.linalg.norm(v) * gn + 1e-12)
            freq = counts[u.cid] / max(counts.sum(), 1.0)
            ws.append(float((1.0 + dev) * u.n_samples / (1.0 + freq)))
        w = np.maximum(np.asarray(ws, np.float32), 1e-6)
        p = jnp.asarray(w / w.sum())
        return aggregate_models([u.params for u in buffer], p), table


class DeFedAvg(Algorithm):
    """DeFedAvg [42]: uniform weights; the server accepts delayed updates
    as-is (linear-speedup analysis assumes unweighted averaging)."""

    name = "defedavg"
    strategy = AggregationStrategy.MODEL

    def _base_weights(self, buffer):
        return jnp.full((len(buffer),), 1.0 / len(buffer))


# ===========================================================================
# gradient-aggregation baselines
# ===========================================================================
class FedBuff(Algorithm):
    """FedBuff [16]: buffered async aggregation with staleness discount
    s(τ) = 1/sqrt(1+τ) on each pseudo-gradient."""

    name = "fedbuff"
    strategy = AggregationStrategy.GRADIENT

    def server_aggregate(self, engine, buffer):
        table = self._table(engine, buffer)
        stale = np.asarray([engine.round - u.stale_round for u in buffer], np.float32)
        n = np.asarray([u.n_samples for u in buffer], np.float32)
        w = n / n.sum() / np.sqrt(1.0 + stale)
        p = jnp.asarray(w / w.sum())
        new = aggregate_gradients(engine.global_params, [u.delta for u in buffer], p, self.hp.eta_g)
        return new, table


class WKAFL(Algorithm):
    """WKAFL [15]: two-stage — estimate an unbiased global gradient from an
    EMA of past aggregates, then weight each local update by its cosine to
    the estimate (negative-aligned updates are dropped); clipped."""

    name = "wkafl"
    strategy = AggregationStrategy.GRADIENT

    def __init__(self, hp, ema: float = 0.5):
        super().__init__(hp)
        self.est: Optional[Params] = None
        self.ema = ema

    def server_aggregate(self, engine, buffer):
        table = self._table(engine, buffer)
        n = np.asarray([u.n_samples for u in buffer], np.float32)
        if self.est is None:
            w = n / n.sum()
        else:
            e = tree_flat_vector(self.est)
            en = jnp.linalg.norm(e) + 1e-12
            cos = []
            for u in buffer:
                d = tree_flat_vector(u.delta)
                cos.append(float(jnp.vdot(d, e) / (jnp.linalg.norm(d) * en + 1e-12)))
            w = n * np.maximum(np.asarray(cos, np.float32), 0.05)
            w = w / w.sum()
        p = jnp.asarray(w)
        agg = tree_weighted_sum([u.delta for u in buffer], p)
        self.est = agg if self.est is None else jax.tree_util.tree_map(
            lambda a, b: self.ema * a + (1 - self.ema) * b, self.est, agg
        )
        new = jax.tree_util.tree_map(lambda wg, s: wg - self.hp.eta_g * s, engine.global_params, agg)
        return new, table


class FedAC(Algorithm):
    """FedAC [20]: prospective momentum aggregation + temporal (staleness)
    gradient evaluation + SCAFFOLD-style fine-grained correction (server
    keeps a control variate approximated by the running mean update)."""

    name = "fedac"
    strategy = AggregationStrategy.GRADIENT

    def __init__(self, hp, server_momentum: float = 0.5):
        super().__init__(hp)
        self.u: Optional[Params] = None
        self.c_global: Optional[Params] = None
        self.gamma = server_momentum

    def server_aggregate(self, engine, buffer):
        table = self._table(engine, buffer)
        stale = np.asarray([engine.round - u.stale_round for u in buffer], np.float32)
        n = np.asarray([u.n_samples for u in buffer], np.float32)
        w = (n / n.sum()) * np.exp(-0.5 * stale)
        w = w / max(w.sum(), 1e-12)
        agg = tree_weighted_sum([u.delta for u in buffer], jnp.asarray(w))
        if self.c_global is not None:  # drift correction toward running mean
            agg = jax.tree_util.tree_map(lambda a, c: 0.9 * a + 0.1 * c, agg, self.c_global)
        self.c_global = agg if self.c_global is None else jax.tree_util.tree_map(
            lambda c, a: 0.9 * c + 0.1 * a, self.c_global, agg
        )
        self.u = agg if self.u is None else jax.tree_util.tree_map(
            lambda u_, a: self.gamma * u_ + a, self.u, agg
        )
        new = jax.tree_util.tree_map(lambda wg, s: wg - self.hp.eta_g * s, engine.global_params, self.u)
        return new, table


class FADAS(Algorithm):
    """FADAS [43]: FedBuff-style buffering + Adam-like server update over
    the aggregated pseudo-gradient (delay-adaptive η)."""

    name = "fadas"
    strategy = AggregationStrategy.GRADIENT

    def __init__(self, hp, b1=0.9, b2=0.99, eps=1e-8, server_lr=0.05):
        super().__init__(hp)
        self.b1, self.b2, self.eps, self.server_lr = b1, b2, eps, server_lr
        self.m: Optional[Params] = None
        self.v: Optional[Params] = None
        self.t = 0

    def server_aggregate(self, engine, buffer):
        table = self._table(engine, buffer)
        stale = np.asarray([engine.round - u.stale_round for u in buffer], np.float32)
        p = self._base_weights(buffer)
        agg = tree_weighted_sum([u.delta for u in buffer], p)
        self.t += 1
        if self.m is None:
            self.m, self.v = tree_zeros_like(agg), tree_zeros_like(agg)
        self.m = jax.tree_util.tree_map(lambda m, g: self.b1 * m + (1 - self.b1) * g, self.m, agg)
        self.v = jax.tree_util.tree_map(lambda v, g: self.b2 * v + (1 - self.b2) * g * g, self.v, agg)
        mh = tree_scale(self.m, 1.0 / (1 - self.b1**self.t))
        vh = tree_scale(self.v, 1.0 / (1 - self.b2**self.t))
        # delay-adaptive step: shrink with max staleness in the buffer
        lr = self.server_lr / np.sqrt(1.0 + stale.max())
        new = jax.tree_util.tree_map(
            lambda w, m, v: w - lr * m / (jnp.sqrt(v) + self.eps),
            engine.global_params, mh, vh,
        )
        return new, table


class CA2FL(Algorithm):
    """CA²FL [44]: cached update calibration — the server keeps the latest
    update h_i per client and calibrates each aggregation with the cache
    mean: v = mean_i(h_i) + Σ_{i∈S} p_i (δ_i − h_i)."""

    name = "ca2fl"
    strategy = AggregationStrategy.GRADIENT

    def __init__(self, hp):
        super().__init__(hp)
        self.cache: dict[int, Params] = {}

    def server_aggregate(self, engine, buffer):
        table = self._table(engine, buffer)
        p = self._base_weights(buffer)
        deltas = [u.delta for u in buffer]
        cached = [self.cache.get(u.cid) for u in buffer]
        corr = [
            tree_sub(d, h) if h is not None else d for d, h in zip(deltas, cached)
        ]
        v = tree_weighted_sum(corr, p)
        if self.cache:
            hbar = tree_scale(
                jax.tree_util.tree_map(
                    lambda *xs: sum(xs), *list(self.cache.values())
                ),
                1.0 / len(self.cache),
            )
            v = tree_add(v, hbar)
        for u in buffer:
            self.cache[u.cid] = u.delta
        new = jax.tree_util.tree_map(lambda w, s: w - self.hp.eta_g * s, engine.global_params, v)
        return new, table


ALGORITHMS = {
    "fedqs-sgd": lambda hp: FedQS(hp, AggregationStrategy.GRADIENT),
    "fedqs-avg": lambda hp: FedQS(hp, AggregationStrategy.MODEL),
    "fedavg": FedAvg,
    "fedsgd": FedSGD,
    "safa": SAFA,
    "fedat": FedAT,
    "m-step": MStep,
    "defedavg": DeFedAvg,
    "fedbuff": FedBuff,
    "wkafl": WKAFL,
    "fedac": FedAC,
    "fadas": FADAS,
    "ca2fl": CA2FL,
}


def make_algorithm(name: str, hp: FedQSHyperParams) -> Algorithm:
    try:
        return ALGORITHMS[name](hp)
    except KeyError:
        raise ValueError(f"unknown algorithm {name!r}; choose from {sorted(ALGORITHMS)}") from None
