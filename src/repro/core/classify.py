"""Mod-2: local training adaptation (divide-and-conquer over clients).

Implements the paper's §3.3:

* quadrant classification from (f_i vs f̄, s_i vs s̄);
* per-quadrant learning-rate adaptation  η_i ← η_i ∓ a·F, F = f̄/f_i;
* momentum assignment m_i = m0 + k(1/G − 1), G = s̄/s_i, applied only to
  the well-aligned quadrants (FWBC, SWBC) and to SSBC in Situation 1;
* the SSBC situation detector (per-label validation performance spread);
* the 1-bit feedback flag raised by FSBC and SSBC-Situation-2 clients.

Everything is expressed as branch-free jnp algebra so the same code also
runs vectorized over the client axis inside the distributed shard_map step.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from .types import FedQSHyperParams, Quadrant, SSBCSituation


def update_speed(counts: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Paper Eq. 2: f_i = n(i)/Σn(i), f̄ = mean_i f_i = 1/N.

    Returns (f[N], f̄).  With the paper's definition f̄ is identically 1/N;
    we keep the explicit mean so alternative speed estimators slot in.
    """
    total = jnp.maximum(jnp.sum(counts), 1)
    f = counts.astype(jnp.float32) / total
    return f, jnp.mean(f)


def mean_similarity(sims: jnp.ndarray) -> jnp.ndarray:
    """Paper Eq. 2: s̄ = (Σ_i s_g(i)) / N over the server table."""
    return jnp.mean(sims)


def classify_quadrant(f_i, f_bar, s_i, s_bar) -> jnp.ndarray:
    """Vectorizable quadrant id (Figure 3). Ties break toward 'weakly biased'
    / 'straggling' which matches the paper's >/< strict inequalities."""
    fast = f_i > f_bar
    weak = s_i >= s_bar
    # FSBC=0 fast&biased, FWBC=1 fast&weak, SWBC=2 slow&weak, SSBC=3 slow&biased
    return jnp.where(
        fast,
        jnp.where(weak, Quadrant.FWBC, Quadrant.FSBC),
        jnp.where(weak, Quadrant.SWBC, Quadrant.SSBC),
    ).astype(jnp.int32)


def speed_ratio(f_i, f_bar, clip: float = 1e3) -> jnp.ndarray:
    """F = f̄ / f_i, clamped (DESIGN §9: near-idle clients make F explode)."""
    return jnp.clip(f_bar / jnp.maximum(f_i, 1e-12), 1.0 / clip, clip)


def similarity_ratio(s_i, s_bar, clip: float = 1e3) -> jnp.ndarray:
    """G = s̄ / s_i, clamped. Negative cosine similarities are floored so G
    stays meaningful (strongly-anti-aligned ⇒ tiny momentum anyway)."""
    s_i = jnp.maximum(s_i, 1e-6)
    s_bar = jnp.maximum(s_bar, 1e-6)
    return jnp.clip(s_bar / s_i, 1.0 / clip, clip)


def adapt_learning_rate(
    lr: jnp.ndarray,
    quadrant: jnp.ndarray,
    F: jnp.ndarray,
    hp: FedQSHyperParams,
) -> jnp.ndarray:
    """Per-quadrant lr update (§3.3):

    FSBC: unchanged.  FWBC: η ← η − a·F.  SWBC/SSBC: η ← η + a·F.
    Bounded to [lr_min, lr_max] = [α, β] per Appendix D.3.
    """
    delta = jnp.where(
        quadrant == Quadrant.FWBC,
        -hp.a * F,
        jnp.where(
            (quadrant == Quadrant.SWBC) | (quadrant == Quadrant.SSBC),
            hp.a * F,
            0.0,
        ),
    )
    return jnp.clip(lr + delta, hp.lr_min, hp.lr_max)


def momentum_rate(G: jnp.ndarray, hp: FedQSHyperParams) -> jnp.ndarray:
    """m_i = m0 + k(1/G − 1), clipped to [0, θ] (θ=momentum_max)."""
    m = hp.m0 + hp.k * (1.0 / G - 1.0)
    return jnp.clip(m, 0.0, hp.momentum_max)


def ssbc_situation(per_label_acc: jnp.ndarray, cv_threshold: float) -> jnp.ndarray:
    """SSBC diagnosis from the local validation set (§3.3).

    If the global model performs *similarly on each label* → Situation 1
    (plain straggler, momentum path).  Large per-label spread → Situation 2
    (dispersed distribution, feedback path).  Spread is measured by the
    coefficient of variation of per-label accuracy; labels absent from the
    validation set must be passed as NaN and are ignored.
    """
    valid = ~jnp.isnan(per_label_acc)
    n = jnp.maximum(jnp.sum(valid), 1)
    masked = jnp.where(valid, per_label_acc, 0.0)
    mean = jnp.sum(masked) / n
    var = jnp.sum(jnp.where(valid, (per_label_acc - mean) ** 2, 0.0)) / n
    cv = jnp.sqrt(var) / jnp.maximum(mean, 1e-6)
    return jnp.where(
        cv > cv_threshold, SSBCSituation.DISPERSED, SSBCSituation.STRAGGLER
    ).astype(jnp.int32)


class AdaptationDecision(NamedTuple):
    """Everything Mod-2 hands to local training + the 1-bit uplink."""

    quadrant: jnp.ndarray      # i32
    lr: jnp.ndarray            # f32 — adapted local learning rate
    momentum: jnp.ndarray      # f32 — Eq-3 momentum rate (0 disables)
    feedback: jnp.ndarray      # bool — raise Mod-3 feedback weighting
    F: jnp.ndarray             # f̄/f_i (server needs it for the weight formula)
    G: jnp.ndarray             # s̄/s_i


def adapt(
    f_i,
    f_bar,
    s_i,
    s_bar,
    lr,
    hp: FedQSHyperParams,
    ssbc_sit: jnp.ndarray | int = SSBCSituation.STRAGGLER,
) -> AdaptationDecision:
    """Full Mod-2 decision for one client (vectorizes with vmap over clients).

    ``ssbc_sit`` is the validation-set diagnosis; it only matters when the
    client lands in SSBC.
    """
    q = classify_quadrant(f_i, f_bar, s_i, s_bar)
    F = speed_ratio(f_i, f_bar, hp.ratio_clip)
    G = similarity_ratio(s_i, s_bar, hp.ratio_clip)
    new_lr = adapt_learning_rate(jnp.asarray(lr, jnp.float32), q, F, hp)

    sit = jnp.asarray(ssbc_sit, jnp.int32)
    ssbc_dispersed = (q == Quadrant.SSBC) & (sit == SSBCSituation.DISPERSED)
    # momentum for FWBC, SWBC, SSBC-Sit1; never for FSBC / SSBC-Sit2
    momentum_on = (
        (q == Quadrant.FWBC)
        | (q == Quadrant.SWBC)
        | ((q == Quadrant.SSBC) & (sit == SSBCSituation.STRAGGLER))
    )
    m = jnp.where(momentum_on & hp.use_momentum, momentum_rate(G, hp), 0.0)
    feedback = ((q == Quadrant.FSBC) | ssbc_dispersed) & hp.use_feedback
    return AdaptationDecision(q, new_lr, m, feedback, F, G)
