"""FedQS core: Mod-1 (similarity), Mod-2 (adaptation), Mod-3 (aggregation),
the SAFL engine, and the baseline algorithm zoo."""
from .types import (
    AggregationStrategy,
    ClientState,
    FedQSHyperParams,
    Quadrant,
    RoundMetrics,
    ServerTable,
    SSBCSituation,
    Update,
)
from .similarity import (
    cosine_similarity,
    euclidean_similarity,
    get_similarity_fn,
    local_global_similarity,
    manhattan_similarity,
    pseudo_global_gradient,
)
from .classify import adapt, classify_quadrant, momentum_rate, ssbc_situation
from .aggregation import aggregation_weights, feedback_weight, server_aggregate, update_table
from .safl import EngineResult, ModelSpec, SAFLEngine
from .algorithms import ALGORITHMS, Algorithm, FedQS, make_algorithm

__all__ = [
    "AggregationStrategy", "ClientState", "FedQSHyperParams", "Quadrant",
    "RoundMetrics", "ServerTable", "SSBCSituation", "Update",
    "cosine_similarity", "euclidean_similarity", "get_similarity_fn",
    "local_global_similarity", "manhattan_similarity", "pseudo_global_gradient",
    "adapt", "classify_quadrant", "momentum_rate", "ssbc_situation",
    "aggregation_weights", "feedback_weight", "server_aggregate", "update_table",
    "EngineResult", "ModelSpec", "SAFLEngine",
    "ALGORITHMS", "Algorithm", "FedQS", "make_algorithm",
]
