"""Distributed FedQS runtime: the SAFL round as ONE pjit tensor program
(DESIGN §2 layer 2).

Two modes, selected by ``cfg.fl_mode``:

* ``stacked`` — the K buffered clients live on the ``data`` mesh axis.
  Local E-step training runs under ``vmap`` over the client axis (each
  data shard trains its client in parallel); per-client deltas are stacked
  [C, ...] arrays sharded on the client axis; Mod-3's weighted aggregation
  is a single einsum over C that GSPMD lowers to the ICI all-reduce /
  reduce-scatter.  For architectures whose full weights fit one
  model-parallel column (≲50 GB).

* ``fsdp`` — weights are FSDP-sharded over (data[, pod]) × model; the K
  clients are processed by ``lax.scan`` (weights shared — all clients
  start each round from the same fetched w_g; their divergence lives in
  the per-client delta, which is consumed into the weighted accumulator
  inside the scan step so peak memory stays at weights + 2 accumulators).
  For the ≥100 B architectures (kimi-k2, deepseek-v3, llama-90b, qwen-110b).

Both modes implement the full Mod-①/②/③ state machine with mesh-resident
per-client vectors (lr, momentum, similarity, staleness) and the server
table as dense arrays — the host-side event loop (repro.core.safl) feeds
staleness/speeds in a real deployment; the dry-run feeds ShapeDtypeStructs.

NOTE: the jitted step never calls Pallas — the dry-run compiles for the
forced-host CPU backend where TPU custom-calls cannot lower.  On real TPU
hardware ``repro.kernels`` swap in via the serving/aggregation wrappers.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as T
from .classify import (
    adapt_learning_rate,
    classify_quadrant,
    momentum_rate,
    similarity_ratio,
    speed_ratio,
)
from .types import FedQSHyperParams, Quadrant


def _tree_vdot(a, b):
    """Σ⟨leaf_a, leaf_b⟩ WITHOUT flattening.

    §Perf (EXPERIMENTS pair 2, iter 3): ``jnp.vdot`` ravels its inputs; a
    1-D reshape of a tensor whose *middle* dim is mesh-sharded is not
    expressible as a sharded layout, so GSPMD all-gathers the whole
    operand first — observed as f32 [60,·,384,7168,2048] gathers (1.35 TB
    × 14 ops × 16 clients) on kimi-k2.  Elementwise multiply + full
    reduction keeps the sharding and lowers to partial sums + a scalar
    all-reduce."""
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    return sum(jnp.sum(x.astype(jnp.float32) * y.astype(jnp.float32))
               for x, y in zip(la, lb))


def _tree_sqnorm(a):
    return _tree_vdot(a, a)


def _clip_by_global_norm(grads, max_norm):
    norm = jnp.sqrt(_tree_sqnorm(grads))
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-12))
    return jax.tree_util.tree_map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads)


class RoundState(NamedTuple):
    """Mesh-resident FedQS state threaded between rounds."""
    params: Any            # w_g^t
    prev_params: Any       # w_g^{t-1}  (Mod-1 pseudo-global gradient source)
    lr: jax.Array          # f32[C] per-slot client learning rates
    momentum: jax.Array    # f32[C]
    counts: jax.Array      # i32[N] server table n(i)
    sims: jax.Array        # f32[N] server table s_g(i)


def _mod2_vectors(hp: FedQSHyperParams, f_i, f_bar, s_i, s_bar, lr):
    """Vectorized Mod-2 over the buffer (dispersed-SSBC detection is a
    host-side signal; the mesh program treats SSBC as Situation 1, the
    conservative momentum path — the feedback bit for Situation 2 arrives
    with the host metadata in deployment)."""
    q = classify_quadrant(f_i, f_bar, s_i, s_bar)
    F = speed_ratio(f_i, f_bar, hp.ratio_clip)
    G = similarity_ratio(s_i, s_bar, hp.ratio_clip)
    new_lr = adapt_learning_rate(lr, q, F, hp)
    momentum_on = (q == Quadrant.FWBC) | (q == Quadrant.SWBC) | (q == Quadrant.SSBC)
    m = jnp.where(momentum_on & hp.use_momentum, momentum_rate(G, hp), 0.0)
    feedback = (q == Quadrant.FSBC) & hp.use_feedback
    return q, F, G, new_lr, m, feedback


def _mod3_weights(hp: FedQSHyperParams, feedback, F, G, K: int, N: int):
    phi = jnp.asarray(K / N, jnp.float32)
    x = phi - F
    fb_w = jnp.exp(x) / jnp.exp2(x) * (1.0 + G) ** 2 / K
    p = jnp.where(feedback, fb_w, 1.0 / K)   # equal n_i in the tensor program
    return p / jnp.maximum(jnp.sum(p), 1e-12)


def _local_train(cfg, hp, params, lr, momentum, batch, param_pspecs=None):
    """E local epochs of Eq-3 momentum SGD for ONE client.
    Returns (delta = w_start − w_end, mean loss).

    ``param_pspecs`` (§Perf): optional PartitionSpec pytree matching
    ``params``; when given, gradients/velocity/updated weights are
    explicitly constrained to the weight shardings each step — without
    this, sharding propagation through the grad-of-scan accumulators can
    fall back to all-gathering full f32 stacked-parameter tensors per
    client (observed on kimi-k2; EXPERIMENTS §Perf pair 2)."""
    loss_fn = lambda p, b: T.train_loss(cfg, p, b)
    grad_fn = jax.value_and_grad(loss_fn)

    def pin(tree):
        if param_pspecs is None:
            return tree
        return jax.tree_util.tree_map(
            jax.lax.with_sharding_constraint, tree, param_pspecs)

    w = params
    vel = jax.tree_util.tree_map(lambda x: jnp.zeros_like(x, jnp.float32), params)
    total_loss = 0.0
    for _ in range(hp.local_epochs):
        loss, grads = grad_fn(w, batch)
        grads = pin(grads)
        grads = _clip_by_global_norm(grads, hp.grad_clip)
        vel = pin(jax.tree_util.tree_map(
            lambda g, v: g.astype(jnp.float32) + momentum * v, grads, vel))
        w = pin(jax.tree_util.tree_map(
            lambda x, v: (x.astype(jnp.float32) - lr * v).astype(x.dtype), w, vel))
        total_loss = total_loss + loss
    delta = pin(jax.tree_util.tree_map(
        lambda a, b: a.astype(jnp.float32) - b.astype(jnp.float32), params, w))
    return delta, total_loss / hp.local_epochs


def _similarity_to_pseudo_global(delta, pseudo_global):
    """Mod-1: cos(−δ, w_g^t − w_g^{t−1}) — both in descent-step space."""
    dot = -_tree_vdot(delta, pseudo_global)
    na = jnp.sqrt(_tree_sqnorm(delta))
    nb = jnp.sqrt(_tree_sqnorm(pseudo_global))
    return dot / jnp.maximum(na * nb, 1e-12)


def make_fedqs_round_step(cfg, hp: FedQSHyperParams, *, strategy: str = "sgd",
                          n_clients: int = 16, total_clients: int = 100,
                          client_group_size: int = 1, param_pspecs=None):
    """Build the jittable FedQS round.  Signature:

        step(state: RoundState, batch, slot_cids i32[C], staleness f32[C])
            -> (new_state, metrics)

    ``batch['tokens']`` is [C, b, S] — one microbatch per buffered client.

    ``client_group_size`` (fsdp mode, §Perf): process g clients per scan
    step under vmap so each FSDP weight all-gather is amortized over g
    clients — collective volume ∝ C/g, delta live-memory ∝ g.
    """
    C, N = n_clients, total_clients
    g = max(1, client_group_size)
    assert C % g == 0, "client_group_size must divide n_clients"

    def per_client(w_g, pseudo_global, lr_c, m_c, batch_c):
        delta, loss = _local_train(cfg, hp, w_g, lr_c, m_c, batch_c,
                                   param_pspecs=param_pspecs)
        sim = _similarity_to_pseudo_global(delta, pseudo_global)
        return delta, loss, sim

    def step(state: RoundState, batch, slot_cids, staleness):
        w_g = state.params
        pseudo_global = jax.tree_util.tree_map(
            lambda a, b: a.astype(jnp.float32) - b.astype(jnp.float32),
            w_g, state.prev_params)

        # ---- server-table-derived indicators (Eq. 1/2) ------------------
        total = jnp.maximum(jnp.sum(state.counts), 1)
        f_all = state.counts.astype(jnp.float32) / total
        f_bar = jnp.mean(f_all)
        s_bar = jnp.mean(state.sims)
        f_i = f_all[slot_cids]

        if cfg.fl_mode == "stacked":
            deltas, losses, sims = jax.vmap(
                lambda lr_c, m_c, batch_c: per_client(w_g, pseudo_global, lr_c, m_c, batch_c),
                in_axes=(0, 0, 0),
            )(state.lr, state.momentum, batch)
            q, F, G, new_lr, new_m, feedback = _mod2_vectors(
                hp, f_i, f_bar, sims, s_bar, state.lr)
            # staleness folds into the speed term (stale slot ⇒ smaller f)
            F = F * (1.0 + staleness)
            p = _mod3_weights(hp, feedback, F, G, C, N)
            if strategy == "avg":
                # FedQS-Avg: Σ p_c (w_g − δ_c) = (Σp)·w_g − Σ p_c δ_c —
                # algebraically expanded so no [C, |w|] copy materializes
                p_sum = jnp.sum(p)
                new_params = jax.tree_util.tree_map(
                    lambda wl, dl: (p_sum * wl.astype(jnp.float32)
                                    - jnp.einsum("c,c...->...", p, dl)).astype(wl.dtype),
                    w_g, deltas)
            else:
                agg = jax.tree_util.tree_map(
                    lambda dl: jnp.einsum("c,c...->...", p, dl), deltas)
                new_params = jax.tree_util.tree_map(
                    lambda wl, al: (wl.astype(jnp.float32) - hp.eta_g * al).astype(wl.dtype),
                    w_g, agg)
            mean_loss = jnp.mean(losses)
        else:  # fsdp: scan client groups, weights shared, O(g) delta memory
            agg0 = jax.tree_util.tree_map(
                lambda x: jnp.zeros(x.shape, jnp.float32), w_g)

            def grp(x):
                return x.reshape((C // g, g) + x.shape[1:])

            def body(carry, xs):
                agg, psum, loss_acc = carry
                lr_c, m_c, f_c, stale_c, batch_c = xs  # leading dim g
                delta, loss, sim = jax.vmap(
                    lambda l, m, b: per_client(w_g, pseudo_global, l, m, b),
                    in_axes=(0, 0, 0),
                )(lr_c, m_c, batch_c)
                qc = classify_quadrant(f_c, f_bar, sim, s_bar)
                Fc = speed_ratio(f_c, f_bar, hp.ratio_clip) * (1.0 + stale_c)
                Gc = similarity_ratio(sim, s_bar, hp.ratio_clip)
                fb = (qc == Quadrant.FSBC) & hp.use_feedback
                phi = jnp.asarray(C / N, jnp.float32)
                pw = jnp.where(fb, jnp.exp(phi - Fc) / jnp.exp2(phi - Fc)
                               * (1 + Gc) ** 2 / C, 1.0 / C)          # [g]
                agg = jax.tree_util.tree_map(
                    lambda a, d: a + jnp.einsum("g,g...->...", pw, d), agg, delta)
                new_lr_c = adapt_learning_rate(lr_c, qc, Fc, hp)
                mom_on = (qc != Quadrant.FSBC)
                new_m_c = jnp.where(mom_on & hp.use_momentum,
                                    momentum_rate(Gc, hp), 0.0)
                return (agg, psum + jnp.sum(pw), loss_acc + jnp.sum(loss)), \
                    (sim, new_lr_c, new_m_c)

            (agg, psum, loss_sum), (sims, new_lr, new_m) = jax.lax.scan(
                body, (agg0, jnp.float32(0.0), jnp.float32(0.0)),
                tuple(grp(x) for x in (state.lr, state.momentum, f_i, staleness))
                + (jax.tree_util.tree_map(grp, batch),))
            sims = sims.reshape(C)
            new_lr = new_lr.reshape(C)
            new_m = new_m.reshape(C)
            inv = 1.0 / jnp.maximum(psum, 1e-12)
            # sgd and avg coincide here: Σp(w_g−δ)/Σp = w_g − Σpδ/Σp
            eta = hp.eta_g if strategy == "sgd" else 1.0
            new_params = jax.tree_util.tree_map(
                lambda wl, al: (wl.astype(jnp.float32) - eta * al * inv).astype(wl.dtype),
                w_g, agg)
            mean_loss = loss_sum / C

        new_counts = state.counts.at[slot_cids].add(1)
        new_sims = state.sims.at[slot_cids].set(sims)
        new_state = RoundState(new_params, w_g, new_lr, new_m, new_counts, new_sims)
        metrics = {"loss": mean_loss, "mean_similarity": jnp.mean(sims),
                   "s_bar": s_bar, "f_bar": f_bar}
        return new_state, metrics

    return step


def make_serve_step(cfg):
    """Single-token sharded decode (decode_32k / long_500k shapes)."""

    def serve_step(params, cache, tokens, memory_embeds=None):
        return T.decode_step(cfg, params, cache, tokens, memory_embeds)

    return serve_step


def make_prefill_step(cfg, max_seq: Optional[int] = None):
    def prefill_step(params, tokens, memory_embeds=None):
        return T.prefill(cfg, params, tokens, memory_embeds, max_seq=max_seq)

    return prefill_step


# --------------------------------------------------------------------------
# input specs (dry-run stand-ins; no allocation)
# --------------------------------------------------------------------------
def input_specs(cfg, shape, *, n_clients: int = 16,
                total_clients: int = 100) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model/step input.

    ``shape`` is a ``repro.configs.InputShape``.  Returns a dict with keys
    matching the corresponding step function's signature.
    """
    sds = jax.ShapeDtypeStruct
    C = n_clients
    if shape.mode == "train":
        b = shape.global_batch // C
        batch = {
            "tokens": sds((C, b, shape.seq_len), jnp.int32),
            "targets": sds((C, b, shape.seq_len), jnp.int32),
        }
        if cfg.frontend != "none":
            batch["memory_embeds"] = sds(
                (C, b, cfg.n_frontend_tokens, cfg.d_model), jnp.float32)
        state = RoundState(
            params=T.abstract_params(cfg),
            prev_params=T.abstract_params(cfg),
            lr=sds((C,), jnp.float32),
            momentum=sds((C,), jnp.float32),
            counts=sds((total_clients,), jnp.int32),
            sims=sds((total_clients,), jnp.float32),
        )
        return {"state": state, "batch": batch,
                "slot_cids": sds((C,), jnp.int32),
                "staleness": sds((C,), jnp.float32)}
    if shape.mode == "prefill":
        out = {"params": T.abstract_params(cfg),
               "tokens": sds((shape.global_batch, shape.seq_len), jnp.int32)}
        if cfg.frontend != "none":
            out["memory_embeds"] = sds(
                (shape.global_batch, cfg.n_frontend_tokens, cfg.d_model), jnp.float32)
        return out
    if shape.mode == "decode":
        cache = jax.eval_shape(
            lambda: T.init_cache(cfg, shape.global_batch, shape.seq_len))
        out = {"params": T.abstract_params(cfg), "cache": cache,
               "tokens": sds((shape.global_batch,), jnp.int32)}
        if cfg.frontend != "none":
            out["memory_embeds"] = sds(
                (shape.global_batch, cfg.n_frontend_tokens, cfg.d_model), jnp.float32)
        return out
    raise ValueError(shape.mode)
