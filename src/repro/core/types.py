"""Core datatypes for the FedQS SAFL framework.

Everything here is deliberately jax-friendly: state that participates in
jitted computation is arrays / pytrees; host-side bookkeeping (the SAFL
event queue) lives in plain dataclasses.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp

Params = Any  # pytree of arrays


class Quadrant(enum.IntEnum):
    """Mod-2 client categories (Figure 3 of the paper).

    Quadrants are determined by (speed f_i vs mean f̄, similarity s_i vs
    mean s̄).  Encoded as ints so the classification can run branch-free
    inside jit.
    """

    FSBC = 0  # fast (f>f̄),       strongly biased (s<s̄)
    FWBC = 1  # fast (f>f̄),       weakly biased   (s≥s̄)
    SWBC = 2  # straggling (f≤f̄), weakly biased   (s≥s̄)
    SSBC = 3  # straggling (f≤f̄), strongly biased (s<s̄)


class AggregationStrategy(enum.Enum):
    GRADIENT = "sgd"  # FedQS-SGD  (gradient / model-difference aggregation)
    MODEL = "avg"     # FedQS-Avg  (parameter averaging)


class SSBCSituation(enum.IntEnum):
    """SSBC sub-diagnosis from the local validation set (paper §3.3)."""

    STRAGGLER = 1   # per-label val accuracy roughly uniform -> momentum path
    DISPERSED = 2   # per-label val accuracy highly uneven  -> feedback path


@dataclass
class FedQSHyperParams:
    """Default hyper-parameters from paper Appendix D.3."""

    eta0: float = 0.1          # initial local learning rate η0
    lr_min: float = 0.001      # α — lower lr bound
    lr_max: float = 0.2        # β — upper lr bound
    a: float = 0.002           # learning-rate change rate
    m0: float = 0.1            # initial momentum
    k: float = 0.2             # momentum change speed
    momentum_max: float = 0.9  # θ — momentum clipping threshold
    grad_clip: float = 20.0    # G_c — gradient clipping threshold
    local_epochs: int = 2      # E
    buffer_k: int = 10         # K — updates needed to trigger aggregation
    eta_g: float = 1.0         # global lr for gradient aggregation
    similarity: str = "cosine"  # Mod-1 similarity function
    # Situation-2 detector: coefficient-of-variation threshold on per-label
    # validation accuracy above which SSBC is declared "dispersed".
    ssbc_cv_threshold: float = 0.5
    use_momentum: bool = True   # Mod-2 ablation switch
    use_feedback: bool = True   # Mod-3 ablation switch
    ratio_clip: float = 1e3     # clamp on F=f̄/f_i and G=s̄/s_i


@dataclass
class ClientState:
    """Host-side per-client state (Mod-2 lives here in the simulator)."""

    cid: int
    n_samples: int
    speed: float                      # wall-seconds of virtual time per local round
    lr: float = 0.1
    momentum: float = 0.1
    quadrant: int = int(Quadrant.SWBC)
    feedback: bool = False            # 1-bit uplink flag (FSBC / SSBC-Sit2)
    last_similarity: float = 0.0
    stale_round: int = 0              # τ_i — global round of the model it trained on
    params: Params = None             # local model (model aggregation uploads this)


@dataclass
class ServerTable:
    """Mod-3 aggregation status table — two dense arrays (paper Eq. 1/2).

    ``counts[i]`` = n(i), number of times client i participated;
    ``sims[i]``   = s_g(i), the latest similarity client i shared.
    """

    counts: jnp.ndarray  # i32[N]
    sims: jnp.ndarray    # f32[N]

    @staticmethod
    def init(n_clients: int) -> "ServerTable":
        return ServerTable(
            counts=jnp.zeros((n_clients,), jnp.int32),
            sims=jnp.zeros((n_clients,), jnp.float32),
        )


@dataclass
class Update:
    """One buffered client upload sitting in the server's K-buffer."""

    cid: int
    n_samples: int
    stale_round: int                  # τ_i
    lr: float
    similarity: float
    feedback: bool
    speed_f: float                    # f_i at upload time
    delta: Params = None              # Σ_e ΔF (momentum-augmented pseudo-gradient)
    params: Params = None             # w_i (model aggregation payload)
    # device-state extensions (docs/ROBUSTNESS.md).  completed_fraction is
    # the share of local work actually finished before upload (1.0 = the
    # classic complete update; admission rejects <= 0); sent_at is the
    # client-side upload timestamp on the service's virtual clock (-1 =
    # unknown), letting adaptive triggers observe true delivery latency.
    completed_fraction: float = 1.0
    sent_at: float = -1.0


@dataclass
class RoundMetrics:
    round: int
    virtual_time: float
    loss: float
    accuracy: float
    n_stale: int
    mean_staleness: float
    quadrant_counts: Dict[str, int] = field(default_factory=dict)


def tree_flat_vector(tree: Params) -> jnp.ndarray:
    """Concatenate a pytree into one flat f32 vector (Mod-1 similarity space)."""
    leaves = jax.tree_util.tree_leaves(tree)
    if not leaves:
        return jnp.zeros((0,), jnp.float32)
    return jnp.concatenate([jnp.ravel(l).astype(jnp.float32) for l in leaves])


def tree_zeros_like(tree: Params) -> Params:
    return jax.tree_util.tree_map(jnp.zeros_like, tree)


def tree_add(a: Params, b: Params) -> Params:
    return jax.tree_util.tree_map(jnp.add, a, b)


def tree_sub(a: Params, b: Params) -> Params:
    return jax.tree_util.tree_map(jnp.subtract, a, b)


def tree_scale(tree: Params, c) -> Params:
    return jax.tree_util.tree_map(lambda x: x * c, tree)


def tree_weighted_sum(trees: List[Params], weights) -> Params:
    """Σ_i w_i · tree_i — the Mod-3 aggregation primitive (host/list form).

    The mesh form lives in ``repro.core.distributed``; the Pallas kernel in
    ``repro.kernels.weighted_agg``.
    """
    w = jnp.asarray(weights)
    out = tree_scale(trees[0], w[0])
    for i, t in enumerate(trees[1:], start=1):
        out = tree_add(out, tree_scale(t, w[i]))
    return out


def tree_global_norm(tree: Params) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def tree_clip_by_global_norm(tree: Params, max_norm: float) -> Params:
    """Gradient clipping — justification of Assumption A.2 (G_c)."""
    norm = tree_global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-12))
    return tree_scale(tree, scale)
