"""Mod-3: global model aggregation (server side).

Implements paper §3.4:

* buffered trigger — the server aggregates once K updates are available;
* the aggregation status table update (Eq. 1/2);
* initial weights p_i = n_i/n, feedback re-weighting
  ``p_i = exp(φ−F)/2^(φ−F) · (1+G)²/K`` with φ = K/N, then normalization;
* FedQS-SGD:  w_g^t = w_g^{t−1} − η_g Σ p_i · δ_i   where δ_i = η_i Σ_e ΔF_{i,e}
  (δ is uploaded as the model difference w_start − w_end, cf. Remark B.1);
* FedQS-Avg:  w_g^t = Σ p_i · w_i^{τ_i}.
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .types import (
    AggregationStrategy,
    FedQSHyperParams,
    Params,
    ServerTable,
    Update,
    tree_scale,
    tree_sub,
    tree_weighted_sum,
)


@jax.jit
def _table_scatter(counts, sims, add_idx, set_idx, set_vals):
    # pad rows carry index == n_clients (out of bounds) and are dropped;
    # the add is commutative and the set indices are pre-deduped, so the
    # result is bit-identical to the eager unpadded scatters
    return (counts.at[add_idx].add(1, mode="drop"),
            sims.at[set_idx].set(set_vals, mode="drop"))


def update_table(table: ServerTable, cids: jnp.ndarray, sims: jnp.ndarray) -> ServerTable:
    """Eq. 1: n(i) += 1 and s_g(i) = s_i^t for the participating clients.

    ``cids`` may contain duplicates (SAFL allows repeat uploads within one
    buffer); each occurrence counts toward n(i), and the **last**
    occurrence's similarity wins — enforced by a host-side dedupe before
    the scatter, because XLA's duplicate-index ``set`` order is
    implementation-defined and the hierarchical plane's host-side table
    math (``repro.hier``) must match this function exactly on every
    backend.  The two scatters run as one jitted dispatch with the index
    axes padded to power-of-two buckets (pads point one past the table
    and are dropped) — profiling the serve round showed the eager form's
    ~6 scatter/gather dispatches cost several ms per fire on CPU.
    """
    cids_np = np.asarray(cids)
    sims_np = np.asarray(sims)
    n = int(table.counts.shape[0])
    # last occurrence of each cid: first occurrence in the reversed array
    _, rev_first = np.unique(cids_np[::-1], return_index=True)
    last = len(cids_np) - 1 - rev_first

    def pad_to(a, fill):
        b = max(4, 1 << max(len(a) - 1, 0).bit_length())
        return np.concatenate([a, np.full(b - len(a), fill, a.dtype)])

    counts, sims_new = _table_scatter(
        table.counts, table.sims,
        jnp.asarray(pad_to(cids_np.astype(np.int32), n)),
        jnp.asarray(pad_to(cids_np[last].astype(np.int32), n)),
        jnp.asarray(pad_to(sims_np[last].astype(np.float32), 0.0)))
    return ServerTable(counts=counts, sims=sims_new)


def staleness_weight(F: jnp.ndarray, phi, *, xp=jnp) -> jnp.ndarray:
    """exp(φ−F)/2^(φ−F) — the stale-update attenuation term (§3.4).

    Equals (e/2)^(φ−F): >1 when the client is *slower* than the buffer
    average would suggest is fine (φ>F), shrinking as F grows.

    ``xp`` selects the array backend (pass ``numpy`` for host-side
    callers like the hierarchical plane's metadata math) so the Eq. §3.4
    algebra lives in exactly one place.
    """
    x = phi - F
    return xp.exp(x) / xp.exp2(x)


def feedback_weight(F, G, K: int, N: int, *, xp=jnp) -> jnp.ndarray:
    """Full feedback weight: exp(φ−F)/2^(φ−F) · (1+G)²/K, φ = K/N."""
    phi = np.float32(K / N)
    return staleness_weight(F, phi, xp=xp) * (1.0 + G) ** 2 / K


def aggregation_weights(
    n_samples: jnp.ndarray,   # i32[K] — n_i of each buffered update
    feedback: jnp.ndarray,    # bool[K]
    F: jnp.ndarray,           # f32[K] — f̄/f_i
    G: jnp.ndarray,           # f32[K] — s̄/s_i
    K: int,
    N: int,
    completed: jnp.ndarray = None,  # f32[K] — completed_fraction ∈ (0,1]
) -> jnp.ndarray:
    """Normalized p over the buffer (vector form usable inside jit).

    ``completed`` scales each row's (pre-normalization) weight by the
    fraction of local work the client actually finished (partial-update
    admission, docs/ROBUSTNESS.md).  ``None`` skips the multiply — since
    ``x * 1.0`` is IEEE-exact, passing all-ones is bit-identical, but the
    ``None`` path keeps legacy callers on the original op sequence.
    """
    n = jnp.maximum(jnp.sum(n_samples), 1)
    p = n_samples.astype(jnp.float32) / n
    p = jnp.where(feedback, feedback_weight(F, G, K, N), p)
    if completed is not None:
        p = p * completed.astype(jnp.float32)
    return p / jnp.maximum(jnp.sum(p), 1e-12)


def aggregate_gradients(
    w_global: Params,
    deltas: Sequence[Params],
    weights: jnp.ndarray,
    eta_g: float = 1.0,
    *,
    tree_sum=tree_weighted_sum,
) -> Params:
    """FedQS-SGD server step.  δ_i is the uploaded model-difference.

    ``tree_sum`` is the Σ_i w_i·tree_i primitive; the default is the
    sequential host form, the streaming service passes the batched
    stacked form (``repro.serve.batched``) to hit the Pallas kernel.
    """
    step = tree_sum(list(deltas), weights)
    return jax.tree_util.tree_map(lambda w, s: w - eta_g * s, w_global, step)


def aggregate_models(
    models: Sequence[Params],
    weights: jnp.ndarray,
    *,
    tree_sum=tree_weighted_sum,
) -> Params:
    """FedQS-Avg server step: convex combination of buffered local models."""
    return tree_sum(list(models), weights)


def server_aggregate(
    strategy: AggregationStrategy,
    w_global: Params,
    buffer: List[Update],
    table: ServerTable,
    hp: FedQSHyperParams,
    n_clients: int,
    *,
    tree_sum=tree_weighted_sum,
) -> Tuple[Params, ServerTable, jnp.ndarray]:
    """Full Mod-3 pass over one K-buffer.

    Returns (new global model, updated table, weights used).
    """
    K = len(buffer)
    cids = jnp.asarray([u.cid for u in buffer], jnp.int32)
    sims = jnp.asarray([u.similarity for u in buffer], jnp.float32)
    table = update_table(table, cids, sims)

    # F/G are recomputed against the *current* table (the server "first
    # calculates the average speed f̄, average similarity s̄" §3.4).
    total = jnp.maximum(jnp.sum(table.counts), 1)
    f = table.counts.astype(jnp.float32) / total
    f_bar = jnp.mean(f)
    s_bar = jnp.mean(table.sims)
    f_i = f[cids]
    F = jnp.clip(f_bar / jnp.maximum(f_i, 1e-12), 1.0 / hp.ratio_clip, hp.ratio_clip)
    s_i = jnp.maximum(sims, 1e-6)
    G = jnp.clip(jnp.maximum(s_bar, 1e-6) / s_i, 1.0 / hp.ratio_clip, hp.ratio_clip)

    n_samples = jnp.asarray([u.n_samples for u in buffer], jnp.int32)
    fb = jnp.asarray([bool(u.feedback) and hp.use_feedback for u in buffer])
    cfs = [float(getattr(u, "completed_fraction", 1.0)) for u in buffer]
    completed = (jnp.asarray(cfs, jnp.float32)
                 if any(c != 1.0 for c in cfs) else None)
    p = aggregation_weights(n_samples, fb, F, G, K, n_clients,
                            completed=completed)

    if strategy is AggregationStrategy.GRADIENT:
        new_global = aggregate_gradients(
            w_global, [u.delta for u in buffer], p, hp.eta_g, tree_sum=tree_sum
        )
    else:
        new_global = aggregate_models([u.params for u in buffer], p, tree_sum=tree_sum)
    return new_global, table, p
