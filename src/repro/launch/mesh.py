"""Production mesh + sharding policy (DESIGN §6).

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state).  Single pod: (16, 16) = 256 chips, axes (data, model).
Multi-pod: (2, 16, 16) = 512 chips, axes (pod, data, model).

Axis semantics:
* data  — the SAFL K-buffer: one buffered client update per data shard
          (stacked mode) or the FSDP weight shard + microbatch shard
          (fsdp mode for ≥100B archs);
* model — tensor parallel (heads / ffn / vocab / expert-ffn);
* pod   — hierarchical SAFL cohorts; cross-pod aggregation rides this
          axis once per round (the DCI collective the multi-pod dry-run
          must prove out).
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(n_data: Optional[int] = None, n_model: int = 1) -> Mesh:
    """Small mesh over whatever devices exist (CPU tests / examples)."""
    n = len(jax.devices())
    n_data = n_data or max(1, n // n_model)
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.shape else 1


def _div(n: int, mesh: Mesh, axis) -> bool:
    if axis is None:
        return True
    size = 1
    for a in (axis if isinstance(axis, tuple) else (axis,)):
        size *= _axis_size(mesh, a)
    return n % size == 0 and n >= size


def _fsdp_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.shape else ("data",)


def param_spec(cfg, mesh: Mesh, path: str, shape, *, fsdp: bool) -> P:
    """Sharding rule for one parameter leaf.

    - embeddings / lm_head: vocab dim over 'model' (falls back to
      replication when the vocab doesn't divide);
    - expert tensors [.., E, d_in, d_out]: expert dim over the fsdp axes
      (expert parallelism), d_out over 'model';
    - generic matrices [.., d_in, d_out]: d_out over 'model'; d_in
      additionally over the fsdp axes in fsdp mode (2-axis FSDP+TP);
    - vectors / scan-stacked leading dims: replicated.
    """
    nd = len(shape)
    fa = _fsdp_axes(mesh)
    if "embed" in path and nd == 2:
        if getattr(cfg, "embed_dshard", False):
            # §Perf: shard the table on d_model — token gathers become
            # shard-local (no per-lookup all-gather of the whole table)
            return P(None, "model" if _div(shape[1], mesh, "model") else None)
        return P("model" if _div(shape[0], mesh, "model") else None, None)
    if "lm_head" in path and nd == 2:
        return P(None, "model" if _div(shape[1], mesh, "model") else None)
    row_par = getattr(cfg, "row_parallel_out", False) and (
        path.endswith("wo/w") or path.endswith("/wo"))
    if cfg.n_experts > 0 and nd >= 3 and cfg.n_experts in shape:
        e_dim = shape.index(cfg.n_experts)
        spec: list = [None] * nd
        if _div(cfg.n_experts, mesh, fa):
            spec[e_dim] = fa if len(fa) > 1 else fa[0]
        elif _div(cfg.n_experts, mesh, "data"):
            spec[e_dim] = "data"
        if row_par and nd - 2 != e_dim and _div(shape[-2], mesh, "model"):
            spec[-2] = "model"       # §Perf: row-parallel expert down-proj
        elif nd - 1 != e_dim and _div(shape[-1], mesh, "model"):
            spec[-1] = "model"
        return P(*spec)
    if nd >= 2 and shape[-1] >= 128:
        spec = [None] * nd
        if row_par and shape[-2] >= 128 and _div(shape[-2], mesh, "model"):
            # §Perf: Megatron pairing — out-projections shard the INPUT dim
            # so the preceding column-parallel activation is consumed
            # shard-local and only the [.., d_model] output is all-reduced
            spec[-2] = "model"
            return P(*spec)
        if _div(shape[-1], mesh, "model"):
            spec[-1] = "model"
        if fsdp and shape[-2] >= 128:
            if _div(shape[-2], mesh, fa):
                spec[-2] = fa if len(fa) > 1 else fa[0]
            elif _div(shape[-2], mesh, "data"):
                spec[-2] = "data"
        return P(*spec)
    return P()


def param_shardings(cfg, mesh: Mesh, abstract, *, fsdp: bool):
    """NamedSharding pytree for ``abstract_params(cfg)``."""

    def one(path_tuple, leaf):
        path = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path_tuple)
        return NamedSharding(mesh, param_spec(cfg, mesh, path, tuple(leaf.shape), fsdp=fsdp))

    return jax.tree_util.tree_map_with_path(one, abstract)


def stacked_param_shardings(cfg, mesh: Mesh, abstract_stacked):
    """Client-stacked params/deltas [C, ...]: leading C over the client
    axes, trailing dims per the (non-fsdp) param policy."""
    ca = _fsdp_axes(mesh)  # client axis = data (+pod)

    def one(path_tuple, leaf):
        path = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path_tuple)
        inner = param_spec(cfg, mesh, path, tuple(leaf.shape[1:]), fsdp=False)
        return NamedSharding(mesh, P(ca if len(ca) > 1 else ca[0], *inner))

    return jax.tree_util.tree_map_with_path(one, abstract_stacked)


def batch_spec(mesh: Mesh, stacked_clients: bool) -> P:
    """tokens [C, b, S] (stacked: C over client axes) or [C, b, S] with b
    over 'data' (fsdp scan mode)."""
    ca = _fsdp_axes(mesh)
    if stacked_clients:
        return P(ca if len(ca) > 1 else ca[0], None, None)
    return P(None, "data", None)


def cache_shardings(cfg, mesh: Mesh, abstract_cache):
    """Decode caches: batch dim over 'data' (+'pod'); attention cache
    sequence dim over 'model' (sequence-sharded KV — flash-decoding style
    partial-softmax reduction is inserted by GSPMD)."""
    ba = _fsdp_axes(mesh)
    b_ax = ba if len(ba) > 1 else ba[0]

    def one(path_tuple, leaf):
        path = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path_tuple)
        nd = leaf.ndim
        if "pos" in path or nd == 0:
            return NamedSharding(mesh, P())
        scanned = "blocks" in path
        batch_dim = 1 if scanned else 0
        spec = [None] * nd
        if leaf.shape[batch_dim] % (np.prod([mesh.shape[a] for a in ba])) == 0:
            spec[batch_dim] = b_ax
        elif leaf.shape[batch_dim] % mesh.shape["data"] == 0:
            spec[batch_dim] = "data"
        # ring-buffer seq dim of k/v/latent caches → 'model'
        if any(k in path for k in ("/k", "/v", "latent")) and nd >= batch_dim + 2:
            seq_dim = batch_dim + 1
            if leaf.shape[seq_dim] % mesh.shape["model"] == 0 and leaf.shape[seq_dim] >= mesh.shape["model"]:
                spec[seq_dim] = "model"
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(one, abstract_cache)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())
