"""Live training-health monitor: tail a telemetry JSONL log in a terminal.

The monitor is the read side of the training-health plane
(docs/OBSERVABILITY.md): point it at the JSONL file a running launcher
is writing (``--telemetry`` on ``launch/serve`` / ``launch/train``) and
it renders a compact dashboard — ingest rate, round progress, the
staleness histogram, per-tier throughput, detector status, and any
health alerts / flight dumps — either once (default) or continuously
with ``--follow``::

    PYTHONPATH=src python -m repro.launch.monitor --events run.jsonl
    PYTHONPATH=src python -m repro.launch.monitor --events run.jsonl --follow

``--prom`` additionally renders the run's final metrics registry
snapshot in Prometheus text exposition format (counters, gauges, and
cumulative ``le`` histogram buckets under a ``repro_`` prefix), so the
same numbers the Markdown report tabulates can be scraped by anything
that speaks the format.

Reading is tolerant by design: the file is being appended to while we
read it, so a torn final line is expected — it is skipped this pass and
picked up complete on the next one.  State accumulation is incremental
(each line is consumed once, however long the run), which keeps a
``--follow`` session O(new events) per refresh.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from typing import Dict, List, Optional


# ---------------------------------------------------------------------------
# Prometheus text exposition
# ---------------------------------------------------------------------------
def _prom_name(name: str) -> str:
    out = []
    for ch in name:
        out.append(ch if (ch.isalnum() or ch == "_") else "_")
    s = "".join(out)
    return "repro_" + s


def _prom_num(v: float) -> str:
    f = float(v)
    if f == float("inf"):
        return "+Inf"
    if f == float("-inf"):
        return "-Inf"
    if f == int(f) and abs(f) < 1e15:
        return str(int(f))
    return repr(f)


def prometheus_text(snapshot: Dict[str, dict]) -> str:
    """Render a ``MetricsRegistry.snapshot()`` dict as Prometheus text
    exposition (version 0.0.4): one ``# TYPE`` header per metric,
    cumulative upper-bound-inclusive ``le`` buckets + ``+Inf`` +
    ``_sum``/``_count`` for histograms — the same ``le`` semantics the
    registry's ``bisect_left`` bucketing already implements."""
    lines: List[str] = []
    for name in sorted(snapshot):
        m = snapshot[name]
        pname = _prom_name(name)
        mtype = m.get("type")
        if mtype == "counter":
            lines.append(f"# TYPE {pname} counter")
            lines.append(f"{pname} {_prom_num(m.get('value', 0))}")
        elif mtype == "gauge":
            lines.append(f"# TYPE {pname} gauge")
            lines.append(f"{pname} {_prom_num(m.get('value', 0.0))}")
        elif mtype == "histogram":
            lines.append(f"# TYPE {pname} histogram")
            bounds = m.get("bounds") or []
            counts = m.get("counts") or []
            cum = 0
            for b, c in zip(bounds, counts):
                cum += int(c)
                lines.append(f'{pname}_bucket{{le="{_prom_num(b)}"}} {cum}')
            total = int(m.get("count", sum(int(c) for c in counts)))
            lines.append(f'{pname}_bucket{{le="+Inf"}} {total}')
            lines.append(f"{pname}_sum {_prom_num(m.get('sum', 0.0))}")
            lines.append(f"{pname}_count {total}")
        else:  # unknown metric type: expose nothing rather than guess
            continue
    return "\n".join(lines) + ("\n" if lines else "")


# ---------------------------------------------------------------------------
# incremental monitor state
# ---------------------------------------------------------------------------
class MonitorState:
    """Everything the dashboard shows, folded incrementally from the
    event stream — feed each JSONL record exactly once via ``ingest``."""

    def __init__(self) -> None:
        self.events = 0
        self.skipped = 0
        self.admitted = 0
        self.rejected = 0
        self.rounds = 0
        self.last_round = -1
        self.t_first: Optional[float] = None
        self.t_last: Optional[float] = None
        self.staleness: Dict[int, int] = {}
        self.tier_fires: Dict[str, int] = {}
        self.loss: Optional[float] = None
        self.accuracy: Optional[float] = None
        self.alerts: List[dict] = []
        self.dumps: List[dict] = []
        self.snapshot: Optional[dict] = None
        self.agg_seconds = 0.0

    def ingest(self, rec: dict) -> None:
        self.events += 1
        e = rec.get("e")
        t = rec.get("t")
        if isinstance(t, (int, float)):
            if self.t_first is None:
                self.t_first = float(t)
            self.t_last = float(t)
        if e == "update-admitted":
            self.admitted += 1
            tau = int(rec.get("staleness", 0))
            self.staleness[tau] = self.staleness.get(tau, 0) + 1
        elif e == "update-rejected":
            self.rejected += 1
        elif e == "round-fired":
            self.rounds += 1
            self.last_round = max(self.last_round, int(rec.get("round", -1)))
            self.agg_seconds += float(rec.get("agg_seconds", 0.0))
        elif e == "tier-merged":
            tier = str(rec.get("tier", "?"))
            self.tier_fires[tier] = self.tier_fires.get(tier, 0) + 1
        elif e == "round-metrics":
            self.loss = float(rec.get("loss", float("nan")))
            self.accuracy = float(rec.get("accuracy", float("nan")))
            self.last_round = max(self.last_round, int(rec.get("round", -1)))
        elif e == "health-alert":
            self.alerts.append(rec)
        elif e == "flight-dump":
            self.dumps.append(rec)
        elif e == "metrics-snapshot":
            self.snapshot = rec.get("metrics") or {}

    # -- derived views ------------------------------------------------------
    @property
    def span(self) -> float:
        if self.t_first is None or self.t_last is None:
            return 0.0
        return max(self.t_last - self.t_first, 0.0)

    def health_line(self) -> str:
        if not self.alerts:
            return "OK — no alerts"
        warn = sum(1 for a in self.alerts if a.get("severity") == "warn")
        crit = len(self.alerts) - warn
        last = self.alerts[-1]
        sev = "CRITICAL" if crit else "WARN"
        return (f"{sev} — {len(self.alerts)} alerts ({crit} critical, "
                f"{warn} warn); last: {last.get('detector')} "
                f"z={float(last.get('zscore', 0.0)):.1f} "
                f"@ round {last.get('round')}")


def _bar(n: int, peak: int, width: int = 30) -> str:
    if peak <= 0:
        return ""
    return "#" * max(1 if n else 0, round(n / peak * width))


def render(state: MonitorState, *, path: str = "") -> str:
    """One dashboard frame as plain text."""
    s = state
    rate = s.admitted / s.span if s.span > 0 else 0.0
    lines = [
        f"== repro monitor{' — ' + path if path else ''} ==",
        f"events {s.events}  (torn/skipped this pass: {s.skipped})",
        f"ingest: {s.admitted} admitted, {s.rejected} rejected  "
        f"[{rate:.1f} updates/s stream-clock]",
        f"rounds: {s.rounds} fired (last round {s.last_round}, "
        f"{s.agg_seconds / max(s.rounds, 1) * 1e3:.2f} ms/round aggregation)",
    ]
    if s.loss is not None:
        lines.append(f"metrics: loss={s.loss:.4f} accuracy={s.accuracy:.4f}")
    if s.tier_fires:
        tiers = "  ".join(f"{k}:{v} fires"
                          for k, v in sorted(s.tier_fires.items()))
        lines.append(f"tiers: {tiers}")
    if s.staleness:
        lines.append("staleness (rounds @ admission):")
        peak = max(s.staleness.values())
        for tau in sorted(s.staleness):
            n = s.staleness[tau]
            lines.append(f"  tau={tau:>3} {n:>6}  {_bar(n, peak)}")
    lines.append(f"health: {s.health_line()}")
    for d in s.dumps[-3:]:
        lines.append(f"  flight dump -> {d.get('path')} "
                     f"({d.get('n_records')} records, {d.get('reason')})")
    return "\n".join(lines)


# ---------------------------------------------------------------------------
# tailing
# ---------------------------------------------------------------------------
def _drain(fh, state: MonitorState) -> int:
    """Consume complete lines from the current position; a torn final
    line (the writer is mid-append) is rewound and retried next pass."""
    n = 0
    state.skipped = 0
    while True:
        pos = fh.tell()
        line = fh.readline()
        if not line:
            break
        if not line.endswith("\n"):
            fh.seek(pos)  # torn tail: retry once the writer finishes it
            break
        line = line.strip()
        if not line:
            continue
        try:
            rec = json.loads(line)
        except json.JSONDecodeError:
            state.skipped += 1
            continue
        state.ingest(rec)
        n += 1
    return n


def monitor(path: str, *, follow: bool = False, interval: float = 1.0,
            out=None, max_frames: Optional[int] = None) -> MonitorState:
    """Tail ``path`` and render dashboard frames to ``out`` (stdout).

    ``max_frames`` bounds the number of --follow refreshes (tests)."""
    out = out or sys.stdout
    state = MonitorState()
    frames = 0
    with open(path, "r", encoding="utf-8") as fh:
        while True:
            _drain(fh, state)
            frame = render(state, path=path)
            if follow and out.isatty():
                out.write("\x1b[2J\x1b[H")  # clear + home between frames
            out.write(frame + "\n")
            out.flush()
            frames += 1
            if not follow or (max_frames is not None and frames >= max_frames):
                return state
            time.sleep(interval)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        description="Terminal dashboard over a telemetry JSONL log "
                    "(docs/OBSERVABILITY.md).")
    ap.add_argument("--events", required=True,
                    help="JSONL event log a launcher is writing "
                         "(--telemetry on launch/serve, launch/train)")
    ap.add_argument("--follow", action="store_true",
                    help="keep tailing and refresh the dashboard "
                         "(default: render one frame and exit)")
    ap.add_argument("--interval", type=float, default=1.0,
                    help="refresh period in seconds with --follow")
    ap.add_argument("--prom", default=None, metavar="PATH",
                    help="write the final metrics-snapshot as Prometheus "
                         "text exposition ('-' = stdout)")
    args = ap.parse_args(argv)

    state = monitor(args.events, follow=args.follow, interval=args.interval)
    if args.prom:
        if state.snapshot is None:
            raise SystemExit("--prom: no metrics-snapshot event in the log "
                             "yet (it is appended by Telemetry.close())")
        text = prometheus_text(state.snapshot)
        if args.prom == "-":
            sys.stdout.write(text)
        else:
            with open(args.prom, "w", encoding="utf-8") as fh:
                fh.write(text)
            print(f"prometheus exposition ({len(text.splitlines())} lines) "
                  f"-> {args.prom}")


if __name__ == "__main__":
    main()
