"""Run analysis: telemetry experiment reports + compiled-artifact parsing.

Two analysis surfaces live here:

* **Experiment reports** — turn a recorded telemetry run
  (docs/OBSERVABILITY.md) into a Markdown experiment report: accuracy/
  loss curves as tables, the member-level staleness histogram, a
  participation-fairness summary, per-tier throughput, codec byte
  accounting, and the final metrics snapshot.  The rendering lives in
  ``repro.telemetry.report``; this module is its CLI::

      PYTHONPATH=src python -m repro.launch.analysis --events run.jsonl --out report.md

  With ``--postmortem`` the input is read as a flight-recorder dump
  (``repro.telemetry.flightrec``): malformed trailing lines are
  tolerated, the dump's own metadata (reason, round, ring occupancy)
  heads the report, and the recorded window is rendered below it.

* **Compiled-artifact analysis** — cost, memory, and collective-byte
  parsing for the roofline report (system prompt §ROOFLINE).

Two accounting paths for the compiled artifacts:

* ``cost_summary`` — XLA's HloCostAnalysis numbers, recorded for
  reference.  CAVEAT (measured, see EXPERIMENTS §Dry-run): XLA counts
  while-loop *bodies once*, so for scan-based stacks (all ten archs) it
  undercounts by the layer-scan trip count.

* ``analyze_hlo`` — our structural analyzer: parses the optimized HLO,
  recovers each while loop's trip count from its condition computation,
  propagates multipliers through the computation call graph
  (while bodies ×trip, fusions ×1), and sums

    - dot FLOPs: 2 · |result| · |contracting dims| per dot × multiplier
      (matmuls dominate every arch here; elementwise flops are ignored),
    - collective bytes per kind × multiplier,
    - a dot-traffic HBM estimate (operand+result bytes of dots +
      collectives + entry I/O) as a *lower bound* on memory traffic.
"""
from __future__ import annotations

import math
import re
from typing import Any, Dict, List, Optional, Tuple

# TPU v5e hardware constants (system prompt)
PEAK_FLOPS = 197e12      # bf16 FLOP/s per chip
HBM_BW = 819e9           # bytes/s per chip
ICI_BW = 50e9            # bytes/s per link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.:  %all-gather.1 = bf16[16,448,8192]{...} all-gather(...)
_OP_RE = re.compile(
    r"=\s*(?:\()?\s*([a-z0-9]+)\[([0-9,]*)\][^=]*?\s("
    + "|".join(_COLLECTIVES) + r")(?:-start|-done)?\(")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


# ---------------------------------------------------------------------------
# structural HLO analyzer (trip-count-aware)
# ---------------------------------------------------------------------------
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->.*\{\s*$")
_COND_ATTR = re.compile(r"condition=%?([\w\.\-]+)")
_BODY_ATTR = re.compile(r"body=%?([\w\.\-]+)")
_CALL_RE = re.compile(r"(?:calls|to_apply|branch_computations)=\{?%?([\w\.\-]+(?:,\s*%?[\w\.\-]+)*)\}?")
_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")
_INSTR_RE = re.compile(r"%([\w\.\-]+)\s*=\s*([a-z0-9]+)\[([0-9,]*)\]")
_HDR_PARAM_RE = re.compile(r"([\w\.\-]+):\s*([a-z0-9]+)\[([0-9,]*)\]")
_DOT_LINE = re.compile(r"=\s*([a-z0-9]+)\[([0-9,]*)\][^\n]*?\bdot\(([^)]*)\)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")


def _dims(s: str) -> List[int]:
    return [int(d) for d in s.split(",") if d]


def _split_computations(hlo: str) -> Tuple[Dict[str, str], Optional[str]]:
    comps: Dict[str, List[str]] = {}
    entry = None
    cur = None
    for line in hlo.splitlines():
        m = _COMP_HDR.match(line.strip())
        if m and ("{" in line):
            cur = m.group(1)
            comps[cur] = [line]  # keep header: it declares parameter shapes
            if line.strip().startswith("ENTRY"):
                entry = cur
        elif cur is not None:
            if line.strip() == "}":
                cur = None
            else:
                comps[cur].append(line)
    return {k: "\n".join(v) for k, v in comps.items()}, entry


def _trip_count(cond_text: str) -> int:
    consts = [int(c) for c in _CONST_RE.findall(cond_text)]
    return max(consts) if consts else 1


def analyze_hlo(hlo: str) -> Dict[str, Any]:
    comps, entry = _split_computations(hlo)
    if entry is None:  # fall back: treat whole text as one computation
        comps = {"__entry__": hlo}
        entry = "__entry__"

    # collect call-graph edges (caller → callee, ×factor), then solve the
    # multiplier system by fixed-point iteration (the call graph is a DAG,
    # so this converges in ≤ depth passes)
    edges: List[Tuple[str, str, float]] = []
    for name, text in comps.items():
        for line in text.splitlines():
            if " while(" in line:
                cm_ = _COND_ATTR.search(line)
                bm_ = _BODY_ATTR.search(line)
                if not (cm_ and bm_):
                    continue
                cond, body = cm_.group(1), bm_.group(1)
                trip = float(_trip_count(comps.get(cond, "")))
                edges.append((name, body, trip))
                edges.append((name, cond, trip))
            else:
                cm = _CALL_RE.search(line)
                if cm:
                    for target in re.split(r",\s*%?", cm.group(1)):
                        target = target.strip().lstrip("%")
                        if target and target in comps and target != name:
                            edges.append((name, target, 1.0))

    mult: Dict[str, float] = {entry: 1.0}
    for _ in range(64):
        new: Dict[str, float] = {}
        for caller, callee, f in edges:
            base = 1.0 if caller == entry else mult.get(caller, 0.0)
            new[callee] = new.get(callee, 0.0) + base * f
        new[entry] = 1.0
        if all(abs(new.get(k, 0.0) - mult.get(k, 0.0)) < 1e-9
               for k in set(new) | set(mult)):
            mult = new
            break
        mult = new

    total_flops = 0.0
    dot_bytes = 0.0
    per_kind: Dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    counts: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for name, text in comps.items():
        m_cur = mult.get(name, 1.0)
        if m_cur == 0.0:
            m_cur = 1.0
        # local symbol table: instruction/parameter name → (dtype, dims)
        sym: Dict[str, Tuple[str, List[int]]] = {}
        lines = text.splitlines()
        if lines:
            for pm in _HDR_PARAM_RE.finditer(lines[0]):
                sym[pm.group(1)] = (pm.group(2), _dims(pm.group(3)))
        for line in lines:
            im = _INSTR_RE.search(line)
            if im:
                sym[im.group(1)] = (im.group(2), _dims(im.group(3)))
        for line in lines:
            dm = _DOT_LINE.search(line)
            if dm:
                out_dt, out_dims = dm.group(1), _dims(dm.group(2))

                def operand_info(piece):
                    # operands print either as "%name" or "f32[dims]{...} %name"
                    sm = re.search(r"\b([a-z0-9]+)\[([0-9,]*)\]", piece)
                    if sm:
                        return sm.group(1), _dims(sm.group(2))
                    nm = re.search(r"%([\w\.\-]+)", piece)
                    return sym.get(nm.group(1)) if nm else None

                pieces = dm.group(3).split(",")
                # tuple-free dot( a , b ) — but inline-typed operands also
                # contain commas inside [dims]; re-join on shape boundaries
                ops_txt = dm.group(3)
                opm = re.findall(
                    r"(?:[a-z0-9]+\[[0-9,]*\](?:\{[0-9,]*\})?\s*)?%[\w\.\-]+",
                    ops_txt)
                infos = [operand_info(p) for p in opm[:2]]
                lhs = infos[0] if infos else None
                contract = 1
                cm = _CONTRACT_RE.search(line)
                if cm and lhs:
                    for idx in _dims(cm.group(1)):
                        if idx < len(lhs[1]):
                            contract *= lhs[1][idx]
                flops = 2.0 * math.prod(out_dims or [1]) * contract
                total_flops += flops * m_cur
                b = _shape_bytes(out_dt, dm.group(2))
                for info in infos:
                    if info:
                        dt, dd = info
                        b += _shape_bytes(dt, ",".join(map(str, dd)))
                dot_bytes += b * m_cur
            for kind in _COLLECTIVES:
                marker = f" {kind}("
                if marker in line and f"{kind}-done" not in line:
                    left = line.split(marker, 1)[0]
                    b = sum(_shape_bytes(dt, dd) for dt, dd in
                            re.findall(r"\b([a-z0-9]+)\[([0-9,]*)\]", left))
                    per_kind[kind] += b * m_cur
                    counts[kind] += 1
                    break
    # debug visibility: the while-trip table and the heaviest collectives
    trips = []
    for caller, callee, f in edges:
        if f != 1.0:
            trips.append({"body": callee, "trip": f,
                          "mult": mult.get(callee, 0.0)})
    top_coll = []
    for name, text in comps.items():
        m_cur = mult.get(name, 1.0) or 1.0
        for line in text.splitlines():
            for kind in _COLLECTIVES:
                if f" {kind}(" in line and f"{kind}-done" not in line:
                    left = line.split(f" {kind}(", 1)[0]
                    b = sum(_shape_bytes(dt, dd) for dt, dd in
                            re.findall(r"\b([a-z0-9]+)\[([0-9,]*)\]", left))
                    top_coll.append({"kind": kind, "bytes": b, "mult": m_cur,
                                     "total": b * m_cur, "comp": name,
                                     "shape": left.strip()[:80]})
                    break
    top_coll.sort(key=lambda x: -x["total"])
    return {
        "flops": total_flops,
        "dot_bytes": dot_bytes,
        "collectives": {"per_kind": per_kind, "counts": counts,
                        "total": sum(per_kind.values())},
        "n_computations": len(comps),
        "while_trips": sorted(trips, key=lambda t: -t["trip"])[:20],
        "top_collectives": top_coll[:12],
    }


def collective_bytes(hlo_text: str) -> Dict[str, Any]:
    """Sum result-shape bytes of every collective in the optimized HLO.
    (Result size ≈ data moved per participating device for AG/AR; a
    conservative uniform accounting across collective types.)"""
    per_kind: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    counts: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for m in _OP_RE.finditer(hlo_text):
        dtype, dims, kind = m.group(1), m.group(2), m.group(3)
        if kind.endswith("-done"):
            continue
        per_kind[kind] += _shape_bytes(dtype, dims)
        counts[kind] += 1
    total = sum(per_kind.values())
    return {"per_kind": per_kind, "counts": counts, "total": total}


def cost_summary(compiled) -> Dict[str, float]:
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
    except Exception:
        ca = {}
    ca = ca or {}
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes_accessed": float(ca.get("bytes accessed", ca.get("bytes_accessed", 0.0))),
        "transcendentals": float(ca.get("transcendentals", 0.0)),
    }


def memory_summary(compiled) -> Dict[str, Any]:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {"available": False}
    if ma is None:
        return {"available": False}
    out = {"available": True}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "alias_size_in_bytes"):
        try:
            out[k] = int(getattr(ma, k))
        except Exception:
            pass
    return out


def roofline_terms(flops: float, hlo_bytes: float, coll_bytes: float,
                   n_chips: int) -> Dict[str, float]:
    """The three roofline terms in seconds.

    cost_analysis and the HLO text both describe the PER-PARTITION program
    (the SPMD-partitioned module), so flops/bytes/collective-bytes are
    already per-chip quantities — equivalent to HLO_total/(chips·peak).
    ``n_chips`` is kept for the record but not divided again.
    """
    compute_s = flops / PEAK_FLOPS
    memory_s = hlo_bytes / HBM_BW
    collective_s = coll_bytes / ICI_BW
    dominant = max(
        ("compute", compute_s), ("memory", memory_s), ("collective", collective_s),
        key=lambda kv: kv[1],
    )[0]
    return {"compute_s": compute_s, "memory_s": memory_s,
            "collective_s": collective_s, "dominant": dominant}


# ---------------------------------------------------------------------------
# telemetry experiment reports (docs/OBSERVABILITY.md)
# ---------------------------------------------------------------------------
# Re-exported so callers can keep importing everything analysis-shaped
# from one module; the implementation lives in repro.telemetry.report.
from repro.telemetry.report import (  # noqa: E402
    experiment_report,
    load_events,
    postmortem_report,
    report_from_jsonl,
)


def export_trace(telemetry, path: str) -> Dict[str, Any]:
    """Write the recorded spans as Chrome trace-event JSON (Perfetto-loadable).

    Returns the critical-path stage summary so callers (launch CLIs, the
    CI trace smoke) can print/validate coverage without re-reading the file.
    """
    import json as _json

    from repro.telemetry.critical_path import stage_summary
    from repro.telemetry.trace import to_chrome_trace

    tracer = telemetry.tracer
    if tracer is None:
        raise ValueError("telemetry hub has no tracer (construct with trace=True)")
    spans = tracer.spans
    doc = to_chrome_trace(spans, dropped=tracer.dropped)
    with open(path, "w", encoding="utf-8") as fh:
        _json.dump(doc, fh)
    summary = stage_summary(spans)
    print(f"trace → {path} ({summary['spans']} spans, "
          f"{summary['rounds']} rounds, "
          f"coverage {summary['coverage'] * 100.0:.1f}%)")
    return summary


def main(argv=None) -> None:
    import argparse

    ap = argparse.ArgumentParser(
        description="Render a recorded telemetry run (JSONL event log) "
                    "as a Markdown experiment report.")
    ap.add_argument("--events", required=True,
                    help="JSONL event log recorded by a Telemetry hub "
                         "(e.g. --telemetry on launch/train, launch/serve)")
    ap.add_argument("--out", default=None,
                    help="write the report here (default: stdout)")
    ap.add_argument("--title", default=None)
    ap.add_argument("--postmortem", action="store_true",
                    help="treat --events as a flight-recorder dump "
                         "(possibly truncated mid-write) and render the "
                         "crash-context postmortem instead of the full "
                         "experiment report")
    args = ap.parse_args(argv)

    if args.postmortem:
        report = postmortem_report(args.events, title=args.title)
    else:
        report = report_from_jsonl(args.events, title=args.title)
    if args.out:
        with open(args.out, "w", encoding="utf-8") as fh:
            fh.write(report)
        print(f"report ({len(report.splitlines())} lines) -> {args.out}")
    else:
        print(report, end="")


if __name__ == "__main__":
    main()
