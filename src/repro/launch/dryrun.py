import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()
"""Multi-pod dry-run (deliverable e).

For every (architecture × input shape × mesh): build the FedQS round step
(train shapes) or the prefill/serve step (inference shapes), ``.lower()``
against ShapeDtypeStruct inputs with production shardings, ``.compile()``,
and record memory_analysis / cost_analysis / collective bytes into
``experiments/dryrun/*.json`` for the §Roofline report.

The XLA_FLAGS line above MUST run before any jax import — jax locks the
device count at first init.  This module is the only place the 512
placeholder devices exist; tests and benches see the real device count.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-1b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod-only]
"""
import argparse
import json
import time
import traceback
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, INPUT_SHAPES, get_config, skip_reason, supports_shape
from repro.core.distributed import (
    RoundState,
    input_specs,
    make_fedqs_round_step,
    make_prefill_step,
    make_serve_step,
)
from repro.core.types import FedQSHyperParams
from repro.launch import analysis
from repro.launch.mesh import (
    batch_spec,
    cache_shardings,
    make_production_mesh,
    param_shardings,
    replicated,
)

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "experiments", "dryrun")


def _state_shardings(cfg, mesh, abstract_state: RoundState) -> RoundState:
    fsdp = cfg.fl_mode == "fsdp"
    return RoundState(
        params=param_shardings(cfg, mesh, abstract_state.params, fsdp=fsdp),
        prev_params=param_shardings(cfg, mesh, abstract_state.prev_params, fsdp=fsdp),
        lr=replicated(mesh),
        momentum=replicated(mesh),
        counts=replicated(mesh),
        sims=replicated(mesh),
    )


def _batch_shardings(cfg, mesh, batch):
    spec = batch_spec(mesh, stacked_clients=(cfg.fl_mode == "stacked"))
    out = {}
    for k, v in batch.items():
        s = P(*spec, *([None] * (v.ndim - len(spec))))
        out[k] = NamedSharding(mesh, s)
    return out


def lower_pair(arch_id: str, shape_name: str, *, multi_pod: bool,
               n_clients: int = 16, override_cfg=None, donate: bool = True,
               variant: str = "", client_group_size: int = 1):
    """Lower + compile one (arch × shape × mesh).  Returns result dict.

    ``variant`` is a comma list of §Perf levers: remat, absorbed,
    cross_cache (applied as config replacements)."""
    import dataclasses as _dc
    cfg = override_cfg or get_config(arch_id)
    vset = set(v for v in variant.split(",") if v)
    if "remat" in vset:
        cfg = _dc.replace(cfg, remat=True)
    if "absorbed" in vset:
        cfg = _dc.replace(cfg, mla_absorbed=True)
    if "cross_cache" in vset:
        cfg = _dc.replace(cfg, cache_cross_kv=True)
    if "embshard" in vset:
        cfg = _dc.replace(cfg, embed_dshard=True)
    if "rowpar" in vset:
        cfg = _dc.replace(cfg, row_parallel_out=True)
    if "moeshard" in vset:
        cfg = _dc.replace(cfg, moe_data_dispatch=True)
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = 512 if multi_pod else 256
    hp = FedQSHyperParams()
    # hierarchical SAFL: each pod contributes a 16-client cohort, so the
    # stacked client axis doubles on the multi-pod mesh (DESIGN §6)
    if multi_pod and cfg.fl_mode == "stacked" and shape.mode == "train":
        n_clients = n_clients * 2
    specs = input_specs(cfg, shape, n_clients=n_clients)

    t0 = time.perf_counter()
    if shape.mode == "train":
        pspecs = None
        if "pinspec" in vset:  # §Perf: pin grad/velocity/delta shardings
            pspecs = param_shardings(cfg, mesh, specs["state"].params,
                                     fsdp=(cfg.fl_mode == "fsdp"))
        step = make_fedqs_round_step(cfg, hp, strategy="sgd", n_clients=n_clients,
                                     client_group_size=client_group_size,
                                     param_pspecs=pspecs)
        in_sh = (
            _state_shardings(cfg, mesh, specs["state"]),
            _batch_shardings(cfg, mesh, specs["batch"]),
            replicated(mesh),
            replicated(mesh),
        )
        jitted = jax.jit(step, in_shardings=in_sh,
                         donate_argnums=(0,) if donate else ())
        with mesh:
            lowered = jitted.lower(specs["state"], specs["batch"],
                                   specs["slot_cids"], specs["staleness"])
    elif shape.mode == "prefill":
        step = make_prefill_step(cfg, max_seq=shape.seq_len)
        args = [specs["params"], specs["tokens"]]
        in_sh = [param_shardings(cfg, mesh, specs["params"], fsdp=False),
                 NamedSharding(mesh, P("data", None))]
        if "memory_embeds" in specs:
            args.append(specs["memory_embeds"])
            in_sh.append(NamedSharding(mesh, P("data", None, None)))
        jitted = jax.jit(step, in_shardings=tuple(in_sh))
        with mesh:
            lowered = jitted.lower(*args)
    else:  # decode
        step = make_serve_step(cfg)
        tok_spec = P("data") if shape.global_batch % mesh.shape["data"] == 0 else P()
        args = [specs["params"], specs["cache"], specs["tokens"]]
        in_sh = [param_shardings(cfg, mesh, specs["params"], fsdp=False),
                 cache_shardings(cfg, mesh, specs["cache"]),
                 NamedSharding(mesh, tok_spec)]
        if "memory_embeds" in specs:
            args.append(specs["memory_embeds"])
            mem_spec = (P("data", None, None) if shape.global_batch % mesh.shape["data"] == 0
                        else P(None, None, None))
            in_sh.append(NamedSharding(mesh, mem_spec))
        jitted = jax.jit(step, in_shardings=tuple(in_sh),
                         donate_argnums=(1,) if donate else ())
        with mesh:
            lowered = jitted.lower(*args)
    t_lower = time.perf_counter() - t0
    if globals().get("_LOWER_ONLY"):
        return ({"arch": arch_id, "shape": shape_name, "status": "lowered",
                 "mesh": "2x16x16" if multi_pod else "16x16",
                 "lower_s": round(t_lower, 2)}, None, lowered)

    t0 = time.perf_counter()
    compiled = lowered.compile()
    t_compile = time.perf_counter() - t0

    hlo = compiled.as_text()
    # trip-count-aware structural analysis (XLA's HloCostAnalysis counts
    # while bodies ONCE — measured; see analysis.py docstring)
    struct = analysis.analyze_hlo(hlo)
    cost = analysis.cost_summary(compiled)       # raw XLA numbers, reference
    mem = analysis.memory_summary(compiled)
    io_bytes = mem.get("argument_size_in_bytes", 0) + mem.get("output_size_in_bytes", 0)
    hbm_bytes = struct["dot_bytes"] + struct["collectives"]["total"] + io_bytes
    terms = analysis.roofline_terms(struct["flops"], hbm_bytes,
                                    struct["collectives"]["total"], n_chips)
    n_params = cfg.param_count()
    n_active = cfg.active_param_count()
    tokens = shape.global_batch * (shape.seq_len if shape.mode != "decode" else 1)
    model_flops = (6 * n_active * tokens if shape.mode == "train"
                   else 2 * n_active * tokens)
    flops_per_chip = struct["flops"]
    result = {
        "arch": arch_id, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16", "chips": n_chips,
        "variant": variant, "client_group_size": client_group_size,
        "mode": shape.mode, "fl_mode": cfg.fl_mode,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "cost": cost, "memory": mem,
        "hlo_struct": {"flops": struct["flops"], "dot_bytes": struct["dot_bytes"],
                       "hbm_bytes_est": hbm_bytes,
                       "n_computations": struct["n_computations"],
                       "while_trips": struct.get("while_trips", []),
                       "top_collectives": struct.get("top_collectives", [])},
        "collectives": struct["collectives"], "roofline": terms,
        "n_params": n_params, "n_active_params": n_active,
        "model_flops": model_flops,
        # MODEL_FLOPS is global; analyzer flops are per-chip
        "useful_flops_ratio": ((model_flops / n_chips) / flops_per_chip)
        if flops_per_chip else None,
        "status": "ok",
    }
    return result, compiled, lowered


def run_one(arch_id, shape_name, multi_pod, out_dir=OUT_DIR, tag="",
            skip_existing=False, variant="", client_group_size=1):
    os.makedirs(out_dir, exist_ok=True)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    fname = os.path.join(out_dir, f"{arch_id}__{shape_name}__{mesh_name}{tag}.json")
    if skip_existing and os.path.exists(fname):
        with open(fname) as f:
            rec = json.load(f)
        if rec.get("status") in ("ok", "skipped"):
            print(f"[cached] {arch_id} × {shape_name} × {mesh_name}")
            return rec
    if not supports_shape(arch_id, shape_name):
        rec = {"arch": arch_id, "shape": shape_name, "mesh": mesh_name,
               "status": "skipped", "reason": skip_reason(arch_id, shape_name)}
        with open(fname, "w") as f:
            json.dump(rec, f, indent=1)
        print(f"[skip] {arch_id} × {shape_name}: {rec['reason']}")
        return rec
    try:
        result, compiled, _ = lower_pair(
            arch_id, shape_name, multi_pod=multi_pod, variant=variant,
            client_group_size=client_group_size)
        if result.get("status") == "lowered":
            print(f"[lowered] {arch_id} × {shape_name} × {mesh_name} "
                  f"({result['lower_s']}s)")
            with open(fname + ".lowered", "w") as f:
                json.dump(result, f)
            return result
        print(f"[ok]   {arch_id} × {shape_name} × {mesh_name}: "
              f"compile={result['compile_s']}s flops={result['cost']['flops']:.3e} "
              f"coll={result['collectives']['total']:.3e}B "
              f"dominant={result['roofline']['dominant']}")
        print("       memory_analysis:", result["memory"])
        print("       cost_analysis: flops=%.4g bytes=%.4g" %
              (result["cost"]["flops"], result["cost"]["bytes_accessed"]))
    except Exception as e:
        result = {"arch": arch_id, "shape": shape_name, "mesh": mesh_name,
                  "status": "error", "error": f"{type(e).__name__}: {e}",
                  "traceback": traceback.format_exc()[-2000:]}
        print(f"[FAIL] {arch_id} × {shape_name} × {mesh_name}: {result['error']}")
    with open(fname, "w") as f:
        json.dump(result, f, indent=1)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=list(ARCH_IDS) + [None])
    ap.add_argument("--shape", default=None, choices=list(INPUT_SHAPES) + [None])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true",
                    help="use the 2×16×16 512-chip mesh")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--lower-only", action="store_true",
                    help="stop after .lower() (fast sharding sanity check)")
    ap.add_argument("--variant", default="",
                    help="comma list of §Perf levers: remat,absorbed,cross_cache")
    ap.add_argument("--group-size", type=int, default=1,
                    help="fsdp client_group_size (§Perf)")
    ap.add_argument("--tag", default="", help="suffix for output filenames")
    ap.add_argument("--out", default=OUT_DIR)
    args = ap.parse_args()
    global _LOWER_ONLY
    _LOWER_ONLY = args.lower_only

    archs = ARCH_IDS if (args.all or args.arch is None) else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                rec = run_one(arch, shape, mp, out_dir=args.out, tag=args.tag,
                              skip_existing=args.skip_existing,
                              variant=args.variant,
                              client_group_size=args.group_size)
                n_fail += rec.get("status") == "error"
    print(f"\ndry-run complete; {n_fail} failures")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
