"""FedQS training launcher.

Two entry modes:

* ``--simulate`` (default): the full-fidelity SAFL event simulation
  (repro.core.safl) on one of the paper's task families — this is what
  reproduces the paper's experiments.

* ``--distributed``: the mesh tensor-program path — runs the jitted
  FedQS round step (repro.core.distributed) for a reduced architecture on
  the host devices.  The production 256/512-chip lowering of the same step
  is exercised by ``repro.launch.dryrun``.

``--scenario`` runs the simulation inside any catalog scenario
(docs/SCENARIOS.md): population model + arrival process + dynamic
events.  ``--cohort`` switches to the vectorized cohort fast path
(``repro.scenarios.CohortEngine``) for 10k+ client populations.
``--compress <spec>`` runs the uplink through the compressed transport
(docs/COMPRESSION.md): client updates cross the submit boundary as
int8/top-k payloads and the service aggregates them through the fused
``dequant_agg`` kernel path.  ``--topology <spec>`` replaces the flat
server with the hierarchical aggregation plane (docs/HIERARCHY.md):
clients report to population-derived edge aggregators and only partial
aggregates flow toward the global tier.

Examples:
    PYTHONPATH=src python -m repro.launch.train --task rwd --algo fedqs-sgd --rounds 100
    PYTHONPATH=src python -m repro.launch.train --task rwd --scenario churn --rounds 60
    PYTHONPATH=src python -m repro.launch.train --scenario diurnal-churn --cohort \
        --clients 10000 --buffer-k 128 --rounds 30
    PYTHONPATH=src python -m repro.launch.train --distributed --arch gemma3-1b --rounds 20
"""
from __future__ import annotations

import argparse
import contextlib
import json
import os
import time


def _make_telemetry(args):
    trace = bool(getattr(args, "trace", None))
    health = bool(getattr(args, "health", False))
    flightrec = getattr(args, "flightrec", None)
    if (not getattr(args, "report", None) and not args.telemetry
            and not trace and not health and not flightrec):
        return None
    if args.report and not args.telemetry:
        raise SystemExit("--report needs --telemetry (the recorded JSONL "
                         "log is what the report renders)")
    from repro.telemetry import Telemetry

    if args.telemetry:
        return Telemetry.to_jsonl(args.telemetry, trace=trace,
                                  health=health, flightrec=flightrec)
    # --trace/--health without --telemetry: events stay in memory
    return Telemetry.in_memory(trace=trace, health=health,
                               flightrec=flightrec)


def _trace_scope(args, telemetry):
    """``profile.activate`` when tracing, else a no-op context — wraps
    the run so kernel dispatches land in the trace."""
    if telemetry is None or telemetry.tracer is None:
        return contextlib.nullcontext()
    from repro.telemetry import profile

    return profile.activate(telemetry)


def _finish_telemetry(args, telemetry):
    if telemetry is None:
        return
    if telemetry.health is not None:
        hm = telemetry.health
        crit = sum(1 for a in hm.alerts if a.severity == "critical")
        print(f"health: {len(hm.alerts)} alerts ({crit} critical) "
              f"across {len(hm.detectors)} detectors")
    trace_path = getattr(args, "trace", None)
    if trace_path and telemetry.tracer is not None:
        from repro.launch.analysis import export_trace

        export_trace(telemetry, trace_path)
    telemetry.close()
    if args.telemetry:
        print(f"telemetry → {args.telemetry}")
    if args.report:
        from repro.launch.analysis import report_from_jsonl

        with open(args.report, "w", encoding="utf-8") as fh:
            fh.write(report_from_jsonl(args.telemetry))
        print(f"experiment report → {args.report}")


def run_cohort(args, hp, scenario):
    from repro.core import make_algorithm
    from repro.scenarios import CohortEngine

    telemetry = _make_telemetry(args)
    eng = CohortEngine(scenario, args.clients, hp=hp,
                       algo=make_algorithm(args.algo, hp), seed=args.seed,
                       eval_every=args.eval_every,
                       resource_ratio=args.resource_ratio,
                       compress=args.compress, topology=args.topology,
                       telemetry=telemetry)
    print(f"cohort fast path: scenario={scenario.describe()} algo={args.algo} "
          f"N={args.clients} K={eng.cohort_k} task=virtual "
          + (f"topology={eng.service.describe()} " if args.topology else "")
          + (f"compress={eng.compressor.describe()} " if eng.compressor else "")
          + "(--task/--alpha/--sigma/--n-total apply to the event engine only)")
    with _trace_scope(args, telemetry):
        res = eng.run(args.rounds)
    for m in res.metrics[:: max(1, len(res.metrics) // 20)]:
        print(f"  round {m.round:4d}  t={m.virtual_time:8.1f}  "
              f"loss={m.loss:.4f}  acc={m.accuracy:.4f}  stale={m.n_stale}")
    s = eng.service.stats
    print(f"best_acc={res.best_accuracy():.4f} final_acc={res.final_accuracy():.4f} "
          f"updates={s.accepted} wall={res.wall_seconds:.1f}s "
          f"({s.accepted / max(res.wall_seconds, 1e-9):.0f} updates/s)")
    if eng.compressor is not None:
        cs = eng.compressor.stats
        print(f"uplink: {cs.bytes_per_update:.0f} bytes/update "
              f"({cs.ratio:.1f}x smaller than dense fp32)")
    if args.ckpt:
        eng.service.save(args.ckpt)
        print("service checkpoint →", args.ckpt)
    _finish_telemetry(args, telemetry)
    return res


def run_simulation(args):
    from repro.checkpoint import save_server_state
    from repro.core import FedQSHyperParams, SAFLEngine, make_algorithm
    from repro.data import make_federated_data
    from repro.models import make_cnn_spec, make_lstm_spec, make_mlp_spec

    hp = FedQSHyperParams(buffer_k=args.buffer_k, eta0=args.lr,
                          local_epochs=args.local_epochs)
    scenario = None
    if args.scenario:
        from repro.scenarios import get_scenario
        scenario = get_scenario(args.scenario)
    if args.cohort:
        if scenario is None:
            from repro.scenarios import Scenario
            scenario = Scenario()
        return run_cohort(args, hp, scenario)
    data = make_federated_data(args.task, args.clients, alpha=args.alpha,
                               sigma=args.sigma, seed=args.seed,
                               n_total=args.n_total)
    spec = {"cv": make_cnn_spec, "nlp": make_lstm_spec, "rwd": make_mlp_spec}[args.task]()
    algo = make_algorithm(args.algo, hp)
    telemetry = _make_telemetry(args)
    eng = SAFLEngine(data, spec, algo, hp, resource_ratio=args.resource_ratio,
                     seed=args.seed, eval_every=args.eval_every,
                     scenario=scenario, compress=args.compress,
                     topology=args.topology, telemetry=telemetry)
    print(f"FedQS SAFL simulation: task={args.task} algo={args.algo} "
          f"N={args.clients} K={hp.buffer_k} ratio=1:{args.resource_ratio:.0f}"
          + (f" scenario={scenario.describe()}" if scenario else "")
          + (f" topology={eng.service.describe()}" if args.topology else "")
          + (f" compress={eng.compressor.describe()}" if eng.compressor else ""))
    with _trace_scope(args, telemetry):
        res = eng.run(args.rounds)
    for m in res.metrics[:: max(1, len(res.metrics) // 20)]:
        print(f"  round {m.round:4d}  t={m.virtual_time:8.1f}  "
              f"loss={m.loss:.4f}  acc={m.accuracy:.4f}  stale={m.n_stale}")
    print(f"best_acc={res.best_accuracy():.4f} "
          f"final_acc={res.final_accuracy():.4f} "
          f"oscillations={res.oscillations()} wall={res.wall_seconds:.1f}s")
    if eng.compressor is not None:
        cs = eng.compressor.stats
        print(f"uplink: {cs.bytes_per_update:.0f} bytes/update "
              f"({cs.ratio:.1f}x smaller than dense fp32)")
    if args.ckpt:
        save_server_state(args.ckpt, eng)
        print("checkpoint →", args.ckpt)
    _finish_telemetry(args, telemetry)
    return res


def run_distributed(args):
    import jax
    import jax.numpy as jnp
    from repro.configs import get_reduced
    from repro.core.distributed import RoundState, make_fedqs_round_step
    from repro.core.types import FedQSHyperParams

    cfg = get_reduced(args.arch)
    hp = FedQSHyperParams(local_epochs=args.local_epochs)
    C, b, S = args.dist_clients, 2, 32
    key = jax.random.PRNGKey(args.seed)
    from repro.models import transformer as T

    params = T.init_params(cfg, key)
    state = RoundState(
        params=params,
        prev_params=params,
        lr=jnp.full((C,), hp.eta0 / 10),
        momentum=jnp.full((C,), hp.m0),
        counts=jnp.zeros((args.clients,), jnp.int32),
        sims=jnp.zeros((args.clients,), jnp.float32),
    )
    step = jax.jit(make_fedqs_round_step(cfg, hp, strategy=args.strategy,
                                         n_clients=C, total_clients=args.clients))
    print(f"distributed FedQS round-step loop: arch={args.arch}(reduced) "
          f"C={C} strategy={args.strategy}")
    for r in range(args.rounds):
        key, k1, k2 = jax.random.split(key, 3)
        tokens = jax.random.randint(k1, (C, b, S), 0, cfg.vocab)
        batch = {"tokens": tokens, "targets": tokens}
        if cfg.frontend != "none":
            batch["memory_embeds"] = jax.random.normal(
                k2, (C, b, cfg.n_frontend_tokens, cfg.d_model))
        cids = jax.random.randint(k2, (C,), 0, args.clients)
        stale = jax.random.uniform(k1, (C,)) * 2
        state, metrics = step(state, batch, cids, stale)
        if r % max(1, args.rounds // 10) == 0:
            print(f"  round {r:3d}  loss={float(metrics['loss']):.4f}  "
                  f"mean_sim={float(metrics['mean_similarity']):.3f}")
    print("done")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--task", default="rwd", choices=["cv", "nlp", "rwd"])
    ap.add_argument("--algo", default="fedqs-sgd")
    ap.add_argument("--rounds", type=int, default=100)
    ap.add_argument("--clients", type=int, default=100)
    ap.add_argument("--buffer-k", type=int, default=10)
    ap.add_argument("--lr", type=float, default=0.1)
    ap.add_argument("--alpha", type=float, default=0.5)
    ap.add_argument("--sigma", type=float, default=1.0)
    ap.add_argument("--local-epochs", type=int, default=2)
    ap.add_argument("--resource-ratio", type=float, default=50.0)
    ap.add_argument("--eval-every", type=int, default=1)
    ap.add_argument("--n-total", type=int, default=4000)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--scenario", default=None,
                    help="named scenario from docs/SCENARIOS.md (or trace:<path>)")
    ap.add_argument("--cohort", action="store_true",
                    help="vectorized cohort fast path (10k+ clients, virtual data)")
    ap.add_argument("--compress", default=None, metavar="SPEC",
                    help="compressed uplink codec spec (docs/COMPRESSION.md), "
                         "e.g. int8, topk:0.05, 'topk:0.05|int8'")
    ap.add_argument("--topology", default=None, metavar="SPEC",
                    help="tiered aggregation plane (docs/HIERARCHY.md), "
                         "e.g. 'hier:16' or 'hier:64x16'")
    ap.add_argument("--telemetry", default=None, metavar="PATH",
                    help="record structured events to a JSONL log "
                         "(docs/OBSERVABILITY.md)")
    ap.add_argument("--report", default=None, metavar="PATH",
                    help="render the recorded telemetry as a Markdown "
                         "experiment report (requires --telemetry)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="record monotonic-clock spans and export a "
                         "Chrome/Perfetto trace JSON (docs/OBSERVABILITY.md)")
    ap.add_argument("--health", action="store_true",
                    help="run the streaming anomaly detectors over "
                         "loss/accuracy/round signals (health-alert "
                         "events, docs/OBSERVABILITY.md)")
    ap.add_argument("--flightrec", default=None, metavar="PATH",
                    help="attach the flight recorder: a bounded black-box "
                         "event ring dumped to PATH on alert/crash/exit "
                         "(consumed by launch/analysis --postmortem)")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--distributed", action="store_true")
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--strategy", default="sgd", choices=["sgd", "avg"])
    ap.add_argument("--dist-clients", type=int, default=4)
    args = ap.parse_args()
    if args.distributed:
        run_distributed(args)
    else:
        run_simulation(args)


if __name__ == "__main__":
    main()
