"""Serving launcher.

Two serving surfaces:

* default — batched autoregressive decoding of a (reduced) architecture
  through the prefill + serve_step path, the host-scale twin of the
  decode-shape dry-runs;
* ``--safl-stream`` — the streaming SAFL aggregation service
  (``repro.serve``): ingest a synthetic semi-asynchronous update stream
  through admission control + a trigger policy and report sustained
  updates/sec and per-round aggregation latency.

With ``--scenario`` the update stream comes from the scenario engine
(docs/SCENARIOS.md): population speeds, arrival-process timing (diurnal
troughs thin the stream, bursts flood it), and mid-stream churn — the
load-generation twin of ``SAFLEngine(..., scenario=...)``.

With ``--topology`` the stream ingests through the hierarchical
aggregation plane (docs/HIERARCHY.md): clients report to edge
aggregators, partials flow upward, and the global tier aggregates
per-tier sums — ``--edge-k`` buffers updates at the edges first.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b --batch 4 --steps 32
    PYTHONPATH=src python -m repro.launch.serve --safl-stream --trigger quorum --updates 400
    PYTHONPATH=src python -m repro.launch.serve --safl-stream --scenario diurnal-churn \
        --clients 256 --updates 800 --trigger timewindow
    PYTHONPATH=src python -m repro.launch.serve --safl-stream --topology hier:16x4 \
        --clients 256 --updates 800 --edge-k 4
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def run_safl_stream(args):
    from repro.core import FedQSHyperParams, make_algorithm
    from repro.models import make_mlp_spec
    from repro.serve import (
        AdmitAll, StalenessAdmission, StreamingAggregator, make_trigger,
        replay, scenario_stream, synthetic_stream,
    )

    hp = FedQSHyperParams(buffer_k=args.buffer_k)
    spec = make_mlp_spec()
    params = spec.init(jax.random.PRNGKey(args.seed))
    algo = make_algorithm(args.algo, hp)
    if args.report and not args.telemetry:
        raise SystemExit("--report needs --telemetry (the recorded JSONL "
                         "log is what the report renders)")
    telemetry = None
    if args.telemetry:
        from repro.telemetry import Telemetry

        # the pipelined service overlaps rounds with ingestion; writing the
        # event log on the ingest thread would hand the stall right back,
        # so the file sink goes non-blocking (AsyncSink) whenever the
        # pipeline is on — close() drains, the on-disk stream is identical
        telemetry = Telemetry.to_jsonl(args.telemetry, trace=bool(args.trace),
                                       health=args.health,
                                       flightrec=args.flightrec,
                                       async_io=args.pipeline)
    elif args.trace or args.health or args.flightrec:
        from repro.telemetry import Telemetry

        # spans/detectors without --telemetry: events stay in memory
        telemetry = Telemetry.in_memory(trace=bool(args.trace),
                                        health=args.health,
                                        flightrec=args.flightrec)

    trigger = {
        "kbuffer": lambda: make_trigger("kbuffer", k=args.buffer_k),
        "timewindow": lambda: make_trigger("timewindow", window=args.window,
                                           min_updates=2),
        "adaptive": lambda: make_trigger("adaptive", window=args.window,
                                         min_updates=2),
        "quorum": lambda: make_trigger("quorum", k=args.buffer_k,
                                       quorum=max(2, args.buffer_k // 2),
                                       grace=args.window),
    }[args.trigger]()
    admission = (StalenessAdmission(args.tau_max, mode=args.admission_mode)
                 if args.tau_max >= 0 else AdmitAll())
    if args.topology:
        from repro.hier import HierarchicalService, parse_topology
        from repro.serve import KBuffer

        topo = parse_topology(args.topology, args.clients)
        service = HierarchicalService(
            algo, hp, params, args.clients, topo,
            trigger=trigger, admission=admission,
            edge_trigger=(lambda e: KBuffer(args.edge_k)) if args.edge_k > 1
            else None,
            pipeline=args.pipeline,
            telemetry=telemetry,
        )
    else:
        service = StreamingAggregator(
            algo, hp, params, args.clients,
            trigger=trigger, admission=admission, batched=args.batched,
            pipeline=args.pipeline,
            telemetry=telemetry,
        )
    if args.scenario:
        from repro.scenarios import get_scenario

        scenario = get_scenario(args.scenario)
        stream = list(scenario_stream(params, scenario, args.clients,
                                      args.updates, seed=args.seed,
                                      telemetry=telemetry))
        source = f"scenario[{scenario.describe()}]"
    else:
        stream = list(synthetic_stream(params, args.clients, args.updates,
                                       seed=args.seed))
        source = "synthetic"
    compressor = None
    if args.compress:
        from repro.compress import ClientCompressor, compress_stream

        compressor = ClientCompressor(args.compress, args.clients,
                                      seed=args.seed)
        compressor.telemetry = telemetry
        service.compressor = compressor
        stream = list(compress_stream(iter(stream), compressor,
                                      strategy=algo.strategy))
    import contextlib

    trace_scope = contextlib.nullcontext()
    if telemetry is not None and telemetry.tracer is not None:
        from repro.telemetry import profile

        trace_scope = profile.activate(telemetry)
    t0 = time.perf_counter()
    with trace_scope:
        reports = replay(service, stream)
    dt = time.perf_counter() - t0
    service.close()
    s = service.stats
    # the tiered plane always runs the batched stacked path
    batched_eff = True if args.topology else args.batched
    print(f"safl-stream: algo={args.algo} trigger={trigger.describe()} "
          f"admission={admission.describe()} batched={batched_eff} "
          f"pipeline={args.pipeline} source={source}"
          + (f" topology={service.describe()}" if args.topology else "")
          + (f" compress={compressor.describe()}" if compressor else ""))
    if args.topology:
        fires = sum(e.fires for e in service.edges)
        print(f"  tiers: {len(service.edges)} edges ({fires} edge fires), "
              f"{len(service.regions)} regions "
              f"({sum(r.fires for r in service.regions)} region fires), "
              f"{service.pending} updates still tier-buffered")
    if compressor is not None:
        cs = compressor.stats
        print(f"  uplink {cs.bytes_per_update:.0f} bytes/update "
              f"({cs.ratio:.1f}x smaller than dense fp32)")
    print(f"  {s.submitted} updates → {s.accepted} admitted, {s.dropped} dropped, "
          f"{s.downweighted} downweighted, {s.partial} partial, {s.rounds} rounds")
    print(f"  sustained {s.submitted / dt:.1f} updates/s "
          f"({dt / max(s.rounds, 1) * 1e3:.2f} ms/round wall, "
          f"{s.agg_seconds / max(s.rounds, 1) * 1e3:.2f} ms/round aggregation)")
    for rep in reports[:: max(1, len(reports) // 8)]:
        print(f"  round {rep.round:3d}  K={rep.n_updates:3d} "
              f"distinct={rep.n_distinct:3d} stale(mean={rep.mean_staleness:.1f},"
              f"max={rep.max_staleness}) dropped={rep.dropped_since_last}")
    if args.ckpt:
        service.save(args.ckpt)
        print("checkpoint →", args.ckpt)
    if telemetry is not None and telemetry.health is not None:
        hm = telemetry.health
        crit = sum(1 for a in hm.alerts if a.severity == "critical")
        print(f"  health: {len(hm.alerts)} alerts "
              f"({crit} critical) across {len(hm.detectors)} detectors"
              + ("" if not hm.alerts else " — see health-alert events"))
    if telemetry is not None:
        if args.trace and telemetry.tracer is not None:
            from repro.launch.analysis import export_trace

            export_trace(telemetry, args.trace)
        telemetry.close()
        if args.telemetry:
            print(f"telemetry → {args.telemetry}")
        if args.report:
            from repro.launch.analysis import report_from_jsonl

            with open(args.report, "w", encoding="utf-8") as fh:
                fh.write(report_from_jsonl(args.telemetry))
            print(f"experiment report → {args.report}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--steps", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--temperature", type=float, default=1.0)
    # streaming SAFL aggregation service
    ap.add_argument("--safl-stream", action="store_true",
                    help="serve a streaming SAFL update stream instead of decoding")
    ap.add_argument("--trigger", default="kbuffer",
                    choices=["kbuffer", "timewindow", "adaptive", "quorum"],
                    help="'adaptive' is a time-window whose deadline tracks "
                         "a running delivery-latency quantile "
                         "(docs/ROBUSTNESS.md)")
    ap.add_argument("--scenario", default=None,
                    help="drive the stream from a named scenario (docs/SCENARIOS.md)")
    ap.add_argument("--algo", default="fedqs-sgd")
    ap.add_argument("--clients", type=int, default=64)
    ap.add_argument("--updates", type=int, default=400)
    ap.add_argument("--buffer-k", type=int, default=10)
    ap.add_argument("--window", type=float, default=3.0,
                    help="time-window / quorum-grace length (stream clock units)")
    ap.add_argument("--tau-max", type=int, default=-1,
                    help="staleness bound for admission (-1 = admit all)")
    ap.add_argument("--admission-mode", default="drop",
                    choices=["drop", "downweight"])
    ap.add_argument("--batched", action="store_true",
                    help="stacked [K,D] aggregation (Pallas kernel on TPU)")
    ap.add_argument("--pipeline", dest="pipeline", action="store_true",
                    default=True,
                    help="overlap each round's device aggregation with the "
                         "next round's ingestion (docs/ARCHITECTURE.md "
                         "'Overlapped rounds'; default on)")
    ap.add_argument("--no-pipeline", dest="pipeline", action="store_false",
                    help="synchronous aggregation — the escape hatch; the "
                         "output stream is bit-identical either way")
    ap.add_argument("--topology", default=None, metavar="SPEC",
                    help="tiered aggregation plane (docs/HIERARCHY.md), "
                         "e.g. 'hier:16' or 'hier:64x16'")
    ap.add_argument("--edge-k", type=int, default=1,
                    help="edge-tier K-buffer size (1 = all-pass, flat parity)")
    ap.add_argument("--compress", default=None, metavar="SPEC",
                    help="encode the stream through the compressed transport "
                         "(docs/COMPRESSION.md), e.g. int8, 'topk:0.05|int8'")
    ap.add_argument("--telemetry", default=None, metavar="PATH",
                    help="record structured events to a JSONL log "
                         "(docs/OBSERVABILITY.md)")
    ap.add_argument("--report", default=None, metavar="PATH",
                    help="render the recorded telemetry as a Markdown "
                         "experiment report (requires --telemetry)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="record monotonic-clock spans and export a "
                         "Chrome/Perfetto trace JSON (docs/OBSERVABILITY.md)")
    ap.add_argument("--health", action="store_true",
                    help="run the streaming anomaly detectors over the "
                         "round stream (health-alert events + on-kernel "
                         "update statistics, docs/OBSERVABILITY.md)")
    ap.add_argument("--flightrec", default=None, metavar="PATH",
                    help="attach the flight recorder: a bounded black-box "
                         "event ring dumped to PATH on alert/crash/exit "
                         "(consumed by launch/analysis --postmortem)")
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    if args.safl_stream:
        run_safl_stream(args)
        return

    from repro.configs import get_reduced
    from repro.core.distributed import make_prefill_step, make_serve_step
    from repro.models import transformer as T

    cfg = get_reduced(args.arch)
    key = jax.random.PRNGKey(args.seed)
    params = T.init_params(cfg, key)
    max_seq = args.prompt_len + args.steps + 1

    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0, cfg.vocab)
    me = None
    if cfg.frontend != "none":
        me = jax.random.normal(key, (args.batch, cfg.n_frontend_tokens, cfg.d_model))

    prefill = jax.jit(lambda p, t: make_prefill_step(cfg, max_seq=max_seq)(p, t, me))
    serve = jax.jit(lambda p, c, t: make_serve_step(cfg)(p, c, t, me))

    t0 = time.perf_counter()
    logits, cache = prefill(params, prompts)
    logits.block_until_ready()
    t_prefill = time.perf_counter() - t0

    toks = jnp.argmax(logits, -1)
    out = [np.asarray(toks)]
    t0 = time.perf_counter()
    for i in range(args.steps):
        key, sk = jax.random.split(key)
        logits, cache = serve(params, cache, toks)
        if args.temperature > 0:
            toks = jax.random.categorical(sk, logits / args.temperature, -1)
        else:
            toks = jnp.argmax(logits, -1)
        out.append(np.asarray(toks))
    jax.block_until_ready(toks)
    t_decode = time.perf_counter() - t0

    seqs = np.stack(out, 1)
    print(f"arch={args.arch}(reduced) batch={args.batch} "
          f"prefill({args.prompt_len} tok)={t_prefill*1e3:.1f}ms "
          f"decode={args.steps} steps in {t_decode*1e3:.1f}ms "
          f"({args.steps*args.batch/t_decode:.1f} tok/s)")
    for b in range(min(args.batch, 2)):
        print(f"  seq[{b}]: {seqs[b][:16].tolist()} ...")


if __name__ == "__main__":
    main()
