"""Serving launcher: batched autoregressive decoding of a (reduced)
architecture through the prefill + serve_step path — the host-scale twin
of the decode-shape dry-runs.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b --batch 4 --steps 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--steps", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--temperature", type=float, default=1.0)
    args = ap.parse_args()

    from repro.configs import get_reduced
    from repro.core.distributed import make_prefill_step, make_serve_step
    from repro.models import transformer as T

    cfg = get_reduced(args.arch)
    key = jax.random.PRNGKey(args.seed)
    params = T.init_params(cfg, key)
    max_seq = args.prompt_len + args.steps + 1

    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0, cfg.vocab)
    me = None
    if cfg.frontend != "none":
        me = jax.random.normal(key, (args.batch, cfg.n_frontend_tokens, cfg.d_model))

    prefill = jax.jit(lambda p, t: make_prefill_step(cfg, max_seq=max_seq)(p, t, me))
    serve = jax.jit(lambda p, c, t: make_serve_step(cfg)(p, c, t, me))

    t0 = time.perf_counter()
    logits, cache = prefill(params, prompts)
    logits.block_until_ready()
    t_prefill = time.perf_counter() - t0

    toks = jnp.argmax(logits, -1)
    out = [np.asarray(toks)]
    t0 = time.perf_counter()
    for i in range(args.steps):
        key, sk = jax.random.split(key)
        logits, cache = serve(params, cache, toks)
        if args.temperature > 0:
            toks = jax.random.categorical(sk, logits / args.temperature, -1)
        else:
            toks = jnp.argmax(logits, -1)
        out.append(np.asarray(toks))
    jax.block_until_ready(toks)
    t_decode = time.perf_counter() - t0

    seqs = np.stack(out, 1)
    print(f"arch={args.arch}(reduced) batch={args.batch} "
          f"prefill({args.prompt_len} tok)={t_prefill*1e3:.1f}ms "
          f"decode={args.steps} steps in {t_decode*1e3:.1f}ms "
          f"({args.steps*args.batch/t_decode:.1f} tok/s)")
    for b in range(min(args.batch, 2)):
        print(f"  seq[{b}]: {seqs[b][:16].tolist()} ...")


if __name__ == "__main__":
    main()
