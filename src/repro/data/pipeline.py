"""Federated data pipeline: client-local datasets with deterministic
batch iteration, validation split, and per-label validation accuracy
(needed by Mod-2's SSBC situation detector).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

from . import synthetic as syn


@dataclass
class ClientDataset:
    x: np.ndarray
    y: np.ndarray
    val_x: np.ndarray
    val_y: np.ndarray

    @property
    def n(self) -> int:
        return len(self.x)

    def batches(self, batch_size: int, epoch_seed: int, n_batches: int):
        """Yield ``n_batches`` minibatches (one per local epoch, paper E)."""
        rng = np.random.default_rng(epoch_seed)
        for _ in range(n_batches):
            idx = rng.integers(0, len(self.x), min(batch_size, len(self.x)))
            yield {"x": self.x[idx], "y": self.y[idx]}

    def per_label_val_accuracy(self, predict_fn, n_labels: int) -> np.ndarray:
        """Per-label accuracy of ``predict_fn`` on the local validation set.
        Labels absent locally are returned as NaN (ignored by the detector)."""
        preds = np.asarray(predict_fn(self.val_x))
        out = np.full(n_labels, np.nan, np.float32)
        for c in range(n_labels):
            mask = self.val_y == c
            if mask.any():
                out[c] = float((preds[mask] == c).mean())
        return out


@dataclass
class FederatedData:
    clients: List[ClientDataset]
    test_x: np.ndarray
    test_y: np.ndarray
    n_labels: int

    @property
    def n_clients(self) -> int:
        return len(self.clients)


def _split_val(x, y, frac: float, seed: int):
    rng = np.random.default_rng(seed)
    idx = rng.permutation(len(x))
    n_val = max(1, int(len(x) * frac))
    v, t = idx[:n_val], idx[n_val:]
    if len(t) == 0:
        t = v
    return x[t], y[t], x[v], y[v]


def make_federated_data(
    task: str,
    n_clients: int,
    *,
    alpha: float = 0.5,
    sigma: float = 1.0,
    roles_per_client: int = 2,
    seed: int = 0,
    n_total: int = 4000,
) -> FederatedData:
    """Build one of the paper's three task families (DESIGN §4).

    task ∈ {"cv", "nlp", "rwd"}; ``alpha`` is the Dirichlet x for cv,
    ``sigma`` the log-normal σ for rwd, ``roles_per_client`` for nlp.
    Validation split: 8:2 (cv/rwd), 9:1 (nlp) per Appendix D.1.
    """
    if task == "cv":
        # one draw for train+test so class templates are shared (the test
        # set is held-out SAMPLES, not a different distribution)
        n_test = max(200, n_total // 10)
        x_all, y_all = syn.synth_cifar10(n=n_total + n_test, seed=seed)
        x, y = x_all[:n_total], y_all[:n_total]
        test_x, test_y = x_all[n_total:], y_all[n_total:]
        parts = syn.dirichlet_partition(y, n_clients, alpha, seed=seed)
        clients = []
        for ix in parts:
            tx, ty, vx, vy = _split_val(x[ix], y[ix], 0.2, seed)
            clients.append(ClientDataset(tx, ty, vx, vy))
        return FederatedData(clients, test_x, test_y, 10)

    if task == "nlp":
        n_roles = n_clients * roles_per_client
        by_role = syn.synth_shakespeare(n_roles=n_roles, seed=seed)
        assign = syn.role_partition(n_roles, n_clients, roles_per_client, seed=seed)
        # test set = held-out windows from every role (same distributions,
        # unseen text), like the paper's held-out Shakespeare lines
        rng = np.random.default_rng(seed + 1)
        test_xs, test_ys = [], []
        train_pool = {}
        for r, (xs, ys) in by_role.items():
            n_hold = max(1, len(xs) // 10)
            idx = rng.permutation(len(xs))
            test_xs.append(xs[idx[:n_hold]])
            test_ys.append(ys[idx[:n_hold]])
            train_pool[r] = (xs[idx[n_hold:]], ys[idx[n_hold:]])
        clients = []
        for role_ids in assign:
            xs = np.concatenate([train_pool[r][0] for r in role_ids])
            ys = np.concatenate([train_pool[r][1] for r in role_ids])
            tx, ty, vx, vy = _split_val(xs, ys, 0.1, seed)
            clients.append(ClientDataset(tx, ty, vx, vy))
        test_x = np.concatenate(test_xs)
        test_y = np.concatenate(test_ys)
        return FederatedData(clients, test_x, test_y, 80)

    if task == "rwd":
        n_test = max(200, n_total // 10)
        x_all, y_all, g_all = syn.synth_adult(n=n_total + n_test, seed=seed)
        x, y, group = x_all[:n_total], y_all[:n_total], g_all[:n_total]
        test_x, test_y = x_all[n_total:], y_all[n_total:]
        # group-keyed log-normal sizes: clients are homogeneous in `group`
        clients = []
        for g in (0, 1):
            gx, gy = x[group == g], y[group == g]
            parts = syn.lognormal_partition(len(gx), n_clients // 2, sigma, seed=seed + g)
            for ix in parts:
                tx, ty, vx, vy = _split_val(gx[ix], gy[ix], 0.2, seed)
                clients.append(ClientDataset(tx, ty, vx, vy))
        return FederatedData(clients[:n_clients], test_x, test_y, 2)

    raise ValueError(f"unknown task {task!r}")
