"""Deterministic synthetic datasets mirroring the paper's three tasks.

The container is offline, so CIFAR-10 / Shakespeare / UCI-Adult are
replaced with structure-preserving synthetic stand-ins (DESIGN §4).  The
*partition laws* are the paper's: Hetero-Dirichlet over labels for CV
(Eq. 13), non-overlapping roles for NLP, Log-N(0,σ²) client sizes for RWD.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import numpy as np


# --------------------------------------------------------------------------
# datasets
# --------------------------------------------------------------------------
def synth_cifar10(
    n: int = 6000, n_classes: int = 10, hw: int = 16, seed: int = 0
) -> Tuple[np.ndarray, np.ndarray]:
    """Class-conditional Gaussian-blob 'images' (hw×hw×3), 10 classes.

    Each class has a fixed random template; samples are template + noise,
    so the Bayes classifier is nontrivial but learnable by a small CNN.
    """
    rng = np.random.default_rng(seed)
    templates = rng.normal(0, 1, (n_classes, hw, hw, 3)).astype(np.float32)
    y = rng.integers(0, n_classes, n).astype(np.int32)
    x = templates[y] + rng.normal(0, 1.5, (n, hw, hw, 3)).astype(np.float32)
    return x, y


def synth_shakespeare(
    n_roles: int = 60,
    chars_per_role: int = 2048,
    vocab: int = 80,
    seq_len: int = 32,
    seed: int = 0,
) -> Dict[int, Tuple[np.ndarray, np.ndarray]]:
    """Per-role Markov-chain char streams → next-char prediction windows.

    Returns {role_id: (x[n_seq, seq_len] int32, y[n_seq] int32)}.  Roles use
    *distinct* transition matrices, so clients holding different roles are
    genuinely non-IID (paper: roles never overlap across clients).
    """
    rng = np.random.default_rng(seed)
    out: Dict[int, Tuple[np.ndarray, np.ndarray]] = {}
    for role in range(n_roles):
        # sparse-ish row-stochastic transition matrix per role
        logits = rng.normal(0, 2.0, (vocab, vocab))
        probs = np.exp(logits) / np.exp(logits).sum(-1, keepdims=True)
        stream = np.empty(chars_per_role, np.int32)
        stream[0] = rng.integers(vocab)
        for t in range(1, chars_per_role):
            stream[t] = rng.choice(vocab, p=probs[stream[t - 1]])
        n_seq = (chars_per_role - 1) // seq_len
        x = np.stack([stream[i * seq_len : i * seq_len + seq_len] for i in range(n_seq)])
        y = np.asarray([stream[i * seq_len + seq_len] if i * seq_len + seq_len < chars_per_role else stream[-1] for i in range(n_seq)], np.int32)
        out[role] = (x.astype(np.int32), y)
    return out


def synth_adult(
    n: int = 8000, n_features: int = 14, seed: int = 0
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Tabular records with planted logistic ground truth + a binary
    sensitive attribute (gender/ethnicity analogue) correlated with x.

    Returns (x[n, d] f32, y[n] int32 ∈{0,1}, group[n] int32 ∈{0,1}).
    """
    rng = np.random.default_rng(seed)
    group = rng.integers(0, 2, n).astype(np.int32)
    x = rng.normal(0, 1, (n, n_features)).astype(np.float32)
    x[:, 0] += 0.8 * group  # group shifts one covariate → heterogeneity
    w_true = rng.normal(0, 1, n_features)
    logit = x @ w_true + 0.5 * group - 0.2
    p = 1 / (1 + np.exp(-logit))
    y = (rng.uniform(size=n) < p).astype(np.int32)
    return x, y, group


# --------------------------------------------------------------------------
# partitioners
# --------------------------------------------------------------------------
def dirichlet_partition(
    labels: np.ndarray, n_clients: int, alpha: float, seed: int = 0, min_size: int = 8
) -> List[np.ndarray]:
    """Hetero-Dirichlet label partition (paper Eq. 13): for each class,
    draw client proportions ~ Dir(alpha) and split that class's indices.
    Smaller alpha ⇒ more skew (paper uses x ∈ {0.1, 0.5, 1})."""
    rng = np.random.default_rng(seed)
    n_classes = int(labels.max()) + 1
    while True:
        idx_by_client: List[List[int]] = [[] for _ in range(n_clients)]
        for c in range(n_classes):
            idx = np.flatnonzero(labels == c)
            rng.shuffle(idx)
            props = rng.dirichlet([alpha] * n_clients)
            cuts = (np.cumsum(props) * len(idx)).astype(int)[:-1]
            for cid, part in enumerate(np.split(idx, cuts)):
                idx_by_client[cid].extend(part.tolist())
        sizes = [len(ix) for ix in idx_by_client]
        if min(sizes) >= min_size:
            return [np.asarray(sorted(ix), np.int64) for ix in idx_by_client]
        seed += 1
        rng = np.random.default_rng(seed)


def lognormal_partition(
    n_items: int, n_clients: int, sigma: float, seed: int = 0, min_size: int = 8
) -> List[np.ndarray]:
    """Client sizes ~ Log-N(0, σ²), normalized to n_items (RWD task)."""
    rng = np.random.default_rng(seed)
    sizes = rng.lognormal(0.0, sigma, n_clients)
    sizes = np.maximum((sizes / sizes.sum() * n_items).astype(int), min_size)
    idx = rng.permutation(n_items)
    out, pos = [], 0
    for s in sizes:
        out.append(np.sort(idx[pos : pos + s]).astype(np.int64))
        pos = min(pos + s, n_items - min_size)
    return out


def role_partition(n_roles: int, n_clients: int, roles_per_client: int, seed: int = 0):
    """Assign non-overlapping role ids to clients (NLP task; R = N·roles)."""
    rng = np.random.default_rng(seed)
    roles = rng.permutation(n_roles)
    need = n_clients * roles_per_client
    if need > n_roles:
        # wrap around deterministically — still disjoint within a client
        roles = np.concatenate([roles, rng.permutation(n_roles)])[:need]
    else:
        roles = roles[:need]
    return [roles[i * roles_per_client : (i + 1) * roles_per_client].tolist() for i in range(n_clients)]
