from .synthetic import (
    dirichlet_partition,
    lognormal_partition,
    synth_adult,
    synth_cifar10,
    synth_shakespeare,
)
from .pipeline import ClientDataset, FederatedData, make_federated_data

__all__ = [
    "dirichlet_partition",
    "lognormal_partition",
    "synth_adult",
    "synth_cifar10",
    "synth_shakespeare",
    "ClientDataset",
    "FederatedData",
    "make_federated_data",
]
