"""The hierarchical aggregation service: edge → region → global SAFL.

``HierarchicalService`` subclasses ``repro.serve.StreamingAggregator``
and keeps its whole public surface — admission, stats, round reports,
``on_round`` hooks, checkpointing, the server-state facade algorithms
read — but routes every admitted update through a ``Topology`` of
``TierAggregator`` nodes instead of one flat ingest buffer.  The global
tier consumes **partial aggregates**: tensor-wise each partial is one
[D] fp32 vector however many client updates it folds, so at scale no
single buffer ever holds the whole population's rows, and edge triggers
bound staleness dispersion locally (CSAFL, arXiv:2104.08184).

Weighting semantics (docs/HIERARCHY.md "Staleness & weighting"):

* partials carry exact per-member metadata, so the aggregation status
  table (Eq. 1/2) and the member-level Mod-3 weights p_i are computed
  from the same facts as the flat service;
* each partial's aggregate weight is Σ of its members' p_i (member
  weights come from the algorithm's own ``_base_weights`` for non-FedQS
  algorithms); inside a partial, members combine sample-proportionally
  (w = n_i).  This is **exact** whenever member weights are
  n-proportional within every partial — always for the
  sample-proportional base algorithms (FedAvg/FedSGD), for FedQS
  without feedback re-weighting, and for *any* supported algorithm when
  edge triggers are all-pass (K=1: every partial is a single update).
  Otherwise only the intra-edge redistribution is approximated (FedQS
  feedback corrections, DeFedAvg's uniform weighting); each edge's
  total weight stays exact.

The global trigger is evaluated against the ``MemberView`` of buffered
partials, so a ``KBuffer(K)`` still fires after K client updates and a
2-tier all-pass plane is round-for-round identical to the flat service
(the parity gate in ``benchmarks/bench_hier.py``).
"""
from __future__ import annotations

import functools
import time as _time
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.flatten_util import ravel_pytree

from repro.core.aggregation import feedback_weight
from repro.core.algorithms import Algorithm, FedQS
from repro.core.types import (
    AggregationStrategy,
    FedQSHyperParams,
    Params,
    ServerTable,
)
from repro.kernels import weighted_agg_auto_op, weighted_agg_op
from repro.kernels.ref import ingest_weights
from repro.serve.batched import _round_meta, bucket_rows
from repro.serve.service import RoundReport, StreamingAggregator
from repro.serve.triggers import KBuffer, TriggerPolicy
from repro.telemetry import Telemetry, TierMerged

from .partial import MemberView, PartialAggregate, materialize
from .tier import EdgeAggregator, RegionAggregator
from .topology import Topology


def _default_edge_trigger(node_id: int) -> TriggerPolicy:
    # all-pass: each update becomes its own partial — zero added latency,
    # exact flat parity; pass a factory to actually buffer at the edge
    return KBuffer(1)


@functools.partial(jax.jit, static_argnames=("n_clients", "grad"))
def _fused_partial_combine(rows, counts, tsims, cids, sims, n, fb, cf, k,
                           onehot, inv_sum_w, flat_g, eta_g, ratio_clip,
                           *, n_clients, grad):
    """The fused global-stage combine: member-level Eq. §3.4 weights →
    per-partial fold → Σw·rows → global step, in ONE jitted dispatch.

    Same algebra as the host-side ``_member_weights`` + ``weighted_agg``
    pair it replaces, but the staleness/feedback weighting runs on-device
    (``kernels/ref.ingest_weights``, shared with the ingest kernels) and
    the member→partial fold is a one-hot [Pb, Kb] matmul.  Both axes
    arrive shape-bucketed — member rows padded with ``n = fb = 0``
    (weight exactly 0) and partial rows with zeros — so the variable
    member/partial counts of time-window fires never recompile."""
    F, G = _round_meta(counts, tsims, cids, sims, ratio_clip)
    Kb = cids.shape[0]
    col = lambda v: v.reshape(Kb, 1)
    p = ingest_weights(col(n), col(F), col(G), col(fb), k,
                       n_clients=n_clients, normalize=True, cf=col(cf))
    w_part = jnp.dot(onehot, p)[:, 0] * inv_sum_w
    flat = jnp.dot(w_part[None, :], rows,
                   preferred_element_type=jnp.float32)[0]
    return flat_g - eta_g * flat if grad else flat


class HierarchicalService(StreamingAggregator):
    """Tiered drop-in for ``StreamingAggregator`` (see module docstring).

    ``edge_trigger`` / ``region_trigger`` are *factories* (node id →
    ``TriggerPolicy``) because every node arms its own policy instance;
    the ``trigger`` argument is the global tier's policy, exactly as on
    the flat service.
    """

    def __init__(
        self,
        algo: Algorithm,
        hp: FedQSHyperParams,
        init_params: Params,
        n_clients: int,
        topology: Topology,
        *,
        trigger: Optional[TriggerPolicy] = None,
        admission=None,
        edge_trigger: Optional[Callable[[int], TriggerPolicy]] = None,
        region_trigger: Optional[Callable[[int], TriggerPolicy]] = None,
        use_kernel: Optional[bool] = None,
        fused: Optional[bool] = None,
        context=None,
        async_agg: bool = False,
        pipeline: bool = False,
        on_round=None,
        speeds: Optional[np.ndarray] = None,
        clock: Callable[[], float] = _time.monotonic,
        telemetry: Optional[Telemetry] = None,
    ):
        if not isinstance(algo, FedQS) and (
            type(algo).server_aggregate is not Algorithm.server_aggregate
        ):
            raise ValueError(
                f"algorithm {algo.name!r} overrides server_aggregate with "
                "stateful logic that cannot run on pre-aggregated partials "
                "— the hierarchical plane supports FedQS and the base "
                "linear-weighting algorithms"
            )
        if topology.n_clients != int(n_clients):
            raise ValueError(
                f"topology is wired for {topology.n_clients} clients, "
                f"service has {n_clients}"
            )
        super().__init__(
            algo, hp, init_params, n_clients,
            trigger=trigger, admission=admission, context=context,
            batched=True, use_kernel=use_kernel, fused=fused,
            async_agg=async_agg, pipeline=pipeline,
            on_round=on_round, speeds=speeds, clock=clock,
            telemetry=telemetry,
        )
        self.topology = topology
        self._use_kernel = use_kernel
        edge_trigger = edge_trigger or _default_edge_trigger
        region_trigger = region_trigger or _default_edge_trigger
        strategy = getattr(algo, "strategy", AggregationStrategy.MODEL)
        self.edges = [
            EdgeAggregator(e, edge_trigger(e), strategy=strategy,
                           use_kernel=use_kernel, fused=self._fused)
            for e in range(topology.n_edges)
        ]
        self.regions = [
            RegionAggregator(r, region_trigger(r), use_kernel=use_kernel)
            for r in range(topology.n_regions)
        ]
        # running member count of self._ingest, so the global trigger's
        # K-buffer check is O(1) per submit instead of re-summing every
        # buffered partial
        self._ingest_members = 0
        if self._tracer is not None:
            # tier nodes record their _reduce time as hier/tier-fire spans
            for node in self.edges + self.regions:
                node.tracer = self._tracer
        if telemetry is not None:
            m = telemetry.metrics
            self._tm_edge_fires = m.counter("hier.edge_fires",
                                            unit="fires", layer="hier")
            self._tm_region_fires = m.counter("hier.region_fires",
                                              unit="fires", layer="hier")
            self._tm_partial_members = m.histogram(
                "hier.partial_members", (1, 2, 4, 8, 16, 32, 64, 128, 256),
                unit="updates", layer="hier")

    # ------------------------------------------------------------- ingestion
    # submit()/submit_burst() are inherited: the base service drives the
    # shared admit → buffer → trigger → fire sequence and these two hooks
    # swap the flat buffer for the tier topology, so every front-end mode
    # (per-update, burst, pipelined) routes identically
    def _buffer_admitted(self, update, now: float) -> None:
        """Route one admitted update down its edge; partials emitted by
        firing tiers bubble up to the global buffer, where the global
        trigger sees the flat member count."""
        if self._tracer is not None:
            # residency spans measure admission → global fire, however
            # many tier hops the update's partial takes in between
            self._ingest_t.append((self._last_tid, _time.perf_counter()))
        edge = self.edges[self.topology.edge_of(update.cid)]
        partial = edge.submit(update, now)
        if partial is not None:
            self._forward(partial, now)

    def _trigger_view(self):
        return MemberView(self._ingest, n=self._ingest_members)

    def _forward(self, partial: PartialAggregate, now: float) -> None:
        """One tier hop: edge partials go to their region (3-tier) or the
        global buffer (2-tier); regional partials go to the global buffer."""
        self._tier_merged(partial, now)
        if partial.tier == "edge" and self.regions:
            region = self.regions[self.topology.region_of(partial.node_id)]
            merged = region.submit(partial, now)
            if merged is not None:
                self._tier_merged(merged, now)
                self._ingest.append(merged)
                self._ingest_members += merged.n_members
        else:
            self._ingest.append(partial)
            self._ingest_members += partial.n_members

    def _tier_merged(self, partial: PartialAggregate, now: float) -> None:
        """Telemetry for one tier fire (no-op without a hub)."""
        tel = self.telemetry
        if tel is None:
            return
        if partial.tier == "edge":
            self._tm_edge_fires.inc()
        else:
            self._tm_region_fires.inc()
        self._tm_partial_members.observe(partial.n_members)
        self._emit_event(TierMerged(
            t=float(now), round=self.round, tier=partial.tier,
            node_id=int(partial.node_id), n_members=int(partial.n_members),
        ))

    def _fire(self, now: float):
        self._ingest_members = 0  # the swap empties the global buffer
        return super()._fire(now)

    @property
    def pending(self) -> int:
        """Client updates admitted but not yet globally aggregated,
        across every tier of the plane."""
        return (
            sum(e.pending for e in self.edges)
            + sum(r.pending for r in self.regions)
            + self._ingest_members
        )

    def flush(self, now: Optional[float] = None) -> Optional[RoundReport]:
        """Drain the whole plane: force-fire every edge, then every
        region, then the global tier (the flat flush semantics)."""
        with self._lock:
            now = self._clock() if now is None else now
            for edge in self.edges:
                partial = edge.flush(now)
                if partial is not None:
                    self._forward(partial, now)
            for region in self.regions:
                merged = region.flush(now)
                if merged is not None:
                    self._forward(merged, now)
            return super().flush(now=now)

    # ----------------------------------------------------------- aggregation
    def _dispatch(self, ctx, batch: List[PartialAggregate]):
        # the inherited _aggregate drives the round bookkeeping; only the
        # batch routing differs — partials, not raw updates
        return self._dispatch_partials(batch)

    def _batch_members(self, batch: List[PartialAggregate]):
        # round reports carry metadata-only MemberRef records: partials
        # do not retain per-member tensor payloads (see RoundReport)
        return list(MemberView(batch))

    def _member_weights(self, batch: List[PartialAggregate],
                        counts: np.ndarray, table_sims: np.ndarray,
                        cids: np.ndarray) -> np.ndarray:
        """Exact member-level Mod-3 weights from the carried metadata —
        the same algebra ``repro.core.aggregation.server_aggregate`` runs
        on a flat buffer of raw updates, computed host-side: the member
        count varies round to round, and a few hundred f32 scalars are
        not worth a per-shape XLA compile on the serialized global stage.
        """
        n_samples = np.concatenate(
            [p.n_samples for p in batch]).astype(np.float32)
        has_partial = any(p.completed is not None for p in batch)
        cf = (np.concatenate([p.completed_or_ones() for p in batch])
              if has_partial else None)
        if not isinstance(self.algo, FedQS):
            # the algorithm's own weighting over the member view —
            # n-proportional for the base class, uniform for DeFedAvg
            p = np.asarray(self.algo._base_weights(list(MemberView(batch))),
                           np.float32)
            if cf is not None:
                p = p * cf
            return p / max(p.sum(), np.float32(1e-12))
        hp = self.hp
        sims = np.concatenate([p.sims for p in batch]).astype(np.float32)
        fb = np.concatenate([p.feedback for p in batch]) & hp.use_feedback
        total = max(counts.sum(), 1)
        f = counts.astype(np.float32) / np.float32(total)
        f_bar, s_bar = f.mean(), table_sims.mean()
        F = np.clip(f_bar / np.maximum(f[cids], 1e-12),
                    1.0 / hp.ratio_clip, hp.ratio_clip).astype(np.float32)
        G = np.clip(max(s_bar, 1e-6) / np.maximum(sims, 1e-6),
                    1.0 / hp.ratio_clip, hp.ratio_clip).astype(np.float32)
        # aggregation_weights (Eq. §3.4) on the numpy backend; cf scales
        # the pre-normalization weight exactly as on the flat service
        K, N = len(cids), self.n_clients
        p = n_samples / max(n_samples.sum(), 1)
        w_fb = feedback_weight(F, G, K, N, xp=np)
        p = np.where(fb, w_fb.astype(np.float32), p)
        if cf is not None:
            p = p * cf
        return p / max(p.sum(), np.float32(1e-12))

    def _dispatch_partials(self, batch: List[PartialAggregate]):
        # one segment_agg launch reduces every still-lazy edge buffer of
        # this fire (the 2-tier fused path; 3-tier planes materialized at
        # their regions already)
        materialize(batch, use_kernel=self._use_kernel)

        # status table (Eq. 1/2) from the exact member metadata, host-side
        # (duplicate cids: each occurrence counts, last similarity wins)
        tr = self._tracer
        t_tab = _time.perf_counter() if tr is not None else 0.0
        cids = np.concatenate([p.cids for p in batch])
        sims = np.concatenate([p.sims for p in batch]).astype(np.float32)
        counts = np.asarray(self.table.counts).copy()
        np.add.at(counts, cids, 1)
        table_sims = np.asarray(self.table.sims).copy()
        table_sims[cids] = sims
        new_table = ServerTable(counts=jnp.asarray(counts, jnp.int32),
                                sims=jnp.asarray(table_sims, jnp.float32))
        if tr is not None:
            tr.record("table", "serve", t_tab,
                      _time.perf_counter() - t_tab, round=self._span_round)

        if self._fused and isinstance(self.algo, FedQS):
            return self._fused_global(batch, new_table, cids, sims)

        t_stk = _time.perf_counter() if tr is not None else 0.0
        p_members = self._member_weights(batch, counts, table_sims, cids)
        part_idx = np.repeat(np.arange(len(batch)),
                             [p.n_members for p in batch])
        w_partials = np.zeros(len(batch), np.float32)
        np.add.at(w_partials, part_idx, p_members)
        # fold the per-partial 1/Σw normalization into the combine weight
        # so the row stack is the raw fp32 sums the tiers forwarded
        w_partials /= np.maximum(
            np.asarray([p.sum_w for p in batch], np.float32), 1e-12)

        rows = jnp.stack([p.sum_wx for p in batch])
        # pad the partial axis to a small bucket: the partial count
        # varies round to round and the serialized global stage should
        # not pay a per-shape compile for it (zero rows contribute 0)
        P = rows.shape[0]
        bucket = max(8, 1 << (P - 1).bit_length())
        if bucket != P:
            rows = jnp.pad(rows, ((0, bucket - P), (0, 0)))
            w_partials = np.pad(w_partials, (0, bucket - P))
        w = jnp.asarray(w_partials)
        if tr is not None:
            tr.record("stack", "serve", t_stk,
                      _time.perf_counter() - t_stk, round=self._span_round)
        if self._use_kernel is None:
            flat = weighted_agg_auto_op(rows, w)
        elif self._use_kernel:
            flat = weighted_agg_op(rows, w)
        else:
            from repro.kernels.ref import weighted_agg_ref

            flat = weighted_agg_ref(rows, w)
        step = self._unravel()(flat)

        strategy = getattr(self.algo, "strategy", AggregationStrategy.MODEL)
        if strategy is AggregationStrategy.GRADIENT:
            new_global = jax.tree_util.tree_map(
                lambda w, s: w - self.hp.eta_g * s, self.global_params, step)
        else:
            new_global = step
        return new_global, new_table

    def _fused_global(self, batch: List[PartialAggregate], new_table,
                      cids: np.ndarray, sims: np.ndarray):
        """FedQS global stage via ``_fused_partial_combine`` — flat global
        in/out (cached between fused rounds, like the flat service)."""
        tr = self._tracer
        t_stk = _time.perf_counter() if tr is not None else 0.0
        K, P = len(cids), len(batch)
        Kb = bucket_rows(K)
        Pb = max(8, 1 << (P - 1).bit_length())
        n = np.zeros(Kb, np.float32)
        n[:K] = np.concatenate([p.n_samples for p in batch])
        fb = np.zeros(Kb, np.float32)
        fb[:K] = (np.concatenate([p.feedback for p in batch])
                  & self.hp.use_feedback)
        cids_b = np.zeros(Kb, np.int64)
        cids_b[:K] = cids
        sims_b = np.ones(Kb, np.float32)
        sims_b[:K] = sims
        cf_b = np.ones(Kb, np.float32)  # pad rows carry cf = 1.0
        cf_b[:K] = np.concatenate([p.completed_or_ones() for p in batch])
        part_idx = np.repeat(np.arange(P), [p.n_members for p in batch])
        onehot = np.zeros((Pb, Kb), np.float32)
        onehot[part_idx, np.arange(K)] = 1.0
        inv_sum_w = np.zeros(Pb, np.float32)
        inv_sum_w[:P] = 1.0 / np.maximum(
            np.asarray([p.sum_w for p in batch], np.float32), 1e-12)
        rows = jnp.stack([p.sum_wx for p in batch])
        if Pb != P:
            rows = jnp.pad(rows, ((0, Pb - P), (0, 0)))
        if (self.global_params is self._flat_src
                and self._flat_cache is not None):
            flat_g = self._flat_cache
        else:
            flat_g, _ = ravel_pytree(self.global_params)
        if tr is not None:
            tr.record("stack", "serve", t_stk,
                      _time.perf_counter() - t_stk, round=self._span_round)
        strategy = getattr(self.algo, "strategy", AggregationStrategy.MODEL)
        new_flat = _fused_partial_combine(
            rows, new_table.counts, new_table.sims, cids_b, sims_b, n, fb,
            cf_b, jnp.float32(K), onehot, inv_sum_w, flat_g,
            jnp.float32(self.hp.eta_g), jnp.float32(self.hp.ratio_clip),
            n_clients=self.n_clients,
            grad=strategy is AggregationStrategy.GRADIENT)
        self._pending_flat = new_flat
        return self._unravel()(new_flat), new_table

    # ------------------------------------------------------------ checkpoint
    def save(self, path: str) -> None:
        from repro.checkpoint.ckpt import save_hier_state

        self.join()
        save_hier_state(path, self)

    def restore(self, path: str) -> None:
        from repro.checkpoint.ckpt import load_hier_state

        self.join()
        load_hier_state(path, self)

    # ------------------------------------------------------------------ misc
    def describe(self) -> str:
        return (f"{self.topology.describe()} "
                f"edges={len(self.edges)} regions={len(self.regions)} "
                f"trigger={self.trigger.describe()}")


def make_aggregation_service(
    algo: Algorithm,
    hp: FedQSHyperParams,
    init_params: Params,
    n_clients: int,
    *,
    topology=None,
    trigger: Optional[TriggerPolicy] = None,
    context=None,
    speeds: Optional[np.ndarray] = None,
    label_probs: Optional[np.ndarray] = None,
    batched: bool = False,
    **kw,
) -> StreamingAggregator:
    """The one server-construction path the engines share: a flat
    ``StreamingAggregator``, or — when ``topology`` parses to a
    ``Topology`` — the tiered plane.  A topology given as a *spec
    string* gets its client→edge assignment derived from the sampled
    population (``speeds``, and ``label_probs`` when the caller has
    them); an explicit ``Topology`` instance keeps whatever wiring the
    caller built (handcrafted maps are never silently overwritten).
    ``batched`` applies to the flat service only; the hierarchy always
    reduces stacked rows."""
    from .topology import Topology, parse_topology

    hand_wired = isinstance(topology, Topology)
    topo = parse_topology(topology, n_clients)
    if topo is None:
        return StreamingAggregator(
            algo, hp, init_params, n_clients,
            trigger=trigger, context=context, speeds=speeds,
            batched=batched, **kw,
        )
    if speeds is not None and not hand_wired:
        topo = topo.with_population(speeds, label_probs)
    return HierarchicalService(
        algo, hp, init_params, n_clients, topo,
        trigger=trigger, context=context, speeds=speeds, **kw,
    )
