"""Hierarchical aggregation plane: tiered edge → regional → global SAFL.

One flat aggregation buffer stops scaling long before the ROADMAP's
millions of clients — every update contends on a single trigger and the
global tier sees the full staleness dispersion of the population.  This
package tiers the plane (CSAFL, arXiv:2104.08184; SEAFL,
arXiv:2503.05755): clients report to **edge** aggregators, edges to
**regional** aggregators, regions to the global tier, and every link
upward carries a ``PartialAggregate`` — one fp32 [D] vector plus scalar
per-member metadata — instead of raw updates.  Tier buffers reduce
through the fused ``segment_agg`` Pallas kernel (all edges of a region
in one VMEM pass) and int8 edges through ``dequant_agg``.

See docs/HIERARCHY.md for the topology grammar, the staleness/weighting
semantics of partials, and the kernel diagram.
"""
from .partial import MemberRef, MemberView, PartialAggregate, materialize, merge
from .service import HierarchicalService, make_aggregation_service
from .tier import EdgeAggregator, RegionAggregator, TierAggregator
from .topology import Topology, parse_topology

__all__ = [
    "EdgeAggregator",
    "HierarchicalService",
    "MemberRef",
    "MemberView",
    "PartialAggregate",
    "RegionAggregator",
    "TierAggregator",
    "Topology",
    "make_aggregation_service",
    "materialize",
    "merge",
    "parse_topology",
]
