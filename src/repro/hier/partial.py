"""The tier wire format: partial aggregates and the member view.

A ``PartialAggregate`` is what one tier node forwards upward when its
trigger fires: the weighted tensor sum of its buffer (Σw·x over a flat
fp32 [D] vector, with w = per-update sample counts) plus Σw and the
per-member *metadata* — cids, sample counts, similarities, feedback
flags, fetch rounds.  The metadata is a few scalars per member, so a
partial costs one [D] vector on the wire no matter how many client
updates it folds; the global tier still updates the aggregation status
table (Eq. 1/2) and computes Mod-3 weights against exact per-member
facts.

Partials are **associative**: merging two partials is an elementwise add
of the tensor sums and a concatenation of the metadata, so a region can
fold its edges' partials into one regional partial without changing the
global result — the algebraic property the whole plane rests on.

The tensor sum may be **lazy**: an edge fire can freeze its member rows
instead of reducing them immediately, and ``materialize`` batches every
lazy partial in a buffer through a single ``segment_agg`` kernel launch
(segment id = partial index) — one launch reduces all edges of a region.

``MemberView`` presents a buffer of partials to a ``TriggerPolicy`` as
the flat sequence of member updates it aggregates, so K-buffer / quorum
semantics keep counting *client updates* (and distinct client ids), not
partial envelopes.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import (
    ingest_segment_agg_auto_op,
    ingest_segment_agg_op,
    segment_agg_auto_op,
    segment_agg_op,
)


@dataclass
class MemberRef:
    """Lightweight per-member record (what triggers and round metrics
    read); mirrors the metadata surface of ``repro.core.types.Update``."""

    cid: int
    n_samples: int
    stale_round: int
    similarity: float
    feedback: bool
    completed_fraction: float = 1.0


@dataclass
class PartialAggregate:
    """One tier node's aggregated contribution (see module docstring).

    ``sum_wx`` is Σ_i n_i·x_i over the members (x = the strategy payload:
    delta for GRADIENT, params for MODEL), ``sum_w`` = Σ_i n_i.  Exactly
    one tensor form is populated: ``sum_wx`` materialized, or frozen
    member rows for a later batched reduction — dense f32
    (``rows``/``row_weights``) or still-quantized int8
    (``qrows``/``qscales``/``row_weights``, the fused-ingestion edge:
    the int8 bytes are deferred too, and dequantization happens inside
    the one ``ingest_segment_agg`` launch that reduces the whole fire).
    """

    tier: str                     # "edge" | "region"
    node_id: int
    sum_w: float
    cids: np.ndarray              # i64[M]
    n_samples: np.ndarray         # i64[M]
    sims: np.ndarray              # f32[M]
    feedback: np.ndarray          # bool[M]
    stale_rounds: np.ndarray      # i64[M]
    # per-member completed_fraction (partial local work); None = all 1.0 —
    # the legacy wire format, kept so old checkpoints restore unchanged
    completed: Optional[np.ndarray] = None  # f32[M]
    fired_at: float = 0.0
    sum_wx: Optional[jnp.ndarray] = None          # f32[D], materialized
    rows: Optional[jnp.ndarray] = field(default=None, repr=False)  # f32[M, D]
    row_weights: Optional[jnp.ndarray] = None     # f32[M]
    qrows: Optional[jnp.ndarray] = field(default=None, repr=False)  # i8[M, Dp]
    qscales: Optional[jnp.ndarray] = field(default=None, repr=False)  # f32[M, nc]
    chunk: int = 0                # int8 scale granularity (0 = not quantized)
    enc_d: int = 0                # decoded length of a qrows row

    @property
    def n_members(self) -> int:
        return len(self.cids)

    @property
    def pending(self) -> bool:
        return self.sum_wx is None

    def max_staleness(self, current_round: int) -> int:
        if not len(self.stale_rounds):
            return 0
        return int(current_round - int(self.stale_rounds.min()))

    def completed_or_ones(self) -> np.ndarray:
        if self.completed is None:
            return np.ones(len(self.cids), np.float32)
        return self.completed

    def members(self) -> List[MemberRef]:
        return [
            MemberRef(int(c), int(n), int(t), float(s), bool(f), float(cf))
            for c, n, t, s, f, cf in zip(self.cids, self.n_samples,
                                         self.stale_rounds, self.sims,
                                         self.feedback,
                                         self.completed_or_ones())
        ]

    def materialized(self) -> jnp.ndarray:
        """This partial's Σw·x, reducing the frozen rows on demand (the
        single-partial path; buffers go through ``materialize``)."""
        if self.sum_wx is None:
            materialize([self])
        return self.sum_wx


@jax.jit
def _weighted_row_sum(rows: jnp.ndarray, weights: jnp.ndarray) -> jnp.ndarray:
    return jnp.einsum("k,kd->d", weights, rows)


@jax.jit
def _dequant_row_sum(q: jnp.ndarray, scales: jnp.ndarray,
                     weights: jnp.ndarray) -> jnp.ndarray:
    K, Dp = q.shape
    nc = scales.shape[1]
    x = (q.astype(jnp.float32).reshape(K, nc, Dp // nc)
         * scales[:, :, None]).reshape(K, Dp)
    return jnp.einsum("k,kd->d", weights, x)


def _materialize_quant(lazy: Sequence[PartialAggregate], *,
                       use_kernel: Optional[bool]) -> None:
    """Reduce int8-lazy partials (fused edges defer even the dequantize).

    On TPU every buffer sharing one (chunk, row-width) layout reduces in
    ONE ``ingest_segment_agg`` launch with ``fb = 0, normalize=False`` —
    the weight fold then degenerates to exactly w = row_weights, so this
    is ``dequant_agg`` per segment; off-TPU each buffer takes a jitted
    dequantize-einsum (same flops argument as the dense path)."""
    if not lazy:
        return
    if use_kernel is None and jax.default_backend() != "tpu":
        for p in lazy:
            p.sum_wx = _dequant_row_sum(p.qrows, p.qscales,
                                        p.row_weights)[:p.enc_d]
            p.qrows = p.qscales = p.row_weights = None
        return
    by_layout = {}
    for p in lazy:
        by_layout.setdefault((p.chunk, p.qrows.shape[1]), []).append(p)
    for (chunk, _), group in by_layout.items():
        q = jnp.concatenate([p.qrows for p in group], axis=0)
        scales = jnp.concatenate([p.qscales for p in group], axis=0)
        weights = jnp.concatenate([p.row_weights for p in group])
        seg = np.repeat(np.arange(len(group), dtype=np.int32),
                        [p.qrows.shape[0] for p in group])
        K = q.shape[0]
        bucket = max(8, 1 << (K - 1).bit_length())
        if bucket != K:
            q = jnp.pad(q, ((0, bucket - K), (0, 0)))
            scales = jnp.pad(scales, ((0, bucket - K), (0, 0)))
            weights = jnp.pad(weights, (0, bucket - K))
            seg = np.pad(seg, (0, bucket - K))
        zeros = jnp.zeros_like(weights)
        G = max(8, 1 << (len(group) - 1).bit_length())
        if use_kernel is None:     # auto on TPU: the compiled fused kernel
            op = ingest_segment_agg_auto_op
        elif use_kernel:           # force the kernel body (interpreted off-TPU)
            op = ingest_segment_agg_op
        else:
            from repro.kernels.ref import ingest_segment_agg_ref

            def op(*a, chunk=0, **kw):  # the oracle needs no chunk layout
                return ingest_segment_agg_ref(*a, **kw)
        sums = op(q, scales, jnp.asarray(seg), weights, zeros, zeros, zeros,
                  num_segments=G, chunk=chunk, n_clients=1, normalize=False)
        for j, p in enumerate(group):
            p.sum_wx = sums[j][:p.enc_d]
            p.qrows = p.qscales = p.row_weights = None


def materialize(partials: Sequence[PartialAggregate], *,
                use_kernel: Optional[bool] = None) -> None:
    """Reduce every lazy partial's frozen rows and store the results in
    place.

    On TPU (or with ``use_kernel=True``) all lazy buffers reduce in ONE
    ``segment_agg`` kernel launch — segment id = partial index, one
    [ΣM, D] VMEM pass instead of one launch per edge; this is the fused
    path the hierarchy exists for.  Int8-lazy buffers (fused edges) take
    the analogous ``ingest_segment_agg`` launch instead, dequantizing in
    VMEM during the reduce.  Off-TPU the auto path reduces each buffer
    with a jitted einsum instead: interpret-mode Pallas and the one-hot
    matmul oracle both do G× the flops of the plain reductions, which is
    the wrong trade on a host simulating thousands of clients.
    """
    _materialize_quant([p for p in partials if p.pending and p.qrows is not None],
                       use_kernel=use_kernel)
    lazy = [p for p in partials if p.pending]
    if not lazy:
        return
    if use_kernel is None and jax.default_backend() != "tpu":
        for p in lazy:
            p.sum_wx = _weighted_row_sum(p.rows, p.row_weights)
            p.rows = p.row_weights = None
        return
    rows = jnp.concatenate([p.rows for p in lazy], axis=0)
    weights = jnp.concatenate([p.row_weights for p in lazy])
    seg = np.repeat(np.arange(len(lazy), dtype=np.int32),
                    [p.rows.shape[0] for p in lazy])
    # bucket-pad the row axis: the frozen member count varies fire to
    # fire (time-window triggers, flush tails) and the jitted kernel
    # must not recompile per shape — zero-weight pad rows contribute 0
    K = rows.shape[0]
    bucket = max(8, 1 << (K - 1).bit_length())
    if bucket != K:
        rows = jnp.pad(rows, ((0, bucket - K), (0, 0)))
        weights = jnp.pad(weights, (0, bucket - K))
        seg = np.pad(seg, (0, bucket - K))
    seg = jnp.asarray(seg)
    # bucket the (static) segment count too — it is the kernel's output
    # shape, and a varying lazy-partial count per fire would otherwise
    # still recompile; the surplus groups reduce nothing and are dropped
    G = max(8, 1 << (len(lazy) - 1).bit_length())
    if use_kernel is None:     # auto on TPU: the compiled segment kernel
        sums = segment_agg_auto_op(rows, weights, seg, num_segments=G)
    elif use_kernel:           # force the kernel body (interpreted off-TPU)
        sums = segment_agg_op(rows, weights, seg, num_segments=G)
    else:
        from repro.kernels.ref import segment_agg_ref

        sums = segment_agg_ref(rows, weights, seg, G)
    for j, p in enumerate(lazy):
        p.sum_wx = sums[j]
        p.rows = p.row_weights = None


def merge(partials: Sequence[PartialAggregate], *, tier: str, node_id: int,
          fired_at: float, use_kernel: Optional[bool] = None) -> PartialAggregate:
    """Fold a buffer of partials into one (the regional tier's fire):
    tensor sums add, metadata concatenates — exactly associative."""
    if not partials:
        raise ValueError("cannot merge an empty partial buffer")
    materialize(partials, use_kernel=use_kernel)
    stack = jnp.stack([p.sum_wx for p in partials])
    # keep the legacy None form unless some member actually reported
    # partial work — associativity and old-checkpoint parity both hold
    completed = None
    if any(p.completed is not None for p in partials):
        completed = np.concatenate([p.completed_or_ones() for p in partials])
    return PartialAggregate(
        tier=tier,
        node_id=node_id,
        sum_w=float(sum(p.sum_w for p in partials)),
        cids=np.concatenate([p.cids for p in partials]),
        n_samples=np.concatenate([p.n_samples for p in partials]),
        sims=np.concatenate([p.sims for p in partials]),
        feedback=np.concatenate([p.feedback for p in partials]),
        stale_rounds=np.concatenate([p.stale_rounds for p in partials]),
        completed=completed,
        fired_at=fired_at,
        sum_wx=jnp.sum(stack, axis=0),
    )


class MemberView(Sequence):
    """A buffer of partials viewed as its flat member sequence (len =
    total member updates; items are ``MemberRef``), so any
    ``TriggerPolicy`` written against ``Sequence[Update]`` — K-buffer,
    time-window, quorum — applies unchanged at the upper tiers.

    ``n`` lets a caller that already tracks the member count (the
    hierarchical service's running counter) skip the per-partial sum —
    the default K-buffer trigger then costs O(1) per submit instead of
    O(#partials)."""

    def __init__(self, partials: Sequence[PartialAggregate],
                 n: Optional[int] = None):
        self._partials = partials
        self._n = n

    def __len__(self) -> int:
        if self._n is None:
            self._n = sum(p.n_members for p in self._partials)
        return self._n

    def __iter__(self):
        # generator over the metadata arrays — no per-partial list
        # materialization on the trigger-evaluation hot path
        for p in self._partials:
            for c, n, t, s, f, cf in zip(p.cids, p.n_samples, p.stale_rounds,
                                         p.sims, p.feedback,
                                         p.completed_or_ones()):
                yield MemberRef(int(c), int(n), int(t), float(s), bool(f),
                                float(cf))

    def __getitem__(self, idx):
        if isinstance(idx, slice):
            return list(self)[idx]
        n = len(self)
        if idx < 0:
            idx += n
        if not 0 <= idx < n:
            raise IndexError(idx)
        for p in self._partials:
            if idx < p.n_members:
                return p.members()[idx]
            idx -= p.n_members
        raise IndexError(idx)  # unreachable
