"""Tier topology: which edge aggregates each client, which region each edge.

A ``Topology`` describes the static wiring of the hierarchical
aggregation plane (docs/HIERARCHY.md):

    clients ──▶ edge aggregators ──▶ regional aggregators ──▶ global

Spec grammar (``parse_topology`` / ``Topology.from_spec``)::

    spec := "flat" | "hier:<edges>" | "hier:<edges>x<regions>"

    "flat"        no hierarchy (callers keep the flat StreamingAggregator)
    "hier:64"     2-tier: 64 edges reporting straight to the global tier
    "hier:64x16"  3-tier: 64 edges grouped into 16 regions (fan-in 4),
                  regions report to the global tier

Edges map onto regions contiguously (edge e → region e·R//E), so region
membership follows edge ordering.  Client → edge assignment defaults to
round-robin; ``with_population`` derives a realistic assignment from a
scenario population instead: clients are banded by speed into regions
(the CSAFL grouping-by-delay setting — an edge site serves devices of
similar latency), and within each region's band clients are clustered by
dominant label so label-skew neighbourhoods land on the same edge (the
geo-correlated non-IID case the hierarchy papers model).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import numpy as np


@dataclass
class Topology:
    """Static tier wiring.  ``client_edge[i]`` is client i's edge id;
    ``edge_region[e]`` is edge e's region id (empty ⇒ 2-tier: edges
    report straight to the global aggregator)."""

    n_clients: int
    n_edges: int
    n_regions: int                       # 0 ⇒ no regional tier
    client_edge: np.ndarray              # i64[N]
    edge_region: np.ndarray = field(default_factory=lambda: np.zeros(0, np.int64))
    spec: str = ""

    def __post_init__(self):
        if self.n_edges < 1 or self.n_edges > self.n_clients:
            raise ValueError(
                f"need 1 <= edges <= clients, got {self.n_edges} edges "
                f"for {self.n_clients} clients"
            )
        if self.n_regions < 0 or self.n_regions > self.n_edges:
            raise ValueError(
                f"need 0 <= regions <= edges, got {self.n_regions} regions "
                f"for {self.n_edges} edges"
            )
        self.client_edge = np.asarray(self.client_edge, np.int64)
        if self.client_edge.shape != (self.n_clients,):
            raise ValueError(
                f"client_edge must be [{self.n_clients}], got "
                f"{self.client_edge.shape}"
            )
        if len(self.client_edge) and (
            self.client_edge.min() < 0 or self.client_edge.max() >= self.n_edges
        ):
            raise ValueError(
                f"client_edge ids must lie in [0, {self.n_edges}); got "
                f"range [{self.client_edge.min()}, {self.client_edge.max()}]"
            )
        if self.n_regions and len(self.edge_region) == 0:
            self.edge_region = _contiguous_regions(self.n_edges, self.n_regions)
        if self.n_regions:
            self.edge_region = np.asarray(self.edge_region, np.int64)
            if self.edge_region.shape != (self.n_edges,):
                raise ValueError(
                    f"edge_region must be [{self.n_edges}], got "
                    f"{self.edge_region.shape}"
                )
            present = np.unique(self.edge_region)
            if (present < 0).any() or (present >= self.n_regions).any() or (
                len(present) != self.n_regions
            ):
                raise ValueError(
                    f"edge_region must cover every region in "
                    f"[0, {self.n_regions}) with at least one edge"
                )
        if not self.spec:
            self.spec = (f"hier:{self.n_edges}x{self.n_regions}"
                         if self.n_regions else f"hier:{self.n_edges}")

    # -------------------------------------------------------------- wiring
    @property
    def tiers(self) -> int:
        """Aggregation tiers above the clients (2 = edge→global)."""
        return 3 if self.n_regions else 2

    def edge_of(self, cid: int) -> int:
        return int(self.client_edge[cid])

    def region_of(self, edge: int) -> int:
        if not self.n_regions:
            raise ValueError("2-tier topology has no regional tier")
        return int(self.edge_region[edge])

    def edges_in_region(self, region: int) -> np.ndarray:
        return np.flatnonzero(self.edge_region == region)

    def describe(self) -> str:
        return self.spec

    # ----------------------------------------------------------- factories
    @classmethod
    def from_spec(cls, spec: str, n_clients: int) -> "Topology":
        """Parse the spec grammar with the default round-robin assignment."""
        n_edges, n_regions = _parse_spec(spec)
        return cls(
            n_clients=int(n_clients),
            n_edges=n_edges,
            n_regions=n_regions,
            client_edge=np.arange(int(n_clients), dtype=np.int64) % n_edges,
            spec=spec.strip(),
        )

    def with_population(self, speeds: np.ndarray,
                        label_probs: Optional[np.ndarray] = None) -> "Topology":
        """Re-derive the client→edge assignment from a sampled population.

        Clients are sorted by speed and banded contiguously into regions
        (2-tier: into edges), so slow and fast devices aggregate at
        different sites; with ``label_probs`` the clients inside each
        region band are re-ordered by dominant label before splitting
        into that region's edges, co-locating label-skew clusters.
        NaN/inf speeds (dead clients) sort last and keep an assignment —
        a revived client reports to a real edge.
        """
        speeds = np.asarray(speeds, np.float64)
        if speeds.shape != (self.n_clients,):
            raise ValueError(
                f"speeds must be [{self.n_clients}], got {speeds.shape}"
            )
        order = np.argsort(np.nan_to_num(speeds, nan=np.inf, posinf=np.inf),
                           kind="stable")
        assignment = np.zeros(self.n_clients, np.int64)
        n_bands = self.n_regions if self.n_regions else self.n_edges
        bands = np.array_split(order, n_bands)
        if not self.n_regions:
            for e, members in enumerate(bands):
                assignment[members] = e
        else:
            for r, members in enumerate(bands):
                # the region's actual edge ids — correct for any
                # edge→region map, contiguous or not
                region_edges = np.flatnonzero(self.edge_region == r)
                if label_probs is not None and len(members):
                    dom = np.argmax(np.asarray(label_probs)[members], axis=1)
                    members = members[np.argsort(dom, kind="stable")]
                chunks = np.array_split(members, len(region_edges))
                for eid, chunk in zip(region_edges, chunks):
                    assignment[chunk] = eid
        return Topology(
            n_clients=self.n_clients,
            n_edges=self.n_edges,
            n_regions=self.n_regions,
            client_edge=assignment,
            edge_region=self.edge_region,
            spec=self.spec,
        )


def _contiguous_regions(n_edges: int, n_regions: int) -> np.ndarray:
    """Edge → region map: contiguous, balanced (edge e → region e·R//E)."""
    return (np.arange(n_edges, dtype=np.int64) * n_regions) // n_edges


def _parse_spec(spec: str):
    s = str(spec).strip().lower()
    if not s.startswith("hier:"):
        raise ValueError(
            f"bad topology spec {spec!r}: expected 'hier:<edges>' or "
            "'hier:<edges>x<regions>' (use 'flat' / None for no hierarchy)"
        )
    body = s[len("hier:"):]
    try:
        if "x" in body:
            e, r = body.split("x", 1)
            return int(e), int(r)
        return int(body), 0
    except ValueError:
        raise ValueError(
            f"bad topology spec {spec!r}: fan-outs must be integers, "
            "e.g. 'hier:64' or 'hier:64x16'"
        ) from None


def parse_topology(spec, n_clients: int) -> Optional[Topology]:
    """CLI-facing parse: ``None``/``"flat"`` → no hierarchy; a ``Topology``
    passes through; anything else goes through the spec grammar."""
    if spec is None or (isinstance(spec, str) and spec.strip().lower() in ("", "flat", "none")):
        return None
    if isinstance(spec, Topology):
        return spec
    return Topology.from_spec(spec, n_clients)
