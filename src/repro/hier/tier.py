"""Tier aggregator nodes: the edge and regional stages of the plane.

Every node owns a buffer and a ``TriggerPolicy`` from
``repro.serve.triggers`` — the same K-buffer / time-window / quorum
policies the flat service uses, evaluated against the node's own buffer
(regions see their buffered partials through ``MemberView`` so trigger
semantics keep counting client updates).  A firing node emits one
``PartialAggregate`` upward and re-arms, exactly the double-buffer
discipline of ``StreamingAggregator``.

**Edge nodes** ingest raw client ``Update``s (dense or compressed):

* a fully-int8 buffer reduces *eagerly* through the fused ``dequant_agg``
  kernel — the quantized payloads are decoded exactly once, at the edge,
  and only the fp32 Σw·x crosses the tier link upward;
* other buffers (dense pytrees, raw-f32 top-k, mixed wire formats)
  freeze their member rows lazily: the parent tier batches every frozen
  edge of a fire through ONE ``segment_agg`` launch
  (``repro.hier.partial.materialize``).

**Region nodes** ingest edge partials and fold them into one regional
partial per fire (``merge`` — associative, so the global aggregate is
independent of how many tiers sat in between).
"""
from __future__ import annotations

import time as _time
from typing import List, Optional

import jax.numpy as jnp
import numpy as np

from repro.compress.codec import decode, is_compressed, ravel_flat
from repro.core.types import AggregationStrategy, Update
from repro.kernels import dequant_agg_auto_op, dequant_agg_op
from repro.serve.batched import fused_eligible, stack_trees
from repro.serve.triggers import TriggerPolicy

from .partial import MemberView, PartialAggregate, merge


class TierAggregator:
    """Common tier-node machinery: buffer + trigger + fire bookkeeping."""

    tier = "base"
    # span tracer (repro.telemetry.trace), attached by the owning
    # HierarchicalService when its hub carries one; None costs nothing
    tracer = None

    def __init__(self, node_id: int, trigger: TriggerPolicy):
        self.node_id = int(node_id)
        self.trigger = trigger
        self.buffer: List = []
        self.fires = 0

    @property
    def pending(self) -> int:
        """Client updates currently buffered at this node."""
        return len(self.buffer)

    def _trigger_view(self):
        return self.buffer

    def submit(self, item, now: float) -> Optional[PartialAggregate]:
        """Buffer one item; returns the emitted partial if the node fired."""
        self.buffer.append(item)
        if self.trigger.should_fire(self._trigger_view(), now):
            return self._fire(now)
        return None

    def flush(self, now: float) -> Optional[PartialAggregate]:
        """Force-emit whatever is buffered (end of stream / checkpoint)."""
        return self._fire(now) if self.buffer else None

    def _fire(self, now: float) -> PartialAggregate:
        batch, self.buffer = self.buffer, []
        self.trigger.arm(now)
        self.fires += 1
        tr = self.tracer
        if tr is None:
            return self._reduce(batch, now)
        t0 = _time.perf_counter()
        out = self._reduce(batch, now)
        tr.record("tier-fire", "hier", t0, _time.perf_counter() - t0,
                  args={"tier": self.tier, "node": self.node_id,
                        "members": len(batch)})
        return out

    def _reduce(self, batch, now: float) -> PartialAggregate:
        raise NotImplementedError

    def describe(self) -> str:
        return f"{self.tier}[{self.node_id}]({self.trigger.describe()})"


class EdgeAggregator(TierAggregator):
    """Leaf tier: raw client updates in, one partial aggregate out."""

    tier = "edge"

    def __init__(self, node_id: int, trigger: TriggerPolicy, *,
                 strategy: AggregationStrategy,
                 use_kernel: Optional[bool] = None,
                 fused: bool = False):
        super().__init__(node_id, trigger)
        self.strategy = strategy
        self.use_kernel = use_kernel
        # fused ingestion: int8 buffers freeze *quantized* — the bytes
        # stay int8 until the parent's single ingest_segment_agg launch
        # dequantizes them in VMEM during the reduce
        self.fused = bool(fused)

    def _payload(self, u):
        if self.strategy is AggregationStrategy.GRADIENT:
            return u.delta
        return u.params

    def _reduce(self, batch: List[Update], now: float) -> PartialAggregate:
        weights = np.asarray([u.n_samples for u in batch], np.float32)
        cfs = np.asarray(
            [float(getattr(u, "completed_fraction", 1.0)) for u in batch],
            np.float32)
        has_partial = bool((cfs != 1.0).any())
        if has_partial:
            # partial local work scales the member's row weight: the edge
            # reduces with w = n_i·cf_i, so Σw·x and Σw both carry the
            # attenuation upward (docs/ROBUSTNESS.md); all-complete
            # batches keep the legacy arrays bit-identical
            weights = weights * cfs
        partial = PartialAggregate(
            tier=self.tier,
            node_id=self.node_id,
            sum_w=float(weights.sum()),
            cids=np.asarray([u.cid for u in batch], np.int64),
            n_samples=np.asarray([u.n_samples for u in batch], np.int64),
            sims=np.asarray([u.similarity for u in batch], np.float32),
            feedback=np.asarray([bool(u.feedback) for u in batch], bool),
            stale_rounds=np.asarray([u.stale_round for u in batch], np.int64),
            completed=cfs if has_partial else None,
            fired_at=now,
        )
        payloads = [self._payload(u) for u in batch]
        w = jnp.asarray(weights)
        if all(is_compressed(u) for u in batch) and fused_eligible(payloads):
            # every payload int8 with one shared layout: fuse the decode
            # into the reduction — the edge is the only place the int8
            # bytes are ever touched, fp32 partials go upward
            from repro.serve.batched import stack_encoded

            q, scales = stack_encoded(payloads)
            chunk, d = payloads[0].chunk, payloads[0].d
            if self.fused:
                partial.qrows, partial.qscales = q, scales
                partial.chunk, partial.enc_d = chunk, d
                partial.row_weights = w
                return partial
            if self.use_kernel is None:
                flat = dequant_agg_auto_op(q, scales, w, chunk=chunk)
            elif self.use_kernel:
                flat = dequant_agg_op(q, scales, w, chunk=chunk)
            else:
                from repro.kernels.ref import dequant_agg_ref

                flat = dequant_agg_ref(q, scales, w)
            partial.sum_wx = flat[:d]
            return partial
        # dense / raw-f32 / mixed buffers: decode once per edge into flat
        # fp32 rows and defer the Σw·x — the parent tier reduces every
        # frozen edge of a fire in one segment_agg launch
        if any(is_compressed(u) for u in batch):
            partial.rows = jnp.stack([
                decode(self._payload(u)) if is_compressed(u)
                else ravel_flat(self._payload(u))
                for u in batch
            ])
        else:
            # the cached-astype stacked ravel of the batched service
            partial.rows, _ = stack_trees(payloads)
        partial.row_weights = w
        return partial


class RegionAggregator(TierAggregator):
    """Middle tier: edge partials in, one merged regional partial out."""

    tier = "region"

    def __init__(self, node_id: int, trigger: TriggerPolicy, *,
                 use_kernel: Optional[bool] = None):
        super().__init__(node_id, trigger)
        self.use_kernel = use_kernel

    @property
    def pending(self) -> int:
        return sum(p.n_members for p in self.buffer)

    def _trigger_view(self):
        # triggers count client updates, not partial envelopes
        return MemberView(self.buffer)

    def _reduce(self, batch: List[PartialAggregate], now: float) -> PartialAggregate:
        return merge(batch, tier=self.tier, node_id=self.node_id,
                     fired_at=now, use_kernel=self.use_kernel)
