"""Small task models for the SAFL simulation (paper §5.1 analogues).

* ConvNet  — residual-block CNN standing in for ResNet-18 on CIFAR-like data;
* LSTM     — char-level LSTM for the Shakespeare-like task;
* MLP(FCN) — two dense layers + dropout-free eval for the Adult-like task.

Pure functional JAX (init/apply pairs) so params are plain pytrees — the
whole FedQS machinery (similarity, weighted aggregation, clipping) treats
them uniformly with the big architectures in ``repro.models.transformer``.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.safl import ModelSpec


def _dense_init(key, n_in, n_out, scale=None):
    scale = scale or (1.0 / np.sqrt(n_in))
    wk, bk = jax.random.split(key)
    return {
        "w": jax.random.normal(wk, (n_in, n_out), jnp.float32) * scale,
        "b": jnp.zeros((n_out,), jnp.float32),
    }


def _dense(p, x):
    return x @ p["w"] + p["b"]


# --------------------------------------------------------------------------
# ConvNet (CV)
# --------------------------------------------------------------------------
def _conv_init(key, cin, cout, k=3):
    scale = 1.0 / np.sqrt(cin * k * k)
    return {"w": jax.random.normal(key, (k, k, cin, cout), jnp.float32) * scale,
            "b": jnp.zeros((cout,), jnp.float32)}


def _conv(p, x, stride=1):
    y = jax.lax.conv_general_dilated(
        x, p["w"], (stride, stride), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
    )
    return y + p["b"]


def cnn_init(key, n_classes=10, width=16):
    ks = jax.random.split(key, 6)
    return {
        "stem": _conv_init(ks[0], 3, width),
        "b1a": _conv_init(ks[1], width, width),
        "b1b": _conv_init(ks[2], width, width),
        "down": _conv_init(ks[3], width, 2 * width),
        "b2a": _conv_init(ks[4], 2 * width, 2 * width),
        "head": _dense_init(ks[5], 2 * width, n_classes),
    }


def cnn_apply(params, x):
    h = jax.nn.relu(_conv(params["stem"], x))
    r = jax.nn.relu(_conv(params["b1a"], h))
    h = jax.nn.relu(h + _conv(params["b1b"], r))       # residual block
    h = jax.nn.relu(_conv(params["down"], h, stride=2))
    h = jax.nn.relu(h + _conv(params["b2a"], h))       # residual block
    h = jnp.mean(h, axis=(1, 2))                        # global avg pool
    return _dense(params["head"], h)


# --------------------------------------------------------------------------
# LSTM (NLP)
# --------------------------------------------------------------------------
def lstm_init(key, vocab=80, embed=24, hidden=64):
    ks = jax.random.split(key, 4)
    return {
        "embed": jax.random.normal(ks[0], (vocab, embed), jnp.float32) * 0.1,
        "wx": jax.random.normal(ks[1], (embed, 4 * hidden), jnp.float32) / np.sqrt(embed),
        "wh": jax.random.normal(ks[2], (hidden, 4 * hidden), jnp.float32) / np.sqrt(hidden),
        "bias": jnp.zeros((4 * hidden,), jnp.float32),
        "head": _dense_init(ks[3], hidden, vocab),
    }


def lstm_apply(params, tokens):
    x = params["embed"][tokens]                        # [B, T, E]
    B = x.shape[0]
    H = params["wh"].shape[0]

    def step(carry, xt):
        h, c = carry
        gates = xt @ params["wx"] + h @ params["wh"] + params["bias"]
        i, f, g, o = jnp.split(gates, 4, axis=-1)
        c = jax.nn.sigmoid(f) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
        h = jax.nn.sigmoid(o) * jnp.tanh(c)
        return (h, c), None

    init = (jnp.zeros((B, H)), jnp.zeros((B, H)))
    (h, _), _ = jax.lax.scan(step, init, jnp.swapaxes(x, 0, 1))
    return _dense(params["head"], h)


# --------------------------------------------------------------------------
# FCN (RWD)
# --------------------------------------------------------------------------
def mlp_init(key, n_features=14, hidden=32, n_classes=2):
    ks = jax.random.split(key, 2)
    return {
        "l1": _dense_init(ks[0], n_features, hidden),
        "l2": _dense_init(ks[1], hidden, n_classes),
    }


def mlp_apply(params, x):
    return _dense(params["l2"], jax.nn.relu(_dense(params["l1"], x)))


# --------------------------------------------------------------------------
# spec factories
# --------------------------------------------------------------------------
def _make_spec(init_fn, apply_fn, batch_size, int_inputs=False) -> ModelSpec:
    def loss_fn(params, batch):
        logits = apply_fn(params, batch["x"])
        labels = batch["y"]
        logp = jax.nn.log_softmax(logits)
        return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=-1))

    @jax.jit
    def grad_fn(params, batch):
        return jax.grad(loss_fn)(params, batch)

    @jax.jit
    def _eval(params, x, y):
        logits = apply_fn(params, x)
        logp = jax.nn.log_softmax(logits)
        loss = -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=-1))
        acc = jnp.mean((jnp.argmax(logits, -1) == y).astype(jnp.float32))
        return loss, acc

    def eval_fn(params, x, y):
        loss, acc = _eval(params, jnp.asarray(x), jnp.asarray(y))
        return float(loss), float(acc)

    @jax.jit
    def _pred(params, x):
        return jnp.argmax(apply_fn(params, x), -1)

    def predict_fn(params, x):
        return np.asarray(_pred(params, jnp.asarray(x)))

    return ModelSpec(init=init_fn, grad_fn=grad_fn, eval_fn=eval_fn,
                     predict_fn=predict_fn, batch_size=batch_size)


def make_cnn_spec(n_classes=10, width=16, batch_size=32) -> ModelSpec:
    return _make_spec(functools.partial(cnn_init, n_classes=n_classes, width=width),
                      cnn_apply, batch_size)


def make_lstm_spec(vocab=80, embed=24, hidden=64, batch_size=32) -> ModelSpec:
    return _make_spec(functools.partial(lstm_init, vocab=vocab, embed=embed, hidden=hidden),
                      lstm_apply, batch_size, int_inputs=True)


def make_mlp_spec(n_features=14, hidden=32, n_classes=2, batch_size=32) -> ModelSpec:
    return _make_spec(functools.partial(mlp_init, n_features=n_features, hidden=hidden, n_classes=n_classes),
                      mlp_apply, batch_size)
