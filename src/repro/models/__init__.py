from .small import make_cnn_spec, make_lstm_spec, make_mlp_spec

__all__ = ["make_cnn_spec", "make_lstm_spec", "make_mlp_spec"]
