"""Composable decoder / encoder-decoder stack covering all 10 assigned
architectures (DESIGN §5).

A model is described by an ``ArchConfig``: an optional *prefix* of unrolled
layers, a scanned *super-block pattern* repeated ``n_repeats`` times (HLO
stays O(pattern), not O(depth) — compile-time critical, DESIGN §9), and an
optional unrolled *suffix*.  Layer kinds:

    attn        causal GQA self-attention (+ optional QKV bias / RoPE)
    local       sliding-window GQA self-attention (gemma3 locals)
    mla         DeepSeek-V3 multi-head latent attention
    attn_cross  self-attention + cross-attention (enc-dec decoder layers)
    cross       cross-attention only (llama-3.2-vision image layers)
    mamba       selective-SSM block (jamba)
    rwkv        RWKV6 time-mix (attention-free)

FFN kinds: ``dense`` (SwiGLU) and ``moe`` (capacity-based top-k).

Three entry points per config — ``train_loss`` (causal LM), ``prefill``
(logits + populated caches), ``decode_step`` (one token against caches).
Caches for attention layers are *ring buffers* of capacity
``min(max_seq, cache_cap)`` so the same code path serves full-context
decode and bounded-window long-context decode (DESIGN §5 skips table).
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import layers as L

Params = Any


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    arch_id: str
    family: str                       # dense|moe|ssm|hybrid|vlm|audio
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab: int
    # layout: tuples of (kind, ffn) pairs
    prefix: Tuple[Tuple[str, str], ...] = ()
    pattern: Tuple[Tuple[str, str], ...] = (("attn", "dense"),)
    n_repeats: int = 1
    suffix: Tuple[Tuple[str, str], ...] = ()
    # attention options
    qkv_bias: bool = False
    rope_theta: float = 1e4
    window: int = 1024                # sliding window for `local` layers
    global_cache_cap: int = 0         # 0 = unbounded full-attention cache
    # MLA dims (deepseek)
    q_lora_rank: int = 0
    kv_lora_rank: int = 512
    qk_rope_dim: int = 64
    qk_nope_dim: int = 128
    v_head_dim: int = 128
    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    expert_d_ff: int = 0
    capacity_factor: float = 1.25
    aux_loss_coef: float = 0.01
    # SSM
    ssm_state: int = 16
    ssm_conv: int = 4
    ssm_expand: int = 2
    # encoder (audio enc-dec)
    n_encoder_layers: int = 0
    # modality frontend stub (the one allowed stub): precomputed embeddings
    frontend: str = "none"            # none|audio|vision
    n_frontend_tokens: int = 0
    # MTP head (deepseek)
    mtp: bool = False
    # FL distributed mode (DESIGN §6): stacked per-client weights vs FSDP
    fl_mode: str = "stacked"
    # ---- §Perf hillclimb knobs (EXPERIMENTS.md §Perf) ----
    remat: bool = False          # jax.checkpoint each super-block (memory)
    mla_absorbed: bool = False   # absorbed MLA decode (skip k/v expansion)
    cache_cross_kv: bool = False  # cache cross-attn memory K/V at prefill
    embed_dshard: bool = False   # shard embedding on d_model (not vocab):
    #   token lookups stay shard-local instead of all-gathering the table
    row_parallel_out: bool = False  # Megatron pairing: down/out projections
    #   sharded on the INPUT dim (+psum) instead of gathering activations
    moe_data_dispatch: bool = False  # constrain MoE dispatch buffer to the
    #   expert ('data') axis so GSPMD all-to-alls tokens instead of
    #   all-gathering the stacked expert weights
    # source citation for the config
    source: str = ""

    @property
    def n_layers(self) -> int:
        return len(self.prefix) + len(self.pattern) * self.n_repeats + len(self.suffix)

    def param_count(self) -> int:
        """Analytic parameter count (used for 6·N·D roofline MODEL_FLOPS)."""
        shapes = jax.eval_shape(lambda k: init_params(self, k), jax.random.PRNGKey(0))
        return sum(int(math.prod(s.shape)) for s in jax.tree_util.tree_leaves(shapes))

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k + shared experts only)."""
        if self.n_experts == 0:
            return self.param_count()
        total = self.param_count()
        per_expert = 3 * self.d_model * self.expert_d_ff
        n_moe_layers = sum(
            1 for k, f in (self.prefix + self.pattern * self.n_repeats + self.suffix) if f == "moe"
        )
        inactive = n_moe_layers * (self.n_experts - self.top_k) * per_expert
        return total - inactive


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------
def _mix_init(cfg: ArchConfig, kind: str, key):
    if kind in ("attn", "local"):
        return L.gqa_init(key, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head, cfg.qkv_bias)
    if kind == "mla":
        return L.mla_init(key, cfg.d_model, cfg.n_heads, cfg)
    if kind == "cross":
        return L.gqa_init(key, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head, False)
    if kind == "attn_cross":
        k1, k2 = jax.random.split(key)
        return {
            "self": L.gqa_init(k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head, cfg.qkv_bias),
            "cross": L.gqa_init(k2, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head, False),
        }
    if kind == "mamba":
        return L.mamba_init(key, cfg.d_model, cfg.ssm_state, cfg.ssm_conv, cfg.ssm_expand)
    if kind == "rwkv":
        return L.rwkv6_init(key, cfg.d_model, cfg.n_heads)
    raise ValueError(f"unknown layer kind {kind!r}")


def _ffn_init(cfg: ArchConfig, ffn: str, key):
    if ffn == "dense":
        return L.swiglu_init(key, cfg.d_model, cfg.d_ff)
    if ffn == "moe":
        return L.moe_init(
            key, cfg.d_model, cfg.n_experts, cfg.expert_d_ff,
            cfg.n_shared_experts, cfg.expert_d_ff,
        )
    raise ValueError(f"unknown ffn kind {ffn!r}")


def _layer_init(cfg: ArchConfig, kind: str, ffn: str, key):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {"ln1": L.rmsnorm_init(cfg.d_model), "mix": _mix_init(cfg, kind, k1),
         "ln2": L.rmsnorm_init(cfg.d_model), "ffn": _ffn_init(cfg, ffn, k2)}
    if kind == "attn_cross":
        p["lnx"] = L.rmsnorm_init(cfg.d_model)
    return p


def _superblock_init(cfg: ArchConfig, key):
    ks = jax.random.split(key, len(cfg.pattern))
    return {f"l{i}": _layer_init(cfg, kind, ffn, ks[i])
            for i, (kind, ffn) in enumerate(cfg.pattern)}


def init_params(cfg: ArchConfig, key) -> Params:
    n_keys = 6 + len(cfg.prefix) + len(cfg.suffix)
    ks = list(jax.random.split(key, n_keys))
    p: Dict[str, Any] = {
        "embed": (jax.random.normal(ks[0], (cfg.vocab, cfg.d_model), jnp.float32)
                  * 0.02).astype(L.DTYPE),
        "final_norm": L.rmsnorm_init(cfg.d_model),
        "lm_head": L.dense_init(ks[1], cfg.d_model, cfg.vocab),
    }
    # scanned super-blocks: stacked along leading axis via vmap-init
    block_keys = jax.random.split(ks[2], cfg.n_repeats)
    p["blocks"] = jax.vmap(lambda k: _superblock_init(cfg, k))(block_keys)
    for i, (kind, ffn) in enumerate(cfg.prefix):
        p[f"pre{i}"] = _layer_init(cfg, kind, ffn, ks[3 + i])
    for i, (kind, ffn) in enumerate(cfg.suffix):
        p[f"suf{i}"] = _layer_init(cfg, kind, ffn, ks[3 + len(cfg.prefix) + i])
    if cfg.n_encoder_layers > 0:
        enc_keys = jax.random.split(ks[-3], cfg.n_encoder_layers)
        p["enc_blocks"] = jax.vmap(
            lambda k: _layer_init(cfg, "attn", "dense", k)
        )(enc_keys)
        p["enc_norm"] = L.rmsnorm_init(cfg.d_model)
    if cfg.frontend == "vision":
        # projector from stub patch embeddings into d_model (the ViT itself
        # is the allowed carve-out stub)
        p["vis_proj"] = L.dense_init(ks[-2], cfg.d_model, cfg.d_model)
    if cfg.mtp:
        k1, k2 = jax.random.split(ks[-1])
        p["mtp"] = {
            "proj": L.dense_init(k1, 2 * cfg.d_model, cfg.d_model),
            "layer": _layer_init(cfg, "attn", "dense", k2),
            "norm": L.rmsnorm_init(cfg.d_model),
        }
    return p


def abstract_params(cfg: ArchConfig):
    """ShapeDtypeStruct pytree — used by the dry-run (no allocation)."""
    return jax.eval_shape(lambda k: init_params(cfg, k), jax.random.PRNGKey(0))


# --------------------------------------------------------------------------
# forward (training / prefill)
# --------------------------------------------------------------------------
def _apply_mix(cfg, kind, p, h, positions, memory):
    """→ (mix_out, cache_payload).  Payload = what decode later needs:
    roped (k, v) for attention kinds, the compressed latent for MLA,
    final recurrent state for SSM kinds, () for cross (memory is static)."""
    if kind in ("attn", "local"):
        q, k, v = L.gqa_qkv(p, h, cfg.n_heads, cfg.n_kv_heads, cfg.d_head,
                            positions, cfg.rope_theta)
        out = (L.local_attention(q, k, v, cfg.window) if kind == "local"
               else L.causal_attention(q, k, v))
        return L.dense(p["wo"], out.reshape(*h.shape[:2], -1)), (k, v)
    if kind == "mla":
        q, k, v, latent = L.mla_qkv(p, h, cfg.n_heads, cfg, positions, cfg.rope_theta)
        out = L.causal_attention(q, k, v)
        return L.dense(p["wo"], out.reshape(*h.shape[:2], -1)), latent
    if kind == "cross":
        B, T, _ = h.shape
        q = L.dense(p["wq"], h).reshape(B, T, cfg.n_heads, cfg.d_head)
        mk = L.dense(p["wk"], memory).reshape(B, -1, cfg.n_kv_heads, cfg.d_head)
        mv = L.dense(p["wv"], memory).reshape(B, -1, cfg.n_kv_heads, cfg.d_head)
        out = L.cross_attention_core(q, mk, mv)
        return L.dense(p["wo"], out.reshape(B, T, -1)), (
            (mk, mv) if cfg.cache_cross_kv else ())
    if kind == "mamba":
        out, state = L.mamba_apply(p, h, cfg.ssm_state, return_state=True)
        return out, state
    if kind == "rwkv":
        out, state = L.rwkv6_apply(p, h, cfg.n_heads, return_state=True)
        return out, state
    raise ValueError(kind)


def _apply_ffn(cfg, ffn, p, h):
    if ffn == "dense":
        return L.swiglu(p, h), 0.0
    dispatch_spec = ("data", None, None) if cfg.moe_data_dispatch else None
    out, aux = L.moe_apply(p, h, cfg.top_k, cfg.capacity_factor,
                           dispatch_spec=dispatch_spec)
    return out, aux


def _apply_layer(cfg, kind, ffn, p, h, positions, memory):
    """→ (h, aux_loss, cache_payload)."""
    if kind == "attn_cross":
        B, T, _ = h.shape
        q, k, v = L.gqa_qkv(p["mix"]["self"], L.rmsnorm(p["ln1"], h),
                            cfg.n_heads, cfg.n_kv_heads, cfg.d_head,
                            positions, cfg.rope_theta)
        h = h + L.dense(p["mix"]["self"]["wo"],
                        L.causal_attention(q, k, v).reshape(B, T, -1))
        hx = L.rmsnorm(p["lnx"], h)
        cp = p["mix"]["cross"]
        q = L.dense(cp["wq"], hx).reshape(B, T, cfg.n_heads, cfg.d_head)
        mk = L.dense(cp["wk"], memory).reshape(B, -1, cfg.n_kv_heads, cfg.d_head)
        mv = L.dense(cp["wv"], memory).reshape(B, -1, cfg.n_kv_heads, cfg.d_head)
        h = h + L.dense(cp["wo"], L.cross_attention_core(q, mk, mv).reshape(B, T, -1))
        payload = (k, v, mk, mv) if cfg.cache_cross_kv else (k, v)
        aux = 0.0
    else:
        mix_out, payload = _apply_mix(cfg, kind, p["mix"], L.rmsnorm(p["ln1"], h),
                                      positions, memory)
        h = h + mix_out
        aux = 0.0
    ffn_out, aux_ffn = _apply_ffn(cfg, ffn, p["ffn"], L.rmsnorm(p["ln2"], h))
    return h + ffn_out, aux + aux_ffn, payload


def _encoder(cfg: ArchConfig, params, src_embeds):
    """Bidirectional encoder over (stub-)frontend embeddings."""
    def step(h, blk):
        B, S, _ = h.shape
        q, k, v = L.gqa_qkv(blk["mix"], L.rmsnorm(blk["ln1"], h),
                            cfg.n_heads, cfg.n_kv_heads, cfg.d_head,
                            jnp.arange(S), cfg.rope_theta)
        h = h + L.dense(blk["mix"]["wo"], L.cross_attention_core(q, k, v).reshape(B, S, -1))
        h = h + L.swiglu(blk["ffn"], L.rmsnorm(blk["ln2"], h))
        return h, None

    h, _ = jax.lax.scan(step, src_embeds.astype(L.DTYPE), params["enc_blocks"])
    return L.rmsnorm(params["enc_norm"], h)


def forward(cfg: ArchConfig, params, tokens, memory_embeds=None, emit_cache=False):
    """Causal LM forward → (hidden [B,T,D], aux_loss[, payloads]).

    ``memory_embeds`` feeds cross-attention layers: encoder output (audio),
    projected patch embeddings (vision).  With ``emit_cache`` the per-layer
    cache payloads are also returned (for prefill)."""
    B, T = tokens.shape
    h = params["embed"][tokens].astype(L.DTYPE)
    positions = jnp.arange(T)
    memory = None
    if cfg.n_encoder_layers > 0 and memory_embeds is not None:
        memory = _encoder(cfg, params, memory_embeds)
    elif cfg.frontend == "vision" and memory_embeds is not None:
        memory = L.dense(params["vis_proj"], memory_embeds.astype(L.DTYPE))

    payloads: Dict[str, Any] = {}
    aux_total = 0.0
    for i, (kind, ffn) in enumerate(cfg.prefix):
        h, aux, pay = _apply_layer(cfg, kind, ffn, params[f"pre{i}"], h, positions, memory)
        aux_total += aux
        payloads[f"pre{i}"] = pay

    def block_step(carry, blk):
        h, aux = carry
        pays = {}
        for i, (kind, ffn) in enumerate(cfg.pattern):
            h, a, pay = _apply_layer(cfg, kind, ffn, blk[f"l{i}"], h, positions, memory)
            aux = aux + a
            pays[f"l{i}"] = pay
        return (h, aux), (pays if emit_cache else None)

    if cfg.remat:
        # activation checkpointing at super-block granularity: save only the
        # inter-block residual stream, recompute block internals on backward
        block_step = jax.checkpoint(block_step)
    (h, aux_total), blk_pays = jax.lax.scan(
        block_step, (h, jnp.asarray(aux_total, jnp.float32)), params["blocks"])
    payloads["blocks"] = blk_pays
    for i, (kind, ffn) in enumerate(cfg.suffix):
        h, aux, pay = _apply_layer(cfg, kind, ffn, params[f"suf{i}"], h, positions, memory)
        aux_total += aux
        payloads[f"suf{i}"] = pay
    h = L.rmsnorm(params["final_norm"], h)
    if emit_cache:
        return h, aux_total, payloads
    return h, aux_total


def train_loss(cfg: ArchConfig, params, batch):
    """batch: tokens [B,T] int32, targets [B,T] int32 (−1 = masked),
    optional memory_embeds [B,M,D] f32."""
    h, aux = forward(cfg, params, batch["tokens"], batch.get("memory_embeds"))
    logits = L.dense(params["lm_head"], h).astype(jnp.float32)
    targets = batch["targets"]
    mask = (targets >= 0).astype(jnp.float32)
    logp = jax.nn.log_softmax(logits)
    tgt = jnp.maximum(targets, 0)
    nll = -jnp.take_along_axis(logp, tgt[..., None], -1)[..., 0]
    loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)

    if cfg.mtp:  # next-next-token prediction head (DeepSeek-V3 style)
        emb_next = params["embed"][jnp.maximum(batch["tokens"], 0)].astype(L.DTYPE)
        emb_next = jnp.concatenate([emb_next[:, 1:], emb_next[:, -1:]], axis=1)
        hm = L.dense(params["mtp"]["proj"], jnp.concatenate([h.astype(L.DTYPE), emb_next], -1))
        hm, _, _ = _apply_layer(cfg, "attn", "dense", params["mtp"]["layer"], hm,
                                jnp.arange(h.shape[1]), None)
        hm = L.rmsnorm(params["mtp"]["norm"], hm)
        logits2 = L.dense(params["lm_head"], hm).astype(jnp.float32)
        tgt2 = jnp.concatenate([targets[:, 1:], -jnp.ones_like(targets[:, -1:])], 1)
        mask2 = (tgt2 >= 0).astype(jnp.float32)
        nll2 = -jnp.take_along_axis(jax.nn.log_softmax(logits2),
                                    jnp.maximum(tgt2, 0)[..., None], -1)[..., 0]
        loss = loss + 0.3 * jnp.sum(nll2 * mask2) / jnp.maximum(jnp.sum(mask2), 1.0)

    return loss + cfg.aux_loss_coef * aux


# --------------------------------------------------------------------------
# KV / state caches
# --------------------------------------------------------------------------
def _cache_cap(cfg: ArchConfig, kind: str, max_seq: int) -> int:
    if kind == "local":
        return min(cfg.window, max_seq)
    cap = cfg.global_cache_cap or max_seq
    return min(cap, max_seq)


def _layer_cache_init(cfg: ArchConfig, kind: str, B: int, max_seq: int):
    if kind in ("attn", "local"):
        cap = _cache_cap(cfg, kind, max_seq)
        shp = (B, cap, cfg.n_kv_heads, cfg.d_head)
        return {"k": jnp.zeros(shp, L.DTYPE), "v": jnp.zeros(shp, L.DTYPE)}
    if kind == "mla":
        cap = _cache_cap(cfg, "attn", max_seq)
        return {"latent": jnp.zeros((B, cap, cfg.kv_lora_rank + cfg.qk_rope_dim), L.DTYPE)}
    if kind == "attn_cross":
        cap = _cache_cap(cfg, "attn", max_seq)
        shp = (B, cap, cfg.n_kv_heads, cfg.d_head)
        out = {"k": jnp.zeros(shp, L.DTYPE), "v": jnp.zeros(shp, L.DTYPE)}
        if cfg.cache_cross_kv:
            mshp = (B, cfg.n_frontend_tokens, cfg.n_kv_heads, cfg.d_head)
            out["mk"] = jnp.zeros(mshp, L.DTYPE)
            out["mv"] = jnp.zeros(mshp, L.DTYPE)
        return out
    if kind == "cross":
        if cfg.cache_cross_kv:   # §Perf: memory K/V computed once at prefill
            mshp = (B, cfg.n_frontend_tokens, cfg.n_kv_heads, cfg.d_head)
            return {"mk": jnp.zeros(mshp, L.DTYPE), "mv": jnp.zeros(mshp, L.DTYPE)}
        return {}  # memory K/V are recomputed from memory_embeds (static)
    if kind == "mamba":
        d_in = cfg.ssm_expand * cfg.d_model
        return {"conv": jnp.zeros((B, cfg.ssm_conv - 1, d_in), L.DTYPE),
                "h": jnp.zeros((B, d_in, cfg.ssm_state), jnp.float32)}
    if kind == "rwkv":
        dh = cfg.d_model // cfg.n_heads
        return {"x_prev": jnp.zeros((B, cfg.d_model), L.DTYPE),
                "S": jnp.zeros((B, cfg.n_heads, dh, dh), jnp.float32)}
    raise ValueError(kind)


def init_cache(cfg: ArchConfig, B: int, max_seq: int):
    cache: Dict[str, Any] = {"pos": jnp.zeros((), jnp.int32)}
    for i, (kind, _) in enumerate(cfg.prefix):
        cache[f"pre{i}"] = _layer_cache_init(cfg, kind, B, max_seq)
    blk = {f"l{i}": _layer_cache_init(cfg, kind, B, max_seq)
           for i, (kind, _) in enumerate(cfg.pattern)}
    cache["blocks"] = jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (cfg.n_repeats,) + x.shape), blk
    )
    for i, (kind, _) in enumerate(cfg.suffix):
        cache[f"suf{i}"] = _layer_cache_init(cfg, kind, B, max_seq)
    return cache


def _ring_write(buf, val, pos):
    """Write val [B,1,...] at ring slot pos%cap."""
    cap = buf.shape[1]
    slot = jnp.mod(pos, cap)
    return jax.lax.dynamic_update_slice(buf, val.astype(buf.dtype),
                                        (0, slot) + (0,) * (buf.ndim - 2))


def _decode_layer(cfg, kind, ffn, p, c, h, pos, memory):
    """One-token layer step.  h [B,1,D]."""
    B = h.shape[0]
    aux = 0.0
    if kind in ("attn", "local", "attn_cross"):
        sp = p["mix"]["self"] if kind == "attn_cross" else p["mix"]
        q, k, v = L.gqa_qkv(sp, L.rmsnorm(p["ln1"], h), cfg.n_heads,
                            cfg.n_kv_heads, cfg.d_head, pos[None], cfg.rope_theta)
        c = dict(c, k=_ring_write(c["k"], k, pos), v=_ring_write(c["v"], v, pos))
        cap = c["k"].shape[1]
        valid = jnp.minimum(pos + 1, cap)
        out = L.decode_attention(q, c["k"], c["v"], valid)
        h = h + L.dense(sp["wo"], out.reshape(B, 1, -1))
        if kind == "attn_cross":
            hx = L.rmsnorm(p["lnx"], h)
            cp = p["mix"]["cross"]
            qx = L.dense(cp["wq"], hx).reshape(B, 1, cfg.n_heads, cfg.d_head)
            if "mk" in c:   # §Perf: memory K/V cached at prefill
                mk, mv = c["mk"], c["mv"]
            else:
                mk = L.dense(cp["wk"], memory).reshape(B, -1, cfg.n_kv_heads, cfg.d_head)
                mv = L.dense(cp["wv"], memory).reshape(B, -1, cfg.n_kv_heads, cfg.d_head)
            h = h + L.dense(cp["wo"], L.cross_attention_core(qx, mk, mv).reshape(B, 1, -1))
    elif kind == "mla":
        if cfg.mla_absorbed:
            # §Perf: absorbed decode — no per-token cache re-expansion
            q_nope, q_rope, latent = L.mla_q_and_latent(
                p["mix"], L.rmsnorm(p["ln1"], h), cfg.n_heads, cfg,
                pos[None], cfg.rope_theta)
            c = dict(c, latent=_ring_write(c["latent"], latent, pos))
            cap = c["latent"].shape[1]
            valid = jnp.minimum(pos + 1, cap)
            out = L.mla_absorbed_decode(p["mix"], q_nope, q_rope,
                                        c["latent"], valid, cfg.n_heads, cfg)
        else:
            q, k, v, latent = L.mla_qkv(p["mix"], L.rmsnorm(p["ln1"], h),
                                        cfg.n_heads, cfg, pos[None], cfg.rope_theta)
            c = dict(c, latent=_ring_write(c["latent"], latent, pos))
            cap = c["latent"].shape[1]
            k_all, v_all = L.mla_expand(p["mix"], c["latent"], cfg.n_heads, cfg)
            valid = jnp.minimum(pos + 1, cap)
            out = L.decode_attention(q, k_all, v_all, valid)
        h = h + L.dense(p["mix"]["wo"], out.reshape(B, 1, -1))
    elif kind == "cross":
        hx = L.rmsnorm(p["ln1"], h)
        q = L.dense(p["mix"]["wq"], hx).reshape(B, 1, cfg.n_heads, cfg.d_head)
        if "mk" in c:   # §Perf: memory K/V cached at prefill
            mk, mv = c["mk"], c["mv"]
        else:
            mk = L.dense(p["mix"]["wk"], memory).reshape(B, -1, cfg.n_kv_heads, cfg.d_head)
            mv = L.dense(p["mix"]["wv"], memory).reshape(B, -1, cfg.n_kv_heads, cfg.d_head)
        h = h + L.dense(p["mix"]["wo"], L.cross_attention_core(q, mk, mv).reshape(B, 1, -1))
    elif kind == "mamba":
        st = (c["conv"], c["h"])
        st, y = L.mamba_decode(p["mix"], st, L.rmsnorm(p["ln1"], h)[:, 0], cfg.ssm_state)
        c = dict(c, conv=st[0], h=st[1])
        h = h + y[:, None, :]
    elif kind == "rwkv":
        st = (c["x_prev"], c["S"])
        st, y = L.rwkv6_decode(p["mix"], st, L.rmsnorm(p["ln1"], h)[:, 0], cfg.n_heads)
        c = dict(c, x_prev=st[0], S=st[1])
        h = h + y[:, None, :]
    else:
        raise ValueError(kind)
    ffn_out, aux = _apply_ffn(cfg, ffn, p["ffn"], L.rmsnorm(p["ln2"], h))
    return h + ffn_out, c


def decode_step(cfg: ArchConfig, params, cache, token, memory_embeds=None):
    """One decoding step.  token [B] int32 → (logits [B,V], new cache)."""
    B = token.shape[0]
    pos = cache["pos"]
    h = params["embed"][token][:, None, :].astype(L.DTYPE)
    memory = None
    # §Perf: with cache_cross_kv the memory K/V live in the cache, so the
    # encoder / vision projector is NOT re-run per decoded token.
    if memory_embeds is not None and not cfg.cache_cross_kv:
        if cfg.n_encoder_layers > 0:
            memory = _encoder(cfg, params, memory_embeds)
        elif cfg.frontend == "vision":
            memory = L.dense(params["vis_proj"], memory_embeds.astype(L.DTYPE))

    new_cache: Dict[str, Any] = {"pos": pos + 1}
    for i, (kind, ffn) in enumerate(cfg.prefix):
        h, new_cache[f"pre{i}"] = _decode_layer(
            cfg, kind, ffn, params[f"pre{i}"], cache[f"pre{i}"], h, pos, memory)

    def block_step(h, xs):
        blk, bc = xs
        nc = {}
        for i, (kind, ffn) in enumerate(cfg.pattern):
            h, nc[f"l{i}"] = _decode_layer(cfg, kind, ffn, blk[f"l{i}"], bc[f"l{i}"],
                                           h, pos, memory)
        return h, nc

    h, new_cache["blocks"] = jax.lax.scan(block_step, h,
                                          (params["blocks"], cache["blocks"]))
    for i, (kind, ffn) in enumerate(cfg.suffix):
        h, new_cache[f"suf{i}"] = _decode_layer(
            cfg, kind, ffn, params[f"suf{i}"], cache[f"suf{i}"], h, pos, memory)

    h = L.rmsnorm(params["final_norm"], h)
    logits = L.dense(params["lm_head"], h)[:, 0].astype(jnp.float32)
    return logits, new_cache


def _to_ring(x, T: int, cap: int, seq_axis: int):
    """Convert a length-T sequence tensor into ring-buffer layout with
    capacity ``cap``: slot p%cap holds position p for the last cap
    positions (matches ``_ring_write``'s indexing).  Static T, cap."""
    if T >= cap:
        sl = [slice(None)] * x.ndim
        sl[seq_axis] = slice(T - cap, T)
        arr = x[tuple(sl)]
        return jnp.roll(arr, shift=T % cap, axis=seq_axis)
    pad = [(0, 0)] * x.ndim
    pad[seq_axis] = (0, cap - T)
    return jnp.pad(x, pad)


def _payload_to_cache(cfg, kind, pay, T: int, max_seq: int, scanned: bool):
    """Convert a forward cache-payload into the decode cache structure.
    ``scanned`` payloads carry a leading n_repeats axis."""
    ax = 2 if scanned else 1  # seq axis of [R?,B,T,...]
    if kind in ("attn", "local", "attn_cross"):
        cap = _cache_cap(cfg, "local" if kind == "local" else "attn", max_seq)
        k, v = pay[0], pay[1]
        out = {"k": _to_ring(k, T, cap, ax), "v": _to_ring(v, T, cap, ax)}
        if kind == "attn_cross" and len(pay) == 4:
            out["mk"], out["mv"] = pay[2], pay[3]
        return out
    if kind == "mla":
        cap = _cache_cap(cfg, "attn", max_seq)
        return {"latent": _to_ring(pay, T, cap, ax)}
    if kind == "cross":
        if pay:
            return {"mk": pay[0], "mv": pay[1]}
        return {}
    if kind == "mamba":
        conv, hst = pay
        return {"conv": conv, "h": hst}
    if kind == "rwkv":
        x_prev, S = pay
        return {"x_prev": x_prev, "S": S}
    raise ValueError(kind)


def prefill(cfg: ArchConfig, params, tokens, memory_embeds=None, max_seq=None):
    """Full-sequence forward that also populates the decode caches (ring
    semantics for attention, final states for SSM).  Returns
    (last-position logits [B,V], cache)."""
    B, T = tokens.shape
    max_seq = max_seq or T
    h, _, payloads = forward(cfg, params, tokens, memory_embeds, emit_cache=True)
    logits = L.dense(params["lm_head"], h[:, -1])

    cache: Dict[str, Any] = {"pos": jnp.asarray(T, jnp.int32)}
    for i, (kind, _) in enumerate(cfg.prefix):
        cache[f"pre{i}"] = _payload_to_cache(cfg, kind, payloads[f"pre{i}"],
                                             T, max_seq, scanned=False)
    blk = {}
    for i, (kind, _) in enumerate(cfg.pattern):
        blk[f"l{i}"] = _payload_to_cache(cfg, kind, payloads["blocks"][f"l{i}"],
                                         T, max_seq, scanned=True)
    cache["blocks"] = blk
    for i, (kind, _) in enumerate(cfg.suffix):
        cache[f"suf{i}"] = _payload_to_cache(cfg, kind, payloads[f"suf{i}"],
                                             T, max_seq, scanned=False)
    return logits.astype(jnp.float32), cache
