"""Layer primitives for the assigned-architecture zoo.

Pure functional (init/apply) JAX.  Covers: RMSNorm, RoPE, GQA attention
(full-causal chunked, sliding-window block-banded, cross, decode), MLA
(DeepSeek-V3 latent attention), SwiGLU FFN, capacity-based MoE, Mamba
selective-SSM block (Jamba), and RWKV6 data-dependent-decay block.

Attention is *memory-bounded by construction*: training/prefill use an
online-softmax scan over KV chunks (flash-style in pure JAX, DESIGN §3) so
no [T, S] score tensor ever materializes — this is what keeps the 32k
prefill dry-run's memory_analysis sane and is also the jnp oracle for the
Pallas window-attention kernel.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Any
DTYPE = jnp.bfloat16
NEG_INF = -1e9


# --------------------------------------------------------------------------
# basics
# --------------------------------------------------------------------------
def dense_init(key, n_in, n_out, bias=False, dtype=DTYPE):
    p = {"w": (jax.random.normal(key, (n_in, n_out), jnp.float32) / math.sqrt(n_in)).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((n_out,), dtype)
    return p


def dense(p, x):
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


def rmsnorm_init(d, dtype=DTYPE):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p, x, eps=1e-6):
    x32 = x.astype(jnp.float32)
    y = x32 * jax.lax.rsqrt(jnp.mean(x32 * x32, -1, keepdims=True) + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


def rope(x, positions, theta: float = 1e4):
    """Rotary embedding.  x [..., T, H, dh], positions [..., T]."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = jnp.exp(-jnp.arange(0, half, dtype=jnp.float32) * (math.log(theta) / half))
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # [..., T, half]
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]  # [..., T, 1, half]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    ).astype(x.dtype)


# --------------------------------------------------------------------------
# attention cores
# --------------------------------------------------------------------------
def _gqa_scores(q, k):
    """q [B,T,H,dh], k [B,S,KV,dh] → scores [B,KV,G,T,S] with H=KV·G."""
    B, T, H, dh = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, T, KV, G, dh)
    return jnp.einsum("btkgd,bskd->bkgts", qg, k) / math.sqrt(dh)


def causal_attention(q, k, v, *, kv_chunk: int = 1024, q_offset: int = 0):
    """Online-softmax causal attention, scanning KV in chunks.

    q [B,T,H,dk]; k [B,S,KV,dk]; v [B,S,KV,dv] (dk may differ from dv —
    MLA); ``q_offset`` is the absolute position of q[0] (so decode /
    prefill-continuation mask correctly).  Returns [B,T,H,dv].
    """
    B, T, H, dh = q.shape
    S, KV = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    G = H // KV
    n_chunks = max(1, (S + kv_chunk - 1) // kv_chunk)
    pad = n_chunks * kv_chunk - S
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(B, n_chunks, kv_chunk, KV, dh).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, n_chunks, kv_chunk, KV, dv).transpose(1, 0, 2, 3, 4)

    qg = q.reshape(B, T, KV, G, dh)
    q_pos = q_offset + jnp.arange(T)

    def step(carry, chunk):
        m, l, acc, s0 = carry
        kj, vj = chunk  # [B, C, KV, dh]
        s = jnp.einsum("btkgd,bckd->bkgtc", qg, kj).astype(jnp.float32) / math.sqrt(dh)
        kv_pos = s0 + jnp.arange(kv_chunk)
        mask = (kv_pos[None, :] <= q_pos[:, None]) & (kv_pos[None, :] < S)
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bkgtc,bckd->bkgtd", p.astype(vj.dtype), vj
        ).astype(jnp.float32)
        return (m_new, l_new, acc_new, s0 + kv_chunk), None

    m0 = jnp.full((B, KV, G, T), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, KV, G, T), jnp.float32)
    a0 = jnp.zeros((B, KV, G, T, dv), jnp.float32)
    (m, l, acc, _), _ = jax.lax.scan(step, (m0, l0, a0, 0), (kc, vc))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.transpose(0, 3, 1, 2, 4).reshape(B, T, H, dv).astype(q.dtype)


def local_attention(q, k, v, window: int):
    """Block-banded causal sliding-window attention (sub-quadratic).

    Blocks of size ``window``; each q block attends to itself + previous
    block with an exact band mask, so each token sees exactly the trailing
    ``window`` positions.  q,k,v [B,T,H/KV,dh]; T padded to window multiple.
    """
    B, T, H, dh = q.shape
    KV = k.shape[2]
    G = H // KV
    W = window
    n_blk = (T + W - 1) // W
    pad = n_blk * W - T
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    qb = q.reshape(B, n_blk, W, KV, G, dh)
    kb = k.reshape(B, n_blk, W, KV, dh)
    vb = v.reshape(B, n_blk, W, KV, dh)
    k_prev = jnp.concatenate([jnp.zeros_like(kb[:, :1]), kb[:, :-1]], 1)
    v_prev = jnp.concatenate([jnp.zeros_like(vb[:, :1]), vb[:, :-1]], 1)
    k2 = jnp.concatenate([k_prev, kb], 2)  # [B,n,2W,KV,dh]
    v2 = jnp.concatenate([v_prev, vb], 2)

    s = jnp.einsum("bnwkgd,bnckd->bnkgwc", qb, k2).astype(jnp.float32) / math.sqrt(dh)
    qi = jnp.arange(W)[:, None] + W           # absolute pos within 2W frame
    ki = jnp.arange(2 * W)[None, :]
    band = (ki <= qi) & (ki > qi - W)          # exactly the last `window` keys
    first = jnp.arange(n_blk) == 0             # block 0's `prev` is padding
    valid = jnp.where(first[:, None, None], ki >= W, True) & band  # [n,W,2W]
    s = jnp.where(valid[None, :, None, None, :, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bnkgwc,bnckd->bnwkgd", p.astype(v2.dtype), v2)
    out = out.reshape(B, n_blk * W, H, dh)[:, :T]
    return out.astype(q.dtype)


def decode_attention(q, cache_k, cache_v, cache_len):
    """Single-position attention against a cache.  q [B,1,H,dk];
    cache_k [B,S,KV,dk], cache_v [B,S,KV,dv] (dk may differ from dv — MLA);
    ``cache_len`` = number of valid positions."""
    B, _, H, dh = q.shape
    S, KV = cache_k.shape[1], cache_k.shape[2]
    dv = cache_v.shape[-1]
    G = H // KV
    qg = q.reshape(B, 1, KV, G, dh)
    s = jnp.einsum("btkgd,bskd->bkgts", qg, cache_k).astype(jnp.float32) / math.sqrt(dh)
    mask = jnp.arange(S)[None, None, None, None, :] < cache_len
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, -1)
    out = jnp.einsum("bkgts,bskd->btkgd", p.astype(cache_v.dtype), cache_v)
    return out.reshape(B, 1, H, dv).astype(q.dtype)


def cross_attention_core(q, k, v):
    """Plain softmax attention to a (small) memory."""
    s = _gqa_scores(q, k).astype(jnp.float32)
    p = jax.nn.softmax(s, -1)
    B, T, H, dh = q.shape
    KV = k.shape[2]
    G = H // KV
    out = jnp.einsum("bkgts,bskd->btkgd", p.astype(v.dtype), v)
    return out.reshape(B, T, H, dh).astype(q.dtype)


# --------------------------------------------------------------------------
# GQA attention layer (q/k/v/o projections + RoPE)
# --------------------------------------------------------------------------
def gqa_init(key, d_model, n_heads, n_kv, d_head, qkv_bias=False, dtype=DTYPE):
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], d_model, n_heads * d_head, qkv_bias, dtype),
        "wk": dense_init(ks[1], d_model, n_kv * d_head, qkv_bias, dtype),
        "wv": dense_init(ks[2], d_model, n_kv * d_head, qkv_bias, dtype),
        "wo": dense_init(ks[3], n_heads * d_head, d_model, False, dtype),
    }


def gqa_qkv(p, x, n_heads, n_kv, d_head, positions, theta):
    B, T, _ = x.shape
    q = dense(p["wq"], x).reshape(B, T, n_heads, d_head)
    k = dense(p["wk"], x).reshape(B, T, n_kv, d_head)
    v = dense(p["wv"], x).reshape(B, T, n_kv, d_head)
    q = rope(q, positions, theta)
    k = rope(k, positions, theta)
    return q, k, v


# --------------------------------------------------------------------------
# MLA — multi-head latent attention (DeepSeek-V3)
# --------------------------------------------------------------------------
def mla_init(key, d_model, n_heads, cfg, dtype=DTYPE):
    """cfg carries q_lora_rank, kv_lora_rank, qk_rope_dim, qk_nope_dim,
    v_head_dim.  The KV cache stores only [c_kv ; k_rope]."""
    ks = jax.random.split(key, 6)
    qk_dim = cfg.qk_nope_dim + cfg.qk_rope_dim
    p = {
        "wkv_a": dense_init(ks[0], d_model, cfg.kv_lora_rank + cfg.qk_rope_dim, False, dtype),
        "kv_norm": rmsnorm_init(cfg.kv_lora_rank, dtype),
        "wkv_b": dense_init(ks[1], cfg.kv_lora_rank,
                            n_heads * (cfg.qk_nope_dim + cfg.v_head_dim), False, dtype),
        "wo": dense_init(ks[2], n_heads * cfg.v_head_dim, d_model, False, dtype),
    }
    if cfg.q_lora_rank > 0:
        p["wq_a"] = dense_init(ks[3], d_model, cfg.q_lora_rank, False, dtype)
        p["q_norm"] = rmsnorm_init(cfg.q_lora_rank, dtype)
        p["wq_b"] = dense_init(ks[4], cfg.q_lora_rank, n_heads * qk_dim, False, dtype)
    else:
        p["wq"] = dense_init(ks[5], d_model, n_heads * qk_dim, False, dtype)
    return p


def mla_qkv(p, x, n_heads, cfg, positions, theta):
    """Returns (q, k, v, latent) — latent is what the decode cache stores."""
    B, T, _ = x.shape
    qk_dim = cfg.qk_nope_dim + cfg.qk_rope_dim
    if "wq_a" in p:
        q = dense(p["wq_b"], rmsnorm(p["q_norm"], dense(p["wq_a"], x)))
    else:
        q = dense(p["wq"], x)
    q = q.reshape(B, T, n_heads, qk_dim)
    q_nope, q_rope = q[..., : cfg.qk_nope_dim], q[..., cfg.qk_nope_dim :]
    q_rope = rope(q_rope, positions, theta)
    q = jnp.concatenate([q_nope, q_rope], -1)

    kv = dense(p["wkv_a"], x)
    c_kv, k_rope = kv[..., : cfg.kv_lora_rank], kv[..., cfg.kv_lora_rank :]
    c_kv = rmsnorm(p["kv_norm"], c_kv)
    k_rope = rope(k_rope.reshape(B, T, 1, cfg.qk_rope_dim), positions, theta)
    latent = jnp.concatenate([c_kv, k_rope.reshape(B, T, cfg.qk_rope_dim)], -1)
    k, v = mla_expand(p, latent, n_heads, cfg)
    return q, k, v, latent


def mla_q_and_latent(p, x, n_heads, cfg, positions, theta):
    """The MLA pieces WITHOUT k/v expansion: (q_nope, q_rope, latent).
    Used by the absorbed decode path (§Perf: skip the O(S·R·H·d) per-token
    re-expansion of the whole cache)."""
    B, T, _ = x.shape
    qk_dim = cfg.qk_nope_dim + cfg.qk_rope_dim
    if "wq_a" in p:
        q = dense(p["wq_b"], rmsnorm(p["q_norm"], dense(p["wq_a"], x)))
    else:
        q = dense(p["wq"], x)
    q = q.reshape(B, T, n_heads, qk_dim)
    q_nope, q_rope = q[..., : cfg.qk_nope_dim], q[..., cfg.qk_nope_dim :]
    q_rope = rope(q_rope, positions, theta)
    kv = dense(p["wkv_a"], x)
    c_kv, k_rope = kv[..., : cfg.kv_lora_rank], kv[..., cfg.kv_lora_rank :]
    c_kv = rmsnorm(p["kv_norm"], c_kv)
    k_rope = rope(k_rope.reshape(B, T, 1, cfg.qk_rope_dim), positions, theta)
    latent = jnp.concatenate([c_kv, k_rope.reshape(B, T, cfg.qk_rope_dim)], -1)
    return q_nope, q_rope, latent


def mla_absorbed_decode(p, q_nope, q_rope, latent_cache, valid_len, n_heads, cfg):
    """Absorbed-matrix MLA decode: attention runs directly in latent space.

    score_h(s) = q_nope_h·(W_UK_h c_s) + q_rope_h·k_rope_s
               = (W_UK_hᵀ q_nope_h)·c_s + q_rope_h·k_rope_s
    so we absorb W_UK into the query once per token (H·R·nope flops) and
    never materialize per-position k/v.  ctx stays in latent space and is
    decoded through W_UV at the end.  q_nope/q_rope [B,1,H,·];
    latent_cache [B,S,R+rope].  Returns [B,1,H,v_head_dim].
    """
    R = cfg.kv_lora_rank
    wb = p["wkv_b"]["w"].astype(jnp.float32)
    wb = wb.reshape(R, n_heads, cfg.qk_nope_dim + cfg.v_head_dim)
    W_UK, W_UV = wb[..., : cfg.qk_nope_dim], wb[..., cfg.qk_nope_dim :]
    c = latent_cache[..., :R].astype(jnp.float32)          # [B,S,R]
    kr = latent_cache[..., R:].astype(jnp.float32)         # [B,S,rope]
    q_abs = jnp.einsum("bthn,rhn->bthr", q_nope.astype(jnp.float32), W_UK)
    scale = 1.0 / math.sqrt(cfg.qk_nope_dim + cfg.qk_rope_dim)
    s = (jnp.einsum("bthr,bsr->bhts", q_abs, c)
         + jnp.einsum("bthr,bsr->bhts", q_rope.astype(jnp.float32), kr)) * scale
    S = c.shape[1]
    mask = jnp.arange(S)[None, None, None, :] < valid_len
    s = jnp.where(mask, s, NEG_INF)
    pr = jax.nn.softmax(s, -1)
    ctx = jnp.einsum("bhts,bsr->bthr", pr, c)              # [B,1,H,R]
    out = jnp.einsum("bthr,rhv->bthv", ctx, W_UV)          # [B,1,H,v]
    return out.astype(q_nope.dtype)


def mla_expand(p, latent, n_heads, cfg):
    """Expand cached latent [B,S,kv_lora+rope] → k,v [B,S,H,·]."""
    B, S, _ = latent.shape
    c_kv = latent[..., : cfg.kv_lora_rank]
    k_rope = latent[..., cfg.kv_lora_rank :]
    kvb = dense(p["wkv_b"], c_kv).reshape(B, S, n_heads, cfg.qk_nope_dim + cfg.v_head_dim)
    k_nope, v = kvb[..., : cfg.qk_nope_dim], kvb[..., cfg.qk_nope_dim :]
    k_rope_b = jnp.broadcast_to(k_rope[:, :, None, :], (B, S, n_heads, cfg.qk_rope_dim))
    k = jnp.concatenate([k_nope, k_rope_b], -1)
    return k, v


# --------------------------------------------------------------------------
# FFNs
# --------------------------------------------------------------------------
def swiglu_init(key, d_model, d_ff, dtype=DTYPE):
    ks = jax.random.split(key, 3)
    return {
        "wi": dense_init(ks[0], d_model, d_ff, False, dtype),
        "wu": dense_init(ks[1], d_model, d_ff, False, dtype),
        "wo": dense_init(ks[2], d_ff, d_model, False, dtype),
    }


def swiglu(p, x):
    return dense(p["wo"], jax.nn.silu(dense(p["wi"], x)) * dense(p["wu"], x))


def moe_init(key, d_model, n_experts, expert_d_ff, n_shared, shared_d_ff, dtype=DTYPE):
    ks = jax.random.split(key, 5)

    def ed(k, a, b):
        return (jax.random.normal(k, (n_experts, a, b), jnp.float32) / math.sqrt(a)).astype(dtype)

    p = {
        "router": dense_init(ks[0], d_model, n_experts, False, jnp.float32),
        "wi": ed(ks[1], d_model, expert_d_ff),
        "wu": ed(ks[2], d_model, expert_d_ff),
        "wo": ed(ks[3], expert_d_ff, d_model),
    }
    if n_shared > 0:
        p["shared"] = swiglu_init(ks[4], d_model, shared_d_ff * n_shared, dtype)
    return p


def moe_apply(p, x, top_k: int, capacity_factor: float = 1.25,
              dispatch_spec=None):
    """Capacity-based top-k MoE (DESIGN §3 hardware-adaptation notes).

    x [B,T,D] → [B,T,D].  Tokens beyond an expert's capacity are dropped
    (contribute zero), standard TPU practice.  Returns (out, aux_loss).

    ``dispatch_spec`` (§Perf): PartitionSpec axes for the [E, C, D]
    dispatch buffer.  Constraining the expert dim to the weight's expert
    axis makes GSPMD move TOKENS (all-to-all) instead of all-gathering the
    stacked expert weights.  Ignored outside a mesh context.
    """
    def _constrain(t):
        if dispatch_spec is None:
            return t
        try:
            from jax.sharding import PartitionSpec as _P
            return jax.lax.with_sharding_constraint(t, _P(*dispatch_spec[: t.ndim]))
        except Exception:
            return t  # no mesh (host tests) — constraint is advisory

    B, T, D = x.shape
    E = p["wi"].shape[0]
    xt = x.reshape(B * T, D)
    n_tok = B * T
    logits = dense(p["router"], xt.astype(jnp.float32))          # [N, E]
    probs = jax.nn.softmax(logits, -1)
    gate, idx = jax.lax.top_k(probs, top_k)                      # [N, k]
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    capacity = max(1, int(n_tok * top_k * capacity_factor / E))
    # position of each (token, k) within its expert, via cumsum over one-hot
    onehot = jax.nn.one_hot(idx, E, dtype=jnp.int32)             # [N, k, E]
    flat = onehot.reshape(n_tok * top_k, E)
    pos = jnp.cumsum(flat, 0) * flat - 1                         # [N·k, E]
    pos = pos.max(-1).reshape(n_tok, top_k)                      # [N, k]
    keep = pos < capacity

    # dispatch: scatter tokens into [E, C, D]
    e_idx = idx.reshape(-1)
    p_idx = jnp.clip(pos.reshape(-1), 0, capacity - 1)
    src = jnp.repeat(xt, top_k, axis=0) * keep.reshape(-1, 1)
    buf = jnp.zeros((E, capacity, D), x.dtype).at[e_idx, p_idx].add(src)
    buf = _constrain(buf)

    # expert compute: grouped matmuls [E, C, ·]
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["wi"])) * jnp.einsum(
        "ecd,edf->ecf", buf, p["wu"]
    )
    y = jnp.einsum("ecf,efd->ecd", h, p["wo"])                   # [E, C, D]
    y = _constrain(y)

    # combine: gather back and weight by gate
    out = y[e_idx, p_idx] * (gate.reshape(-1, 1) * keep.reshape(-1, 1)).astype(y.dtype)
    out = out.reshape(n_tok, top_k, D).sum(1)

    if "shared" in p:
        out = out + swiglu(p["shared"], xt)

    # load-balance aux loss (Switch-style)
    me = probs.mean(0)
    ce = (onehot.sum(1).astype(jnp.float32)).mean(0) / top_k
    aux = E * jnp.sum(me * ce)
    return out.reshape(B, T, D), aux


# --------------------------------------------------------------------------
# Mamba block (Jamba's SSM layers)
# --------------------------------------------------------------------------
def mamba_init(key, d_model, d_state=16, d_conv=4, expand=2, dtype=DTYPE):
    d_in = expand * d_model
    dt_rank = max(1, d_model // 16)
    ks = jax.random.split(key, 6)
    return {
        "in_proj": dense_init(ks[0], d_model, 2 * d_in, False, dtype),
        "conv_w": (jax.random.normal(ks[1], (d_conv, d_in), jnp.float32) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((d_in,), dtype),
        "x_proj": dense_init(ks[2], d_in, dt_rank + 2 * d_state, False, dtype),
        "dt_proj": dense_init(ks[3], dt_rank, d_in, True, dtype),
        "A_log": jnp.log(jnp.tile(jnp.arange(1, d_state + 1, dtype=jnp.float32), (d_in, 1))),
        "D": jnp.ones((d_in,), jnp.float32),
        "out_proj": dense_init(ks[4], d_in, d_model, False, dtype),
    }


def _mamba_scan(u, dt, A, B, C, D):
    """Selective scan.  u,dt [Bt,T,din]; A [din,S]; B,C [Bt,T,S]."""
    dA = jnp.exp(dt[..., None] * A)                     # [Bt,T,din,S]
    dBu = dt[..., None] * B[..., None, :] * u[..., None]

    def step(h, xs):
        dA_t, dBu_t, C_t = xs
        h = dA_t * h + dBu_t                             # [Bt,din,S]
        y = jnp.einsum("bds,bs->bd", h, C_t)
        return h, y

    h0 = jnp.zeros((u.shape[0], u.shape[2], A.shape[1]), jnp.float32)
    h_final, ys = jax.lax.scan(
        step, h0,
        (dA.transpose(1, 0, 2, 3), dBu.transpose(1, 0, 2, 3), C.transpose(1, 0, 2)),
    )
    y = ys.transpose(1, 0, 2)                            # [Bt,T,din]
    return y + u * D, h_final


def mamba_apply(p, x, d_state=16, return_state=False):
    B, T, D = x.shape
    d_in = p["conv_b"].shape[0]
    dt_rank = p["dt_proj"]["w"].shape[0]
    xz = dense(p["in_proj"], x)
    u, z = jnp.split(xz, 2, -1)
    # causal depthwise conv (kernel k): sum of right-shifted copies, so
    # conv_w[k-1] multiplies the current token and conv_w[0] the oldest.
    k = p["conv_w"].shape[0]
    conv = sum(
        jnp.pad(u, ((0, 0), (k - 1 - i, 0), (0, 0)))[:, :T] * p["conv_w"][i]
        for i in range(k)
    )
    u = jax.nn.silu(conv + p["conv_b"])
    proj = dense(p["x_proj"], u).astype(jnp.float32)
    dt_r, Bc, Cc = jnp.split(proj, [dt_rank, dt_rank + d_state], -1)
    dt = jax.nn.softplus(dense(p["dt_proj"], dt_r.astype(x.dtype)).astype(jnp.float32))
    A = -jnp.exp(p["A_log"])
    y, h_final = _mamba_scan(u.astype(jnp.float32), dt, A, Bc, Cc, p["D"])
    out = dense(p["out_proj"], (y.astype(x.dtype) * jax.nn.silu(z)))
    if not return_state:
        return out
    # decode state: last k−1 *pre-conv* inputs + final SSM state
    u_raw = jnp.split(xz, 2, -1)[0]
    pad = max(0, (k - 1) - T)
    conv_buf = jnp.pad(u_raw, ((0, 0), (pad, 0), (0, 0)))[:, -(k - 1):]
    return out, (conv_buf, h_final)


def mamba_decode(p, state, x, d_state=16):
    """Single-token step.  state = (conv_buf [B,k-1,din], h [B,din,S])."""
    conv_buf, h = state
    B = x.shape[0]
    d_in = p["conv_b"].shape[0]
    dt_rank = p["dt_proj"]["w"].shape[0]
    xz = dense(p["in_proj"], x)            # [B, 2·din]
    u, z = jnp.split(xz, 2, -1)
    k = p["conv_w"].shape[0]
    window = jnp.concatenate([conv_buf, u[:, None, :]], 1)   # [B,k,din]
    conv = jnp.einsum("bkd,kd->bd", window, p["conv_w"]) + p["conv_b"]
    u = jax.nn.silu(conv)
    proj = dense(p["x_proj"], u).astype(jnp.float32)
    dt_r, Bc, Cc = jnp.split(proj, [dt_rank, dt_rank + d_state], -1)
    dt = jax.nn.softplus(dense(p["dt_proj"], dt_r.astype(x.dtype)).astype(jnp.float32))
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt[..., None] * A)
    dBu = dt[..., None] * Bc[:, None, :] * u.astype(jnp.float32)[..., None]
    h = dA * h + dBu
    y = jnp.einsum("bds,bs->bd", h, Cc) + u.astype(jnp.float32) * p["D"]
    out = dense(p["out_proj"], y.astype(x.dtype) * jax.nn.silu(z))
    return (window[:, 1:], h), out


# --------------------------------------------------------------------------
# RWKV6 block ("Finch": data-dependent decay linear attention)
# --------------------------------------------------------------------------
def rwkv6_init(key, d_model, n_heads, dtype=DTYPE):
    dh = d_model // n_heads
    ks = jax.random.split(key, 8)
    return {
        "mix": (jax.random.uniform(ks[0], (5, d_model), jnp.float32)).astype(dtype),
        "wr": dense_init(ks[1], d_model, d_model, False, dtype),
        "wk": dense_init(ks[2], d_model, d_model, False, dtype),
        "wv": dense_init(ks[3], d_model, d_model, False, dtype),
        "wg": dense_init(ks[4], d_model, d_model, False, dtype),
        "ww": dense_init(ks[5], d_model, d_model, False, dtype),  # decay proj (data-dependent!)
        "u": (jax.random.normal(ks[6], (n_heads, dh), jnp.float32) * 0.1),
        "wo": dense_init(ks[7], d_model, d_model, False, dtype),
        "ln_x": rmsnorm_init(d_model, dtype),
    }


def _rwkv6_core(r, k, v, w, u):
    """WKV6 recurrence.  r,k,v [B,T,H,dh]; w [B,T,H,dh] decay ∈(0,1);
    u [H,dh] bonus.  Returns [B,T,H,dh].  State S: [B,H,dh_k,dh_v]."""
    B, T, H, dh = r.shape

    def step(S, xs):
        r_t, k_t, v_t, w_t = xs                      # [B,H,dh]
        kv = k_t[..., :, None] * v_t[..., None, :]   # [B,H,dh,dh]
        out = jnp.einsum("bhk,bhkv->bhv", r_t, S + u[..., :, None] * kv)
        S = w_t[..., :, None] * S + kv
        return S, out

    S0 = jnp.zeros((B, H, dh, dh), jnp.float32)
    xs = tuple(a.transpose(1, 0, 2, 3).astype(jnp.float32) for a in (r, k, v, w))
    S_final, ys = jax.lax.scan(step, S0, xs)
    return ys.transpose(1, 0, 2, 3), S_final         # [B,T,H,dh]


def rwkv6_apply(p, x, n_heads, return_state=False):
    B, T, D = x.shape
    dh = D // n_heads
    prev = jnp.concatenate([jnp.zeros_like(x[:, :1]), x[:, :-1]], 1)  # token shift
    mixed = [x + (prev - x) * p["mix"][i] for i in range(5)]
    r = dense(p["wr"], mixed[0]).reshape(B, T, n_heads, dh)
    k = dense(p["wk"], mixed[1]).reshape(B, T, n_heads, dh)
    v = dense(p["wv"], mixed[2]).reshape(B, T, n_heads, dh)
    g = dense(p["wg"], mixed[3])
    w = dense(p["ww"], mixed[4]).reshape(B, T, n_heads, dh)
    w = jnp.exp(-jnp.exp(w.astype(jnp.float32)))      # data-dependent decay ∈ (0,1)
    y, S_final = _rwkv6_core(r, k, v, w, p["u"])
    y = y.reshape(B, T, D).astype(x.dtype)
    out = dense(p["wo"], rmsnorm(p["ln_x"], y) * jax.nn.silu(g))
    if not return_state:
        return out
    return out, (x[:, -1], S_final)


def rwkv6_decode(p, state, x, n_heads):
    """state = (x_prev [B,D], S [B,H,dh,dh]); x [B,D] single token."""
    x_prev, S = state
    B, D = x.shape
    dh = D // n_heads
    mixed = [x + (x_prev - x) * p["mix"][i] for i in range(5)]
    r = dense(p["wr"], mixed[0]).reshape(B, n_heads, dh).astype(jnp.float32)
    k = dense(p["wk"], mixed[1]).reshape(B, n_heads, dh).astype(jnp.float32)
    v = dense(p["wv"], mixed[2]).reshape(B, n_heads, dh).astype(jnp.float32)
    g = dense(p["wg"], mixed[3])
    w = dense(p["ww"], mixed[4]).reshape(B, n_heads, dh)
    w = jnp.exp(-jnp.exp(w.astype(jnp.float32)))
    kv = k[..., :, None] * v[..., None, :]
    out = jnp.einsum("bhk,bhkv->bhv", r, S + p["u"][..., :, None] * kv)
    S = w[..., :, None] * S + kv
    y = out.reshape(B, D).astype(x.dtype)
    y = dense(p["wo"], rmsnorm(p["ln_x"], y) * jax.nn.silu(g))
    return (x, S), y
