from .ckpt import (
    load_params,
    load_server_state,
    load_service_state,
    save_params,
    save_server_state,
    save_service_state,
)

__all__ = [
    "load_params", "save_params",
    "save_server_state", "load_server_state",
    "save_service_state", "load_service_state",
]
