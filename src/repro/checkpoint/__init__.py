from .ckpt import load_params, save_params, save_server_state, load_server_state

__all__ = ["load_params", "save_params", "save_server_state", "load_server_state"]
