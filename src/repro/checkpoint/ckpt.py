"""Checkpointing: pytree ↔ .npz with stable key paths, plus SAFL server
state (global model, status table, round counter, per-client lr/momentum)
and streaming-service state (``repro.serve.StreamingAggregator``).

Restore is sharding-aware: ``load_params(..., like=params_spec)`` places
leaves with ``jax.device_put`` against the template's shardings when given.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _flatten_with_paths(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        flat[key] = np.asarray(leaf)
    return flat


def save_params(path: str, params) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten_with_paths(params)
    np.savez(path, **flat)


def load_params(path: str, like, device_put: bool = False):
    """Load into the structure of ``like`` (a pytree template)."""
    with np.load(path) as data:
        flat = {k: data[k] for k in data.files}
    paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for path_, template in paths:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path_)
        arr = flat[key]
        if arr.shape != tuple(template.shape):
            raise ValueError(f"shape mismatch for {key}: {arr.shape} vs {template.shape}")
        leaf = jnp.asarray(arr, dtype=template.dtype)
        if device_put and hasattr(template, "sharding"):
            leaf = jax.device_put(leaf, template.sharding)
        leaves.append(leaf)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def _save_compressor(path: str, compressor) -> None:
    """Persist compressed-transport codec state (the error-feedback
    residual bank) next to the model; see ``repro.compress.feedback``."""
    state = compressor.state_dict()
    arrays = {}
    if state["residual"] is not None:
        arrays["residual"] = state["residual"]
    np.savez(os.path.join(path, "codec.npz"),
             spec=np.asarray(state["spec"]),
             error_feedback=np.asarray(state["error_feedback"]),
             **arrays)


def _load_compressor(path: str, compressor) -> bool:
    """Restore codec state saved by ``_save_compressor``; returns whether
    a codec checkpoint was present."""
    f = os.path.join(path, "codec.npz")
    if not os.path.exists(f):
        return False
    with np.load(f) as data:
        state = {
            "spec": str(data["spec"]),
            "error_feedback": bool(data["error_feedback"]),
            "residual": data["residual"] if "residual" in data.files else None,
        }
    compressor.load_state_dict(state)
    return True


def save_server_state(path: str, engine) -> None:
    """Persist a ``SAFLEngine`` so a run can resume mid-training."""
    os.makedirs(path, exist_ok=True)
    save_params(os.path.join(path, "global.npz"), engine.global_params)
    if getattr(engine, "compressor", None) is not None:
        _save_compressor(path, engine.compressor)
    meta = {
        "round": engine.round,
        "counts": np.asarray(engine.table.counts).tolist(),
        "sims": np.asarray(engine.table.sims).tolist(),
        "clients": [
            {"lr": c.lr, "momentum": c.momentum, "similarity": c.last_similarity,
             "quadrant": c.quadrant, "speed": c.speed}
            for c in engine.clients
        ],
    }
    with open(os.path.join(path, "server.json"), "w") as f:
        json.dump(meta, f)


def load_server_state(path: str, engine) -> None:
    from repro.core.types import ServerTable

    engine.global_params = load_params(os.path.join(path, "global.npz"), engine.global_params)
    with open(os.path.join(path, "server.json")) as f:
        meta = json.load(f)
    engine.round = meta["round"]
    engine.table = ServerTable(
        counts=jnp.asarray(meta["counts"], jnp.int32),
        sims=jnp.asarray(meta["sims"], jnp.float32),
    )
    for c, m in zip(engine.clients, meta["clients"]):
        c.lr, c.momentum = m["lr"], m["momentum"]
        c.last_similarity, c.quadrant, c.speed = m["similarity"], m["quadrant"], m["speed"]
    if getattr(engine, "compressor", None) is not None:
        _load_compressor(path, engine.compressor)


def save_service_state(path: str, service) -> None:
    """Persist a ``repro.serve.StreamingAggregator`` for resume.

    Captures the aggregation state (global model, status table, round) and
    the ingestion counters; the in-flight ingest buffer is deliberately NOT
    persisted — a restarted service re-admits live traffic, it does not
    replay half-filled buffers (clients re-upload on reconnect).
    """
    os.makedirs(path, exist_ok=True)
    save_params(os.path.join(path, "global.npz"), service.global_params)
    meta = {
        "round": service.round,
        "counts": np.asarray(service.table.counts).tolist(),
        "sims": np.asarray(service.table.sims).tolist(),
        "stats": {
            "submitted": service.stats.submitted,
            "accepted": service.stats.accepted,
            "dropped": service.stats.dropped,
            "downweighted": service.stats.downweighted,
            "rounds": service.stats.rounds,
        },
        "trigger": service.trigger.describe(),
        "admission": service.admission.describe(),
    }
    with open(os.path.join(path, "service.json"), "w") as f:
        json.dump(meta, f)
    if getattr(service, "compressor", None) is not None:
        _save_compressor(path, service.compressor)


def load_service_state(path: str, service) -> None:
    """Restore ``save_service_state`` output into ``service`` in place."""
    from repro.core.types import ServerTable

    service.global_params = load_params(
        os.path.join(path, "global.npz"), service.global_params
    )
    with open(os.path.join(path, "service.json")) as f:
        meta = json.load(f)
    service.round = meta["round"]
    service.table = ServerTable(
        counts=jnp.asarray(meta["counts"], jnp.int32),
        sims=jnp.asarray(meta["sims"], jnp.float32),
    )
    for k, v in meta.get("stats", {}).items():
        setattr(service.stats, k, v)
    if getattr(service, "compressor", None) is not None:
        _load_compressor(path, service.compressor)
