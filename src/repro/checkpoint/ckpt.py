"""Checkpointing: pytree ↔ .npz with stable key paths, plus SAFL server
state (global model, status table, round counter, per-client lr/momentum)
and streaming-service state (``repro.serve.StreamingAggregator``).

Restore is sharding-aware: ``load_params(..., like=params_spec)`` places
leaves with ``jax.device_put`` against the template's shardings when given.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _flatten_with_paths(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        flat[key] = np.asarray(leaf)
    return flat


def save_params(path: str, params) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten_with_paths(params)
    np.savez(path, **flat)


def load_params(path: str, like, device_put: bool = False):
    """Load into the structure of ``like`` (a pytree template)."""
    with np.load(path) as data:
        flat = {k: data[k] for k in data.files}
    paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for path_, template in paths:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path_)
        arr = flat[key]
        if arr.shape != tuple(template.shape):
            raise ValueError(f"shape mismatch for {key}: {arr.shape} vs {template.shape}")
        leaf = jnp.asarray(arr, dtype=template.dtype)
        if device_put and hasattr(template, "sharding"):
            leaf = jax.device_put(leaf, template.sharding)
        leaves.append(leaf)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def _save_compressor(path: str, compressor) -> None:
    """Persist compressed-transport codec state (the error-feedback
    residual bank) next to the model; see ``repro.compress.feedback``."""
    state = compressor.state_dict()
    arrays = {}
    if state["residual"] is not None:
        arrays["residual"] = state["residual"]
    np.savez(os.path.join(path, "codec.npz"),
             spec=np.asarray(state["spec"]),
             error_feedback=np.asarray(state["error_feedback"]),
             **arrays)


def _load_compressor(path: str, compressor) -> bool:
    """Restore codec state saved by ``_save_compressor``; returns whether
    a codec checkpoint was present."""
    f = os.path.join(path, "codec.npz")
    if not os.path.exists(f):
        return False
    with np.load(f) as data:
        state = {
            "spec": str(data["spec"]),
            "error_feedback": bool(data["error_feedback"]),
            "residual": data["residual"] if "residual" in data.files else None,
        }
    compressor.load_state_dict(state)
    return True


def save_server_state(path: str, engine) -> None:
    """Persist a ``SAFLEngine`` so a run can resume mid-training."""
    os.makedirs(path, exist_ok=True)
    save_params(os.path.join(path, "global.npz"), engine.global_params)
    if getattr(engine, "compressor", None) is not None:
        _save_compressor(path, engine.compressor)
    meta = {
        "round": engine.round,
        "counts": np.asarray(engine.table.counts).tolist(),
        "sims": np.asarray(engine.table.sims).tolist(),
        "clients": [
            {"lr": c.lr, "momentum": c.momentum, "similarity": c.last_similarity,
             "quadrant": c.quadrant, "speed": c.speed}
            for c in engine.clients
        ],
    }
    with open(os.path.join(path, "server.json"), "w") as f:
        json.dump(meta, f)


def load_server_state(path: str, engine) -> None:
    from repro.core.types import ServerTable

    engine.global_params = load_params(os.path.join(path, "global.npz"), engine.global_params)
    with open(os.path.join(path, "server.json")) as f:
        meta = json.load(f)
    engine.round = meta["round"]
    engine.table = ServerTable(
        counts=jnp.asarray(meta["counts"], jnp.int32),
        sims=jnp.asarray(meta["sims"], jnp.float32),
    )
    for c, m in zip(engine.clients, meta["clients"]):
        c.lr, c.momentum = m["lr"], m["momentum"]
        c.last_similarity, c.quadrant, c.speed = m["similarity"], m["quadrant"], m["speed"]
    if getattr(engine, "compressor", None) is not None:
        _load_compressor(path, engine.compressor)


def save_service_state(path: str, service) -> None:
    """Persist a ``repro.serve.StreamingAggregator`` for resume.

    Captures the aggregation state (global model, status table, round) and
    the ingestion counters; the in-flight ingest buffer is deliberately NOT
    persisted — a restarted service re-admits live traffic, it does not
    replay half-filled buffers (clients re-upload on reconnect).
    """
    os.makedirs(path, exist_ok=True)
    save_params(os.path.join(path, "global.npz"), service.global_params)
    meta = {
        "round": service.round,
        "counts": np.asarray(service.table.counts).tolist(),
        "sims": np.asarray(service.table.sims).tolist(),
        "stats": {
            "submitted": service.stats.submitted,
            "accepted": service.stats.accepted,
            "dropped": service.stats.dropped,
            "downweighted": service.stats.downweighted,
            "partial": getattr(service.stats, "partial", 0),
            "rounds": service.stats.rounds,
        },
        "trigger": service.trigger.describe(),
        "admission": service.admission.describe(),
    }
    with open(os.path.join(path, "service.json"), "w") as f:
        json.dump(meta, f)
    if getattr(service, "compressor", None) is not None:
        _save_compressor(path, service.compressor)


def save_hier_state(path: str, service) -> None:
    """Persist a ``repro.hier.HierarchicalService``: the flat service
    state plus every tier's in-flight buffer.

    Unlike the flat service — whose ingest buffer holds raw uploads that
    clients simply re-send on reconnect — tier buffers hold *admitted*
    work that may already be pre-aggregated (partials fold many clients'
    updates), so dropping them at restart would silently lose accepted
    contributions.  Edge buffers are stored as raveled fp32 payload rows
    (compressed uploads are decoded — codec residual state is already
    persisted separately), partials as their materialized Σw·x vectors
    plus member metadata.
    """
    from repro.hier.partial import materialize

    save_service_state(path, service)
    topo = service.topology
    arrays: Dict[str, np.ndarray] = {}
    manifest: Dict[str, Any] = {
        "topology": {
            "spec": topo.spec,
            "n_clients": topo.n_clients,
            "n_edges": topo.n_edges,
            "n_regions": topo.n_regions,
        },
        "edges": {},
        "partials": [],
        "edge_fires": [e.fires for e in service.edges],
        "region_fires": [r.fires for r in service.regions],
    }
    arrays["client_edge"] = topo.client_edge
    arrays["edge_region"] = topo.edge_region

    from repro.compress.codec import decode, is_compressed, ravel_flat

    for e, edge in enumerate(service.edges):
        if not edge.buffer:
            continue
        rows = np.stack([
            np.asarray(decode(edge._payload(u)) if is_compressed(u)
                       else ravel_flat(edge._payload(u)), np.float32)
            for u in edge.buffer
        ])
        arrays[f"edge{e}_rows"] = rows
        for name, dtype in (("cid", np.int64), ("n_samples", np.int64),
                            ("stale_round", np.int64)):
            arrays[f"edge{e}_{name}"] = np.asarray(
                [getattr(u, name) for u in edge.buffer], dtype)
        for name in ("similarity", "lr", "speed_f"):
            arrays[f"edge{e}_{name}"] = np.asarray(
                [getattr(u, name) for u in edge.buffer], np.float32)
        arrays[f"edge{e}_feedback"] = np.asarray(
            [bool(u.feedback) for u in edge.buffer], bool)
        # device-state extensions (docs/ROBUSTNESS.md): partial-work scale
        # and pre-latency send time ride the buffered updates
        arrays[f"edge{e}_completed_fraction"] = np.asarray(
            [float(getattr(u, "completed_fraction", 1.0)) for u in edge.buffer],
            np.float32)
        arrays[f"edge{e}_sent_at"] = np.asarray(
            [float(getattr(u, "sent_at", -1.0)) for u in edge.buffer],
            np.float64)
        manifest["edges"][str(e)] = len(edge.buffer)

    pending = [("global", -1, p) for p in service._ingest]
    for r, region in enumerate(service.regions):
        pending.extend(("region", r, p) for p in region.buffer)
    materialize([p for _, _, p in pending])
    for j, (where, node, p) in enumerate(pending):
        arrays[f"p{j}_sum_wx"] = np.asarray(p.sum_wx, np.float32)
        arrays[f"p{j}_cids"] = p.cids
        arrays[f"p{j}_n_samples"] = p.n_samples
        arrays[f"p{j}_sims"] = p.sims
        arrays[f"p{j}_feedback"] = p.feedback
        arrays[f"p{j}_stale_rounds"] = p.stale_rounds
        if p.completed is not None:
            arrays[f"p{j}_completed"] = p.completed
        manifest["partials"].append({
            "where": where, "node": node, "tier": p.tier,
            "node_id": p.node_id, "sum_w": p.sum_w, "fired_at": p.fired_at,
        })

    np.savez(os.path.join(path, "hier.npz"), **arrays)
    with open(os.path.join(path, "hier.json"), "w") as f:
        json.dump(manifest, f)


def load_hier_state(path: str, service) -> None:
    """Restore ``save_hier_state`` output into ``service`` in place."""
    from repro.core.types import AggregationStrategy, Update
    from repro.hier.partial import PartialAggregate

    load_service_state(path, service)
    with open(os.path.join(path, "hier.json")) as f:
        manifest = json.load(f)
    topo_meta = manifest["topology"]
    topo = service.topology
    if (topo_meta["n_clients"], topo_meta["n_edges"], topo_meta["n_regions"]) != (
        topo.n_clients, topo.n_edges, topo.n_regions
    ):
        raise ValueError(
            f"checkpoint topology {topo_meta['spec']!r} "
            f"({topo_meta['n_edges']}x{topo_meta['n_regions']} over "
            f"{topo_meta['n_clients']} clients) does not match the "
            f"service topology {topo.describe()!r}"
        )
    with np.load(os.path.join(path, "hier.npz")) as data:
        arrays = {k: data[k] for k in data.files}
    # a fresh Topology, not an in-place rewire: the caller may share the
    # original object with other services (parse_topology passes
    # Topology instances through by reference)
    from repro.hier.topology import Topology

    service.topology = Topology(
        n_clients=topo.n_clients,
        n_edges=topo.n_edges,
        n_regions=topo.n_regions,
        client_edge=np.asarray(arrays["client_edge"], np.int64),
        edge_region=np.asarray(arrays["edge_region"], np.int64),
        spec=topo.spec,
    )

    for e, fires in enumerate(manifest.get("edge_fires", [])):
        service.edges[e].fires = int(fires)
    for r, fires in enumerate(manifest.get("region_fires", [])):
        service.regions[r].fires = int(fires)

    unravel = service._unravel()
    strategy = getattr(service.algo, "strategy", AggregationStrategy.MODEL)
    for e, edge in enumerate(service.edges):
        edge.buffer = []
        m = manifest["edges"].get(str(e), 0)
        for i in range(m):
            tree = unravel(jnp.asarray(arrays[f"edge{e}_rows"][i]))
            edge.buffer.append(Update(
                cid=int(arrays[f"edge{e}_cid"][i]),
                n_samples=int(arrays[f"edge{e}_n_samples"][i]),
                stale_round=int(arrays[f"edge{e}_stale_round"][i]),
                lr=float(arrays[f"edge{e}_lr"][i]),
                similarity=float(arrays[f"edge{e}_similarity"][i]),
                feedback=bool(arrays[f"edge{e}_feedback"][i]),
                speed_f=float(arrays[f"edge{e}_speed_f"][i]),
                delta=tree if strategy is AggregationStrategy.GRADIENT else None,
                params=tree if strategy is not AggregationStrategy.GRADIENT else None,
                # pre-device-state checkpoints lack these keys: all-complete
                completed_fraction=(
                    float(arrays[f"edge{e}_completed_fraction"][i])
                    if f"edge{e}_completed_fraction" in arrays else 1.0),
                sent_at=(float(arrays[f"edge{e}_sent_at"][i])
                         if f"edge{e}_sent_at" in arrays else -1.0),
            ))
    service._ingest = []
    service._ingest_members = 0
    for region in service.regions:
        region.buffer = []
    for j, meta in enumerate(manifest["partials"]):
        partial = PartialAggregate(
            tier=meta["tier"],
            node_id=int(meta["node_id"]),
            sum_w=float(meta["sum_w"]),
            cids=np.asarray(arrays[f"p{j}_cids"], np.int64),
            n_samples=np.asarray(arrays[f"p{j}_n_samples"], np.int64),
            sims=np.asarray(arrays[f"p{j}_sims"], np.float32),
            feedback=np.asarray(arrays[f"p{j}_feedback"], bool),
            stale_rounds=np.asarray(arrays[f"p{j}_stale_rounds"], np.int64),
            completed=(np.asarray(arrays[f"p{j}_completed"], np.float32)
                       if f"p{j}_completed" in arrays else None),
            fired_at=float(meta["fired_at"]),
            sum_wx=jnp.asarray(arrays[f"p{j}_sum_wx"]),
        )
        if meta["where"] == "global":
            service._ingest.append(partial)
            service._ingest_members += partial.n_members
        else:
            service.regions[int(meta["node"])].buffer.append(partial)


def load_service_state(path: str, service) -> None:
    """Restore ``save_service_state`` output into ``service`` in place."""
    from repro.core.types import ServerTable

    service.global_params = load_params(
        os.path.join(path, "global.npz"), service.global_params
    )
    with open(os.path.join(path, "service.json")) as f:
        meta = json.load(f)
    service.round = meta["round"]
    service.table = ServerTable(
        counts=jnp.asarray(meta["counts"], jnp.int32),
        sims=jnp.asarray(meta["sims"], jnp.float32),
    )
    for k, v in meta.get("stats", {}).items():
        setattr(service.stats, k, v)
    if getattr(service, "compressor", None) is not None:
        _load_compressor(path, service.compressor)
