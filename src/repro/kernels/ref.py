"""Pure-jnp oracles for every Pallas kernel (allclose targets for tests)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e9


def weighted_agg_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """x [K,D], w [K] → Σ_k w[k]·x[k]."""
    return jnp.einsum("k,kd->d", w.astype(jnp.float32), x.astype(jnp.float32))


def dequant_agg_ref(q: jax.Array, scales: jax.Array, w: jax.Array) -> jax.Array:
    """q [K,Dp] i8, scales [K,Dp/chunk], w [K] → Σ_k w[k]·q[k]·s[k,·/chunk]
    (decode-then-weighted_agg, fully materialized)."""
    K, Dp = q.shape
    nc = scales.shape[1]
    x = q.astype(jnp.float32).reshape(K, nc, Dp // nc)
    x = (x * scales.astype(jnp.float32)[:, :, None]).reshape(K, Dp)
    return weighted_agg_ref(x, w)


def segment_agg_ref(x: jax.Array, w: jax.Array, seg: jax.Array,
                    num_segments: int) -> jax.Array:
    """x [K,D], w [K], seg [K] → [G,D] per-group Σ_k [seg==g]·w[k]·x[k].

    Deliberately the same one-hot-matmul algebra as the Pallas kernel
    (not ``jax.ops.segment_sum``) so interpret-mode kernel runs are
    bit-identical in fp32 — the hierarchy's exactness gate relies on it.
    Out-of-range segment ids select no group, matching the kernel.
    """
    groups = jnp.arange(num_segments, dtype=jnp.int32)[:, None]
    selector = (groups == seg.astype(jnp.int32)[None, :]).astype(jnp.float32)
    selector = selector * w.astype(jnp.float32)[None, :]
    return jnp.dot(selector, x.astype(jnp.float32),
                   preferred_element_type=jnp.float32)


def fused_similarity_stats_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    a = a.astype(jnp.float32)
    b = b.astype(jnp.float32)
    return jnp.stack([jnp.vdot(a, b), jnp.vdot(a, a), jnp.vdot(b, b)])


def cosine_from_stats_ref(a, b):
    s = fused_similarity_stats_ref(a, b)
    return s[0] / jnp.maximum(jnp.sqrt(s[1] * s[2]), 1e-12)


def window_decode_attention_ref(q, k, v, valid_len):
    """q [B,H,dh]; k,v [B,W,KV,dh]; masked softmax over live slots."""
    B, H, dh = q.shape
    W, KV = k.shape[1], k.shape[2]
    G = H // KV
    qg = q.reshape(B, KV, G, dh).astype(jnp.float32)
    s = jnp.einsum("bkgd,bwkd->bkgw", qg, k.astype(jnp.float32)) / math.sqrt(dh)
    mask = jnp.arange(W)[None, None, None, :] < valid_len
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, -1)
    out = jnp.einsum("bkgw,bwkd->bkgd", p, v.astype(jnp.float32))
    return out.reshape(B, H, dh).astype(q.dtype)
