"""Pure-jnp oracles for every Pallas kernel (allclose targets for tests)."""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e9


def weighted_agg_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """x [K,D], w [K] → Σ_k w[k]·x[k]."""
    return jnp.einsum("k,kd->d", w.astype(jnp.float32), x.astype(jnp.float32))


def dequant_agg_ref(q: jax.Array, scales: jax.Array, w: jax.Array) -> jax.Array:
    """q [K,Dp] i8, scales [K,Dp/chunk], w [K] → Σ_k w[k]·q[k]·s[k,·/chunk]
    (decode-then-weighted_agg, fully materialized)."""
    K, Dp = q.shape
    nc = scales.shape[1]
    x = q.astype(jnp.float32).reshape(K, nc, Dp // nc)
    x = (x * scales.astype(jnp.float32)[:, :, None]).reshape(K, Dp)
    return weighted_agg_ref(x, w)


def segment_agg_ref(x: jax.Array, w: jax.Array, seg: jax.Array,
                    num_segments: int) -> jax.Array:
    """x [K,D], w [K], seg [K] → [G,D] per-group Σ_k [seg==g]·w[k]·x[k].

    Deliberately the same one-hot-matmul algebra as the Pallas kernel
    (not ``jax.ops.segment_sum``) so interpret-mode kernel runs are
    bit-identical in fp32 — the hierarchy's exactness gate relies on it.
    Out-of-range segment ids select no group, matching the kernel.
    """
    groups = jnp.arange(num_segments, dtype=jnp.int32)[:, None]
    selector = (groups == seg.astype(jnp.int32)[None, :]).astype(jnp.float32)
    selector = selector * w.astype(jnp.float32)[None, :]
    return jnp.dot(selector, x.astype(jnp.float32),
                   preferred_element_type=jnp.float32)


def ingest_weights(n_samples, F, G, fb, k, *, n_clients: int,
                   normalize: bool = True, xp=jnp, cf=None):
    """The Mod-3 weight fold shared by the fused ingestion kernel and its
    oracle: Eq. §3.4 feedback re-weighting applied to a buffer's per-row
    metadata.

    ``n_samples``/``F``/``G``/``fb`` are same-shaped arrays (the kernel
    feeds [K, 1] VMEM columns; the oracle reshapes to match so every
    elementwise op and reduction lowers identically — that is what makes
    interpret-mode kernel runs bit-exact).  ``fb`` is a f32 0/1 mask,
    ``k`` the *logical* member count as a scalar (may be traced — the
    bucketed serving path pads the row axis, so the row count of the
    arrays is not the buffer size).  Padding rows must carry
    ``n_samples = fb = 0``: their weight is exactly 0 on either branch.

    ``normalize=True`` is ``repro.core.aggregation.aggregation_weights``:
    sample-proportional base, feedback rows swapped for the §3.4 term,
    then 1/Σp normalization.  ``normalize=False`` keeps raw weights
    (base rows weigh ``n_samples`` outright) — the tier-edge form, whose
    Σw is carried beside the partial aggregate instead.

    ``cf`` is the per-row ``completed_fraction`` column (partial local
    work, docs/ROBUSTNESS.md): it scales the pre-normalization weight of
    either branch.  ``None`` skips the multiply entirely, keeping legacy
    callers on the original op sequence; an all-ones column is
    bit-identical because ``x * 1.0`` is IEEE-exact.  Padding rows must
    carry ``cf = 1`` (their weight is already exactly 0).
    """
    from repro.core.aggregation import staleness_weight

    k = xp.asarray(k, jnp.float32) if xp is jnp else np.float32(k)
    phi = k / n_clients
    w_fb = staleness_weight(F, phi, xp=xp) * (1.0 + G) ** 2 / k
    if not normalize:
        w = xp.where(fb > 0, w_fb, n_samples)
        return w if cf is None else w * cf
    base = n_samples / xp.maximum(xp.sum(n_samples), 1.0)
    p = xp.where(fb > 0, w_fb, base)
    if cf is not None:
        p = p * cf
    return p / xp.maximum(xp.sum(p), 1e-12)


def _dequant_rows(q: jax.Array, scales) -> jax.Array:
    """int8 rows → f32 rows via per-chunk scales (``scales=None`` means
    the rows are already dense f32) — the exact per-element algebra the
    ingest kernel applies per VMEM tile, so tiling cannot change bits."""
    if scales is None:
        return q.astype(jnp.float32)
    K, D = q.shape
    nc = scales.shape[1]
    x = q.astype(jnp.float32).reshape(K, nc, D // nc)
    return (x * scales.astype(jnp.float32)[:, :, None]).reshape(K, D)


@functools.partial(jax.jit, static_argnames=("n_clients", "normalize"))
def ingest_agg_ref(q: jax.Array, scales, n_samples, F, G, fb, k=None,
                   cf=None, *, n_clients: int,
                   normalize: bool = True) -> jax.Array:
    """Oracle for the fused ingestion kernel: dequantize (when ``scales``
    is given) + Eq. §3.4 weight fold + Σw·x, sharing every op with the
    kernel body so interpret mode is bit-exact.  Returns [D] f32.

    ``cf=None`` materializes an all-ones completed-fraction column — the
    kernel always carries the column, and ``x * 1.0`` is IEEE-exact, so
    legacy callers see unchanged bits.

    Jitted on purpose: the kernel body runs under the interpret-mode
    ``pallas_call`` inside a jit, where XLA fuses the exp/exp2 weight
    chain; the oracle must compile the same subgraph to land on the
    same bits (eager op-by-op execution differs at ~1e-8)."""
    K = q.shape[0]
    col = lambda v: jnp.asarray(v, jnp.float32).reshape(K, 1)
    k = jnp.float32(K) if k is None else jnp.asarray(k, jnp.float32)
    cf_col = jnp.ones((K, 1), jnp.float32) if cf is None else col(cf)
    p = ingest_weights(col(n_samples), col(F), col(G), col(fb), k,
                       n_clients=n_clients, normalize=normalize, cf=cf_col)
    x = _dequant_rows(q, scales)
    return jnp.dot(p.T, x, preferred_element_type=jnp.float32)[0]


@functools.partial(jax.jit,
                   static_argnames=("n_clients", "normalize", "block_d"))
def stats_agg_ref(x: jax.Array, n_samples, F, G, fb, k=None, cf=None, *,
                  n_clients: int, normalize: bool = True,
                  block_d: int = 4096):
    """Oracle for the fused stats kernel: same weight fold and Σw·x as
    ``ingest_agg_ref`` plus per-row squared norms and the weight column
    — ``(agg [D], row_sq [K], w [K])`` f32.

    ``row_sq`` in the kernel accumulates per-VMEM-block partials
    sequentially across grid steps, so its bits depend on the tiling.
    The oracle mirrors that exact order: per-block ``Σx²`` partials over
    ``block_d``-wide slices (default matches ``stats_agg.BLOCK_D``),
    added left to right.  Pass the kernel's ``block_d`` to compare
    against a non-default tiling.
    """
    K, D = x.shape
    col = lambda v: jnp.asarray(v, jnp.float32).reshape(K, 1)
    k = jnp.float32(K) if k is None else jnp.asarray(k, jnp.float32)
    cf_col = jnp.ones((K, 1), jnp.float32) if cf is None else col(cf)
    p = ingest_weights(col(n_samples), col(F), col(G), col(fb), k,
                       n_clients=n_clients, normalize=normalize, cf=cf_col)
    xf = x.astype(jnp.float32)
    agg = jnp.dot(p.T, xf, preferred_element_type=jnp.float32)[0]
    pad = (-D) % block_d
    xb = jnp.pad(xf, ((0, 0), (0, pad))) if pad else xf
    acc = None
    for j in range((D + pad) // block_d):
        xj = xb[:, j * block_d:(j + 1) * block_d]
        part = jnp.sum(xj * xj, axis=1, keepdims=True)
        acc = part if acc is None else acc + part
    return agg, acc[:, 0], p[:, 0]


@functools.partial(jax.jit,
                   static_argnames=("num_segments", "n_clients", "normalize"))
def ingest_segment_agg_ref(q: jax.Array, scales, seg, n_samples, F, G, fb,
                           k=None, cf=None, *, num_segments: int,
                           n_clients: int,
                           normalize: bool = False) -> jax.Array:
    """Oracle for the segment variant: per-group Σw·x̂ with the weight
    fold on-device — [G, D] f32.  Out-of-range segment ids select no
    group (the padding convention of ``segment_agg``)."""
    K = q.shape[0]
    col = lambda v: jnp.asarray(v, jnp.float32).reshape(K, 1)
    k = jnp.float32(K) if k is None else jnp.asarray(k, jnp.float32)
    cf_col = jnp.ones((K, 1), jnp.float32) if cf is None else col(cf)
    p = ingest_weights(col(n_samples), col(F), col(G), col(fb), k,
                       n_clients=n_clients, normalize=normalize, cf=cf_col)
    groups = jnp.arange(num_segments, dtype=jnp.int32)[:, None]
    selector = (groups == seg.astype(jnp.int32)[None, :]).astype(jnp.float32)
    selector = selector * p.T
    x = _dequant_rows(q, scales)
    return jnp.dot(selector, x, preferred_element_type=jnp.float32)


def fused_similarity_stats_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    a = a.astype(jnp.float32)
    b = b.astype(jnp.float32)
    return jnp.stack([jnp.vdot(a, b), jnp.vdot(a, a), jnp.vdot(b, b)])


def cosine_from_stats_ref(a, b):
    s = fused_similarity_stats_ref(a, b)
    return s[0] / jnp.maximum(jnp.sqrt(s[1] * s[2]), 1e-12)


def window_decode_attention_ref(q, k, v, valid_len):
    """q [B,H,dh]; k,v [B,W,KV,dh]; masked softmax over live slots."""
    B, H, dh = q.shape
    W, KV = k.shape[1], k.shape[2]
    G = H // KV
    qg = q.reshape(B, KV, G, dh).astype(jnp.float32)
    s = jnp.einsum("bkgd,bwkd->bkgw", qg, k.astype(jnp.float32)) / math.sqrt(dh)
    mask = jnp.arange(W)[None, None, None, :] < valid_len
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, -1)
    out = jnp.einsum("bkgw,bwkd->bkgd", p, v.astype(jnp.float32))
    return out.reshape(B, H, dh).astype(q.dtype)
