"""Block-size autotuner for the Pallas kernel suite.

Every kernel in ``repro.kernels`` tiles the model dimension D into
VMEM-resident blocks; the fastest block size depends on (kernel, buffer
shape, dtype, backend) — K rows share VMEM with the output tile, so a
K=256 segment reduce wants smaller blocks than a K=10 flat reduce.  In
the spirit of xformers' Triton config sweeps, this module measures each
candidate once, persists the winner in an on-disk JSON cache, and the
``*_auto_op`` dispatchers in ``repro.kernels.ops`` consult the cache on
every call (a dict lookup — no measurement ever happens on a serving
hot path).

Cache contract (docs/KERNELS.md):

* keyed by ``<kernel>|k<Kb>xd<Db>|<dtype>|<backend>`` where Kb/Db round
  the buffer shape up to powers of two (shape *buckets*, so a stream
  whose K jitters by one does not re-tune);
* written atomically (tmp file + ``os.replace``) so a crash mid-write
  never corrupts it;
* a missing or corrupt cache degrades to the built-in defaults with a
  single warning — never an exception;
* deterministic: ties break toward the smaller block, and any process
  that finds a cached entry returns it verbatim, so one sweep fixes the
  config fleet-wide.

Results are bit-identical regardless of which config wins: block size
only partitions the output axis, and every out[d] is one K-length dot
whichever tile it lands in (pinned by ``tests/test_autotune.py``).
"""
from __future__ import annotations

import json
import os
import time
import warnings
from dataclasses import dataclass
from typing import Callable, Dict, Optional, Sequence, Tuple

import jax

from repro.telemetry import profile as _profile

DEFAULT_BLOCKS: Dict[str, int] = {
    "weighted_agg": 4096,
    "dequant_agg": 4096,
    "segment_agg": 2048,
    "ingest_agg": 4096,
    "ingest_segment_agg": 2048,
}

CANDIDATE_BLOCKS: Dict[str, Tuple[int, ...]] = {
    "weighted_agg": (512, 1024, 2048, 4096, 8192),
    "dequant_agg": (512, 1024, 2048, 4096, 8192),
    "segment_agg": (256, 512, 1024, 2048, 4096),
    "ingest_agg": (512, 1024, 2048, 4096, 8192),
    "ingest_segment_agg": (256, 512, 1024, 2048, 4096),
}

ENV_CACHE = "REPRO_AUTOTUNE_CACHE"


@dataclass(frozen=True)
class KernelConfig:
    block_d: int
    source: str = "default"  # "default" | "cache" | "measured"
    us: Optional[float] = None       # measured wall-µs per call (winner)
    gbps: Optional[float] = None     # achieved HBM GB/s, when bytes known


def _pow2_ceil(x: int) -> int:
    return 1 << max(int(x) - 1, 0).bit_length() if x > 1 else 1


def shape_bucket(shape: Sequence[int]) -> Tuple[int, ...]:
    """Round every dim up to a power of two — the cache granularity."""
    return tuple(_pow2_ceil(int(d)) for d in shape)


def cache_key(kernel: str, shape: Sequence[int], dtype,
              backend: Optional[str] = None) -> str:
    kb, db = shape_bucket(shape[:2]) if len(shape) >= 2 else (1, *shape_bucket(shape))
    backend = backend or jax.default_backend()
    return f"{kernel}|k{kb}xd{db}|{jax.numpy.dtype(dtype).name}|{backend}"


def default_cache_path(backend: Optional[str] = None) -> str:
    path = os.environ.get(ENV_CACHE)
    if path:
        return path
    backend = backend or jax.default_backend()
    root = os.path.abspath(os.path.join(os.path.dirname(__file__),
                                        "..", "..", ".."))
    return os.path.join(root, "experiments", "autotune", f"{backend}.json")


def load_cache(path: Optional[str] = None) -> Dict[str, dict]:
    """Read the config cache; missing → {} silently, corrupt → {} with a
    warning.  Autotuning must never be able to take the service down."""
    path = path or default_cache_path()
    if not os.path.exists(path):
        return {}
    try:
        with open(path, encoding="utf-8") as fh:
            cache = json.load(fh)
        if not isinstance(cache, dict):
            raise ValueError(f"expected a JSON object, got {type(cache).__name__}")
        return cache
    except Exception as exc:  # corrupt file, partial write, bad perms, ...
        warnings.warn(
            f"autotune cache {path} unreadable ({exc}); "
            "falling back to default kernel configs", RuntimeWarning)
        return {}


def save_cache(cache: Dict[str, dict], path: Optional[str] = None) -> str:
    """Atomic write (tmp + rename): a crash never leaves a torn cache."""
    path = path or default_cache_path()
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "w", encoding="utf-8") as fh:
        json.dump(cache, fh, indent=2, sort_keys=True)
        fh.write("\n")
    os.replace(tmp, path)
    return path


# process-wide view of the on-disk cache, loaded once per path
_LOADED: Dict[str, Dict[str, dict]] = {}


def reload_cache(path: Optional[str] = None) -> None:
    """Drop the in-process view (tests; or after an external sweep)."""
    if path is None:
        _LOADED.clear()
    else:
        _LOADED.pop(path, None)


def get_config(kernel: str, shape: Sequence[int], dtype,
               backend: Optional[str] = None,
               path: Optional[str] = None) -> KernelConfig:
    """Cache lookup → ``KernelConfig``; never measures, never raises.
    The ``*_auto_op`` hot-path entry: a couple of dict probes.  An
    active profiler (``repro.telemetry.profile``) counts each probe as
    an autotune cache hit or miss."""
    path = path or default_cache_path(backend)
    if path not in _LOADED:
        _LOADED[path] = load_cache(path)
    entry = _LOADED[path].get(cache_key(kernel, shape, dtype, backend))
    default = DEFAULT_BLOCKS.get(kernel, 4096)
    hit = isinstance(entry, dict) and isinstance(entry.get("block_d"), int)
    prof = _profile.active()
    if prof is not None:
        prof.config_probe(hit)
    if not hit:
        return KernelConfig(block_d=default)
    return KernelConfig(block_d=entry["block_d"], source="cache",
                        us=entry.get("us"), gbps=entry.get("gbps"))


def _default_timer(fn: Callable[[], object], repeats: int) -> float:
    """Best-of-N wall time in µs; blocks on the result each call."""
    fn()  # compile / warm
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn()
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def autotune(kernel: str, make_call: Callable[[int], Callable[[], object]],
             shape: Sequence[int], dtype, *,
             candidates: Optional[Sequence[int]] = None,
             repeats: int = 3, timer=None,
             bytes_moved: Optional[int] = None,
             backend: Optional[str] = None,
             path: Optional[str] = None) -> KernelConfig:
    """Measure every candidate block size and persist the winner.

    ``make_call(block_d)`` returns a zero-arg callable running the kernel
    at that block size; ``timer(fn, repeats) -> µs`` is injectable so
    tests can pin a deterministic cost model.  A cached entry short-
    circuits the sweep — determinism across processes comes from the
    shared cache, and ties break toward the smaller block so even a
    degenerate timer chooses reproducibly.
    """
    cached = get_config(kernel, shape, dtype, backend=backend, path=path)
    if cached.source == "cache":
        return cached
    timer = timer or _default_timer
    measured: Dict[int, float] = {}
    for block_d in candidates or CANDIDATE_BLOCKS.get(kernel, (2048, 4096)):
        try:
            measured[block_d] = float(timer(make_call(block_d), repeats))
        except Exception as exc:
            warnings.warn(f"autotune {kernel} block_d={block_d} failed: {exc}",
                          RuntimeWarning)
    if not measured:
        return KernelConfig(block_d=DEFAULT_BLOCKS.get(kernel, 4096))
    best_block = min(measured, key=lambda b: (measured[b], b))
    us = measured[best_block]
    gbps = (bytes_moved / (us * 1e-6) / 1e9) if bytes_moved and us > 0 else None
    path = path or default_cache_path(backend)
    cache = load_cache(path)
    cache[cache_key(kernel, shape, dtype, backend)] = {
        "kernel": kernel,
        "block_d": best_block,
        "us": round(us, 2),
        "gbps": round(gbps, 3) if gbps is not None else None,
        "bytes": bytes_moved,
        "candidates_us": {str(b): round(u, 2) for b, u in sorted(measured.items())},
    }
    save_cache(cache, path)
    reload_cache(path)
    return KernelConfig(block_d=best_block, source="measured", us=us, gbps=gbps)


def roofline_rows(path: Optional[str] = None,
                  hbm_bw: Optional[float] = None) -> list:
    """Cache entries → per-kernel roofline rows: these kernels are pure
    HBM streamers (≈2 flops/byte), so %-of-roofline is achieved GB/s
    against the HBM bandwidth ceiling (``repro.launch.analysis.HBM_BW``).
    Consumed by ``benchmarks/roofline.py`` and the ``ingest`` suite."""
    if hbm_bw is None:
        from repro.launch.analysis import HBM_BW
        hbm_bw = HBM_BW
    rows = []
    for key, entry in sorted(load_cache(path).items()):
        if not isinstance(entry, dict) or entry.get("gbps") is None:
            continue
        rows.append({
            "key": key,
            "kernel": entry.get("kernel", key.split("|")[0]),
            "block_d": entry.get("block_d"),
            "us": entry.get("us"),
            "gbps": entry["gbps"],
            "pct_roofline": round(100.0 * entry["gbps"] * 1e9 / hbm_bw, 2),
        })
    return rows
