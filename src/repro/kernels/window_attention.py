"""Pallas TPU kernel: sliding-window decode attention.

The hot op of the ``long_500k`` / gemma3-local decode path: one query token
per sequence attends to a ring-buffered window of W cached KV positions.
Memory-bound: per (batch, kv-head) we stream W·dh keys + W·dh values once
through VMEM, compute the [G, W] score tile (MXU), softmax it in-register,
and produce [G, dh].  No [S, S] tensor, no HBM round-trip for scores.

Grid: (B, KV).  Blocks: q (1, 1, G, dh); k/v (1, W, 1, dh); an additive
mask (1, W) carries ring-validity (0 for live slots, −inf for empty) —
precomputed by the wrapper so the kernel stays scalar-free.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e9


def _window_attn_kernel(q_ref, k_ref, v_ref, mask_ref, o_ref):
    # q [1,1,G,dh]; k,v [1,W,1,dh]; mask [1,W]; o [1,1,G,dh]
    q = q_ref[0, 0].astype(jnp.float32)                  # [G, dh]
    k = k_ref[0, :, 0].astype(jnp.float32)               # [W, dh]
    v = v_ref[0, :, 0].astype(jnp.float32)               # [W, dh]
    dh = q.shape[-1]
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) / math.sqrt(dh)
    s = s + mask_ref[...]                                # [G, W] + [1, W]
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    o_ref[0, 0] = jnp.dot(p, v, preferred_element_type=jnp.float32).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def window_decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                            valid_len: jax.Array, *, interpret: bool = False):
    """q [B,H,dh]; k,v [B,W,KV,dh] ring caches; valid_len scalar i32 =
    number of live slots.  Returns [B,H,dh]."""
    B, H, dh = q.shape
    W, KV = k.shape[1], k.shape[2]
    G = H // KV
    qg = q.reshape(B, KV, G, dh)
    mask = jnp.where(jnp.arange(W)[None, :] < valid_len, 0.0, NEG_INF)
    mask = jnp.broadcast_to(mask.astype(jnp.float32), (B, W))
    out = pl.pallas_call(
        _window_attn_kernel,
        grid=(B, KV),
        in_specs=[
            pl.BlockSpec((1, 1, G, dh), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((1, W, 1, dh), lambda b, h: (b, 0, h, 0)),
            pl.BlockSpec((1, W, 1, dh), lambda b, h: (b, 0, h, 0)),
            pl.BlockSpec((1, W), lambda b, h: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, dh), lambda b, h: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, dh), q.dtype),
        interpret=interpret,
    )(qg, k, v, mask)
    return out.reshape(B, H, dh)
