"""Pallas TPU kernel: segment-reduce weighted aggregation for the
hierarchical plane (``repro.hier``).

    out[g, d] = Σ_k [seg[k] == g] · w[k] · x[k, d]

The tiered aggregation plane stacks every member row of a region's
*ready* edge buffers into one [K, D] matrix with a per-row segment id
(= which edge the row belongs to).  Reducing edge-by-edge would cost one
kernel launch per edge and re-read the weight/one-hot bookkeeping each
time; the segment kernel computes **all** per-edge partial sums in a
single VMEM pass — the [K, blk] tile is read once and multiplied by a
[G, K] one-hot-times-weight matrix on the MXU, producing every group's
Σw·x for that block simultaneously.

Tiling: grid over D/BLOCK_D; per step the (K, BLOCK_D) row tile sits in
VMEM with the (K, 1) weight and segment-id columns.  The [G, K] selector
is rebuilt per step from an iota compare — G·K ops, negligible against
the G·K·BLOCK_D matmul it feeds.

The one-hot-matmul algebra is deliberately shared with
``repro.kernels.ref.segment_agg_ref`` so interpret-mode runs are
bit-identical to the oracle (the acceptance gate in
``benchmarks/bench_hier.py`` checks exact fp32 equality).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

BLOCK_D = 2048  # f32: (K + G)×2048×4B tiles; K=256, G=64 → 2.6 MiB VMEM


def _segment_agg_kernel(seg_ref, w_ref, x_ref, o_ref):
    # seg_ref [K, 1] i32, w_ref [K, 1] f32, x_ref [K, blk] f32, o_ref [G, blk]
    G = o_ref.shape[0]
    K = x_ref.shape[0]
    groups = jax.lax.broadcasted_iota(jnp.int32, (G, K), 0)
    selector = (groups == seg_ref[...].T).astype(jnp.float32) * w_ref[...].T
    o_ref[...] = jnp.dot(
        selector, x_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(
    jax.jit, static_argnames=("num_segments", "block_d", "interpret")
)
def segment_agg(x: jax.Array, w: jax.Array, seg: jax.Array, *,
                num_segments: int, block_d: int = BLOCK_D,
                interpret: bool = False) -> jax.Array:
    """x [K, D] f32, w [K] f32, seg [K] i32 → [G, D] f32 per-group Σw·x.

    Rows whose segment id falls outside [0, num_segments) contribute to
    no group (the one-hot selector row is all-zero) — the hierarchy uses
    this for padding rows.
    """
    K, D = x.shape
    if w.shape != (K,) or seg.shape != (K,):
        raise ValueError(
            f"w {w.shape} and seg {seg.shape} must both be [{K}] to match x"
        )
    if num_segments < 1:
        raise ValueError(f"num_segments must be >= 1, got {num_segments}")
    pad = (-D) % block_d
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad)))
    Dp = D + pad
    out = pl.pallas_call(
        _segment_agg_kernel,
        grid=(Dp // block_d,),
        in_specs=[
            pl.BlockSpec((K, 1), lambda i: (0, 0)),
            pl.BlockSpec((K, 1), lambda i: (0, 0)),
            pl.BlockSpec((K, block_d), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((num_segments, block_d), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((num_segments, Dp), jnp.float32),
        interpret=interpret,
    )(seg.astype(jnp.int32)[:, None], w.astype(jnp.float32)[:, None],
      x.astype(jnp.float32))
    return out[:, :D]


def segment_agg_sharded(x: jax.Array, w: jax.Array, seg: jax.Array, *,
                        num_segments: int, axis_name: str = "edges",
                        devices=None) -> jax.Array:
    """Multi-device segment reduce: shard the stacked row axis.

    Each device runs one ``segment_agg`` launch over its row shard (rows
    of any segment may land on any device) and the per-device [G, D]
    partials ``psum`` across the mesh — tiers aggregate in parallel with
    one collective.  Rows are zero-weight-padded up to a multiple of the
    device count; on a single device this degenerates to one local
    launch (no mesh, no collective).
    """
    from repro.kernels.ops import segment_agg_op

    devices = list(jax.devices() if devices is None else devices)
    n_dev = len(devices)
    if n_dev == 1:
        return segment_agg_op(x, w, seg, num_segments=num_segments)

    from jax.experimental.shard_map import shard_map
    from jax.sharding import Mesh, PartitionSpec as P

    K = x.shape[0]
    pad = (-K) % n_dev
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
        w = jnp.pad(w, (0, pad))            # zero weight → contributes 0
        seg = jnp.pad(seg, (0, pad))
    mesh = Mesh(np.asarray(devices), (axis_name,))

    def local_reduce(xs, ws, ss):
        part = segment_agg_op(xs, ws, ss, num_segments=num_segments)
        return jax.lax.psum(part, axis_name)

    fn = shard_map(
        local_reduce,
        mesh=mesh,
        in_specs=(P(axis_name, None), P(axis_name), P(axis_name)),
        out_specs=P(None, None),
    )
    return fn(x, w, seg)
