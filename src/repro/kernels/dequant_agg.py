"""Pallas TPU kernel: fused dequantize + Mod-3 weighted aggregation.

    out[d] = Σ_k w[k] · q[k,d] · s[k, d // chunk]

The compressed-transport buffer stacks K quantized client rows
(int8, per-chunk f32 scales — ``repro.compress``) and reduces them with
externally computed Mod-3 weights.  Doing decode-then-``weighted_agg``
would materialize a [K, D] f32 matrix in HBM (4·K·D bytes written, then
read again); the fused kernel reads each int8 byte exactly once —
**≈ 4× less HBM traffic than even the dense kernel** — dequantizes in
VMEM registers, and runs the weighted reduction on the spot.

Tiling: grid over D/block; per step the (K, block) int8 tile, its
(K, block/chunk) scale columns and the (K, 1) weight column live in VMEM
together (int8 halves the f32 tile footprint even after the f32
upcast for the multiply).  ``block`` is the largest multiple of the
scale chunk ≤ ``BLOCK_D`` so scale columns never straddle tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_D = 4096  # int8: K×4096 ≤ 16·4096 = 64 KiB per tile for K=16


def _dequant_agg_kernel(w_ref, s_ref, q_ref, o_ref):
    # w_ref [K, 1], s_ref [K, NC], q_ref [K, BLK] i8, o_ref [1, BLK] f32
    K, blk = q_ref.shape
    nc = s_ref.shape[1]
    x = q_ref[...].astype(jnp.float32).reshape(K, nc, blk // nc)
    x = (x * s_ref[...][:, :, None]).reshape(K, blk)
    o_ref[...] = jnp.dot(
        w_ref[...].T, x, preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("chunk", "block_d", "interpret"))
def dequant_agg(q: jax.Array, scales: jax.Array, w: jax.Array, *,
                chunk: int, block_d: int = BLOCK_D,
                interpret: bool = False) -> jax.Array:
    """q [K, Dp] int8, scales [K, Dp/chunk] f32, w [K] f32 → [Dp] f32.

    ``Dp`` must be a multiple of ``chunk`` (the encoder pads to it);
    further padding up to the kernel block is handled here with zero
    rows/scales, which contribute exactly 0 to the reduction.
    """
    K, Dp = q.shape
    if Dp % chunk:
        raise ValueError(f"D={Dp} must be a multiple of chunk={chunk}")
    if scales.shape != (K, Dp // chunk):
        raise ValueError(
            f"scales shape {scales.shape} != {(K, Dp // chunk)} for chunk={chunk}"
        )
    blk = max(chunk, block_d - block_d % chunk)  # whole chunks per tile
    pad = (-Dp) % blk
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad)))
        scales = jnp.pad(scales, ((0, 0), (0, pad // chunk)))
    nc_blk = blk // chunk
    out = pl.pallas_call(
        _dequant_agg_kernel,
        grid=((Dp + pad) // blk,),
        in_specs=[
            pl.BlockSpec((K, 1), lambda i: (0, 0)),
            pl.BlockSpec((K, nc_blk), lambda i: (0, i)),
            pl.BlockSpec((K, blk), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((1, blk), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, Dp + pad), jnp.float32),
        interpret=interpret,
    )(w.astype(jnp.float32)[:, None], scales.astype(jnp.float32),
      q.astype(jnp.int8))
    return out[0, :Dp]
