"""Pallas TPU kernel: Mod-3 weighted aggregation  out[d] = Σ_k w[k]·x[k,d].

The server's K-buffer aggregation is a K-way weighted reduction over
model-dimension vectors — purely memory-bound (arithmetic intensity
≈ 2·K FLOPs per 4·K bytes read).  The kernel tiles the model dimension D
into VMEM-resident blocks so every parameter byte is read exactly once and
the weighted reduction happens on-chip, vs. the naive jnp form which
XLA may lower as K separate scale+add passes over HBM.

Tiling: grid over D/BLOCK_D; per step the (K, BLOCK_D) tile of stacked
updates sits in VMEM together with the (K, 1) weight column; the matvec
w^T·X runs on the MXU (K and BLOCK_D are 8/128-aligned by padding).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_D = 4096  # f32: K×4096×4B ≤ 16·4096·4 = 256 KiB per tile for K=16


def _weighted_agg_kernel(w_ref, x_ref, o_ref):
    # w_ref [K, 1], x_ref [K, BLOCK_D], o_ref [1, BLOCK_D]
    o_ref[...] = jnp.dot(
        w_ref[...].T, x_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def weighted_agg(x: jax.Array, w: jax.Array, *, block_d: int = BLOCK_D,
                 interpret: bool = False) -> jax.Array:
    """x [K, D] f32, w [K] f32 → [D] f32 = Σ_k w[k]·x[k]."""
    K, D = x.shape
    pad = (-D) % block_d
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad)))
    Dp = D + pad
    out = pl.pallas_call(
        _weighted_agg_kernel,
        grid=(Dp // block_d,),
        in_specs=[
            pl.BlockSpec((K, 1), lambda i: (0, 0)),
            pl.BlockSpec((K, block_d), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((1, block_d), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, Dp), jnp.float32),
        interpret=interpret,
    )(w.astype(jnp.float32)[:, None], x.astype(jnp.float32))
    return out[0, :D]
