"""Pallas TPU kernel: the fused ingestion pass — int8 dequantize +
Eq. §3.4 staleness-decay weighting + Σw·x in ONE double-buffered VMEM
sweep.

    p   = fold(n, F, G, fb)                 # feedback_weight on-device
    out[d] = Σ_k p[k] · q[k,d] · s[k, d // chunk]

The streaming service's hot loop used to run three stages per fire:
dequantize (``dequant_agg``), the Mod-3 weight algebra host-side /
as a dozen tiny XLA dispatches, then the weighted reduce
(``weighted_agg``).  This kernel folds the §3.4 ``feedback_weight``
term into the reduction weights *inside* the kernel: the per-row
metadata columns (n_samples, F, G, feedback mask — a few f32 per row)
ride along in VMEM, the weight vector is rebuilt per grid step from a
handful of VPU ops (negligible against the K×BLOCK matmul it feeds),
and every int8 payload byte still crosses HBM exactly once.  Pallas's
grid pipeline double-buffers the tile DMAs against compute, exactly as
in ``weighted_agg``/``dequant_agg``.

The logical member count ``k`` arrives as a (1, 1) operand rather than
a static — the serving path pads the row axis to a shape bucket so
variable-K triggers (time-window, quorum grace) stop paying a per-shape
compile, and padding rows (n = fb = 0) weigh exactly 0.

``ingest_segment_agg`` is the tier-edge variant: per-group Σw·x̂ over a
stacked buffer with per-row segment ids, so every int8 edge buffer of a
hierarchical fire reduces in one launch (cf. ``segment_agg``).

Weight algebra lives in ``repro.kernels.ref.ingest_weights`` and is
shared verbatim with the oracles, so interpret-mode runs are bit-exact
(the contract ``tests/test_kernel_parity.py`` fuzzes).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import ingest_weights

BLOCK_D = 4096          # dense f32 tiles: matches weighted_agg
BLOCK_D_SEGMENT = 2048  # segment variant carries a [G, blk] output tile too


def _fold(k_ref, n_ref, F_ref, G_ref, fb_ref, cf_ref, *, n_clients,
          normalize):
    # [K, 1] metadata columns → [K, 1] reduction weights, recomputed per
    # grid step (K-length VPU ops — free next to the K×blk matmul).  The
    # completed-fraction column is always carried: all-ones for complete
    # updates (``x * 1.0`` is IEEE-exact, so legacy bits are unchanged).
    return ingest_weights(
        n_ref[...], F_ref[...], G_ref[...], fb_ref[...], k_ref[0, 0],
        n_clients=n_clients, normalize=normalize, cf=cf_ref[...],
    )


def _ingest_dense_kernel(k_ref, n_ref, F_ref, G_ref, fb_ref, cf_ref, x_ref,
                         o_ref, *, n_clients, normalize):
    p = _fold(k_ref, n_ref, F_ref, G_ref, fb_ref, cf_ref,
              n_clients=n_clients, normalize=normalize)
    o_ref[...] = jnp.dot(
        p.T, x_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )


def _ingest_quant_kernel(k_ref, n_ref, F_ref, G_ref, fb_ref, cf_ref, s_ref,
                         q_ref, o_ref, *, n_clients, normalize):
    p = _fold(k_ref, n_ref, F_ref, G_ref, fb_ref, cf_ref,
              n_clients=n_clients, normalize=normalize)
    K, blk = q_ref.shape
    nc = s_ref.shape[1]
    x = q_ref[...].astype(jnp.float32).reshape(K, nc, blk // nc)
    x = (x * s_ref[...][:, :, None]).reshape(K, blk)
    o_ref[...] = jnp.dot(p.T, x, preferred_element_type=jnp.float32)


def _meta_cols(q, n_samples, F, G, fb, k, cf):
    K = q.shape[0]
    col = lambda v: jnp.asarray(v, jnp.float32).reshape(K, 1)
    k = jnp.float32(K) if k is None else jnp.asarray(k, jnp.float32)
    cf_col = jnp.ones((K, 1), jnp.float32) if cf is None else col(cf)
    return k.reshape(1, 1), col(n_samples), col(F), col(G), col(fb), cf_col


@functools.partial(jax.jit, static_argnames=(
    "chunk", "n_clients", "normalize", "block_d", "interpret"))
def ingest_agg(q: jax.Array, scales, n_samples, F, G, fb, k=None, cf=None, *,
               chunk: int = 0, n_clients: int, normalize: bool = True,
               block_d: int = BLOCK_D, interpret: bool = False) -> jax.Array:
    """Fused ingestion reduce → [D] f32 (see module docstring).

    ``q`` is [K, D] int8 with per-chunk f32 ``scales`` [K, D/chunk]
    (``chunk`` required, D a multiple of it), or [K, D] dense rows with
    ``scales=None``.  ``n_samples``/``F``/``G``/``fb`` are [K] f32 rows
    of per-member metadata; ``k`` the logical member count (defaults to
    the row count; pass the unpadded count when the row axis is
    bucketed); ``cf`` the per-row completed fraction (``None`` → all
    complete; padding rows must carry 1.0).  Padding up to the kernel
    block adds zero columns that reduce to exactly 0.
    """
    K, D = q.shape
    kcol, ncol, Fcol, Gcol, fbcol, cfcol = _meta_cols(
        q, n_samples, F, G, fb, k, cf)
    meta_specs = [pl.BlockSpec((1, 1), lambda i: (0, 0))] + [
        pl.BlockSpec((K, 1), lambda i: (0, 0)) for _ in range(5)
    ]
    if scales is None:
        blk = block_d
        pad = (-D) % blk
        x = jnp.pad(q, ((0, 0), (0, pad))) if pad else q
        out = pl.pallas_call(
            functools.partial(_ingest_dense_kernel, n_clients=n_clients,
                              normalize=normalize),
            grid=((D + pad) // blk,),
            in_specs=meta_specs + [pl.BlockSpec((K, blk), lambda i: (0, i))],
            out_specs=pl.BlockSpec((1, blk), lambda i: (0, i)),
            out_shape=jax.ShapeDtypeStruct((1, D + pad), jnp.float32),
            interpret=interpret,
        )(kcol, ncol, Fcol, Gcol, fbcol, cfcol, x.astype(jnp.float32))
        return out[0, :D]
    if chunk <= 0:
        raise ValueError("quantized rows need chunk > 0")
    if D % chunk:
        raise ValueError(f"D={D} must be a multiple of chunk={chunk}")
    if scales.shape != (K, D // chunk):
        raise ValueError(
            f"scales shape {scales.shape} != {(K, D // chunk)} for chunk={chunk}")
    blk = max(chunk, block_d - block_d % chunk)  # whole chunks per tile
    pad = (-D) % blk
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad)))
        scales = jnp.pad(scales, ((0, 0), (0, pad // chunk)))
    nc_blk = blk // chunk
    out = pl.pallas_call(
        functools.partial(_ingest_quant_kernel, n_clients=n_clients,
                          normalize=normalize),
        grid=((D + pad) // blk,),
        in_specs=meta_specs + [
            pl.BlockSpec((K, nc_blk), lambda i: (0, i)),
            pl.BlockSpec((K, blk), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((1, blk), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, D + pad), jnp.float32),
        interpret=interpret,
    )(kcol, ncol, Fcol, Gcol, fbcol, cfcol, scales.astype(jnp.float32),
      q.astype(jnp.int8))
    return out[0, :D]


def _ingest_segment_dense_kernel(k_ref, seg_ref, n_ref, F_ref, G_ref, fb_ref,
                                 cf_ref, x_ref, o_ref, *, n_clients,
                                 normalize):
    p = _fold(k_ref, n_ref, F_ref, G_ref, fb_ref, cf_ref,
              n_clients=n_clients, normalize=normalize)
    G_out, K = o_ref.shape[0], x_ref.shape[0]
    groups = jax.lax.broadcasted_iota(jnp.int32, (G_out, K), 0)
    selector = (groups == seg_ref[...].T).astype(jnp.float32) * p.T
    o_ref[...] = jnp.dot(
        selector, x_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )


def _ingest_segment_quant_kernel(k_ref, seg_ref, n_ref, F_ref, G_ref, fb_ref,
                                 cf_ref, s_ref, q_ref, o_ref, *, n_clients,
                                 normalize):
    p = _fold(k_ref, n_ref, F_ref, G_ref, fb_ref, cf_ref,
              n_clients=n_clients, normalize=normalize)
    G_out, (K, blk) = o_ref.shape[0], q_ref.shape
    nc = s_ref.shape[1]
    x = q_ref[...].astype(jnp.float32).reshape(K, nc, blk // nc)
    x = (x * s_ref[...][:, :, None]).reshape(K, blk)
    groups = jax.lax.broadcasted_iota(jnp.int32, (G_out, K), 0)
    selector = (groups == seg_ref[...].T).astype(jnp.float32) * p.T
    o_ref[...] = jnp.dot(selector, x, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=(
    "chunk", "num_segments", "n_clients", "normalize", "block_d", "interpret"))
def ingest_segment_agg(q: jax.Array, scales, seg, n_samples, F, G, fb,
                       k=None, cf=None, *, num_segments: int, chunk: int = 0,
                       n_clients: int, normalize: bool = False,
                       block_d: int = BLOCK_D_SEGMENT,
                       interpret: bool = False) -> jax.Array:
    """Per-group fused ingestion reduce → [G, D] f32.

    Same payload/metadata contract as ``ingest_agg`` plus a [K] i32
    segment id per row; rows whose id falls outside [0, num_segments)
    contribute to no group (the padding convention the tier plane uses).
    ``normalize`` defaults to False — edges forward raw Σw·x̂ with Σw
    carried beside the partial; True normalizes over the WHOLE buffer
    (not per group).
    """
    K, D = q.shape
    if seg.shape != (K,):
        raise ValueError(f"seg {seg.shape} must be [{K}] to match rows")
    if num_segments < 1:
        raise ValueError(f"num_segments must be >= 1, got {num_segments}")
    kcol, ncol, Fcol, Gcol, fbcol, cfcol = _meta_cols(
        q, n_samples, F, G, fb, k, cf)
    segcol = seg.astype(jnp.int32)[:, None]
    meta_specs = [pl.BlockSpec((1, 1), lambda i: (0, 0))] + [
        pl.BlockSpec((K, 1), lambda i: (0, 0)) for _ in range(6)
    ]
    if scales is None:
        blk = block_d
        pad = (-D) % blk
        x = jnp.pad(q, ((0, 0), (0, pad))) if pad else q
        out = pl.pallas_call(
            functools.partial(_ingest_segment_dense_kernel,
                              n_clients=n_clients, normalize=normalize),
            grid=((D + pad) // blk,),
            in_specs=meta_specs + [pl.BlockSpec((K, blk), lambda i: (0, i))],
            out_specs=pl.BlockSpec((num_segments, blk), lambda i: (0, i)),
            out_shape=jax.ShapeDtypeStruct((num_segments, D + pad), jnp.float32),
            interpret=interpret,
        )(kcol, segcol, ncol, Fcol, Gcol, fbcol, cfcol,
          x.astype(jnp.float32))
        return out[:, :D]
    if chunk <= 0:
        raise ValueError("quantized rows need chunk > 0")
    if D % chunk:
        raise ValueError(f"D={D} must be a multiple of chunk={chunk}")
    blk = max(chunk, block_d - block_d % chunk)
    pad = (-D) % blk
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad)))
        scales = jnp.pad(scales, ((0, 0), (0, pad // chunk)))
    nc_blk = blk // chunk
    out = pl.pallas_call(
        functools.partial(_ingest_segment_quant_kernel,
                          n_clients=n_clients, normalize=normalize),
        grid=((D + pad) // blk,),
        in_specs=meta_specs + [
            pl.BlockSpec((K, nc_blk), lambda i: (0, i)),
            pl.BlockSpec((K, blk), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((num_segments, blk), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((num_segments, D + pad), jnp.float32),
        interpret=interpret,
    )(kcol, segcol, ncol, Fcol, Gcol, fbcol, cfcol,
      scales.astype(jnp.float32), q.astype(jnp.int8))
    return out[:, :D]
