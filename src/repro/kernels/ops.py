"""Jit'd public wrappers for the Pallas kernels.

On a real TPU these dispatch to the compiled kernels; on CPU (this
container) they run the kernel bodies under ``interpret=True`` so the
exact same code path is validated.  Set ``REPRO_KERNEL_MODE=ref`` to force
the pure-jnp oracles (used by A/B benchmarking).
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.telemetry import profile as _profile

from . import ref as _ref
from .autotune import get_config
from .dequant_agg import dequant_agg
from .ingest_agg import ingest_agg, ingest_segment_agg
from .segment_agg import segment_agg
from .stats_agg import stats_agg
from .similarity import cosine_from_stats, fused_similarity_stats
from .weighted_agg import weighted_agg
from .window_attention import window_decode_attention

_ON_TPU = jax.default_backend() == "tpu"
_FORCE_REF = os.environ.get("REPRO_KERNEL_MODE", "") == "ref"
_INTERPRET = not _ON_TPU


def _tuned_block(kernel: str, shape, dtype) -> int:
    """Autotuned block size for a compiled-TPU dispatch — a cache probe
    (``kernels/autotune.py``), never a measurement.  Bit-identical
    results whichever config wins: block size only partitions the
    output axis, and each out[d] is one K-length dot either way."""
    return get_config(kernel, shape, dtype).block_d


def weighted_agg_op(x, w):
    if _FORCE_REF:
        return _ref.weighted_agg_ref(x, w)
    return weighted_agg(x, w, interpret=_INTERPRET)


def weighted_agg_auto_op(x, w):
    """Throughput-oriented dispatch for the streaming service: the compiled
    Pallas kernel on TPU, the jnp oracle elsewhere.  Unlike ``weighted_agg_op``
    (which exercises the kernel body under interpret=True for validation),
    this never pays interpret-mode cost on a serving hot path."""
    if _ON_TPU and not _FORCE_REF:
        return weighted_agg(x, w,
                            block_d=_tuned_block("weighted_agg", x.shape, x.dtype))
    return _ref.weighted_agg_ref(x, w)


def dequant_agg_op(q, scales, w, *, chunk):
    if _FORCE_REF:
        return _ref.dequant_agg_ref(q, scales, w)
    return dequant_agg(q, scales, w, chunk=chunk, interpret=_INTERPRET)


def dequant_agg_auto_op(q, scales, w, *, chunk):
    """Throughput dispatch for the compressed aggregation hot path: the
    fused Pallas kernel on TPU, the jnp decode-then-reduce oracle
    elsewhere (interpret-mode Pallas is too slow for an ingest loop)."""
    if _ON_TPU and not _FORCE_REF:
        return dequant_agg(q, scales, w, chunk=chunk,
                           block_d=_tuned_block("dequant_agg", q.shape, q.dtype))
    return _ref.dequant_agg_ref(q, scales, w)


def segment_agg_op(x, w, seg, *, num_segments):
    if _FORCE_REF:
        return _ref.segment_agg_ref(x, w, seg, num_segments)
    return segment_agg(x, w, seg, num_segments=num_segments,
                       interpret=_INTERPRET)


def segment_agg_auto_op(x, w, seg, *, num_segments):
    """Throughput dispatch for the tiered aggregation hot path: the
    compiled segment kernel on TPU, the one-hot-matmul oracle elsewhere
    (interpret-mode Pallas is too slow for an ingest loop)."""
    if _ON_TPU and not _FORCE_REF:
        return segment_agg(x, w, seg, num_segments=num_segments,
                           block_d=_tuned_block("segment_agg", x.shape, x.dtype))
    return _ref.segment_agg_ref(x, w, seg, num_segments)


def ingest_agg_op(q, scales, n_samples, F, G, fb, k=None, cf=None, *,
                  chunk=0, n_clients, normalize=True):
    """Fused ingestion reduce, interpret-mode kernel body (validation)."""
    if _FORCE_REF:
        return _ref.ingest_agg_ref(q, scales, n_samples, F, G, fb, k, cf,
                                   n_clients=n_clients, normalize=normalize)
    return ingest_agg(q, scales, n_samples, F, G, fb, k, cf, chunk=chunk,
                      n_clients=n_clients, normalize=normalize,
                      interpret=_INTERPRET)


def ingest_agg_auto_op(q, scales, n_samples, F, G, fb, k=None, cf=None, *,
                       chunk=0, n_clients, normalize=True):
    """Throughput dispatch for the fused serve ingestion path: compiled
    kernel on TPU (autotuned block), jitted oracle elsewhere — both
    fold the Eq. §3.4 weights on-device, so no host round-trip."""
    if _ON_TPU and not _FORCE_REF:
        return ingest_agg(q, scales, n_samples, F, G, fb, k, cf, chunk=chunk,
                          n_clients=n_clients, normalize=normalize,
                          block_d=_tuned_block("ingest_agg", q.shape, q.dtype))
    return _ref.ingest_agg_ref(q, scales, n_samples, F, G, fb, k, cf,
                               n_clients=n_clients, normalize=normalize)


def stats_agg_op(x, n_samples, F, G, fb, k=None, cf=None, *, n_clients,
                 normalize=True):
    """Fused ingestion + stats reduce, interpret-mode (validation)."""
    if _FORCE_REF:
        return _ref.stats_agg_ref(x, n_samples, F, G, fb, k, cf,
                                  n_clients=n_clients, normalize=normalize)
    return stats_agg(x, n_samples, F, G, fb, k, cf, n_clients=n_clients,
                     normalize=normalize, interpret=_INTERPRET)


def stats_agg_auto_op(x, n_samples, F, G, fb, k=None, cf=None, *, n_clients,
                      normalize=True):
    """Throughput dispatch for the health-instrumented serve ingestion
    path: compiled kernel on TPU (autotuned block), jitted oracle
    elsewhere.  The aggregate output is bit-identical to
    ``ingest_agg_auto_op`` either way; ``row_sq`` bits follow the
    winning tiling (health detectors threshold, never compare bits)."""
    if _ON_TPU and not _FORCE_REF:
        return stats_agg(x, n_samples, F, G, fb, k, cf, n_clients=n_clients,
                         normalize=normalize,
                         block_d=_tuned_block("stats_agg", x.shape, x.dtype))
    return _ref.stats_agg_ref(x, n_samples, F, G, fb, k, cf,
                              n_clients=n_clients, normalize=normalize)


def ingest_segment_agg_op(q, scales, seg, n_samples, F, G, fb, k=None,
                          cf=None, *, num_segments, chunk=0, n_clients,
                          normalize=False):
    """Per-group fused ingestion reduce, interpret-mode (validation)."""
    if _FORCE_REF:
        return _ref.ingest_segment_agg_ref(
            q, scales, seg, n_samples, F, G, fb, k, cf,
            num_segments=num_segments, n_clients=n_clients,
            normalize=normalize)
    return ingest_segment_agg(q, scales, seg, n_samples, F, G, fb, k, cf,
                              num_segments=num_segments, chunk=chunk,
                              n_clients=n_clients, normalize=normalize,
                              interpret=_INTERPRET)


def ingest_segment_agg_auto_op(q, scales, seg, n_samples, F, G, fb, k=None,
                               cf=None, *, num_segments, chunk=0, n_clients,
                               normalize=False):
    """Throughput dispatch for the tier-edge fused ingestion path."""
    if _ON_TPU and not _FORCE_REF:
        return ingest_segment_agg(
            q, scales, seg, n_samples, F, G, fb, k, cf,
            num_segments=num_segments, chunk=chunk, n_clients=n_clients,
            normalize=normalize,
            block_d=_tuned_block("ingest_segment_agg", q.shape, q.dtype))
    return _ref.ingest_segment_agg_ref(
        q, scales, seg, n_samples, F, G, fb, k, cf,
        num_segments=num_segments, n_clients=n_clients, normalize=normalize)


def similarity_stats_op(a, b):
    if _FORCE_REF:
        return _ref.fused_similarity_stats_ref(a, b)
    return fused_similarity_stats(a, b, interpret=_INTERPRET)


def cosine_op(a, b):
    if _FORCE_REF:
        return _ref.cosine_from_stats_ref(a, b)
    return cosine_from_stats(a, b, interpret=_INTERPRET)


def window_decode_attention_op(q, k, v, valid_len):
    if _FORCE_REF:
        return _ref.window_decode_attention_ref(q, k, v, valid_len)
    return window_decode_attention(q, k, v, valid_len, interpret=_INTERPRET)


# --------------------------------------------------------------------------
# Profiling hooks (repro.telemetry.profile): every public op funnels
# through ``timed_call`` so an active profiler sees per-dispatch wall
# time, ref-path fallbacks, and kernel spans; with no profiler active
# the wrapper is one global read + ``is None`` check and the call goes
# through untouched (no block_until_ready — async behavior and results
# are bit-identical, gated by ``serve_trace_overhead``).

def _hooked(fn, *, auto: bool):
    mode = _profile.resolved_mode(auto)
    name = fn.__name__

    @functools.wraps(fn)
    def wrapped(*args, **kw):
        return _profile.timed_call(name, mode, fn, *args, **kw)

    return wrapped


weighted_agg_op = _hooked(weighted_agg_op, auto=False)
weighted_agg_auto_op = _hooked(weighted_agg_auto_op, auto=True)
dequant_agg_op = _hooked(dequant_agg_op, auto=False)
dequant_agg_auto_op = _hooked(dequant_agg_auto_op, auto=True)
segment_agg_op = _hooked(segment_agg_op, auto=False)
segment_agg_auto_op = _hooked(segment_agg_auto_op, auto=True)
ingest_agg_op = _hooked(ingest_agg_op, auto=False)
ingest_agg_auto_op = _hooked(ingest_agg_auto_op, auto=True)
stats_agg_op = _hooked(stats_agg_op, auto=False)
stats_agg_auto_op = _hooked(stats_agg_auto_op, auto=True)
ingest_segment_agg_op = _hooked(ingest_segment_agg_op, auto=False)
ingest_segment_agg_auto_op = _hooked(ingest_segment_agg_auto_op, auto=True)
similarity_stats_op = _hooked(similarity_stats_op, auto=False)
cosine_op = _hooked(cosine_op, auto=False)
window_decode_attention_op = _hooked(window_decode_attention_op, auto=False)
