"""Pallas TPU kernels for the FedQS hot spots (DESIGN §7):

* ``weighted_agg``      — Mod-3 K-way weighted parameter reduction;
* ``dequant_agg``       — fused int8 dequantize + weighted reduction
  (compressed-transport aggregation, ``repro.compress``);
* ``segment_agg``       — per-group segment-reduce Σw·x over stacked
  client rows (hierarchical aggregation plane, ``repro.hier``);
* ``ingest_agg``        — fused ingestion: int8 dequantize + Eq. §3.4
  staleness-decay weight fold + Σw·x in one pass (``repro.serve``),
  with an ``ingest_segment_agg`` variant for hierarchical edges;
* ``stats_agg``         — ``ingest_agg`` dense variant that also emits
  per-update squared norms + the weight column in the same VMEM sweep
  (the training-health plane's stability vector, ``telemetry.health``);
* ``similarity``        — Mod-1 fused <a,b>/|a|^2/|b|^2 one-pass statistics;
* ``window_attention``  — sliding-window decode attention (long_500k path).

Block sizes for the ``*_auto_op`` compiled dispatch come from the
persistent autotuner cache (``autotune.py``; see docs/KERNELS.md).
Validated against ``ref.py`` oracles with ``interpret=True`` on CPU.
"""
from .autotune import get_config
from .ops import (
    cosine_op,
    dequant_agg_auto_op,
    dequant_agg_op,
    ingest_agg_auto_op,
    ingest_agg_op,
    ingest_segment_agg_auto_op,
    ingest_segment_agg_op,
    segment_agg_auto_op,
    segment_agg_op,
    similarity_stats_op,
    stats_agg_auto_op,
    stats_agg_op,
    weighted_agg_auto_op,
    weighted_agg_op,
    window_decode_attention_op,
)

__all__ = [
    "cosine_op",
    "dequant_agg_auto_op",
    "dequant_agg_op",
    "get_config",
    "ingest_agg_auto_op",
    "ingest_agg_op",
    "ingest_segment_agg_auto_op",
    "ingest_segment_agg_op",
    "segment_agg_auto_op",
    "segment_agg_op",
    "similarity_stats_op",
    "stats_agg_auto_op",
    "stats_agg_op",
    "weighted_agg_auto_op",
    "weighted_agg_op",
    "window_decode_attention_op",
]
