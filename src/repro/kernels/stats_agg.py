"""Pallas TPU kernel: the fused ingestion pass WITH on-kernel update
statistics — Σw·x plus the per-round stability vector, in one sweep.

    agg[d]    = Σ_k p[k] · x[k,d]          (identical to ``ingest_agg``)
    row_sq[k] = Σ_d x[k,d]²                (per-update squared norm)
    w[k]      = p[k]                       (the §3.4 fold, exported)

The training-health plane (docs/OBSERVABILITY.md) needs, every round,
the weighted dispersion E_w‖x−μ‖² — FedQS's fluctuation quantity — and
per-update norms to catch explosions.  Computing them host-side would
re-stream the whole [K, D] payload from HBM; here the squares ride the
same VMEM tiles the reduction already pays for, so the marginal cost is
one K×blk elementwise multiply-add per grid step.

``row_sq`` accumulates across grid steps into a [K, 1] output block
with a constant index map (resident in VMEM the whole launch):
initialized on step 0, added to afterwards.  That makes the reduction
order *tiling-dependent* — per-block partials summed left-to-right —
so the oracle (``ref.stats_agg_ref``) mirrors the same blocked
accumulation to stay bit-exact (unlike ``agg``, where each out[d] is a
single K-length dot regardless of block size).

``round_stats`` assembles the stability vector from the three outputs;
the weight algebra is shared verbatim with ``ingest_agg`` via
``_fold``/``ingest_weights``, so the aggregate output is bit-identical
to the stats-free kernel (gated by ``tests/test_health.py`` and the
``serve_health_overhead`` benchmark).

Dense f32 rows only: the compressed (int8) serving path keeps the plain
``ingest_agg`` kernel and skips stats for that round.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ingest_agg import BLOCK_D, _fold, _meta_cols

#: Order of the entries ``round_stats`` packs (the stats-vector schema
#: in docs/OBSERVABILITY.md; ``telemetry.health`` consumes by name).
STATS_FIELDS = ("sum_w", "wnorm2", "dispersion", "max_sq", "mean_sq")


def _stats_dense_kernel(k_ref, n_ref, F_ref, G_ref, fb_ref, cf_ref, x_ref,
                        o_ref, sq_ref, w_ref, *, n_clients, normalize):
    p = _fold(k_ref, n_ref, F_ref, G_ref, fb_ref, cf_ref,
              n_clients=n_clients, normalize=normalize)
    x = x_ref[...].astype(jnp.float32)
    o_ref[...] = jnp.dot(p.T, x, preferred_element_type=jnp.float32)
    blk_sq = jnp.sum(x * x, axis=1, keepdims=True)
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        sq_ref[...] = blk_sq
        w_ref[...] = p

    @pl.when(i > 0)
    def _accumulate():
        sq_ref[...] = sq_ref[...] + blk_sq


@functools.partial(jax.jit, static_argnames=(
    "n_clients", "normalize", "block_d", "interpret"))
def stats_agg(x: jax.Array, n_samples, F, G, fb, k=None, cf=None, *,
              n_clients: int, normalize: bool = True,
              block_d: int = BLOCK_D, interpret: bool = False):
    """Fused ingestion reduce + statistics → ``(agg [D], row_sq [K],
    w [K])`` f32 (see module docstring).

    Same metadata contract as the dense path of ``ingest_agg``: ``x`` is
    [K, D] dense rows, ``n_samples``/``F``/``G``/``fb`` [K] f32 columns,
    ``k`` the logical member count (row-axis padding rows carry
    ``n = fb = 0`` and weigh exactly 0 — their ``row_sq`` is 0 too when
    the padding payload is zeros, which the serving path guarantees).
    """
    K, D = x.shape
    kcol, ncol, Fcol, Gcol, fbcol, cfcol = _meta_cols(
        x, n_samples, F, G, fb, k, cf)
    meta_specs = [pl.BlockSpec((1, 1), lambda i: (0, 0))] + [
        pl.BlockSpec((K, 1), lambda i: (0, 0)) for _ in range(5)
    ]
    blk = block_d
    pad = (-D) % blk
    xp = jnp.pad(x, ((0, 0), (0, pad))) if pad else x
    agg, row_sq, w = pl.pallas_call(
        functools.partial(_stats_dense_kernel, n_clients=n_clients,
                          normalize=normalize),
        grid=((D + pad) // blk,),
        in_specs=meta_specs + [pl.BlockSpec((K, blk), lambda i: (0, i))],
        out_specs=(
            pl.BlockSpec((1, blk), lambda i: (0, i)),
            pl.BlockSpec((K, 1), lambda i: (0, 0)),
            pl.BlockSpec((K, 1), lambda i: (0, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((1, D + pad), jnp.float32),
            jax.ShapeDtypeStruct((K, 1), jnp.float32),
            jax.ShapeDtypeStruct((K, 1), jnp.float32),
        ),
        interpret=interpret,
    )(kcol, ncol, Fcol, Gcol, fbcol, cfcol, xp.astype(jnp.float32))
    return agg[0, :D], row_sq[:, 0], w[:, 0]


def round_stats(agg: jax.Array, row_sq: jax.Array, w: jax.Array,
                k=None) -> jax.Array:
    """Pack the kernel outputs into the [5] stability vector
    (``STATS_FIELDS`` order).  Pure jnp — traced inside the caller's jit.

    * ``sum_w``      — Σw (≈1 on the normalized serve path; the raw
      mass on tier edges);
    * ``wnorm2``     — Σw·‖x‖², the weighted second moment;
    * ``dispersion`` — E_w‖x−μ‖² = Σw‖x‖²/Σw − ‖μ‖² with μ = Σw·x/Σw,
      clamped at 0 against fp cancellation: the paper's fluctuation
      quantity;
    * ``max_sq``     — max_k ‖x_k‖² (update-norm explosion signal);
    * ``mean_sq``    — Σ‖x_k‖²/k, unweighted (padding rows contribute
      0 to the numerator and are excluded from ``k``).
    """
    k = (jnp.float32(row_sq.shape[0]) if k is None
         else jnp.asarray(k, jnp.float32))
    sum_w = jnp.sum(w)
    wnorm2 = jnp.sum(w * row_sq)
    mu_sq = jnp.sum(agg * agg) / jnp.maximum(sum_w * sum_w, 1e-24)
    dispersion = jnp.maximum(wnorm2 / jnp.maximum(sum_w, 1e-12) - mu_sq, 0.0)
    max_sq = jnp.max(row_sq)
    mean_sq = jnp.sum(row_sq) / jnp.maximum(k, 1.0)
    return jnp.stack([sum_w, wnorm2, dispersion, max_sq, mean_sq])
