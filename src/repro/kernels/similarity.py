"""Pallas TPU kernel: Mod-1 fused similarity statistics.

Cosine similarity over flattened parameter vectors needs three reductions:
⟨a,b⟩, ‖a‖², ‖b‖².  Separately they cost three HBM passes over ~100 MB+
vectors; fused they cost one (DESIGN §3).  The kernel streams 8-MB-aligned
(1, BLOCK) tiles of both vectors through VMEM and accumulates the three
scalars in a revisited (1, 128) output tile (grid steps on TPU execute
sequentially, so read-modify-write accumulation across steps is sound).
Lanes 0..2 of the 128-lane tile carry the results; the rest are padding
for hardware lane alignment.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 65536  # 2 × 256 KiB f32 tiles per step


def _similarity_kernel(a_ref, b_ref, o_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = a_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    dot = jnp.sum(a * b)
    na = jnp.sum(a * a)
    nb = jnp.sum(b * b)
    lane = jax.lax.broadcasted_iota(jnp.int32, (1, 128), 1)
    upd = jnp.where(lane == 0, dot, jnp.where(lane == 1, na,
                    jnp.where(lane == 2, nb, 0.0)))
    o_ref[...] += upd


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def fused_similarity_stats(a: jax.Array, b: jax.Array, *, block: int = BLOCK,
                           interpret: bool = False) -> jax.Array:
    """a, b [D] → f32[3] = (⟨a,b⟩, ‖a‖², ‖b‖²) in ONE pass over HBM."""
    D = a.shape[0]
    pad = (-D) % block
    if pad:
        a = jnp.pad(a, (0, pad))
        b = jnp.pad(b, (0, pad))
    Dp = D + pad
    out = pl.pallas_call(
        _similarity_kernel,
        grid=(Dp // block,),
        in_specs=[
            pl.BlockSpec((1, block), lambda i: (0, i)),
            pl.BlockSpec((1, block), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((1, 128), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 128), jnp.float32),
        interpret=interpret,
    )(a.reshape(1, Dp), b.reshape(1, Dp))
    return out[0, :3]


@functools.partial(jax.jit, static_argnames=("interpret",))
def cosine_from_stats(a: jax.Array, b: jax.Array, *, interpret: bool = False):
    s = fused_similarity_stats(a, b, interpret=interpret)
    return s[0] / jnp.maximum(jnp.sqrt(s[1] * s[2]), 1e-12)
