from .sgd import eq3_momentum_step, local_train_epochs, sgd_step
from .schedule import constant_schedule, wsd_schedule

__all__ = [
    "eq3_momentum_step",
    "local_train_epochs",
    "sgd_step",
    "constant_schedule",
    "wsd_schedule",
]
