"""Learning-rate schedules.

``wsd_schedule`` (Warmup–Stable–Decay) is required by the minicpm-2b
assigned architecture [arXiv:2404.06395]; FedQS itself adapts the *local*
lr multiplicatively on top of whatever schedule the deployment uses.
"""
from __future__ import annotations

import jax.numpy as jnp


def constant_schedule(base_lr: float):
    def fn(step):
        return jnp.asarray(base_lr, jnp.float32)

    return fn


def wsd_schedule(
    base_lr: float,
    warmup_steps: int,
    stable_steps: int,
    decay_steps: int,
    final_ratio: float = 0.1,
):
    """Warmup–Stable–Decay: linear warmup, flat plateau, exponential-ish
    (here cosine-to-ratio) decay tail, per MiniCPM."""

    def fn(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / jnp.maximum(warmup_steps, 1)
        decay_t = jnp.clip(
            (step - warmup_steps - stable_steps) / jnp.maximum(decay_steps, 1), 0.0, 1.0
        )
        decay = base_lr * (final_ratio + (1 - final_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * decay_t)))
        return jnp.where(
            step < warmup_steps,
            warm,
            jnp.where(step < warmup_steps + stable_steps, base_lr, decay),
        )

    return fn
