"""Local optimizer implementing paper Eq. 3.

Eq. 3 momentum is a geometric accumulation over the *local* trajectory:

    w_{i,e} = w_{i,e−1} − η_i [ Σ_{r=1..e} m^r ∇F_{i,e−r} + ∇F_{i,e} ]

The bracket telescopes into the recursion  v_e = g_e + m · v_{e−1}
(v_0 = 0), since  v_e = g_e + m g_{e−1} + m² g_{e−2} + …  matches the
paper's sum term-for-term.  With m=0 this is plain SGD, which is what
FSBC / SSBC-Situation-2 clients run.

Gradients are clipped by global norm at G_c (Assumption A.2 justification:
"the gradient clipping threshold can be directly utilized as the upper
bound").
"""
from __future__ import annotations

from typing import Callable, Tuple

import jax
import jax.numpy as jnp

from repro.core.types import Params, tree_clip_by_global_norm, tree_zeros_like


def eq3_momentum_step(
    params: Params,
    velocity: Params,
    grads: Params,
    lr,
    momentum,
) -> Tuple[Params, Params]:
    """One Eq-3 step: v ← g + m·v ; w ← w − η·v. Returns (params, velocity)."""
    velocity = jax.tree_util.tree_map(lambda g, v: g + momentum * v, grads, velocity)
    params = jax.tree_util.tree_map(lambda w, v: w - lr * v, params, velocity)
    return params, velocity


def sgd_step(params: Params, grads: Params, lr) -> Params:
    return jax.tree_util.tree_map(lambda w, g: w - lr * g, params, grads)


def local_train_epochs(
    params: Params,
    grad_fn: Callable[[Params, dict], Params],
    batches,
    lr,
    momentum,
    grad_clip: float = 20.0,
) -> Tuple[Params, Params]:
    """Run the client's E local epochs (one batch = one epoch, paper E=2).

    Returns (final params, final velocity).  The uploaded FedQS-SGD payload
    is the model difference  δ = w_start − w_end = η Σ_e v_e, equal to the
    paper's η_i Σ_e ΔF_{i,e} (Remark B.1 / §3.4).
    """
    velocity = tree_zeros_like(params)
    for batch in batches:
        grads = grad_fn(params, batch)
        grads = tree_clip_by_global_norm(grads, grad_clip)
        params, velocity = eq3_momentum_step(params, velocity, grads, lr, momentum)
    return params, velocity
