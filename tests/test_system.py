"""End-to-end behaviour tests for the FedQS system (replaces scaffold)."""
import subprocess
import sys
import os

import numpy as np
import pytest

from repro.core import FedQSHyperParams, SAFLEngine, make_algorithm
from repro.data import make_federated_data
from repro.models import make_mlp_spec


class TestEndToEnd:
    def test_full_fedqs_pipeline(self):
        """data -> engine -> Mod1/2/3 -> metrics, all modules exercised."""
        data = make_federated_data("rwd", 8, seed=0, n_total=800)
        spec = make_mlp_spec()
        hp = FedQSHyperParams(buffer_k=4)
        eng = SAFLEngine(data, spec, make_algorithm("fedqs-sgd", hp), hp, seed=0)
        res = eng.run(12)
        assert len(res.metrics) == 12
        # Mod-1 produced similarities
        assert any(abs(c.last_similarity) > 0 for c in eng.clients)
        # Mod-2 placed clients in more than one quadrant eventually
        quadrants = {c.quadrant for c in eng.clients}
        assert len(quadrants) >= 2
        # Mod-3 table is consistent
        assert int(np.asarray(eng.table.counts).sum()) == 12 * 4

    def test_gradient_vs_model_both_work_same_engine(self):
        data = make_federated_data("rwd", 6, seed=1, n_total=600)
        spec = make_mlp_spec()
        hp = FedQSHyperParams(buffer_k=3)
        for name in ("fedqs-sgd", "fedqs-avg"):
            eng = SAFLEngine(data, spec, make_algorithm(name, hp), hp, seed=1)
            res = eng.run(6)
            assert all(np.isfinite(m.loss) for m in res.metrics)

    def test_mesh_factory_importable_without_device_init(self):
        """Importing mesh.py must not initialize jax devices (DESIGN 6)."""
        code = (
            "import sys; sys.path.insert(0, 'src');"
            "from repro.launch import mesh;"
            "import jax;"
            "print(len(jax.devices()))"
        )
        out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                             text=True, cwd=os.path.join(os.path.dirname(__file__), ".."))
        assert out.returncode == 0, out.stderr
        assert out.stdout.strip() == "1"  # real topology, not 512

    def test_benchmark_registry_importable(self):
        import benchmarks.run as br
        assert callable(br.main)
