"""Overlapped-round pipeline: pipelined-vs-synchronous bit-identity,
vectorized burst admission parity, concurrency/conservation ledgers, and
the checkpoint-mid-flight contract (docs/ARCHITECTURE.md 'Overlapped
rounds').

The headline contract under test: for the same arrival stream, a
``pipeline=True`` service must produce **bit-identical** global params,
server table, ``ServiceStats`` (minus wall time), and telemetry event
taxonomy as the synchronous service — overlap is a latency optimization,
never a semantics change.
"""
import dataclasses
import os
import threading
import time
from collections import Counter
from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import FedQSHyperParams, make_algorithm
from repro.core.types import Update
from repro.hier import HierarchicalService, parse_topology
from repro.serve import (
    AdmitAll,
    KBuffer,
    AdaptiveTimeWindow,
    StalenessAdmission,
    StreamingAggregator,
    TimeWindow,
    flatten_bursts,
    replay,
    replay_bursts,
    zipf_burst_stream,
)
from repro.telemetry import Telemetry


def _tiny_params(seed=0):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    return {"w": jax.random.normal(k1, (7, 5)), "b": jax.random.normal(k2, (5,))}


def _mk_update(cid=0, n_samples=50, stale_round=0, similarity=0.5, delta=None,
               params=None, sent_at=-1.0):
    return Update(cid=cid, n_samples=n_samples, stale_round=stale_round,
                  lr=0.1, similarity=similarity, feedback=False, speed_f=0.1,
                  delta=delta, params=params, sent_at=sent_at)


def _stats_dict(svc):
    """ServiceStats as a dict minus ``agg_seconds`` (host wall time is the
    one legitimately nondeterministic field)."""
    d = dataclasses.asdict(svc.stats)
    d.pop("agg_seconds")
    return d


def _ring_events(tel):
    """Ring records with wall-time fields stripped — the event-taxonomy
    pin: same events, same order, same payloads."""
    out = []
    for rec in tel.ring.records:
        rec = dict(rec)
        rec.pop("agg_seconds", None)
        out.append(rec)
    return out


def _assert_trees_equal(a, b):
    for x, y in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def _burst_trace(params, *, n_clients=64, n_updates=192, seed=7, burst=24):
    return list(zipf_burst_stream(params, n_clients, n_updates, seed=seed,
                                  burst=burst, stale_spread=3))


TRIGGERS = {
    "kbuffer": lambda: KBuffer(8),
    "timewindow": lambda: TimeWindow(window=2.0, min_updates=1),
    "adaptive": lambda: AdaptiveTimeWindow(2.0, min_updates=1, warmup=4),
}


# ---------------------------------------------------------------------------
# pipelined ≡ synchronous (the determinism contract)
# ---------------------------------------------------------------------------
class TestPipelineBitIdentity:
    @pytest.mark.parametrize("trig", sorted(TRIGGERS))
    @pytest.mark.parametrize("algo", ["fedqs-sgd", "fedavg"])
    def test_flat_service_bit_identical(self, trig, algo):
        """Params, table, stats, and the full telemetry event stream must
        match the synchronous service bit-for-bit under every trigger."""
        hp = FedQSHyperParams(buffer_k=8)
        params = _tiny_params()
        bursts = _burst_trace(params)
        stream = flatten_bursts(bursts)
        admission = StalenessAdmission(tau_max=1, mode="downweight")

        results = {}
        for pipelined in (False, True):
            tel = Telemetry.in_memory()
            svc = StreamingAggregator(
                make_algorithm(algo, hp), hp, params, 64,
                trigger=TRIGGERS[trig](), admission=admission,
                batched=True, pipeline=pipelined, telemetry=tel)
            reports = replay(svc, stream)
            svc.close()
            results[pipelined] = (svc, reports, tel)

        sync_svc, sync_reps, sync_tel = results[False]
        pipe_svc, pipe_reps, pipe_tel = results[True]
        assert pipe_svc.round == sync_svc.round >= 2
        _assert_trees_equal(pipe_svc.global_params, sync_svc.global_params)
        np.testing.assert_array_equal(np.asarray(pipe_svc.table.counts),
                                      np.asarray(sync_svc.table.counts))
        assert _stats_dict(pipe_svc) == _stats_dict(sync_svc)
        assert _ring_events(pipe_tel) == _ring_events(sync_tel)
        got = [(r.round, r.n_updates, r.trigger) for r in pipe_reps]
        want = [(r.round, r.n_updates, r.trigger) for r in sync_reps]
        assert got == want

    def test_hier_service_bit_identical(self):
        """The tiered global stage rides the same pipeline: edge/region
        routing plus the fused global fire must stay bit-identical."""
        hp = FedQSHyperParams(buffer_k=4)
        params = _tiny_params()
        topo = parse_topology("hier:4", 32)
        bursts = _burst_trace(params, n_clients=32, n_updates=160, burst=20)
        stream = flatten_bursts(bursts)

        results = {}
        for pipelined in (False, True):
            tel = Telemetry.in_memory()
            svc = HierarchicalService(
                make_algorithm("fedqs-sgd", hp), hp, params, 32, topo,
                trigger=KBuffer(4),
                edge_trigger=lambda e: KBuffer(2),
                pipeline=pipelined, telemetry=tel)
            replay(svc, stream)
            svc.close()
            results[pipelined] = (svc, tel)

        sync_svc, sync_tel = results[False]
        pipe_svc, pipe_tel = results[True]
        assert pipe_svc.round == sync_svc.round >= 2
        _assert_trees_equal(pipe_svc.global_params, sync_svc.global_params)
        np.testing.assert_array_equal(np.asarray(pipe_svc.table.counts),
                                      np.asarray(sync_svc.table.counts))
        assert _stats_dict(pipe_svc) == _stats_dict(sync_svc)
        assert _ring_events(pipe_tel) == _ring_events(sync_tel)

    def test_validates_exclusive_modes(self):
        hp = FedQSHyperParams(buffer_k=4)
        params = _tiny_params()
        with pytest.raises(ValueError):
            StreamingAggregator(make_algorithm("fedavg", hp), hp, params, 8,
                                pipeline=True, async_agg=True)


# ---------------------------------------------------------------------------
# vectorized burst admission ≡ per-update admission
# ---------------------------------------------------------------------------
class TestBurstAdmission:
    @pytest.mark.parametrize("mode", ["drop", "downweight"])
    def test_fast_path_matches_per_update(self, mode):
        """submit_burst's windowed numpy verdicts must reproduce the
        per-update scalar path exactly: same params, same counters."""
        hp = FedQSHyperParams(buffer_k=8)
        params = _tiny_params()
        bursts = _burst_trace(params, n_updates=256, burst=32)
        admission = StalenessAdmission(tau_max=1, mode=mode)

        slow = StreamingAggregator(make_algorithm("fedqs-sgd", hp), hp,
                                   params, 64, trigger=KBuffer(8),
                                   admission=admission, batched=True)
        fast = StreamingAggregator(make_algorithm("fedqs-sgd", hp), hp,
                                   params, 64, trigger=KBuffer(8),
                                   admission=admission, batched=True,
                                   pipeline=True)
        replay(slow, flatten_bursts(bursts))
        replay_bursts(fast, bursts)
        fast.close()

        assert fast.round == slow.round
        _assert_trees_equal(fast.global_params, slow.global_params)
        np.testing.assert_array_equal(np.asarray(fast.table.counts),
                                      np.asarray(slow.table.counts))
        assert _stats_dict(fast) == _stats_dict(slow)

    def test_adaptive_observe_batch_matches_per_update(self):
        """Segment-wise latency observation inside a burst must leave the
        adaptive deadline bit-identical to the per-update path (the arm
        segments close before each mid-burst fire)."""
        hp = FedQSHyperParams(buffer_k=8)
        params = _tiny_params()
        bursts = _burst_trace(params, n_updates=256, burst=32)

        svcs = {}
        for tag, drive in (("slow", False), ("fast", True)):
            svc = StreamingAggregator(
                make_algorithm("fedavg", hp), hp, params, 64,
                trigger=AdaptiveTimeWindow(2.0, min_updates=1, warmup=4),
                batched=True, pipeline=drive)
            if drive:
                replay_bursts(svc, bursts)
            else:
                replay(svc, flatten_bursts(bursts))
            svc.close()
            svcs[tag] = svc
        assert svcs["fast"].trigger.describe() == svcs["slow"].trigger.describe()
        _assert_trees_equal(svcs["fast"].global_params,
                            svcs["slow"].global_params)
        assert _stats_dict(svcs["fast"]) == _stats_dict(svcs["slow"])

    def test_burst_result_counts(self):
        hp = FedQSHyperParams(buffer_k=4)
        params = _tiny_params()
        svc = StreamingAggregator(make_algorithm("fedavg", hp), hp, params, 16,
                                  trigger=KBuffer(4), admission=AdmitAll(),
                                  batched=True, pipeline=True)
        (batch, now), = _burst_trace(params, n_clients=16, n_updates=10,
                                     burst=10)
        res = svc.submit_burst(batch, now=now)
        assert res.submitted == 10 and res.accepted == 10
        assert res.dropped == 0 and res.fired == 2
        assert svc.pending == 2  # 10 admitted - 2 fires * K=4
        svc.close()
        assert svc.stats.submitted == 10 and svc.stats.rounds == 2


# ---------------------------------------------------------------------------
# concurrency: ingestion under contention
# ---------------------------------------------------------------------------
class TestConcurrency:
    N_THREADS = 8
    PER_THREAD = 48

    def _hammer(self, svc, deltas):
        barrier = threading.Barrier(self.N_THREADS)

        def worker(tid):
            d = deltas[tid]
            u = _mk_update(cid=tid, delta=d,
                           params=jax.tree_util.tree_map(jnp.add,
                                                         svc.global_params, d))
            barrier.wait()
            for i in range(self.PER_THREAD):
                svc.submit(replace(u, stale_round=svc.round), now=float(i))

        threads = [threading.Thread(target=worker, args=(t,))
                   for t in range(self.N_THREADS)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        svc.flush(now=float(self.PER_THREAD))
        svc.join()

    def test_threaded_submit_conservation(self):
        """Hammer a pipelined service from many threads: every accepted
        update must land in exactly one round's buffer (per-cid ledger)."""
        hp = FedQSHyperParams(buffer_k=16)
        params = _tiny_params()
        reports = []
        svc = StreamingAggregator(make_algorithm("fedavg", hp), hp, params,
                                  self.N_THREADS, trigger=KBuffer(16),
                                  admission=AdmitAll(), batched=True,
                                  pipeline=True, on_round=reports.append)
        key = jax.random.PRNGKey(5)
        deltas = []
        for _ in range(self.N_THREADS):
            key, sub = jax.random.split(key)
            deltas.append(jax.tree_util.tree_map(
                lambda l, s=sub: 0.01 * jax.random.normal(s, l.shape), params))
        self._hammer(svc, deltas)
        svc.close()

        total = self.N_THREADS * self.PER_THREAD
        assert svc.stats.submitted == svc.stats.accepted == total
        ledger = Counter()
        for rep in reports:
            for u in rep.buffer:
                ledger[u.cid] += 1
        assert sum(ledger.values()) == total  # nothing lost, nothing doubled
        assert all(ledger[cid] == self.PER_THREAD
                   for cid in range(self.N_THREADS))
        rounds = [rep.round for rep in reports]
        assert rounds == list(range(1, len(reports) + 1))  # monotone, gapless
        assert svc.pending == 0

    def test_stats_atomic_under_contention(self):
        """ServiceStats.bump must not lose increments when admission mixes
        accepts and drops across racing threads (the read-modify-write on
        the dataclass counters used to be unguarded)."""
        hp = FedQSHyperParams(buffer_k=16)
        params = _tiny_params()
        svc = StreamingAggregator(
            make_algorithm("fedavg", hp), hp, params, self.N_THREADS,
            trigger=KBuffer(16),
            admission=StalenessAdmission(tau_max=0, mode="drop"),
            batched=True, pipeline=True)
        deltas = [jax.tree_util.tree_map(jnp.zeros_like, params)
                  for _ in range(self.N_THREADS)]
        self._hammer(svc, deltas)
        svc.close()
        s = svc.stats
        total = self.N_THREADS * self.PER_THREAD
        assert s.submitted == total
        assert s.accepted + s.dropped == s.submitted

    def test_drain_idempotent(self):
        hp = FedQSHyperParams(buffer_k=4)
        params = _tiny_params()
        svc = StreamingAggregator(make_algorithm("fedavg", hp), hp, params, 8,
                                  trigger=KBuffer(4), batched=True,
                                  pipeline=True)
        key = jax.random.PRNGKey(3)
        for i in range(4):
            key, sub = jax.random.split(key)
            d = jax.tree_util.tree_map(
                lambda l, s=sub: 0.01 * jax.random.normal(s, l.shape), params)
            svc.submit(_mk_update(cid=i, delta=d,
                                  params=jax.tree_util.tree_map(
                                      jnp.add, params, d)), now=float(i))
        rep = svc.drain()
        assert rep is not None and rep.round == 1
        assert svc.drain() is None  # nothing in flight: a no-op
        assert svc.drain() is None
        assert svc.stats.rounds == 1
        svc.close()

    def test_checkpoint_mid_flight(self, tmp_path):
        """Saving while a round is in flight drains it first; the restored
        service fed the identical suffix must land bit-exact."""
        hp = FedQSHyperParams(buffer_k=8)
        params = _tiny_params()
        bursts = _burst_trace(params, n_updates=128, burst=16)
        stream = flatten_bursts(bursts)
        head, tail = stream[:64], stream[64:]

        a = StreamingAggregator(make_algorithm("fedqs-sgd", hp), hp, params,
                                64, trigger=KBuffer(8), batched=True,
                                pipeline=True)
        for u, now in head:
            a.submit(u, now=now)
        # the 64th submit fired round 8: its aggregation is (or was) in
        # flight on the pipeline worker right now — save must drain it
        a.save(str(tmp_path / "ck"))
        assert a.round == 8 and a.stats.rounds == 8

        b = StreamingAggregator(make_algorithm("fedqs-sgd", hp), hp, params,
                                64, trigger=KBuffer(8), batched=True,
                                pipeline=True)
        b.restore(str(tmp_path / "ck"))
        assert b.round == a.round
        for u, now in tail:
            a.submit(u, now=now)
            b.submit(u, now=now)
        a.join(), b.join()
        _assert_trees_equal(a.global_params, b.global_params)
        np.testing.assert_array_equal(np.asarray(a.table.counts),
                                      np.asarray(b.table.counts))
        a.close(), b.close()


# ---------------------------------------------------------------------------
# soak: seeded Zipf-burst stress (excluded from tier-1; scripts/ci.sh)
# ---------------------------------------------------------------------------
@pytest.mark.stress
class TestSoak:
    def test_zipf_burst_soak(self):
        """Drive a pipelined service with seeded Zipf bursts for
        ``REPRO_SOAK_SECONDS`` (default 60): no deadlock (the test
        finishes), no dropped rounds (gapless monotone round ids), and the
        conservation ledger balances at every cycle boundary."""
        seconds = float(os.environ.get("REPRO_SOAK_SECONDS", "60"))
        hp = FedQSHyperParams(buffer_k=32)
        params = _tiny_params()
        reports = []
        svc = StreamingAggregator(
            make_algorithm("fedqs-sgd", hp), hp, params, 100_000,
            trigger=KBuffer(32),
            admission=StalenessAdmission(tau_max=2, mode="downweight"),
            batched=True, pipeline=True, on_round=reports.append)

        deadline = time.monotonic() + seconds
        cycle = 0
        aggregated = 0
        while time.monotonic() < deadline:
            for batch, now in zipf_burst_stream(params, 100_000, 4096,
                                                seed=cycle, burst=512,
                                                stale_spread=3):
                svc.submit_burst(batch, now=now)
            svc.drain()
            # ledger: every admitted update is either aggregated or pending
            aggregated = sum(rep.n_updates for rep in reports)
            assert aggregated + svc.pending == svc.stats.accepted
            cycle += 1
        svc.flush(now=float(cycle))
        svc.close()
        assert cycle >= 1 and svc.stats.rounds == len(reports) > 0
        rounds = [rep.round for rep in reports]
        assert rounds == list(range(1, len(reports) + 1))  # gapless, monotone
        assert svc.stats.submitted == svc.stats.accepted + svc.stats.dropped
