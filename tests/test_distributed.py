"""Distributed FedQS round step: correctness on the host (1-device) mesh.

The production 256/512-chip lowering is exercised by
``repro.launch.dryrun`` (deliverable e); here we verify the *numerics* of
the same step functions at toy scale.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import INPUT_SHAPES, get_reduced
from repro.core.distributed import (
    RoundState,
    input_specs,
    make_fedqs_round_step,
    make_prefill_step,
    make_serve_step,
)
from repro.core.types import FedQSHyperParams

KEY = jax.random.PRNGKey(0)
HP = FedQSHyperParams(local_epochs=2)


def _setup(aid="phi4-mini-3.8b", C=4, b=2, S=16, fl_mode=None, **cfg_kw):
    import dataclasses
    from repro.models import transformer as T

    cfg = get_reduced(aid)
    if fl_mode:
        cfg = dataclasses.replace(cfg, fl_mode=fl_mode)
    params = T.init_params(cfg, KEY)
    tokens = jax.random.randint(KEY, (C, b, S), 0, cfg.vocab)
    batch = {"tokens": tokens, "targets": tokens}
    if cfg.frontend != "none":
        batch["memory_embeds"] = jax.random.normal(
            KEY, (C, b, cfg.n_frontend_tokens, cfg.d_model))
    state = RoundState(
        params=params,
        prev_params=jax.tree_util.tree_map(lambda x: x * 0.999, params),
        lr=jnp.full((C,), 0.05),
        momentum=jnp.full((C,), 0.1),
        counts=jnp.ones((10,), jnp.int32),
        sims=jnp.full((10,), 0.3),
    )
    return cfg, state, batch, jnp.arange(C, dtype=jnp.int32), jnp.zeros((C,))


class TestRoundStep:
    @pytest.mark.parametrize("mode", ["stacked", "fsdp"])
    @pytest.mark.parametrize("strategy", ["sgd", "avg"])
    def test_round_updates_and_is_finite(self, mode, strategy):
        cfg, state, batch, cids, stale = _setup(fl_mode=mode)
        step = jax.jit(make_fedqs_round_step(cfg, HP, strategy=strategy,
                                             n_clients=4, total_clients=10))
        new_state, metrics = step(state, batch, cids, stale)
        assert np.isfinite(float(metrics["loss"]))
        # params actually moved
        d = sum(float(jnp.sum(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
                for a, b in zip(jax.tree_util.tree_leaves(new_state.params),
                                jax.tree_util.tree_leaves(state.params)))
        assert d > 0
        # table advanced by C participations
        assert int(jnp.sum(new_state.counts)) == int(jnp.sum(state.counts)) + 4
        # prev_params rolled forward (Mod-1 window)
        for a, b in zip(jax.tree_util.tree_leaves(new_state.prev_params),
                        jax.tree_util.tree_leaves(state.params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_stacked_and_fsdp_agree_numerically(self):
        """Both execution strategies implement the same math (sgd path,
        uniform weights when no feedback fires)."""
        cfg_s, st_s, batch, cids, stale = _setup(fl_mode="stacked")
        cfg_f, st_f, _, _, _ = _setup(fl_mode="fsdp")
        step_s = jax.jit(make_fedqs_round_step(cfg_s, HP, strategy="sgd",
                                               n_clients=4, total_clients=10))
        step_f = jax.jit(make_fedqs_round_step(cfg_f, HP, strategy="sgd",
                                               n_clients=4, total_clients=10))
        ns, _ = step_s(st_s, batch, cids, stale)
        nf, _ = step_f(st_f, batch, cids, stale)
        for a, b in zip(jax.tree_util.tree_leaves(ns.params),
                        jax.tree_util.tree_leaves(nf.params)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       rtol=2e-2, atol=2e-2)

    def test_loss_decreases_over_rounds(self):
        cfg, state, batch, cids, stale = _setup()
        step = jax.jit(make_fedqs_round_step(cfg, HP, strategy="sgd",
                                             n_clients=4, total_clients=10))
        losses = []
        for _ in range(5):
            state, metrics = step(state, batch, cids, stale)
            losses.append(float(metrics["loss"]))
        assert losses[-1] < losses[0]

    def test_similarity_vector_bounded(self):
        cfg, state, batch, cids, stale = _setup()
        step = jax.jit(make_fedqs_round_step(cfg, HP, n_clients=4, total_clients=10))
        new_state, metrics = step(state, batch, cids, stale)
        s = np.asarray(new_state.sims[np.asarray(cids)])
        assert (s >= -1.001).all() and (s <= 1.001).all()


class TestServePrefill:
    def test_serve_step_advances_cache(self):
        from repro.models import transformer as T
        cfg = get_reduced("gemma3-1b")
        params = T.init_params(cfg, KEY)
        cache = T.init_cache(cfg, B=2, max_seq=32)
        serve = jax.jit(make_serve_step(cfg))
        toks = jnp.asarray([1, 2], jnp.int32)
        logits, cache = serve(params, cache, toks)
        assert logits.shape == (2, cfg.vocab)
        assert int(cache["pos"]) == 1

    def test_input_specs_cover_all_modes(self):
        cfg = get_reduced("phi4-mini-3.8b")
        for name, shape in INPUT_SHAPES.items():
            specs = input_specs(cfg, shape, n_clients=4)
            assert isinstance(specs, dict) and specs
            if shape.mode == "train":
                C, b, S = specs["batch"]["tokens"].shape
                assert C * b == shape.global_batch and S == shape.seq_len
            elif shape.mode == "decode":
                assert specs["tokens"].shape == (shape.global_batch,)
                assert specs["cache"]["pos"].shape == ()

    def test_input_specs_are_abstract(self):
        """Dry-run inputs must be ShapeDtypeStructs (no allocation)."""
        cfg = get_reduced("kimi-k2-1t-a32b")
        specs = input_specs(cfg, INPUT_SHAPES["train_4k"], n_clients=4)
        for leaf in jax.tree_util.tree_leaves(specs,
                                              is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct)):
            assert isinstance(leaf, jax.ShapeDtypeStruct)
