"""SAFL engine behaviour: buffering, staleness, table updates, dynamics."""
import numpy as np
import pytest

from repro.core import FedQSHyperParams, SAFLEngine, make_algorithm
from repro.core.safl import (
    scenario_dropout,
    scenario_resource_scale,
    scenario_unstable_resources,
)
from repro.data import make_federated_data
from repro.models import make_mlp_spec


@pytest.fixture(scope="module")
def rwd_data():
    return make_federated_data("rwd", 10, sigma=1.0, seed=0, n_total=1000)


@pytest.fixture(scope="module")
def spec():
    return make_mlp_spec()


def _run(data, spec, name="fedqs-sgd", rounds=8, hp=None, **kw):
    hp = hp or FedQSHyperParams(buffer_k=4)
    eng = SAFLEngine(data, spec, make_algorithm(name, hp), hp, seed=1, **kw)
    return eng, eng.run(rounds)


class TestEngineMechanics:
    def test_buffer_trigger_counts_rounds(self, rwd_data, spec):
        eng, res = _run(rwd_data, spec, rounds=6)
        assert eng.round == 6
        assert len(res.metrics) == 6

    def test_staleness_occurs_under_heterogeneity(self, rwd_data, spec):
        eng, res = _run(rwd_data, spec, rounds=10)
        # with 1:50 resources some buffered updates must be stale
        assert any(m.n_stale > 0 for m in res.metrics)

    def test_table_tracks_participation(self, rwd_data, spec):
        eng, _ = _run(rwd_data, spec, rounds=6)
        counts = np.asarray(eng.table.counts)
        assert counts.sum() == 6 * eng.hp.buffer_k
        # fast clients participate more (speeds sorted ↔ counts anti-sorted)
        fast = np.argsort(eng.speeds)[:3]
        slow = np.argsort(eng.speeds)[-3:]
        assert counts[fast].sum() >= counts[slow].sum()

    def test_virtual_time_monotone(self, rwd_data, spec):
        _, res = _run(rwd_data, spec, rounds=6)
        vts = [m.virtual_time for m in res.metrics]
        assert all(a <= b for a, b in zip(vts, vts[1:]))

    def test_sync_mode_runs(self, rwd_data, spec):
        _, res = _run(rwd_data, spec, rounds=4, sync_mode=True)
        assert len(res.metrics) == 4

    def test_fedqs_adapts_lrs(self, rwd_data, spec):
        eng, _ = _run(rwd_data, spec, rounds=10)
        lrs = {round(c.lr, 5) for c in eng.clients}
        assert len(lrs) > 1  # Mod-2 produced heterogeneous lrs

    def test_quadrants_populated(self, rwd_data, spec):
        eng, res = _run(rwd_data, spec, rounds=10)
        qc = res.metrics[-1].quadrant_counts
        assert sum(qc.values()) == rwd_data.n_clients


class TestDynamics:
    def test_resource_scale_scenario(self, rwd_data, spec):
        eng, res = _run(rwd_data, spec, rounds=6,
                        dynamics=scenario_resource_scale(3, 100.0))
        assert len(res.metrics) == 6

    def test_unstable_resources(self, rwd_data, spec):
        eng, res = _run(rwd_data, spec, rounds=6,
                        dynamics=scenario_unstable_resources())
        assert len(res.metrics) == 6

    def test_dropout_kills_clients(self, rwd_data, spec):
        eng, res = _run(rwd_data, spec, rounds=8,
                        dynamics=scenario_dropout(2, 0.5))
        assert (~eng.alive).sum() == rwd_data.n_clients // 2
        assert len(res.metrics) == 8


def _result(accs):
    from repro.core.safl import EngineResult
    from repro.core.types import RoundMetrics

    ms = [RoundMetrics(round=i + 1, virtual_time=float(i), loss=1.0 - a,
                       accuracy=a, n_stale=0, mean_staleness=0.0)
          for i, a in enumerate(accs)]
    return EngineResult(ms, 0.0, None)


class TestResultHelpers:
    def test_metrics_api(self, rwd_data, spec):
        _, res = _run(rwd_data, spec, rounds=6)
        assert 0.0 <= res.best_accuracy() <= 1.0
        assert res.oscillations(threshold=0.0) >= 0
        t = res.rounds_to_accuracy(0.0)
        assert t == 1  # trivially reached at first eval

    @pytest.mark.parametrize("last", [0, -1, -20])
    def test_final_accuracy_nonpositive_window_raises(self, last):
        with pytest.raises(ValueError):
            _result([0.5, 0.6]).final_accuracy(last)

    def test_final_accuracy_window_longer_than_history(self):
        # a too-long tail window averages whatever exists, never raises
        res = _result([0.2, 0.4, 0.6])
        assert res.final_accuracy(3) == pytest.approx(0.4)
        assert res.final_accuracy(4) == pytest.approx(0.4)
        assert res.final_accuracy(10_000) == pytest.approx(0.4)

    def test_empty_metrics_accessors(self):
        res = _result([])
        assert res.best_accuracy() == 0.0
        assert res.final_accuracy() == 0.0
        assert res.final_accuracy(1) == 0.0
        assert res.rounds_to_accuracy(0.5) is None
        assert res.oscillations() == 0
        assert res.stability_score() == 1.0
        assert res.virtual_time() == 0.0

    def test_stability_score_bounds_and_degenerate(self):
        assert _result([0.7]).stability_score() == 1.0      # no transitions
        assert _result([0.1, 0.2, 0.3]).stability_score() == 1.0
        # every transition is a deep drop -> the floor of the score
        assert _result([0.9, 0.1]).stability_score() == 0.0
        # sawtooth: drops at 2 of 3 transitions
        assert _result([0.9, 0.1, 0.9, 0.1]).stability_score() == \
            pytest.approx(1 - 2 / 3)

    def test_stability_score_monotone_in_oscillations(self):
        # histories of equal length with 0, 1, 2, 3 deep drops: the score
        # must be non-increasing as the oscillation count grows
        base = [0.5] * 8
        histories = []
        for k in range(4):
            acc = list(base)
            for j in range(k):
                acc[2 * j + 1] = 0.9   # up...
                acc[2 * j + 2] = 0.1   # ...then a deep drop
            histories.append(_result(acc))
        counts = [r.oscillations() for r in histories]
        scores = [r.stability_score() for r in histories]
        assert counts == sorted(counts)
        assert scores == sorted(scores, reverse=True)
        assert all(0.0 <= s <= 1.0 for s in scores)


class TestAllAlgorithmsRun:
    @pytest.mark.parametrize("name", [
        "fedqs-sgd", "fedqs-avg", "fedavg", "fedsgd", "safa", "fedat",
        "m-step", "defedavg", "fedbuff", "wkafl", "fedac", "fadas", "ca2fl"])
    def test_runs_and_finite(self, rwd_data, spec, name):
        _, res = _run(rwd_data, spec, name=name, rounds=4)
        assert len(res.metrics) == 4
        assert all(np.isfinite(m.loss) for m in res.metrics)
