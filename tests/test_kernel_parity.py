"""Property-based kernel-parity harness: every ``repro.kernels.ops``
dispatcher fuzzed against its ``repro.kernels.ref`` oracle in interpret
mode (docs/KERNELS.md — the oracle contract).

Strategies draw shapes from small curated grids (every distinct shape is
a fresh interpret-mode compile, so an unbounded integer strategy would
spend the whole budget tracing) and randomize *contents* through seeded
numpy generators: zero and extreme weights, saturated int8 codes,
out-of-range and empty segment ids, D far from any block multiple, K=1.

Tolerance contract:

* the fused ingestion ops are BIT-EXACT against their jitted oracles —
  kernel body and oracle share the ``ingest_weights`` algebra and both
  run under jit, so XLA lowers the same subgraph (see ref.py);
* the older kernels keep their established allclose gates (their refs
  are eager, so op-by-op rounding differs at ~1e-7).

Run explicitly (the conftest guard skips collection when hypothesis is
absent):  python -m pytest tests/test_kernel_parity.py -q \
              --hypothesis-profile kernel-ci
"""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.kernels import ref
from repro.kernels.ops import (
    cosine_op,
    dequant_agg_op,
    ingest_agg_op,
    ingest_segment_agg_op,
    segment_agg_op,
    similarity_stats_op,
    stats_agg_op,
    weighted_agg_op,
    window_decode_attention_op,
)

# shape grids: interesting, cheap, and few enough that interpret-mode
# compiles stay bounded.  D values straddle nothing-special (64), odd
# primes (257), and D ≫/≪ any block multiple boundary for the default
# 2048/4096 blocks (every D here exercises the partial-block path).
KS = st.sampled_from([1, 2, 3, 4, 7, 8, 12])
DS = st.sampled_from([1, 5, 64, 100, 257, 500, 700])
SEEDS = st.integers(0, 2**31 - 1)
WEIGHT_REGIMES = st.sampled_from(["normal", "zero", "extreme"])


def _weights(rng, k, regime):
    if regime == "zero":
        return np.zeros(k, np.float32)
    if regime == "extreme":
        return rng.choice([1e-6, 1e6, 0.0], k).astype(np.float32)
    return rng.uniform(0.0, 2.0, k).astype(np.float32)


def _meta(rng, k, regime, n_clients=64):
    """Eq. §3.4 per-row metadata in (and beyond) serving ranges."""
    if regime == "zero":
        n = np.zeros(k, np.float32)          # all-padding buffer
        fb = np.zeros(k, np.float32)
    elif regime == "extreme":
        n = rng.choice([0.0, 1.0, 1e6], k).astype(np.float32)
        fb = (rng.random(k) < 0.8).astype(np.float32)
    else:
        n = rng.integers(1, 200, k).astype(np.float32)
        fb = (rng.random(k) < 0.5).astype(np.float32)
    F = rng.uniform(0.2, 5.0, k).astype(np.float32)
    G = rng.uniform(0.2, 5.0, k).astype(np.float32)
    return n, F, G, fb


class TestWeightedAggFuzz:
    @given(KS, DS, SEEDS, WEIGHT_REGIMES)
    @settings(deadline=None)
    def test_matches_ref(self, K, D, seed, regime):
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.standard_normal((K, D)).astype(np.float32))
        w = jnp.asarray(_weights(rng, K, regime))
        got = weighted_agg_op(x, w)
        want = ref.weighted_agg_ref(x, w)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


class TestDequantAggFuzz:
    @given(KS, st.sampled_from([(1, 8), (2, 64), (4, 100), (3, 257)]),
           SEEDS, WEIGHT_REGIMES, st.booleans())
    @settings(deadline=None)
    def test_matches_ref(self, K, layout, seed, regime, saturate):
        nc, chunk = layout
        D = nc * chunk
        rng = np.random.default_rng(seed)
        q = rng.integers(-128, 128, (K, D)).astype(np.int8)
        if saturate:
            q[:, : min(chunk, D)] = rng.choice([-128, 127])
        scales = (rng.random((K, nc)).astype(np.float32)) * 1e-2
        w = jnp.asarray(_weights(rng, K, regime))
        got = dequant_agg_op(jnp.asarray(q), jnp.asarray(scales), w,
                             chunk=chunk)
        want = ref.dequant_agg_ref(jnp.asarray(q), jnp.asarray(scales), w)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


class TestSegmentAggFuzz:
    @given(KS, DS, st.sampled_from([1, 2, 4, 8]), SEEDS, WEIGHT_REGIMES)
    @settings(deadline=None)
    def test_matches_ref(self, K, D, G, seed, regime):
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.standard_normal((K, D)).astype(np.float32))
        w = jnp.asarray(_weights(rng, K, regime))
        # ids may hit G (out of range → dropped); some segments stay empty
        seg = jnp.asarray(rng.integers(0, G + 1, K).astype(np.int32))
        got = segment_agg_op(x, w, seg, num_segments=G)
        want = ref.segment_agg_ref(x, w, seg, G)
        assert got.shape == (G, D)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


class TestIngestAggFuzz:
    """The fused ingestion op is bit-exact against its jitted oracle."""

    @given(KS, DS, SEEDS, WEIGHT_REGIMES, st.booleans(), st.booleans())
    @settings(deadline=None)
    def test_dense_bitexact(self, K, D, seed, regime, normalize, bucketed):
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.standard_normal((K, D)).astype(np.float32))
        n, F, G, fb = _meta(rng, K, regime)
        # bucketed: trailing rows are padding (n = fb = 0), logical k < K
        k = None
        if bucketed and K > 1:
            n[-1] = fb[-1] = 0.0
            k = jnp.float32(K - 1)
        args = (x, None, jnp.asarray(n), jnp.asarray(F), jnp.asarray(G),
                jnp.asarray(fb), k)
        got = ingest_agg_op(*args, n_clients=64, normalize=normalize)
        want = ref.ingest_agg_ref(*args, n_clients=64, normalize=normalize)
        assert got.shape == (D,)
        assert jnp.array_equal(got, want), (
            f"ingest_agg diverged from oracle: K={K} D={D} seed={seed} "
            f"regime={regime} normalize={normalize} "
            f"max|Δ|={float(jnp.abs(got - want).max()):.3e}")

    @given(KS, st.sampled_from([(1, 8), (2, 64), (4, 100)]), SEEDS,
           WEIGHT_REGIMES, st.booleans())
    @settings(deadline=None)
    def test_int8_bitexact(self, K, layout, seed, regime, saturate):
        nc, chunk = layout
        D = nc * chunk
        rng = np.random.default_rng(seed)
        q = rng.integers(-128, 128, (K, D)).astype(np.int8)
        if saturate:
            q[:, : min(chunk, D)] = rng.choice([-128, 127])
        scales = rng.random((K, nc)).astype(np.float32) * 1e-2
        n, F, G, fb = _meta(rng, K, regime)
        args = (jnp.asarray(q), jnp.asarray(scales), jnp.asarray(n),
                jnp.asarray(F), jnp.asarray(G), jnp.asarray(fb), None)
        got = ingest_agg_op(*args, chunk=chunk, n_clients=64)
        want = ref.ingest_agg_ref(*args, n_clients=64)
        assert jnp.array_equal(got, want), (
            f"ingest_agg int8 diverged: K={K} nc={nc} chunk={chunk} "
            f"seed={seed} regime={regime}")


class TestStatsAggFuzz:
    """The fused stats variant (health plane, docs/OBSERVABILITY.md).

    The load-bearing contract is that emitting statistics must not
    perturb aggregation: the stats kernel's aggregate is BIT-IDENTICAL
    to the plain ingestion kernel's on every input.  Against the jitted
    oracle, ``row_sq`` and the fold weights are bit-exact; the aggregate
    is bit-exact in the serving configuration (normalized weights) and
    ulp-tight otherwise — raw extreme weights (~1e11 spread,
    ``normalize=False``) shift the dot's contraction order by a last
    ulp, a latitude the plain ingestion kernel shares."""

    @given(KS, DS, SEEDS, WEIGHT_REGIMES, st.booleans(), st.booleans())
    @settings(deadline=None)
    def test_dense_parity(self, K, D, seed, regime, normalize, with_cf):
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.standard_normal((K, D)).astype(np.float32))
        n, F, G, fb = _meta(rng, K, regime)
        cf = (jnp.asarray(rng.uniform(0.05, 1.0, K).astype(np.float32))
              if with_cf else None)
        meta = (jnp.asarray(n), jnp.asarray(F), jnp.asarray(G),
                jnp.asarray(fb))
        agg, row_sq, w = stats_agg_op(x, *meta, None, cf, n_clients=64,
                                      normalize=normalize)
        ragg, rrow_sq, rw = ref.stats_agg_ref(x, *meta, None, cf,
                                              n_clients=64,
                                              normalize=normalize)
        label = (f"K={K} D={D} seed={seed} regime={regime} "
                 f"normalize={normalize} cf={with_cf}")
        assert row_sq.shape == (K,) and w.shape == (K,)
        assert jnp.array_equal(row_sq, rrow_sq), f"row_sq diverged: {label}"
        assert jnp.array_equal(w, rw), f"weights diverged: {label}"
        scale = max(1.0, float(jnp.abs(ragg).max()))
        assert float(jnp.abs(agg - ragg).max()) <= 1e-6 * scale, (
            f"stats_agg aggregate left the oracle's ulp envelope: {label} "
            f"max|Δ|={float(jnp.abs(agg - ragg).max()):.3e}")
        # the hard gate: stats emission never changes the aggregate
        plain = ingest_agg_op(x, None, *meta, None, cf, n_clients=64,
                              normalize=normalize)
        assert jnp.array_equal(agg, plain), (
            f"stats variant perturbed the aggregate: {label}")


class TestIngestSegmentAggFuzz:
    @given(KS, DS, st.sampled_from([1, 2, 4, 8]), SEEDS, WEIGHT_REGIMES)
    @settings(deadline=None)
    def test_dense_bitexact(self, K, D, G, seed, regime):
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.standard_normal((K, D)).astype(np.float32))
        n, F, Gr, fb = _meta(rng, K, regime)
        seg = jnp.asarray(rng.integers(0, G + 1, K).astype(np.int32))
        args = (x, None, seg, jnp.asarray(n), jnp.asarray(F),
                jnp.asarray(Gr), jnp.asarray(fb), None)
        got = ingest_segment_agg_op(*args, num_segments=G, n_clients=64)
        want = ref.ingest_segment_agg_ref(*args, num_segments=G,
                                          n_clients=64)
        assert got.shape == (G, D)
        assert jnp.array_equal(got, want), (
            f"ingest_segment_agg diverged: K={K} D={D} G={G} seed={seed} "
            f"regime={regime}")

    @given(st.sampled_from([2, 4, 8]), st.sampled_from([(2, 64), (4, 100)]),
           SEEDS)
    @settings(deadline=None)
    def test_int8_fb_zero_equals_plain_weights(self, K, layout, seed):
        """fb=0 + normalize=False ⇒ weights are exactly n_samples — the
        tier-edge contract ``hier.partial._materialize_quant`` relies on."""
        nc, chunk = layout
        D = nc * chunk
        rng = np.random.default_rng(seed)
        q = rng.integers(-128, 128, (K, D)).astype(np.int8)
        scales = rng.random((K, nc)).astype(np.float32) * 1e-2
        w = rng.uniform(0.5, 3.0, K).astype(np.float32)
        z = jnp.zeros(K, jnp.float32)
        seg = jnp.asarray(rng.integers(0, 2, K).astype(np.int32))
        got = ingest_segment_agg_op(
            jnp.asarray(q), jnp.asarray(scales), seg, jnp.asarray(w),
            z, z, z, None, num_segments=2, chunk=chunk, n_clients=1,
            normalize=False)
        want = ref.ingest_segment_agg_ref(
            jnp.asarray(q), jnp.asarray(scales), seg, jnp.asarray(w),
            z, z, z, None, num_segments=2, n_clients=1, normalize=False)
        assert jnp.array_equal(got, want)


class TestPartialWorkWeights:
    """Partial-work (completed_fraction) weight algebra, fuzzed.

    The device-state layer (docs/ROBUSTNESS.md) scales each row's
    pre-normalization Eq. §3.4 weight by its completed fraction.  Three
    contracts: cf of exactly 1 is a bit-identical no-op (×1.0 is IEEE
    exact, and the cf=None fast path skips the multiply entirely); any
    cf < 1 strictly attenuates a positive weight; and the fused kernels
    stay bit-exact against their oracles with a cf column in play.
    """

    @given(KS, DS, SEEDS, WEIGHT_REGIMES, st.booleans(), st.booleans())
    @settings(deadline=None)
    def test_cf_ones_is_identity(self, K, D, seed, regime, normalize, int8):
        rng = np.random.default_rng(seed)
        n, F, G, fb = _meta(rng, K, regime)
        if int8:
            chunk = 64
            D = 2 * chunk
            q = jnp.asarray(rng.integers(-128, 128, (K, D)).astype(np.int8))
            scales = jnp.asarray(rng.random((K, 2)).astype(np.float32) * 1e-2)
        else:
            chunk = 0
            q = jnp.asarray(rng.standard_normal((K, D)).astype(np.float32))
            scales = None
        meta = (jnp.asarray(n), jnp.asarray(F), jnp.asarray(G),
                jnp.asarray(fb))
        base = ingest_agg_op(q, scales, *meta, None, None,
                             chunk=chunk, n_clients=64, normalize=normalize)
        ones = ingest_agg_op(q, scales, *meta, None, jnp.ones(K, jnp.float32),
                             chunk=chunk, n_clients=64, normalize=normalize)
        assert jnp.array_equal(base, ones), (
            f"cf=1 not a no-op: K={K} D={D} seed={seed} regime={regime} "
            f"normalize={normalize} int8={int8}")

    @given(KS, SEEDS, st.floats(0.05, 0.95))
    @settings(deadline=None)
    def test_partial_weight_strictly_below_full(self, K, seed, cf_val):
        rng = np.random.default_rng(seed)
        n = rng.integers(1, 200, K).astype(np.float32)
        F = rng.uniform(0.2, 5.0, K).astype(np.float32)
        G = rng.uniform(0.2, 5.0, K).astype(np.float32)
        fb = (rng.random(K) < 0.5).astype(np.float32)
        full = ref.ingest_weights(
            jnp.asarray(n), jnp.asarray(F), jnp.asarray(G), jnp.asarray(fb),
            jnp.float32(K), n_clients=64, normalize=False)
        part = ref.ingest_weights(
            jnp.asarray(n), jnp.asarray(F), jnp.asarray(G), jnp.asarray(fb),
            jnp.float32(K), n_clients=64, normalize=False,
            cf=jnp.full(K, cf_val, jnp.float32))
        assert bool((part < full).all()), (
            f"cf={cf_val} did not strictly attenuate: seed={seed}")
        np.testing.assert_allclose(np.asarray(part),
                                   np.asarray(full) * np.float32(cf_val),
                                   rtol=1e-6)

    @given(KS, DS, SEEDS, WEIGHT_REGIMES, st.booleans())
    @settings(deadline=None)
    def test_dense_cf_bitexact(self, K, D, seed, regime, normalize):
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.standard_normal((K, D)).astype(np.float32))
        n, F, G, fb = _meta(rng, K, regime)
        cf = jnp.asarray(rng.uniform(0.05, 1.0, K).astype(np.float32))
        args = (x, None, jnp.asarray(n), jnp.asarray(F), jnp.asarray(G),
                jnp.asarray(fb), None, cf)
        got = ingest_agg_op(*args, n_clients=64, normalize=normalize)
        want = ref.ingest_agg_ref(*args, n_clients=64, normalize=normalize)
        assert jnp.array_equal(got, want), (
            f"ingest_agg+cf diverged: K={K} D={D} seed={seed} "
            f"regime={regime} normalize={normalize}")

    @given(KS, st.sampled_from([1, 2, 4]), SEEDS, st.booleans())
    @settings(deadline=None)
    def test_segment_cf_bitexact(self, K, G, seed, int8):
        rng = np.random.default_rng(seed)
        n, F, Gr, fb = _meta(rng, K, "normal")
        if int8:
            chunk = 64
            D = 2 * chunk
            q = jnp.asarray(rng.integers(-128, 128, (K, D)).astype(np.int8))
            scales = jnp.asarray(rng.random((K, 2)).astype(np.float32) * 1e-2)
        else:
            chunk = 0
            q = jnp.asarray(rng.standard_normal((K, 100)).astype(np.float32))
            scales = None
        seg = jnp.asarray(rng.integers(0, G + 1, K).astype(np.int32))
        cf = jnp.asarray(rng.uniform(0.05, 1.0, K).astype(np.float32))
        args = (q, scales, seg, jnp.asarray(n), jnp.asarray(F),
                jnp.asarray(Gr), jnp.asarray(fb), None, cf)
        got = ingest_segment_agg_op(*args, num_segments=G, chunk=chunk,
                                    n_clients=64)
        want = ref.ingest_segment_agg_ref(*args, num_segments=G,
                                          n_clients=64)
        assert jnp.array_equal(got, want), (
            f"ingest_segment_agg+cf diverged: K={K} G={G} seed={seed} "
            f"int8={int8}")


class TestWindowAttentionFuzz:
    @given(st.sampled_from([(1, 4, 4, 32, 16), (2, 8, 2, 64, 32),
                            (3, 4, 1, 32, 16)]),
           st.integers(1, 32), SEEDS)
    @settings(deadline=None)
    def test_matches_ref(self, dims, valid, seed):
        B, H, KV, W, dh = dims
        rng = np.random.default_rng(seed)
        q = jnp.asarray(rng.standard_normal((B, H, dh)).astype(np.float32))
        k = jnp.asarray(rng.standard_normal((B, W, KV, dh)).astype(np.float32))
        v = jnp.asarray(rng.standard_normal((B, W, KV, dh)).astype(np.float32))
        vl = jnp.asarray(min(valid, W))
        got = window_decode_attention_op(q, k, v, vl)
        want = ref.window_decode_attention_ref(q, k, v, vl)
        np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


class TestSimilarityFuzz:
    @given(DS, SEEDS)
    @settings(deadline=None)
    def test_stats_match_ref(self, D, seed):
        rng = np.random.default_rng(seed)
        a = jnp.asarray(rng.standard_normal(D).astype(np.float32))
        b = jnp.asarray(rng.standard_normal(D).astype(np.float32))
        np.testing.assert_allclose(similarity_stats_op(a, b),
                                   ref.fused_similarity_stats_ref(a, b),
                                   rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(cosine_op(a, b),
                                   ref.cosine_from_stats_ref(a, b),
                                   rtol=1e-4, atol=1e-5)
