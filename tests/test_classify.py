"""Mod-2 (divide-and-conquer adaptation) unit + property tests."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, strategies as st

from repro.core.classify import (
    adapt,
    adapt_learning_rate,
    classify_quadrant,
    mean_similarity,
    momentum_rate,
    speed_ratio,
    ssbc_situation,
    update_speed,
)
from repro.core.types import FedQSHyperParams, Quadrant, SSBCSituation

HP = FedQSHyperParams()

pos = st.floats(1e-4, 1e3, allow_nan=False)
sim = st.floats(-1.0, 1.0, allow_nan=False)


class TestQuadrants:
    def test_four_corners(self):
        # (fast, biased) (fast, weak) (slow, weak) (slow, biased)
        assert int(classify_quadrant(2.0, 1.0, 0.1, 0.5)) == Quadrant.FSBC
        assert int(classify_quadrant(2.0, 1.0, 0.9, 0.5)) == Quadrant.FWBC
        assert int(classify_quadrant(0.5, 1.0, 0.9, 0.5)) == Quadrant.SWBC
        assert int(classify_quadrant(0.5, 1.0, 0.1, 0.5)) == Quadrant.SSBC

    @given(pos, pos, sim, sim)
    def test_partition_total_and_disjoint(self, f, fb, s, sb):
        q = int(classify_quadrant(f, fb, s, sb))
        assert q in (0, 1, 2, 3)
        # consistency with the defining inequalities
        fast, weak = f > fb, s >= sb
        expect = {(True, False): 0, (True, True): 1,
                  (False, True): 2, (False, False): 3}[(fast, weak)]
        assert q == expect


class TestLearningRate:
    def test_fsbc_keeps_lr(self):
        lr = adapt_learning_rate(jnp.float32(0.1), jnp.int32(Quadrant.FSBC), 1.0, HP)
        assert float(lr) == pytest.approx(0.1)

    def test_fwbc_decreases_lr(self):
        lr = adapt_learning_rate(jnp.float32(0.1), jnp.int32(Quadrant.FWBC), 1.0, HP)
        assert float(lr) == pytest.approx(0.1 - HP.a)

    def test_stragglers_increase_lr(self):
        for q in (Quadrant.SWBC, Quadrant.SSBC):
            lr = adapt_learning_rate(jnp.float32(0.1), jnp.int32(q), 2.0, HP)
            assert float(lr) == pytest.approx(0.1 + HP.a * 2.0)

    @given(st.floats(0.001, 0.5), pos,
           st.sampled_from([0, 1, 2, 3]))
    def test_lr_always_within_bounds(self, lr0, F, q):
        lr = adapt_learning_rate(jnp.float32(lr0), jnp.int32(q), jnp.float32(F), HP)
        assert HP.lr_min - 1e-7 <= float(lr) <= HP.lr_max + 1e-7


class TestMomentum:
    def test_momentum_formula(self):
        # m = m0 + k(1/G − 1)
        m = momentum_rate(jnp.float32(0.5), HP)
        assert float(m) == pytest.approx(HP.m0 + HP.k * (1 / 0.5 - 1))

    @given(pos)
    def test_momentum_clipped(self, G):
        m = float(momentum_rate(jnp.float32(G), HP))
        assert 0.0 <= m <= HP.momentum_max

    def test_aligned_clients_get_more_momentum(self):
        # smaller G = s̄/s_i (client more aligned) ⇒ larger momentum
        assert float(momentum_rate(jnp.float32(0.5), HP)) > float(
            momentum_rate(jnp.float32(2.0), HP))


class TestSSBCSituation:
    def test_uniform_labels_is_straggler(self):
        acc = jnp.asarray([0.8, 0.82, 0.79, 0.81])
        assert int(ssbc_situation(acc, 0.5)) == SSBCSituation.STRAGGLER

    def test_dispersed_labels_is_situation2(self):
        acc = jnp.asarray([0.95, 0.05, 0.9, 0.02])
        assert int(ssbc_situation(acc, 0.5)) == SSBCSituation.DISPERSED

    def test_nan_labels_ignored(self):
        acc = jnp.asarray([0.8, jnp.nan, 0.8, jnp.nan])
        assert int(ssbc_situation(acc, 0.5)) == SSBCSituation.STRAGGLER


class TestAdaptEndToEnd:
    def test_fsbc_raises_feedback_no_momentum(self):
        d = adapt(2.0, 1.0, 0.1, 0.5, 0.1, HP)
        assert int(d.quadrant) == Quadrant.FSBC
        assert bool(d.feedback)
        assert float(d.momentum) == 0.0

    def test_ssbc_situation2_raises_feedback(self):
        d = adapt(0.5, 1.0, 0.1, 0.5, 0.1, HP, ssbc_sit=SSBCSituation.DISPERSED)
        assert int(d.quadrant) == Quadrant.SSBC
        assert bool(d.feedback)
        assert float(d.momentum) == 0.0

    def test_ssbc_situation1_gets_momentum(self):
        # mildly-biased straggler: momentum path, no feedback.  (A *strongly*
        # anti-aligned SSBC gets m clipped to 0 — the Eq-3 formula m0+k(1/G−1)
        # goes negative for G ≫ 1, which is the paper's intended damping.)
        d = adapt(0.5, 1.0, 0.45, 0.5, 0.1, HP, ssbc_sit=SSBCSituation.STRAGGLER)
        assert not bool(d.feedback)
        assert float(d.momentum) > 0.0
        # strongly-biased straggler: momentum floors at 0
        d2 = adapt(0.5, 1.0, 0.1, 0.5, 0.1, HP, ssbc_sit=SSBCSituation.STRAGGLER)
        assert float(d2.momentum) == 0.0

    def test_momentum_ablation_switch(self):
        hp = FedQSHyperParams(use_momentum=False)
        d = adapt(0.5, 1.0, 0.9, 0.5, 0.1, hp)
        assert float(d.momentum) == 0.0

    @given(pos, pos, sim, sim)
    def test_F_G_ratios_clamped(self, f, fb, s, sb):
        d = adapt(f, fb, s, sb, 0.1, HP)
        assert 1 / HP.ratio_clip <= float(d.F) <= HP.ratio_clip
        assert 1 / HP.ratio_clip <= float(d.G) <= HP.ratio_clip


def test_update_speed_eq2():
    counts = jnp.asarray([2, 4, 2, 0])
    f, f_bar = update_speed(counts)
    np.testing.assert_allclose(np.asarray(f), [0.25, 0.5, 0.25, 0.0])
    assert float(f_bar) == pytest.approx(0.25)  # = 1/N


def test_mean_similarity():
    assert float(mean_similarity(jnp.asarray([0.0, 1.0]))) == pytest.approx(0.5)
