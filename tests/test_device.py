"""Device-state layer (docs/ROBUSTNESS.md): availability chains, latency
models, mid-round dropout, partial local work, and the adaptive deadline
trigger — plus the bit-identity parity gates that pin the all-complete
device path to the legacy engine."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import FedQSHyperParams, make_algorithm
from repro.core.types import Update
from repro.models import make_mlp_spec
from repro.scenarios import (
    BimodalLatency,
    CohortEngine,
    DeviceStateModel,
    LognormalLatency,
    MarkovAvailability,
    get_scenario,
)
from repro.scenarios.arrivals import TraceReplay
from repro.scenarios.scenario import Scenario
from repro.serve import (
    AdaptiveTimeWindow,
    AdmitAll,
    StalenessAdmission,
    StreamingAggregator,
    TimeWindow,
    make_trigger,
    replay,
    scenario_stream,
)

KEY = jax.random.PRNGKey(0)


def _mk_update(cid=0, completed_fraction=1.0, sent_at=-1.0, stale_round=0):
    return Update(cid=cid, n_samples=50, stale_round=stale_round, lr=0.1,
                  similarity=0.5, feedback=False, speed_f=0.1,
                  completed_fraction=completed_fraction, sent_at=sent_at)


def _leaves_equal(a, b):
    return all(bool(jnp.array_equal(x, y)) for x, y in
               zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)))


# ---------------------------------------------------------------------------
# the model itself
# ---------------------------------------------------------------------------
class TestDeviceStateModel:
    def test_validation(self):
        with pytest.raises(ValueError):
            DeviceStateModel(drop_prob=1.5)
        with pytest.raises(ValueError):
            DeviceStateModel(partial_prob=-0.1)
        with pytest.raises(ValueError):
            DeviceStateModel(partial_range=(0.0, 0.5))   # lo must be > 0
        with pytest.raises(ValueError):
            DeviceStateModel(partial_range=(0.9, 0.3))
        with pytest.raises(ValueError):
            DeviceStateModel(recovery_gap=-1.0)
        with pytest.raises(ValueError):
            LognormalLatency(median=-1.0)
        with pytest.raises(ValueError):
            BimodalLatency(slow_prob=2.0)
        with pytest.raises(ValueError):
            MarkovAvailability(mean_on=0.0)

    def test_trivial_model_draws_nothing(self):
        """The bit-identity contract: an inactive model must not consume
        RNG draws, so all-complete runs replay the device-free stream."""
        dev = DeviceStateModel()
        assert dev.trivial
        rng = np.random.default_rng(7)
        state = rng.bit_generator.state
        for cid in range(16):
            assert dev.round_outcome(cid, rng) == (False, 1.0)
            assert dev.sample_latency(cid, rng) == 0.0
        assert rng.bit_generator.state == state

    def test_outcomes_in_range(self):
        dev = DeviceStateModel(drop_prob=0.3, partial_prob=0.5,
                               partial_range=(0.2, 0.8),
                               latency=LognormalLatency(median=2.0))
        rng = np.random.default_rng(0)
        saw_drop = saw_partial = saw_full = False
        for _ in range(300):
            dropped, cf = dev.round_outcome(0, rng)
            if dropped:
                saw_drop = True
                assert cf == 0.0
            elif cf < 1.0:
                saw_partial = True
                assert 0.2 <= cf <= 0.8
            else:
                saw_full = True
            assert dev.sample_latency(0, rng) >= 0.0
        assert saw_drop and saw_partial and saw_full

    def test_latency_models_sample_positive(self):
        rng = np.random.default_rng(1)
        for m in (LognormalLatency(median=3.0, sigma=1.0),
                  BimodalLatency(fast=1.0, slow=20.0, slow_prob=0.5)):
            xs = [m.sample(0, rng) for _ in range(200)]
            assert min(xs) >= 0.0
            assert m.describe()


class TestMarkovAvailability:
    def test_start_stationary_and_deterministic(self):
        arr = MarkovAvailability(mean_on=50.0, mean_off=20.0)
        a = arr.start(64, np.random.default_rng(3))
        b = MarkovAvailability(mean_on=50.0, mean_off=20.0).start(
            64, np.random.default_rng(3))
        np.testing.assert_array_equal(a, b)
        assert (a >= 0).all()
        # stationary: a healthy majority starts inside an on-period (t=0)
        assert (a == 0.0).mean() > 0.4

    def test_next_start_monotone(self):
        arr = MarkovAvailability(mean_on=10.0, mean_off=5.0)
        rng = np.random.default_rng(0)
        arr.start(4, rng)
        t = 0.0
        for _ in range(200):
            nxt = arr.next_start(2, t + 0.5, rng)
            assert nxt >= t
            t = nxt


# ---------------------------------------------------------------------------
# admission invariant + adaptive deadline trigger
# ---------------------------------------------------------------------------
class TestPartialAdmission:
    def test_nonpositive_fraction_rejected(self):
        for policy in (AdmitAll(), StalenessAdmission(5)):
            u, adm = policy.apply(_mk_update(completed_fraction=0.0), 0)
            assert u is None and not adm.accepted
            assert "completed_fraction" in adm.reason
            u, adm = policy.apply(_mk_update(completed_fraction=-0.5), 0)
            assert u is None and not adm.accepted

    def test_overfull_fraction_clamped(self):
        u, adm = AdmitAll().apply(_mk_update(completed_fraction=1.7), 0)
        assert adm.accepted and u.completed_fraction == 1.0

    def test_full_fraction_untouched(self):
        orig = _mk_update(completed_fraction=1.0)
        u, adm = AdmitAll().apply(orig, 0)
        assert adm.accepted and u is orig


class TestAdaptiveTimeWindow:
    def test_without_observations_matches_fixed_window(self):
        fixed, adaptive = TimeWindow(window=10.0), AdaptiveTimeWindow(window=10.0)
        buf = [_mk_update(0)]
        for t in (0.0, 5.0, 10.0):
            assert fixed.should_fire(list(buf), t) == \
                adaptive.should_fire(list(buf), t)
        assert adaptive.consume_adaptation() is None

    def test_deadline_tracks_latency_quantile(self):
        trig = AdaptiveTimeWindow(window=2.0, q=0.9, slack=1.25, warmup=8)
        now = 0.0
        for i in range(16):
            now += 1.0
            trig.observe(_mk_update(i, sent_at=now - 8.0), now)  # 8.0 latency
        trig.arm(now)
        adapted = trig.consume_adaptation()
        assert adapted is not None
        old_w, new_w, q_lat = adapted
        assert old_w == 2.0
        assert q_lat == pytest.approx(8.0)
        assert new_w == pytest.approx(8.0 * 1.25)
        assert trig.consume_adaptation() is None  # one-shot
        assert "adaptive" in trig.describe()

    def test_window_clamped(self):
        trig = AdaptiveTimeWindow(window=2.0, warmup=4)
        for i in range(8):
            trig.observe(_mk_update(i, sent_at=0.0), 1e6 + i)  # ~1e6 latency
        trig.arm(1e6 + 8.0)
        _, new_w, _ = trig.consume_adaptation()
        assert new_w <= 2.0 * 16  # max_window default: window · 16

    def test_negative_sentinel_not_observed(self):
        trig = AdaptiveTimeWindow(window=2.0, warmup=1)
        trig.observe(_mk_update(0, sent_at=-1.0), 5.0)
        trig.arm(5.0)
        assert trig.consume_adaptation() is None

    def test_factory(self):
        assert isinstance(make_trigger("adaptive", window=3.0),
                          AdaptiveTimeWindow)


# ---------------------------------------------------------------------------
# bit-identity parity: all-complete device runs == legacy runs
# ---------------------------------------------------------------------------
class TestAllCompleteParity:
    def test_stream_bit_identical(self):
        params = make_mlp_spec().init(KEY)
        plain = Scenario(name="p")
        device = Scenario(name="p", device=DeviceStateModel())
        a = list(scenario_stream(params, plain, 24, 80, seed=11))
        b = list(scenario_stream(params, device, 24, 80, seed=11))
        assert len(a) == len(b) == 80
        for (ua, ta), (ub, tb) in zip(a, b):
            assert ta == tb
            assert (ua.cid, ua.n_samples, ua.stale_round, ua.similarity,
                    ua.feedback) == (ub.cid, ub.n_samples, ub.stale_round,
                                     ub.similarity, ub.feedback)
            assert ub.completed_fraction == 1.0

    @pytest.mark.parametrize("batched", [False, True])
    def test_flat_service_bit_identical(self, batched):
        hp = FedQSHyperParams(buffer_k=6)
        spec = make_mlp_spec()
        params = spec.init(KEY)

        def run(scenario):
            svc = StreamingAggregator(
                make_algorithm("fedqs-sgd", hp), hp, params, 24,
                batched=batched)
            stream = scenario_stream(params, scenario, 24, 60, seed=4)
            replay(svc, stream)
            return svc

        a = run(Scenario(name="p"))
        b = run(Scenario(name="p", device=DeviceStateModel()))
        assert a.round == b.round
        assert _leaves_equal(a.global_params, b.global_params)

    def test_cohort_engine_bit_identical(self):
        a = CohortEngine(Scenario(name="p"), 48, seed=3, cohort_k=8).run(5)
        b = CohortEngine(Scenario(name="p", device=DeviceStateModel()),
                         48, seed=3, cohort_k=8).run(5)
        assert _leaves_equal(a.final_params, b.final_params)
        assert [(m.loss, m.accuracy) for m in a.metrics] == \
            [(m.loss, m.accuracy) for m in b.metrics]


# ---------------------------------------------------------------------------
# partial-work weighting end to end (flat vs hier member-exactness)
# ---------------------------------------------------------------------------
class TestPartialWeighting:
    def _stream_with_partials(self, params, n=36, updates=72, seed=9):
        sc = Scenario(name="partial",
                      device=DeviceStateModel(partial_prob=0.5,
                                              partial_range=(0.2, 0.9)))
        return list(scenario_stream(params, sc, n, updates, seed=seed))

    def test_partial_updates_counted_and_weighted(self):
        hp = FedQSHyperParams(buffer_k=6)
        spec = make_mlp_spec()
        params = spec.init(KEY)
        stream = self._stream_with_partials(params)
        assert any(u.completed_fraction < 1.0 for u, _ in stream)
        svc = StreamingAggregator(make_algorithm("fedqs-sgd", hp), hp,
                                  params, 36, batched=True)
        replay(svc, stream)
        assert svc.stats.partial > 0
        # partial work changes the aggregate relative to full-work credit
        full = StreamingAggregator(make_algorithm("fedqs-sgd", hp), hp,
                                   params, 36, batched=True)
        from dataclasses import replace

        replay(full, ((replace(u, completed_fraction=1.0), t)
                      for u, t in stream))
        assert not _leaves_equal(svc.global_params, full.global_params)

    def test_flat_vs_hier_all_pass_parity_with_partials(self):
        from repro.hier import HierarchicalService, Topology

        hp = FedQSHyperParams(buffer_k=6)
        spec = make_mlp_spec()
        params = spec.init(KEY)
        stream = self._stream_with_partials(params)
        algo = make_algorithm("fedqs-sgd", hp)
        flat = StreamingAggregator(algo, hp, params, 36, batched=True)
        replay(flat, iter(stream))
        hier = HierarchicalService(
            make_algorithm("fedqs-sgd", hp), hp, params, 36,
            Topology.from_spec("hier:6", 36))
        replay(hier, iter(stream))
        assert flat.round == hier.round
        fa = np.concatenate([np.ravel(l) for l in
                             jax.tree_util.tree_leaves(flat.global_params)])
        ha = np.concatenate([np.ravel(l) for l in
                             jax.tree_util.tree_leaves(hier.global_params)])
        gap = float(np.max(np.abs(fa - ha)) / max(np.max(np.abs(fa)), 1e-12))
        assert gap <= 1e-5, f"flat/hier partial-work gap {gap:.2e}"


# ---------------------------------------------------------------------------
# checkpoint round-trip with device fields
# ---------------------------------------------------------------------------
class TestDeviceCheckpoint:
    def test_hier_buffers_keep_partial_fields(self, tmp_path):
        from repro.hier import HierarchicalService, Topology
        from repro.serve import KBuffer

        hp = FedQSHyperParams(buffer_k=12)
        spec = make_mlp_spec()
        params = spec.init(KEY)

        def build():
            return HierarchicalService(
                make_algorithm("fedqs-sgd", hp), hp, params, 24,
                Topology.from_spec("hier:4", 24),
                edge_trigger=lambda e: KBuffer(3))

        a = build()
        stream = self._partial_stream(params)
        for u, t in stream[:20]:
            a.submit(u, now=t)
        assert a.pending > 0
        d = str(tmp_path / "ckpt")
        a.save(d)
        b = build()
        b.restore(d)
        cfs_a = sorted(float(getattr(u, "completed_fraction", 1.0))
                       for e in a.edges for u in e.buffer)
        cfs_b = sorted(float(getattr(u, "completed_fraction", 1.0))
                       for e in b.edges for u in e.buffer)
        assert cfs_a == cfs_b
        assert any(c < 1.0 for c in cfs_b), \
            "partial fractions must survive the round trip"
        for u, t in stream[20:]:
            a.submit(u, now=t)
            b.submit(u, now=t)
        assert a.round == b.round
        assert _leaves_equal(a.global_params, b.global_params)

    def _partial_stream(self, params):
        sc = Scenario(name="partial",
                      device=DeviceStateModel(partial_prob=0.6,
                                              partial_range=(0.3, 0.9)))
        return list(scenario_stream(params, sc, 24, 40, seed=2))

    def test_flat_stats_partial_persisted(self, tmp_path):
        hp = FedQSHyperParams(buffer_k=4)
        spec = make_mlp_spec()
        params = spec.init(KEY)
        svc = StreamingAggregator(make_algorithm("fedqs-sgd", hp), hp,
                                  params, 24)
        replay(svc, iter(self._partial_stream(params)))
        assert svc.stats.partial > 0
        d = str(tmp_path / "ckpt")
        svc.save(d)
        with open(os.path.join(d, "service.json")) as f:
            meta = json.load(f)
        assert meta["stats"]["partial"] == svc.stats.partial
        restored = StreamingAggregator(make_algorithm("fedqs-sgd", hp), hp,
                                       params, 24)
        restored.restore(d)
        assert restored.stats.partial == svc.stats.partial


# ---------------------------------------------------------------------------
# engine guards + trace validation (the arrivals fix pin)
# ---------------------------------------------------------------------------
class TestGuards:
    def test_safl_engine_rejects_device_scenarios(self):
        from repro.core import SAFLEngine
        from repro.data import make_federated_data

        data = make_federated_data("rwd", 8, sigma=1.0, seed=0, n_total=400)
        with pytest.raises(ValueError, match="device-state"):
            SAFLEngine(data, make_mlp_spec(),
                       make_algorithm("fedqs-sgd", FedQSHyperParams()),
                       FedQSHyperParams(),
                       scenario=get_scenario("flaky-battery"))


class TestTraceValidation:
    def test_out_of_order_rows_sorted_stably(self):
        tr = TraceReplay([(0, 30.0, 1.0), (0, 10.0, 2.0), (0, 20.0, 3.0),
                          (0, 10.0, 9.0)])
        rng = np.random.default_rng(0)
        starts = tr.start(1, rng)
        assert starts[0] == 10.0
        # stable on equal timestamps: trace order preserved, so the first
        # t=10 row's compute time (2.0) wins
        assert tr.compute_time(0, 10.0, 99.0, rng) == 2.0
        assert tr.next_start(0, 10.5, rng) == 20.0
        assert tr.next_start(0, 20.5, rng) == 30.0

    @pytest.mark.parametrize("bad", [-1.0, float("nan"), float("inf")])
    def test_invalid_timestamps_rejected(self, bad):
        with pytest.raises(ValueError, match="t_arrival"):
            TraceReplay([(3, bad, 1.0)])
