"""Pallas kernel validation: shape/dtype sweeps vs the ref.py oracles,
executed with interpret=True on CPU (deliverable c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ref
from repro.kernels.similarity import cosine_from_stats, fused_similarity_stats
from repro.kernels.weighted_agg import weighted_agg
from repro.kernels.window_attention import window_decode_attention

KEY = jax.random.PRNGKey(0)


class TestWeightedAgg:
    @pytest.mark.parametrize("K,D", [(2, 64), (4, 100), (8, 4096), (16, 5000),
                                     (10, 12289), (3, 1)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, K, D, dtype):
        x = jax.random.normal(KEY, (K, D), dtype)
        w = jax.random.uniform(jax.random.PRNGKey(1), (K,))
        got = weighted_agg(x, w, interpret=True)
        want = ref.weighted_agg_ref(x, w)
        np.testing.assert_allclose(got, want, rtol=2e-2 if dtype == jnp.bfloat16 else 1e-5,
                                   atol=1e-4)

    def test_convex_weights_bound_output(self):
        x = jax.random.normal(KEY, (5, 200))
        w = jnp.full((5,), 0.2)
        got = np.asarray(weighted_agg(x, w, interpret=True))
        xs = np.asarray(x)
        assert (got <= xs.max(0) + 1e-5).all() and (got >= xs.min(0) - 1e-5).all()

    @given(st.integers(2, 8), st.integers(1, 300))
    @settings(max_examples=10)
    def test_property_shapes(self, K, D):
        x = jnp.ones((K, D))
        w = jnp.ones((K,)) / K
        got = weighted_agg(x, w, interpret=True)
        assert got.shape == (D,)
        np.testing.assert_allclose(got, np.ones(D), rtol=1e-5)


class TestSimilarity:
    @pytest.mark.parametrize("D", [64, 1000, 65536, 70000, 131073])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_stats_match_ref(self, D, dtype):
        a = jax.random.normal(KEY, (D,), dtype)
        b = jax.random.normal(jax.random.PRNGKey(1), (D,), dtype)
        got = fused_similarity_stats(a, b, interpret=True)
        want = ref.fused_similarity_stats_ref(a, b)
        np.testing.assert_allclose(got, want,
                                   rtol=2e-2 if dtype == jnp.bfloat16 else 1e-4)

    def test_cosine_of_self_is_one(self):
        a = jax.random.normal(KEY, (5000,))
        s = cosine_from_stats(a, a, interpret=True)
        assert float(s) == pytest.approx(1.0, abs=1e-5)

    def test_cosine_orthogonal(self):
        a = jnp.concatenate([jnp.ones(64), jnp.zeros(64)])
        b = jnp.concatenate([jnp.zeros(64), jnp.ones(64)])
        s = cosine_from_stats(a, b, interpret=True)
        assert float(s) == pytest.approx(0.0, abs=1e-6)


class TestWindowAttention:
    @pytest.mark.parametrize("B,H,KV,W,dh", [
        (1, 4, 4, 32, 16), (2, 8, 2, 64, 32), (2, 8, 8, 128, 64),
        (1, 16, 2, 256, 128), (3, 4, 1, 32, 16),
    ])
    def test_matches_ref_full_window(self, B, H, KV, W, dh):
        q = jax.random.normal(KEY, (B, H, dh))
        k = jax.random.normal(jax.random.PRNGKey(1), (B, W, KV, dh))
        v = jax.random.normal(jax.random.PRNGKey(2), (B, W, KV, dh))
        got = window_decode_attention(q, k, v, jnp.asarray(W), interpret=True)
        want = ref.window_decode_attention_ref(q, k, v, jnp.asarray(W))
        np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)

    @pytest.mark.parametrize("valid", [1, 7, 31, 64])
    def test_partial_validity_masking(self, valid):
        B, H, KV, W, dh = 2, 4, 2, 64, 32
        q = jax.random.normal(KEY, (B, H, dh))
        k = jax.random.normal(jax.random.PRNGKey(1), (B, W, KV, dh))
        v = jax.random.normal(jax.random.PRNGKey(2), (B, W, KV, dh))
        got = window_decode_attention(q, k, v, jnp.asarray(valid), interpret=True)
        want = ref.window_decode_attention_ref(q, k, v, jnp.asarray(valid))
        np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)

    def test_invalid_slots_do_not_leak(self):
        """Changing dead-slot contents must not change the output."""
        B, H, KV, W, dh = 1, 4, 2, 32, 16
        q = jax.random.normal(KEY, (B, H, dh))
        k = jax.random.normal(jax.random.PRNGKey(1), (B, W, KV, dh))
        v = jax.random.normal(jax.random.PRNGKey(2), (B, W, KV, dh))
        valid = jnp.asarray(10)
        out1 = window_decode_attention(q, k, v, valid, interpret=True)
        k2 = k.at[:, 10:].set(999.0)
        v2 = v.at[:, 10:].set(-999.0)
        out2 = window_decode_attention(q, k2, v2, valid, interpret=True)
        np.testing.assert_allclose(out1, out2, rtol=1e-5, atol=1e-5)
