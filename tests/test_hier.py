"""Hierarchical aggregation plane: topology wiring, the segment-reduce
kernel, partial-aggregate algebra, tier nodes, service parity vs the
flat StreamingAggregator, checkpointing, and engine integration."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import FedQSHyperParams, make_algorithm
from repro.core.types import AggregationStrategy, Update
from repro.hier import (
    HierarchicalService,
    MemberView,
    PartialAggregate,
    Topology,
    materialize,
    merge,
    parse_topology,
)
from repro.hier.tier import EdgeAggregator, RegionAggregator
from repro.kernels.ref import segment_agg_ref
from repro.kernels.segment_agg import segment_agg, segment_agg_sharded
from repro.models import make_mlp_spec
from repro.serve import KBuffer, StalenessAdmission, StreamingAggregator, replay, synthetic_stream
from repro.serve.triggers import TimeWindow

KEY = jax.random.PRNGKey(0)


def _mk_update(cid=0, n_samples=50, stale_round=0, similarity=0.5,
               feedback=False, delta=None, params=None):
    return Update(cid=cid, n_samples=n_samples, stale_round=stale_round,
                  lr=0.1, similarity=similarity, feedback=feedback,
                  speed_f=0.1, delta=delta, params=params)


# ---------------------------------------------------------------------------
# topology
# ---------------------------------------------------------------------------
class TestTopology:
    def test_spec_grammar(self):
        t = Topology.from_spec("hier:8", 64)
        assert (t.n_edges, t.n_regions, t.tiers) == (8, 0, 2)
        t = Topology.from_spec("hier:8x4", 64)
        assert (t.n_edges, t.n_regions, t.tiers) == (8, 4, 3)
        assert t.describe() == "hier:8x4"

    @pytest.mark.parametrize("bad", ["tree:4", "hier:", "hier:axb", "hier:4x"])
    def test_bad_specs_rejected(self, bad):
        with pytest.raises(ValueError):
            Topology.from_spec(bad, 64)

    def test_bounds_validated(self):
        with pytest.raises(ValueError):
            Topology.from_spec("hier:65", 64)       # more edges than clients
        with pytest.raises(ValueError):
            Topology.from_spec("hier:4x8", 64)      # more regions than edges

    def test_round_robin_default(self):
        t = Topology.from_spec("hier:4", 10)
        assert t.edge_of(0) == 0 and t.edge_of(5) == 1
        assert all(0 <= t.edge_of(c) < 4 for c in range(10))

    def test_contiguous_region_map(self):
        t = Topology.from_spec("hier:8x4", 64)
        np.testing.assert_array_equal(t.edge_region,
                                      [0, 0, 1, 1, 2, 2, 3, 3])
        assert t.region_of(5) == 2
        np.testing.assert_array_equal(t.edges_in_region(3), [6, 7])

    def test_2tier_has_no_regions(self):
        t = Topology.from_spec("hier:4", 16)
        with pytest.raises(ValueError):
            t.region_of(0)

    def test_population_speed_banding(self):
        rng = np.random.default_rng(0)
        speeds = rng.uniform(1, 50, 64)
        t = Topology.from_spec("hier:8", 64).with_population(speeds)
        # each edge holds a contiguous speed band: the slowest client of
        # edge e+1 is at least as slow as the fastest of edge e
        per_edge = [speeds[t.client_edge == e] for e in range(8)]
        assert all(len(p) == 8 for p in per_edge)
        for a, b in zip(per_edge, per_edge[1:]):
            assert a.max() <= b.min()

    def test_population_label_clusters_within_region(self):
        rng = np.random.default_rng(1)
        speeds = rng.uniform(1, 50, 60)
        labels = rng.dirichlet([0.1] * 4, 60).astype(np.float32)
        t = Topology.from_spec("hier:6x2", 60).with_population(speeds, labels)
        # dominant labels inside one region appear edge-contiguously:
        # the region's member order was sorted by dominant label
        for r in range(2):
            edges = t.edges_in_region(r)
            doms = [np.argmax(labels[t.client_edge == e], 1) for e in edges]
            # label values never interleave back and forth across edges
            firsts = [d.min() for d in doms]
            assert firsts == sorted(firsts)

    def test_noncontiguous_edge_region_respected(self):
        # hand-built interleaved wiring: population assignment must land
        # each speed band on that region's actual edge ids
        t = Topology(n_clients=40, n_edges=4, n_regions=2,
                     client_edge=np.arange(40) % 4,
                     edge_region=np.asarray([0, 1, 0, 1]))
        speeds = np.linspace(1, 50, 40)
        t2 = t.with_population(speeds)
        slow_band = speeds[np.isin(t2.client_edge, t2.edges_in_region(0))]
        fast_band = speeds[np.isin(t2.client_edge, t2.edges_in_region(1))]
        assert slow_band.max() <= fast_band.min()

    def test_bad_edge_region_rejected(self):
        with pytest.raises(ValueError, match="edge_region"):
            Topology(n_clients=8, n_edges=2, n_regions=2,
                     client_edge=np.zeros(8, np.int64),
                     edge_region=np.asarray([0, 0]))  # region 1 empty
        with pytest.raises(ValueError, match="edge_region"):
            Topology(n_clients=8, n_edges=2, n_regions=1,
                     client_edge=np.zeros(8, np.int64),
                     edge_region=np.asarray([0, 5]))  # out of range

    def test_dead_speeds_still_assigned(self):
        speeds = np.asarray([1.0, np.nan, 3.0, np.inf])
        t = Topology.from_spec("hier:2", 4).with_population(speeds)
        assert set(t.client_edge) <= {0, 1}

    def test_parse_topology(self):
        assert parse_topology(None, 8) is None
        assert parse_topology("flat", 8) is None
        assert parse_topology("none", 8) is None
        t = parse_topology("hier:2", 8)
        assert isinstance(t, Topology)
        assert parse_topology(t, 8) is t


# ---------------------------------------------------------------------------
# segment-reduce kernel
# ---------------------------------------------------------------------------
class TestSegmentAggKernel:
    @pytest.mark.parametrize("K,D,G", [
        (4, 128, 2), (100, 5000, 8), (33, 2048, 7), (8, 2049, 3),
    ])
    def test_matches_oracle_exactly(self, K, D, G):
        x = jax.random.normal(KEY, (K, D))
        w = jax.random.uniform(jax.random.PRNGKey(1), (K,))
        seg = jax.random.randint(jax.random.PRNGKey(2), (K,), 0, G)
        got = segment_agg(x, w, seg, num_segments=G, interpret=True)
        want = segment_agg_ref(x, w, seg, G)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_matches_segment_sum_semantics(self):
        K, D, G = 40, 512, 5
        x = jax.random.normal(KEY, (K, D))
        w = jax.random.uniform(jax.random.PRNGKey(1), (K,))
        seg = jax.random.randint(jax.random.PRNGKey(2), (K,), 0, G)
        want = jax.ops.segment_sum(x * w[:, None], seg, num_segments=G)
        got = segment_agg(x, w, seg, num_segments=G, interpret=True)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_out_of_range_rows_drop(self):
        x = jnp.ones((3, 64))
        w = jnp.ones(3)
        seg = jnp.asarray([0, 7, 1], jnp.int32)  # 7 outside [0, 2)
        got = segment_agg(x, w, seg, num_segments=2, interpret=True)
        np.testing.assert_array_equal(np.asarray(got),
                                      np.tile([[1.0], [1.0]], 64))

    def test_rejects_bad_shapes(self):
        with pytest.raises(ValueError):
            segment_agg(jnp.ones((3, 8)), jnp.ones(2), jnp.zeros(3, jnp.int32),
                        num_segments=2, interpret=True)
        with pytest.raises(ValueError):
            segment_agg(jnp.ones((3, 8)), jnp.ones(3), jnp.zeros(3, jnp.int32),
                        num_segments=0, interpret=True)

    def test_sharded_single_device_fallthrough(self):
        x = jax.random.normal(KEY, (10, 256))
        w = jnp.ones(10)
        seg = jnp.asarray(np.arange(10) % 3, jnp.int32)
        got = segment_agg_sharded(x, w, seg, num_segments=3)
        np.testing.assert_array_equal(np.asarray(got),
                                      np.asarray(segment_agg_ref(x, w, seg, 3)))


# ---------------------------------------------------------------------------
# partial aggregates
# ---------------------------------------------------------------------------
def _mk_partial(node_id=0, cids=(0, 1), d=16, seed=0, tier="edge"):
    rng = np.random.default_rng(seed)
    m = len(cids)
    rows = jnp.asarray(rng.normal(size=(m, d)).astype(np.float32))
    weights = jnp.asarray(rng.integers(10, 100, m).astype(np.float32))
    return PartialAggregate(
        tier=tier, node_id=node_id, sum_w=float(weights.sum()),
        cids=np.asarray(cids, np.int64),
        n_samples=np.asarray(weights, np.int64),
        sims=rng.uniform(0, 1, m).astype(np.float32),
        feedback=np.zeros(m, bool),
        stale_rounds=np.asarray(rng.integers(0, 5, m), np.int64),
        rows=rows, row_weights=weights,
    )


class TestPartialAggregate:
    def test_materialize_matches_manual(self):
        p = _mk_partial()
        rows, w = np.asarray(p.rows), np.asarray(p.row_weights)
        assert p.pending
        got = p.materialized()
        assert not p.pending and p.rows is None
        np.testing.assert_allclose(np.asarray(got), (w[:, None] * rows).sum(0),
                                   rtol=1e-6)

    def test_batched_materialize_all_lazy(self):
        ps = [_mk_partial(i, cids=(2 * i, 2 * i + 1), seed=i) for i in range(4)]
        singles = [np.asarray((np.asarray(p.row_weights)[:, None]
                               * np.asarray(p.rows)).sum(0)) for p in ps]
        materialize(ps, use_kernel=True)  # the fused segment kernel path
        for p, want in zip(ps, singles):
            assert not p.pending
            np.testing.assert_allclose(np.asarray(p.sum_wx), want,
                                       rtol=1e-5, atol=1e-5)

    def test_merge_is_associative(self):
        ps = [_mk_partial(i, cids=(i,), seed=i) for i in range(3)]
        left = merge([merge(ps[:2], tier="region", node_id=0, fired_at=0.0),
                      ps[2]], tier="region", node_id=0, fired_at=0.0)
        ps2 = [_mk_partial(i, cids=(i,), seed=i) for i in range(3)]
        right = merge([ps2[0], merge(ps2[1:], tier="region", node_id=0,
                                     fired_at=0.0)],
                      tier="region", node_id=0, fired_at=0.0)
        np.testing.assert_allclose(np.asarray(left.sum_wx),
                                   np.asarray(right.sum_wx), rtol=1e-6)
        assert left.sum_w == right.sum_w
        assert sorted(left.cids) == sorted(right.cids)

    def test_member_view(self):
        ps = [_mk_partial(0, cids=(1, 2)), _mk_partial(1, cids=(3,))]
        view = MemberView(ps)
        assert len(view) == 3
        assert [m.cid for m in view] == [1, 2, 3]
        assert view[2].cid == 3 and view[-1].cid == 3
        with pytest.raises(IndexError):
            view[3]
        # any stock trigger works against the view
        assert KBuffer(3).should_fire(view, 0.0)
        assert not KBuffer(4).should_fire(view, 0.0)

    def test_max_staleness(self):
        p = _mk_partial()
        p.stale_rounds = np.asarray([2, 5], np.int64)
        assert p.max_staleness(7) == 5


# ---------------------------------------------------------------------------
# tier nodes
# ---------------------------------------------------------------------------
class TestTierNodes:
    def _tree(self, seed=0, scale=1.0):
        k = jax.random.PRNGKey(seed)
        return {"w": scale * jax.random.normal(k, (4, 5)),
                "b": jnp.ones(3) * seed}

    def test_edge_fires_on_trigger(self):
        edge = EdgeAggregator(0, KBuffer(2),
                              strategy=AggregationStrategy.GRADIENT)
        assert edge.submit(_mk_update(0, delta=self._tree(1)), 0.0) is None
        assert edge.pending == 1
        p = edge.submit(_mk_update(1, delta=self._tree(2)), 1.0)
        assert p is not None and p.n_members == 2 and edge.pending == 0
        assert p.fired_at == 1.0 and edge.fires == 1

    def test_edge_partial_sums_sample_weighted(self):
        edge = EdgeAggregator(3, KBuffer(2),
                              strategy=AggregationStrategy.GRADIENT)
        t1, t2 = self._tree(1), self._tree(2)
        edge.submit(_mk_update(0, n_samples=10, delta=t1), 0.0)
        p = edge.submit(_mk_update(1, n_samples=30, delta=t2), 0.0)
        from repro.compress import ravel_flat

        want = 10 * np.asarray(ravel_flat(t1)) + 30 * np.asarray(ravel_flat(t2))
        np.testing.assert_allclose(np.asarray(p.materialized()), want,
                                   rtol=1e-5)
        assert p.sum_w == 40.0

    def test_edge_model_strategy_uses_params(self):
        edge = EdgeAggregator(0, KBuffer(1),
                              strategy=AggregationStrategy.MODEL)
        t = self._tree(4)
        p = edge.submit(_mk_update(0, n_samples=5, params=t, delta=None), 0.0)
        from repro.compress import ravel_flat

        np.testing.assert_allclose(np.asarray(p.materialized()),
                                   5 * np.asarray(ravel_flat(t)), rtol=1e-5)

    def test_edge_int8_buffer_fuses_eagerly(self):
        from repro.compress import ClientCompressor, compress_stream

        spec = make_mlp_spec()
        params = spec.init(KEY)
        comp = ClientCompressor("int8", 8, seed=0)
        stream = list(compress_stream(
            iter(list(synthetic_stream(params, 8, 2, seed=0))), comp,
            strategy=AggregationStrategy.GRADIENT))
        edge = EdgeAggregator(0, KBuffer(2),
                              strategy=AggregationStrategy.GRADIENT)
        edge.submit(stream[0][0], 0.0)
        p = edge.submit(stream[1][0], 0.0)
        assert not p.pending, "int8 edges reduce eagerly through dequant_agg"
        from repro.compress import decode

        want = sum(float(u.n_samples) * np.asarray(decode(u.delta))
                   for u, _ in stream[:2])
        np.testing.assert_allclose(np.asarray(p.sum_wx), want,
                                   rtol=1e-4, atol=1e-5)

    def test_edge_raw_topk_defers(self):
        from repro.compress import ClientCompressor, compress_stream

        spec = make_mlp_spec()
        params = spec.init(KEY)
        comp = ClientCompressor("topk:0.2", 8, seed=0)
        stream = list(compress_stream(
            iter(list(synthetic_stream(params, 8, 2, seed=0))), comp,
            strategy=AggregationStrategy.GRADIENT))
        edge = EdgeAggregator(0, KBuffer(2),
                              strategy=AggregationStrategy.GRADIENT)
        edge.submit(stream[0][0], 0.0)
        p = edge.submit(stream[1][0], 0.0)
        assert p.pending, "raw-f32 payloads decode once, reduce at the parent"

    def test_edge_flush(self):
        edge = EdgeAggregator(0, KBuffer(10),
                              strategy=AggregationStrategy.GRADIENT)
        edge.submit(_mk_update(0, delta=self._tree(1)), 0.0)
        p = edge.flush(5.0)
        assert p is not None and p.n_members == 1
        assert edge.flush(6.0) is None

    def test_region_merges_member_counts(self):
        region = RegionAggregator(0, KBuffer(3))
        assert region.submit(_mk_partial(0, cids=(0, 1)), 0.0) is None
        assert region.pending == 2
        merged = region.submit(_mk_partial(1, cids=(2,)), 1.0)
        assert merged is not None and merged.n_members == 3
        assert merged.tier == "region" and region.pending == 0

    def test_region_time_window_trigger(self):
        region = RegionAggregator(0, TimeWindow(5.0, min_updates=1))
        assert region.submit(_mk_partial(0, cids=(0,)), 1.0) is None
        merged = region.submit(_mk_partial(1, cids=(1,)), 7.0)
        assert merged is not None


# ---------------------------------------------------------------------------
# the hierarchical service
# ---------------------------------------------------------------------------
def _rel_gap(a, b):
    gaps = [
        float(np.abs(np.asarray(x) - np.asarray(y)).max()
              / max(np.abs(np.asarray(x)).max(), 1e-12))
        for x, y in zip(jax.tree_util.tree_leaves(a),
                        jax.tree_util.tree_leaves(b))
    ]
    return max(gaps)


class TestHierarchicalService:
    def _stream(self, params, n=64, updates=240, seed=0):
        return list(synthetic_stream(params, n, updates, seed=seed))

    def _flat(self, hp, params, n, algo="fedqs-sgd"):
        return StreamingAggregator(make_algorithm(algo, hp), hp, params, n,
                                   batched=True)

    @pytest.mark.parametrize("spec", ["hier:8", "hier:8x4"])
    def test_allpass_parity_with_flat(self, spec):
        mspec = make_mlp_spec()
        params = mspec.init(KEY)
        hp = FedQSHyperParams(buffer_k=10)
        stream = self._stream(params)
        flat = self._flat(hp, params, 64)
        replay(flat, stream, flush=False)
        hier = HierarchicalService(
            make_algorithm("fedqs-sgd", hp), hp, params, 64,
            Topology.from_spec(spec, 64))
        replay(hier, stream, flush=False)
        assert hier.round == flat.round
        assert _rel_gap(flat.global_params, hier.global_params) <= 1e-5
        np.testing.assert_array_equal(np.asarray(flat.table.counts),
                                      np.asarray(hier.table.counts))
        np.testing.assert_allclose(np.asarray(flat.table.sims),
                                   np.asarray(hier.table.sims), atol=1e-6)

    @pytest.mark.parametrize("algo", ["fedavg", "fedsgd", "defedavg"])
    def test_allpass_parity_base_algorithm(self, algo):
        # defedavg pins the non-FedQS weight path to the algorithm's own
        # _base_weights (uniform), not blanket n-proportional weighting
        mspec = make_mlp_spec()
        params = mspec.init(KEY)
        hp = FedQSHyperParams(buffer_k=8)
        stream = self._stream(params, updates=160)
        flat = self._flat(hp, params, 64, algo=algo)
        replay(flat, stream, flush=False)
        hier = HierarchicalService(
            make_algorithm(algo, hp), hp, params, 64,
            Topology.from_spec("hier:8", 64))
        replay(hier, stream, flush=False)
        assert hier.round == flat.round
        assert _rel_gap(flat.global_params, hier.global_params) <= 1e-5

    def test_buffered_edges_same_result_when_weights_linear(self):
        """With use_feedback off, member weights are n-proportional, so
        ANY edge buffering produces the flat aggregate (the partial
        decomposition is exact) as long as rounds fire identically."""
        mspec = make_mlp_spec()
        params = mspec.init(KEY)
        hp = FedQSHyperParams(buffer_k=12, use_feedback=False)
        stream = self._stream(params, updates=120)
        flat = self._flat(hp, params, 64)
        replay(flat, stream, flush=False)
        hier = HierarchicalService(
            make_algorithm("fedqs-sgd", hp), hp, params, 64,
            Topology.from_spec("hier:4", 64),
            edge_trigger=lambda e: KBuffer(3))
        replay(hier, stream, flush=False)
        # rounds may differ (edges hold stragglers) — compare per-round
        # via the table instead: every admitted member is accounted once
        assert hier.stats.accepted == flat.stats.accepted

    def test_duplicate_cid_table_matches_flat_exactly(self):
        # SAFL allows repeat uploads in one buffer; the similarity table
        # must pick the same (last) occurrence on both services
        mspec = make_mlp_spec()
        params = mspec.init(KEY)
        hp = FedQSHyperParams(buffer_k=4)
        tree = jax.tree_util.tree_map(lambda l: 1e-3 * jnp.ones_like(l),
                                      params)
        ups = [
            _mk_update(1, similarity=0.9, delta=tree, params=tree),
            _mk_update(1, similarity=0.2, delta=tree, params=tree),
            _mk_update(2, similarity=0.5, delta=tree, params=tree),
            _mk_update(1, similarity=0.7, delta=tree, params=tree),
        ]
        flat = StreamingAggregator(make_algorithm("fedqs-sgd", hp), hp,
                                   params, 8, batched=True)
        hier = HierarchicalService(make_algorithm("fedqs-sgd", hp), hp,
                                   params, 8, Topology.from_spec("hier:2", 8))
        for i, u in enumerate(ups):
            flat.submit(u, now=float(i))
            hier.submit(u, now=float(i))
        assert flat.round == hier.round == 1
        np.testing.assert_array_equal(np.asarray(flat.table.sims),
                                      np.asarray(hier.table.sims))
        assert float(flat.table.sims[1]) == pytest.approx(0.7)

    def test_handwired_topology_not_overwritten(self):
        from repro.hier import make_aggregation_service

        mspec = make_mlp_spec()
        params = mspec.init(KEY)
        hp = FedQSHyperParams(buffer_k=4)
        wiring = np.asarray([3, 2, 1, 0] * 4, np.int64)
        topo = Topology(n_clients=16, n_edges=4, n_regions=0,
                        client_edge=wiring.copy())
        svc = make_aggregation_service(
            make_algorithm("fedqs-sgd", hp), hp, params, 16,
            topology=topo, speeds=np.linspace(1, 50, 16))
        np.testing.assert_array_equal(svc.topology.client_edge, wiring)

    def test_rejects_stateful_algorithms(self):
        mspec = make_mlp_spec()
        params = mspec.init(KEY)
        hp = FedQSHyperParams()
        with pytest.raises(ValueError, match="hierarchical"):
            HierarchicalService(make_algorithm("fedbuff", hp), hp, params,
                                8, Topology.from_spec("hier:2", 8))

    def test_rejects_topology_size_mismatch(self):
        mspec = make_mlp_spec()
        params = mspec.init(KEY)
        hp = FedQSHyperParams()
        with pytest.raises(ValueError, match="topology"):
            HierarchicalService(make_algorithm("fedqs-sgd", hp), hp, params,
                                16, Topology.from_spec("hier:2", 8))

    def test_pending_spans_tiers_and_flush_drains(self):
        mspec = make_mlp_spec()
        params = mspec.init(KEY)
        hp = FedQSHyperParams(buffer_k=50)
        hier = HierarchicalService(
            make_algorithm("fedqs-sgd", hp), hp, params, 16,
            Topology.from_spec("hier:4x2", 16),
            edge_trigger=lambda e: KBuffer(2),
            region_trigger=lambda r: KBuffer(4))
        for i, (u, t) in enumerate(self._stream(params, 16, 9, seed=1)):
            hier.submit(u, now=t)
        assert hier.pending == 9 and hier.round == 0
        report = hier.flush(now=100.0)
        assert report is not None and report.n_updates == 9
        assert hier.pending == 0 and hier.round == 1

    def test_admission_drops_before_edges(self):
        mspec = make_mlp_spec()
        params = mspec.init(KEY)
        hp = FedQSHyperParams(buffer_k=4)
        hier = HierarchicalService(
            make_algorithm("fedqs-sgd", hp), hp, params, 8,
            Topology.from_spec("hier:2", 8),
            admission=StalenessAdmission(tau_max=0, mode="drop"))
        hier.round = 5
        res = hier.submit(_mk_update(0, stale_round=1,
                                     delta={"w": jnp.ones(4)}), now=0.0)
        assert not res.accepted and hier.stats.dropped == 1
        assert hier.pending == 0

    def test_round_report_member_semantics(self):
        mspec = make_mlp_spec()
        params = mspec.init(KEY)
        hp = FedQSHyperParams(buffer_k=6)
        reports = []
        hier = HierarchicalService(
            make_algorithm("fedqs-sgd", hp), hp, params, 16,
            Topology.from_spec("hier:4", 16),
            edge_trigger=lambda e: KBuffer(2),
            on_round=reports.append)
        replay(hier, self._stream(params, 16, 40, seed=2), flush=False)
        assert reports
        for rep in reports:
            assert rep.n_updates >= 6
            assert rep.n_distinct <= rep.n_updates
            assert all(hasattr(m, "cid") and hasattr(m, "stale_round")
                       for m in rep.buffer)

    def test_compressed_end_to_end(self):
        from repro.compress import ClientCompressor, compress_stream

        mspec = make_mlp_spec()
        params = mspec.init(KEY)
        hp = FedQSHyperParams(buffer_k=8)
        base = self._stream(params, 16, 80, seed=3)
        comp = ClientCompressor("topk:0.3|int8", 16, seed=0)
        stream = list(compress_stream(iter(base), comp,
                                      strategy=AggregationStrategy.GRADIENT))
        hier = HierarchicalService(
            make_algorithm("fedqs-sgd", hp), hp, params, 16,
            Topology.from_spec("hier:4", 16),
            edge_trigger=lambda e: KBuffer(2))
        hier.compressor = comp
        reports = replay(hier, stream)
        assert hier.round >= 8
        assert all(np.isfinite(np.asarray(l)).all()
                   for l in jax.tree_util.tree_leaves(hier.global_params))
        assert sum(r.n_updates for r in reports) == hier.stats.accepted


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------
class TestHierCheckpoint:
    def _build(self, params, hp):
        return HierarchicalService(
            make_algorithm("fedqs-sgd", hp), hp, params, 32,
            Topology.from_spec("hier:8x4", 32),
            edge_trigger=lambda e: KBuffer(2),
            region_trigger=lambda r: KBuffer(4))

    def test_round_trip_with_inflight_tier_buffers(self):
        mspec = make_mlp_spec()
        params = mspec.init(KEY)
        hp = FedQSHyperParams(buffer_k=12)
        stream = list(synthetic_stream(params, 32, 100, seed=0))
        a = self._build(params, hp)
        half = 55
        for u, t in stream[:half]:
            a.submit(u, now=t)
        assert a.pending > 0, "checkpoint must capture in-flight tier state"
        with tempfile.TemporaryDirectory() as d:
            a.save(d)
            assert os.path.exists(os.path.join(d, "hier.npz"))
            b = self._build(params, hp)
            b.restore(d)
        assert b.pending == a.pending and b.round == a.round
        assert [e.fires for e in b.edges] == [e.fires for e in a.edges]
        assert [r.fires for r in b.regions] == [r.fires for r in a.regions]
        for u, t in stream[half:]:
            a.submit(u, now=t)
            b.submit(u, now=t)
        assert a.round == b.round
        assert _rel_gap(a.global_params, b.global_params) == 0.0

    def test_restore_does_not_mutate_shared_topology(self):
        mspec = make_mlp_spec()
        params = mspec.init(KEY)
        hp = FedQSHyperParams(buffer_k=12)
        shared = Topology.from_spec("hier:4", 32)
        a = HierarchicalService(make_algorithm("fedqs-sgd", hp), hp, params,
                                32, shared)
        before = shared.client_edge.copy()
        with tempfile.TemporaryDirectory() as d:
            a.save(d)
            b = HierarchicalService(make_algorithm("fedqs-sgd", hp), hp,
                                    params, 32, shared)
            b.restore(d)
        np.testing.assert_array_equal(shared.client_edge, before)
        assert b.topology is not shared

    def test_topology_mismatch_rejected(self):
        mspec = make_mlp_spec()
        params = mspec.init(KEY)
        hp = FedQSHyperParams(buffer_k=12)
        a = self._build(params, hp)
        with tempfile.TemporaryDirectory() as d:
            a.save(d)
            other = HierarchicalService(
                make_algorithm("fedqs-sgd", hp), hp, params, 32,
                Topology.from_spec("hier:4", 32))
            with pytest.raises(ValueError, match="topology"):
                other.restore(d)


# ---------------------------------------------------------------------------
# engine integration
# ---------------------------------------------------------------------------
class TestEngineIntegration:
    def test_safl_engine_topology_matches_flat(self):
        from repro.core import SAFLEngine
        from repro.data import make_federated_data

        hp = FedQSHyperParams(buffer_k=4)
        spec = make_mlp_spec()

        def run(topology):
            data = make_federated_data("rwd", 12, sigma=1.0, seed=0,
                                       n_total=600)
            eng = SAFLEngine(data, spec, make_algorithm("fedqs-sgd", hp), hp,
                             seed=1, topology=topology)
            eng.run(5)
            return eng

        flat, hier = run(None), run("hier:4")
        assert flat.round == hier.round
        assert _rel_gap(flat.global_params, hier.global_params) <= 1e-5
        from repro.hier import HierarchicalService as HS

        assert isinstance(hier.service, HS)
        # edge assignment follows the sampled speeds (speed banding)
        topo = hier.service.topology
        per_edge = [hier.speeds[topo.client_edge == e] for e in range(4)]
        for a, b in zip(per_edge, per_edge[1:]):
            assert a.max() <= b.min()

    def test_cohort_engine_topology(self):
        from repro.scenarios import CohortEngine, Scenario

        hp = FedQSHyperParams(buffer_k=16)
        flat = CohortEngine(Scenario(), 200, hp=hp, cohort_k=16, seed=0,
                            eval_every=2)
        rf = flat.run(6)
        hier = CohortEngine(Scenario(), 200, hp=hp, cohort_k=16, seed=0,
                            eval_every=2, topology="hier:8x2")
        rh = hier.run(6)
        assert flat.round == hier.round == 6
        assert _rel_gap(flat.service.global_params,
                        hier.service.global_params) <= 1e-5
        assert rf.final_accuracy(3) == pytest.approx(rh.final_accuracy(3),
                                                     abs=1e-6)
