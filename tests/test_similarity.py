"""Mod-1 (global aggregation estimation) unit + property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, strategies as st
from hypothesis.extra import numpy as hnp

from repro.core.similarity import (
    cosine_similarity,
    euclidean_similarity,
    fused_dot_norms,
    get_similarity_fn,
    local_global_similarity,
    manhattan_similarity,
    pseudo_global_gradient,
)

vec = hnp.arrays(np.float32, st.integers(2, 64),
                 elements=st.floats(-10, 10, width=32))


def test_pseudo_global_gradient_is_model_difference():
    a = {"w": jnp.asarray([1.0, 2.0]), "b": jnp.asarray(3.0)}
    b = {"w": jnp.asarray([0.5, 1.0]), "b": jnp.asarray(1.0)}
    pg = pseudo_global_gradient(a, b)
    np.testing.assert_allclose(pg["w"], [0.5, 1.0])
    np.testing.assert_allclose(pg["b"], 2.0)


def test_cosine_self_similarity_is_one():
    v = jnp.asarray([1.0, -2.0, 3.0])
    assert float(cosine_similarity(v, v)) == pytest.approx(1.0, abs=1e-6)


def test_cosine_opposite_is_minus_one():
    v = jnp.asarray([1.0, -2.0, 3.0])
    assert float(cosine_similarity(v, -v)) == pytest.approx(-1.0, abs=1e-6)


@given(vec)
def test_cosine_bounded(a):
    b = a[::-1].copy() + 0.5
    s = float(cosine_similarity(jnp.asarray(a), jnp.asarray(b)))
    assert -1.0 - 1e-4 <= s <= 1.0 + 1e-4


@given(vec)
def test_distance_similarities_in_unit_interval(a):
    b = a * 0.5 + 1.0
    for fn in (euclidean_similarity, manhattan_similarity):
        s = float(fn(jnp.asarray(a), jnp.asarray(b)))
        assert 0.0 < s <= 1.0 + 1e-6


@given(vec)
def test_identical_vectors_maximize_every_metric(a):
    a_j = jnp.asarray(a)
    for name in ("cosine", "euclidean", "manhattan"):
        fn = get_similarity_fn(name)
        s_self = float(fn(a_j, a_j))
        s_other = float(fn(a_j, a_j + 1.0))
        assert s_self >= s_other - 1e-6


def test_unknown_similarity_raises():
    with pytest.raises(ValueError):
        get_similarity_fn("chebyshev")


def test_local_global_similarity_on_trees():
    upd = {"a": jnp.ones((3,)), "b": jnp.ones((2, 2))}
    s = local_global_similarity(upd, upd, "cosine")
    assert float(s) == pytest.approx(1.0, abs=1e-6)


def test_fused_dot_norms_matches_components():
    a = jnp.asarray([1.0, 2.0, 3.0])
    b = jnp.asarray([-1.0, 0.5, 2.0])
    dot, na, nb = fused_dot_norms(a, b)
    assert float(dot) == pytest.approx(float(jnp.vdot(a, b)))
    assert float(na) == pytest.approx(float(jnp.vdot(a, a)))
    assert float(nb) == pytest.approx(float(jnp.vdot(b, b)))
