"""Streaming SAFL aggregation service: triggers, admission, batched
aggregation parity, and stream-vs-virtual-clock equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import FedQSHyperParams, SAFLEngine, make_algorithm
from repro.core.aggregation import server_aggregate
from repro.core.types import ServerTable, Update, tree_weighted_sum
from repro.data import make_federated_data
from repro.models import make_mlp_spec
from repro.serve import (
    AdmitAll,
    CaptureStream,
    KBuffer,
    Quorum,
    StalenessAdmission,
    StreamingAggregator,
    TimeWindow,
    batched_weighted_sum,
    make_trigger,
    replay,
    synthetic_stream,
)


def _mk_update(cid=0, n_samples=50, stale_round=0, similarity=0.5, delta=None,
               params=None):
    return Update(cid=cid, n_samples=n_samples, stale_round=stale_round,
                  lr=0.1, similarity=similarity, feedback=False, speed_f=0.1,
                  delta=delta, params=params)


# ---------------------------------------------------------------------------
# trigger policies
# ---------------------------------------------------------------------------
class TestTriggers:
    def test_kbuffer_fires_at_k(self):
        t = KBuffer(3)
        buf = [_mk_update(i) for i in range(2)]
        assert not t.should_fire(buf, 0.0)
        buf.append(_mk_update(2))
        assert t.should_fire(buf, 0.0)

    def test_kbuffer_validates(self):
        with pytest.raises(ValueError):
            KBuffer(0)

    def test_timewindow_waits_for_window(self):
        t = TimeWindow(window=10.0, min_updates=2)
        buf = [_mk_update(0), _mk_update(1)]
        assert not t.should_fire(buf, 5.0)    # lazily opens at t=5
        assert not t.should_fire(buf, 14.0)   # 9 < 10 elapsed
        assert t.should_fire(buf, 15.0)       # 10 elapsed

    def test_timewindow_needs_min_updates(self):
        t = TimeWindow(window=1.0, min_updates=3)
        buf = [_mk_update(0)]
        assert not t.should_fire(buf, 100.0)

    def test_timewindow_rearms_lazily(self):
        """After a fire the window reopens at the NEXT submit, so an idle
        gap never makes the first new update fire on a stale window."""
        t = TimeWindow(window=10.0)
        buf = [_mk_update(0)]
        assert t.should_fire(buf, 0.0) is False
        assert t.should_fire(buf, 10.0)
        t.arm(10.0)
        assert not t.should_fire(buf, 50.0)   # long idle gap: reopens at 50
        assert not t.should_fire(buf, 59.0)
        assert t.should_fire(buf, 60.0)

    def test_quorum_grace_rearms_lazily(self):
        t = Quorum(k=4, quorum=3, grace=5.0)
        same = [_mk_update(0) for _ in range(4)]
        assert t.should_fire(same, 1.0) is False
        t.arm(6.0)
        assert not t.should_fire(same, 100.0)  # idle gap: grace restarts here
        assert t.should_fire(same, 105.5)

    def test_quorum_needs_distinct_clients(self):
        t = Quorum(k=4, quorum=3)
        same = [_mk_update(0) for _ in range(4)]          # 1 distinct client
        assert not t.should_fire(same, 0.0)
        mixed = [_mk_update(c) for c in (0, 0, 1, 2)]     # 3 distinct
        assert t.should_fire(mixed, 0.0)

    def test_quorum_grace_breaks_stalls(self):
        t = Quorum(k=4, quorum=3, grace=5.0)
        same = [_mk_update(0) for _ in range(4)]
        assert not t.should_fire(same, 1.0)   # opens at t=1
        assert t.should_fire(same, 6.5)       # grace expired

    def test_quorum_validates(self):
        with pytest.raises(ValueError):
            Quorum(k=2, quorum=3)

    def test_factory(self):
        assert make_trigger("kbuffer", k=5).k == 5
        with pytest.raises(ValueError):
            make_trigger("nope")


# ---------------------------------------------------------------------------
# admission control
# ---------------------------------------------------------------------------
class TestAdmission:
    def test_admit_all(self):
        u, v = AdmitAll().apply(_mk_update(stale_round=0), current_round=1000)
        assert u is not None and v.accepted

    def test_staleness_drop(self):
        pol = StalenessAdmission(tau_max=2, mode="drop")
        ok, v = pol.apply(_mk_update(stale_round=8), current_round=10)
        assert ok is not None and v.accepted            # tau=2 == tau_max
        gone, v = pol.apply(_mk_update(stale_round=7), current_round=10)
        assert gone is None and not v.accepted and "stale" in v.reason

    def test_staleness_downweight_scales_samples(self):
        pol = StalenessAdmission(tau_max=1, mode="downweight", decay=0.5)
        u, v = pol.apply(_mk_update(n_samples=100, stale_round=0), current_round=3)
        assert u is not None and v.accepted
        assert u.n_samples == 25                        # 100 * 0.5**(3-1)
        # floor at 1 so an admitted update never vanishes
        u2, _ = pol.apply(_mk_update(n_samples=2, stale_round=0), current_round=20)
        assert u2.n_samples == 1

    def test_validates(self):
        with pytest.raises(ValueError):
            StalenessAdmission(1, mode="explode")
        with pytest.raises(ValueError):
            StalenessAdmission(1, decay=0.0)


# ---------------------------------------------------------------------------
# service mechanics + aggregation parity
# ---------------------------------------------------------------------------
def _tiny_params(seed=0):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    return {"w": jax.random.normal(k1, (7, 5)), "b": jax.random.normal(k2, (5,))}


def _tiny_buffer(params, n=4, seed=1):
    key = jax.random.PRNGKey(seed)
    buf = []
    for i in range(n):
        key, sub = jax.random.split(key)
        delta = jax.tree_util.tree_map(
            lambda l, s=sub: 0.01 * jax.random.normal(s, l.shape), params)
        buf.append(_mk_update(cid=i, n_samples=50 + 10 * i, similarity=0.2 + 0.1 * i,
                              delta=delta,
                              params=jax.tree_util.tree_map(jnp.add, params, delta)))
    return buf


class TestService:
    def test_kbuffer_parity_with_server_aggregate(self):
        """One service round must equal a direct Mod-3 pass (§3.4)."""
        hp = FedQSHyperParams(buffer_k=4)
        params = _tiny_params()
        buf = _tiny_buffer(params, n=4)

        svc = StreamingAggregator(make_algorithm("fedqs-sgd", hp), hp, params, 8)
        reports = replay(svc, [(u, float(i)) for i, u in enumerate(buf)], flush=False)
        assert len(reports) == 1 and svc.round == 1

        want, want_table, _ = server_aggregate(
            make_algorithm("fedqs-sgd", hp).strategy, params, list(buf),
            ServerTable.init(8), hp, 8)
        for a, b in zip(jax.tree_util.tree_leaves(svc.global_params),
                        jax.tree_util.tree_leaves(want)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        np.testing.assert_array_equal(np.asarray(svc.table.counts),
                                      np.asarray(want_table.counts))

    @pytest.mark.parametrize("algo", ["fedqs-sgd", "fedqs-avg", "fedavg", "fedsgd"])
    def test_batched_path_matches_sequential(self, algo):
        """Stacked [K,D] aggregation ≡ sequential tree sum (fp32 tol)."""
        hp = FedQSHyperParams(buffer_k=4)
        params = _tiny_params()
        buf = _tiny_buffer(params, n=4)
        stream = [(u, float(i)) for i, u in enumerate(buf)]

        plain = StreamingAggregator(make_algorithm(algo, hp), hp, params, 8)
        fast = StreamingAggregator(make_algorithm(algo, hp), hp, params, 8,
                                   batched=True, use_kernel=False)
        replay(plain, stream, flush=False)
        replay(fast, stream, flush=False)
        for a, b in zip(jax.tree_util.tree_leaves(plain.global_params),
                        jax.tree_util.tree_leaves(fast.global_params)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=1e-6)

    def test_batched_weighted_sum_matches_tree_weighted_sum(self):
        trees = [_tiny_params(s) for s in range(3)]
        w = jnp.asarray([0.2, 0.5, 0.3])
        want = tree_weighted_sum(trees, w)
        for use_kernel in (False, True):  # jnp oracle and interpreted Pallas
            got = batched_weighted_sum(trees, w, use_kernel=use_kernel)
            for a, b in zip(jax.tree_util.tree_leaves(want),
                            jax.tree_util.tree_leaves(got)):
                np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                           rtol=1e-5, atol=1e-6)

    def test_staleness_admission_drops_in_stream(self):
        hp = FedQSHyperParams(buffer_k=2)
        params = _tiny_params()
        buf = _tiny_buffer(params, n=6)
        svc = StreamingAggregator(
            make_algorithm("fedavg", hp), hp, params, 8,
            admission=StalenessAdmission(tau_max=0, mode="drop"))
        # two clean rounds (stamped fresh), then two updates 3 rounds stale
        from dataclasses import replace
        for i, u in enumerate(buf[:4]):
            assert svc.submit(replace(u, stale_round=svc.round), now=float(i)).accepted
        assert svc.round == 2
        stale = [replace(u, stale_round=-3) for u in buf[4:]]
        for u in stale:
            res = svc.submit(u, now=9.0)
            assert not res.accepted and "stale" in res.reason
        assert svc.stats.dropped == 2 and svc.pending == 0

    def test_flush_forces_partial_round(self):
        hp = FedQSHyperParams(buffer_k=10)
        params = _tiny_params()
        svc = StreamingAggregator(make_algorithm("fedavg", hp), hp, params, 8)
        for i, u in enumerate(_tiny_buffer(params, n=3)):
            svc.submit(u, now=float(i))
        assert svc.round == 0 and svc.pending == 3
        rep = svc.flush(now=3.0)
        assert rep is not None and rep.n_updates == 3 and svc.round == 1
        assert svc.flush(now=4.0) is None  # empty buffer is a no-op

    def test_async_agg_matches_sync(self):
        hp = FedQSHyperParams(buffer_k=4)
        params = _tiny_params()
        buf = _tiny_buffer(params, n=8)
        stream = [(u, float(i)) for i, u in enumerate(buf)]
        sync = StreamingAggregator(make_algorithm("fedqs-sgd", hp), hp, params, 8)
        seen = []
        asyn = StreamingAggregator(make_algorithm("fedqs-sgd", hp), hp, params, 8,
                                   async_agg=True, on_round=seen.append)
        replay(sync, stream, flush=False)
        replay(asyn, stream, flush=False)
        asyn.close()
        assert asyn.round == sync.round == 2 and len(seen) == 2
        for a, b in zip(jax.tree_util.tree_leaves(sync.global_params),
                        jax.tree_util.tree_leaves(asyn.global_params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_async_flush_returns_report(self):
        """flush is a barrier: on an async service it joins the dispatched
        partial round and hands back its report (None = empty buffer only)."""
        hp = FedQSHyperParams(buffer_k=10)
        params = _tiny_params()
        svc = StreamingAggregator(make_algorithm("fedavg", hp), hp, params, 8,
                                  async_agg=True)
        for i, u in enumerate(_tiny_buffer(params, n=3)):
            svc.submit(u, now=float(i))
        rep = svc.flush(now=3.0)
        assert rep is not None and rep.n_updates == 3 and svc.round == 1
        svc.close()

    def test_checkpoint_roundtrip(self, tmp_path):
        hp = FedQSHyperParams(buffer_k=4)
        params = _tiny_params()
        svc = StreamingAggregator(make_algorithm("fedqs-sgd", hp), hp, params, 8)
        replay(svc, [(u, float(i)) for i, u in enumerate(_tiny_buffer(params, 4))],
               flush=False)
        svc.save(str(tmp_path / "ck"))
        svc2 = StreamingAggregator(make_algorithm("fedqs-sgd", hp), hp, params, 8)
        svc2.restore(str(tmp_path / "ck"))
        assert svc2.round == svc.round == 1
        np.testing.assert_array_equal(np.asarray(svc2.table.counts),
                                      np.asarray(svc.table.counts))
        for a, b in zip(jax.tree_util.tree_leaves(svc.global_params),
                        jax.tree_util.tree_leaves(svc2.global_params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_synthetic_stream_shape(self):
        params = _tiny_params()
        pairs = list(synthetic_stream(params, 6, 40, seed=3))
        assert len(pairs) == 40
        times = [t for _, t in pairs]
        assert all(a <= b for a, b in zip(times, times[1:]))  # arrival order
        assert {u.cid for u, _ in pairs} <= set(range(6))


# ---------------------------------------------------------------------------
# stream ≡ virtual clock (the acceptance bar)
# ---------------------------------------------------------------------------
class TestStreamEquivalence:
    def test_stream_replay_equals_virtual_clock(self):
        """Capturing the engine's submits and replaying them through a
        standalone service must reproduce the virtual-clock global model."""
        data = make_federated_data("rwd", 10, sigma=1.0, seed=0, n_total=1000)
        spec = make_mlp_spec()
        hp = FedQSHyperParams(buffer_k=4)
        eng = SAFLEngine(data, spec, make_algorithm("fedqs-sgd", hp), hp, seed=1)
        init = eng.global_params
        cap = CaptureStream()
        cap.wrap(eng.service)
        eng.run(5)

        svc = StreamingAggregator(make_algorithm("fedqs-sgd", hp), hp, init,
                                  data.n_clients)
        reports = replay(svc, cap.updates, flush=False)
        assert svc.round == eng.round == 5 and len(reports) == 5
        for a, b in zip(jax.tree_util.tree_leaves(eng.global_params),
                        jax.tree_util.tree_leaves(svc.global_params)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=0, atol=1e-6)
        np.testing.assert_array_equal(np.asarray(svc.table.counts),
                                      np.asarray(eng.table.counts))
