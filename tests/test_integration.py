"""Integration: end-to-end SAFL runs reproducing the paper's directional
claims at toy scale + checkpoint round-trips."""
import os

import numpy as np
import pytest

from repro.checkpoint import load_params, load_server_state, save_params, save_server_state
from repro.core import FedQSHyperParams, SAFLEngine, make_algorithm
from repro.data import make_federated_data
from repro.models import make_mlp_spec


@pytest.fixture(scope="module")
def noniid_cv():
    # strongly non-IID tabular stand-in (fast) — heterogeneity via sigma
    return make_federated_data("rwd", 12, sigma=1.4, seed=3, n_total=2400)


@pytest.fixture(scope="module")
def spec():
    return make_mlp_spec(hidden=24)


def _final_acc(data, spec, name, rounds=30, seed=5):
    hp = FedQSHyperParams(buffer_k=4, eta0=0.1)
    eng = SAFLEngine(data, spec, make_algorithm(name, hp), hp, seed=seed,
                     eval_every=2)
    return eng.run(rounds)


class TestPaperClaims:
    def test_fedqs_sgd_competitive_with_fedsgd(self, noniid_cv, spec):
        """Table 2 direction: FedQS-SGD ≥ FedSGD on non-IID SAFL (allow a
        small tolerance at toy scale)."""
        a = _final_acc(noniid_cv, spec, "fedqs-sgd").final_accuracy(6)
        b = _final_acc(noniid_cv, spec, "fedsgd").final_accuracy(6)
        assert a >= b - 0.03

    def test_fedqs_avg_competitive_with_fedavg(self, noniid_cv, spec):
        a = _final_acc(noniid_cv, spec, "fedqs-avg").final_accuracy(6)
        b = _final_acc(noniid_cv, spec, "fedavg").final_accuracy(6)
        assert a >= b - 0.03

    def test_training_actually_learns(self, noniid_cv, spec):
        res = _final_acc(noniid_cv, spec, "fedqs-sgd")
        assert res.best_accuracy() > 0.6  # planted logistic task is learnable

    def test_both_strategies_converge_to_similar_utility(self, noniid_cv, spec):
        """FedQS bridges the two strategies (the paper's headline)."""
        sgd = _final_acc(noniid_cv, spec, "fedqs-sgd").final_accuracy(6)
        avg = _final_acc(noniid_cv, spec, "fedqs-avg").final_accuracy(6)
        assert abs(sgd - avg) < 0.15


class TestCheckpoint:
    def test_params_roundtrip(self, tmp_path, spec):
        import jax
        params = spec.init(jax.random.PRNGKey(0))
        f = str(tmp_path / "p.npz")
        save_params(f, params)
        loaded = load_params(f, params)
        for a, b in zip(jax.tree_util.tree_leaves(params),
                        jax.tree_util.tree_leaves(loaded)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_server_state_roundtrip(self, tmp_path, noniid_cv, spec):
        hp = FedQSHyperParams(buffer_k=4)
        eng = SAFLEngine(noniid_cv, spec, make_algorithm("fedqs-sgd", hp), hp, seed=0)
        eng.run(4)
        save_server_state(str(tmp_path / "ck"), eng)

        eng2 = SAFLEngine(noniid_cv, spec, make_algorithm("fedqs-sgd", hp), hp, seed=0)
        load_server_state(str(tmp_path / "ck"), eng2)
        assert eng2.round == eng.round
        np.testing.assert_array_equal(np.asarray(eng2.table.counts),
                                      np.asarray(eng.table.counts))
        for a, b in zip(np.asarray(eng.table.sims), np.asarray(eng2.table.sims)):
            assert a == pytest.approx(b)

    def test_shape_mismatch_rejected(self, tmp_path, spec):
        import jax
        import jax.numpy as jnp
        params = spec.init(jax.random.PRNGKey(0))
        f = str(tmp_path / "p.npz")
        save_params(f, params)
        bad = jax.tree_util.tree_map(lambda x: jnp.zeros(x.shape + (1,)), params)
        with pytest.raises(ValueError):
            load_params(f, bad)
