"""Training-health plane: streaming detectors, on-kernel stats wiring,
flight recorder, monitor/Prometheus exposition (docs/OBSERVABILITY.md)."""
import json
import threading

import jax
import numpy as np
import pytest

from repro.core import FedQSHyperParams, make_algorithm
from repro.models import make_mlp_spec
from repro.serve import KBuffer, StreamingAggregator, replay, synthetic_stream
from repro.serve.stream import inject_norm_explosion
from repro.telemetry import (
    DEFAULT_DETECTORS,
    DetectorConfig,
    EwmaDetector,
    FlightRecorder,
    HealthMonitor,
    MetricsRegistry,
    Telemetry,
)
from repro.telemetry.health import STATS_FIELDS, _gini


@pytest.fixture(scope="module")
def mlp_params():
    return make_mlp_spec().init(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def stream(mlp_params):
    return list(synthetic_stream(mlp_params, 16, 120, seed=0))


def _service(mlp_params, telemetry=None, *, batched=True, k=5):
    hp = FedQSHyperParams(buffer_k=k)
    return StreamingAggregator(
        make_algorithm("fedqs-sgd", hp), hp, mlp_params, 16,
        trigger=KBuffer(k), batched=batched, telemetry=telemetry)


class TestEwmaDetector:
    def test_silent_during_warmup(self):
        det = EwmaDetector("x", DetectorConfig(warmup=5))
        # a huge excursion inside the warmup window must not alert
        assert all(det.observe(v) is None for v in [1, 1, 1, 1, 1e9])

    def test_spike_alerts_after_warmup(self):
        det = EwmaDetector("x", DetectorConfig())
        for _ in range(10):
            assert det.observe(1.0) is None
        sev, z, mean, std = det.observe(100.0)
        assert sev == "critical" and z > 6.0
        assert mean == pytest.approx(1.0, abs=0.1)  # EWMA warming from 0

    def test_warn_vs_critical_thresholds(self):
        cfg = DetectorConfig(rel_floor=0.0, abs_floor=1.0, alpha=0.0)
        det = EwmaDetector("x", cfg)
        for _ in range(6):
            det.observe(0.0)
        sev, z, _, _ = det.observe(4.0)   # z = 4 with std floored at 1
        assert sev == "warn" and z == pytest.approx(4.0)
        det2 = EwmaDetector("x", cfg)
        for _ in range(6):
            det2.observe(0.0)
        sev2, z2, _, _ = det2.observe(8.0)
        assert sev2 == "critical" and z2 == pytest.approx(8.0)

    def test_direction_low_alerts_on_drops_only(self):
        det = EwmaDetector("acc", DetectorConfig(direction="low",
                                                 abs_floor=0.01))
        for _ in range(10):
            det.observe(0.9)
        assert det.observe(5.0) is None       # a rise is fine for "low"
        det2 = EwmaDetector("acc", DetectorConfig(direction="low",
                                                  abs_floor=0.01))
        for _ in range(10):
            det2.observe(0.9)
        assert det2.observe(0.1) is not None  # a collapse is not

    def test_cooldown_debounces(self):
        det = EwmaDetector("x", DetectorConfig(cooldown=5, alpha=0.0,
                                               rel_floor=0.0, abs_floor=1.0))
        for _ in range(6):
            det.observe(0.0)
        hits = [det.observe(100.0) is not None for _ in range(5)]
        # one alert, then the cooldown window swallows the rest
        assert hits == [True, False, False, False, False]
        assert det.observe(100.0) is not None  # window over → alert again

    def test_constant_series_never_alerts(self):
        det = EwmaDetector("x", DetectorConfig())
        # fp-noise around a constant stays inside the rel_floor envelope
        rng = np.random.default_rng(0)
        vals = 5.0 + rng.normal(0.0, 1e-9, 200)
        assert all(det.observe(v) is None for v in vals)

    def test_gini(self):
        assert _gini([5, 5, 5, 5]) == pytest.approx(0.0)
        assert _gini([0, 0, 0, 100]) == pytest.approx(0.75)
        assert _gini([]) == 0.0


class TestHealthMonitor:
    def test_unknown_signal_ignored(self):
        hm = HealthMonitor()
        assert hm.observe("not-a-detector", 1.0) is None
        assert hm.alerts == []

    def test_alert_emits_event_and_counters(self):
        tel = Telemetry.in_memory(health=True)
        hm = tel.health
        for r in range(10):
            hm.observe("loss", 1.0, t=float(r), round=r)
        alert = hm.observe("loss", 50.0, t=10.0, round=10)
        assert alert is not None and alert.severity == "critical"
        recs = [r for r in tel.ring.records if r["e"] == "health-alert"]
        assert len(recs) == 1 and recs[0]["detector"] == "loss"
        assert tel.metrics.get("health.alerts_critical").value == 1
        tel.close()

    def test_configure_retunes_detector(self):
        hm = HealthMonitor()
        hm.configure("loss", z_warn=1e9, z_crit=1e12)
        for r in range(10):
            hm.observe("loss", 1.0, round=r)
        assert hm.observe("loss", 1e6, round=10) is None

    def test_observe_round_maps_stats_fields(self):
        hm = HealthMonitor()
        stats = dict(zip(STATS_FIELDS, [1.0, 2.0, 3.0, 16.0, 4.0]))
        vec = [stats[f] for f in STATS_FIELDS]
        hm.observe_round(t=0.0, round=0, mean_staleness=2.0, stats=vec)
        assert hm.detectors["update_norm"].mean > 0   # fed sqrt(max_sq)=4
        assert hm.detectors["dispersion"].count == 1
        assert hm.detectors["staleness"].count == 1

    def test_observe_metrics_quadrant_skew(self):
        hm = HealthMonitor()
        hm.observe_metrics(t=0.0, round=0, loss=1.0, accuracy=0.5,
                           quadrant_counts={"0": 5, "1": 5, "2": 5, "3": 5})
        assert hm.detectors["quadrant_skew"].count == 1
        assert hm.detectors["loss"].count == 1
        assert hm.detectors["accuracy"].count == 1

    def test_default_detector_set_documented(self):
        assert set(DEFAULT_DETECTORS) == {
            "loss", "accuracy", "update_norm", "dispersion", "staleness",
            "quadrant_skew"}


class TestServiceWiring:
    def test_health_service_bit_identical_and_silent(self, mlp_params,
                                                     stream):
        plain = _service(mlp_params)
        tel = Telemetry.in_memory(health=True)
        health = _service(mlp_params, tel)
        replay(plain, stream)
        replay(health, stream)
        for a, b in zip(jax.tree_util.tree_leaves(plain.global_params),
                        jax.tree_util.tree_leaves(health.global_params)):
            assert jnp_equal(a, b)
        assert tel.health.alerts == []
        # the fused stats variant actually fed the detectors
        assert tel.health.detectors["update_norm"].count == health.round
        assert tel.health.detectors["dispersion"].count == health.round
        assert tel.health.detectors["staleness"].count == health.round
        tel.close()

    def test_sequential_path_feeds_staleness_only(self, mlp_params, stream):
        tel = Telemetry.in_memory(health=True)
        svc = _service(mlp_params, tel, batched=False)
        replay(svc, stream[:40])
        assert tel.health.detectors["staleness"].count == svc.round
        # no stats vector on the sequential path — and no crash either
        assert tel.health.detectors["update_norm"].count == 0
        tel.close()

    def test_injected_explosion_alerts_within_five_rounds(self, mlp_params,
                                                          tmp_path):
        flight = str(tmp_path / "flight.jsonl")
        tel = Telemetry.in_memory(health=True, flightrec=flight)
        svc = _service(mlp_params, tel)
        stream = list(inject_norm_explosion(
            synthetic_stream(mlp_params, 16, 120, seed=0),
            after=50, scale=100.0))
        replay(svc, stream)
        inj_round = 50 // 5 + 1
        assert tel.health.alerts, "seeded divergence raised no alert"
        first = min(a.round for a in tel.health.alerts)
        assert 0 <= first - inj_round <= 5
        # the alert triggered an on-the-spot black-box dump
        dump = [json.loads(l) for l in open(flight) if l.strip()]
        assert dump[-1]["e"] == "flight-dump"
        assert dump[-1]["reason"] == "alert"
        tel.close()


def jnp_equal(a, b):
    return bool(np.array_equal(np.asarray(a), np.asarray(b)))


class TestFlightRecorder:
    def test_ring_bounded_and_counts_evictions(self, tmp_path):
        fr = FlightRecorder(str(tmp_path / "f.jsonl"), capacity=8,
                            auto_dump=False)
        for i in range(20):
            fr.write({"e": "x", "i": i})
        assert len(fr) == 8
        assert fr.evicted == 12

    def test_dump_round_trips_with_meta_record(self, tmp_path):
        path = str(tmp_path / "f.jsonl")
        fr = FlightRecorder(path, capacity=8, auto_dump=False)
        for i in range(5):
            fr.write({"e": "x", "i": i})
        out = fr.dump(reason="alert", round=3, t=1.0)
        assert out == path
        recs = [json.loads(l) for l in open(path) if l.strip()]
        assert [r.get("i") for r in recs[:-1]] == list(range(5))
        meta = recs[-1]
        assert meta["e"] == "flight-dump" and meta["reason"] == "alert"
        assert meta["n_records"] == 5 and meta["round"] == 3

    def test_successive_dumps_get_distinct_paths(self, tmp_path):
        path = str(tmp_path / "f.jsonl")
        fr = FlightRecorder(path, capacity=8, auto_dump=False)
        fr.write({"e": "x"})
        first = fr.dump(reason="alert")
        second = fr.dump(reason="alert")
        assert first == path and second == f"{path}.1"

    def test_empty_ring_dump_is_noop(self, tmp_path):
        fr = FlightRecorder(str(tmp_path / "f.jsonl"), auto_dump=False)
        assert fr.dump(reason="alert") is None

    def test_hub_close_dumps_once_and_is_idempotent(self, tmp_path):
        path = str(tmp_path / "f.jsonl")
        tel = Telemetry.in_memory(flightrec=path)
        from repro.telemetry import RoundFired

        tel.emit(RoundFired(t=0.0, round=1, n_updates=5, n_distinct=5,
                            mean_staleness=0.0, max_staleness=0,
                            dropped_since_last=0, trigger="kbuffer",
                            agg_seconds=0.0))
        tel.close()
        tel.close()  # second close must be a no-op, not a second dump
        recs = [json.loads(l) for l in open(path) if l.strip()]
        assert recs[-1]["e"] == "flight-dump"
        assert recs[-1]["reason"] == "close"
        assert tel.flightrec.dumps == 1

    def test_concurrent_close_is_safe(self, tmp_path):
        tel = Telemetry.to_jsonl(str(tmp_path / "t.jsonl"),
                                 flightrec=str(tmp_path / "f.jsonl"))
        from repro.telemetry import RoundFired

        for r in range(50):
            tel.emit(RoundFired(t=float(r), round=r, n_updates=5,
                                n_distinct=5, mean_staleness=0.0,
                                max_staleness=0, dropped_since_last=0,
                                trigger="kbuffer", agg_seconds=0.0))
        errors = []

        def close():
            try:
                tel.close()
            except Exception as exc:  # pragma: no cover - the assertion
                errors.append(exc)

        threads = [threading.Thread(target=close) for _ in range(8)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert not errors
        assert tel.flightrec.dumps == 1


class TestConfigureBounds:
    def test_override_before_creation_wins(self):
        reg = MetricsRegistry()
        reg.configure_bounds("serve.staleness", (0, 10, 100))
        h = reg.histogram("serve.staleness", (0, 1, 2))
        assert h.bounds == (0.0, 10.0, 100.0)

    def test_after_materialization_raises(self):
        reg = MetricsRegistry()
        reg.histogram("h", (0, 1, 2))
        with pytest.raises(ValueError):
            reg.configure_bounds("h", (0, 10))

    def test_same_bounds_reassertion_is_noop(self):
        reg = MetricsRegistry()
        reg.histogram("h", (0, 1, 2))
        reg.configure_bounds("h", (0, 1, 2))  # must not raise

    def test_overflow_bucket_counts(self):
        reg = MetricsRegistry()
        h = reg.histogram("h", (0, 1, 2))
        for v in (0, 1, 2, 50, 99):
            h.observe(v)
        assert h.counts[-1] == 2  # 50 and 99 overflow the ladder


class TestMonitorAndProm:
    def test_prometheus_text_shapes(self):
        from repro.launch.monitor import prometheus_text

        reg = MetricsRegistry()
        reg.counter("serve.accepted").inc(7)
        reg.gauge("buffer.depth").set(3.5)
        h = reg.histogram("serve.staleness", (0, 1, 2))
        for v in (0, 0, 1, 5):
            h.observe(v)
        text = prometheus_text(reg.snapshot())
        assert "# TYPE repro_serve_accepted counter" in text
        assert "repro_serve_accepted 7" in text
        assert "repro_buffer_depth 3.5" in text
        # cumulative le buckets + overflow-inclusive +Inf/_count
        assert 'repro_serve_staleness_bucket{le="0"} 2' in text
        assert 'repro_serve_staleness_bucket{le="1"} 3' in text
        assert 'repro_serve_staleness_bucket{le="2"} 3' in text
        assert 'repro_serve_staleness_bucket{le="+Inf"} 4' in text
        assert "repro_serve_staleness_count 4" in text
        assert "repro_serve_staleness_sum 6" in text

    def test_monitor_state_folds_stream(self, tmp_path, mlp_params, stream):
        from repro.launch.monitor import monitor, render

        path = str(tmp_path / "run.jsonl")
        tel = Telemetry.to_jsonl(path, health=True)
        svc = _service(mlp_params, tel)
        replay(svc, stream[:60])
        tel.close()
        state = monitor(path, out=open("/dev/null", "w"))
        assert state.admitted == 60
        assert state.rounds == svc.round
        assert state.snapshot is not None
        frame = render(state, path=path)
        assert "OK — no alerts" in frame
        assert "staleness" in frame

    def test_monitor_tolerates_torn_tail(self, tmp_path):
        from repro.launch.monitor import MonitorState, _drain

        path = tmp_path / "run.jsonl"
        path.write_text('{"e": "update-admitted", "t": 1.0, "staleness": 0}\n'
                        '{"e": "round-fired", "t": 2.0, "round": 1')  # torn
        state = MonitorState()
        with open(path) as fh:
            _drain(fh, state)
            assert state.admitted == 1 and state.rounds == 0
            # the writer finishes the line → the next pass picks it up
            with open(path, "a") as app:
                app.write(', "agg_seconds": 0.5}\n')
            _drain(fh, state)
        assert state.rounds == 1


class TestHealthReport:
    def _records(self, mlp_params, stream, *, inject=False, tmp_path=None):
        tel = Telemetry.in_memory(health=True)
        svc = _service(mlp_params, tel)
        if inject:
            stream = list(inject_norm_explosion(iter(stream), after=50,
                                                scale=100.0))
        replay(svc, stream)
        records = list(tel.ring.records)
        tel.close()
        records.append(
            {"e": "metrics-snapshot", "t": None,
             "metrics": tel.metrics.snapshot()})
        return records

    def test_alert_free_run_renders_quiet_health_section(self, mlp_params,
                                                         stream):
        from repro.telemetry.report import experiment_report

        report = experiment_report(self._records(mlp_params, stream))
        assert "## Health / alerts" in report
        assert "no alerts fired" in report

    def test_alert_heavy_run_renders_alert_table(self, mlp_params, stream):
        from repro.telemetry.report import experiment_report

        report = experiment_report(
            self._records(mlp_params, stream, inject=True))
        assert "## Health / alerts" in report
        assert "critical" in report
        assert "`update_norm`" in report or "`dispersion`" in report

    def test_health_section_absent_without_plane(self, mlp_params, stream):
        from repro.telemetry.report import experiment_report

        tel = Telemetry.in_memory()
        svc = _service(mlp_params, tel)
        replay(svc, stream[:30])
        report = experiment_report(list(tel.ring.records))
        tel.close()
        assert "## Health / alerts" not in report

    def test_tolerant_loader_skips_corrupt_tail(self, tmp_path):
        from repro.telemetry.report import load_events, load_events_tolerant

        path = tmp_path / "e.jsonl"
        path.write_text('{"e": "round-fired", "round": 1}\n'
                        'not json at all\n'
                        '{"e": "round-f')  # torn mid-crash
        records, skipped = load_events_tolerant(str(path))
        assert len(records) == 1 and skipped == 2
        with pytest.raises(ValueError):
            load_events(str(path))  # the strict loader still rejects

    def test_postmortem_from_truncated_dump(self, mlp_params, tmp_path):
        from repro.telemetry.report import postmortem_report

        flight = str(tmp_path / "flight.jsonl")
        tel = Telemetry.in_memory(health=True, flightrec=flight)
        svc = _service(mlp_params, tel)
        stream = list(inject_norm_explosion(
            synthetic_stream(mlp_params, 16, 80, seed=0),
            after=30, scale=100.0))
        replay(svc, stream)
        tel.close()
        # simulate a crash mid-write: chop the dump's final line in half
        raw = open(flight, "rb").read()
        open(flight, "wb").write(raw[: int(len(raw) * 0.98)])
        report = postmortem_report(flight)
        assert "black box" in report
        assert "unreadable" in report or "records recovered" in report
        assert "health-alert" in report or "Health / alerts" in report
